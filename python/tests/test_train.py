"""Training loop: loss decreases, QAT works, determinism."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import dataset, model, pa_model, train
from compile.kernels.quant import QSpec


@pytest.fixture(scope="module")
def setup():
    pa = pa_model.ganlike_spec()
    x = dataset.generate_ofdm(dataset.OfdmConfig(n_symbols=8, seed=0))
    frames = dataset.frames_from_signal(x, 50)
    params = model.init_params(model.ModelConfig(), jax.random.PRNGKey(0))
    return pa, frames, params


class TestLoss:
    def test_loss_positive_finite(self, setup):
        pa, frames, params = setup
        l = float(train.dpd_loss(params, jnp.asarray(frames, jnp.float32), pa, None, "hard"))
        assert np.isfinite(l) and l > 0

    def test_loss_differentiable(self, setup):
        pa, frames, params = setup
        g = jax.grad(lambda p: train.dpd_loss(p, jnp.asarray(frames[:8], jnp.float32), pa, None, "hard"))(params)
        for k, v in g.items():
            assert np.isfinite(np.asarray(v)).all(), k
            assert np.abs(np.asarray(v)).max() > 0, f"zero grad for {k}"

    def test_qat_loss_differentiable(self, setup):
        """STE keeps gradients alive through fake-quant."""
        pa, frames, params = setup
        spec = QSpec(12)
        g = jax.grad(lambda p: train.dpd_loss(p, jnp.asarray(frames[:8], jnp.float32), pa, spec, "hard"))(params)
        nonzero = sum(float(np.abs(np.asarray(v)).max()) > 0 for v in g.values())
        assert nonzero >= 5  # nearly all tensors receive gradient


class TestTrain:
    def test_loss_decreases(self, setup):
        pa, frames, params = setup
        _, hist = train.train(dict(params), frames, pa, train.TrainConfig(steps=60, batch=16))
        first = np.mean(hist["loss"][:10])
        last = np.mean(hist["loss"][-10:])
        assert last < first * 0.8, f"{first} -> {last}"

    def test_deterministic(self, setup):
        pa, frames, params = setup
        cfg = train.TrainConfig(steps=15, batch=8, seed=3)
        p1, h1 = train.train(dict(params), frames, pa, cfg)
        p2, h2 = train.train(dict(params), frames, pa, cfg)
        assert h1["loss"] == h2["loss"]
        for k in p1:
            np.testing.assert_array_equal(np.asarray(p1[k]), np.asarray(p2[k]))

    def test_qat_trains(self, setup):
        pa, frames, params = setup
        spec = QSpec(10)
        _, hist = train.train(
            dict(params), frames, pa, train.TrainConfig(steps=40, batch=16), spec=spec, act="lut"
        )
        assert hist["loss"][-1] < hist["loss"][0]


class TestNmse:
    def test_nmse_zero_error(self):
        t = np.random.default_rng(0).normal(size=(100, 2))
        assert train.nmse_db(t, t) == -np.inf or train.nmse_db(t, t) < -200

    def test_nmse_known_value(self):
        t = np.ones((10, 2))
        y = np.ones((10, 2)) * 1.1
        assert abs(train.nmse_db(y, t) - 10 * np.log10(0.01)) < 1e-9
