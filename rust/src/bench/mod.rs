//! Criterion-free benchmark harness (offline build has no criterion).
//!
//! `time_it` runs a closure with warmup and repeated timed iterations,
//! reporting mean/median/min and a robust std estimate. Used by every
//! `benches/` target (declared with `harness = false`).

use std::time::{Duration, Instant};

/// Result of a timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    /// median absolute deviation (robust spread)
    pub mad: Duration,
}

impl BenchResult {
    /// Throughput given work items per iteration.
    pub fn per_second(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<40} {:>10.3?} mean  {:>10.3?} median  {:>10.3?} min  (n={})",
            self.name, self.mean, self.median, self.min, self.iters
        )
    }
}

/// Time `f`, auto-scaling iteration count to fill ~`budget`.
pub fn time_it<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(100));
    let target_iters = (budget.as_secs_f64() / once.as_secs_f64()).clamp(3.0, 1000.0) as usize;

    let mut times: Vec<Duration> = Vec::with_capacity(target_iters);
    for _ in 0..target_iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed());
    }
    times.sort();
    let n = times.len();
    let median = times[n / 2];
    let min = times[0];
    let mean = times.iter().sum::<Duration>() / n as u32;
    let mut devs: Vec<Duration> = times
        .iter()
        .map(|&t| if t > median { t - median } else { median - t })
        .collect();
    devs.sort();
    let mad = devs[n / 2];
    BenchResult { name: name.to_string(), iters: n, mean, median, min, mad }
}

/// Convenience wrapper printing the result.
pub fn bench<F: FnMut()>(name: &str, f: F) -> BenchResult {
    let r = time_it(name, Duration::from_millis(300), f);
    println!("{}", r.summary());
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = time_it("spin", Duration::from_millis(20), || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters >= 3);
        assert!(r.min <= r.median && r.median <= r.mean * 3);
    }

    #[test]
    fn per_second_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean: Duration::from_millis(10),
            median: Duration::from_millis(10),
            min: Duration::from_millis(10),
            mad: Duration::ZERO,
        };
        assert!((r.per_second(100.0) - 10_000.0).abs() < 1e-6);
    }
}
