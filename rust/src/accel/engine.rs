//! Cycle-accurate DPD-NeuralEngine simulator.
//!
//! Executes the FSM schedule sample by sample on the modelled units
//! (weight buffer, preprocessor, PE arrays, activation units, hidden
//! double-buffer), producing output codes that are **bit-exact** with
//! the functional model `dpd::qgru::QGruDpd` (cross-checked by tests)
//! while accounting cycles, unit activity and memory accesses for the
//! power model.

use anyhow::Result;

use super::act_unit::{ActImpl, ActUnit};
use super::buffers::{HiddenBuffer, WeightBuffer};
use super::fsm::{self, HwConfig};
use super::ops::ModelDims;
use super::pe::MacPe;
use super::preproc::Preprocessor;
use crate::dpd::weights::QGruWeights;
use crate::fixed::ops::{requantize, rshift_round, saturate_i64};
use crate::fixed::QSpec;

/// Activity statistics accumulated over a run.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub samples: u64,
    pub cycles: u64,
    pub macs: u64,
    pub alu_ops: u64,
    pub act_ops: u64,
    pub weight_reads: u64,
    pub hidden_reads: u64,
    pub hidden_writes: u64,
}

impl EngineStats {
    /// Steady-state cycles per sample (must equal the FSM II).
    pub fn cycles_per_sample(&self) -> f64 {
        self.cycles as f64 / self.samples as f64
    }
}

/// The simulator.
pub struct CycleAccurateEngine {
    pub cfg: HwConfig,
    pub dims: ModelDims,
    spec: QSpec,
    weights: WeightBuffer,
    hidden: HiddenBuffer,
    preproc: Preprocessor,
    act: ActUnit,
    /// one representative PE per array for arithmetic (the arrays are
    /// SIMD-identical; per-PE replication would only burn host time)
    pe: MacPe,
    stats: EngineStats,
    // scratch
    gi: Vec<i32>,
    gh: Vec<i32>,
    r: Vec<i32>,
    z: Vec<i32>,
    n: Vec<i32>,
}

impl CycleAccurateEngine {
    pub fn new(w: &QGruWeights, act_impl: ActImpl, cfg: HwConfig) -> CycleAccurateEngine {
        let dims = ModelDims { features: w.features, hidden: w.hidden };
        let spec = w.spec;
        CycleAccurateEngine {
            cfg,
            dims,
            spec,
            weights: WeightBuffer::load(w),
            hidden: HiddenBuffer::new(w.hidden),
            preproc: Preprocessor::new(spec),
            act: ActUnit::new(spec, act_impl),
            pe: MacPe::new(spec),
            stats: EngineStats::default(),
            gi: vec![0; 3 * w.hidden],
            gh: vec![0; 3 * w.hidden],
            r: vec![0; w.hidden],
            z: vec![0; w.hidden],
            n: vec![0; w.hidden],
        }
    }

    pub fn reset(&mut self) {
        self.hidden.reset();
    }

    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    pub fn spec(&self) -> QSpec {
        self.spec
    }

    /// Snapshot the architectural hidden state h_{t-1} between samples
    /// (everything a lane needs to resume this stream elsewhere).
    pub fn hidden_state(&self) -> Vec<i32> {
        self.hidden.snapshot()
    }

    /// Restore a snapshot from [`CycleAccurateEngine::hidden_state`].
    /// Activity counters are untouched — they track total unit work,
    /// not stream identity.
    pub fn set_hidden_state(&mut self, h: &[i32]) -> Result<()> {
        self.hidden.restore(h)
    }

    /// Process one sample through the full FSM window.
    /// Returns the predistorted I/Q codes.
    pub fn step(&mut self, iq: [i32; 2]) -> Result<[i32; 2]> {
        let h = self.dims.hidden;
        let f = self.spec.frac();
        let one = 1i64 << f;

        // c0-c1: preprocessor
        let x = self.preproc.features(iq);

        // c2-c4: input array (bias preload + 4 MACs per row)
        for row in 0..3 * h {
            let b = self.weights.b_ih(row);
            self.pe.preload_bias(b);
            for col in 0..self.dims.features {
                let w = self.weights.w_ih(row, col);
                self.pe.mac(w, x[col]);
            }
            self.gi[row] = self.pe.readout();
        }
        // c2-c4: hidden array (reads h_{t-1} from the front buffer)
        for row in 0..3 * h {
            let b = self.weights.b_hh(row);
            self.pe.preload_bias(b);
            for col in 0..h {
                let w = self.weights.w_hh(row, col);
                let hv = self.hidden.read(col);
                self.pe.mac(w, hv);
            }
            self.gh[row] = self.pe.readout();
        }

        // c5: r/z gate adds + sigmoids
        for k in 0..h {
            let pre_r = saturate_i64(self.gi[k] as i64 + self.gh[k] as i64, self.spec);
            self.r[k] = self.act.sigmoid(pre_r);
            let pre_z = saturate_i64(self.gi[h + k] as i64 + self.gh[h + k] as i64, self.spec);
            self.z[k] = self.act.sigmoid(pre_z);
            self.stats.alu_ops += 2;
        }
        // c6: rh mul + n add ; c7: tanh
        for k in 0..h {
            let rh = requantize(self.r[k] as i64 * self.gh[2 * h + k] as i64, f, self.spec);
            let pre_n = saturate_i64(self.gi[2 * h + k] as i64 + rh as i64, self.spec);
            self.n[k] = self.act.tanh(pre_n);
            self.stats.alu_ops += 2;
        }
        // c7-c9: hidden update, staged into the back buffer, commit
        for k in 0..h {
            let zn = rshift_round((one - self.z[k] as i64) * self.n[k] as i64, f);
            let zh = rshift_round(self.z[k] as i64 * self.hidden.read(k) as i64, f);
            let hv = saturate_i64(zn + zh, self.spec);
            self.hidden.write(k, hv)?;
            self.stats.alu_ops += 4;
        }
        self.hidden.commit();

        // c10-c12: FC + residual (reads the *new* h)
        let mut y = [0i32; 2];
        for (o, out) in y.iter_mut().enumerate() {
            let b = self.weights.b_fc(o);
            self.pe.preload_bias(b);
            for col in 0..h {
                let w = self.weights.w_fc(o, col);
                let hv = self.hidden.read(col);
                self.pe.mac(w, hv);
            }
            let fc = self.pe.readout();
            *out = saturate_i64(fc as i64 + iq[o] as i64, self.spec);
            self.stats.alu_ops += 1;
        }

        self.stats.samples += 1;
        self.stats.cycles += fsm::II_CYCLES as u64;
        Ok(y)
    }

    /// Run a burst of codes (resets first). Refreshes the aggregated
    /// counters from the unit-local ones at the end.
    pub fn run_codes(&mut self, iq: &[[i32; 2]]) -> Result<Vec<[i32; 2]>> {
        self.reset();
        let mut out = Vec::with_capacity(iq.len());
        for &s in iq {
            out.push(self.step(s)?);
        }
        self.sync_stats();
        Ok(out)
    }

    fn sync_stats(&mut self) {
        self.stats.macs = self.pe.mac_count;
        self.stats.act_ops = self.act.sigmoid_count + self.act.tanh_count;
        self.stats.weight_reads = self.weights.reads;
        self.stats.hidden_reads = self.hidden.reads;
        self.stats.hidden_writes = self.hidden.writes;
        // preprocessor ops fold into alu accounting
        self.stats.alu_ops += self.preproc.op_count;
        self.preproc.op_count = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpd::qgru::{ActKind, LutTables, QGruDpd};
    use crate::util::Rng;

    fn rand_qweights(seed: u64, spec: QSpec) -> QGruWeights {
        let mut rng = Rng::new(seed);
        let hidden = 10;
        let bound = (0.32 * spec.scale()) as i64;
        let mut gen =
            |n: usize| -> Vec<i32> { (0..n).map(|_| rng.int_in(-bound, bound) as i32).collect() };
        QGruWeights {
            hidden,
            features: 4,
            spec,
            w_ih: gen(120),
            b_ih: gen(30),
            w_hh: gen(300),
            b_hh: gen(30),
            w_fc: gen(20),
            b_fc: gen(2),
        }
    }

    #[test]
    fn bit_exact_with_functional_model_hard() {
        for bits in [8u32, 12, 16] {
            let spec = QSpec::new(bits).unwrap();
            let w = rand_qweights(bits as u64, spec);
            let mut sim = CycleAccurateEngine::new(&w, ActImpl::Hard, HwConfig::default());
            let mut func = QGruDpd::new(w, ActKind::Hard);
            let mut rng = Rng::new(1000 + bits as u64);
            let x: Vec<[i32; 2]> = (0..300)
                .map(|_| {
                    [
                        rng.int_in(spec.qmin() as i64, spec.qmax() as i64) as i32,
                        rng.int_in(spec.qmin() as i64, spec.qmax() as i64) as i32,
                    ]
                })
                .collect();
            let a = sim.run_codes(&x).unwrap();
            let b = func.run_codes(&x);
            assert_eq!(a, b, "cycle sim diverged from functional model at {bits} bits");
        }
    }

    #[test]
    fn bit_exact_with_functional_model_lut() {
        let spec = QSpec::Q12;
        let w = rand_qweights(9, spec);
        let mut sim = CycleAccurateEngine::new(
            &w,
            ActImpl::Lut(LutTables::default_for(spec)),
            HwConfig::default(),
        );
        let mut func = QGruDpd::new(w, ActKind::Lut(LutTables::default_for(spec)));
        let mut rng = Rng::new(77);
        let x: Vec<[i32; 2]> = (0..200)
            .map(|_| [rng.int_in(-900, 900) as i32, rng.int_in(-900, 900) as i32])
            .collect();
        assert_eq!(sim.run_codes(&x).unwrap(), func.run_codes(&x));
    }

    #[test]
    fn hidden_snapshot_resumes_the_stream() {
        let spec = QSpec::Q12;
        let w = rand_qweights(6, spec);
        let mut sim = CycleAccurateEngine::new(&w, ActImpl::Hard, HwConfig::default());
        let mut rng = Rng::new(61);
        for _ in 0..40 {
            sim.step([rng.int_in(-800, 800) as i32, rng.int_in(-800, 800) as i32]).unwrap();
        }
        let snap = sim.hidden_state();
        let probe = [[100, -50], [-300, 20], [7, 900]];
        let a: Vec<_> = probe.iter().map(|&s| sim.step(s).unwrap()).collect();
        // restoring the snapshot replays the identical future — the
        // front buffer is the whole architectural state between samples
        sim.set_hidden_state(&snap).unwrap();
        let b: Vec<_> = probe.iter().map(|&s| sim.step(s).unwrap()).collect();
        assert_eq!(a, b);
        assert!(sim.set_hidden_state(&[0; 3]).is_err());
    }

    #[test]
    fn cycle_accounting_matches_ii() {
        let spec = QSpec::Q12;
        let w = rand_qweights(3, spec);
        let mut sim = CycleAccurateEngine::new(&w, ActImpl::Hard, HwConfig::default());
        let x = vec![[100, -100]; 64];
        sim.run_codes(&x).unwrap();
        assert_eq!(sim.stats().cycles_per_sample(), fsm::II_CYCLES as f64);
    }

    #[test]
    fn activity_counters_per_sample() {
        let spec = QSpec::Q12;
        let w = rand_qweights(4, spec);
        let mut sim = CycleAccurateEngine::new(&w, ActImpl::Hard, HwConfig::default());
        let n = 50u64;
        let x = vec![[50, 60]; 50];
        sim.run_codes(&x).unwrap();
        let s = sim.stats();
        // per sample: 120 + 300 + 20 MACs
        assert_eq!(s.macs, n * 440);
        // 30 activations
        assert_eq!(s.act_ops, n * 30);
        // weight reads: all 502 words touched every sample
        // (440 weights + 62 biases)
        assert_eq!(s.weight_reads, n * 502);
        // hidden reads: 300 (matvec) + 10 (z.h) + 20 (fc)
        assert_eq!(s.hidden_reads, n * 330);
        assert_eq!(s.hidden_writes, n * 10);
    }

    #[test]
    fn golden_artifacts_if_present() {
        // bit-exactness against the jax oracle through the artifact
        // golden vectors (same as tests/golden_parity.rs but for the
        // cycle engine)
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping (no artifacts)");
            return;
        }
        let (w, j) =
            crate::dpd::weights::QGruWeights::load_golden(&dir.join("golden/g_b12_hard.json"))
                .unwrap();
        let iq: Vec<[i32; 2]> = j
            .get("iq_codes")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|r| {
                let v = r.as_i32_vec().unwrap();
                [v[0], v[1]]
            })
            .collect();
        let want: Vec<[i32; 2]> = j
            .get("out_codes")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|r| {
                let v = r.as_i32_vec().unwrap();
                [v[0], v[1]]
            })
            .collect();
        let mut sim = CycleAccurateEngine::new(&w, ActImpl::Hard, HwConfig::default());
        assert_eq!(sim.run_codes(&iq).unwrap(), want);
    }
}
