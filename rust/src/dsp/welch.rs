//! Welch power-spectral-density estimation — the instrument behind the
//! ACPR measurements (what the paper's R&S FSW43 analyzer computes).

use anyhow::Result;

use super::fft::Fft;
use super::window::hann;
use crate::util::C64;

/// Welch estimator configuration.
#[derive(Clone, Debug)]
pub struct WelchConfig {
    /// FFT segment length (power of two).
    pub nfft: usize,
    /// Segment overlap as a fraction of nfft (0.0 .. 0.9).
    pub overlap: f64,
}

impl Default for WelchConfig {
    fn default() -> Self {
        WelchConfig { nfft: 4096, overlap: 0.5 }
    }
}

/// Averaged, Hann-windowed periodogram of a complex baseband signal.
///
/// Returns (freqs, psd) with freqs in cycles/sample, *fftshifted* so
/// the axis runs -0.5 .. 0.5 — the natural layout for band-power
/// integration. PSD is in linear power units (per-bin power density up
/// to a constant factor; ACPR/band ratios are scale-free).
pub fn welch_psd(x: &[[f64; 2]], cfg: &WelchConfig) -> Result<(Vec<f64>, Vec<f64>)> {
    let n = cfg.nfft;
    let plan = Fft::new(n)?;
    let w = hann(n);
    let step = ((n as f64) * (1.0 - cfg.overlap)).max(1.0) as usize;
    let mut psd = vec![0.0; n];
    let mut buf = vec![C64::ZERO; n];
    let mut segs = 0usize;

    let mut start = 0;
    while start + n <= x.len() {
        for i in 0..n {
            let [re, im] = x[start + i];
            buf[i] = C64::new(re * w[i], im * w[i]);
        }
        plan.forward(&mut buf);
        for i in 0..n {
            psd[i] += buf[i].norm_sq();
        }
        segs += 1;
        start += step;
    }
    anyhow::ensure!(segs > 0, "signal shorter than one Welch segment ({n})");

    let norm = 1.0 / segs as f64;
    // fftshift
    let half = n / 2;
    let mut shifted = vec![0.0; n];
    let mut freqs = vec![0.0; n];
    for i in 0..n {
        let src = (i + half) % n;
        shifted[i] = psd[src] * norm;
        freqs[i] = (i as f64 - half as f64) / n as f64;
    }
    Ok((freqs, shifted))
}

/// Integrate PSD power over a frequency band [lo, hi) (cycles/sample).
pub fn band_power(freqs: &[f64], psd: &[f64], lo: f64, hi: f64) -> f64 {
    freqs
        .iter()
        .zip(psd)
        .filter(|(f, _)| **f >= lo && **f < hi)
        .map(|(_, p)| *p)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tone(freq: f64, n: usize) -> Vec<[f64; 2]> {
        (0..n)
            .map(|t| {
                let ph = 2.0 * std::f64::consts::PI * freq * t as f64;
                [ph.cos(), ph.sin()]
            })
            .collect()
    }

    #[test]
    fn tone_peaks_at_its_frequency() {
        let x = tone(0.1, 1 << 15);
        let cfg = WelchConfig { nfft: 1024, overlap: 0.5 };
        let (f, p) = welch_psd(&x, &cfg).unwrap();
        let imax = (0..p.len()).max_by(|&a, &b| p[a].total_cmp(&p[b])).unwrap();
        assert!((f[imax] - 0.1).abs() < 2.0 / 1024.0, "peak at {}", f[imax]);
    }

    #[test]
    fn tone_leakage_floor_deep() {
        let x = tone(0.05, 1 << 15);
        let (f, p) = welch_psd(&x, &WelchConfig { nfft: 4096, overlap: 0.5 }).unwrap();
        let inband = band_power(&f, &p, 0.04, 0.06);
        let far = band_power(&f, &p, 0.2, 0.4);
        assert!(10.0 * (far / inband).log10() < -100.0);
    }

    #[test]
    fn white_noise_flat() {
        let mut rng = Rng::new(3);
        let x: Vec<[f64; 2]> = (0..1 << 16).map(|_| [rng.gauss(), rng.gauss()]).collect();
        let (f, p) = welch_psd(&x, &WelchConfig { nfft: 256, overlap: 0.5 }).unwrap();
        let lo = band_power(&f, &p, -0.4, -0.1);
        let hi = band_power(&f, &p, 0.1, 0.4);
        let ratio = 10.0 * (lo / hi).log10();
        assert!(ratio.abs() < 0.5, "flatness {ratio} dB");
    }

    #[test]
    fn total_power_tracks_signal_power() {
        let mut rng = Rng::new(9);
        let x: Vec<[f64; 2]> = (0..1 << 14).map(|_| [rng.gauss() * 0.5, rng.gauss() * 0.5]).collect();
        let (f, p) = welch_psd(&x, &WelchConfig { nfft: 512, overlap: 0.0 }).unwrap();
        let x2: Vec<[f64; 2]> = x.iter().map(|&[a, b]| [2.0 * a, 2.0 * b]).collect();
        let (_, p2) = welch_psd(&x2, &WelchConfig { nfft: 512, overlap: 0.0 }).unwrap();
        let r = band_power(&f, &p2, -0.5, 0.5) / band_power(&f, &p, -0.5, 0.5);
        assert!((r - 4.0).abs() < 1e-9, "power scaling {r}");
    }

    #[test]
    fn errors_on_short_signal() {
        let x = vec![[0.0, 0.0]; 100];
        assert!(welch_psd(&x, &WelchConfig { nfft: 256, overlap: 0.5 }).is_err());
    }

    #[test]
    fn freq_axis_shifted() {
        let x = vec![[1.0, 0.0]; 512];
        let (f, _) = welch_psd(&x, &WelchConfig { nfft: 256, overlap: 0.0 }).unwrap();
        assert_eq!(f[0], -0.5);
        assert_eq!(f[128], 0.0);
        assert!((f[255] - (0.5 - 1.0 / 256.0)).abs() < 1e-12);
    }
}
