"""Model config, initialization, serialization and equation checks."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref
from compile.kernels.activations import hardsigmoid, hardtanh
from compile.kernels.quant import QSpec


class TestConfig:
    def test_paper_parameter_count(self):
        assert model.ModelConfig(hidden=10).n_params == 502

    def test_param_count_formula(self):
        for h in (4, 8, 10, 16, 32):
            cfg = model.ModelConfig(hidden=h)
            total = sum(int(np.prod(s)) for s in cfg.shapes().values())
            assert cfg.n_params == total


class TestInit:
    def test_shapes(self):
        cfg = model.ModelConfig(hidden=10)
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        assert params["w_ih"].shape == (30, 4)
        assert params["w_hh"].shape == (30, 10)
        assert params["w_fc"].shape == (2, 10)
        assert params["b_ih"].shape == (30,)

    def test_bound(self):
        cfg = model.ModelConfig(hidden=10)
        params = model.init_params(cfg, jax.random.PRNGKey(1))
        bound = 1.0 / np.sqrt(10)
        for v in params.values():
            assert np.abs(np.asarray(v)).max() <= bound

    def test_deterministic(self):
        cfg = model.ModelConfig()
        a = model.init_params(cfg, jax.random.PRNGKey(2))
        b = model.init_params(cfg, jax.random.PRNGKey(2))
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


class TestEquations:
    """float_step must literally implement Eq. (2)-(6) + residual."""

    def test_step_matches_manual_transcription(self):
        cfg = model.ModelConfig(hidden=10)
        params = model.init_params(cfg, jax.random.PRNGKey(3))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(0, 0.3, (4,)), jnp.float32)
        h = jnp.asarray(rng.normal(0, 0.3, (10,)), jnp.float32)

        w_ih, b_ih = params["w_ih"], params["b_ih"]
        w_hh, b_hh = params["w_hh"], params["b_hh"]
        w_ir, w_iz, w_in = w_ih[:10], w_ih[10:20], w_ih[20:]
        w_hr, w_hz, w_hn = w_hh[:10], w_hh[10:20], w_hh[20:]
        b_ir, b_iz, b_in = b_ih[:10], b_ih[10:20], b_ih[20:]
        b_hr, b_hz, b_hn = b_hh[:10], b_hh[10:20], b_hh[20:]

        r = hardsigmoid(w_ir @ x + b_ir + w_hr @ h + b_hr)       # Eq. 2
        z = hardsigmoid(w_iz @ x + b_iz + w_hz @ h + b_hz)       # Eq. 3
        n = hardtanh(w_in @ x + b_in + r * (w_hn @ h + b_hn))    # Eq. 4
        h_want = (1 - z) * n + z * h                              # Eq. 5
        y_want = params["w_fc"] @ h_want + params["b_fc"] + x[0:2]  # Eq. 6 + residual

        h_got, y_got = ref.float_step(params, h, x)
        np.testing.assert_allclose(np.asarray(h_got), np.asarray(h_want), atol=1e-6)
        np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want), atol=1e-6)

    def test_feature_definition(self):
        iq = jnp.asarray([[0.3, -0.4]], jnp.float32)
        f = np.asarray(ref.features_float(iq, None))[0]
        p = 4 * (0.3 ** 2 + 0.4 ** 2)
        np.testing.assert_allclose(f, [0.3, -0.4, p, p * p], rtol=1e-6)


class TestSerialization:
    def test_roundtrip(self, tmp_path):
        cfg = model.ModelConfig()
        params = model.init_params(cfg, jax.random.PRNGKey(4))
        path = tmp_path / "w.json"
        model.save_params(str(path), params, meta={"bits": 12})
        loaded, meta = model.load_params(str(path))
        assert meta["bits"] == 12
        for k in params:
            np.testing.assert_allclose(
                np.asarray(loaded[k]), np.asarray(params[k]), atol=1e-7
            )

    def test_quantize_params_range(self):
        cfg = model.ModelConfig()
        params = model.init_params(cfg, jax.random.PRNGKey(5))
        spec = QSpec(12)
        ip = ref.quantize_params(params, spec)
        for v in ip.values():
            arr = np.asarray(v)
            assert arr.dtype == np.int32
            assert arr.min() >= spec.qmin and arr.max() <= spec.qmax
