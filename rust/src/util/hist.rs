//! Dependency-free fixed-bucket log-scale latency histogram — the
//! fleet's observability substrate.
//!
//! [`LatencyAgg`](crate::coordinator::stats::LatencyAgg) tracks
//! mean/max per session; a fleet needs *distribution* shape (p50 /
//! p90 / p99 across thousands of pushes) and needs to aggregate it
//! across shards without storing every sample. This module is the
//! classic HDR-style log-linear scheme, sized once at compile time so
//! every histogram in the process shares one bucket layout and
//! [`LatencyHistogram::merge`] is always well-defined:
//!
//! * values are durations in **nanoseconds**;
//! * the first [`SUB`] buckets are unit-width (0..8 ns, exact);
//! * above that, each power-of-two octave splits into [`SUB`] linear
//!   sub-buckets, so the bucket width is always ≤ 1/8 of the value —
//!   a guaranteed ≤ 12.5 % relative quantile error;
//! * the layout covers up to ~2^36 ns (≈ 69 s); anything beyond
//!   saturates into the last bucket (no per-push service latency is
//!   anywhere near that — the exact maximum is still tracked).
//!
//! Two flavors share the layout: [`LatencyHistogram`] (plain, owned,
//! mergeable — what snapshots and reports use) and
//! [`AtomicHistogram`] (lock-free `record(&self)` — what live
//! sessions write into from many threads, see
//! `coordinator::fleet`). Quantiles report the **upper edge** of the
//! bucket holding the requested order statistic: conservative, and
//! exact to the bucket resolution.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// log2 of the sub-bucket count per octave.
const SUB_BITS: u32 = 3;
/// Linear sub-buckets per power-of-two octave (and the width of the
/// exact unit-bucket prefix).
const SUB: usize = 1 << SUB_BITS;
/// Highest octave covered with full resolution (2^36 ns ≈ 69 s).
const MAX_OCTAVE: u32 = 35;
/// Total bucket count: the unit prefix + SUB per covered octave.
pub const N_BUCKETS: usize = SUB + ((MAX_OCTAVE - SUB_BITS + 1) as usize) * SUB;

/// Bucket index for a value in nanoseconds. Monotone in `ns`; values
/// past the covered range clamp into the last bucket.
#[inline]
pub fn bucket_index(ns: u64) -> usize {
    if ns < SUB as u64 {
        return ns as usize;
    }
    let o = 63 - ns.leading_zeros();
    let sub = ((ns >> (o - SUB_BITS)) as usize) & (SUB - 1);
    let idx = SUB + ((o - SUB_BITS) as usize) * SUB + sub;
    idx.min(N_BUCKETS - 1)
}

/// Inclusive lower edge of a bucket, in nanoseconds.
#[inline]
pub fn bucket_lower(idx: usize) -> u64 {
    debug_assert!(idx < N_BUCKETS);
    if idx < SUB {
        return idx as u64;
    }
    let k = idx - SUB;
    let o = SUB_BITS + (k / SUB) as u32;
    let sub = (k % SUB) as u64;
    (1u64 << o) + (sub << (o - SUB_BITS))
}

/// Exclusive upper edge of a bucket, in nanoseconds. (The last bucket
/// additionally absorbs everything past the covered range; its
/// nominal edge is still returned, which is what keeps quantiles
/// finite.)
#[inline]
pub fn bucket_upper(idx: usize) -> u64 {
    debug_assert!(idx < N_BUCKETS);
    if idx < SUB {
        return idx as u64 + 1;
    }
    let k = idx - SUB;
    let o = SUB_BITS + (k / SUB) as u32;
    bucket_lower(idx) + (1u64 << (o - SUB_BITS))
}

#[inline]
fn duration_ns(d: Duration) -> u64 {
    d.as_nanos().min(u64::MAX as u128) as u64
}

/// A plain, owned, mergeable latency histogram (module docs for the
/// bucket scheme). `record` is O(1); `quantile` walks the fixed
/// bucket array.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    // u128: a fleet-lifetime sum of ns-scale samples crosses 2^64
    // after ~584 years·thread of recorded latency, but a handful of
    // clamped u64::MAX samples (clock glitches, tests) got there
    // immediately — and the old saturating u64 silently dragged
    // `mean()` toward u64::MAX/total. 2^128 is out of reach.
    sum_ns: u128,
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram { counts: vec![0; N_BUCKETS], total: 0, sum_ns: 0, max_ns: 0 }
    }

    /// Record one latency sample.
    pub fn record(&mut self, d: Duration) {
        self.record_ns(duration_ns(d));
    }

    /// Record one sample given directly in nanoseconds.
    pub fn record_ns(&mut self, ns: u64) {
        self.counts[bucket_index(ns)] += 1;
        self.total += 1;
        self.sum_ns += ns as u128;
        self.max_ns = self.max_ns.max(ns);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Exact mean of the recorded samples (not bucketized). The
    /// widened accumulator keeps this exact past the 2^64 ns edge;
    /// the (unreachable-in-practice) clamp to `u64::MAX` ns only
    /// guards `Duration::from_nanos`'s argument type.
    pub fn mean(&self) -> Duration {
        if self.total == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos((self.sum_ns / self.total as u128).min(u64::MAX as u128) as u64)
    }

    /// Exact maximum recorded sample.
    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns)
    }

    /// Fold another histogram in. Always well-defined: every histogram
    /// in the process shares the one compile-time bucket layout.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// The `q`-quantile (q in [0, 1], clamped): the upper edge of the
    /// bucket holding the ⌈q·n⌉-th smallest sample — conservative, and
    /// within the bucket scheme's ≤ 12.5 % relative error of the true
    /// order statistic. Returns zero on an empty histogram.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.total == 0 {
            return Duration::ZERO;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Duration::from_nanos(bucket_upper(idx));
            }
        }
        // unreachable while total == sum(counts); stay safe regardless
        self.max()
    }

    pub fn p50(&self) -> Duration {
        self.quantile(0.50)
    }

    pub fn p90(&self) -> Duration {
        self.quantile(0.90)
    }

    pub fn p99(&self) -> Duration {
        self.quantile(0.99)
    }
}

/// The concurrent flavor: lock-free `record(&self)` from any number
/// of threads (per-bucket atomic counters), snapshotted into a plain
/// [`LatencyHistogram`] for merging and quantile queries. Counters
/// are monotone, so a snapshot taken during concurrent recording is a
/// valid histogram of a slightly earlier instant (`total` is derived
/// from the bucket counts, never a separately-raced counter).
pub struct AtomicHistogram {
    counts: Vec<AtomicU64>,
    // the 128-bit sum split across two u64 words (no AtomicU128 on
    // stable): `record_ns` detects the low-word wrap from fetch_add's
    // returned value and carries into the high word. A snapshot
    // racing the tiny wrap→carry window can read a momentarily low
    // sum — counters are monotone, so that is just a histogram of a
    // slightly earlier instant, same as the bucket counters.
    sum_lo: AtomicU64,
    sum_hi: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        AtomicHistogram::new()
    }
}

impl AtomicHistogram {
    pub fn new() -> AtomicHistogram {
        AtomicHistogram {
            counts: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_lo: AtomicU64::new(0),
            sum_hi: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    /// Record one latency sample (lock-free, `&self`).
    pub fn record(&self, d: Duration) {
        self.record_ns(duration_ns(d));
    }

    pub fn record_ns(&self, ns: u64) {
        self.counts[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        // wait-free 128-bit accumulate: fetch_add returns the prior
        // low word, so this thread — and only this thread — observes
        // its own wrap and owns the carry. Concurrent recorders each
        // carry for their own wrap, so the composed (hi, lo) sum is
        // exact once all recorders are quiescent.
        let prev = self.sum_lo.fetch_add(ns, Ordering::Relaxed);
        if prev > u64::MAX - ns {
            self.sum_hi.fetch_add(1, Ordering::Relaxed);
        }
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Owned snapshot for merging/quantiles.
    pub fn snapshot(&self) -> LatencyHistogram {
        let counts: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total = counts.iter().sum();
        let sum_ns = ((self.sum_hi.load(Ordering::Relaxed) as u128) << 64)
            | self.sum_lo.load(Ordering::Relaxed) as u128;
        LatencyHistogram {
            counts,
            total,
            sum_ns,
            max_ns: self.max_ns.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::Rng;

    /// Draw a latency-like value spanning ns to tens of seconds —
    /// exercising the unit prefix, every octave band, and the clamp.
    fn draw_ns(rng: &mut Rng) -> u64 {
        let mag = rng.below(38); // up to 2^37: past the covered range
        rng.below((1u64 << mag).max(1) + 1)
    }

    fn hist_of(vals: &[u64]) -> LatencyHistogram {
        let mut h = LatencyHistogram::new();
        for &v in vals {
            h.record_ns(v);
        }
        h
    }

    #[test]
    fn unit_prefix_is_exact_and_layout_is_continuous() {
        for v in 0..SUB as u64 {
            assert_eq!(bucket_index(v), v as usize);
            assert_eq!(bucket_lower(v as usize), v);
            assert_eq!(bucket_upper(v as usize), v + 1);
        }
        // every bucket's upper edge is the next bucket's lower edge
        for idx in 0..N_BUCKETS - 1 {
            assert_eq!(
                bucket_upper(idx),
                bucket_lower(idx + 1),
                "gap/overlap between buckets {idx} and {}",
                idx + 1
            );
        }
    }

    #[test]
    fn relative_error_is_bounded() {
        // log-linear contract: width <= lower / 8 for every bucket
        // past the unit prefix
        for idx in SUB..N_BUCKETS {
            let lo = bucket_lower(idx);
            let width = bucket_upper(idx) - lo;
            assert!(width * 8 <= lo, "bucket {idx}: width {width} > lower {lo} / 8");
        }
    }

    #[test]
    fn empty_and_zero_behavior() {
        let h = LatencyHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.5), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.max(), Duration::ZERO);
        let mut h = LatencyHistogram::new();
        h.record(Duration::ZERO);
        assert_eq!(h.count(), 1);
        assert_eq!(h.p50(), Duration::from_nanos(1)); // upper edge of bucket 0
    }

    #[test]
    fn huge_values_saturate_into_the_last_bucket() {
        let mut h = LatencyHistogram::new();
        h.record_ns(u64::MAX);
        h.record(Duration::from_secs(10_000));
        assert_eq!(h.count(), 2);
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
        assert_eq!(h.max(), Duration::from_nanos(u64::MAX));
        // the quantile stays finite (the clamp bucket's nominal edge)
        assert_eq!(h.p99(), Duration::from_nanos(bucket_upper(N_BUCKETS - 1)));
    }

    #[test]
    fn atomic_snapshot_matches_plain_recording() {
        let mut rng = Rng::new(17);
        let vals: Vec<u64> = (0..500).map(|_| draw_ns(&mut rng)).collect();
        let plain = hist_of(&vals);
        let atomic = AtomicHistogram::new();
        std::thread::scope(|s| {
            for chunk in vals.chunks(100) {
                let a = &atomic;
                s.spawn(move || {
                    for &v in chunk {
                        a.record_ns(v);
                    }
                });
            }
        });
        assert_eq!(atomic.snapshot(), plain);
    }

    #[test]
    fn sums_stay_exact_past_the_u64_overflow_edge() {
        // Regression, two generations of the same bug: the atomic
        // flavor once wrapped sum_ns at u64::MAX (snapshots diverged
        // from the plain flavor), and the saturating fix that replaced
        // it still corrupted `mean()` — two u64::MAX samples saturated
        // to a sum of u64::MAX and reported a mean of u64::MAX/2. The
        // widened accumulator (u128 plain, split lo/hi atomics with a
        // carry) keeps the mean exact: (2·(2^64−1))/2 = u64::MAX.
        let mut plain = LatencyHistogram::new();
        let atomic = AtomicHistogram::new();
        for ns in [u64::MAX, u64::MAX] {
            plain.record_ns(ns);
            atomic.record_ns(ns);
        }
        assert_eq!(plain.mean(), Duration::from_nanos(u64::MAX));
        assert_eq!(atomic.snapshot(), plain);

        // the MAX + MAX/2 shape that pinned the old saturating
        // behavior now has its true mean too
        let mut plain = LatencyHistogram::new();
        let atomic = AtomicHistogram::new();
        for ns in [u64::MAX, u64::MAX / 2] {
            plain.record_ns(ns);
            atomic.record_ns(ns);
        }
        let want = (u64::MAX as u128 + (u64::MAX / 2) as u128) / 2;
        assert_eq!(plain.mean(), Duration::from_nanos(want as u64));
        assert_eq!(atomic.snapshot(), plain);

        // merge folds the widened sums exactly as well
        let mut merged = plain.clone();
        merged.merge(&plain);
        assert_eq!(merged.mean(), plain.mean());
        assert_eq!(merged.count(), 2 * plain.count());
    }

    #[test]
    fn prop_bucket_edges_contain_their_values() {
        check("hist bucket containment", 200, |rng| {
            let v = draw_ns(rng);
            let idx = bucket_index(v);
            let (lo, hi) = (bucket_lower(idx), bucket_upper(idx));
            if idx < N_BUCKETS - 1 && !(lo <= v && v < hi) {
                return Err(format!("v={v} outside its bucket {idx} [{lo},{hi})"));
            }
            // the clamp bucket also absorbs everything past its
            // nominal range, but never anything below it
            if idx == N_BUCKETS - 1 && v < lo {
                return Err(format!("v={v} clamped into bucket {idx} but below lower {lo}"));
            }
            // index is monotone
            if bucket_index(v.saturating_add(1)) < idx {
                return Err(format!("bucket_index not monotone at v={v}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_merge_is_commutative_and_associative() {
        check("hist merge algebra", 60, |rng| {
            let n = 1 + rng.below(120) as usize;
            let mut sets: Vec<Vec<u64>> = Vec::new();
            for _ in 0..3 {
                sets.push((0..n).map(|_| draw_ns(rng)).collect());
            }
            let (a, b, c) = (hist_of(&sets[0]), hist_of(&sets[1]), hist_of(&sets[2]));
            // commutative: a+b == b+a
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            if ab != ba {
                return Err("merge not commutative".into());
            }
            // associative: (a+b)+c == a+(b+c)
            let mut ab_c = ab.clone();
            ab_c.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut a_bc = a.clone();
            a_bc.merge(&bc);
            if ab_c != a_bc {
                return Err("merge not associative".into());
            }
            // identity: a + empty == a
            let mut a_id = a.clone();
            a_id.merge(&LatencyHistogram::new());
            if a_id != a {
                return Err("empty histogram is not the merge identity".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_quantile_is_the_bucket_edge_of_the_order_statistic() {
        check("hist quantile order statistic", 60, |rng| {
            let n = 1 + rng.below(200) as usize;
            let mut vals: Vec<u64> = (0..n).map(|_| draw_ns(rng)).collect();
            let h = hist_of(&vals);
            vals.sort_unstable();
            let mut prev = Duration::ZERO;
            for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
                let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
                let v = vals[rank - 1];
                let got = h.quantile(q);
                // exactly the upper edge of the bucket holding the
                // rank-th smallest sample...
                let want = Duration::from_nanos(bucket_upper(bucket_index(v)));
                if got != want {
                    return Err(format!(
                        "q={q}: quantile {got:?} != bucket edge {want:?} of sample {v}"
                    ));
                }
                // ...which bounds the true order statistic from above
                if (got.as_nanos() as u64) <= v && bucket_index(v) < N_BUCKETS - 1 {
                    return Err(format!("q={q}: quantile {got:?} not above sample {v}"));
                }
                // and quantiles are monotone in q
                if got < prev {
                    return Err(format!("quantile not monotone at q={q}"));
                }
                prev = got;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_merge_equals_concatenation() {
        check("hist merge = concat", 60, |rng| {
            let n = 1 + rng.below(100) as usize;
            let m = 1 + rng.below(100) as usize;
            let a_vals: Vec<u64> = (0..n).map(|_| draw_ns(rng)).collect();
            let b_vals: Vec<u64> = (0..m).map(|_| draw_ns(rng)).collect();
            let mut merged = hist_of(&a_vals);
            merged.merge(&hist_of(&b_vals));
            let mut all = a_vals;
            all.extend(b_vals);
            if merged != hist_of(&all) {
                return Err("merged histogram differs from recording the union".into());
            }
            Ok(())
        });
    }
}
