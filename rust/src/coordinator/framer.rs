//! Sample-stream framing: cut a continuous I/Q stream into fixed-size
//! frames for the engines (the HLO executable has a static frame
//! shape; the native engines accept any size but batch better on
//! frames). The last frame is zero-padded and the valid length
//! remembered so the sink can trim.

/// A frame of samples plus its valid prefix length.
#[derive(Clone, Debug)]
pub struct Frame {
    pub seq: u64,
    pub data: Vec<[f64; 2]>,
    pub valid: usize,
}

/// Stateful framer.
pub struct Framer {
    frame_len: usize,
    buf: Vec<[f64; 2]>,
    next_seq: u64,
}

impl Framer {
    pub fn new(frame_len: usize) -> Framer {
        assert!(frame_len > 0);
        Framer { frame_len, buf: Vec::with_capacity(frame_len), next_seq: 0 }
    }

    /// Push samples; emit every completed frame.
    pub fn push(&mut self, samples: &[[f64; 2]]) -> Vec<Frame> {
        let mut out = Vec::new();
        for &s in samples {
            self.buf.push(s);
            if self.buf.len() == self.frame_len {
                out.push(self.emit(self.frame_len));
            }
        }
        out
    }

    /// Flush a final partial frame (zero-padded).
    pub fn flush(&mut self) -> Option<Frame> {
        if self.buf.is_empty() {
            return None;
        }
        let valid = self.buf.len();
        self.buf.resize(self.frame_len, [0.0, 0.0]);
        Some(self.emit(valid))
    }

    fn emit(&mut self, valid: usize) -> Frame {
        let data = std::mem::replace(&mut self.buf, Vec::with_capacity(self.frame_len));
        let seq = self.next_seq;
        self.next_seq += 1;
        Frame { seq, data, valid }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samples(n: usize) -> Vec<[f64; 2]> {
        (0..n).map(|i| [i as f64, -(i as f64)]).collect()
    }

    #[test]
    fn exact_multiple_no_flush_needed() {
        let mut f = Framer::new(4);
        let frames = f.push(&samples(8));
        assert_eq!(frames.len(), 2);
        assert!(f.flush().is_none());
        assert_eq!(frames[0].seq, 0);
        assert_eq!(frames[1].seq, 1);
        assert_eq!(frames[1].data[0], [4.0, -4.0]);
        assert_eq!(frames[0].valid, 4);
    }

    #[test]
    fn ragged_tail_padded() {
        let mut f = Framer::new(4);
        let frames = f.push(&samples(6));
        assert_eq!(frames.len(), 1);
        let tail = f.flush().unwrap();
        assert_eq!(tail.valid, 2);
        assert_eq!(tail.data.len(), 4);
        assert_eq!(tail.data[2], [0.0, 0.0]);
        assert_eq!(tail.seq, 1);
    }

    #[test]
    fn incremental_pushes_equivalent_to_bulk() {
        let mut a = Framer::new(5);
        let mut fa = Vec::new();
        for chunk in samples(23).chunks(3) {
            fa.extend(a.push(chunk));
        }
        fa.extend(a.flush());
        let mut b = Framer::new(5);
        let mut fb = b.push(&samples(23));
        fb.extend(b.flush());
        assert_eq!(fa.len(), fb.len());
        for (x, y) in fa.iter().zip(&fb) {
            assert_eq!(x.data, y.data);
            assert_eq!(x.valid, y.valid);
        }
    }

    #[test]
    fn conservation() {
        // total valid samples across frames == input length
        let mut f = Framer::new(7);
        let mut frames = f.push(&samples(40));
        frames.extend(f.flush());
        let total: usize = frames.iter().map(|fr| fr.valid).sum();
        assert_eq!(total, 40);
    }
}
