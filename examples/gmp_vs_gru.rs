//! GMP baseline vs GRU-NN DPD — the algorithmic comparison behind
//! Table II's "model" column (the FPGA competitors run GMP/MP; this
//! work runs the GRU).
//!
//! Fits a generalized memory polynomial by indirect learning on a PA
//! capture, then compares linearization and complexity against the
//! trained GRU at equal drive.
//!
//! ```bash
//! make artifacts && cargo run --release --example gmp_vs_gru
//! ```

use dpd_ne::dpd::gmp::{GmpConfig, GmpDpd};
use dpd_ne::dpd::qgru::{ActKind, QGruDpd};
use dpd_ne::dpd::weights::QGruWeights;
use dpd_ne::dpd::Dpd;
use dpd_ne::fixed::QSpec;
use dpd_ne::metrics::acpr::{acpr_db, AcprConfig};
use dpd_ne::metrics::evm::evm_db_nmse;
use dpd_ne::pa::{PaSpec, RappMemPa};
use dpd_ne::report::{f1, Table};
use dpd_ne::runtime::Manifest;
use dpd_ne::signal::ofdm::{OfdmConfig, OfdmModulator};

/// Envelope-clip a DPD output to the Q2.f DAC range, like the chip.
fn clip2(z: &[[f64; 2]]) -> Vec<[f64; 2]> {
    z.iter()
        .map(|&[i, q]| {
            let e = (i * i + q * q).sqrt();
            if e > 2.0 {
                [i * 2.0 / e, q * 2.0 / e]
            } else {
                [i, q]
            }
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let m = Manifest::discover(None)?;
    let pa = RappMemPa::new(PaSpec::load(&m.pa_model)?);
    let g = pa.spec.target_gain();

    // training capture for the GMP ILA fit
    let train = OfdmModulator::generate(&OfdmConfig { n_symbols: 96, seed: 7, ..Default::default() })?;
    let y_train = pa.run(&train.iq);

    // held-out evaluation burst
    let eval = OfdmModulator::generate(&OfdmConfig { n_symbols: 48, seed: 1234, ..Default::default() })?;
    let y_off = pa.run(&eval.iq);

    let mut t = Table::new(
        "GMP baseline vs GRU DPD (held-out burst)",
        &["DPD", "params (real)", "ACPR (dBc)", "EVM (dB)"],
    );
    t.row(&[
        "off".into(),
        "0".into(),
        f1(acpr_db(&y_off, &AcprConfig::default())?.acpr_dbc),
        f1(evm_db_nmse(&y_off, &eval.iq, g)),
    ]);

    for (label, cfg) in [
        ("GMP small (MP only)", GmpConfig { k_max: 7, mem: 3, cross_k: 0, cross_m: 0, cross_lags: 0, lambda: 1e-9 }),
        ("GMP full", GmpConfig::default()),
        (
            "GMP large",
            GmpConfig { k_max: 11, mem: 5, cross_k: 7, cross_m: 3, cross_lags: 2, lambda: 1e-9 },
        ),
    ] {
        let mut gmp = GmpDpd::fit_ila(&cfg, &train.iq, &y_train, g)?;
        let z = clip2(&gmp.run(&eval.iq));
        let y = pa.run(&z);
        t.row(&[
            label.into(),
            cfg.n_params_real().to_string(),
            f1(acpr_db(&y, &AcprConfig::default())?.acpr_dbc),
            f1(evm_db_nmse(&y, &eval.iq, g)),
        ]);
    }

    let spec = QSpec::new(m.qspec_bits)?;
    let w = QGruWeights::load_params_int(&m.weights_main, spec)?;
    let mut gru = QGruDpd::new(w, ActKind::Hard);
    let z = gru.run(&eval.iq);
    let y = pa.run(&z);
    t.row(&[
        "GRU (this work, Q2.10)".into(),
        "502".into(),
        f1(acpr_db(&y, &AcprConfig::default())?.acpr_dbc),
        f1(evm_db_nmse(&y, &eval.iq, g)),
    ]);
    println!("{}", t.render());
    println!("note: GMP coefficients are complex f64 (the FPGA baselines run W16+);");
    println!("the GRU row runs the chip's 12-bit fixed-point datapath end to end.");
    Ok(())
}
