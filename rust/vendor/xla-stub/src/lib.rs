//! Compile-time stub of the `xla` (PJRT) crate API surface used by the
//! `dpd-ne` runtime.
//!
//! The container image does not ship the `xla_extension` native
//! library, so the real `xla` crate cannot build here. This stub lets
//! `cargo build --features xla` type-check and link the whole HLO/PJRT
//! code path; every operation that would need a live PJRT client
//! returns [`Error`] at runtime instead. To run the real thing, point
//! the `xla` path dependency in `rust/Cargo.toml` at the actual crate
//! (see DESIGN.md §Feature flags).
//!
//! Only the constructors touch the (absent) backend: `PjRtClient::cpu`
//! fails first, so downstream methods are unreachable in practice but
//! still fail cleanly if called.

use std::fmt;

const UNAVAILABLE: &str =
    "PJRT unavailable: built against the vendored xla stub (see DESIGN.md to link the real xla crate)";

/// Error type mirroring `xla::Error` closely enough for `?` + anyhow.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// Element types `Literal` can carry (subset used by dpd-ne).
pub trait NativeType: Copy {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for f32 {}
impl NativeType for f64 {}

/// Host literal (stub: shapeless placeholder).
#[derive(Clone, Debug, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Parsed HLO module (stub: parsing requires the native library).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation built from an HLO module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client (stub: construction always fails — no backend).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT unavailable"));
    }

    #[test]
    fn literal_data_ops_succeed() {
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert!(lit.reshape(&[1, 3]).is_ok());
        assert!(lit.to_vec::<i32>().is_err());
    }
}
