//! Tiny property-testing helper (no proptest crate offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` random
//! inputs drawn from a deterministic seed derived from `name`, so
//! failures are reproducible. On failure it reports, inline: the case
//! index, the seed, the property's own message, AND the failing
//! case's **shrunk input** — the recorded draw tape greedily
//! minimized (values zeroed/halved while the property keeps failing),
//! so the offending values are visible in the panic itself instead of
//! forcing a manual env-replay round-trip.
//!
//! Replay knobs:
//! * `DPD_PROPTEST_SEED=<u64>` — case 0 starts at exactly that seed
//!   (re-run one reported case);
//! * `DPD_PROPTEST_TAPE=<v,v,...>` (or `@<path>` to a file holding
//!   the same comma-separated form) — run a single case whose draws
//!   are served from the given tape (the shrunk input printed by a
//!   failure; large tapes are spilled to a temp file and reported as
//!   `@<path>`), on top of the seed above when both are set.

use super::rng::Rng;

/// Base seed for a property: the env override when set (reproducible
/// replay of a reported failure), else a stable hash of the name
/// (the shared content hash with an empty word stream).
fn base_seed(name: &str) -> u64 {
    match std::env::var("DPD_PROPTEST_SEED") {
        Ok(s) => s
            .trim()
            .parse::<u64>()
            .unwrap_or_else(|_| panic!("DPD_PROPTEST_SEED must be a u64, got '{s}'")),
        Err(_) => super::fnv1a_words(name, std::iter::empty()),
    }
}

/// Parse the replay tape override, if any. `@<path>` loads the
/// comma-separated tape from a file (how large shrunk inputs are
/// reported; see [`tape_replay_command`]).
fn env_tape() -> Option<Vec<u64>> {
    let s = std::env::var("DPD_PROPTEST_TAPE").ok()?;
    let s = match s.strip_prefix('@') {
        Some(path) => std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("DPD_PROPTEST_TAPE file '{path}': {e}")),
        None => s,
    };
    Some(
        s.split(',')
            .map(|v| {
                v.trim()
                    .parse::<u64>()
                    .unwrap_or_else(|_| panic!("DPD_PROPTEST_TAPE must be u64s, got '{v}'"))
            })
            .collect(),
    )
}

/// The copy-pasteable replay setting for a shrunk tape: the full
/// comma-separated tape inline when it is short enough, else spilled
/// to a temp file and referenced as `@<path>` — the value must always
/// reproduce the failure verbatim, never a truncated prefix.
fn tape_replay_command(name: &str, seed: u64, tape: &[u64]) -> String {
    let csv: Vec<String> = tape.iter().map(u64::to_string).collect();
    let csv = csv.join(",");
    if tape.len() <= 64 {
        return format!("DPD_PROPTEST_TAPE='{csv}'");
    }
    let slug: String =
        name.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '-' }).collect();
    let path = std::env::temp_dir().join(format!("dpd_proptest_{slug}_{seed}.tape"));
    match std::fs::write(&path, &csv) {
        Ok(()) => format!("DPD_PROPTEST_TAPE=@{}", path.display()),
        // fall back to the inline form — long, but always correct
        Err(_) => format!("DPD_PROPTEST_TAPE='{csv}'"),
    }
}

/// Greedy tape minimization: try zeroing, halving and decrementing
/// each draw while the property still fails; keep the smallest
/// failing tape (bounded by a fixed re-run budget). Returns the
/// shrunk tape and its failure message.
fn shrink<F>(seed: u64, tape: Vec<u64>, msg: String, f: &mut F) -> (Vec<u64>, String)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut best_tape = tape;
    let mut best_msg = msg;
    let mut budget = 256usize;
    let mut improved = true;
    while improved && budget > 0 {
        improved = false;
        'outer: for i in 0..best_tape.len() {
            let orig = best_tape[i];
            for cand in [0u64, orig >> 1] {
                if cand == orig || budget == 0 {
                    continue;
                }
                budget -= 1;
                let mut t = best_tape.clone();
                t[i] = cand;
                let mut rng = Rng::replaying(seed, t);
                if let Err(m) = f(&mut rng) {
                    // keep what was actually consumed: control flow may
                    // have shifted, and the consumed tape is the one
                    // that replays this failure exactly
                    best_tape = rng.take_trace();
                    best_msg = m;
                    improved = true;
                    break 'outer;
                }
            }
        }
    }
    (best_tape, best_msg)
}

/// Render a tape for the panic message (capped — shrunk tapes are
/// mostly zeros, but some properties draw thousands of values).
fn render_tape(tape: &[u64]) -> String {
    const SHOW: usize = 64;
    let head: Vec<String> = tape.iter().take(SHOW).map(u64::to_string).collect();
    if tape.len() > SHOW {
        format!("{} (+{} more draws)", head.join(","), tape.len() - SHOW)
    } else {
        head.join(",")
    }
}

/// Run `f` for `cases` seeded iterations; `f` returns Err(description)
/// on a property violation. Panics with full reproduction info: seed,
/// original failure, and the shrunk input tape inline.
pub fn check<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = base_seed(name);
    if let Some(tape) = env_tape() {
        // single-case tape replay (the shrunk input from a report)
        let mut rng = Rng::replaying(base, tape);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed replaying DPD_PROPTEST_TAPE (seed {base}): {msg}");
        }
        return;
    }
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Rng::traced(seed);
        if let Err(msg) = f(&mut rng) {
            let tape = rng.take_trace();
            let (shrunk, shrunk_msg) = shrink(seed, tape, msg.clone(), &mut f);
            panic!(
                "property '{name}' failed at case {case} (seed {seed}): {msg}\n\
                 shrunk input ({} draws): [{}]\n\
                 shrunk failure: {shrunk_msg}\n\
                 replay with DPD_PROPTEST_SEED={seed} (the case), or additionally\n\
                 {} (the shrunk input)",
                shrunk.len(),
                render_tape(&shrunk),
                tape_replay_command(name, seed, &shrunk),
            );
        }
    }
}

/// Assert two floats are within an absolute tolerance, with context.
pub fn assert_close(got: f64, want: f64, tol: f64, what: &str) -> Result<(), String> {
    if (got - want).abs() > tol {
        return Err(format!("{what}: got {got}, want {want} (tol {tol})"));
    }
    Ok(())
}

/// Assert two slices are element-wise within tolerance.
pub fn assert_close_slice(got: &[f64], want: &[f64], tol: f64, what: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{what}: length {} vs {}", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        if (g - w).abs() > tol {
            return Err(format!("{what}[{i}]: got {g}, want {w} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("counter", 25, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failure() {
        check("fails", 10, |rng| {
            let v = rng.uniform();
            if v >= 0.0 {
                Err(format!("always fails, v={v}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    #[should_panic(expected = "shrunk input")]
    fn check_reports_shrunk_input_inline() {
        check("shrinks", 5, |rng| {
            let v = rng.next_u64();
            if v > 10 {
                Err(format!("v={v} too big"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn shrinker_minimizes_failing_draws() {
        // property: fails iff the first draw exceeds 1000. Any failing
        // tape shrinks toward the boundary: halving stops working at
        // <= 1000, so the shrunk head stays > 1000 but gets small.
        let mut f = |rng: &mut Rng| -> Result<(), String> {
            let v = rng.next_u64();
            if v > 1000 {
                Err(format!("v={v}"))
            } else {
                Ok(())
            }
        };
        let mut rng = Rng::traced(1);
        let first = rng.next_u64();
        assert!(first > 1000, "seed 1's first draw is astronomically likely > 1000");
        let tape = rng.take_trace();
        let (shrunk, msg) = shrink(1, tape, format!("v={first}"), &mut f);
        assert_eq!(shrunk.len(), 1);
        assert!(shrunk[0] > 1000, "shrunk tape must still fail");
        assert!(shrunk[0] <= 2001, "halving should reach the boundary, got {}", shrunk[0]);
        assert!(msg.starts_with("v="));
        // replaying the shrunk tape reproduces the shrunk failure
        let mut rep = Rng::replaying(1, shrunk.clone());
        assert_eq!(f(&mut rep), Err(msg));
    }

    #[test]
    fn replay_command_is_always_complete() {
        // short tapes inline verbatim
        let cmd = tape_replay_command("p", 1, &[5, 6, 7]);
        assert_eq!(cmd, "DPD_PROPTEST_TAPE='5,6,7'");
        // long tapes spill to a file that holds the FULL tape — the
        // reported command must reproduce the failure, never a prefix
        let tape: Vec<u64> = (0..500).collect();
        let cmd = tape_replay_command("some name!", 2, &tape);
        let path = cmd.strip_prefix("DPD_PROPTEST_TAPE=@").expect("file form");
        let read = std::fs::read_to_string(path).unwrap();
        let parsed: Vec<u64> = read.split(',').map(|v| v.parse().unwrap()).collect();
        assert_eq!(parsed, tape);
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        check("det", 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check("det", 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
