//! FIR filter design (windowed sinc) and application.
//!
//! The TX lowpass in the signal chain mirrors the python generator's
//! Kaiser windowed-sinc (`dataset.kaiser_lowpass`) exactly, so the rust
//! OFDM source produces the same spectrum-contained stimulus.

use super::window::kaiser;

/// Unity-DC-gain lowpass via Kaiser windowed sinc.
/// `cutoff` in cycles/sample (0 .. 0.5).
pub fn kaiser_lowpass(ntaps: usize, cutoff: f64, beta: f64) -> Vec<f64> {
    assert!(ntaps >= 3 && cutoff > 0.0 && cutoff < 0.5);
    let w = kaiser(ntaps, beta);
    let mid = (ntaps - 1) as f64 / 2.0;
    let mut h: Vec<f64> = (0..ntaps)
        .map(|i| {
            let n = i as f64 - mid;
            let s = if n == 0.0 {
                2.0 * cutoff
            } else {
                (2.0 * std::f64::consts::PI * cutoff * n).sin() / (std::f64::consts::PI * n)
            };
            s * w[i]
        })
        .collect();
    let sum: f64 = h.iter().sum();
    for v in h.iter_mut() {
        *v /= sum;
    }
    h
}

/// 'same'-mode convolution of complex I/Q with a real FIR — matches
/// `numpy.convolve(x, h, mode="same")`.
pub fn convolve_same(x: &[[f64; 2]], h: &[f64]) -> Vec<[f64; 2]> {
    let n = x.len();
    let m = h.len();
    let mut y = vec![[0.0; 2]; n];
    // full convolution index k = i + j, 'same' keeps k in
    // [(m-1)/2, (m-1)/2 + n)
    let off = (m - 1) / 2;
    for (i, out) in y.iter_mut().enumerate() {
        let k = i + off;
        // j ranges so that k-j in [0, n)
        let j_lo = k.saturating_sub(n - 1);
        let j_hi = k.min(m - 1);
        let mut acc = [0.0f64; 2];
        for j in j_lo..=j_hi {
            let c = h[j];
            let s = x[k - j];
            acc[0] += c * s[0];
            acc[1] += c * s[1];
        }
        *out = acc;
    }
    y
}

/// Filter frequency response magnitude at a given frequency.
pub fn freq_response_mag(h: &[f64], freq: f64) -> f64 {
    let mut re = 0.0;
    let mut im = 0.0;
    for (n, &c) in h.iter().enumerate() {
        let ph = -2.0 * std::f64::consts::PI * freq * n as f64;
        re += c * ph.cos();
        im += c * ph.sin();
    }
    (re * re + im * im).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn unity_dc_gain() {
        let h = kaiser_lowpass(255, 0.13, 10.0);
        assert!((h.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((freq_response_mag(&h, 0.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn passband_flat_stopband_deep() {
        let h = kaiser_lowpass(511, 0.13, 10.0);
        for f in [0.02, 0.06, 0.10, 0.12] {
            let g = 20.0 * freq_response_mag(&h, f).log10();
            assert!(g.abs() < 0.1, "passband ripple at {f}: {g} dB");
        }
        for f in [0.17, 0.2, 0.3, 0.45] {
            let g = 20.0 * freq_response_mag(&h, f).log10();
            assert!(g < -80.0, "stopband at {f}: {g} dB");
        }
    }

    #[test]
    fn symmetric_linear_phase() {
        let h = kaiser_lowpass(101, 0.2, 8.0);
        for i in 0..101 {
            assert!((h[i] - h[100 - i]).abs() < 1e-15);
        }
    }

    #[test]
    fn convolve_same_identity() {
        let x: Vec<[f64; 2]> = (0..10).map(|i| [i as f64, -(i as f64)]).collect();
        let y = convolve_same(&x, &[1.0]);
        assert_eq!(x, y);
    }

    #[test]
    fn convolve_same_matches_numpy_semantics() {
        // numpy.convolve([1,2,3,4], [0.5,0.5], 'same') = [0.5, 1.5, 2.5, 3.5]
        let x: Vec<[f64; 2]> = vec![[1.0, 0.0], [2.0, 0.0], [3.0, 0.0], [4.0, 0.0]];
        let y = convolve_same(&x, &[0.5, 0.5]);
        let got: Vec<f64> = y.iter().map(|v| v[0]).collect();
        assert_eq!(got, vec![0.5, 1.5, 2.5, 3.5]);
    }

    #[test]
    fn convolve_same_odd_kernel_centered() {
        // delta in the middle passes through unchanged
        let mut x = vec![[0.0, 0.0]; 9];
        x[4] = [1.0, 2.0];
        let h = [0.25, 0.5, 0.25];
        let y = convolve_same(&x, &h);
        assert!((y[4][0] - 0.5).abs() < 1e-15);
        assert!((y[3][0] - 0.25).abs() < 1e-15);
        assert!((y[5][0] - 0.25).abs() < 1e-15);
    }

    #[test]
    fn convolution_is_linear() {
        check("convolution linearity", 20, |rng| {
            let n = 64;
            let h = kaiser_lowpass(31, 0.2, 6.0);
            let a: Vec<[f64; 2]> = (0..n).map(|_| [rng.gauss(), rng.gauss()]).collect();
            let b: Vec<[f64; 2]> = (0..n).map(|_| [rng.gauss(), rng.gauss()]).collect();
            let sum: Vec<[f64; 2]> = a
                .iter()
                .zip(&b)
                .map(|(&x, &y)| [x[0] + y[0], x[1] + y[1]])
                .collect();
            let ya = convolve_same(&a, &h);
            let yb = convolve_same(&b, &h);
            let ys = convolve_same(&sum, &h);
            for i in 0..n {
                if (ys[i][0] - ya[i][0] - yb[i][0]).abs() > 1e-12 {
                    return Err("linearity".into());
                }
            }
            Ok(())
        });
    }
}
