//! Small shared utilities: deterministic RNG, JSON, complex numbers,
//! latency histograms, property-test helpers, and the cross-engine
//! conformance harness.

pub mod conformance;
pub mod cplx;
pub mod hist;
pub mod json;
pub mod proptest;
pub mod rng;

pub use cplx::C64;
pub use rng::Rng;

/// FNV-style 64-bit content hash over a tag string plus a word stream
/// (the xor-multiply construction of FNV-1a with the crate's
/// historical multiplier — not the canonical FNV-64 prime, so outputs
/// will not match external FNV tools). Used for property-test seeds
/// (`util::proptest`, empty word stream) and as the fingerprint behind
/// batch-class identification: equal fingerprints are taken to mean
/// identical datapaths, and the 64-bit space makes an accidental
/// collision between *different* weight sets negligible.
pub fn fnv1a_words(tag: &str, words: impl IntoIterator<Item = u64>) -> u64 {
    const P: u64 = 0x1000_0000_01b3;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in tag.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(P);
    }
    for w in words {
        let mut v = w;
        for _ in 0..8 {
            h ^= v & 0xff;
            h = h.wrapping_mul(P);
            v >>= 8;
        }
    }
    h
}
