//! Golden-vector parity: the rust fixed-point engine must reproduce
//! the jax integer oracle (and therefore the AOT Pallas kernel) BIT
//! FOR BIT on the captured test vectors in `artifacts/golden/`.
//!
//! Requires `make artifacts` to have run; tests are skipped (pass
//! trivially with a note) when the artifact tree is absent so that
//! `cargo test` works on a fresh checkout.

use std::path::{Path, PathBuf};

use dpd_ne::dpd::qgru::{ActKind, LutTables, QGruDpd};
use dpd_ne::dpd::weights::QGruWeights;
use dpd_ne::util::json::Json;

fn artifacts_dir() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        None
    }
}

fn load_codes(j: &Json, key: &str) -> Vec<[i32; 2]> {
    j.get(key)
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|row| {
            let v = row.as_i32_vec().unwrap();
            [v[0], v[1]]
        })
        .collect()
}

fn run_golden(path: &Path) {
    let (w, j) = QGruWeights::load_golden(path).unwrap();
    let spec = w.spec;
    let act_name = j.get("act").unwrap().as_str().unwrap().to_string();
    let act = match act_name.as_str() {
        "hard" => ActKind::Hard,
        "lut" => {
            let lut = j.get("lut").unwrap();
            ActKind::Lut(LutTables::build(
                spec,
                lut.get("lo").unwrap().as_f64().unwrap(),
                lut.get("hi").unwrap().as_f64().unwrap(),
                lut.get("addr_bits").unwrap().as_usize().unwrap() as u32,
            ))
        }
        other => panic!("unknown act {other}"),
    };
    let iq = load_codes(&j, "iq_codes");
    let want = load_codes(&j, "out_codes");

    let mut dpd = QGruDpd::new(w, act);
    let got = dpd.run_codes(&iq);
    assert_eq!(got.len(), want.len());
    for (t, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g, w, "{path:?} ({act_name}): divergence at sample {t}");
    }

    // per-step trace: features + hidden state must also match
    let trace = j.get("trace").unwrap();
    let feats_want: Vec<Vec<i32>> = trace
        .get("features")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|r| r.as_i32_vec().unwrap())
        .collect();
    let mut dpd2 = QGruDpd::new(QGruWeights::load_golden(path).unwrap().0, match act_name.as_str() {
        "hard" => ActKind::Hard,
        _ => ActKind::Lut(LutTables::default_for(spec)),
    });
    for (t, fw) in feats_want.iter().enumerate() {
        let f = dpd2.features(iq[t]);
        assert_eq!(&f.to_vec(), fw, "feature mismatch at step {t}");
    }
}

#[test]
fn golden_vectors_bit_exact() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return;
    };
    let manifest = Json::parse_file(&dir.join("manifest.json")).unwrap();
    let golden = manifest.get("golden").unwrap().as_arr().unwrap();
    assert!(!golden.is_empty(), "manifest lists no golden vectors");
    for g in golden {
        let path = dir.join(g.as_str().unwrap());
        run_golden(&path);
    }
}

#[test]
fn main_weights_quantization_matches_python() {
    // weights_main.json carries both float params and the python-side
    // quantized codes; rust quantization of the former must equal the
    // latter exactly.
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let path = dir.join("weights_main.json");
    let fw = dpd_ne::dpd::GruWeights::load(&path).unwrap();
    let spec = dpd_ne::fixed::QSpec::Q12;
    let qw = fw.quantize(spec).unwrap();
    let want = QGruWeights::load_params_int(&path, spec).unwrap();
    assert_eq!(qw.w_ih, want.w_ih);
    assert_eq!(qw.b_ih, want.b_ih);
    assert_eq!(qw.w_hh, want.w_hh);
    assert_eq!(qw.b_hh, want.b_hh);
    assert_eq!(qw.w_fc, want.w_fc);
    assert_eq!(qw.b_fc, want.b_fc);
}

#[test]
fn trained_model_linearizes_pa() {
    // End-to-end on artifacts: ACPR through the shared PA improves by
    // >10 dB with the trained quantized model, and beats -40 dBc.
    use dpd_ne::metrics::acpr::{acpr_db, AcprConfig};
    use dpd_ne::pa::{PaSpec, RappMemPa};
    use dpd_ne::signal::ofdm::{OfdmConfig, OfdmModulator};

    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let pa = RappMemPa::new(PaSpec::load(&dir.join("pa_model.json")).unwrap());
    let w = QGruWeights::load_params_int(&dir.join("weights_main.json"), dpd_ne::fixed::QSpec::Q12).unwrap();
    let mut dpd = QGruDpd::new(w, ActKind::Hard);

    let sig = OfdmModulator::generate(&OfdmConfig { n_symbols: 24, seed: 42, ..Default::default() }).unwrap();
    let before = acpr_db(&pa.run(&sig.iq), &AcprConfig::default()).unwrap().acpr_dbc;

    use dpd_ne::dpd::Dpd;
    let z = dpd.run(&sig.iq);
    let after = acpr_db(&pa.run(&z), &AcprConfig::default()).unwrap().acpr_dbc;
    assert!(after < before - 10.0, "ACPR {before:.1} -> {after:.1}");
    assert!(after < -40.0, "ACPR after DPD {after:.1}");
}
