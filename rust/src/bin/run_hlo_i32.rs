//! Debug utility: run an HLO-text module that maps s32[N] -> (s32[N],)
//! with a comma-separated input vector, print the output. Used to
//! bisect xla_extension miscompilations of jax-lowered constructs.

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let path = args.next().expect("usage: run_hlo_i32 <hlo.txt> <v0,v1,...>");
    let vals: Vec<i32> = args
        .next()
        .expect("need input csv")
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(&path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    let lit = match args.next() {
        Some(shape) => {
            let dims: Vec<i64> = shape.split(',').map(|s| s.parse().unwrap()).collect();
            xla::Literal::vec1(&vals).reshape(&dims)?
        }
        None => xla::Literal::vec1(&vals),
    };
    let result = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
    let out = result.to_tuple1()?;
    println!("{:?}", out.to_vec::<i32>()?);
    Ok(())
}
