//! Peak-to-average power ratio statistics.

/// PAPR of an I/Q burst in dB.
pub fn papr_db(iq: &[[f64; 2]]) -> f64 {
    let mut peak = 0.0f64;
    let mut sum = 0.0f64;
    for &[i, q] in iq {
        let p = i * i + q * q;
        peak = peak.max(p);
        sum += p;
    }
    let avg = sum / iq.len() as f64;
    10.0 * (peak / avg).log10()
}

/// CCDF of the instantaneous power: fraction of samples whose PAPR
/// exceeds each threshold (dB). Returns (thresholds_db, prob).
pub fn ccdf(iq: &[[f64; 2]], thresholds_db: &[f64]) -> Vec<(f64, f64)> {
    let n = iq.len() as f64;
    let avg: f64 = iq.iter().map(|&[i, q]| i * i + q * q).sum::<f64>() / n;
    thresholds_db
        .iter()
        .map(|&t| {
            let lim = avg * 10f64.powf(t / 10.0);
            let count = iq.iter().filter(|&&[i, q]| i * i + q * q > lim).count();
            (t, count as f64 / n)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn constant_envelope_zero_papr() {
        let iq: Vec<[f64; 2]> = (0..100)
            .map(|t| {
                let ph = 0.1 * t as f64;
                [ph.cos(), ph.sin()]
            })
            .collect();
        assert!(papr_db(&iq).abs() < 1e-9);
    }

    #[test]
    fn gaussian_papr_realistic() {
        let mut rng = Rng::new(0);
        let iq: Vec<[f64; 2]> = (0..100_000).map(|_| [rng.gauss(), rng.gauss()]).collect();
        let p = papr_db(&iq);
        assert!((7.0..14.0).contains(&p), "gaussian PAPR {p}");
    }

    #[test]
    fn ccdf_monotone_decreasing() {
        let mut rng = Rng::new(1);
        let iq: Vec<[f64; 2]> = (0..10_000).map(|_| [rng.gauss(), rng.gauss()]).collect();
        let c = ccdf(&iq, &[0.0, 3.0, 6.0, 9.0]);
        for w in c.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
        assert!(c[0].1 > 0.1); // plenty of samples above average power
    }
}
