//! Rapp-static + memory GaN-Doherty-like PA model.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::C64;

/// PA model parameters (see python `pa_model.PASpec` for semantics).
#[derive(Clone, Debug, PartialEq)]
pub struct PaSpec {
    pub g1: C64,
    pub asat: f64,
    pub p: f64,
    pub apm: f64,
    pub bpm: f64,
    pub mem_linear: Vec<C64>,
    pub mem_cubic: Vec<C64>,
    pub target_backoff: f64,
    pub label: String,
}

impl PaSpec {
    /// Load from the shared JSON artifact.
    pub fn load(path: &Path) -> Result<PaSpec> {
        let j = Json::parse_file(path).context("loading PA spec")?;
        let pair = |v: &Json| -> Result<C64> {
            let a = v.as_f64_vec()?;
            anyhow::ensure!(a.len() == 2, "complex pair must have 2 entries");
            Ok(C64::new(a[0], a[1]))
        };
        let mem = |v: &Json| -> Result<Vec<C64>> { v.as_arr()?.iter().map(pair).collect() };
        Ok(PaSpec {
            g1: pair(j.get("g1")?)?,
            asat: j.get("asat")?.as_f64()?,
            p: j.get("p")?.as_f64()?,
            apm: j.get("apm")?.as_f64()?,
            bpm: j.get("bpm")?.as_f64()?,
            mem_linear: mem(j.get("mem_linear")?)?,
            mem_cubic: mem(j.get("mem_cubic")?)?,
            target_backoff: j.get("target_backoff")?.as_f64()?,
            label: j.get("label")?.as_str()?.to_string(),
        })
    }

    /// The default calibrated spec (python `ganlike_spec()` twin) —
    /// used by tests and examples when no artifact tree is present.
    pub fn ganlike() -> PaSpec {
        PaSpec {
            g1: C64::new(0.995, 0.087),
            asat: 0.82,
            p: 1.1,
            apm: 0.9,
            bpm: 1.6,
            mem_linear: vec![
                C64::new(0.08, -0.045),
                C64::new(-0.032, 0.018),
                C64::new(0.011, -0.006),
            ],
            mem_cubic: vec![C64::new(-0.055, 0.035)],
            target_backoff: 0.95,
            label: "ganlike-doherty-rapp-mem".to_string(),
        }
    }

    /// Small-signal complex gain g1.
    pub fn linear_gain(&self) -> C64 {
        self.g1
    }

    /// The gain a DPD should linearize to (g1 with peak headroom).
    pub fn target_gain(&self) -> C64 {
        self.g1.scale(self.target_backoff)
    }
}

/// Stateful PA instance (owns delay-line state for streaming use).
pub struct RappMemPa {
    pub spec: PaSpec,
}

impl RappMemPa {
    pub fn new(spec: PaSpec) -> RappMemPa {
        RappMemPa { spec }
    }

    /// Static stage: x * G(|x|) * e^{j phi(|x|)} * g1.
    #[inline]
    fn static_stage(&self, x: C64) -> C64 {
        let s = &self.spec;
        let a2 = x.norm_sq();
        let g = (1.0 + (a2 / (s.asat * s.asat)).powf(s.p)).powf(-1.0 / (2.0 * s.p));
        let phi = s.apm * a2 / (1.0 + s.bpm * a2);
        x.scale(g) * C64::cis(phi) * s.g1
    }

    /// Run a burst through the PA (batch form; zero initial memory,
    /// matching `pa_model.apply_pa_np`).
    pub fn run(&self, x: &[[f64; 2]]) -> Vec<[f64; 2]> {
        let n = x.len();
        // static stage first
        let s: Vec<C64> = x.iter().map(|&[i, q]| self.static_stage(C64::new(i, q))).collect();
        let mut y: Vec<C64> = s.clone();
        for (m, &b) in self.spec.mem_linear.iter().enumerate() {
            let d = m + 1;
            for i in d..n {
                y[i] += b * s[i - d];
            }
        }
        for (m, &c) in self.spec.mem_cubic.iter().enumerate() {
            let d = m + 1;
            for i in d..n {
                let v = s[i - d];
                y[i] += c * v.scale(v.norm_sq());
            }
        }
        y.iter().map(|z| [z.re, z.im]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::acpr::{acpr_db, AcprConfig};
    use crate::signal::ofdm::{OfdmConfig, OfdmModulator};

    #[test]
    fn small_signal_linear() {
        let pa = RappMemPa::new(PaSpec::ganlike());
        let x = vec![[1e-4, 0.0]; 100];
        let y = pa.run(&x);
        let g_eff = pa.spec.g1
            + pa.spec.mem_linear.iter().fold(C64::ZERO, |a, &b| a + b) * pa.spec.g1;
        let got = C64::new(y[50][0], y[50][1]).scale(1e4);
        assert!((got - g_eff).abs() < 1e-3, "{got:?} vs {g_eff:?}");
    }

    #[test]
    fn compression_at_peak_1p5_to_4p5_db() {
        let pa = RappMemPa::new(PaSpec::ganlike());
        let gain_at = |a: f64| {
            let x = vec![[a, 0.0]; 50];
            let y = pa.run(&x);
            (y[40][0].powi(2) + y[40][1].powi(2)).sqrt() / a
        };
        let comp = 20.0 * (gain_at(1e-3) / gain_at(0.95)).log10();
        assert!((1.5..4.5).contains(&comp), "compression {comp} dB");
    }

    #[test]
    fn amam_monotone() {
        let pa = RappMemPa::new(PaSpec::ganlike());
        let mut last = 0.0;
        for k in 1..160 {
            let a = 0.01 * k as f64;
            let x = vec![[a, 0.0]; 20];
            let y = pa.run(&x);
            let out = (y[15][0].powi(2) + y[15][1].powi(2)).sqrt();
            assert!(out > last, "non-monotone at {a}");
            last = out;
        }
    }

    #[test]
    fn uncorrected_acpr_regime() {
        let sig = OfdmModulator::generate(&OfdmConfig { n_symbols: 32, seed: 7, ..Default::default() }).unwrap();
        let pa = RappMemPa::new(PaSpec::ganlike());
        let y = pa.run(&sig.iq);
        let r = acpr_db(&y, &AcprConfig::default()).unwrap();
        assert!(
            (-35.0..-28.0).contains(&r.acpr_dbc),
            "uncorrected ACPR {} dBc",
            r.acpr_dbc
        );
    }

    #[test]
    fn target_gain_backoff() {
        let s = PaSpec::ganlike();
        assert!((s.target_gain().abs() / s.linear_gain().abs() - 0.95).abs() < 1e-12);
    }
}
