//! Canonical rounding / saturation primitives of the datapath.
//!
//! These two functions define the arithmetic contract every quantized
//! implementation shares — the jax integer oracle
//! (`kernels/quant.py::rshift_round`/`saturate`), the rust functional
//! engine (`dpd::qgru`) and the cycle-accurate simulator
//! (`accel::engine`) must agree bit-for-bit, which the golden-vector
//! tests enforce.

use super::QSpec;

/// Arithmetic right shift with round-to-nearest, ties toward +inf:
/// `floor(v / 2^s + 0.5)` computed as `(v + (1 << (s-1))) >> s`.
///
/// This is the requantization step after every multiply (products of
/// two Q2.f codes carry 2f fractional bits).
///
/// **Caller contract:** `v <= i64::MAX - (1 << (s-1))` — the rounding
/// bias must not overflow the add (wrap in release, panic in debug;
/// enforced by the `debug_assert!` below). Every datapath call site
/// satisfies this by construction (accumulators are bounded by
/// `|bias| << f + Σ|w|·|x|`, orders of magnitude below the rail); a
/// caller that can legally hold arbitrary i64 accumulators must use
/// [`rshift_round_sat`] instead, as [`requantize`] does.
#[inline]
pub fn rshift_round(v: i64, s: u32) -> i64 {
    if s == 0 {
        return v;
    }
    debug_assert!(
        v <= i64::MAX - (1i64 << (s - 1)),
        "rshift_round bias overflow: v={v} s={s} violates the caller contract"
    );
    (v + (1i64 << (s - 1))) >> s
}

/// Total (contract-free) form of [`rshift_round`]: the rounding bias
/// is added with saturating arithmetic, so any i64 input is safe. On
/// the documented domain of [`rshift_round`] the two are bit-identical
/// (property-pinned below); within `2^(s-1)` of `i64::MAX` — where the
/// plain form would wrap — this saturates the bias instead, which
/// under-rounds the result by at most 1 LSB right at the rail (and the
/// only production caller, [`requantize`], clamps far below it anyway).
#[inline]
pub fn rshift_round_sat(v: i64, s: u32) -> i64 {
    if s == 0 {
        return v;
    }
    v.saturating_add(1i64 << (s - 1)) >> s
}

/// Saturate a wide accumulator into the Q2.f code range.
#[inline]
pub fn saturate_i64(v: i64, spec: QSpec) -> i32 {
    v.clamp(spec.qmin() as i64, spec.qmax() as i64) as i32
}

/// Requantize: shift by `s` then saturate (the common composition).
///
/// Takes *any* i64 accumulator — this is the one entry point whose
/// callers may legally approach the i64 rail (the signature promises
/// nothing less), so the shift uses the saturating-bias form: for
/// `acc > i64::MAX - 2^(s-1)` the plain rounding add would wrap to a
/// huge negative value and requantize to `qmin` instead of `qmax`
/// (silently in release, panicking in debug). The saturating form is
/// bit-identical everywhere else and clamps correctly at the rails.
#[inline]
pub fn requantize(acc: i64, s: u32, spec: QSpec) -> i32 {
    saturate_i64(rshift_round_sat(acc, s), spec)
}

/// i32 twin of [`rshift_round`] for the narrow accumulation path
/// (formats with `bits <= 13`, where products stay under 2^24 and sums
/// under 2^28). Caller contract: `|v| < 2^30` so the rounding bias
/// cannot overflow (debug-asserted like the i64 form). Bit-identical
/// to the i64 version on that domain — a property the `fixed::ops`
/// suite checks in both debug and release (where the overflow behavior
/// of a violated contract would differ).
#[inline]
pub fn rshift_round_i32(v: i32, s: u32) -> i32 {
    if s == 0 {
        return v;
    }
    debug_assert!(
        v <= i32::MAX - (1i32 << (s - 1)),
        "rshift_round_i32 bias overflow: v={v} s={s} violates the caller contract"
    );
    (v + (1i32 << (s - 1))) >> s
}

/// Saturate a narrow accumulator into the code range.
#[inline]
pub fn saturate_i32(v: i32, spec: QSpec) -> i32 {
    v.clamp(spec.qmin(), spec.qmax())
}

/// i32 requantize (shift + saturate) — the per-row op of the narrow
/// matvec path, scalar and batched alike.
#[inline]
pub fn requantize_i32(acc: i32, s: u32, spec: QSpec) -> i32 {
    saturate_i32(rshift_round_i32(acc, s), spec)
}

/// Requantize a whole accumulator block element-wise — the SoA form
/// the batched kernels use after each matvec. Equivalent to applying
/// [`requantize_i32`] per element in any split of the block (the
/// "commutativity of batching" invariant the property suite pins).
#[inline]
pub fn requantize_block_i32(acc: &[i32], s: u32, spec: QSpec, out: &mut [i32]) {
    debug_assert_eq!(acc.len(), out.len());
    for (o, &a) in out.iter_mut().zip(acc) {
        *o = requantize_i32(a, s, spec);
    }
}

/// The delta-engine threshold test: does a column delta `d` (in codes)
/// exceed θ? θ semantics are defined here once for every delta kernel:
/// a column is *propagated* iff `|d| > θ`, so θ = 0 propagates every
/// nonzero delta — which is exactly what makes the θ=0 delta path
/// bit-identical to the dense path (skipped columns have `d == 0` and
/// contribute nothing).
#[inline(always)]
pub fn exceeds_theta(d: i32, theta: u32) -> bool {
    d.unsigned_abs() > theta
}

/// The delta-engine column update: fold a propagated column delta into
/// the carried raw accumulators, `acc[r] += w_col[r] * d`. In exact
/// (i64) arithmetic this keeps the invariant
/// `acc == bias << f + W · v_prev` — the algebra that lets a delta
/// step skip every below-threshold column while the θ=0 path stays
/// bit-identical to recomputing the dense matvec from scratch.
#[inline]
pub fn delta_axpy_i64(acc: &mut [i64], w_col: &[i32], d: i32) {
    debug_assert_eq!(acc.len(), w_col.len());
    for (a, &w) in acc.iter_mut().zip(w_col) {
        *a += w as i64 * d as i64;
    }
}

/// Requantize a block of wide (i64) delta accumulators into codes —
/// the per-step readout of the delta engine. Element-wise
/// [`requantize`]; agrees with the narrow i32 block form on the
/// documented narrow domain (property-pinned below).
#[inline]
pub fn requantize_block_i64(acc: &[i64], s: u32, spec: QSpec, out: &mut [i32]) {
    debug_assert_eq!(acc.len(), out.len());
    for (o, &a) in out.iter_mut().zip(acc) {
        *o = requantize(a, s, spec);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn rshift_round_matches_float_reference() {
        check("rshift_round vs floor(v/2^s+0.5)", 500, |rng| {
            let v = rng.int_in(-(1 << 40), 1 << 40);
            let s = rng.int_in(1, 20) as u32;
            let got = rshift_round(v, s);
            let want = ((v as f64) / (1i64 << s) as f64 + 0.5).floor() as i64;
            if got != want {
                return Err(format!("v={v} s={s}: got {got} want {want}"));
            }
            Ok(())
        });
    }

    #[test]
    fn rshift_round_ties_toward_plus_inf() {
        // -1.5 rounds to -1 (toward +inf), +1.5 rounds to +2
        assert_eq!(rshift_round(-3, 1), -1);
        assert_eq!(rshift_round(3, 1), 2);
        assert_eq!(rshift_round(-2, 2), 0); // -0.5 -> 0
        assert_eq!(rshift_round(2, 2), 1); // 0.5 -> 1
    }

    #[test]
    fn rshift_round_zero_shift_identity() {
        assert_eq!(rshift_round(-12345, 0), -12345);
        assert_eq!(rshift_round_sat(-12345, 0), -12345);
    }

    #[test]
    fn saturating_form_bit_identical_on_the_contract_domain() {
        // rshift_round_sat must equal rshift_round everywhere the
        // caller contract holds — run in debug AND release (the plain
        // form would wrap silently in release on a violation)
        check("rshift_round_sat vs rshift_round", 800, |rng| {
            let s = rng.int_in(1, 40) as u32;
            let v = rng.int_in(-(1 << 60), (1 << 60) - 1);
            let got = rshift_round_sat(v, s);
            let want = rshift_round(v, s);
            if got != want {
                return Err(format!("v={v} s={s}: sat {got} vs plain {want}"));
            }
            Ok(())
        });
    }

    #[test]
    fn requantize_safe_at_the_i64_rails() {
        // The rounding-bias overflow regression: pre-fix,
        // `requantize(v, s, spec)` for v within 2^(s-1) of i64::MAX
        // computed `v + (1 << (s-1))` — wrapping to a huge negative in
        // release (requantizing to qmin instead of qmax) and panicking
        // in debug. The saturating form must clamp to qmax.
        for bits in [8u32, 12, 16] {
            let spec = QSpec::new(bits).unwrap();
            let s = spec.frac();
            for v in [i64::MAX, i64::MAX - 1, i64::MAX - (1 << (s - 1)) + 1] {
                assert_eq!(requantize(v, s, spec), spec.qmax(), "bits={bits} v={v}");
            }
            assert_eq!(requantize(i64::MIN, s, spec), spec.qmin());
            assert_eq!(requantize(i64::MIN + 1, s, spec), spec.qmin());
            // just inside the plain form's contract: both forms agree
            let edge = i64::MAX - (1 << (s - 1));
            assert_eq!(rshift_round_sat(edge, s), rshift_round(edge, s));
        }
        // saturating bias at the very rail: documented semantics
        assert_eq!(rshift_round_sat(i64::MAX, 4), i64::MAX >> 4);
    }

    #[test]
    fn saturate_clamps() {
        let s = QSpec::Q12;
        assert_eq!(saturate_i64(5_000_000, s), 2047);
        assert_eq!(saturate_i64(-5_000_000, s), -2048);
        assert_eq!(saturate_i64(123, s), 123);
    }

    #[test]
    fn requantize_composition() {
        check("requantize = shift then sat", 300, |rng| {
            let spec = QSpec::new(rng.int_in(4, 16) as u32).unwrap();
            let acc = rng.int_in(-(1 << 34), 1 << 34);
            let s = spec.frac();
            let got = requantize(acc, s, spec);
            let want = saturate_i64(rshift_round(acc, s), spec);
            if got != want {
                return Err(format!("acc={acc}"));
            }
            Ok(())
        });
    }

    #[test]
    fn i32_twin_matches_i64_on_the_narrow_domain() {
        // The batched SoA kernels accumulate in i32 (bits <= 13); the
        // i32 requantize must agree with the canonical i64 one on the
        // whole documented domain |v| < 2^30. Run under both debug and
        // release in CI — a contract violation would wrap silently in
        // release but panic in debug.
        check("rshift_round_i32 vs i64", 800, |rng| {
            let v = rng.int_in(-(1 << 30) + 1, (1 << 30) - 1) as i32;
            let s = rng.int_in(0, 14) as u32;
            let got = rshift_round_i32(v, s) as i64;
            let want = rshift_round(v as i64, s);
            if got != want {
                return Err(format!("v={v} s={s}: got {got} want {want}"));
            }
            Ok(())
        });
        check("requantize_i32 vs i64", 800, |rng| {
            let spec = QSpec::new(rng.int_in(4, 13) as u32).unwrap();
            let v = rng.int_in(-(1 << 29), 1 << 29) as i32;
            let got = requantize_i32(v, spec.frac(), spec);
            let want = requantize(v as i64, spec.frac(), spec);
            if got != want {
                return Err(format!("v={v} bits={}: got {got} want {want}", spec.bits));
            }
            Ok(())
        });
    }

    #[test]
    fn i32_rounding_ties_toward_plus_inf() {
        assert_eq!(rshift_round_i32(-3, 1), -1);
        assert_eq!(rshift_round_i32(3, 1), 2);
        assert_eq!(rshift_round_i32(-2, 2), 0);
        assert_eq!(rshift_round_i32(2, 2), 1);
        assert_eq!(rshift_round_i32(-12345, 0), -12345);
    }

    #[test]
    fn i32_saturation_always_lands_in_code_range() {
        check("requantize_i32 saturates", 600, |rng| {
            let spec = QSpec::new(rng.int_in(4, 13) as u32).unwrap();
            let v = rng.int_in(-(1 << 30) + 1, (1 << 30) - 1) as i32;
            let s = rng.int_in(0, 14) as u32;
            let got = requantize_i32(v, s, spec);
            if got < spec.qmin() || got > spec.qmax() {
                return Err(format!("v={v} s={s} escaped: {got}"));
            }
            // saturation is sticky at the rails
            if saturate_i32(i32::MAX / 2, spec) != spec.qmax()
                || saturate_i32(i32::MIN / 2, spec) != spec.qmin()
            {
                return Err("rails not clamped".into());
            }
            Ok(())
        });
    }

    #[test]
    fn block_requantize_commutes_with_batching() {
        // The invariant the batched kernels lean on: requantizing a
        // whole SoA block equals requantizing any split of it and
        // concatenating — i.e. batching lanes together cannot change
        // any lane's values.
        check("requantize_block_i32 split-invariant", 300, |rng| {
            let spec = QSpec::new(rng.int_in(4, 13) as u32).unwrap();
            let s = spec.frac();
            let n = rng.int_in(1, 64) as usize;
            let acc: Vec<i32> =
                (0..n).map(|_| rng.int_in(-(1 << 29), 1 << 29) as i32).collect();
            let mut whole = vec![0i32; n];
            requantize_block_i32(&acc, s, spec, &mut whole);
            // element-wise reference
            for (i, (&w, &a)) in whole.iter().zip(&acc).enumerate() {
                if w != requantize_i32(a, s, spec) {
                    return Err(format!("element {i} diverged"));
                }
            }
            // arbitrary split point
            let cut = rng.int_in(0, n as i64) as usize;
            let mut parts = vec![0i32; n];
            requantize_block_i32(&acc[..cut], s, spec, &mut parts[..cut]);
            requantize_block_i32(&acc[cut..], s, spec, &mut parts[cut..]);
            if parts != whole {
                return Err(format!("split at {cut} changed the block"));
            }
            Ok(())
        });
    }

    #[test]
    fn theta_test_defines_the_propagation_rule() {
        // |d| > θ, strictly: θ=0 propagates every nonzero delta and
        // nothing else (the θ=0 bit-exactness hinge), θ=k skips
        // exactly |d| <= k
        assert!(!exceeds_theta(0, 0));
        assert!(exceeds_theta(1, 0));
        assert!(exceeds_theta(-1, 0));
        assert!(!exceeds_theta(5, 5));
        assert!(!exceeds_theta(-5, 5));
        assert!(exceeds_theta(6, 5));
        assert!(exceeds_theta(-6, 5));
        // i32::MIN must not overflow the magnitude test: |MIN| = 2^31
        // sits exactly one above i32::MAX as a u32
        assert!(exceeds_theta(i32::MIN, i32::MAX as u32));
        assert!(!exceeds_theta(i32::MIN, 1u32 << 31));
    }

    #[test]
    fn delta_axpy_reconstructs_the_dense_matvec() {
        // The accumulator invariant: starting from bias << f and
        // applying delta_axpy for an arbitrary update schedule that
        // ends with every column at its final value reproduces the
        // dense accumulator exactly.
        check("delta axpy vs dense recompute", 300, |rng| {
            let rows = rng.int_in(1, 40) as usize;
            let cols = rng.int_in(1, 12) as usize;
            let f = rng.int_in(2, 12) as u32;
            let w: Vec<i32> =
                (0..rows * cols).map(|_| rng.int_in(-2048, 2047) as i32).collect();
            let bias: Vec<i32> = (0..rows).map(|_| rng.int_in(-2048, 2047) as i32).collect();
            let x: Vec<i32> = (0..cols).map(|_| rng.int_in(-2048, 2047) as i32).collect();
            // delta path: several intermediate values per column, each
            // folded as a delta from the previous one
            let mut acc: Vec<i64> = bias.iter().map(|&b| (b as i64) << f).collect();
            let mut prev = vec![0i32; cols];
            let hops = rng.int_in(1, 3);
            for _ in 0..hops {
                for c in 0..cols {
                    let v = rng.int_in(-2048, 2047) as i32;
                    delta_axpy_i64(&mut acc, &w[c * rows..(c + 1) * rows], v - prev[c]);
                    prev[c] = v;
                }
            }
            for c in 0..cols {
                delta_axpy_i64(&mut acc, &w[c * rows..(c + 1) * rows], x[c] - prev[c]);
            }
            // dense recompute
            for r in 0..rows {
                let mut dense = (bias[r] as i64) << f;
                for c in 0..cols {
                    dense += w[c * rows + r] as i64 * x[c] as i64;
                }
                if acc[r] != dense {
                    return Err(format!("row {r}: delta {} vs dense {dense}", acc[r]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn i64_block_requantize_matches_elementwise_and_narrow_twin() {
        check("requantize_block_i64 vs requantize / i32 block", 300, |rng| {
            let spec = QSpec::new(rng.int_in(4, 13) as u32).unwrap();
            let s = spec.frac();
            let n = rng.int_in(1, 48) as usize;
            // narrow-domain accumulators so the i32 twin is also valid
            let acc: Vec<i64> =
                (0..n).map(|_| rng.int_in(-(1 << 29), 1 << 29)).collect();
            let mut wide = vec![0i32; n];
            requantize_block_i64(&acc, s, spec, &mut wide);
            let acc32: Vec<i32> = acc.iter().map(|&a| a as i32).collect();
            let mut narrow = vec![0i32; n];
            requantize_block_i32(&acc32, s, spec, &mut narrow);
            for (i, (&a, (&w, &nr))) in
                acc.iter().zip(wide.iter().zip(&narrow)).enumerate()
            {
                if w != requantize(a, s, spec) {
                    return Err(format!("element {i} diverged from requantize"));
                }
                if w != nr {
                    return Err(format!("element {i}: i64 block {w} vs i32 block {nr}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn product_requantize_matches_real_arithmetic() {
        // (a/2^f)*(b/2^f) rounded back to f frac bits == requantize(a*b, f)
        check("product requantize", 500, |rng| {
            let spec = QSpec::Q12;
            let a = rng.int_in(spec.qmin() as i64, spec.qmax() as i64);
            let b = rng.int_in(spec.qmin() as i64, spec.qmax() as i64);
            let got = requantize(a * b, spec.frac(), spec) as f64 / spec.scale();
            let real = (a as f64 / spec.scale()) * (b as f64 / spec.scale());
            // round-half-up on the code grid, then saturate
            let code = (real * spec.scale() + 0.5).floor();
            let want = code.clamp(spec.qmin() as f64, spec.qmax() as f64) / spec.scale();
            if (got - want).abs() > 1e-12 {
                return Err(format!("a={a} b={b}: got {got} want {want}"));
            }
            Ok(())
        });
    }
}
