//! The unified DPD engine backend: one frame-level trait over every
//! engine substrate, plus the factory the coordinator and benches use
//! to construct them.
//!
//! [`DpdEngine`] is the execution contract of the transmit chain: a
//! mutable burst of f64 I/Q goes in, the predistorted burst comes out
//! in place. Two families implement it:
//!
//! * **streaming** engines ([`StreamingEngine`] over any [`Dpd`]) —
//!   sample-in/sample-out, hidden state carries across frames (the
//!   silicon's continuous operating mode);
//! * **frame** engines ([`InterpGruEngine`], and [`HloEngine`] under
//!   `--features xla`) — shape-specialized to a compiled frame length,
//!   hidden state resets at every frame start (h0 = 0, the AOT HLO
//!   artifact's training convention). They report the length through
//!   [`DpdEngine::frame_len`] so the framer can match it.
//!
//! Parity contract (enforced by the unit tests below, the golden
//! vectors and the conformance matrix in `tests/conformance.rs`):
//! `fixed`, `cyclesim`, `interp` and `delta` at θ=0 share the
//! bit-exact integer datapath — equal inputs give *identical* outputs
//! (modulo the frame-reset semantics of `interp`). `delta` with
//! θ>0 deliberately trades bounded drift for skipped MACs (golden
//! delta trace pins the envelope). The `+simd` decoration puts the
//! same datapaths behind the vector
//! [`GateKernel`](crate::fixed::GateKernel), bit-identical to
//! their scalar twins on every host (the kernel seam's contract) —
//! including when the host lacks AVX2 or `DPD_SIMD=off` forces the
//! scalar fallback. `native` is the float
//! reference; it tracks the integer engines within the quantization
//! envelope (documented tolerance: NMSE better than -12 dB and
//! per-sample deviation under 0.3 on small-signal stimulus at Q2.10).
//!
//! Engine selection is string-addressable: [`EngineSpec::parse`] and
//! `Display` round-trip the spec grammar `native |
//! fixed[@WwAa][+sparse:ρ][+simd] | delta[:θ][@WwAa][+sparse:ρ][+simd]
//! | cyclesim | interp | hlo`. The spec is *normalized*: one struct
//! with a base plus independent decoration axes (`theta`, `profile`,
//! `rho`, `simd`) — the `@WwAa` (per-tensor mixed-precision profile)
//! and `+sparse:ρ` (magnitude pruning) decorations select the
//! [`SparseMpGruDpd`] family member — and
//! [`EngineFactory::available_kinds`] returns structured
//! [`EngineDescriptor`] rows (kind, spec, syntax, host SIMD state) so
//! CLI help, the conformance grid and examples render from the
//! registry instead of hardcoded lists.
//!
//! Without the `xla` feature, `EngineBase::Hlo` does not exist and the
//! frame-semantics role is served by `Interp` — the pure-Rust
//! *interpreted* twin of the HLO artifact: the same bit-exact
//! `QGruDpd` datapath the artifact was lowered from, run with the same
//! per-frame h0 reset and tail zero-padding. Default builds therefore
//! stay hermetic (no PJRT, no network) without losing the frame path.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::accel::act_unit::ActImpl;
use crate::accel::fsm::HwConfig;
use crate::accel::CycleAccurateEngine;
use crate::dpd::qgru::{ActKind, DeltaQGruDpd, QGruDpd};
use crate::dpd::weights::{GruWeights, QGruWeights};
use crate::dpd::{Dpd, GruDpd, SparseMpGruDpd, SparseQGruWeights};
use crate::fixed::kernel::{resolve_simd, SimdPolicy};
use crate::fixed::{QProfile, QSpec};
use crate::runtime::Manifest;
use crate::util::fnv1a_words;

pub use crate::dpd::{DpdLane, DpdState};

/// Frame length used by `Interp` when the artifact tree carries no
/// lowered HLO entry to inherit a shape from.
pub const DEFAULT_FRAME_LEN: usize = 2048;

/// The engine substrate an [`EngineSpec`] selects — the part of the
/// spec grammar before any decoration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineBase {
    /// f64 GRU (float reference)
    NativeF64,
    /// the bit-exact integer datapath, dense recompute every sample
    /// (the chip's functional model)
    Fixed,
    /// the bit-exact integer datapath with DeltaDPD column skipping at
    /// threshold `theta` (codes). θ=0 is bit-identical to `Fixed` —
    /// now a *structural* identity (one executor, one plan seam);
    /// θ>0 trades bounded ACPR/EVM drift for skipped MACs
    Delta,
    /// cycle-accurate ASIC simulator
    CycleSim,
    /// interpreted frame engine: the bit-exact `QGruDpd` run with the
    /// HLO artifact's frame semantics (h0 reset per frame) — the
    /// hermetic stand-in for `Hlo`
    Interp,
    /// AOT HLO via the PJRT CPU client (frame-based)
    #[cfg(feature = "xla")]
    Hlo,
}

/// Which DPD engine a worker instantiates — the normalized form of the
/// spec grammar `base[:θ][@WwAa][+sparse:ρ][+simd]`. One struct
/// replaces the historical enum whose variants enumerated decoration
/// *combinations* (`Fixed`, `FixedSimd`, `DeltaFixed`, `SparseMp{..}`,
/// …): every axis is now its own field, so the factory dispatches on
/// `base` once and composition happens in data, not in variant count.
///
/// Field invariants (what [`EngineSpec::parse`] constructs and
/// `Display` assumes):
///
/// * `theta` is meaningful only for `base == Delta` (0 elsewhere);
/// * `profile`/`rho`/`simd` decorate only the integer bases
///   (`Fixed`/`Delta`) — decorated non-integer bases are rejected by
///   the parser and never constructed by the registry;
/// * `profile.is_some() || rho.is_some()` selects the sparse +
///   mixed-precision family ([`SparseMpGruDpd`]): `rho: Some(0)`
///   (CSC storage, nothing pruned) is a *different engine* from
///   `rho: None` (dense storage) even though both compute the same
///   function — the conformance hinge `fixed+sparse:0 ≡ fixed` is
///   bit-exactness across that representation change;
/// * `simd` requests the vector [`GateKernel`](crate::fixed::GateKernel);
///   on hosts without AVX2, or under `DPD_SIMD=off` /
///   [`SimdPolicy::Off`], construction silently falls back to the
///   scalar kernel — same bits (the kernel seam's contract), no error.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineSpec {
    /// the engine substrate (`native | fixed | delta | cyclesim |
    /// interp | hlo`)
    pub base: EngineBase,
    /// delta propagation threshold in Q-format codes (`delta:θ`);
    /// always 0 for non-`Delta` bases
    pub theta: u32,
    /// `Some((w, a))` = per-tensor weight bits `w`, activation bits
    /// `a` (the `@WwAa` decoration); `None` = uniform at the
    /// manifest's Q-format
    pub profile: Option<(u8, u8)>,
    /// `Some(ρ)` = prune the ρ% smallest-magnitude codes per gate
    /// tensor into CSC storage (the `+sparse:ρ` decoration); `None` =
    /// dense storage
    pub rho: Option<u8>,
    /// run the inner loops behind the vector kernel (the `+simd`
    /// suffix)
    pub simd: bool,
}

/// The historical name: every call site and config string says
/// "engine kind"; the normalized struct is the same concept.
pub type EngineKind = EngineSpec;

impl EngineSpec {
    const fn bare(base: EngineBase) -> EngineSpec {
        EngineSpec { base, theta: 0, profile: None, rho: None, simd: false }
    }

    /// f64 GRU (float reference) — spec string `native`.
    pub const fn native() -> EngineSpec {
        EngineSpec::bare(EngineBase::NativeF64)
    }

    /// Bit-exact fixed point — spec string `fixed`.
    pub const fn fixed() -> EngineSpec {
        EngineSpec::bare(EngineBase::Fixed)
    }

    /// Delta-sparsity fixed point at threshold θ — spec string
    /// `delta:θ`.
    pub const fn delta(theta: u32) -> EngineSpec {
        EngineSpec { base: EngineBase::Delta, theta, profile: None, rho: None, simd: false }
    }

    /// `fixed` behind the vector kernel — spec string `fixed+simd`.
    pub const fn fixed_simd() -> EngineSpec {
        EngineSpec { base: EngineBase::Fixed, theta: 0, profile: None, rho: None, simd: true }
    }

    /// `delta:θ` behind the vector kernel — spec string
    /// `delta:θ+simd`.
    pub const fn delta_simd(theta: u32) -> EngineSpec {
        EngineSpec { base: EngineBase::Delta, theta, profile: None, rho: None, simd: true }
    }

    /// Cycle-accurate ASIC simulator — spec string `cyclesim`.
    pub const fn cyclesim() -> EngineSpec {
        EngineSpec::bare(EngineBase::CycleSim)
    }

    /// Interpreted frame engine — spec string `interp`.
    pub const fn interp() -> EngineSpec {
        EngineSpec::bare(EngineBase::Interp)
    }

    /// AOT HLO via PJRT — spec string `hlo`.
    #[cfg(feature = "xla")]
    pub const fn hlo() -> EngineSpec {
        EngineSpec::bare(EngineBase::Hlo)
    }

    /// Add the `+simd` decoration (integer bases only — the parser
    /// and registry never attach it elsewhere).
    pub const fn with_simd(self) -> EngineSpec {
        EngineSpec { simd: true, ..self }
    }

    /// Add the `@WwAa` mixed-precision decoration (selects the sparse
    /// family).
    pub const fn with_profile(self, w: u8, a: u8) -> EngineSpec {
        EngineSpec { profile: Some((w, a)), ..self }
    }

    /// Add the `+sparse:ρ` pruning decoration (selects the sparse
    /// family; ρ=0 means CSC storage with nothing pruned).
    pub const fn with_rho(self, rho: u8) -> EngineSpec {
        EngineSpec { rho: Some(rho), ..self }
    }

    /// Whether this spec constructs the sparse + mixed-precision
    /// family member ([`SparseMpGruDpd`]) rather than the dense-storage
    /// executor: any `@WwAa` or `+sparse:ρ` decoration selects it.
    pub fn is_sparse_family(&self) -> bool {
        self.profile.is_some() || self.rho.is_some()
    }

    /// Whether this spec's engine is generic over the
    /// [`GateKernel`](crate::fixed::GateKernel) seam (the integer
    /// bases; `+simd` composes only with these).
    pub fn has_kernel_seam(&self) -> bool {
        matches!(self.base, EngineBase::Fixed | EngineBase::Delta)
    }
}

impl std::fmt::Display for EngineSpec {
    /// The canonical engine-spec string; [`EngineSpec::parse`] is the
    /// exact inverse (round-trip contract, pinned by the unit tests
    /// and the grammar-wide property test).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.base {
            EngineBase::NativeF64 => return write!(f, "native"),
            EngineBase::CycleSim => return write!(f, "cyclesim"),
            EngineBase::Interp => return write!(f, "interp"),
            #[cfg(feature = "xla")]
            EngineBase::Hlo => return write!(f, "hlo"),
            EngineBase::Fixed => write!(f, "fixed")?,
            EngineBase::Delta => write!(f, "delta:{}", self.theta)?,
        }
        if let Some((w, a)) = self.profile {
            write!(f, "@W{w}A{a}")?;
        }
        if let Some(r) = self.rho {
            write!(f, "+sparse:{r}")?;
        }
        if self.simd {
            write!(f, "+simd")?;
        }
        Ok(())
    }
}

impl EngineSpec {
    /// Parse an engine-spec string — the single grammar every surface
    /// (CLI `--engine`, conformance scenario labels, service configs)
    /// shares:
    ///
    /// ```text
    /// native | fixed[@WwAa][+sparse:ρ][+simd]
    ///        | delta[:θ][@WwAa][+sparse:ρ][+simd]
    ///        | cyclesim | interp | hlo
    /// ```
    ///
    /// Bare `delta` means θ=0 (the bit-exact hinge). The `@WwAa` /
    /// `+sparse:ρ` decorations select the sparse + mixed-precision
    /// family and compose only with the `fixed` / `delta[:θ]` bases;
    /// `+simd` composes only with those bases too. The parser
    /// tokenizes on `+`, so duplicate decorations
    /// (`fixed+simd+simd`), out-of-order decorations
    /// (`fixed+simd+sparse:50`), trailing garbage (`delta:8:16`) and
    /// unknown decorations are each rejected with an error naming the
    /// offender — never last-wins or silently ignored.
    /// `parse(&k.to_string()) == k` for every kind in this build.
    pub fn parse(spec: &str) -> Result<EngineKind> {
        let s = spec.trim();
        let mut tokens = s.split('+');
        // head token: base[@WwAa]
        let head = tokens.next().unwrap_or_default();
        let (base_str, profile) = match head.split_once('@') {
            Some((b, p)) => (b, Some(parse_profile_bits(p).with_context(|| {
                format!("bad precision profile in engine spec '{spec}' (want @W<bits>A<bits>)")
            })?)),
            None => (head, None),
        };
        // decoration tokens, in Display order: [+sparse:ρ][+simd]
        let mut rho: Option<u8> = None;
        let mut simd = false;
        for deco in tokens {
            if deco == "simd" {
                if simd {
                    bail!("engine spec '{spec}': duplicate '+simd' decoration");
                }
                simd = true;
            } else if let Some(r) = deco.strip_prefix("sparse:") {
                if rho.is_some() {
                    bail!("engine spec '{spec}': duplicate '+sparse:ρ' decoration");
                }
                if simd {
                    bail!(
                        "engine spec '{spec}': decorations are ordered \
                         [@WwAa][+sparse:ρ][+simd] — '+sparse:{r}' after '+simd'"
                    );
                }
                let r: u8 = r.parse().with_context(|| {
                    format!("bad ρ in engine spec '{spec}' (want +sparse:<percent>)")
                })?;
                if r > 100 {
                    bail!("engine spec '{spec}': sparsity ρ={r} is a percentage (0..=100)");
                }
                rho = Some(r);
            } else {
                bail!("engine spec '{spec}': unknown decoration '+{deco}'");
            }
        }
        // resolve the base
        let (base, theta) = match base_str {
            "fixed" => (EngineBase::Fixed, 0),
            "delta" => (EngineBase::Delta, 0),
            _ if base_str.starts_with("delta:") => {
                let t: u32 = base_str["delta:".len()..].parse().with_context(|| {
                    format!("bad θ in engine spec '{spec}' (want delta:<codes>)")
                })?;
                (EngineBase::Delta, t)
            }
            "native" | "native-f64" => (EngineBase::NativeF64, 0),
            "cyclesim" => (EngineBase::CycleSim, 0),
            "interp" => (EngineBase::Interp, 0),
            #[cfg(feature = "xla")]
            "hlo" => (EngineBase::Hlo, 0),
            #[cfg(not(feature = "xla"))]
            "hlo" => bail!("engine 'hlo' needs a build with --features xla (try 'interp')"),
            other => bail!(
                "unknown engine '{other}' \
                 (spec grammar: native | fixed[@WwAa][+sparse:ρ][+simd] | \
                 delta[:θ][@WwAa][+sparse:ρ][+simd] | cyclesim | interp | hlo)"
            ),
        };
        // decorations compose only with the integer bases
        if !matches!(base, EngineBase::Fixed | EngineBase::Delta) {
            if profile.is_some() || rho.is_some() {
                bail!(
                    "engine spec '{spec}': '@WwAa' / '+sparse:ρ' compose only with \
                     'fixed' or 'delta[:θ]'"
                );
            }
            if simd {
                bail!("engine spec '{spec}': '+simd' composes only with 'fixed' or 'delta[:θ]'");
            }
        }
        Ok(EngineSpec { base, theta, profile, rho, simd })
    }
}

/// Parse the `W<bits>A<bits>` payload of an `@` decoration into the
/// `(weight_bits, act_bits)` pair [`EngineSpec::profile`] carries,
/// validating ranges through [`QProfile::wa`] so a spec string can
/// never name a profile the engine cannot construct.
fn parse_profile_bits(s: &str) -> Result<(u8, u8)> {
    let p = QProfile::parse_wa(s)?;
    let w = p.weight_bits().expect("wa profiles are weight-homogeneous");
    Ok((w as u8, p.act.bits as u8))
}

impl std::str::FromStr for EngineKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<EngineKind> {
        EngineKind::parse(s)
    }
}

/// One registry row from [`EngineFactory::available_kinds`]: the
/// structured description CLI help, examples and reports render from,
/// so the engine list can never drift from what the build constructs.
#[derive(Clone, Debug)]
pub struct EngineDescriptor {
    /// canonical kind (θ=0 for the delta family's registry row)
    pub kind: EngineKind,
    /// canonical spec string, `kind.to_string()`
    pub spec: String,
    /// human-facing spec syntax, e.g. `"delta[:θ][+simd]"`
    pub syntax: &'static str,
    /// `Some(active)` for kernel-seam kinds: whether the vector kernel
    /// would engage on this host under [`SimdPolicy::Auto`] (AVX2
    /// detected and not vetoed by `DPD_SIMD`); `None` for kinds with
    /// no kernel seam
    pub simd: Option<bool>,
}

/// A DPD engine behind the unified frame-level interface.
pub trait DpdEngine {
    /// Engine label for reports and stats.
    fn name(&self) -> &'static str;

    /// `Some(n)` when the engine is shape-specialized to n-sample
    /// frames (the framer should cut the stream accordingly);
    /// `None` for streaming engines that accept any burst length.
    fn frame_len(&self) -> Option<usize> {
        None
    }

    /// Predistort a burst in place. Streaming engines carry hidden
    /// state across calls; frame engines process in `frame_len()`
    /// chunks with a state reset at each frame start, zero-padding a
    /// ragged tail internally (the output keeps the input length).
    fn process_frame(&mut self, iq: &mut [[f64; 2]]) -> Result<()>;

    /// Reset internal state (no-op for frame engines, which reset at
    /// every frame anyway).
    fn reset(&mut self);

    /// Snapshot the current stream's recurrent state (the lane payload
    /// of a batched call). Default: [`DpdState::Stateless`]; stateful
    /// engines override this together with [`DpdEngine::load_state`]
    /// so the pair round-trips exactly.
    fn save_state(&self) -> DpdState {
        DpdState::Stateless
    }

    /// Restore a snapshot from [`DpdEngine::save_state`] on the same
    /// engine kind and shape.
    fn load_state(&mut self, state: &DpdState) -> Result<()> {
        match state {
            DpdState::Stateless => Ok(()),
            other => {
                anyhow::bail!("{}: cannot load a {} state snapshot", self.name(), other.kind())
            }
        }
    }

    /// Coalescing identity: engines with equal `Some` classes promise
    /// identical datapaths (kind + format + weights + activation), so
    /// the scheduler may gather their sessions' frames into one
    /// [`DpdEngine::run_batch`] call on any one of them. `None` (the
    /// default) opts out of coalescing entirely.
    fn batch_class(&self) -> Option<u64> {
        None
    }

    /// Batched execution over several independent streams: lane k's
    /// samples in `lanes[k].iq`, its recurrent state in
    /// `lanes[k].state`, both updated in place. Must be bit-identical,
    /// lane for lane, to processing each stream alone through
    /// [`DpdEngine::process_frame`] (the batch-parity contract). On
    /// error the whole batch is reported failed and the lanes must be
    /// discarded (already-processed lanes may have advanced) — the
    /// scheduler poisons every member session and drops the frames.
    ///
    /// The default multiplexes lanes sequentially via
    /// `save_state`/`load_state` (valid for engines whose snapshots
    /// round-trip their full state, and trivially for stateless frame
    /// engines); `self`'s own stream state is preserved.
    fn run_batch(&mut self, lanes: &mut [DpdLane<'_>]) -> Result<()> {
        run_batch_sequential(self, lanes)
    }
}

/// The sequential fallback behind [`DpdEngine::run_batch`].
pub fn run_batch_sequential<E: DpdEngine + ?Sized>(
    engine: &mut E,
    lanes: &mut [DpdLane<'_>],
) -> Result<()> {
    let own = engine.save_state();
    let mut result = Ok(());
    for lane in lanes.iter_mut() {
        if let Err(e) = engine.load_state(lane.state) {
            result = Err(e);
            break;
        }
        if let Err(e) = engine.process_frame(lane.iq) {
            result = Err(e);
            break;
        }
        *lane.state = engine.save_state();
    }
    engine.load_state(&own).ok();
    result
}

/// Adapter: any streaming [`Dpd`] as a [`DpdEngine`].
pub struct StreamingEngine {
    inner: Box<dyn Dpd>,
}

impl StreamingEngine {
    pub fn new(inner: Box<dyn Dpd>) -> StreamingEngine {
        StreamingEngine { inner }
    }
}

impl DpdEngine for StreamingEngine {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn process_frame(&mut self, iq: &mut [[f64; 2]]) -> Result<()> {
        for s in iq.iter_mut() {
            *s = self.inner.process(*s);
        }
        Ok(())
    }

    fn reset(&mut self) {
        self.inner.reset();
    }

    fn save_state(&self) -> DpdState {
        self.inner.save_state()
    }

    fn load_state(&mut self, state: &DpdState) -> Result<()> {
        self.inner.load_state(state)
    }

    fn batch_class(&self) -> Option<u64> {
        self.inner.batch_fingerprint()
    }

    fn run_batch(&mut self, lanes: &mut [DpdLane<'_>]) -> Result<()> {
        // delegate to the Dpd-level batched path (SoA kernels for
        // QGruDpd/GruDpd, sequential multiplexing otherwise)
        self.inner.process_lanes(lanes)
    }
}

/// Adapter: the cycle-accurate simulator as a streaming [`Dpd`].
pub struct CycleSimDpd {
    sim: CycleAccurateEngine,
    spec: QSpec,
    /// batch-class fingerprint, resolved once at construction
    fingerprint: u64,
}

impl CycleSimDpd {
    pub fn new(w: &QGruWeights) -> CycleSimDpd {
        CycleSimDpd {
            sim: CycleAccurateEngine::new(w, ActImpl::Hard, HwConfig::default()),
            spec: w.spec,
            fingerprint: fnv1a_words("cyclesim-hard", [w.fingerprint()]),
        }
    }
}

impl Dpd for CycleSimDpd {
    fn process(&mut self, iq: [f64; 2]) -> [f64; 2] {
        let codes = [self.spec.quantize(iq[0]), self.spec.quantize(iq[1])];
        let y = self.sim.step(codes).expect("sim step");
        [self.spec.dequantize(y[0]), self.spec.dequantize(y[1])]
    }
    fn reset(&mut self) {
        self.sim.reset();
    }
    fn name(&self) -> &'static str {
        "cyclesim"
    }
    fn save_state(&self) -> DpdState {
        DpdState::I32(self.sim.hidden_state())
    }
    fn load_state(&mut self, state: &DpdState) -> Result<()> {
        match state {
            DpdState::I32(h) => self.sim.set_hidden_state(h),
            other => anyhow::bail!("cyclesim: incompatible state snapshot ({})", other.kind()),
        }
    }
    fn batch_fingerprint(&self) -> Option<u64> {
        // sessions coalesce via the default sequential lane multiplexer
        // (no SoA kernel for the cycle model — it exercises the trait's
        // fallback path in the parity suite)
        Some(self.fingerprint)
    }
}

/// The interpreted frame engine: bit-exact `QGruDpd` with the HLO
/// artifact's frame semantics (h0 = 0 at frame start, zero-padded
/// tail). On the code grid its output equals the lowered artifact's.
pub struct InterpGruEngine {
    dpd: QGruDpd,
    frame_len: usize,
}

impl InterpGruEngine {
    pub fn new(dpd: QGruDpd, frame_len: usize) -> InterpGruEngine {
        assert!(frame_len > 0);
        InterpGruEngine { dpd, frame_len }
    }
}

impl DpdEngine for InterpGruEngine {
    fn name(&self) -> &'static str {
        "interp-qgru"
    }

    fn frame_len(&self) -> Option<usize> {
        Some(self.frame_len)
    }

    fn process_frame(&mut self, iq: &mut [[f64; 2]]) -> Result<()> {
        let spec = self.dpd.spec();
        let t = self.frame_len;
        let mut frame = vec![[0i32; 2]; t];
        for chunk in iq.chunks_mut(t) {
            let n = chunk.len();
            for (dst, s) in frame.iter_mut().zip(chunk.iter()) {
                *dst = [spec.quantize(s[0]), spec.quantize(s[1])];
            }
            for dst in frame.iter_mut().skip(n) {
                *dst = [0, 0];
            }
            // run_codes resets the hidden state first — frame semantics
            let y = self.dpd.run_codes(&frame);
            for (dst, &[i, q]) in chunk.iter_mut().zip(&y) {
                *dst = [spec.dequantize(i), spec.dequantize(q)];
            }
        }
        Ok(())
    }

    fn reset(&mut self) {}

    fn batch_class(&self) -> Option<u64> {
        // stateless across process_frame calls (h0 resets every frame),
        // so the default sequential run_batch is trivially bit-exact;
        // the class still gates coalescing to identical datapaths
        self.dpd
            .batch_fingerprint()
            .map(|fp| fnv1a_words("interp-frame", [fp, self.frame_len as u64]))
    }
}

/// The PJRT-executed AOT HLO artifact as a [`DpdEngine`].
#[cfg(feature = "xla")]
pub struct HloEngine {
    // the client must outlive the executable compiled on it
    _client: xla::PjRtClient,
    inner: crate::runtime::HloGruEngine,
    /// coalescing identity of the compiled artifact (file + shape +
    /// format), resolved once at load
    batch_class: u64,
}

#[cfg(feature = "xla")]
impl HloEngine {
    /// Compile the best integer HLO artifact of a manifest.
    pub fn load(m: &Manifest) -> Result<HloEngine> {
        let e = m.best_int_hlo().context("no integer HLO artifact")?.clone();
        let client = xla::PjRtClient::cpu()?;
        let spec = QSpec::new(e.bits)?;
        let inner = crate::runtime::HloGruEngine::load(
            &client,
            &m.hlo_path(&e),
            e.batch,
            e.time,
            true,
            Some(spec),
        )?;
        // coalescing identity is *content*-true like every other
        // engine's (weight fingerprints): hash the compiled artifact's
        // bytes + shape + format, so regenerating the tree in place
        // can never alias a stale executable with a fresh one
        let path = m.hlo_path(&e);
        let text = std::fs::read(&path)
            .with_context(|| format!("reading {} for the batch class", path.display()))?;
        let batch_class = fnv1a_words(
            "hlo-frame",
            [e.batch as u64, e.time as u64, e.bits as u64]
                .into_iter()
                .chain(text.into_iter().map(u64::from)),
        );
        Ok(HloEngine { _client: client, inner, batch_class })
    }
}

#[cfg(feature = "xla")]
impl DpdEngine for HloEngine {
    fn name(&self) -> &'static str {
        "hlo-pjrt"
    }

    fn frame_len(&self) -> Option<usize> {
        Some(self.inner.time)
    }

    fn process_frame(&mut self, iq: &mut [[f64; 2]]) -> Result<()> {
        let out = self.inner.run_burst(iq)?;
        iq.copy_from_slice(&out);
        Ok(())
    }

    // Frame engine: hidden state resets at every frame start (the AOT
    // artifact's training convention), so there is no cross-frame
    // stream state to reset or snapshot — the `save_state`/`load_state`
    // defaults (`Stateless`) are exact, and the default sequential
    // `run_batch` is trivially bit-identical to solo processing.
    fn reset(&mut self) {}

    fn batch_class(&self) -> Option<u64> {
        // stateless per frame (like Interp): sequential lane
        // multiplexing is exact, and the class gates coalescing to
        // sessions compiled against the identical artifact
        Some(self.batch_class)
    }
}

/// Resolves an [`EngineKind`] against an artifact tree and builds
/// engines from it. Construction happens on the caller's thread (the
/// manifest is `Send`); [`EngineFactory::build`] runs wherever the
/// engine will live — the PJRT client is `!Send`, so the coordinator
/// calls it inside the worker thread.
pub struct EngineFactory {
    kind: EngineKind,
    manifest: Arc<Manifest>,
    frame_len: Option<usize>,
    /// kernel policy for the `*Simd` kinds: `Auto` (host detection +
    /// the `DPD_SIMD` veto) or `Off` (force the scalar kernel)
    simd: SimdPolicy,
}

impl EngineFactory {
    /// Discover the artifact tree and resolve the engine's preferred
    /// frame length (frame engines inherit the lowered artifact's
    /// compiled shape).
    pub fn new(kind: EngineKind, artifacts: Option<&Path>) -> Result<EngineFactory> {
        EngineFactory::from_manifest(kind, Arc::new(Manifest::discover(artifacts)?))
    }

    /// Build a factory over an already-resolved manifest. This is how
    /// a [`DpdService`](crate::coordinator::DpdService) shares one
    /// manifest (discovery + JSON parse done once) across every
    /// session it opens, instead of re-resolving per stream.
    pub fn from_manifest(kind: EngineKind, manifest: Arc<Manifest>) -> Result<EngineFactory> {
        let frame_len = match kind.base {
            EngineBase::Interp => Some(
                manifest.best_int_hlo().map(|e| e.time).unwrap_or(DEFAULT_FRAME_LEN),
            ),
            #[cfg(feature = "xla")]
            EngineBase::Hlo => {
                Some(manifest.best_int_hlo().context("no integer HLO artifact")?.time)
            }
            _ => None,
        };
        Ok(EngineFactory { kind, manifest, frame_len, simd: SimdPolicy::default() })
    }

    /// Override the SIMD kernel policy (default [`SimdPolicy::Auto`]).
    /// `Off` forces the scalar kernel even on AVX2 hosts — the
    /// `DPD_SIMD=off` escape hatch, routed here by
    /// [`ServiceConfig`](crate::coordinator::ServiceConfig).
    pub fn with_simd_policy(mut self, simd: SimdPolicy) -> EngineFactory {
        self.simd = simd;
        self
    }

    /// Structured descriptors for every kind this build can construct,
    /// with the host's SIMD state resolved — the single source of
    /// truth for CLI help and `examples/end_to_end.rs`.
    pub fn available_kinds() -> Vec<EngineDescriptor> {
        let host_simd = resolve_simd(SimdPolicy::Auto).is_some();
        available_kinds()
            .into_iter()
            .map(|kind| {
                let (syntax, simd) = match (kind.base, kind.is_sparse_family(), kind.simd) {
                    (EngineBase::NativeF64, ..) => ("native", None),
                    (EngineBase::CycleSim, ..) => ("cyclesim", None),
                    (EngineBase::Interp, ..) => ("interp", None),
                    #[cfg(feature = "xla")]
                    (EngineBase::Hlo, ..) => ("hlo", None),
                    (EngineBase::Fixed, false, false) => ("fixed", Some(false)),
                    (EngineBase::Fixed, false, true) => ("fixed+simd", Some(host_simd)),
                    (EngineBase::Delta, false, false) => ("delta[:θ]", Some(false)),
                    (EngineBase::Delta, false, true) => ("delta[:θ]+simd", Some(host_simd)),
                    (_, true, false) => ("fixed|delta[:θ][@WwAa][+sparse:ρ]", Some(false)),
                    (_, true, true) => ("fixed|delta[:θ]+sparse:ρ+simd", Some(host_simd)),
                };
                EngineDescriptor { kind, spec: kind.to_string(), syntax, simd }
            })
            .collect()
    }

    pub fn kind(&self) -> EngineKind {
        self.kind
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The shared manifest handle (cheap to clone into more factories).
    pub fn manifest_arc(&self) -> Arc<Manifest> {
        Arc::clone(&self.manifest)
    }

    /// The frame length the framer should cut: the engine's compiled
    /// shape for frame engines, `default` for streaming engines.
    pub fn frame_len(&self, default: usize) -> usize {
        self.frame_len.unwrap_or(default)
    }

    /// Construct the engine (call on the thread that will run it).
    /// One arm per *base family*: the decoration axes (`theta`,
    /// `profile`, `rho`, `simd`) are data threaded into the shared
    /// integer-engine constructors, not dispatch.
    pub fn build(&self) -> Result<Box<dyn DpdEngine>> {
        let m = &self.manifest;
        let kind = self.kind;
        Ok(match kind.base {
            EngineBase::NativeF64 => {
                let w = GruWeights::load(&m.weights_float)?;
                Box::new(StreamingEngine::new(Box::new(GruDpd::new(w))))
            }
            EngineBase::Fixed | EngineBase::Delta => {
                let spec = QSpec::new(m.qspec_bits)?;
                if kind.is_sparse_family() {
                    // profile-less specs prune the manifest's *integer*
                    // codes directly, so `fixed+sparse:0` is
                    // bit-identical to `fixed` from the very same
                    // artifact tree; an explicit @WwAa profile needs
                    // the float twin to requantize from
                    let sw = match kind.profile {
                        None => QGruWeights::load_params_int(&m.weights_main, spec)?
                            .to_sparse(kind.rho.unwrap_or(0)),
                        Some((wb, ab)) => {
                            let prof = QProfile::wa(wb as u32, ab as u32)?;
                            GruWeights::load(&m.weights_float)?
                                .prune_quantize(prof, kind.rho.unwrap_or(0))?
                        }
                    };
                    build_sparse_engine(sw, kind, self.simd)
                } else {
                    let w = QGruWeights::load_params_int(&m.weights_main, spec)?;
                    build_int_engine(w, kind, self.simd)
                }
            }
            EngineBase::CycleSim => {
                let spec = QSpec::new(m.qspec_bits)?;
                let w = QGruWeights::load_params_int(&m.weights_main, spec)?;
                Box::new(StreamingEngine::new(Box::new(CycleSimDpd::new(&w))))
            }
            EngineBase::Interp => {
                let spec = QSpec::new(m.qspec_bits)?;
                let w = QGruWeights::load_params_int(&m.weights_main, spec)?;
                let frame = self.frame_len.unwrap_or(DEFAULT_FRAME_LEN);
                Box::new(InterpGruEngine::new(QGruDpd::new(w, ActKind::Hard), frame))
            }
            #[cfg(feature = "xla")]
            EngineBase::Hlo => Box::new(HloEngine::load(m)?),
        })
    }
}

/// Dense integer engine construction shared by the manifest-backed and
/// synthetic paths: `base` picks dense vs delta recompute, `simd`
/// requests the vector kernel (scalar fallback when the host or policy
/// vetoes it — bit-identical by the kernel seam's contract).
fn build_int_engine(w: QGruWeights, kind: EngineKind, policy: SimdPolicy) -> Box<dyn DpdEngine> {
    let kernel = if kind.simd { resolve_simd(policy) } else { None };
    match (kind.base, kernel) {
        (EngineBase::Delta, Some(k)) => Box::new(StreamingEngine::new(Box::new(
            DeltaQGruDpd::with_kernel(w, ActKind::Hard, kind.theta, k),
        ))),
        (EngineBase::Delta, None) => Box::new(StreamingEngine::new(Box::new(DeltaQGruDpd::new(
            w,
            ActKind::Hard,
            kind.theta,
        )))),
        (_, Some(k)) => {
            Box::new(StreamingEngine::new(Box::new(QGruDpd::with_kernel(w, ActKind::Hard, k))))
        }
        (_, None) => Box::new(StreamingEngine::new(Box::new(QGruDpd::new(w, ActKind::Hard)))),
    }
}

/// Sparse-family construction twin of [`build_int_engine`] (same
/// kernel-fallback contract, on the CSC gather loops).
fn build_sparse_engine(
    sw: SparseQGruWeights,
    kind: EngineKind,
    policy: SimdPolicy,
) -> Box<dyn DpdEngine> {
    let kernel = if kind.simd { resolve_simd(policy) } else { None };
    match kernel {
        Some(k) => Box::new(StreamingEngine::new(Box::new(SparseMpGruDpd::with_kernel(
            sw,
            ActKind::Hard,
            kind.theta,
            k,
        )))),
        None => Box::new(StreamingEngine::new(Box::new(SparseMpGruDpd::new(
            sw,
            ActKind::Hard,
            kind.theta,
        )))),
    }
}

impl EngineFactory {
    /// The engine-spec registry rendered as a Markdown table — the
    /// generator behind the README's engine table (embedded between
    /// `<!-- engine-spec-table:begin/end -->` markers and pinned by a
    /// drift-guard test, so the docs cannot diverge from what this
    /// build constructs). Deliberately host- and feature-independent:
    /// only the registry's syntax column is used (no live SIMD
    /// detection), and the feature-gated `hlo` row is appended
    /// statically so default and `--features xla` builds render the
    /// same table.
    pub fn spec_table_markdown() -> String {
        fn describe(kind: EngineKind) -> (&'static str, &'static str) {
            match (kind.base, kind.is_sparse_family(), kind.simd) {
                (EngineBase::NativeF64, ..) => (
                    "f64 GRU (float reference)",
                    "tracks the integer engines within the quantization envelope",
                ),
                (EngineBase::Fixed, false, false) => (
                    "bit-exact Q2.10 fixed point",
                    "the chip's functional model; the conformance baseline",
                ),
                (EngineBase::Delta, false, false) => (
                    "delta-sparsity fixed point",
                    "θ=0 is bit-identical to `fixed`; θ>0 skips MACs with bounded drift",
                ),
                (EngineBase::Fixed, false, true) => (
                    "`fixed` behind the AVX2 gate kernels",
                    "bit-identical to `fixed`; scalar fallback off-AVX2 or under `DPD_SIMD=off`",
                ),
                (EngineBase::Delta, false, true) => (
                    "`delta` behind the AVX2 gate kernels",
                    "same fallback and bit-exactness contract, on the i64 delta accumulators",
                ),
                (_, true, false) => (
                    "magnitude-pruned sparse + mixed-precision fixed point",
                    "CSC gate tensors at ρ% pruning, per-tensor W/A widths; ρ=0 at a \
                     uniform profile and θ=0 is bit-identical to `fixed`",
                ),
                (_, true, true) => (
                    "sparse CSC gathers behind the AVX2 gate kernels",
                    "bit-identical to the scalar sparse family; same fallback contract \
                     as `fixed+simd`",
                ),
                (EngineBase::CycleSim, ..) => (
                    "cycle-accurate ASIC simulator",
                    "bit-identical to `fixed`, plus cycle/energy accounting",
                ),
                (EngineBase::Interp, ..) => (
                    "interpreted frame engine",
                    "the bit-exact datapath with the HLO artifact's per-frame h0 reset",
                ),
                #[cfg(feature = "xla")]
                (EngineBase::Hlo, ..) => unreachable!("hlo row is rendered statically"),
            }
        }
        let mut out = String::from("| spec | engine | notes |\n|---|---|---|\n");
        for row in EngineFactory::available_kinds() {
            #[cfg(feature = "xla")]
            if row.kind.base == EngineBase::Hlo {
                continue;
            }
            let (what, notes) = describe(row.kind);
            out.push_str(&format!("| `{}` | {} | {} |\n", row.syntax, what, notes));
        }
        out.push_str(
            "| `hlo` | AOT-lowered HLO via the PJRT CPU client | needs a build with \
             `--features xla`; `interp` is its hermetic twin |\n",
        );
        out
    }
}

/// Build a hermetic engine of `kind` from the shared synthetic weight
/// fixtures ([`QGruWeights::synthetic`] / [`GruWeights::synthetic`],
/// seeded, no artifact tree) — the construction path of the fleet
/// tests and the `loadgen` harness. Engines built here obey the same
/// parity contract as manifest-backed ones: equal `(kind, seed)` give
/// bit-identical engines wherever they run. `frame_len` only affects
/// the frame-based `Interp` kind (`None` = [`DEFAULT_FRAME_LEN`]);
/// `hlo` has no synthetic form (it needs a compiled artifact) and is
/// rejected.
pub fn build_synthetic(
    kind: EngineKind,
    seed: u64,
    simd: SimdPolicy,
    frame_len: Option<usize>,
) -> Result<Box<dyn DpdEngine>> {
    let qw = || QGruWeights::synthetic(seed, QSpec::Q12);
    Ok(match kind.base {
        EngineBase::NativeF64 => {
            Box::new(StreamingEngine::new(Box::new(GruDpd::new(GruWeights::synthetic(seed)))))
        }
        EngineBase::Fixed | EngineBase::Delta => {
            if kind.is_sparse_family() {
                // profile-less kinds prune the same integer fixture
                // `fixed` uses (ρ=0 ≡ `fixed`, bit for bit); an
                // explicit profile requantizes the float fixture per
                // tensor
                let sw = match kind.profile {
                    None => qw().to_sparse(kind.rho.unwrap_or(0)),
                    Some((wb, ab)) => GruWeights::synthetic(seed)
                        .prune_quantize(QProfile::wa(wb as u32, ab as u32)?, kind.rho.unwrap_or(0))?,
                };
                build_sparse_engine(sw, kind, simd)
            } else {
                build_int_engine(qw(), kind, simd)
            }
        }
        EngineBase::CycleSim => Box::new(StreamingEngine::new(Box::new(CycleSimDpd::new(&qw())))),
        EngineBase::Interp => Box::new(InterpGruEngine::new(
            QGruDpd::new(qw(), ActKind::Hard),
            frame_len.unwrap_or(DEFAULT_FRAME_LEN),
        )),
        #[cfg(feature = "xla")]
        EngineBase::Hlo => bail!("hlo engines need a compiled artifact tree (no synthetic form)"),
    })
}

/// The kinds available in this build (used by reports and the CLI) —
/// the registry the conformance grid, the batch-parity suite and the
/// README table all enumerate. One row per *engine identity*: base
/// family × the decoration combinations this build ships golden
/// coverage for.
pub fn available_kinds() -> Vec<EngineKind> {
    let mut kinds = vec![
        EngineKind::native(),
        EngineKind::fixed(),
        EngineKind::delta(0),
        EngineKind::fixed_simd(),
        EngineKind::delta_simd(0),
        EngineKind::fixed().with_profile(8, 12).with_rho(50),
        EngineKind::fixed().with_rho(50).with_simd(),
        EngineKind::cyclesim(),
        EngineKind::interp(),
    ];
    #[cfg(feature = "xla")]
    kinds.push(EngineKind::hlo());
    kinds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Documented tolerance of the float reference against the
    /// integer datapath on small-signal stimulus (see module docs).
    const NATIVE_ABS_TOL: f64 = 0.3;
    const NATIVE_NMSE_DB_TOL: f64 = -12.0;

    fn synth_float_weights(seed: u64) -> GruWeights {
        let mut rng = Rng::new(seed);
        let hidden = 10;
        let features = 4;
        let mut gen = |n: usize| -> Vec<f64> { (0..n).map(|_| rng.range(-0.15, 0.15)).collect() };
        GruWeights {
            hidden,
            features,
            w_ih: gen(3 * hidden * features),
            b_ih: gen(3 * hidden),
            w_hh: gen(3 * hidden * hidden),
            b_hh: gen(3 * hidden),
            w_fc: gen(2 * hidden),
            b_fc: gen(2),
            meta_bits: None,
            meta_act: None,
            meta_val_nmse_db: None,
        }
    }

    fn stimulus(n: usize, seed: u64) -> Vec<[f64; 2]> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| [rng.gauss() * 0.2, rng.gauss() * 0.2]).collect()
    }

    fn run_engine(eng: &mut dyn DpdEngine, input: &[[f64; 2]]) -> Vec<[f64; 2]> {
        let mut buf = input.to_vec();
        eng.reset();
        eng.process_frame(&mut buf).unwrap();
        buf
    }

    #[test]
    fn backends_agree_on_short_frame() {
        // The parity claim of tests/golden_parity.rs, runnable without
        // xla or an artifact tree: table-driven over the backends, each
        // with its documented tolerance against the Fixed reference.
        let fw = synth_float_weights(42);
        let spec = QSpec::Q12;
        let qw = fw.quantize(spec).unwrap();
        let input = stimulus(48, 7);

        let mut reference =
            StreamingEngine::new(Box::new(QGruDpd::new(qw.clone(), ActKind::Hard)));
        let want = run_engine(&mut reference, &input);

        // (engine, exact?, label)
        let table: Vec<(Box<dyn DpdEngine>, bool, &str)> = vec![
            (
                Box::new(StreamingEngine::new(Box::new(QGruDpd::new(
                    qw.clone(),
                    ActKind::Hard,
                )))),
                true,
                "fixed",
            ),
            (
                Box::new(StreamingEngine::new(Box::new(CycleSimDpd::new(&qw)))),
                true,
                "cyclesim",
            ),
            (
                Box::new(StreamingEngine::new(Box::new(DeltaQGruDpd::new(
                    qw.clone(),
                    ActKind::Hard,
                    0,
                )))),
                true,
                "delta-fixed@0",
            ),
            (
                Box::new(StreamingEngine::new(Box::new(GruDpd::new(fw.clone())))),
                false,
                "native-f64",
            ),
        ];

        for (mut eng, exact, label) in table {
            let got = run_engine(eng.as_mut(), &input);
            assert_eq!(got.len(), want.len(), "{label}");
            if exact {
                assert_eq!(got, want, "{label}: integer backends must be bit-exact");
                continue;
            }
            let mut err = 0.0;
            let mut refp = 0.0;
            for (g, w) in got.iter().zip(&want) {
                let (di, dq) = (g[0] - w[0], g[1] - w[1]);
                assert!(
                    di.abs() < NATIVE_ABS_TOL && dq.abs() < NATIVE_ABS_TOL,
                    "{label}: sample deviation {di}/{dq} beyond envelope"
                );
                err += di * di + dq * dq;
                refp += w[0] * w[0] + w[1] * w[1];
            }
            let nmse = 10.0 * (err / refp).log10();
            assert!(
                nmse < NATIVE_NMSE_DB_TOL,
                "{label}: NMSE {nmse:.1} dB vs integer reference"
            );
        }
    }

    #[test]
    fn interp_matches_per_frame_reset_reference() {
        // InterpGruEngine must equal the manual chunk/reset/pad loop
        // (i.e. the HLO artifact's frame semantics) exactly.
        let qw = synth_float_weights(3).quantize(QSpec::Q12).unwrap();
        let spec = qw.spec;
        let frame = 16;
        let input = stimulus(40, 11); // 2 full frames + ragged tail

        let mut interp = InterpGruEngine::new(QGruDpd::new(qw.clone(), ActKind::Hard), frame);
        let mut got = input.clone();
        interp.process_frame(&mut got).unwrap();

        let mut reference = QGruDpd::new(qw, ActKind::Hard);
        let mut want: Vec<[f64; 2]> = Vec::new();
        for chunk in input.chunks(frame) {
            let mut padded: Vec<[i32; 2]> = chunk
                .iter()
                .map(|&[i, q]| [spec.quantize(i), spec.quantize(q)])
                .collect();
            padded.resize(frame, [0, 0]);
            let y = reference.run_codes(&padded);
            want.extend(
                y[..chunk.len()]
                    .iter()
                    .map(|&[i, q]| [spec.dequantize(i), spec.dequantize(q)]),
            );
        }
        assert_eq!(got, want);
    }

    #[test]
    fn streaming_engine_state_carries_across_frames() {
        let qw = synth_float_weights(5).quantize(QSpec::Q12).unwrap();
        let input = stimulus(64, 13);

        let mut whole = StreamingEngine::new(Box::new(QGruDpd::new(qw.clone(), ActKind::Hard)));
        let want = run_engine(&mut whole, &input);

        let mut split = StreamingEngine::new(Box::new(QGruDpd::new(qw, ActKind::Hard)));
        split.reset();
        let (mut a, mut b) = (input[..24].to_vec(), input[24..].to_vec());
        split.process_frame(&mut a).unwrap();
        split.process_frame(&mut b).unwrap();
        a.extend_from_slice(&b);
        assert_eq!(a, want, "frame boundaries must not disturb streaming state");
    }

    #[test]
    fn engine_kind_is_frame_or_streaming_as_documented() {
        let qw = synth_float_weights(9).quantize(QSpec::Q12).unwrap();
        let streaming = StreamingEngine::new(Box::new(QGruDpd::new(qw.clone(), ActKind::Hard)));
        assert_eq!(streaming.frame_len(), None);
        let interp = InterpGruEngine::new(QGruDpd::new(qw, ActKind::Hard), 256);
        assert_eq!(interp.frame_len(), Some(256));
        assert_eq!(interp.name(), "interp-qgru");
    }

    #[test]
    fn batch_classes_separate_kinds_weights_and_geometry() {
        let fw = synth_float_weights(31);
        let qw = fw.quantize(QSpec::Q12).unwrap();
        let fixed_a = StreamingEngine::new(Box::new(QGruDpd::new(qw.clone(), ActKind::Hard)));
        let fixed_b = StreamingEngine::new(Box::new(QGruDpd::new(qw.clone(), ActKind::Hard)));
        let cyclesim = StreamingEngine::new(Box::new(CycleSimDpd::new(&qw)));
        let native = StreamingEngine::new(Box::new(GruDpd::new(fw.clone())));
        let interp16 = InterpGruEngine::new(QGruDpd::new(qw.clone(), ActKind::Hard), 16);
        let interp64 = InterpGruEngine::new(QGruDpd::new(qw.clone(), ActKind::Hard), 64);
        // same kind + same weights coalesce
        assert!(fixed_a.batch_class().is_some());
        assert_eq!(fixed_a.batch_class(), fixed_b.batch_class());
        // kinds never mix, even on identical weights
        assert_ne!(fixed_a.batch_class(), cyclesim.batch_class());
        assert_ne!(fixed_a.batch_class(), native.batch_class());
        assert_ne!(fixed_a.batch_class(), interp16.batch_class());
        // frame geometry is part of a frame engine's identity
        assert_ne!(interp16.batch_class(), interp64.batch_class());
        // the delta engine is its own class: never mixed with Fixed
        // (even at θ=0) and split by θ
        let delta0 = StreamingEngine::new(Box::new(DeltaQGruDpd::new(
            qw.clone(),
            ActKind::Hard,
            0,
        )));
        let delta8 = StreamingEngine::new(Box::new(DeltaQGruDpd::new(
            qw.clone(),
            ActKind::Hard,
            8,
        )));
        assert!(delta0.batch_class().is_some());
        assert_ne!(delta0.batch_class(), fixed_a.batch_class());
        assert_ne!(delta0.batch_class(), delta8.batch_class());
        // different weights never coalesce
        let other = synth_float_weights(32).quantize(QSpec::Q12).unwrap();
        let fixed_c = StreamingEngine::new(Box::new(QGruDpd::new(other, ActKind::Hard)));
        assert_ne!(fixed_a.batch_class(), fixed_c.batch_class());
    }

    #[test]
    fn run_batch_is_bit_identical_to_solo_processing() {
        // The trait-level batch-parity contract over every hermetic
        // engine family (the full differential suite lives in
        // tests/batch_parity.rs; this pins the trait defaults and the
        // StreamingEngine delegation next to their definitions).
        let fw = synth_float_weights(21);
        let qw = fw.quantize(QSpec::Q12).unwrap();
        type Mk<'a> = Box<dyn Fn() -> Box<dyn DpdEngine> + 'a>;
        let makers: Vec<(Mk, &str)> = vec![
            (
                Box::new(|| -> Box<dyn DpdEngine> {
                    Box::new(StreamingEngine::new(Box::new(QGruDpd::new(
                        qw.clone(),
                        ActKind::Hard,
                    ))))
                }),
                "fixed",
            ),
            (
                Box::new(|| -> Box<dyn DpdEngine> {
                    Box::new(StreamingEngine::new(Box::new(CycleSimDpd::new(&qw))))
                }),
                "cyclesim",
            ),
            (
                Box::new(|| -> Box<dyn DpdEngine> {
                    Box::new(StreamingEngine::new(Box::new(GruDpd::new(fw.clone()))))
                }),
                "native-f64",
            ),
            (
                Box::new(|| -> Box<dyn DpdEngine> {
                    Box::new(InterpGruEngine::new(QGruDpd::new(qw.clone(), ActKind::Hard), 16))
                }),
                "interp",
            ),
            (
                Box::new(|| -> Box<dyn DpdEngine> {
                    // θ>0 on purpose: lane snapshots must round-trip
                    // the delta caches, not just the hidden state
                    Box::new(StreamingEngine::new(Box::new(DeltaQGruDpd::new(
                        qw.clone(),
                        ActKind::Hard,
                        24,
                    ))))
                }),
                "delta-fixed@24",
            ),
        ];
        for (mk, label) in makers {
            let mut batched = mk();
            batched.reset();
            let mut solos: Vec<Box<dyn DpdEngine>> = (0..3).map(|_| mk()).collect();
            for s in solos.iter_mut() {
                s.reset();
            }
            let mut states: Vec<DpdState> =
                solos.iter().map(|_| batched.save_state()).collect();
            let mut rng = Rng::new(77);
            // several rounds: lane states must carry streams across
            // run_batch calls exactly like the solo engines' own state
            for round in 0..3 {
                let lens = [17 + round, 40, 8];
                let mut chunks: Vec<Vec<[f64; 2]>> = lens
                    .iter()
                    .map(|&n| {
                        (0..n).map(|_| [rng.gauss() * 0.2, rng.gauss() * 0.2]).collect()
                    })
                    .collect();
                let mut want = chunks.clone();
                for (s, w) in solos.iter_mut().zip(want.iter_mut()) {
                    s.process_frame(w).unwrap();
                }
                let mut lanes: Vec<DpdLane> = chunks
                    .iter_mut()
                    .zip(states.iter_mut())
                    .map(|(c, st)| DpdLane { iq: c.as_mut_slice(), state: st })
                    .collect();
                batched.run_batch(&mut lanes).unwrap();
                drop(lanes);
                assert_eq!(chunks, want, "{label}: batched lanes diverged in round {round}");
            }
        }
    }

    #[test]
    fn synthetic_sparse_family_honors_the_fixed_hinge() {
        // `fixed+sparse:0` from the synthetic construction path is
        // bit-identical to `fixed` at the same seed (the conformance
        // hinge, checked here at the factory level), while remaining
        // its own batch class — like delta@0, a sparse engine never
        // coalesces with the dense implementation
        let input = stimulus(96, 5);
        let mut fixed = build_synthetic(EngineKind::fixed(), 11, SimdPolicy::Off, None).unwrap();
        let want = run_engine(fixed.as_mut(), &input);
        let kind = EngineKind::parse("fixed+sparse:0").unwrap();
        let mut sparse = build_synthetic(kind, 11, SimdPolicy::Off, None).unwrap();
        let got = run_engine(sparse.as_mut(), &input);
        assert_eq!(got, want, "fixed+sparse:0 must be bit-identical to fixed");
        assert!(sparse.batch_class().is_some());
        assert_ne!(fixed.batch_class(), sparse.batch_class());
        // decorated kinds build working engines end to end
        for spec in ["fixed@W8A12+sparse:50", "delta:24+sparse:30", "fixed@W4A12"] {
            let kind = EngineKind::parse(spec).unwrap();
            let mut eng = build_synthetic(kind, 11, SimdPolicy::Off, None).unwrap();
            let out = run_engine(eng.as_mut(), &input);
            assert_eq!(out.len(), input.len(), "{spec}");
            assert!(out.iter().all(|s| s[0].is_finite() && s[1].is_finite()), "{spec}");
        }
    }

    #[test]
    fn available_kinds_lists_default_backends() {
        let kinds = available_kinds();
        assert!(kinds.contains(&EngineKind::native()));
        assert!(kinds.contains(&EngineKind::fixed()));
        assert!(kinds.contains(&EngineKind::delta(0)));
        assert!(kinds.contains(&EngineKind::fixed_simd()));
        assert!(kinds.contains(&EngineKind::delta_simd(0)));
        assert!(kinds.contains(&EngineKind::cyclesim()));
        assert!(kinds.contains(&EngineKind::interp()));
        assert!(kinds.contains(&EngineKind::fixed().with_profile(8, 12).with_rho(50)));
        // the SIMD sparse gather path is a first-class registry row
        assert!(kinds.contains(&EngineKind::fixed().with_rho(50).with_simd()));
        // every registry row is a distinct engine identity
        for (i, a) in kinds.iter().enumerate() {
            for b in &kinds[i + 1..] {
                assert_ne!(a, b, "duplicate registry row {a}");
            }
        }
    }

    #[test]
    fn engine_spec_strings_round_trip() {
        // parse is the exact inverse of Display for every kind in the
        // build, including non-registry θ values
        let mut kinds = available_kinds();
        kinds.push(EngineKind::delta(32));
        kinds.push(EngineKind::delta_simd(32));
        // the sparse/mixed-precision family: every combination of
        // optional decorations (profile/rho/simd) over both integer
        // bases that satisfies the at-least-one-decoration invariant
        // must round-trip
        for base in [EngineKind::fixed(), EngineKind::delta(0), EngineKind::delta(32)] {
            for profile in [None, Some((4u8, 12u8)), Some((8, 12))] {
                for rho in [None, Some(0u8), Some(50), Some(100)] {
                    if profile.is_none() && rho.is_none() {
                        continue; // the plain (dense) spellings
                    }
                    for simd in [false, true] {
                        let mut kind = base;
                        if let Some((w, a)) = profile {
                            kind = kind.with_profile(w, a);
                        }
                        if let Some(r) = rho {
                            kind = kind.with_rho(r);
                        }
                        if simd {
                            kind = kind.with_simd();
                        }
                        kinds.push(kind);
                    }
                }
            }
        }
        for kind in kinds {
            let spec = kind.to_string();
            assert_eq!(EngineKind::parse(&spec).unwrap(), kind, "round-trip of '{spec}'");
        }
        // the canonical spellings are API surface — pin them
        assert_eq!(EngineKind::fixed().to_string(), "fixed");
        assert_eq!(EngineKind::fixed_simd().to_string(), "fixed+simd");
        assert_eq!(EngineKind::delta(32).to_string(), "delta:32");
        assert_eq!(EngineKind::delta_simd(32).to_string(), "delta:32+simd");
        // bare "delta" means θ=0, with or without the simd suffix
        assert_eq!(EngineKind::parse("delta").unwrap(), EngineKind::delta(0));
        assert_eq!(EngineKind::parse("delta+simd").unwrap(), EngineKind::delta_simd(0));
        // whitespace-tolerant, and FromStr delegates
        assert_eq!(EngineKind::parse(" fixed+simd ").unwrap(), EngineKind::fixed_simd());
        assert_eq!("delta:7".parse::<EngineKind>().unwrap(), EngineKind::delta(7));
        // canonical sparse/mixed-precision spellings are API surface
        assert_eq!(EngineKind::fixed().with_rho(50).to_string(), "fixed+sparse:50");
        assert_eq!(
            EngineKind::delta(32).with_profile(8, 12).with_rho(50).with_simd().to_string(),
            "delta:32@W8A12+sparse:50+simd"
        );
        assert_eq!(
            EngineKind::parse("fixed@W4A12").unwrap(),
            EngineKind::fixed().with_profile(4, 12)
        );
        // bare `delta` with a decoration still means θ=0 (and stays a
        // distinct identity from the decorated `fixed` base)
        assert_eq!(
            EngineKind::parse("delta+sparse:30").unwrap(),
            EngineKind::delta(0).with_rho(30)
        );
        assert_ne!(
            EngineKind::parse("delta+sparse:30").unwrap(),
            EngineKind::parse("fixed+sparse:30").unwrap()
        );
    }

    #[test]
    fn engine_spec_rejects_duplicate_and_conflicting_decorations() {
        // the tokenizing parser names the offending decoration instead
        // of last-wins or silently ignoring it
        for (bad, offender) in [
            ("fixed+simd+simd", "simd"),
            ("fixed+sparse:50+sparse:30", "sparse"),
            ("delta+sparse:10+sparse:10", "sparse"),
            ("fixed+sparse:50+simd+simd", "simd"),
            ("fixed+simd+sparse:50", "ordered"),
            ("delta:8:16", "θ"),
            ("delta:0:0", "θ"),
            ("fixed+sparse:50+avx", "avx"),
        ] {
            let err = EngineKind::parse(bad).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains(offender),
                "'{bad}': error must name the offender ('{offender}'), got: {msg}"
            );
        }
    }

    #[test]
    fn engine_spec_parse_display_round_trip_property() {
        // satellite to the hand-picked round-trip list: random draws
        // over the full grammar (base × θ × @WwAa × +sparse:ρ × +simd)
        use crate::util::proptest::check;
        check("engine spec round-trip", 300, |rng| {
            let mut kind = match rng.int_in(0, 6) {
                0 => EngineKind::native(),
                1 => EngineKind::cyclesim(),
                2 => EngineKind::interp(),
                3 => EngineKind::fixed(),
                // weight the integer bases: they carry the decorations
                _ => EngineKind::delta(rng.int_in(0, 4096) as u32),
            };
            if kind.has_kernel_seam() {
                if rng.uniform() < 0.5 {
                    // only draw profiles QProfile accepts (4 ≤ w ≤ a)
                    let a = rng.int_in(4, 16);
                    let w = rng.int_in(4, a);
                    if QProfile::wa(w as u32, a as u32).is_ok() {
                        kind = kind.with_profile(w as u8, a as u8);
                    }
                }
                if rng.uniform() < 0.5 {
                    kind = kind.with_rho(rng.int_in(0, 100) as u8);
                }
                if rng.uniform() < 0.5 {
                    kind = kind.with_simd();
                }
            }
            let spec = kind.to_string();
            let parsed =
                EngineKind::parse(&spec).map_err(|e| format!("'{spec}' rejected: {e:#}"))?;
            if parsed != kind {
                return Err(format!("'{spec}' parsed to {parsed:?}, want {kind:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn engine_spec_rejects_malformed_strings() {
        for bad in [
            "",
            "quantum",
            "delta:",
            "delta:x",
            "delta:-3",
            "native+simd",
            "cyclesim+simd",
            "interp+simd",
            "fixed+avx",
            // sparse/mixed-precision decorations: incomplete payloads,
            // out-of-range widths/percentages, or the wrong base kind
            "fixed@",
            "fixed@W4",
            "fixed@4A12",
            "fixed@W13A12", // weights wider than activations
            "fixed@W2A12",  // below QSpec's 4-bit floor
            "fixed+sparse:",
            "fixed+sparse:x",
            "fixed+sparse:101",
            "cyclesim@W4A12",
            "native+sparse:50",
            "interp@W8A12+sparse:50",
        ] {
            assert!(EngineKind::parse(bad).is_err(), "'{bad}' should not parse");
        }
        #[cfg(not(feature = "xla"))]
        {
            let err = EngineKind::parse("hlo").unwrap_err();
            assert!(format!("{err:#}").contains("xla"));
        }
    }

    #[test]
    fn readme_engine_spec_table_matches_the_generator() {
        // the README's engine table is pasted generator output between
        // HTML markers; this pins it so the docs cannot drift from the
        // registry (add an engine → this fails until the README block
        // is regenerated from `EngineFactory::spec_table_markdown()`)
        let readme =
            std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/../README.md"))
                .expect("README.md at the repo root");
        let begin = "<!-- engine-spec-table:begin -->";
        let end = "<!-- engine-spec-table:end -->";
        let start = readme.find(begin).expect("README lost the begin marker") + begin.len();
        let stop = readme.find(end).expect("README lost the end marker");
        assert_eq!(
            readme[start..stop].trim(),
            EngineFactory::spec_table_markdown().trim(),
            "README engine-spec table drifted — regenerate the block between the \
             engine-spec-table markers from EngineFactory::spec_table_markdown()"
        );
    }

    #[test]
    fn factory_registry_descriptors_cover_every_kind() {
        // the structured registry is in lockstep with available_kinds
        // and every row's spec string parses back to its kind — the
        // property that keeps CLI help from drifting
        let rows = EngineFactory::available_kinds();
        assert_eq!(rows.len(), available_kinds().len());
        for row in &rows {
            assert_eq!(EngineKind::parse(&row.spec).unwrap(), row.kind, "spec '{}'", row.spec);
            assert!(!row.syntax.is_empty());
        }
        let simd_row = rows.iter().find(|r| r.kind == EngineKind::fixed_simd()).unwrap();
        assert!(simd_row.simd.is_some(), "kernel kinds must report host SIMD state");
        let scalar_row = rows.iter().find(|r| r.kind == EngineKind::fixed()).unwrap();
        assert_eq!(scalar_row.simd, Some(false), "scalar kinds carry the seam, vector off");
        let native = rows.iter().find(|r| r.kind == EngineKind::native()).unwrap();
        assert!(native.simd.is_none(), "no kernel seam on the float twin");
        let sparse_simd =
            rows.iter().find(|r| r.kind == EngineKind::fixed().with_rho(50).with_simd()).unwrap();
        assert!(sparse_simd.simd.is_some(), "the sparse gather row reports host SIMD state");
    }

    #[test]
    fn batch_class_is_independent_of_kernel_choice() {
        // Coalescing must never split on host capability: a SIMD-built
        // engine advertises the same batch class as the scalar build of
        // the same datapath (dense and delta alike), so sessions opened
        // as "fixed+simd" and "fixed" coalesce wherever the weights and
        // θ agree. The class hashes kind + format + weights + act only;
        // the kernel is bit-neutral by contract, hence class-neutral.
        use crate::fixed::SimdKernel;
        let qw = synth_float_weights(31).quantize(QSpec::Q12).unwrap();
        let scalar = StreamingEngine::new(Box::new(QGruDpd::new(qw.clone(), ActKind::Hard)));
        let scalar_delta =
            StreamingEngine::new(Box::new(DeltaQGruDpd::new(qw.clone(), ActKind::Hard, 24)));
        if let Some(k) = SimdKernel::try_new() {
            let vector = StreamingEngine::new(Box::new(QGruDpd::with_kernel(
                qw.clone(),
                ActKind::Hard,
                k,
            )));
            assert_eq!(scalar.batch_class(), vector.batch_class());
            let vector_delta = StreamingEngine::new(Box::new(DeltaQGruDpd::with_kernel(
                qw.clone(),
                ActKind::Hard,
                24,
                k,
            )));
            assert_eq!(scalar_delta.batch_class(), vector_delta.batch_class());
        } else {
            eprintln!("host has no AVX2 — scalar half of the class check only");
        }
        assert!(scalar.batch_class().is_some());
        assert_ne!(scalar.batch_class(), scalar_delta.batch_class());
    }

    #[test]
    fn factory_builds_every_available_kind_with_artifacts() {
        let Ok(factory) = EngineFactory::new(EngineKind::fixed(), None) else {
            eprintln!("skipping (no artifacts)");
            return;
        };
        drop(factory);
        for kind in available_kinds() {
            let f = EngineFactory::new(kind, None).unwrap();
            assert_eq!(f.kind(), kind);
            match f.build() {
                Ok(mut eng) => {
                    let mut burst = stimulus(32, 1);
                    eng.process_frame(&mut burst).unwrap();
                    assert_eq!(burst.len(), 32);
                }
                // the xla stub compiles but cannot execute
                #[cfg(feature = "xla")]
                Err(e) if kind == EngineKind::hlo() => {
                    eprintln!("hlo backend unavailable: {e:#}");
                }
                Err(e) => panic!("{kind:?}: {e:#}"),
            }
        }
    }

    #[test]
    fn from_manifest_shares_one_resolution() {
        // A synthetic manifest (no artifact tree on disk) is enough to
        // resolve factories for every streaming kind plus Interp's
        // default frame length — the path DpdService uses to share one
        // manifest across heterogeneous sessions.
        let m = Arc::new(Manifest {
            root: std::path::PathBuf::from("/synthetic"),
            hidden: 10,
            features: 4,
            n_params: 502,
            qspec_bits: 12,
            pa_model: std::path::PathBuf::from("/synthetic/pa.json"),
            weights_main: std::path::PathBuf::from("/synthetic/weights_main.json"),
            weights_float: std::path::PathBuf::from("/synthetic/weights_float.json"),
            sweep: Vec::new(),
            hlo: Vec::new(),
            golden: Vec::new(),
        });
        for kind in [
            EngineKind::native(),
            EngineKind::fixed(),
            EngineKind::delta(32),
            EngineKind::cyclesim(),
        ] {
            let f = EngineFactory::from_manifest(kind, Arc::clone(&m)).unwrap();
            assert_eq!(f.kind(), kind);
            assert_eq!(f.frame_len(100), 100, "streaming kinds keep the caller's frame");
        }
        let f = EngineFactory::from_manifest(EngineKind::interp(), Arc::clone(&m)).unwrap();
        assert_eq!(f.frame_len(100), DEFAULT_FRAME_LEN, "no HLO entry -> default frame");
        assert_eq!(f.manifest().n_params, 502);
        // the resolution is genuinely shared, not copied per factory
        assert!(Arc::ptr_eq(&f.manifest_arc(), &m));
    }

    /// What `artifacts.rs` also asserts, restated here because the
    /// factory depends on it: discovery fails cleanly with a pointer
    /// to `make artifacts` when no tree exists.
    #[test]
    fn factory_error_mentions_artifacts() {
        let err = EngineFactory::new(
            EngineKind::fixed(),
            Some(std::path::Path::new("/nonexistent/nowhere")),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("manifest"));
    }
}
