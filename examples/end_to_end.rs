//! End-to-end system driver — the full-stack validation run recorded
//! in EXPERIMENTS.md.
//!
//! Exercises every layer on a real workload:
//!   * signal:   windowed, TX-filtered 64-QAM CP-OFDM (62.5 MHz @ the
//!     paper's 250 MSps mapping), ~2 Msample run
//!   * L3:       one long-lived `DpdService` pool hosting a
//!     heterogeneous session per engine (manifest resolved once)
//!   * engines:  every kind in `EngineFactory::available_kinds()` —
//!     native f64, bit-exact fixed-point (scalar and AVX2 SIMD
//!     kernels), delta-sparsity, cycle-accurate ASIC sim, the
//!     interpreted frame engine, and (with `--features xla`) the AOT
//!     HLO via the embedded PJRT client
//!   * plant:    the shared GaN-Doherty-like PA model
//!   * metrics:  ACPR (Welch), NMSE-EVM, constellation EVM, throughput
//!   * ASIC:     activity-annotated power/area at the nominal point
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use dpd_ne::accel::AsicSpec;
use dpd_ne::coordinator::{
    DpdService, EngineKind, ServiceConfig, SessionAdaptConfig, SessionConfig,
};
use dpd_ne::dpd::weights::QGruWeights;
use dpd_ne::fixed::QSpec;
use dpd_ne::metrics::acpr::{acpr_db, AcprConfig};
use dpd_ne::metrics::evm::evm_db_nmse;
use dpd_ne::pa::{DriftTrajectory, DriftingPa, PaSpec, RappMemPa};
use dpd_ne::report::{f1, f2, Table};
use dpd_ne::runtime::EngineFactory;
use dpd_ne::signal::ofdm::{OfdmConfig, OfdmModulator};
use dpd_ne::signal::papr::papr_db;

fn main() -> anyhow::Result<()> {
    // the service resolves the artifact tree once; everything below —
    // PA model, per-engine sessions, ASIC weights — reuses it
    let service = DpdService::start(ServiceConfig::default())?;
    let m = service
        .manifest()
        .ok_or_else(|| anyhow::anyhow!("no artifact tree found — run `make artifacts` first"))?
        .clone();
    let pa = RappMemPa::new(PaSpec::load(&m.pa_model)?);
    let g = pa.spec.target_gain();

    // workload: ~130k samples of OFDM (488 symbols ~= 0.5 ms at 250 MSps)
    let sig = OfdmModulator::generate(&OfdmConfig { n_symbols: 480, seed: 99, ..Default::default() })?;
    println!(
        "workload: {} samples, PAPR {:.1} dB, occupied BW {:.3} fs (62.5 MHz at 250 MSps)\n",
        sig.iq.len(),
        papr_db(&sig.iq),
        sig.cfg.occupied_bw()
    );

    // reference: DPD off
    let y_off = pa.run(&sig.iq);
    let acpr_off = acpr_db(&y_off, &AcprConfig::default())?;
    let evm_off = evm_db_nmse(&y_off, &sig.iq, g);
    let cevm_off = sig.constellation_evm_db(&y_off)?;

    let mut t = Table::new(
        "End-to-end linearization, all engines (paper: ACPR -45.3 dBc, EVM -39.8 dB)",
        &["engine", "ACPR (dBc)", "EVM (dB)", "const-EVM (dB)", "engine MSps", "x250MSps"],
    );
    t.row(&[
        "off".into(),
        f1(acpr_off.acpr_dbc),
        f1(evm_off),
        f1(cevm_off),
        "-".into(),
        "-".into(),
    ]);

    // the engine list comes from the factory registry — every kind
    // this build can construct, never a hardcoded copy. The delta rows
    // are widened from the registry's θ=0 defaults: θ=0 must land in
    // the same row as Fixed (bit-identical), and the golden θ=32
    // trades ≤0.5 dB for ~2.6x fewer MACs — solo and SIMD-composed.
    let mut engines = Vec::new();
    for d in EngineFactory::available_kinds() {
        if let Some(active) = d.simd {
            println!(
                "engine {:<16} (syntax {:<16}) vector kernel {}",
                d.spec,
                d.syntax,
                if active { "active" } else { "scalar fallback" }
            );
        }
        engines.push(d.kind);
        match d.kind {
            EngineKind::DeltaFixed { .. } => {
                engines.push(EngineKind::DeltaFixed { theta: 32 });
            }
            EngineKind::DeltaFixedSimd { .. } => {
                engines.push(EngineKind::DeltaFixedSimd { theta: 32 });
            }
            _ => {}
        }
    }
    println!();

    // one persistent service hosts every engine as a session; each
    // session gets the burst pushed in chunks, state carried across
    // pushes
    for engine in engines {
        let mut session = service.open_session(SessionConfig { engine, ..Default::default() })?;
        for chunk in sig.iq.chunks(8192) {
            session.push(chunk)?;
        }
        let out = session.finish()?;
        let y = pa.run(&out.iq);
        let acpr = acpr_db(&y, &AcprConfig::default())?;
        let evm = evm_db_nmse(&y, &sig.iq, g);
        let cevm = sig.constellation_evm_db(&y)?;
        t.row(&[
            format!("{engine}"),
            f1(acpr.acpr_dbc),
            f1(evm),
            f1(cevm),
            f2(out.stats.engine_msps()),
            format!("{:.3}", out.stats.realtime_factor_vs_250msps()),
        ]);
    }
    println!("{}", t.render());

    // closed-loop adaptation: step the PA through the reference drift
    // and let the adaptive session (ILA trainer + engine hot-swaps)
    // pull the linearization back. Opening through `open_session`
    // loads the float twin from the manifest and inherits its
    // qspec_bits, so the adaptive and frozen sessions deploy the same
    // integer format.
    let mut drifted = DriftingPa::new(pa.spec.clone(), DriftTrajectory::reference(0));
    let acfg = SessionAdaptConfig { refresh_interval: 1 << 15, ..Default::default() };
    let mut session = service.open_session(SessionConfig {
        engine: EngineKind::Fixed,
        adapt: Some(acfg),
        ..Default::default()
    })?;
    let y_drift_frozen = {
        // frozen DPD on the drifted PA: the "before adaptation" point
        let cfg = SessionConfig { engine: EngineKind::Fixed, ..Default::default() };
        let mut s = service.open_session(cfg)?;
        for chunk in sig.iq.chunks(8192) {
            s.push(chunk)?;
        }
        let u = s.finish()?.iq;
        DriftingPa::new(pa.spec.clone(), DriftTrajectory::reference(0)).run(&u)
    };
    let acpr_frozen = acpr_db(&y_drift_frozen, &AcprConfig::default())?;
    let mut x_fifo: Vec<[f64; 2]> = Vec::new();
    for _round in 0..3 {
        for chunk in sig.iq.chunks(8192) {
            session.push(chunk)?;
            x_fifo.extend_from_slice(chunk);
            let u = session.drain()?;
            if u.is_empty() {
                continue;
            }
            let x: Vec<[f64; 2]> = x_fifo.drain(..u.len()).collect();
            let y = drifted.run(&u);
            session.adapt_feedback(&x, &u, &y)?;
        }
    }
    session.adapt_barrier()?;
    let astats = session.adapt_stats().expect("adaptive session");
    println!(
        "closed loop vs drifted PA: frozen DPD {} dBc; after {} refreshes ({} samples, \
         recent train NMSE {:.1} dB) window ACPR {} dBc",
        f1(acpr_frozen.acpr_dbc),
        astats.refreshes,
        astats.samples,
        astats.recent_nmse_db,
        astats.window_acpr_dbc.map(f1).unwrap_or_else(|| "-".into()),
    );
    let _ = session.finish()?;
    service.shutdown()?;

    // ASIC nominal operating point from the same weights
    let w = QGruWeights::load_params_int(&m.weights_main, QSpec::new(m.qspec_bits)?)?;
    let s = AsicSpec::nominal(&w, true);
    println!(
        "ASIC nominal point: {:.1} GOPS, {:.1} mW, {:.3} mm², {:.0} GOPS/W, PAE {:.2} TOPS/W/mm² \
         (paper: 256.5 / 195 / 0.2 / 1315 / 6.58)",
        s.throughput_gops,
        s.power.total_mw(),
        s.area.total_mm2(),
        s.power_efficiency_gops_w(),
        s.pae_tops_w_mm2()
    );
    Ok(())
}
