//! mMIMO fan-out scaling — the deployment the paper's introduction
//! motivates: one DPD engine instance per antenna stream.
//!
//! Runs 1..=8 parallel antenna streams through the coordinator and
//! reports per-stream and aggregate throughput scaling.
//!
//! ```bash
//! cargo run --release --example mmimo_streams
//! ```

use dpd_ne::coordinator::{Coordinator, CoordinatorConfig, EngineKind};
use dpd_ne::report::{f2, Table};
use dpd_ne::signal::ofdm::{OfdmConfig, OfdmModulator};

fn main() -> anyhow::Result<()> {
    let mut t = Table::new(
        "mMIMO scaling (fixed-point engine, one instance per antenna)",
        &["streams", "aggregate MSps", "per-stream MSps", "scaling eff."],
    );
    let mut base = 0.0;
    for n in [1usize, 2, 4, 8] {
        let inputs: Vec<Vec<[f64; 2]>> = (0..n)
            .map(|k| {
                OfdmModulator::generate(&OfdmConfig {
                    n_symbols: 96,
                    seed: 100 + k as u64,
                    ..Default::default()
                })
                .unwrap()
                .iq
            })
            .collect();
        let total: usize = inputs.iter().map(|v| v.len()).sum();
        let coord = Coordinator::new(CoordinatorConfig {
            engine: EngineKind::Fixed,
            ..Default::default()
        });
        let t0 = std::time::Instant::now();
        let outs = coord.run_streams(inputs)?;
        let wall = t0.elapsed();
        assert_eq!(outs.iter().map(|o| o.iq.len()).sum::<usize>(), total);
        let agg = total as f64 / wall.as_secs_f64() / 1e6;
        if n == 1 {
            base = agg;
        }
        t.row(&[
            n.to_string(),
            f2(agg),
            f2(agg / n as f64),
            format!("{:.0}%", 100.0 * agg / (base * n as f64)),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
