//! The 2-PE preprocessor (paper §III-A): extracts the Eq. (1) feature
//! vector from the incoming I/Q codes. One PE squares-and-sums the
//! I/Q pair, the other squares the envelope feature; the x4
//! conditioning is the requantize shift (f-2), free in hardware.

use crate::fixed::ops::requantize;
use crate::fixed::QSpec;

/// Preprocessor unit with activity counters.
#[derive(Clone, Debug)]
pub struct Preprocessor {
    pub spec: QSpec,
    pub op_count: u64,
}

impl Preprocessor {
    pub fn new(spec: QSpec) -> Preprocessor {
        Preprocessor { spec, op_count: 0 }
    }

    /// Cycle 0: p = requant(i^2 + q^2, f-2)  (PE #1: 2 mults + add).
    #[inline]
    pub fn stage1(&mut self, iq: [i32; 2]) -> i32 {
        self.op_count += 3;
        let (i, q) = (iq[0] as i64, iq[1] as i64);
        requantize(i * i + q * q, self.spec.frac() - 2, self.spec)
    }

    /// Cycle 1: p2 = requant(p^2, f)  (PE #2: 1 mult).
    #[inline]
    pub fn stage2(&mut self, p: i32) -> i32 {
        self.op_count += 1;
        requantize(p as i64 * p as i64, self.spec.frac(), self.spec)
    }

    /// Both stages: the full feature vector.
    pub fn features(&mut self, iq: [i32; 2]) -> [i32; 4] {
        let p = self.stage1(iq);
        let p2 = self.stage2(p);
        [iq[0], iq[1], p, p2]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpd::qgru::{ActKind, QGruDpd};
    use crate::dpd::weights::QGruWeights;
    use crate::util::proptest::check;

    fn dummy_weights(spec: QSpec) -> QGruWeights {
        QGruWeights {
            hidden: 10,
            features: 4,
            spec,
            w_ih: vec![0; 120],
            b_ih: vec![0; 30],
            w_hh: vec![0; 300],
            b_hh: vec![0; 30],
            w_fc: vec![0; 20],
            b_fc: vec![0; 2],
        }
    }

    #[test]
    fn matches_qgru_features() {
        check("preproc vs qgru features", 200, |rng| {
            let spec = QSpec::Q12;
            let mut pp = Preprocessor::new(spec);
            let dpd = QGruDpd::new(dummy_weights(spec), ActKind::Hard);
            let iq = [
                rng.int_in(spec.qmin() as i64, spec.qmax() as i64) as i32,
                rng.int_in(spec.qmin() as i64, spec.qmax() as i64) as i32,
            ];
            if pp.features(iq) != dpd.features(iq) {
                return Err(format!("feature mismatch for {iq:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn counts_ops() {
        let mut pp = Preprocessor::new(QSpec::Q12);
        pp.features([100, -200]);
        assert_eq!(pp.op_count, 4);
    }
}
