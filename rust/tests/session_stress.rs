//! Service stress: many short-lived sessions churning next to one
//! long-lived session on a deliberately small pool with depth-1
//! queues — maximal contention on the worker command channels. A
//! watchdog fails the test if the whole run doesn't complete within
//! the timeout, which is how CI detects pool deadlocks rather than
//! hanging the job.
//!
//! Hermetic: synthetic weights, no artifact tree needed. CI runs this
//! as its own step (`cargo test --release --test session_stress`).

use anyhow::Result;
use dpd_ne::coordinator::{DpdService, ServiceConfig, SessionConfig};
use dpd_ne::dpd::qgru::{ActKind, QGruDpd};
use dpd_ne::dpd::weights::QGruWeights;
use dpd_ne::fixed::QSpec;
use dpd_ne::runtime::backend::StreamingEngine;
use dpd_ne::runtime::DpdEngine;
use dpd_ne::util::Rng;

const WATCHDOG: std::time::Duration = std::time::Duration::from_secs(120);

fn signal(n: usize, seed: u64) -> Vec<[f64; 2]> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect()
}

fn fixed_engine(seed: u64) -> Box<dyn DpdEngine> {
    let qw = QGruWeights::synthetic(seed, QSpec::Q12);
    Box::new(StreamingEngine::new(Box::new(QGruDpd::new(qw, ActKind::Hard))))
}

fn stress(batch: usize) -> Result<()> {
    // queue_depth 1 keeps the original maximal-contention shape (the
    // service itself sizes worker channels up to `batch` for gathering)
    let service = DpdService::start(ServiceConfig {
        workers: 2,
        queue_depth: 1,
        frame_len: 32,
        batch,
        ..Default::default()
    })?;
    std::thread::scope(|scope| -> Result<()> {
        let svc = &service;
        // one long-lived session streaming for the whole run (state
        // persists across all 100 bursts); its full output is checked
        // against the direct bit-exact oracle at the end, so batched
        // scheduling under churn cannot silently corrupt a stream
        let long = scope.spawn(move || -> Result<()> {
            let mut sess =
                svc.open_session_with(SessionConfig::default(), || Ok(fixed_engine(1)))?;
            let burst = signal(257, 9);
            let mut n_in = 0usize;
            let mut got: Vec<[f64; 2]> = Vec::new();
            for _ in 0..100 {
                sess.push(&burst)?;
                n_in += burst.len();
                got.extend(sess.drain()?);
            }
            got.extend(sess.finish()?.iq);
            anyhow::ensure!(
                got.len() == n_in,
                "long-lived session lost samples: {}/{n_in}",
                got.len()
            );
            let whole: Vec<[f64; 2]> =
                std::iter::repeat(burst).take(100).flatten().collect();
            let mut oracle = QGruDpd::new(QGruWeights::synthetic(1, QSpec::Q12), ActKind::Hard);
            anyhow::ensure!(
                got == dpd_ne::dpd::Dpd::run(&mut oracle, &whole),
                "long-lived session diverged from the bit-exact oracle"
            );
            Ok(())
        });
        // churn: 4 threads x 10 short-lived sessions each, all sharing
        // one weight class (seed 100) so the coalescing scheduler (when
        // batch > 1) genuinely groups cross-thread sessions while they
        // contend for the same 2 workers
        let churners: Vec<_> = (0..4u64)
            .map(|t| {
                scope.spawn(move || -> Result<()> {
                    for k in 0..10u64 {
                        let mut sess = svc
                            .open_session_with(SessionConfig::default(), move || {
                                Ok(fixed_engine(100))
                            })?;
                        let sig = signal(500 + 37 * k as usize, t * 100 + k);
                        for chunk in sig.chunks(123) {
                            sess.push(chunk)?;
                        }
                        let out = sess.finish()?;
                        anyhow::ensure!(
                            out.iq.len() == sig.len(),
                            "short session lost samples: {}/{}",
                            out.iq.len(),
                            sig.len()
                        );
                    }
                    Ok(())
                })
            })
            .collect();
        long.join().expect("long-lived session thread panicked")?;
        for c in churners {
            c.join().expect("churn thread panicked")?;
        }
        Ok(())
    })?;
    service.shutdown()
}

fn run_with_watchdog(batch: usize) {
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    let runner = std::thread::spawn(move || {
        let r = stress(batch);
        done_tx.send(()).ok();
        r
    });
    match done_rx.recv_timeout(WATCHDOG) {
        Ok(()) => runner.join().expect("stress runner panicked").unwrap(),
        Err(_) => panic!(
            "session stress (batch {batch}) did not complete within {WATCHDOG:?} — pool deadlock?"
        ),
    }
}

#[test]
fn session_stress_no_deadlock_within_timeout() {
    run_with_watchdog(1);
}

#[test]
fn session_stress_batched_no_deadlock_within_timeout() {
    // same churn, coalescing scheduler on: the gather/group/flush path
    // must preserve the pool's deadlock-freedom invariant too
    run_with_watchdog(4);
}
