//! CP-OFDM 64-QAM modulator/demodulator — the rust twin of
//! `python/compile/dataset.py` (same construction: RC symbol
//! windowing + Kaiser TX lowpass; different RNG stream, same
//! statistics), plus the receiver used for constellation EVM.
//!
//! Channel raster (normalized to fs): occupied BW = n_used/nfft
//! (default 0.25), i.e. with fs mapped to the paper's 250 MSps this is
//! a 62.5 MHz signal — the paper's 60 MHz f_BB operating point.

use anyhow::{ensure, Result};

use super::qam;
use crate::dsp::fft::Fft;
use crate::dsp::fir::{convolve_same, kaiser_lowpass};
use crate::dsp::window::rc_edge;
use crate::util::{C64, Rng};

/// OFDM generator configuration (defaults match the python dataset).
#[derive(Clone, Debug)]
pub struct OfdmConfig {
    pub nfft: usize,
    pub n_used: usize,
    pub cp: usize,
    pub qam: usize,
    pub n_symbols: usize,
    pub rms: f64,
    pub seed: u64,
    /// raised-cosine overlap length (0 = rectangular)
    pub window: usize,
    /// TX lowpass taps (0 = no filter)
    pub fir_taps: usize,
    pub fir_cutoff: f64,
    pub fir_beta: f64,
}

impl Default for OfdmConfig {
    fn default() -> Self {
        OfdmConfig {
            nfft: 256,
            n_used: 64,
            cp: 16,
            qam: 64,
            n_symbols: 64,
            rms: 0.25,
            seed: 0,
            window: 12,
            fir_taps: 511,
            fir_cutoff: 0.130,
            fir_beta: 10.0,
        }
    }
}

impl OfdmConfig {
    /// Samples per OFDM symbol including CP.
    pub fn sym_len(&self) -> usize {
        self.nfft + self.cp
    }

    /// Total burst length in samples.
    pub fn total_len(&self) -> usize {
        self.n_symbols * self.sym_len()
    }

    /// Occupied bandwidth in cycles/sample.
    pub fn occupied_bw(&self) -> f64 {
        self.n_used as f64 / self.nfft as f64
    }

    /// Occupied FFT bins: ±1..±n_used/2, DC unused (python parity).
    pub fn used_bins(&self) -> Vec<usize> {
        let half = self.n_used / 2;
        let mut bins: Vec<usize> = (1..=half).collect();
        bins.extend((1..=self.n_used - half).map(|k| self.nfft - k));
        bins
    }
}

/// A generated OFDM burst with its ground-truth symbols (for EVM).
pub struct OfdmSignal {
    pub cfg: OfdmConfig,
    pub iq: Vec<[f64; 2]>,
    /// tx_symbols[s][u] = QAM symbol on used-bin u of OFDM symbol s
    pub tx_symbols: Vec<Vec<C64>>,
    /// post-normalization scale actually applied (for reference)
    pub scale: f64,
}

/// Stateless modulator namespace.
pub struct OfdmModulator;

impl OfdmModulator {
    /// Generate a windowed, filtered CP-OFDM burst (python twin).
    pub fn generate(cfg: &OfdmConfig) -> Result<OfdmSignal> {
        ensure!(cfg.nfft.is_power_of_two(), "nfft must be a power of two");
        ensure!(cfg.n_used < cfg.nfft, "n_used must be < nfft");
        // the RC taper must fit inside the CP so the FFT body stays
        // ISI-free (classic W-OFDM layout)
        ensure!(cfg.window <= cfg.cp, "window must be <= cp");

        let constellation = qam::constellation(cfg.qam)?;
        let bins = cfg.used_bins();
        let plan = Fft::new(cfg.nfft)?;
        let mut rng = Rng::new(cfg.seed);
        let win = cfg.window;
        let edge = rc_edge(win.max(1));
        let sym_len = cfg.sym_len();
        let total = cfg.total_len();

        let mut x = vec![C64::ZERO; total + win];
        let mut tx_symbols = Vec::with_capacity(cfg.n_symbols);
        let root_n = (cfg.nfft as f64).sqrt();

        for s in 0..cfg.n_symbols {
            // random QAM on the used bins
            let syms: Vec<C64> = (0..cfg.n_used)
                .map(|_| constellation[rng.below(cfg.qam as u64) as usize])
                .collect();
            let mut spec = vec![C64::ZERO; cfg.nfft];
            for (u, &b) in bins.iter().enumerate() {
                spec[b] = syms[u];
            }
            tx_symbols.push(syms);
            // time domain: ifft * sqrt(nfft)
            plan.inverse(&mut spec);
            let td: Vec<C64> = spec.iter().map(|z| z.scale(root_n)).collect();

            if win > 0 {
                // classic W-OFDM: CP + body + `win` cyclic suffix; taper
                // the first/last `win` samples. Consecutive symbols
                // overlap-add only inside each other's tapered guards,
                // so the FFT body stays ISI-free (taper <= CP).
                let ext_len = cfg.nfft + cfg.cp + win;
                let start = s * sym_len;
                for i in 0..ext_len {
                    // source index into td, cyclically: prefix = CP tail,
                    // then body, then cyclic suffix
                    let src = if i < cfg.cp {
                        cfg.nfft - cfg.cp + i
                    } else if i < cfg.cp + cfg.nfft {
                        i - cfg.cp
                    } else {
                        i - (cfg.cp + cfg.nfft)
                    };
                    let mut w = 1.0;
                    if i < win {
                        w = edge[i];
                    } else if i >= ext_len - win {
                        w = edge[ext_len - 1 - i];
                    }
                    x[start + i] += td[src].scale(w);
                }
            } else {
                let start = s * sym_len;
                for i in 0..cfg.cp {
                    x[start + i] = td[cfg.nfft - cfg.cp + i];
                }
                for i in 0..cfg.nfft {
                    x[start + cfg.cp + i] = td[i];
                }
            }
        }

        // drop the trailing suffix skirt
        let mut iq: Vec<[f64; 2]> = x[..total].iter().map(|z| [z.re, z.im]).collect();

        // TX lowpass
        if cfg.fir_taps > 0 {
            let h = kaiser_lowpass(cfg.fir_taps, cfg.fir_cutoff, cfg.fir_beta);
            iq = convolve_same(&iq, &h);
        }

        // normalize RMS
        let p: f64 = iq.iter().map(|v| v[0] * v[0] + v[1] * v[1]).sum::<f64>() / iq.len() as f64;
        let k = cfg.rms / p.sqrt();
        for v in iq.iter_mut() {
            v[0] *= k;
            v[1] *= k;
        }

        Ok(OfdmSignal { cfg: cfg.clone(), iq, tx_symbols, scale: k })
    }
}

impl OfdmSignal {
    /// Demodulate a received burst (same timing as this signal) and
    /// compute constellation EVM in dB after per-subcarrier one-tap LS
    /// equalization — what a VSA reports.
    ///
    /// `rx` must be the received signal aligned to this burst (same
    /// sample indices). Edge symbols are skipped to avoid filter/PA
    /// warm-up transients.
    pub fn constellation_evm_db(&self, rx: &[[f64; 2]]) -> Result<f64> {
        let cfg = &self.cfg;
        ensure!(rx.len() >= cfg.total_len(), "rx shorter than burst");
        let plan = Fft::new(cfg.nfft)?;
        let bins = cfg.used_bins();
        let root_n = (cfg.nfft as f64).sqrt();
        let skip = 2.min(cfg.n_symbols / 4);

        // gather per-subcarrier rx/tx pairs
        let n_used = cfg.n_used;
        let mut rx_syms: Vec<Vec<C64>> = Vec::new();
        let mut tx_syms: Vec<&Vec<C64>> = Vec::new();
        for s in skip..cfg.n_symbols - skip {
            let start = s * cfg.sym_len() + cfg.cp;
            let mut buf: Vec<C64> = rx[start..start + cfg.nfft]
                .iter()
                .map(|&[re, im]| C64::new(re, im))
                .collect();
            plan.forward(&mut buf);
            let row: Vec<C64> = bins.iter().map(|&b| buf[b].scale(1.0 / root_n)).collect();
            rx_syms.push(row);
            tx_syms.push(&self.tx_symbols[s]);
        }
        ensure!(!rx_syms.is_empty(), "no symbols to demodulate");

        // one-tap LS equalizer per subcarrier: h_u = <rx, tx> / <tx, tx>
        let mut err = 0.0;
        let mut refp = 0.0;
        for u in 0..n_used {
            let mut num = C64::ZERO;
            let mut den = 0.0;
            for (r, t) in rx_syms.iter().zip(&tx_syms) {
                num += r[u] * t[u].conj();
                den += t[u].norm_sq();
            }
            let h = if den > 0.0 { num.scale(1.0 / den) } else { C64::ONE };
            let hinv = h.recip();
            for (r, t) in rx_syms.iter().zip(&tx_syms) {
                let eq = r[u] * hinv;
                err += (eq - t[u]).norm_sq();
                refp += t[u].norm_sq();
            }
        }
        Ok(10.0 * (err / refp).log10())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::welch::{band_power, welch_psd, WelchConfig};
    use crate::signal::papr::papr_db;

    #[test]
    fn shape_rms_papr() {
        let cfg = OfdmConfig { n_symbols: 16, ..Default::default() };
        let sig = OfdmModulator::generate(&cfg).unwrap();
        assert_eq!(sig.iq.len(), 16 * 272);
        let rms: f64 = (sig.iq.iter().map(|v| v[0] * v[0] + v[1] * v[1]).sum::<f64>()
            / sig.iq.len() as f64)
            .sqrt();
        assert!((rms - 0.25).abs() < 1e-12);
        let papr = papr_db(&sig.iq);
        assert!((7.0..13.0).contains(&papr), "PAPR {papr}");
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = OfdmConfig { n_symbols: 4, ..Default::default() };
        let a = OfdmModulator::generate(&cfg).unwrap();
        let b = OfdmModulator::generate(&cfg).unwrap();
        assert_eq!(a.iq, b.iq);
        let c = OfdmModulator::generate(&OfdmConfig { seed: 1, ..cfg }).unwrap();
        assert_ne!(a.iq, c.iq);
    }

    #[test]
    fn spectrum_contained() {
        let cfg = OfdmConfig { n_symbols: 32, seed: 2, ..Default::default() };
        let sig = OfdmModulator::generate(&cfg).unwrap();
        let (f, p) = welch_psd(&sig.iq, &WelchConfig { nfft: 4096, overlap: 0.5 }).unwrap();
        let inband = band_power(&f, &p, -0.13, 0.13);
        let adj = band_power(&f, &p, 0.15, 0.4) + band_power(&f, &p, -0.4, -0.15);
        let acpr = 10.0 * (adj / inband).log10();
        assert!(acpr < -60.0, "leakage {acpr} dBc");
    }

    #[test]
    fn used_bins_exclude_dc_and_are_symmetric() {
        let cfg = OfdmConfig::default();
        let bins = cfg.used_bins();
        assert_eq!(bins.len(), 64);
        assert!(!bins.contains(&0));
        for &b in &bins {
            let mirror = cfg.nfft - b;
            assert!(bins.contains(&mirror));
        }
    }

    #[test]
    fn self_evm_is_low() {
        // demodulating the clean generated signal: EVM limited only by
        // windowing/filter ISI, must be below -35 dB
        let cfg = OfdmConfig { n_symbols: 16, seed: 3, ..Default::default() };
        let sig = OfdmModulator::generate(&cfg).unwrap();
        let evm = sig.constellation_evm_db(&sig.iq).unwrap();
        assert!(evm < -35.0, "self EVM {evm} dB");
    }

    #[test]
    fn evm_detects_distortion() {
        let cfg = OfdmConfig { n_symbols: 16, seed: 4, ..Default::default() };
        let sig = OfdmModulator::generate(&cfg).unwrap();
        // cubic distortion
        let rx: Vec<[f64; 2]> = sig
            .iq
            .iter()
            .map(|&[i, q]| {
                let e2 = i * i + q * q;
                [i * (1.0 - 0.5 * e2), q * (1.0 - 0.5 * e2)]
            })
            .collect();
        let evm = sig.constellation_evm_db(&rx).unwrap();
        assert!(evm > -30.0, "distorted EVM unexpectedly low: {evm}");
    }

    #[test]
    fn occupied_bw_quarter_rate() {
        assert!((OfdmConfig::default().occupied_bw() - 0.25).abs() < 1e-12);
    }
}
