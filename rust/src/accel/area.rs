//! 22FDX area model (Fig. 5's 0.2 mm² post-layout).
//!
//! Component densities for GF 22FDX standard-cell implementation at
//! ~70% placement utilization (the usual 22 nm numbers: ~3 MGates/mm²
//! NAND2-equivalent; a 12x12 multiplier ≈ 600 GE, a 12-bit adder ≈ 70
//! GE, a 12-bit register ≈ 60 GE):
//!
//! | block                     | per-unit estimate |
//! |---------------------------|-------------------|
//! | MAC PE (mult+acc+regs)    | 900 µm²           |
//! | preproc PE                | 900 µm²           |
//! | PWL activation lane       | 60 µm²            |
//! | LUT ROM (1024x12b, synth) | 9,000 µm² / fn    |
//! | weight buffer (502x12b)   | 210 µm²/word eq -> see below |
//! | hidden ping-pong buffer   | 2 x 10 x 12b regs |
//! | FSM + clock + IO + route  | fixed 36,000 µm²  |
//!
//! The weight buffer is register-file based (single-cycle random
//! access for 156 parallel consumers), ~35 µm²/word incl. decode.

use super::fsm::HwConfig;
use crate::dpd::qgru::ActKind;

/// Area constants in µm².
#[derive(Clone, Debug)]
pub struct AreaModel {
    pub mac_pe_um2: f64,
    pub act_pwl_lane_um2: f64,
    pub act_lut_rom_um2: f64,
    pub wbuf_word_um2: f64,
    pub hbuf_word_um2: f64,
    pub fixed_um2: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        AreaModel {
            mac_pe_um2: 900.0,
            act_pwl_lane_um2: 60.0,
            act_lut_rom_um2: 9000.0,
            wbuf_word_um2: 35.0,
            hbuf_word_um2: 25.0,
            fixed_um2: 36000.0,
        }
    }
}

/// Area breakdown in mm².
#[derive(Clone, Debug)]
pub struct AreaBreakdown {
    pub pe_array_mm2: f64,
    pub preproc_mm2: f64,
    pub act_mm2: f64,
    pub wbuf_mm2: f64,
    pub hbuf_mm2: f64,
    pub fixed_mm2: f64,
}

impl AreaBreakdown {
    pub fn total_mm2(&self) -> f64 {
        self.pe_array_mm2 + self.preproc_mm2 + self.act_mm2 + self.wbuf_mm2 + self.hbuf_mm2
            + self.fixed_mm2
    }
}

impl AreaModel {
    pub fn area(&self, cfg: &HwConfig, n_weights: usize, hidden: usize, act: &ActKind) -> AreaBreakdown {
        let um2_to_mm2 = 1e-6;
        let act_area = match act {
            ActKind::Hard => {
                (cfg.sigmoid_lanes + cfg.tanh_lanes) as f64 * self.act_pwl_lane_um2
            }
            // two ROMs (sigmoid + tanh), shared across lanes via muxing
            ActKind::Lut(_) => 2.0 * self.act_lut_rom_um2,
        };
        AreaBreakdown {
            pe_array_mm2: cfg.pe_array_total() as f64 * self.mac_pe_um2 * um2_to_mm2,
            preproc_mm2: cfg.pe_preproc as f64 * self.mac_pe_um2 * um2_to_mm2,
            act_mm2: act_area * um2_to_mm2,
            wbuf_mm2: n_weights as f64 * self.wbuf_word_um2 * um2_to_mm2,
            hbuf_mm2: 2.0 * hidden as f64 * self.hbuf_word_um2 * um2_to_mm2,
            fixed_mm2: self.fixed_um2 * um2_to_mm2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_area_matches_paper_within_10pct() {
        let a = AreaModel::default().area(&HwConfig::default(), 502, 10, &ActKind::Hard);
        let total = a.total_mm2();
        let rel = (total - 0.2).abs() / 0.2;
        assert!(rel < 0.10, "area {total:.3} mm² vs paper 0.2 mm²");
    }

    #[test]
    fn pe_array_dominates() {
        let a = AreaModel::default().area(&HwConfig::default(), 502, 10, &ActKind::Hard);
        assert!(a.pe_array_mm2 > 0.5 * a.total_mm2());
    }

    #[test]
    fn lut_variant_larger() {
        let m = AreaModel::default();
        let hard = m.area(&HwConfig::default(), 502, 10, &ActKind::Hard).total_mm2();
        let lut = m
            .area(
                &HwConfig::default(),
                502,
                10,
                &ActKind::Lut(crate::dpd::qgru::LutTables::default_for(
                    crate::fixed::QSpec::Q12,
                )),
            )
            .total_mm2();
        assert!(lut > hard);
    }
}
