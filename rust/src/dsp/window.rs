//! Window functions (Hann, Blackman, Kaiser, raised-cosine edge) used
//! by the Welch PSD estimator, the FIR designer and the OFDM
//! symbol-windowing stage.

/// Hann window of length n (periodic=false, symmetric — matches numpy's
/// `hanning`).
pub fn hann(n: usize) -> Vec<f64> {
    if n == 1 {
        return vec![1.0];
    }
    (0..n)
        .map(|i| {
            let x = std::f64::consts::PI * i as f64 / (n - 1) as f64;
            let s = x.sin();
            // 0.5*(1-cos(2x)) == sin^2(x)
            s * s
        })
        .collect()
}

/// Blackman window (symmetric).
pub fn blackman(n: usize) -> Vec<f64> {
    if n == 1 {
        return vec![1.0];
    }
    (0..n)
        .map(|i| {
            let x = 2.0 * std::f64::consts::PI * i as f64 / (n - 1) as f64;
            0.42 - 0.5 * x.cos() + 0.08 * (2.0 * x).cos()
        })
        .collect()
}

/// Modified Bessel function of the first kind, order 0 (series).
pub fn bessel_i0(x: f64) -> f64 {
    // converges quickly for the beta range we use (<= 20)
    let mut sum = 1.0;
    let mut term = 1.0;
    let half_x2 = (x / 2.0) * (x / 2.0);
    for k in 1..50 {
        term *= half_x2 / (k as f64 * k as f64);
        sum += term;
        if term < 1e-18 * sum {
            break;
        }
    }
    sum
}

/// Kaiser window with shape parameter beta (matches numpy.kaiser).
pub fn kaiser(n: usize, beta: f64) -> Vec<f64> {
    if n == 1 {
        return vec![1.0];
    }
    let denom = bessel_i0(beta);
    let m = (n - 1) as f64;
    (0..n)
        .map(|i| {
            let r = 2.0 * i as f64 / m - 1.0;
            bessel_i0(beta * (1.0 - r * r).max(0.0).sqrt()) / denom
        })
        .collect()
}

/// Raised-cosine edge ramp of length n (0 -> 1), sampled at midpoints —
/// the OFDM symbol-windowing taper (matches `dataset.generate_ofdm`).
pub fn rc_edge(n: usize) -> Vec<f64> {
    (0..n)
        .map(|i| {
            let t = (i as f64 + 0.5) / n as f64;
            0.5 * (1.0 - (std::f64::consts::PI * t).cos())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hann_endpoints_and_peak() {
        let w = hann(65);
        assert!(w[0].abs() < 1e-15);
        assert!(w[64].abs() < 1e-12);
        assert!((w[32] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn hann_matches_numpy_values() {
        // numpy.hanning(8) reference values
        let w = hann(8);
        let want = [
            0.0,
            0.1882550990706332,
            0.6112604669781572,
            0.9504844339512095,
            0.9504844339512095,
            0.6112604669781572,
            0.1882550990706332,
            0.0,
        ];
        for (g, w_) in w.iter().zip(want) {
            assert!((g - w_).abs() < 1e-12);
        }
    }

    #[test]
    fn blackman_symmetric_nonneg() {
        let w = blackman(33);
        for i in 0..33 {
            assert!((w[i] - w[32 - i]).abs() < 1e-12);
            assert!(w[i] > -1e-12);
        }
    }

    #[test]
    fn bessel_i0_known_values() {
        // I0(0)=1, I0(1)=1.2660658..., I0(5)=27.239871...
        assert!((bessel_i0(0.0) - 1.0).abs() < 1e-15);
        assert!((bessel_i0(1.0) - 1.2660658777520084).abs() < 1e-12);
        assert!((bessel_i0(5.0) - 27.239871823604442).abs() < 1e-9);
    }

    #[test]
    fn kaiser_matches_numpy_values() {
        // numpy.kaiser(7, 9.0) reference
        let w = kaiser(7, 9.0);
        let want = [
            9.14420857e-04,
            1.17736844e-01,
            6.16121850e-01,
            1.0,
            6.16121850e-01,
            1.17736844e-01,
            9.14420857e-04,
        ];
        for (g, w_) in w.iter().zip(want) {
            assert!((g - w_).abs() < 1e-9, "{g} vs {w_}");
        }
    }

    #[test]
    fn rc_edge_monotone_0_to_1() {
        let e = rc_edge(16);
        assert!(e[0] > 0.0 && e[0] < 0.05);
        assert!(e[15] > 0.95 && e[15] < 1.0);
        for i in 1..16 {
            assert!(e[i] > e[i - 1]);
        }
    }
}
