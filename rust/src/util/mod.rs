//! Small shared utilities: deterministic RNG, JSON, complex numbers,
//! property-test helpers.

pub mod cplx;
pub mod json;
pub mod proptest;
pub mod rng;

pub use cplx::C64;
pub use rng::Rng;
