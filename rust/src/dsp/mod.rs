//! Signal-processing substrate: FFT, windows, Welch PSD, FIR filters,
//! delay alignment. Everything is implemented from scratch (offline
//! build), validated by property tests (Parseval, inverse round-trip,
//! known transforms).

pub mod align;
pub mod fft;
pub mod fir;
pub mod welch;
pub mod window;

pub use fft::{fft_inplace, ifft_inplace, Fft};
pub use welch::{welch_psd, WelchConfig};
