//! The central FSM schedule (paper §III-A, Fig. 2) — reverse-engineered
//! to reproduce the published timing exactly:
//!
//! * f_clk = 2 GHz, f_s,I/Q = 250 MSps  ->  II = 8 cycles/sample;
//! * latency = 7.5 ns  ->  15 cycles input-to-output.
//!
//! The paper does not publish the schedule; the reconstruction below is
//! the unique simple schedule consistent with both numbers:
//!
//! ```text
//! cycle  unit                 work (ops)
//!  in    I/O input register   sample latch                    (1 cy)
//!  c0    preproc PE#1         p = requant(i^2+q^2, f-2)   [3]
//!  c1    preproc PE#2         p2 = requant(p^2, f)        [1]
//!  c2-4  input array (40 PE)  W_ih x + b  (120 MAC)
//!  c2-4  hidden array (106)   W_hh h + b  (300 MAC)
//!  c5    hidden-array ALUs    r/z gate adds (20)
//!  c5    sigmoid units (20)   r, z activations (20)
//!  c6    hidden-array ALUs    r.gh_n mul (10) + n add (10)
//!  c7    tanh units (10)      n activation (10)
//!  c7    hidden-array ALUs    (1-z) sub (10)
//!  c8    hidden-array ALUs    (1-z).n mul (10) + z.h mul (10)
//!  c9    hidden-array ALUs    h sum (10)  -> h_t commit
//!  c10-11 FC array (10 PE)    W_fc h + b  (20 MAC)
//!  c12   FC adders            residual add (2)
//!  out   I/O output register  DAC handoff                     (1 cy)
//! ```
//!
//! **The initiation interval is recurrence-limited**: the hidden matvec
//! of sample t+1 (its c2) needs h_t, which commits at the end of c9 —
//! an 8-cycle dependency loop. 2 GHz / 8 = 250 MSps is therefore the
//! paper's *exact* "up to 250 MSps" limit, not a soft target.
//! Latency: in-reg + c0..c12 + out-reg = 1 + 13 + 1 = 15 cycles = 7.5 ns.
//!
//! PE allocation (the paper's "156 PEs subdivided into input, hidden
//! and FC arrays"; elementwise gate math reuses idle hidden-array PEs
//! in c5-c9):  input 40 + hidden 106 + FC 10 = 156, preprocessor 2
//! (counted separately, as in the paper).

use super::ops::ModelDims;
#[cfg(test)]
use super::ops::ops_per_sample;

/// Hardware configuration of the engine.
#[derive(Clone, Copy, Debug)]
pub struct HwConfig {
    pub f_clk_ghz: f64,
    pub pe_input: usize,
    pub pe_hidden: usize,
    pub pe_fc: usize,
    pub pe_preproc: usize,
    pub sigmoid_lanes: usize,
    pub tanh_lanes: usize,
}

impl Default for HwConfig {
    fn default() -> Self {
        HwConfig {
            f_clk_ghz: 2.0,
            pe_input: 40,
            pe_hidden: 106,
            pe_fc: 10,
            pe_preproc: 2,
            sigmoid_lanes: 20,
            tanh_lanes: 10,
        }
    }
}

impl HwConfig {
    /// The paper's headline array size (excludes the 2 preproc PEs).
    pub fn pe_array_total(&self) -> usize {
        self.pe_input + self.pe_hidden + self.pe_fc
    }
}

/// One scheduled activity within the per-sample window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Slot {
    pub unit: Unit,
    /// first cycle (relative to c0) and cycle count
    pub start: usize,
    pub len: usize,
    /// total scalar ops performed in this slot
    pub ops: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Unit {
    Preproc,
    InputArray,
    HiddenArray,
    /// elementwise gate math on idle hidden-array PEs
    HiddenAlu,
    SigmoidUnit,
    TanhUnit,
    FcArray,
    IoReg,
}

/// The static schedule for the paper's model dimensions.
pub fn schedule(d: ModelDims) -> Vec<Slot> {
    let h = d.hidden;
    let f = d.features;
    vec![
        Slot { unit: Unit::Preproc, start: 0, len: 1, ops: 3 },
        Slot { unit: Unit::Preproc, start: 1, len: 1, ops: 1 },
        Slot { unit: Unit::InputArray, start: 2, len: 3, ops: 2 * 3 * h * f },
        Slot { unit: Unit::HiddenArray, start: 2, len: 3, ops: 2 * 3 * h * h },
        Slot { unit: Unit::HiddenAlu, start: 5, len: 1, ops: 2 * h }, // r,z adds
        Slot { unit: Unit::SigmoidUnit, start: 5, len: 1, ops: 2 * h },
        Slot { unit: Unit::HiddenAlu, start: 6, len: 1, ops: 2 * h }, // rh mul + n add
        Slot { unit: Unit::TanhUnit, start: 7, len: 1, ops: h },
        Slot { unit: Unit::HiddenAlu, start: 7, len: 1, ops: h }, // (1-z)
        Slot { unit: Unit::HiddenAlu, start: 8, len: 1, ops: 2 * h }, // two muls
        Slot { unit: Unit::HiddenAlu, start: 9, len: 1, ops: h }, // h sum (commit)
        Slot { unit: Unit::FcArray, start: 10, len: 2, ops: 2 * 2 * h },
        Slot { unit: Unit::FcArray, start: 12, len: 1, ops: 2 }, // residual
    ]
}

/// Initiation interval in cycles: the recurrence loop c2..c9.
pub const II_CYCLES: usize = 8;

/// Input-to-output latency in cycles: in-reg + c0..c12 + out-reg.
pub const LATENCY_CYCLES: usize = 15;

/// Maximum sustainable I/Q sample rate (MSps) at a clock (GHz).
pub fn max_sample_rate_msps(f_clk_ghz: f64) -> f64 {
    f_clk_ghz * 1e3 / II_CYCLES as f64
}

/// Latency in ns at a clock (GHz).
pub fn latency_ns(f_clk_ghz: f64) -> f64 {
    LATENCY_CYCLES as f64 / f_clk_ghz
}

/// Average PE-array utilization over the II window (MAC-capable ops on
/// the 156-PE array / capacity).
pub fn array_utilization(cfg: &HwConfig, d: ModelDims) -> f64 {
    let array_ops: usize = schedule(d)
        .iter()
        .filter(|s| {
            matches!(
                s.unit,
                Unit::InputArray | Unit::HiddenArray | Unit::HiddenAlu | Unit::FcArray
            )
        })
        .map(|s| s.ops)
        .sum();
    // each PE does one MAC (2 ops) or one ALU op per cycle; capacity in
    // "ops" terms: MAC slots count 2
    let capacity = cfg.pe_array_total() * II_CYCLES * 2;
    array_ops as f64 / capacity as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_timing_constants() {
        assert_eq!(II_CYCLES, 8);
        assert_eq!(LATENCY_CYCLES, 15);
        assert!((max_sample_rate_msps(2.0) - 250.0).abs() < 1e-9);
        assert!((latency_ns(2.0) - 7.5).abs() < 1e-12);
    }

    #[test]
    fn pe_array_is_156() {
        let cfg = HwConfig::default();
        assert_eq!(cfg.pe_array_total(), 156);
        assert_eq!(cfg.pe_preproc, 2);
    }

    #[test]
    fn schedule_covers_all_ops() {
        let d = ModelDims::default();
        let total: usize = schedule(d).iter().map(|s| s.ops).sum();
        assert_eq!(total, ops_per_sample(d).total());
    }

    #[test]
    fn capacity_never_exceeded() {
        let cfg = HwConfig::default();
        let d = ModelDims::default();
        for s in schedule(d) {
            let per_cycle = (s.ops + s.len - 1) / s.len;
            let cap = match s.unit {
                Unit::Preproc => cfg.pe_preproc * 2, // MAC = 2 ops
                Unit::InputArray => cfg.pe_input * 2,
                Unit::HiddenArray => cfg.pe_hidden * 2,
                Unit::HiddenAlu => cfg.pe_hidden, // 1 ALU op per PE
                Unit::SigmoidUnit => cfg.sigmoid_lanes,
                Unit::TanhUnit => cfg.tanh_lanes,
                Unit::FcArray => cfg.pe_fc * 2,
                Unit::IoReg => usize::MAX,
            };
            assert!(
                per_cycle <= cap,
                "{:?} needs {per_cycle}/cycle > capacity {cap}",
                s.unit
            );
        }
    }

    #[test]
    fn recurrence_loop_is_exactly_ii() {
        // hidden matvec starts at c2; h commits at end of c9
        let d = ModelDims::default();
        let sched = schedule(d);
        let hmv_start = sched
            .iter()
            .find(|s| s.unit == Unit::HiddenArray)
            .unwrap()
            .start;
        let h_commit = sched
            .iter()
            .filter(|s| s.unit == Unit::HiddenAlu)
            .map(|s| s.start + s.len)
            .max()
            .unwrap();
        assert_eq!(h_commit - hmv_start, II_CYCLES);
    }

    #[test]
    fn dependencies_honored() {
        let d = ModelDims::default();
        let sched = schedule(d);
        let end = |u: Unit| -> usize {
            sched
                .iter()
                .filter(|s| s.unit == u)
                .map(|s| s.start + s.len)
                .max()
                .unwrap()
        };
        let start = |u: Unit| -> usize {
            sched.iter().filter(|s| s.unit == u).map(|s| s.start).min().unwrap()
        };
        // features before matvecs
        assert!(end(Unit::Preproc) <= start(Unit::InputArray));
        // matvecs before gate math
        assert!(end(Unit::InputArray) <= start(Unit::SigmoidUnit));
        assert!(end(Unit::HiddenArray) <= start(Unit::HiddenAlu));
        // gates before FC
        assert!(end(Unit::HiddenAlu) <= start(Unit::FcArray));
    }

    #[test]
    fn utilization_realistic() {
        let u = array_utilization(&HwConfig::default(), ModelDims::default());
        assert!((0.2..0.8).contains(&u), "utilization {u}");
    }

    #[test]
    fn overclock_scaling() {
        // at 1 GHz the chip sustains 125 MSps
        assert!((max_sample_rate_msps(1.0) - 125.0).abs() < 1e-9);
        assert!((latency_ns(1.0) - 15.0).abs() < 1e-12);
    }
}
