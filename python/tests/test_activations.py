"""Hardsigmoid/Hardtanh (Eq. 7-8) and LUT activation properties."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.activations import (
    LutSpec,
    hardsigmoid,
    hardsigmoid_int,
    hardtanh,
    hardtanh_int,
    lut_activation_int,
    make_sigmoid_table,
    make_tanh_table,
)
from compile.kernels.quant import QSpec

FLOATS = st.floats(min_value=-8.0, max_value=8.0, allow_nan=False, width=32)
BITS = st.integers(min_value=6, max_value=16)


class TestHardFloat:
    def test_eq7_cases(self):
        # the three branches of Eq. (7)
        assert float(hardsigmoid(jnp.float32(3.0))) == 1.0
        assert float(hardsigmoid(jnp.float32(-3.0))) == 0.0
        assert float(hardsigmoid(jnp.float32(0.0))) == 0.5
        assert float(hardsigmoid(jnp.float32(1.0))) == 0.75

    def test_eq8_cases(self):
        assert float(hardtanh(jnp.float32(2.0))) == 1.0
        assert float(hardtanh(jnp.float32(-2.0))) == -1.0
        assert float(hardtanh(jnp.float32(0.5))) == 0.5

    @given(FLOATS)
    @settings(max_examples=100, deadline=None)
    def test_bounds(self, x):
        assert 0.0 <= float(hardsigmoid(jnp.float32(x))) <= 1.0
        assert -1.0 <= float(hardtanh(jnp.float32(x))) <= 1.0

    @given(FLOATS, FLOATS)
    @settings(max_examples=100, deadline=None)
    def test_monotone(self, a, b):
        lo, hi = sorted((a, b))
        assert float(hardsigmoid(jnp.float32(lo))) <= float(hardsigmoid(jnp.float32(hi)))
        assert float(hardtanh(jnp.float32(lo))) <= float(hardtanh(jnp.float32(hi)))

    @given(FLOATS)
    @settings(max_examples=100, deadline=None)
    def test_approximates_smooth(self, x):
        """PWL stays within the known worst-case gap of the smooth fn."""
        hs = float(hardsigmoid(jnp.float32(x)))
        sg = 1.0 / (1.0 + np.exp(-x))
        assert abs(hs - sg) < 0.12  # max gap of hardsigmoid vs sigmoid
        ht = float(hardtanh(jnp.float32(x)))
        assert abs(ht - np.tanh(x)) < 0.25


class TestHardInt:
    @given(BITS, st.integers(min_value=-(2 ** 15), max_value=2 ** 15))
    @settings(max_examples=150, deadline=None)
    def test_int_matches_float_within_lsb(self, bits, code):
        spec = QSpec(bits)
        code = max(spec.qmin, min(spec.qmax, code))
        x = code / spec.scale
        got = int(hardsigmoid_int(jnp.int32(code), spec)) / spec.scale
        want = float(hardsigmoid(jnp.float32(x)))
        # floor shift vs exact /4: at most 1 LSB apart
        assert abs(got - want) <= spec.lsb + 1e-9

        got_t = int(hardtanh_int(jnp.int32(code), spec)) / spec.scale
        want_t = float(hardtanh(jnp.float32(x)))
        assert abs(got_t - want_t) <= spec.lsb + 1e-9

    def test_int_output_codes_bounded(self):
        spec = QSpec(12)
        codes = jnp.arange(spec.qmin, spec.qmax + 1, dtype=jnp.int32)
        hs = np.asarray(hardsigmoid_int(codes, spec))
        ht = np.asarray(hardtanh_int(codes, spec))
        one = 1 << spec.frac
        assert hs.min() >= 0 and hs.max() <= one
        assert ht.min() >= -one and ht.max() <= one


class TestLut:
    def test_table_sizes(self):
        lut = LutSpec()
        spec = QSpec(12)
        assert make_sigmoid_table(lut, spec).shape == (1024,)
        assert make_tanh_table(lut, spec).shape == (1024,)

    def test_tables_monotone(self):
        lut = LutSpec()
        spec = QSpec(12)
        assert np.all(np.diff(make_sigmoid_table(lut, spec)) >= 0)
        assert np.all(np.diff(make_tanh_table(lut, spec)) >= 0)

    def test_table_asymptotes(self):
        lut = LutSpec()
        spec = QSpec(12)
        sig = make_sigmoid_table(lut, spec)
        one = 1 << spec.frac
        assert sig[0] <= 0.03 * one
        assert sig[-1] >= 0.97 * one
        tanh = make_tanh_table(lut, spec)
        assert tanh[0] <= -0.97 * one
        assert tanh[-1] >= 0.97 * one

    @given(BITS, st.integers(min_value=-(2 ** 15), max_value=2 ** 15))
    @settings(max_examples=150, deadline=None)
    def test_lut_close_to_true_function(self, bits, code):
        spec = QSpec(bits)
        code = max(spec.qmin, min(spec.qmax, code))
        lut = LutSpec()
        table = jnp.asarray(make_sigmoid_table(lut, spec))
        got = int(lut_activation_int(jnp.int32(code), table, lut, spec)) / spec.scale
        want = 1.0 / (1.0 + np.exp(-code / spec.scale))
        # quantization + table-step error: half a table step of slope(max 1/4) + lsb
        step = (lut.hi - lut.lo) / lut.n
        assert abs(got - want) <= 0.25 * step + 2 * spec.lsb

    def test_index_int_in_bounds_everywhere(self):
        spec = QSpec(12)
        lut = LutSpec()
        codes = jnp.arange(spec.qmin, spec.qmax + 1, dtype=jnp.int32)
        idx = np.asarray(lut.index_int(codes, spec))
        assert idx.min() >= 0 and idx.max() < lut.n
