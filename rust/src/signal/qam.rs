//! Square-QAM constellations (unit average power) and hard-decision
//! demapping — what the EVM receiver slices against.

use anyhow::{bail, Result};

use crate::util::C64;

/// Square QAM constellation of the given order (4, 16, 64, 256),
/// normalized to unit average power. Point order matches the python
/// generator: meshgrid(levels, levels) flattened row-major, i.e.
/// index = row*side + col with re = levels[col], im = levels[row].
pub fn constellation(order: usize) -> Result<Vec<C64>> {
    let side = (order as f64).sqrt().round() as usize;
    if side * side != order {
        bail!("square QAM only, got order {order}");
    }
    let levels: Vec<f64> = (0..side).map(|i| (2 * i) as f64 - (side - 1) as f64).collect();
    let mut pts = Vec::with_capacity(order);
    for &im in &levels {
        for &re in &levels {
            pts.push(C64::new(re, im));
        }
    }
    let p_avg: f64 = pts.iter().map(|z| z.norm_sq()).sum::<f64>() / order as f64;
    let k = 1.0 / p_avg.sqrt();
    Ok(pts.into_iter().map(|z| z.scale(k)).collect())
}

/// Nearest-constellation-point index (hard decision).
pub fn slice_symbol(points: &[C64], z: C64) -> usize {
    let mut best = 0;
    let mut best_d = f64::INFINITY;
    for (i, &p) in points.iter().enumerate() {
        let d = (z - p).norm_sq();
        if d < best_d {
            best_d = d;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn unit_average_power() {
        for order in [4usize, 16, 64, 256] {
            let c = constellation(order).unwrap();
            assert_eq!(c.len(), order);
            let p: f64 = c.iter().map(|z| z.norm_sq()).sum::<f64>() / order as f64;
            assert!((p - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn rejects_non_square() {
        assert!(constellation(32).is_err());
        assert!(constellation(8).is_err());
    }

    #[test]
    fn qam64_matches_python_order() {
        // python: levels = 2*arange(8)-7; meshgrid(re, im); (re+1j*im)/sqrt(42)
        let c = constellation(64).unwrap();
        let s = 42f64.sqrt();
        assert!((c[0] - C64::new(-7.0 / s, -7.0 / s)).abs() < 1e-12);
        assert!((c[7] - C64::new(7.0 / s, -7.0 / s)).abs() < 1e-12);
        assert!((c[56] - C64::new(-7.0 / s, 7.0 / s)).abs() < 1e-12);
        assert!((c[63] - C64::new(7.0 / s, 7.0 / s)).abs() < 1e-12);
    }

    #[test]
    fn slicing_inverts_mapping() {
        check("slice inverts map", 50, |rng| {
            let c = constellation(64).unwrap();
            let idx = rng.below(64) as usize;
            // small noise, well inside the decision region (d_min/2 = 1/sqrt(42))
            let noise = C64::new(rng.gauss(), rng.gauss()).scale(0.02);
            let got = slice_symbol(&c, c[idx] + noise);
            if got != idx {
                return Err(format!("sliced {got} != {idx}"));
            }
            Ok(())
        });
    }
}
