//! Operation accounting — the paper's "OP/S" (operations per I/Q
//! sample) column.
//!
//! Counting convention (documented because the paper's 1,026 is not
//! broken down): multiplies and adds each count as one op; a MAC is 2
//! ops; bias terms are preloaded into the accumulator (0 extra ops);
//! requantization shifts and saturation are wiring/control, not ops.
//! Under this convention the datapath performs **996 OP/S** — within
//! 3% of the paper's 1,026 (whose exact convention is unspecified).
//! Both numbers are surfaced in the Table II bench.

/// Model dimensions (paper defaults: F=4 features, H=10 hidden).
#[derive(Clone, Copy, Debug)]
pub struct ModelDims {
    pub features: usize,
    pub hidden: usize,
}

impl Default for ModelDims {
    fn default() -> Self {
        ModelDims { features: 4, hidden: 10 }
    }
}

/// Per-sample operation breakdown.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounts {
    pub mults: usize,
    pub adds: usize,
    pub activations: usize,
}

impl OpCounts {
    pub fn total(&self) -> usize {
        self.mults + self.adds + self.activations
    }
}

/// Exact per-sample op counts of the (residual, feature-conditioned)
/// GRU-DPD datapath.
pub fn ops_per_sample(d: ModelDims) -> OpCounts {
    let h = d.hidden;
    let f = d.features;
    let mut c = OpCounts::default();

    // preprocessor: i^2, q^2 (2 mul), sum (1 add), x4 shift (free),
    // p^2 (1 mul), shift (free)
    c.mults += 3;
    c.adds += 1;

    // input matvec W_ih (3H x F): MAC = mul+add, bias preloaded
    c.mults += 3 * h * f;
    c.adds += 3 * h * f;

    // hidden matvec W_hh (3H x H)
    c.mults += 3 * h * h;
    c.adds += 3 * h * h;

    // gate pre-activations: gi + gh for r, z, n-path add of r*ghn
    c.adds += 3 * h; // r, z adds (2H) + n add of (gi_n + rh) (H)
    c.mults += h; // r (.) gh_n

    // activations: 2H sigmoids + H tanh
    c.activations += 3 * h;

    // hidden update: (1-z) sub, (1-z)*n, z*h, sum
    c.adds += 2 * h;
    c.mults += 2 * h;

    // FC (2 x H) + residual adds
    c.mults += 2 * h;
    c.adds += 2 * h + 2;

    c
}

/// Per-sample MAC count of the dense datapath (gate matvecs + FC,
/// bias preloads free) — the denominator of the delta engine's
/// measured MAC-reduction (`accel::delta`). Paper model: 440.
pub fn macs_per_sample(d: ModelDims) -> usize {
    3 * d.hidden * (d.features + d.hidden) + 2 * d.hidden
}

/// The paper's reported OP/S figure for the same model.
pub const PAPER_OPS_PER_SAMPLE: usize = 1026;

/// GOPS at a given I/Q sample rate.
pub fn gops(d: ModelDims, fs_msps: f64) -> f64 {
    ops_per_sample(d).total() as f64 * fs_msps * 1e6 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_model_is_996_ops() {
        let c = ops_per_sample(ModelDims::default());
        // preproc 4 + in-mv 240 + hid-mv 600 + gates 40 + act 30 +
        // h-update 40 + fc/residual 52
        assert_eq!(c.mults, 3 + 120 + 300 + 10 + 20 + 20);
        assert_eq!(c.adds, 1 + 120 + 300 + 30 + 20 + 22);
        assert_eq!(c.activations, 30);
        assert_eq!(c.total(), 996);
    }

    #[test]
    fn paper_model_is_440_macs() {
        // 120 (input matvec) + 300 (hidden matvec) + 20 (FC)
        assert_eq!(macs_per_sample(ModelDims::default()), 440);
    }

    #[test]
    fn within_3pct_of_paper() {
        let ours = ops_per_sample(ModelDims::default()).total() as f64;
        let rel = (ours - PAPER_OPS_PER_SAMPLE as f64).abs() / PAPER_OPS_PER_SAMPLE as f64;
        assert!(rel < 0.03, "op count deviates {:.1}% from paper", rel * 100.0);
    }

    #[test]
    fn gops_at_250msps() {
        let g = gops(ModelDims::default(), 250.0);
        // paper: 256.5 GOPS; ours: 996 * 250e6 = 249.0 GOPS
        assert!((g - 249.0).abs() < 0.1);
        assert!((g - 256.5).abs() / 256.5 < 0.03);
    }

    #[test]
    fn scales_with_dims() {
        let small = ops_per_sample(ModelDims { features: 4, hidden: 5 }).total();
        let big = ops_per_sample(ModelDims { features: 4, hidden: 20 }).total();
        assert!(big > 2 * small);
    }
}
