//! Error Vector Magnitude.
//!
//! Two forms, both used in the DPD literature:
//! * **NMSE-EVM** (time domain): 10 log10(||y - g x||^2 / ||g x||^2)
//!   against the linear reference g·x — what simulation papers report
//!   and what the paper's -39.8 dB corresponds to;
//! * **constellation EVM** lives in `signal::ofdm::OfdmSignal`
//!   (per-subcarrier, after one-tap equalization — the VSA view).

use crate::util::C64;

/// NMSE in dB between a signal and a reference (same length).
pub fn nmse_db(y: &[[f64; 2]], reference: &[[f64; 2]]) -> f64 {
    assert_eq!(y.len(), reference.len());
    let mut err = 0.0;
    let mut refp = 0.0;
    for (a, b) in y.iter().zip(reference) {
        let dr = a[0] - b[0];
        let di = a[1] - b[1];
        err += dr * dr + di * di;
        refp += b[0] * b[0] + b[1] * b[1];
    }
    10.0 * (err / refp).log10()
}

/// Time-domain EVM of PA output `y` against the linear target `g * x`.
pub fn evm_db_nmse(y: &[[f64; 2]], x: &[[f64; 2]], g: C64) -> f64 {
    let target: Vec<[f64; 2]> = x
        .iter()
        .map(|&[i, q]| {
            let t = C64::new(i, q) * g;
            [t.re, t.im]
        })
        .collect();
    nmse_db(y, &target)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn zero_error_is_minus_inf() {
        let x = vec![[1.0, -1.0]; 10];
        assert!(nmse_db(&x, &x).is_infinite());
    }

    #[test]
    fn known_value() {
        let r = vec![[1.0, 0.0]; 100];
        let y: Vec<[f64; 2]> = r.iter().map(|&[i, q]| [i * 1.1, q]).collect();
        assert!((nmse_db(&y, &r) - 10.0 * 0.01f64.log10()).abs() < 1e-9);
    }

    #[test]
    fn evm_perfect_linear_chain() {
        check("evm zero for perfect gain", 20, |rng| {
            let g = C64::new(rng.range(0.5, 1.5), rng.range(-0.5, 0.5));
            let x: Vec<[f64; 2]> = (0..64).map(|_| [rng.gauss(), rng.gauss()]).collect();
            let y: Vec<[f64; 2]> = x
                .iter()
                .map(|&[i, q]| {
                    let v = C64::new(i, q) * g;
                    [v.re, v.im]
                })
                .collect();
            let evm = evm_db_nmse(&y, &x, g);
            if evm > -200.0 {
                return Err(format!("expected -inf-ish, got {evm}"));
            }
            Ok(())
        });
    }

    #[test]
    fn known_qam_references_match_closed_form() {
        // Pin the meter against a known-QAM stream with analytically
        // known errors (what the conformance tolerances lean on).
        use crate::signal::qam::constellation;
        let c64 = constellation(64).unwrap();
        let g = C64::new(0.9, 0.25);
        // cycle through the whole (unit-average-power) constellation
        // so signal power is exactly 1 per symbol on average
        let x: Vec<[f64; 2]> = (0..640).map(|i| {
            let p = c64[i % 64];
            [p.re, p.im]
        }).collect();

        // (1) pure relative gain error: y = g x (1 + eps)
        //     -> EVM = 20 log10(eps) exactly, independent of g
        for eps in [0.01, 0.1] {
            let y: Vec<[f64; 2]> = x
                .iter()
                .map(|&[i, q]| {
                    let v = C64::new(i, q) * g * C64::new(1.0 + eps, 0.0);
                    [v.re, v.im]
                })
                .collect();
            let got = evm_db_nmse(&y, &x, g);
            let want = 20.0 * eps.log10();
            assert!((got - want).abs() < 1e-9, "eps={eps}: got {got}, want {want}");
        }

        // (2) constant displacement d on I of every received symbol:
        //     error power N d², reference power N |g|² (unit-power
        //     constellation) -> EVM = 10 log10(d² / |g|²)
        let d = 0.03;
        let y: Vec<[f64; 2]> = x
            .iter()
            .map(|&[i, q]| {
                let v = C64::new(i, q) * g;
                [v.re + d, v.im]
            })
            .collect();
        let got = evm_db_nmse(&y, &x, g);
        let want = 10.0 * (d * d / g.norm_sq()).log10();
        assert!((got - want).abs() < 1e-9, "displacement: got {got}, want {want}");
    }

    #[test]
    fn evm_monotone_in_noise() {
        let mut rng = crate::util::Rng::new(1);
        let x: Vec<[f64; 2]> = (0..512).map(|_| [rng.gauss(), rng.gauss()]).collect();
        let mut last = -1000.0;
        for noise in [0.001, 0.01, 0.1] {
            let y: Vec<[f64; 2]> = x
                .iter()
                .map(|&[i, q]| [i + noise * rng.gauss(), q + noise * rng.gauss()])
                .collect();
            let evm = evm_db_nmse(&y, &x, C64::ONE);
            assert!(evm > last);
            last = evm;
        }
    }
}
