//! Welch power-spectral-density estimation — the instrument behind the
//! ACPR measurements (what the paper's R&S FSW43 analyzer computes).

use anyhow::Result;

use super::fft::Fft;
use super::window::hann;
use crate::util::C64;

/// Welch estimator configuration.
#[derive(Clone, Debug)]
pub struct WelchConfig {
    /// FFT segment length (power of two).
    pub nfft: usize,
    /// Segment overlap as a fraction of nfft (0.0 .. 0.9).
    pub overlap: f64,
}

impl Default for WelchConfig {
    fn default() -> Self {
        WelchConfig { nfft: 4096, overlap: 0.5 }
    }
}

/// Averaged, Hann-windowed periodogram of a complex baseband signal.
///
/// Returns (freqs, psd) with freqs in cycles/sample, *fftshifted* so
/// the axis runs -0.5 .. 0.5 — the natural layout for band-power
/// integration. PSD is in linear power units (per-bin power density up
/// to a constant factor; ACPR/band ratios are scale-free).
///
/// Trailing samples that don't fill a whole segment are *not*
/// discarded: when at least half a segment remains past the last full
/// one, it is measured as a final zero-padded segment under its own
/// (shorter) Hann window, its power compensated by the window-energy
/// ratio so a stationary signal's tail weighs like a full segment in
/// the average. (Dropping the tail — the pre-fix behavior — silently
/// truncated short-burst ACPR by up to `nfft - 1` samples.)
pub fn welch_psd(x: &[[f64; 2]], cfg: &WelchConfig) -> Result<(Vec<f64>, Vec<f64>)> {
    let n = cfg.nfft;
    let plan = Fft::new(n)?;
    let w = hann(n);
    let step = ((n as f64) * (1.0 - cfg.overlap)).max(1.0) as usize;
    let mut psd = vec![0.0; n];
    let mut buf = vec![C64::ZERO; n];
    let mut segs = 0usize;

    let mut start = 0;
    while start + n <= x.len() {
        for i in 0..n {
            let [re, im] = x[start + i];
            buf[i] = C64::new(re * w[i], im * w[i]);
        }
        plan.forward(&mut buf);
        for i in 0..n {
            psd[i] += buf[i].norm_sq();
        }
        segs += 1;
        start += step;
    }
    // Final partial segment — only when at least half a segment of
    // samples lies past the end of the last full segment (i.e. would
    // otherwise go unmeasured; overlap re-coverage doesn't count). The
    // tail runs from the next grid position under its own (shorter)
    // Hann window, zero-padded to the FFT size, its power scaled by
    // the window-energy ratio U_full/U_tail.
    let covered = if segs > 0 { start - step + n } else { 0 };
    let unmeasured = x.len() - covered.min(x.len());
    let rem = x.len() - start.min(x.len());
    if 2 * unmeasured >= n {
        let wt = hann(rem);
        let u_full: f64 = w.iter().map(|&v| v * v).sum();
        let u_tail: f64 = wt.iter().map(|&v| v * v).sum();
        // a degenerate tail window carries (numerically) no energy —
        // hann(2) is [0, sin(π)²] ≈ [0, 1.5e-32] — and compensating by
        // u_full/u_tail would blow the segment up into garbage; skip
        // it instead, and with no full segment either the too-short
        // error below still fires
        if u_tail > u_full * 1e-12 {
            for i in 0..rem {
                let [re, im] = x[start + i];
                buf[i] = C64::new(re * wt[i], im * wt[i]);
            }
            for b in buf.iter_mut().skip(rem) {
                *b = C64::ZERO;
            }
            plan.forward(&mut buf);
            let comp = u_full / u_tail;
            for i in 0..n {
                psd[i] += buf[i].norm_sq() * comp;
            }
            segs += 1;
        }
    }
    anyhow::ensure!(segs > 0, "signal shorter than half a Welch segment ({n})");

    let norm = 1.0 / segs as f64;
    // fftshift
    let half = n / 2;
    let mut shifted = vec![0.0; n];
    let mut freqs = vec![0.0; n];
    for i in 0..n {
        let src = (i + half) % n;
        shifted[i] = psd[src] * norm;
        freqs[i] = (i as f64 - half as f64) / n as f64;
    }
    Ok((freqs, shifted))
}

/// Integrate PSD power over a frequency band [lo, hi) (cycles/sample).
pub fn band_power(freqs: &[f64], psd: &[f64], lo: f64, hi: f64) -> f64 {
    freqs
        .iter()
        .zip(psd)
        .filter(|(f, _)| **f >= lo && **f < hi)
        .map(|(_, p)| *p)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tone(freq: f64, n: usize) -> Vec<[f64; 2]> {
        (0..n)
            .map(|t| {
                let ph = 2.0 * std::f64::consts::PI * freq * t as f64;
                [ph.cos(), ph.sin()]
            })
            .collect()
    }

    #[test]
    fn tone_peaks_at_its_frequency() {
        let x = tone(0.1, 1 << 15);
        let cfg = WelchConfig { nfft: 1024, overlap: 0.5 };
        let (f, p) = welch_psd(&x, &cfg).unwrap();
        let imax = (0..p.len()).max_by(|&a, &b| p[a].total_cmp(&p[b])).unwrap();
        assert!((f[imax] - 0.1).abs() < 2.0 / 1024.0, "peak at {}", f[imax]);
    }

    #[test]
    fn tone_leakage_floor_deep() {
        let x = tone(0.05, 1 << 15);
        let (f, p) = welch_psd(&x, &WelchConfig { nfft: 4096, overlap: 0.5 }).unwrap();
        let inband = band_power(&f, &p, 0.04, 0.06);
        let far = band_power(&f, &p, 0.2, 0.4);
        assert!(10.0 * (far / inband).log10() < -100.0);
    }

    #[test]
    fn white_noise_flat() {
        let mut rng = Rng::new(3);
        let x: Vec<[f64; 2]> = (0..1 << 16).map(|_| [rng.gauss(), rng.gauss()]).collect();
        let (f, p) = welch_psd(&x, &WelchConfig { nfft: 256, overlap: 0.5 }).unwrap();
        let lo = band_power(&f, &p, -0.4, -0.1);
        let hi = band_power(&f, &p, 0.1, 0.4);
        let ratio = 10.0 * (lo / hi).log10();
        assert!(ratio.abs() < 0.5, "flatness {ratio} dB");
    }

    #[test]
    fn total_power_tracks_signal_power() {
        let mut rng = Rng::new(9);
        let x: Vec<[f64; 2]> = (0..1 << 14).map(|_| [rng.gauss() * 0.5, rng.gauss() * 0.5]).collect();
        let (f, p) = welch_psd(&x, &WelchConfig { nfft: 512, overlap: 0.0 }).unwrap();
        let x2: Vec<[f64; 2]> = x.iter().map(|&[a, b]| [2.0 * a, 2.0 * b]).collect();
        let (_, p2) = welch_psd(&x2, &WelchConfig { nfft: 512, overlap: 0.0 }).unwrap();
        let r = band_power(&f, &p2, -0.5, 0.5) / band_power(&f, &p, -0.5, 0.5);
        assert!((r - 4.0).abs() < 1e-9, "power scaling {r}");
    }

    #[test]
    fn errors_on_short_signal() {
        let x = vec![[0.0, 0.0]; 100];
        assert!(welch_psd(&x, &WelchConfig { nfft: 256, overlap: 0.5 }).is_err());
    }

    #[test]
    fn tail_segment_regression_content_in_the_tail_is_measured() {
        // The tail-drop bug: with `while start + n <= len` alone, a
        // burst of 1.5·nfft at overlap 0 loses its last nfft/2 samples
        // entirely. Put the only signal content there — pre-fix this
        // tone is invisible (leakage floor, < -100 dB); post-fix the
        // tail segment surfaces it at full band power.
        let n = 512usize;
        let mut x = vec![[0.0, 0.0]; 3 * n / 2];
        for (t, s) in x.iter_mut().enumerate().skip(n) {
            let ph = 2.0 * std::f64::consts::PI * 0.125 * t as f64;
            *s = [ph.cos(), ph.sin()];
        }
        // tiny carrier in the head so the reference band is nonzero
        for (t, s) in x.iter_mut().enumerate().take(n) {
            let ph = 2.0 * std::f64::consts::PI * (-0.125) * t as f64;
            *s = [1e-3 * ph.cos(), 1e-3 * ph.sin()];
        }
        let (f, p) = welch_psd(&x, &WelchConfig { nfft: n, overlap: 0.0 }).unwrap();
        let tail_band = band_power(&f, &p, 0.1, 0.15);
        let head_band = band_power(&f, &p, -0.15, -0.1);
        let ratio_db = 10.0 * (tail_band / head_band).log10();
        // the tail tone is 60 dB louder than the head carrier; pre-fix
        // this ratio sits below -40 dB (pure leakage of the head seg)
        assert!(ratio_db > 40.0, "tail content lost: {ratio_db:.1} dB");
    }

    #[test]
    fn tail_segment_tone_burst_band_ratio_matches_full_length() {
        // 1.5·nfft tone at overlap 0 (the maximal-truncation shape):
        // the in-band fraction must equal the full-length measurement,
        // i.e. the compensated zero-padded tail segment neither loses
        // nor invents band power.
        let n = 512usize;
        let cfg = WelchConfig { nfft: n, overlap: 0.0 };
        let ratio = |len: usize| -> f64 {
            let x = tone(0.125, len);
            let (f, p) = welch_psd(&x, &cfg).unwrap();
            let inband = band_power(&f, &p, 0.115, 0.135);
            let total = band_power(&f, &p, -0.5, 0.5);
            10.0 * (inband / total).log10()
        };
        let short = ratio(3 * n / 2); // 1 full segment + half-segment tail
        let long = ratio(8 * n); // full segments only
        assert!(
            (short - long).abs() < 0.05,
            "1.5·nfft burst band ratio {short:.4} dB vs full-length {long:.4} dB"
        );
    }

    #[test]
    fn tail_segment_only_fires_on_unmeasured_samples() {
        // At 50% overlap a 1.5·nfft burst is already fully covered by
        // the two overlapping segments — no tail segment is added, so
        // the result equals the pre-fix value exactly (the fix only
        // measures samples that would otherwise be dropped).
        let n = 256usize;
        let x = tone(0.1, 3 * n / 2);
        let (_, p) = welch_psd(&x, &WelchConfig { nfft: n, overlap: 0.5 }).unwrap();
        // reference: the two 50%-overlap segments, averaged, by hand
        let plan = crate::dsp::fft::Fft::new(n).unwrap();
        let w = hann(n);
        let mut want = vec![0.0; n];
        for start in [0, n / 2] {
            let mut buf: Vec<crate::util::C64> = (0..n)
                .map(|i| crate::util::C64::new(x[start + i][0] * w[i], x[start + i][1] * w[i]))
                .collect();
            plan.forward(&mut buf);
            for (acc, b) in want.iter_mut().zip(&buf) {
                *acc += b.norm_sq();
            }
        }
        let half = n / 2;
        for i in 0..n {
            // same op order as welch_psd: accumulate, then scale once
            assert_eq!(p[i], want[(i + half) % n] * 0.5, "bin {i} diverged");
        }
    }

    #[test]
    fn degenerate_tail_window_stays_a_hard_error() {
        // hann(2) is all zeros: a 2-sample signal at nfft 4 must keep
        // erroring like the pre-fix code, not return a NaN PSD from a
        // zero-energy compensated tail segment
        let x = vec![[1.0, 0.0]; 2];
        assert!(welch_psd(&x, &WelchConfig { nfft: 4, overlap: 0.5 }).is_err());
        // 3 tail samples carry window energy again and measure cleanly
        let x = vec![[1.0, 0.0]; 3];
        let (_, p) = welch_psd(&x, &WelchConfig { nfft: 4, overlap: 0.5 }).unwrap();
        assert!(p.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn sub_segment_burst_measurable_above_half() {
        // >= nfft/2 samples now measure (zero-padded single segment);
        // below half a segment stays a hard error
        let x = tone(0.1, 160);
        let cfg = WelchConfig { nfft: 256, overlap: 0.5 };
        let (f, p) = welch_psd(&x, &cfg).unwrap();
        let imax = (0..p.len()).max_by(|&a, &b| p[a].total_cmp(&p[b])).unwrap();
        assert!((f[imax] - 0.1).abs() < 4.0 / 256.0);
        assert!(welch_psd(&tone(0.1, 127), &cfg).is_err());
    }

    #[test]
    fn freq_axis_shifted() {
        let x = vec![[1.0, 0.0]; 512];
        let (f, _) = welch_psd(&x, &WelchConfig { nfft: 256, overlap: 0.0 }).unwrap();
        assert_eq!(f[0], -0.5);
        assert_eq!(f[128], 0.0);
        assert!((f[255] - (0.5 - 1.0 / 256.0)).abs() < 1e-12);
    }
}
