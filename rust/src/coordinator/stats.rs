//! Pipeline instrumentation: per-stage counters and latency tracking.

use std::time::Duration;

/// Aggregated statistics of one stream's pipeline run.
#[derive(Clone, Debug, Default)]
pub struct PipelineStats {
    pub samples_in: u64,
    pub samples_out: u64,
    pub frames: u64,
    /// wall-clock of the whole stream
    pub wall: Duration,
    /// time the DPD stage spent processing
    pub dpd_busy: Duration,
    /// per-frame latency (enqueue -> processed)
    pub lat_mean: Duration,
    pub lat_max: Duration,
}

impl PipelineStats {
    /// End-to-end throughput in Msamples/s.
    pub fn throughput_msps(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.samples_out as f64 / self.wall.as_secs_f64() / 1e6
    }

    /// DPD-stage-only throughput (what the engine itself sustains).
    pub fn engine_msps(&self) -> f64 {
        if self.dpd_busy.is_zero() {
            return 0.0;
        }
        self.samples_out as f64 / self.dpd_busy.as_secs_f64() / 1e6
    }

    /// Real-time factor against the paper's 250 MSps line rate.
    pub fn realtime_factor_vs_250msps(&self) -> f64 {
        self.engine_msps() / 250.0
    }
}

/// Online latency aggregator.
#[derive(Clone, Debug, Default)]
pub struct LatencyAgg {
    n: u64,
    sum: Duration,
    max: Duration,
}

impl LatencyAgg {
    pub fn record(&mut self, d: Duration) {
        self.n += 1;
        self.sum += d;
        if d > self.max {
            self.max = d;
        }
    }

    pub fn mean(&self) -> Duration {
        if self.n == 0 {
            Duration::ZERO
        } else {
            self.sum / self.n as u32
        }
    }

    pub fn max(&self) -> Duration {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let s = PipelineStats {
            samples_in: 1_000_000,
            samples_out: 1_000_000,
            frames: 10,
            wall: Duration::from_millis(100),
            dpd_busy: Duration::from_millis(50),
            ..Default::default()
        };
        assert!((s.throughput_msps() - 10.0).abs() < 1e-9);
        assert!((s.engine_msps() - 20.0).abs() < 1e-9);
        assert!((s.realtime_factor_vs_250msps() - 0.08).abs() < 1e-9);
    }

    #[test]
    fn latency_agg() {
        let mut a = LatencyAgg::default();
        a.record(Duration::from_micros(10));
        a.record(Duration::from_micros(30));
        assert_eq!(a.mean(), Duration::from_micros(20));
        assert_eq!(a.max(), Duration::from_micros(30));
    }

    #[test]
    fn zero_division_safe() {
        let s = PipelineStats::default();
        assert_eq!(s.throughput_msps(), 0.0);
        assert_eq!(s.engine_msps(), 0.0);
    }
}
