//! Dense complex matrix (row-major) with the handful of operations the
//! LS solver needs.

use anyhow::{ensure, Result};

use crate::util::C64;

/// Dense complex matrix, row-major storage.
#[derive(Clone, Debug)]
pub struct CMat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<C64>,
}

impl CMat {
    pub fn zeros(rows: usize, cols: usize) -> CMat {
        CMat { rows, cols, data: vec![C64::ZERO; rows * cols] }
    }

    pub fn from_rows(rows_v: Vec<Vec<C64>>) -> Result<CMat> {
        ensure!(!rows_v.is_empty(), "empty matrix");
        let cols = rows_v[0].len();
        ensure!(rows_v.iter().all(|r| r.len() == cols), "ragged rows");
        let rows = rows_v.len();
        let data = rows_v.into_iter().flatten().collect();
        Ok(CMat { rows, cols, data })
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> C64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut C64 {
        &mut self.data[r * self.cols + c]
    }

    /// Conjugate-transpose times vector: A^H y.
    pub fn hermitian_mul_vec(&self, y: &[C64]) -> Vec<C64> {
        assert_eq!(y.len(), self.rows);
        let mut out = vec![C64::ZERO; self.cols];
        for r in 0..self.rows {
            let yr = y[r];
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            for (c, a) in row.iter().enumerate() {
                out[c] += a.conj() * yr;
            }
        }
        out
    }

    /// A x.
    pub fn mul_vec(&self, x: &[C64]) -> Vec<C64> {
        assert_eq!(x.len(), self.cols);
        let mut out = vec![C64::ZERO; self.rows];
        for r in 0..self.rows {
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            let mut acc = C64::ZERO;
            for (a, b) in row.iter().zip(x) {
                acc += *a * *b;
            }
            out[r] = acc;
        }
        out
    }

    /// Gram matrix A^H A (cols x cols, Hermitian).
    pub fn gram(&self) -> CMat {
        let n = self.cols;
        let mut g = CMat::zeros(n, n);
        for r in 0..self.rows {
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            for i in 0..n {
                let ai = row[i].conj();
                for j in i..n {
                    *g.at_mut(i, j) += ai * row[j];
                }
            }
        }
        // mirror
        for i in 0..n {
            for j in 0..i {
                *g.at_mut(i, j) = g.at(j, i).conj();
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(re: f64, im: f64) -> C64 {
        C64::new(re, im)
    }

    #[test]
    fn mul_vec_known() {
        let a = CMat::from_rows(vec![
            vec![c(1.0, 0.0), c(0.0, 1.0)],
            vec![c(2.0, 0.0), c(0.0, 0.0)],
        ])
        .unwrap();
        let y = a.mul_vec(&[c(1.0, 0.0), c(1.0, 0.0)]);
        assert!((y[0] - c(1.0, 1.0)).abs() < 1e-15);
        assert!((y[1] - c(2.0, 0.0)).abs() < 1e-15);
    }

    #[test]
    fn gram_is_hermitian_psd() {
        let a = CMat::from_rows(vec![
            vec![c(1.0, 2.0), c(-0.5, 0.3)],
            vec![c(0.0, -1.0), c(2.0, 0.0)],
            vec![c(0.7, 0.7), c(1.0, -1.0)],
        ])
        .unwrap();
        let g = a.gram();
        for i in 0..2 {
            for j in 0..2 {
                assert!((g.at(i, j) - g.at(j, i).conj()).abs() < 1e-12);
            }
            assert!(g.at(i, i).re > 0.0);
            assert!(g.at(i, i).im.abs() < 1e-12);
        }
    }

    #[test]
    fn hermitian_mul_vec_matches_definition() {
        let a = CMat::from_rows(vec![
            vec![c(1.0, 1.0), c(2.0, -1.0)],
            vec![c(0.5, 0.0), c(0.0, 3.0)],
        ])
        .unwrap();
        let y = [c(1.0, -1.0), c(2.0, 0.5)];
        let got = a.hermitian_mul_vec(&y);
        // manual: out[c] = sum_r conj(A[r][c]) y[r]
        let want0 = a.at(0, 0).conj() * y[0] + a.at(1, 0).conj() * y[1];
        let want1 = a.at(0, 1).conj() * y[0] + a.at(1, 1).conj() * y[1];
        assert!((got[0] - want0).abs() < 1e-14);
        assert!((got[1] - want1).abs() < 1e-14);
    }

    #[test]
    fn rejects_ragged() {
        assert!(CMat::from_rows(vec![vec![C64::ZERO], vec![C64::ZERO, C64::ZERO]]).is_err());
    }
}
