//! Sparse + mixed-precision Pareto sweep (SparseDPD arXiv:2506.16591 ×
//! MP-DPD arXiv:2404.15364): linearization quality (ACPR/EVM through
//! the Rapp+memory PA) vs modeled cost (MACs/sample and projected mW
//! under the 22FDX energy model) across the (ρ, W/A-profile, θ) grid
//! of the `SparseMpGruDpd` engine family.
//!
//! Hermetic: runs on the checked-in golden CP-OFDM burst
//! (`tests/data/golden_ofdm_q12.json`) with the synthetic float weight
//! set — the same (stimulus, model) pair the Python oracle pins in
//! `tests/data/golden_pareto.json`, so the numbers this bench reports
//! are the cross-validated ones.
//!
//! Emits `BENCH_pareto.json` (per-point ACPR/EVM/MAC-reduction/power +
//! datapath throughput) for the CI bench-report artifact; the
//! acceptance point of the family (≥1.5× modeled MAC reduction within
//! 0.5 dB ACPR of the dense Q2.10 baseline) is asserted, not just
//! reported.
//!
//! Run: `cargo bench --bench pareto` (`BENCH_QUICK=1` for the CI smoke).

use std::path::PathBuf;
use std::time::Duration;

use dpd_ne::accel::ops::ModelDims;
use dpd_ne::accel::power::EnergyModel;
use dpd_ne::accel::SparseCostModel;
use dpd_ne::bench::{quick_mode, time_it, Report};
use dpd_ne::dpd::qgru::ActKind;
use dpd_ne::dpd::weights::GruWeights;
use dpd_ne::dpd::SparseMpGruDpd;
use dpd_ne::dsp::welch::WelchConfig;
use dpd_ne::fixed::{QProfile, QSpec};
use dpd_ne::metrics::acpr::{acpr_db, AcprConfig};
use dpd_ne::metrics::evm::evm_db_nmse;
use dpd_ne::pa::{PaSpec, RappMemPa};
use dpd_ne::report::{f1, f2, Table};
use dpd_ne::util::json::Json;

const WEIGHTS_SEED: u64 = 7;
const MIN_MAC_REDUCTION: f64 = 1.5;
const MAX_ACPR_DELTA_DB: f64 = 0.5;

/// The sweep grid: (weight bits or None for uniform Q12, ρ%, θ).
/// Mirrors `python/tools/gen_golden_pareto.py::GRID` plus a few extra
/// ρ points for a denser front (the golden subset is what's pinned).
const GRID: &[(Option<u32>, u8, u32)] = &[
    (None, 0, 0),
    (None, 25, 0),
    (None, 50, 0),
    (None, 70, 0),
    (None, 85, 0),
    (Some(8), 0, 0),
    (Some(8), 50, 0),
    (Some(8), 70, 0),
    (Some(6), 50, 0),
    (Some(4), 0, 0),
    (Some(4), 50, 0),
    (Some(8), 50, 32),
];

fn load_iq() -> anyhow::Result<Vec<[f64; 2]>> {
    let path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/data/golden_ofdm_q12.json");
    let j = Json::parse_file(&path)?;
    Ok(j.get("iq")?
        .as_arr()?
        .iter()
        .map(|p| {
            let v = p.as_f64_vec().unwrap();
            [v[0], v[1]]
        })
        .collect())
}

fn main() -> anyhow::Result<()> {
    let budget = Duration::from_millis(200);
    let act_spec = QSpec::Q12;
    let iq = load_iq()?;
    let codes = act_spec.quantize_iq(&iq);
    let fw = GruWeights::synthetic(WEIGHTS_SEED);
    let pa = RappMemPa::new(PaSpec::ganlike());
    let g = pa.spec.target_gain();
    let cfg = AcprConfig {
        bw: 0.25,
        offset: 0.275,
        welch: WelchConfig { nfft: 2048, overlap: 0.5 },
    };
    let em = EnergyModel::default();
    let dims = ModelDims::default();

    // in quick mode keep only the baseline + the acceptance candidates
    let grid: Vec<_> = if quick_mode() {
        GRID.iter().copied().filter(|&(w, r, t)| {
            matches!((w, r, t), (None, 0, 0) | (None, 50, 0) | (Some(8), 50, 0))
        }).collect()
    } else {
        GRID.to_vec()
    };

    let mut report = Report::new("pareto");
    let mut t = Table::new(
        "Sparse/MP Pareto sweep on the golden OFDM burst (dense Q2.10 = first row)",
        &["spec", "MACs/smp", "MAC red.", "power (mW)", "ACPR (dBc)", "dACPR", "EVM (dB)", "kS/s"],
    );

    let mut base_acpr = None;
    let mut accepted = 0u32;
    for &(w_bits, rho, theta) in &grid {
        let profile = match w_bits {
            Some(w) => QProfile::wa(w, act_spec.bits)?,
            None => QProfile::uniform(act_spec),
        };
        let label = {
            let base = if theta > 0 { format!("delta:{theta}") } else { "fixed".into() };
            let prof = w_bits.map(|w| format!("@W{w}A{}", act_spec.bits)).unwrap_or_default();
            let sp = if rho > 0 || w_bits.is_none() { format!("+sparse:{rho}") } else { String::new() };
            format!("{base}{prof}{sp}")
        };
        let sw = fw.prune_quantize(profile, rho)?;
        let mut dpd = SparseMpGruDpd::new(sw.clone(), ActKind::Hard, theta);
        let out = dpd.run_codes(&codes);
        let stats = dpd.stats();

        let model = SparseCostModel::new(dims, profile);
        let macs = model.sparse_macs_per_sample(&stats);
        let red = model.mac_reduction(&stats);
        let power = model.projected_power_mw(&stats, &em, &ActKind::Hard);

        let z = act_spec.dequantize_iq(&out);
        let y = pa.run(&z);
        let acpr = acpr_db(&y, &cfg)?.acpr_dbc;
        let evm = evm_db_nmse(&y, &iq, g);
        let base = *base_acpr.get_or_insert(acpr);
        if red >= MIN_MAC_REDUCTION && (acpr - base).abs() <= MAX_ACPR_DELTA_DB {
            accepted += 1;
        }

        // datapath throughput of this point (host-side, for tracking)
        let mut bench_dpd = SparseMpGruDpd::new(sw, ActKind::Hard, theta);
        let r = time_it(&format!("sparse-mp {label}"), budget, || {
            std::hint::black_box(bench_dpd.run_codes(&codes));
        });
        let ksps = r.per_second(codes.len() as f64) / 1e3;

        t.row(&[
            label.clone(),
            f1(macs),
            f2(red),
            f1(power),
            f2(acpr),
            f2(acpr - base),
            f2(evm),
            f1(ksps),
        ]);
        let key = label.replace([':', '@', '+'], "_");
        report
            .metric(&format!("{key}_macs_per_sample", ), macs)
            .metric(&format!("{key}_mac_reduction"), red)
            .metric(&format!("{key}_power_mw"), power)
            .metric(&format!("{key}_acpr_dbc"), acpr)
            .metric(&format!("{key}_evm_db"), evm)
            .metric(&format!("{key}_ksps"), ksps)
            .push(r);
    }
    println!("{}", t.render());

    // the family's acceptance point, re-derived from live measurements
    assert!(
        accepted >= 1,
        "no sweep point reached >={MIN_MAC_REDUCTION}x MACs within {MAX_ACPR_DELTA_DB} dB ACPR"
    );
    report.metric("accepted_points", accepted as f64);
    report.metric("min_mac_reduction_bar", MIN_MAC_REDUCTION);
    report.metric("max_acpr_delta_db_bar", MAX_ACPR_DELTA_DB);
    let path = report.write()?;
    println!("pareto: {accepted} point(s) met the acceptance bar; wrote {}", path.display());
    Ok(())
}
