#!/usr/bin/env python3
"""Golden-vector generator for the sparse + mixed-precision engine
family (`rust/src/dpd/sparse.rs::SparseMpGruDpd`) — the Pareto sweep's
independent Python oracle.

Mirrors, integer-exactly:

* `GruWeights::synthetic`        -> float_synthetic_weights (f64 twin)
* `GruWeights::prune_quantize`   -> per-tensor quantization + prune
* `dpd::weights::prune_mask`     -> magnitude prune order (|code|, idx)
* `dpd::weights::csc_from_dense` -> surviving-entry CSC storage
* `SparseMpGruDpd::step_codes`   -> run_sparse_mp (per-tensor fracs,
                                    carried accumulators, theta firing)

and emits `rust/tests/data/golden_pareto.json`: for each (profile, rho,
theta) grid point the first-64 output codes (bit-exact pins), the exact
activity counters, the cost-model MAC reduction, and the measured
ACPR/EVM through the shared Rapp+memory PA — which
`rust/tests/pareto_golden.rs` replays against the Rust engine.

The waveform is NOT duplicated here: the sweep reads the checked-in
CP-OFDM burst from `golden_ofdm_q12.json` (the decimals in that file
are the waveform), so both golden suites measure the same stimulus.

Internal contracts asserted before anything is written:

* uniform profile + rho=0 + theta=0  == the dense `run_qgru` port bit
  for bit (the `fixed+sparse:0` conformance hinge);
* at least one grid point achieves >= 1.5x modeled MAC reduction while
  staying within 0.5 dB ACPR of the dense Q2.10 baseline — the
  acceptance point of the sparse/MP family.
"""

import json
import math
import pathlib

import numpy as np

from gen_golden_ofdm import (
    Rng,
    WELCH_NFFT,
    TOL_DB,
    WEIGHTS_SEED,
    acpr_dbc,
    evm_db_nmse,
    pa_run,
    rshift_round,
)

# acceptance bars (ISSUE: >= 1.5x modeled MAC reduction within 0.5 dB
# ACPR of the dense Q2.10 baseline)
MIN_MAC_REDUCTION = 1.5
MAX_ACPR_DELTA_DB = 0.5

HIDDEN, FEATURES = 10, 4


# --- rust/src/fixed/qspec.rs twin, parameterized by bit width ------------


def spec(bits: int) -> dict:
    """QSpec twin: Q2.(bits-2) signed fixed point."""
    frac = bits - 2
    return {
        "bits": bits,
        "frac": frac,
        "scale": float(1 << frac),
        "one": 1 << frac,
        "half": 1 << (frac - 1),
        "qmin": -(1 << (bits - 1)),
        "qmax": (1 << (bits - 1)) - 1,
    }


def sat_s(v: int, s: dict) -> int:
    return s["qmin"] if v < s["qmin"] else (s["qmax"] if v > s["qmax"] else v)


def requant_s(v: int, sh: int, s: dict) -> int:
    return sat_s(rshift_round(v, sh), s)


def quantize_s(x: float, s: dict) -> int:
    q = math.floor(x * s["scale"] + 0.5)
    return sat_s(int(q), s)


def hard_sigmoid_s(c: int, s: dict) -> int:
    v = (c >> 2) + s["half"]
    return 0 if v < 0 else (s["one"] if v > s["one"] else v)


def hard_tanh_s(c: int, s: dict) -> int:
    one = s["one"]
    return -one if c < -one else (one if c > one else c)


# --- rust/src/dpd/weights.rs twins ---------------------------------------


def float_synthetic_weights(seed: int) -> dict:
    """GruWeights::synthetic twin (H=10, F=4, |w| < 0.15), bit-exact
    f64: same xoshiro stream, same `lo + (hi-lo)*uniform` arithmetic."""
    rng = Rng(seed)

    def gen(n: int):
        return [rng.range(-0.15, 0.15) for _ in range(n)]

    return {
        "hidden": HIDDEN,
        "features": FEATURES,
        "w_ih": gen(3 * HIDDEN * FEATURES),
        "b_ih": gen(3 * HIDDEN),
        "w_hh": gen(3 * HIDDEN * HIDDEN),
        "b_hh": gen(3 * HIDDEN),
        "w_fc": gen(2 * HIDDEN),
        "b_fc": gen(2),
    }


def prune_mask(codes: list, rho: int) -> list:
    """dpd::weights::prune_mask twin: drop the floor(rho% * N) smallest
    by (|code|, index) — the deterministic total order both sides pin."""
    k = len(codes) * min(rho, 100) // 100
    order = sorted(range(len(codes)), key=lambda i: (abs(codes[i]), i))
    pruned = [False] * len(codes)
    for i in order[:k]:
        pruned[i] = True
    return pruned


def csc_from_dense(w: list, rows: int, cols: int, pruned: list):
    """csc_from_dense twin: per column, surviving = unpruned AND nonzero."""
    ptr, out_rows, out_vals = [0], [], []
    for c in range(cols):
        for r in range(rows):
            idx = r * cols + c
            if not pruned[idx] and w[idx] != 0:
                out_rows.append(r)
                out_vals.append(w[idx])
        ptr.append(len(out_rows))
    return ptr, out_rows, out_vals


def prune_quantize(fw: dict, w_bits: int, a_bits: int, rho: int) -> dict:
    """GruWeights::prune_quantize twin: gate/FC weights in the weight
    spec, biases in the activation spec, then magnitude-prune + CSC."""
    ws, as_ = spec(w_bits), spec(a_bits)
    q = lambda vs, s: [quantize_s(v, s) for v in vs]
    w_ih = q(fw["w_ih"], ws)
    w_hh = q(fw["w_hh"], ws)
    ih_ptr, ih_rows, ih_vals = csc_from_dense(
        w_ih, 3 * HIDDEN, FEATURES, prune_mask(w_ih, rho)
    )
    hh_ptr, hh_rows, hh_vals = csc_from_dense(
        w_hh, 3 * HIDDEN, HIDDEN, prune_mask(w_hh, rho)
    )
    return {
        "w_bits": w_bits,
        "a_bits": a_bits,
        "rho": rho,
        "ih_ptr": ih_ptr,
        "ih_rows": ih_rows,
        "ih_vals": ih_vals,
        "hh_ptr": hh_ptr,
        "hh_rows": hh_rows,
        "hh_vals": hh_vals,
        "b_ih": q(fw["b_ih"], as_),
        "b_hh": q(fw["b_hh"], as_),
        "w_fc": q(fw["w_fc"], ws),
        "b_fc": q(fw["b_fc"], as_),
    }


# --- rust/src/dpd/sparse.rs twin -----------------------------------------


def run_sparse_mp(sw: dict, codes: list, theta: int):
    """SparseMpGruDpd::step_codes twin, integer exact: carried raw
    accumulators in each tensor's fa+fw domain, |delta| > theta column
    firing over surviving CSC entries only, readout requantized by the
    *weight* fraction, dense gate/FC chain in the activation format.
    Returns (out_codes, stats dict)."""
    act = spec(sw["a_bits"])
    fa = act["frac"]
    fw = sw["w_bits"] - 2  # wa profiles: one weight frac for all tensors
    hd = HIDDEN
    rows = 3 * hd
    one = act["one"]
    h = [0] * hd
    x_prev = [0] * FEATURES
    h_prev = [0] * hd
    acc_ih = [b << fw for b in sw["b_ih"]]
    acc_hh = [b << fw for b in sw["b_hh"]]
    in_updates = hid_updates = gate_macs = 0
    out = []
    for ic, qc in codes:
        p = requant_s(ic * ic + qc * qc, fa - 2, act)
        p2 = requant_s(p * p, fa, act)
        x = [ic, qc, p, p2]
        for c in range(FEATURES):
            d = x[c] - x_prev[c]
            if abs(d) > theta:
                lo, hi = sw["ih_ptr"][c], sw["ih_ptr"][c + 1]
                for e in range(lo, hi):
                    acc_ih[sw["ih_rows"][e]] += sw["ih_vals"][e] * d
                x_prev[c] = x[c]
                in_updates += 1
                gate_macs += hi - lo
        for c in range(hd):
            d = h[c] - h_prev[c]
            if abs(d) > theta:
                lo, hi = sw["hh_ptr"][c], sw["hh_ptr"][c + 1]
                for e in range(lo, hi):
                    acc_hh[sw["hh_rows"][e]] += sw["hh_vals"][e] * d
                h_prev[c] = h[c]
                hid_updates += 1
                gate_macs += hi - lo
        gi = [requant_s(acc_ih[r], fw, act) for r in range(rows)]
        gh = [requant_s(acc_hh[r], fw, act) for r in range(rows)]
        for k in range(hd):
            r_ = hard_sigmoid_s(sat_s(gi[k] + gh[k], act), act)
            z = hard_sigmoid_s(sat_s(gi[hd + k] + gh[hd + k], act), act)
            rh = requant_s(r_ * gh[2 * hd + k], fa, act)
            n = hard_tanh_s(sat_s(gi[2 * hd + k] + rh, act), act)
            zn = rshift_round((one - z) * n, fa)
            zh = rshift_round(z * h[k], fa)
            h[k] = sat_s(zn + zh, act)
        y = []
        for o in range(2):
            acc = sw["b_fc"][o] << fw
            for k in range(hd):
                acc += sw["w_fc"][o * hd + k] * h[k]
            y.append(sat_s(requant_s(acc, fw, act) + x[o], act))
        out.append((y[0], y[1]))
    steps = len(codes)
    stats = {
        "steps": steps,
        "in_updates": in_updates,
        "in_cols": FEATURES * steps,
        "hid_updates": hid_updates,
        "hid_cols": hd * steps,
        "gate_macs": gate_macs,
        "dense_gate_macs": steps * 3 * hd * (FEATURES + hd),
    }
    return out, stats


def mac_reduction(stats: dict) -> float:
    """accel::sparse::SparseCostModel::mac_reduction twin: executed
    gate entries per sample + the dense 2H FC head, vs dense 440."""
    dense = 3 * HIDDEN * (FEATURES + HIDDEN) + 2 * HIDDEN
    sparse = stats["gate_macs"] / stats["steps"] + 2 * HIDDEN
    return dense / sparse


# --- the sweep -----------------------------------------------------------

# (w_bits or None for uniform-at-act, rho, theta); W12A12 is the uniform
# profile, so w=None rows exercise the integer `to_sparse` path and
# profile rows the float `prune_quantize` path — both Rust entry points.
GRID = [
    (None, 0, 0),   # == dense fixed, the conformance hinge
    (None, 25, 0),
    (None, 50, 0),
    (None, 70, 0),
    (8, 0, 0),
    (8, 50, 0),
    (6, 50, 0),
    (4, 0, 0),
    (4, 50, 0),
    (8, 50, 32),    # the fully composed family member
]

A_BITS = 12


def main() -> None:
    root = pathlib.Path(__file__).resolve().parents[2]
    data_dir = root / "rust" / "tests" / "data"
    wave = json.load(open(data_dir / "golden_ofdm_q12.json"))["iq"]
    x = np.array([complex(a, b) for a, b in wave])
    act = spec(A_BITS)
    codes = [(quantize_s(a, act), quantize_s(b, act)) for a, b in wave]

    fw = float_synthetic_weights(WEIGHTS_SEED)
    g_target = (0.995 + 0.087j) * 0.95

    # dense Q2.10 baseline: the uniform quantization of the same float
    # model through the dense datapath == sparse(uniform, rho=0, theta=0)
    base_sw = prune_quantize(fw, A_BITS, A_BITS, 0)
    base_codes, base_stats = run_sparse_mp(base_sw, codes, 0)
    assert base_stats["gate_macs"] <= base_stats["dense_gate_macs"]
    zb = np.array([complex(a / act["scale"], b / act["scale"]) for a, b in base_codes])
    base_acpr = acpr_dbc(pa_run(zb), WELCH_NFFT)
    base_evm = evm_db_nmse(pa_run(zb), x, g_target)

    # contract: the sparse twin at (uniform, 0, 0) is the dense port —
    # cross-check against gen_golden_ofdm's independently written dense
    # runner on the same quantized weight set
    from gen_golden_ofdm import run_qgru

    qw_dense = {
        "hidden": HIDDEN,
        "features": FEATURES,
        "w_ih": [quantize_s(v, act) for v in fw["w_ih"]],
        "b_ih": [quantize_s(v, act) for v in fw["b_ih"]],
        "w_hh": [quantize_s(v, act) for v in fw["w_hh"]],
        "b_hh": [quantize_s(v, act) for v in fw["b_hh"]],
        "w_fc": [quantize_s(v, act) for v in fw["w_fc"]],
        "b_fc": [quantize_s(v, act) for v in fw["b_fc"]],
    }
    assert run_qgru(qw_dense, codes) == base_codes, (
        "sparse twin at (uniform, rho=0, theta=0) diverged from the dense port"
    )

    rows = []
    for w_bits, rho, theta in GRID:
        wb = w_bits if w_bits is not None else A_BITS
        sw = prune_quantize(fw, wb, A_BITS, rho)
        out, stats = run_sparse_mp(sw, codes, theta)
        z = np.array([complex(a / act["scale"], b / act["scale"]) for a, b in out])
        y = pa_run(z)
        nnz = len(sw["ih_vals"]) + len(sw["hh_vals"])
        rows.append(
            {
                "profile": None if w_bits is None else [w_bits, A_BITS],
                "rho": rho,
                "theta": theta,
                "gate_nnz": nnz,
                "stats": stats,
                "mac_reduction": mac_reduction(stats),
                "acpr_dbc": acpr_dbc(y, WELCH_NFFT),
                "evm_db": evm_db_nmse(y, x, g_target),
                "head_codes": [list(c) for c in out[:64]],
            }
        )
        print(
            f"  W{wb}A{A_BITS} rho={rho:3d} theta={theta:2d}: "
            f"{rows[-1]['mac_reduction']:.2f}x MACs, "
            f"ACPR {rows[-1]['acpr_dbc']:+.3f} dBc "
            f"(d {rows[-1]['acpr_dbc'] - base_acpr:+.3f}), "
            f"EVM {rows[-1]['evm_db']:+.2f} dB"
        )

    # row 0 is the uniform rho=0 hinge: bit-identical to the baseline
    assert rows[0]["head_codes"] == [list(c) for c in base_codes[:64]]
    assert abs(rows[0]["acpr_dbc"] - base_acpr) < 1e-12

    # the acceptance point: >= 1.5x modeled MAC reduction within 0.5 dB
    # ACPR of the dense baseline, on at least one grid row
    accepted = [
        i
        for i, r in enumerate(rows)
        if r["mac_reduction"] >= MIN_MAC_REDUCTION
        and abs(r["acpr_dbc"] - base_acpr) <= MAX_ACPR_DELTA_DB
    ]
    assert accepted, "no grid point met the 1.5x-within-0.5dB acceptance bar"

    doc = {
        "meta": {
            "description": "sparse + mixed-precision Pareto golden sweep "
            "(SparseMpGruDpd vs dense Q2.10) on the golden CP-OFDM burst",
            "generator": "python/tools/gen_golden_pareto.py",
            "weights_seed": WEIGHTS_SEED,
            "act_bits": A_BITS,
            "welch_nfft": WELCH_NFFT,
            "waveform": "golden_ofdm_q12.json:iq",
            "min_mac_reduction": MIN_MAC_REDUCTION,
            "max_acpr_delta_db": MAX_ACPR_DELTA_DB,
            "tol_db": TOL_DB,
        },
        "baseline": {
            "acpr_dbc": base_acpr,
            "evm_db": base_evm,
            "head_codes": [list(c) for c in base_codes[:64]],
        },
        "accepted_rows": accepted,
        "rows": rows,
    }
    out_path = data_dir / "golden_pareto.json"
    out_path.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {out_path} ({len(rows)} rows, accepted: {accepted})")


if __name__ == "__main__":
    main()
