"""Direct-learning QAT training of the GRU-DPD model (build path).

The paper trains with PyTorch QAT for 300 epochs (batch 64, frame 50,
stride 1, Adam 1e-3 + ReduceLROnPlateau). We reproduce the same
optimization in jax, hand-rolled Adam (no optax offline), against the
differentiable PA plant (``pa_model``):

    min_theta  E || PA(DPD_theta(x)) - G·x ||^2

with G the PA's backed-off target gain — the classic direct-learning
architecture (what OpenDPD calls the end-to-end pass). QAT inserts
``fake_quant`` at every datapath requantization point (see
``kernels.ref.float_step``), so the trained weights already account for
the Q2.f grid, Hardsigmoid/Hardtanh clipping, or the LUT ROM.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import pa_model
from .kernels import ref
from .kernels.quant import QSpec

Params = Dict[str, jnp.ndarray]

__all__ = ["TrainConfig", "train", "nmse_db", "dpd_loss"]


@dataclass
class TrainConfig:
    steps: int = 600
    batch: int = 64
    lr: float = 1e-3
    # ReduceLROnPlateau-style decay: halve LR after `patience` evals
    # without improvement; evaluate every `eval_every` steps.
    patience: int = 4
    eval_every: int = 25
    lr_min: float = 1e-5
    seed: int = 0
    log_every: int = 0  # 0 = silent


def dpd_loss(params: Params, frames: jnp.ndarray, pa: pa_model.PASpec, spec: QSpec | None, act: str) -> jnp.ndarray:
    """Mean squared direct-learning error over a batch of frames."""
    y_dpd = ref.float_forward(params, frames, spec=spec, act=act)
    y_pa = pa_model.apply_pa(y_dpd, pa)
    g = pa_model.target_gain(pa)
    tr, ti = frames[..., 0], frames[..., 1]
    target = jnp.stack([g.real * tr - g.imag * ti, g.real * ti + g.imag * tr], axis=-1)
    return jnp.mean((y_pa - target) ** 2)


def nmse_db(y: np.ndarray, t: np.ndarray) -> float:
    """Normalized mean-square error in dB (the DPD community's metric)."""
    num = np.sum((y - t) ** 2)
    den = np.sum(t ** 2)
    return float(10.0 * np.log10(num / den))


def _adam_init(params: Params):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.zeros((), jnp.int32)}


def _adam_update(params: Params, grads: Params, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    tf = t.astype(jnp.float32)
    new = {}
    for k in params:
        mhat = m[k] / (1 - b1 ** tf)
        vhat = v[k] / (1 - b2 ** tf)
        new[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new, {"m": m, "v": v, "t": t}


def train(
    params: Params,
    frames: np.ndarray,
    pa: pa_model.PASpec,
    cfg: TrainConfig,
    spec: QSpec | None = None,
    act: str = "hard",
    val_frames: np.ndarray | None = None,
) -> Tuple[Params, dict]:
    """Train (or QAT-fine-tune) the model. Returns (params, history).

    ``frames``: (N, T, 2). Deterministic given cfg.seed.
    """
    frames = jnp.asarray(frames, jnp.float32)
    val = jnp.asarray(val_frames, jnp.float32) if val_frames is not None else frames[: min(len(frames), 256)]
    rng = np.random.default_rng(cfg.seed)

    loss_fn = jax.jit(lambda p, b: dpd_loss(p, b, pa, spec, act))
    grad_fn = jax.jit(jax.value_and_grad(lambda p, b: dpd_loss(p, b, pa, spec, act)))

    state = _adam_init(params)
    lr = cfg.lr
    best_val = float("inf")
    stall = 0
    history = {"loss": [], "val": [], "lr": []}

    update = jax.jit(lambda p, g, s, lr: _adam_update(p, g, s, lr))

    for step in range(cfg.steps):
        idx = rng.integers(0, frames.shape[0], size=cfg.batch)
        batch = frames[jnp.asarray(idx)]
        loss, grads = grad_fn(params, batch)
        params, state = update(params, grads, state, lr)
        history["loss"].append(float(loss))

        if (step + 1) % cfg.eval_every == 0:
            vloss = float(loss_fn(params, val))
            history["val"].append(vloss)
            history["lr"].append(lr)
            if vloss < best_val - 1e-9:
                best_val = vloss
                stall = 0
            else:
                stall += 1
                if stall >= cfg.patience and lr > cfg.lr_min:
                    lr = max(lr * 0.5, cfg.lr_min)
                    stall = 0
            if cfg.log_every and (step + 1) % cfg.log_every == 0:
                print(f"  step {step+1:5d} loss {float(loss):.3e} val {vloss:.3e} lr {lr:.2e}")

    history["best_val"] = best_val
    return params, history
