//! Microbenchmarks of the hot paths (the §Perf baseline/tracking
//! numbers in EXPERIMENTS.md): FFT, Welch PSD, fixed-point GRU step,
//! float GRU step, cycle-sim step, GMP basis, coordinator pipeline,
//! and the HLO/PJRT frame path.
//!
//! Run: `cargo bench --bench micro`

use std::time::Duration;

use dpd_ne::bench::time_it;
use dpd_ne::coordinator::{Coordinator, CoordinatorConfig, EngineKind};
use dpd_ne::dpd::gmp::{GmpConfig, GmpDpd};
use dpd_ne::dpd::gru::GruDpd;
use dpd_ne::dpd::qgru::{ActKind, QGruDpd};
use dpd_ne::dpd::weights::{GruWeights, QGruWeights};
use dpd_ne::dpd::Dpd;
use dpd_ne::dsp::fft::Fft;
use dpd_ne::dsp::welch::{welch_psd, WelchConfig};
use dpd_ne::fixed::QSpec;
use dpd_ne::pa::{PaSpec, RappMemPa};
use dpd_ne::runtime::{HloGruEngine, Manifest};
use dpd_ne::signal::ofdm::{OfdmConfig, OfdmModulator};
use dpd_ne::util::{C64, Rng};

fn main() -> anyhow::Result<()> {
    let budget = Duration::from_millis(400);
    println!("== microbenchmarks (hot paths) ==");

    // FFT 4096
    let mut rng = Rng::new(1);
    let plan = Fft::new(4096)?;
    let mut buf: Vec<C64> = (0..4096).map(|_| C64::new(rng.gauss(), rng.gauss())).collect();
    let r = time_it("fft4096", budget, || {
        plan.forward(&mut buf);
    });
    println!("{}  -> {:.1} MS/s", r.summary(), r.per_second(4096.0) / 1e6);

    // Welch over 128k samples
    let sig: Vec<[f64; 2]> = (0..1 << 17).map(|_| [rng.gauss(), rng.gauss()]).collect();
    let r = time_it("welch psd 128k (nfft 4096)", budget, || {
        std::hint::black_box(welch_psd(&sig, &WelchConfig::default()).unwrap());
    });
    println!("{}  -> {:.1} MS/s", r.summary(), r.per_second(sig.len() as f64) / 1e6);

    // PA model
    let pa = RappMemPa::new(PaSpec::ganlike());
    let burst: Vec<[f64; 2]> = (0..65536).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect();
    let r = time_it("pa rapp+mem 64k", budget, || {
        std::hint::black_box(pa.run(&burst));
    });
    println!("{}  -> {:.1} MS/s", r.summary(), r.per_second(burst.len() as f64) / 1e6);

    // engines (need artifacts)
    if let Ok(m) = Manifest::discover(None) {
        let spec = QSpec::new(m.qspec_bits)?;
        let qw = QGruWeights::load_params_int(&m.weights_main, spec)?;
        let fw = GruWeights::load(&m.weights_float)?;
        let codes: Vec<[i32; 2]> = burst[..16384]
            .iter()
            .map(|&[i, q]| [spec.quantize(i), spec.quantize(q)])
            .collect();

        let mut qdpd = QGruDpd::new(qw.clone(), ActKind::Hard);
        let r = time_it("qgru (bit-exact) 16k samples", budget, || {
            std::hint::black_box(qdpd.run_codes(&codes));
        });
        println!("{}  -> {:.2} MSps", r.summary(), r.per_second(codes.len() as f64) / 1e6);

        let mut fdpd = GruDpd::new(fw);
        let r = time_it("gru f64 16k samples", budget, || {
            std::hint::black_box(fdpd.run(&burst[..16384]));
        });
        println!("{}  -> {:.2} MSps", r.summary(), r.per_second(16384.0) / 1e6);

        let mut sim = dpd_ne::accel::CycleAccurateEngine::new(
            &qw,
            dpd_ne::accel::act_unit::ActImpl::Hard,
            dpd_ne::accel::fsm::HwConfig::default(),
        );
        let r = time_it("cycle-sim 16k samples", budget, || {
            std::hint::black_box(sim.run_codes(&codes).unwrap());
        });
        println!("{}  -> {:.2} MSps", r.summary(), r.per_second(codes.len() as f64) / 1e6);

        // coordinator pipeline end to end
        let coord = Coordinator::new(CoordinatorConfig { engine: EngineKind::Fixed, ..Default::default() });
        let r = time_it("pipeline fixed 64k samples", Duration::from_millis(800), || {
            std::hint::black_box(coord.run_stream(&burst).unwrap());
        });
        println!("{}  -> {:.2} MSps", r.summary(), r.per_second(burst.len() as f64) / 1e6);

        // HLO frame path
        if let Some(e) = m.int_hlo_with_time(2048) {
            let client = xla::PjRtClient::cpu()?;
            let mut eng = HloGruEngine::load(&client, &m.hlo_path(e), 1, e.time, true, Some(spec))?;
            let frame = &codes[..2048.min(codes.len())];
            let frame: Vec<[i32; 2]> = frame.to_vec();
            let r = time_it("hlo/pjrt frame 2048", Duration::from_millis(800), || {
                std::hint::black_box(eng.run_frame_codes(&frame).unwrap());
            });
            println!("{}  -> {:.2} MSps", r.summary(), r.per_second(2048.0) / 1e6);
        }

        // GMP engine
        let sig_t = OfdmModulator::generate(&OfdmConfig { n_symbols: 16, seed: 3, ..Default::default() })?;
        let y = pa.run(&sig_t.iq);
        let mut gmp = GmpDpd::fit_ila(&GmpConfig::default(), &sig_t.iq, &y, pa.spec.target_gain())?;
        let r = time_it("gmp 16k samples", budget, || {
            std::hint::black_box(gmp.run(&burst[..16384]));
        });
        println!("{}  -> {:.2} MSps", r.summary(), r.per_second(16384.0) / 1e6);
    } else {
        eprintln!("(engine benches skipped: no artifacts)");
    }
    Ok(())
}
