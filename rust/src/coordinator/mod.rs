//! L3 coordinator — the streaming transmit-chain runtime around the
//! accelerator (the "DBE" of the paper's introduction).
//!
//! A transmit stream flows source -> framer -> DPD engine -> sink
//! through bounded channels (blocking = backpressure); multiple
//! independent streams model the mMIMO fan-out (one DPD-NeuralEngine
//! macro per antenna). Engines are selectable per stream through the
//! unified [`DpdEngine`](crate::runtime::DpdEngine) backend: native
//! f64 GRU, bit-exact fixed-point, the cycle-accurate ASIC simulator,
//! the interpreted frame engine, or — under `--features xla` — the
//! AOT HLO executed via PJRT.
//!
//! Python never runs here; the HLO path executes the build-time
//! artifacts through the embedded PJRT CPU client.

pub mod framer;
pub mod pipeline;
pub mod stats;

pub use framer::Framer;
pub use pipeline::{Coordinator, CoordinatorConfig, EngineKind, StreamOutput};
pub use stats::PipelineStats;
