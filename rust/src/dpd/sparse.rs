//! Sparse + mixed-precision GRU DPD engine — the SparseDPD
//! (arXiv:2506.16591) × MP-DPD (arXiv:2404.15364) family member.
//!
//! [`SparseMpGruDpd`] combines three MAC-reduction levers: static
//! magnitude pruning in compressed sparse-column form
//! ([`SparseQGruWeights`](super::SparseQGruWeights)), per-tensor mixed
//! precision (the [`QProfile`](crate::fixed::QProfile) — products
//! accumulate in the fa+fw domain and requantize by the *weight*
//! fraction), and the same θ-threshold delta skipping as
//! [`DeltaQGruDpd`](super::DeltaQGruDpd). The engine is the
//! [`SparseCscPlan`](super::exec::SparseCscPlan) alias of
//! [`IntGruExecutor`](super::exec::IntGruExecutor) — see `dpd::exec`
//! for the datapath and the equivalence hinges (uniform ρ=0 θ=0 ≡
//! dense; uniform ρ=0 ≡ delta at any θ), which the unification makes
//! structural and the differential tests below keep as regression
//! armor. For ρ>0 or narrow weights the engine computes a different
//! (cheaper) function whose linearization cost is swept into
//! `BENCH_pareto.json` and cross-validated against the Python mirror.

pub use super::exec::SparseMpGruDpd;

/// Column-update + MAC activity of a sparse engine — the measured
/// work the accel cost model (`accel::sparse`) prices. Like
/// [`DeltaStats`](super::DeltaStats), counters accumulate across the
/// engine's whole life and survive `reset`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SparseStats {
    /// samples processed
    pub steps: u64,
    /// input feature columns whose delta exceeded θ (fired)
    pub in_updates: u64,
    /// input feature column opportunities (steps × F)
    pub in_cols: u64,
    /// hidden columns whose delta exceeded θ (fired)
    pub hid_updates: u64,
    /// hidden column opportunities (steps × H)
    pub hid_cols: u64,
    /// gate MACs actually executed: Σ over fired columns of that
    /// column's surviving (unpruned, nonzero) entry count
    pub gate_macs: u64,
    /// gate MACs the dense engine performs: steps × 3H(F+H)
    pub dense_gate_macs: u64,
}

impl SparseStats {
    /// Executed / dense gate MACs (1.0 = no savings).
    pub fn mac_ratio(&self) -> f64 {
        if self.dense_gate_macs == 0 {
            return 1.0;
        }
        self.gate_macs as f64 / self.dense_gate_macs as f64
    }

    /// Fraction of all matvec columns that fired.
    pub fn update_ratio(&self) -> f64 {
        let cols = self.in_cols + self.hid_cols;
        if cols == 0 {
            return 1.0;
        }
        (self.in_updates + self.hid_updates) as f64 / cols as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpd::qgru::{ActKind, DeltaQGruDpd, QGruDpd};
    use crate::dpd::weights::{GruWeights, QGruWeights};
    use crate::dpd::{Dpd, DpdLane, DpdState};
    use crate::fixed::kernel::ScalarKernel;
    use crate::fixed::{QProfile, QSpec};
    use crate::util::proptest::check;
    use crate::util::Rng;

    fn rand_stream(rng: &mut Rng, n: usize) -> Vec<[f64; 2]> {
        (0..n).map(|_| [rng.range(-0.9, 0.9), rng.range(-0.9, 0.9)]).collect()
    }

    #[test]
    fn uniform_rho0_theta0_is_bit_identical_to_dense() {
        check("sparse rho=0 == dense", 30, |rng| {
            let seed = rng.next_u64();
            let qw = QGruWeights::synthetic(seed, QSpec::Q12);
            let mut dense = QGruDpd::new(qw.clone(), ActKind::Hard);
            let mut sparse = SparseMpGruDpd::new(qw.to_sparse(0), ActKind::Hard, 0);
            let x = rand_stream(rng, 64);
            for (t, &s) in x.iter().enumerate() {
                let a = dense.process(s);
                let b = sparse.process(s);
                if a != b {
                    return Err(format!("seed {seed}: diverged at t={t}: {a:?} vs {b:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn uniform_rho0_matches_the_delta_engine_at_any_theta() {
        check("sparse rho=0 == delta @theta", 20, |rng| {
            let seed = rng.next_u64();
            let theta = rng.int_in(0, 64) as u32;
            let qw = QGruWeights::synthetic(seed, QSpec::Q12);
            let mut delta = DeltaQGruDpd::new(qw.clone(), ActKind::Hard, theta);
            let mut sparse = SparseMpGruDpd::new(qw.to_sparse(0), ActKind::Hard, theta);
            let x = rand_stream(rng, 96);
            for (t, &s) in x.iter().enumerate() {
                let a = delta.process(s);
                let b = sparse.process(s);
                if a != b {
                    return Err(format!(
                        "seed {seed} theta={theta}: diverged at t={t}: {a:?} vs {b:?}"
                    ));
                }
            }
            // same fire decisions -> same update counts
            let (ds, ss) = (delta.stats(), sparse.stats());
            if (ds.in_updates, ds.hid_updates) != (ss.in_updates, ss.hid_updates) {
                return Err(format!("seed {seed}: fire counts diverged"));
            }
            Ok(())
        });
    }

    #[test]
    fn pruning_reduces_gate_macs_proportionally() {
        let qw = QGruWeights::synthetic(7, QSpec::Q12);
        let mut rng = Rng::new(99);
        let x = rand_stream(&mut rng, 200);
        let mut dense0 = SparseMpGruDpd::new(qw.to_sparse(0), ActKind::Hard, 0);
        let mut pruned = SparseMpGruDpd::new(qw.to_sparse(50), ActKind::Hard, 0);
        for &s in &x {
            dense0.process(s);
            pruned.process(s);
        }
        let (s0, s1) = (dense0.stats(), pruned.stats());
        assert_eq!(s0.steps, 200);
        assert!(s1.gate_macs * 2 <= s0.dense_gate_macs, "rho=50 must halve gate MACs");
        assert!(s1.mac_ratio() < s0.mac_ratio());
        assert!(s0.mac_ratio() <= 1.0);
    }

    #[test]
    fn mixed_precision_profile_still_linearizes_reasonably() {
        // W8A12 on the same codes: not bit-identical to dense, but the
        // output must stay close (narrow weights, same activations) —
        // a sanity floor; the real quality accounting is the Pareto
        // golden test.
        let w = GruWeights::synthetic(13);
        let qw = w.quantize(QSpec::Q12).unwrap();
        let sw = w.prune_quantize(QProfile::wa(8, 12).unwrap(), 0).unwrap();
        let mut dense = QGruDpd::new(qw, ActKind::Hard);
        let mut mp = SparseMpGruDpd::new(sw, ActKind::Hard, 0);
        let mut rng = Rng::new(5);
        let x = rand_stream(&mut rng, 256);
        let mut err = 0.0f64;
        let mut pow = 0.0f64;
        for &s in &x {
            let a = dense.process(s);
            let b = mp.process(s);
            err += (a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2);
            pow += a[0].powi(2) + a[1].powi(2);
        }
        let nmse_db = 10.0 * (err / pow).log10();
        assert!(nmse_db < -20.0, "W8A12 deviates too much from dense: {nmse_db:.1} dB");
    }

    #[test]
    fn state_roundtrip_is_exact_mid_stream() {
        let qw = QGruWeights::synthetic(4, QSpec::Q12);
        let sw = qw.to_sparse(40);
        let mut rng = Rng::new(8);
        let x = rand_stream(&mut rng, 120);
        // uninterrupted reference
        let mut a = SparseMpGruDpd::new(sw.clone(), ActKind::Hard, 24);
        let want: Vec<[f64; 2]> = x.iter().map(|&s| a.process(s)).collect();
        // interrupted: snapshot + restore across a fresh engine
        let mut b1 = SparseMpGruDpd::new(sw.clone(), ActKind::Hard, 24);
        let mut got: Vec<[f64; 2]> = x[..60].iter().map(|&s| b1.process(s)).collect();
        let snap = b1.save_state();
        let mut b2 = SparseMpGruDpd::new(sw, ActKind::Hard, 24);
        b2.load_state(&snap).unwrap();
        got.extend(x[60..].iter().map(|&s| b2.process(s)));
        assert_eq!(got, want, "state snapshot must round-trip exactly");
    }

    #[test]
    fn batched_lanes_match_solo_processing() {
        let qw = QGruWeights::synthetic(19, QSpec::Q12);
        let sw = qw.to_sparse(50);
        let mut rng = Rng::new(3);
        let mut streams: Vec<Vec<[f64; 2]>> =
            (0..3).map(|_| rand_stream(&mut rng, 80)).collect();
        // solo references
        let want: Vec<Vec<[f64; 2]>> = streams
            .iter()
            .map(|s| {
                let mut e = SparseMpGruDpd::new(sw.clone(), ActKind::Hard, 16);
                s.iter().map(|&v| e.process(v)).collect()
            })
            .collect();
        // batched over the sequential default
        let mut e = SparseMpGruDpd::new(sw.clone(), ActKind::Hard, 16);
        let mut states: Vec<DpdState> = (0..3)
            .map(|_| DpdState::DeltaI32(SparseMpGruDpd::<ScalarKernel>::fresh_state(&sw)))
            .collect();
        let mut lanes: Vec<DpdLane> = streams
            .iter_mut()
            .zip(states.iter_mut())
            .map(|(iq, state)| DpdLane { iq, state })
            .collect();
        e.process_lanes(&mut lanes).unwrap();
        for (got, want) in streams.iter().zip(&want) {
            assert_eq!(got, want, "batched lane diverged from solo");
        }
    }

    #[test]
    fn batch_fingerprint_separates_theta_and_mask() {
        let qw = QGruWeights::synthetic(2, QSpec::Q12);
        let fp = |rho: u8, theta: u32| {
            SparseMpGruDpd::new(qw.to_sparse(rho), ActKind::Hard, theta)
                .batch_fingerprint()
                .unwrap()
        };
        assert_eq!(fp(0, 0), fp(0, 0));
        assert_ne!(fp(0, 0), fp(0, 32), "theta is part of the identity");
        assert_ne!(fp(0, 0), fp(50, 0), "the mask is part of the identity");
        // and the sparse family never collides with the dense engine's
        let dense = QGruDpd::new(qw.clone(), ActKind::Hard);
        assert_ne!(fp(0, 0), dense.batch_fingerprint().unwrap());
    }
}
