"""L2 — the GRU-RNN DPD model (paper §II, Eq. 1-6).

The model is tiny by design: 4 input features, ``hidden`` GRU units
(10 in the paper → 502 parameters), a 2-output FC head. This module
owns parameter initialization/serialization and the user-facing forward
functions; the arithmetic lives in ``kernels`` (Pallas) and
``kernels.ref`` (oracles).
"""

from __future__ import annotations

import json
import math
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import gru_cell, ref
from .kernels.quant import QSpec

Params = Dict[str, jnp.ndarray]

__all__ = [
    "ModelConfig",
    "init_params",
    "forward",
    "forward_pallas",
    "forward_int",
    "params_to_jsonable",
    "params_from_jsonable",
    "save_params",
    "load_params",
]

PARAM_KEYS = ("w_ih", "b_ih", "w_hh", "b_hh", "w_fc", "b_fc")


class ModelConfig:
    """Model hyper-parameters (paper defaults)."""

    def __init__(self, hidden: int = 10, features: int = ref.INPUT_FEATURES):
        self.hidden = hidden
        self.features = features

    @property
    def n_params(self) -> int:
        return ref.param_count(self.hidden)

    def shapes(self) -> Dict[str, tuple]:
        h, f = self.hidden, self.features
        return {
            "w_ih": (3 * h, f),
            "b_ih": (3 * h,),
            "w_hh": (3 * h, h),
            "b_hh": (3 * h,),
            "w_fc": (2, h),
            "b_fc": (2,),
        }


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    """PyTorch-style GRU init: U(-1/sqrt(H), 1/sqrt(H)) on every tensor."""
    bound = 1.0 / math.sqrt(cfg.hidden)
    params = {}
    for name, shape in cfg.shapes().items():
        key, sub = jax.random.split(key)
        params[name] = jax.random.uniform(sub, shape, jnp.float32, -bound, bound)
    return params


def forward(params: Params, iq: jnp.ndarray, spec: QSpec | None = None, act: str = "hard") -> jnp.ndarray:
    """Reference (scan-based) forward — differentiable, used for QAT."""
    return ref.float_forward(params, iq, spec=spec, act=act)


def forward_pallas(params: Params, iq: jnp.ndarray, spec: QSpec | None = None, act: str = "hard") -> jnp.ndarray:
    """Pallas-kernel forward (the hot-spot path that gets AOT-lowered)."""
    squeeze = iq.ndim == 2
    if squeeze:
        iq = iq[None]
    out = gru_cell.gru_dpd_pallas(params, iq, spec=spec, act=act)
    return out[0] if squeeze else out


def forward_int(iparams: Params, iq_codes: jnp.ndarray, spec: QSpec, act: str = "hard") -> jnp.ndarray:
    """Integer Pallas forward on Q2.f codes (bit-exact with the chip)."""
    squeeze = iq_codes.ndim == 2
    if squeeze:
        iq_codes = iq_codes[None]
    out = gru_cell.gru_dpd_pallas_int(iparams, iq_codes, spec, act=act)
    return out[0] if squeeze else out


# ---------------------------------------------------------------------------
# Serialization (shared JSON schema with rust/src/dpd/weights.rs)
# ---------------------------------------------------------------------------


def params_to_jsonable(params: Params) -> dict:
    out = {}
    for k in PARAM_KEYS:
        v = np.asarray(params[k])
        out[k] = {"shape": list(v.shape), "data": v.reshape(-1).tolist()}
    return out


def params_from_jsonable(obj: dict, dtype=jnp.float32) -> Params:
    params = {}
    for k in PARAM_KEYS:
        entry = obj[k]
        params[k] = jnp.asarray(np.asarray(entry["data"], dtype=np.float64).reshape(entry["shape"]), dtype)
    return params


def save_params(path: str, params: Params, meta: dict | None = None) -> None:
    payload = {"meta": meta or {}, "params": params_to_jsonable(params)}
    with open(path, "w") as fh:
        json.dump(payload, fh)


def load_params(path: str, dtype=jnp.float32) -> tuple[Params, dict]:
    with open(path) as fh:
        payload = json.load(fh)
    return params_from_jsonable(payload["params"], dtype), payload.get("meta", {})
