//! The long-lived DPD runtime service: a persistent worker pool that
//! streaming sessions attach to.
//!
//! The silicon this repo reproduces runs *continuously* — 250 MSps of
//! I/Q flows through one resident GRU engine indefinitely — so the
//! runtime surface is shaped the same way: [`DpdService::start`]
//! spawns N worker threads once, and [`DpdService::open_session`]
//! pins a [`StreamSession`](super::StreamSession) to the least-loaded
//! worker. Each worker owns its engines (built *in-thread* through
//! [`EngineFactory`], preserving the constraint that the PJRT client
//! behind the `Hlo` backend is not `Send`), and GRU hidden state
//! persists for as long as the session lives — across every `push`.
//!
//! ```text
//!   DpdService::start(cfg)                 worker 0   worker 1  ...
//!        │  resolve manifest once             │          │
//!        │  spawn worker pool ───────────────▶│          │
//!   open_session(cfg) ── Cmd::Open ──────────▶│ build engine (in-thread)
//!        │◀── ack (name, frame len) ──────────│          │
//!   session.push(iq) ── Cmd::Frame ──────────▶│ process  │
//!        │◀── OutMsg::Frame ──────────────────│          │
//!   session.finish() ── Cmd::Finish ─────────▶│ drop engine
//!        │◀── OutMsg::Finished ───────────────│          │
//! ```
//!
//! Channels are *bounded* in both directions, so a slow engine
//! backpressures `push` and a slow consumer backpressures its own
//! session (its in-flight cap stops new frames). The worker itself
//! can never block placing output — each session caps its unabsorbed
//! frames below its output queue's capacity (see the session module
//! docs) — so one stalled session cannot stall its worker peers, and
//! the pool is deadlock-free even when one thread multiplexes many
//! sessions on one worker.
//!
//! Worker errors are *propagated*, never swallowed: an engine failure
//! is carried to the session as [`OutMsg::Err`] and surfaces from
//! `push`/`drain`/`finish`; the worker itself survives and keeps
//! serving its other sessions.
//!
//! With `ServiceConfig::batch > 1` the worker runs a **coalescing
//! scheduler**: after taking one command it opportunistically drains
//! whatever else is already queued (never waiting — coalescing adds no
//! latency), and gathers runs of `Frame` commands from *distinct*
//! sessions whose engines share a batch class (same kind + identical
//! weights, attested by a content fingerprint) into a single
//! [`DpdEngine::run_batch`] call. Per-session GRU state rides along as
//! a [`DpdState`] lane snapshot — for delta sessions
//! (`delta:θ` specs) that snapshot carries the *full* delta
//! state (propagated vectors + raw accumulators), and the threshold θ
//! is part of the batch class, so sessions at different θ never
//! coalesce. Per-session command order is preserved (a second frame
//! for a session already in the group, or any control command,
//! flushes the group first), and a failed batch fails *every* session
//! in it with the same sticky error. See DESIGN.md §Coalescing batch
//! scheduler.
//!
//! The service also hosts one background **adapt worker**
//! ([`super::adapt`]): adaptive sessions stream PA feedback to it, an
//! ILA trainer adapts their float twin in-thread, and every refresh
//! interval it re-quantizes fresh integer weights and hot-swaps the
//! session's engine through [`Cmd::Swap`] — atomic at a frame
//! boundary, with the new engine built in the worker thread like any
//! `Open`. See DESIGN.md §Closed-loop adaptation.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::adapt::{
    adapt_worker_loop, rebuild_for_kind, AdaptCmd, AdaptStats, SessionAdaptConfig,
};
use super::framer::Frame;
use super::session::{AdaptLink, SessionConfig, StreamSession};
use crate::dpd::adapt::AdaptTrainer;
use crate::dpd::{DpdLane, DpdState, GruWeights};
use crate::fixed::kernel::SimdPolicy;
use crate::fixed::QSpec;
use crate::runtime::{DpdEngine, EngineFactory, Manifest};

/// Configuration of the worker pool.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// worker threads (each owns its resident engines)
    pub workers: usize,
    /// bounded-channel depth: frames in flight per worker command
    /// queue and per session output queue
    pub queue_depth: usize,
    /// default framer length for sessions on streaming engines (frame
    /// engines override with their compiled shape)
    pub frame_len: usize,
    /// max sessions coalesced into one batched engine call per worker
    /// dispatch (1 = no coalescing, the pre-batching behavior). Only
    /// sessions whose engines share a batch class — same kind and
    /// identical weights — and that did not opt out
    /// ([`SessionConfig::coalesce`]) are ever grouped.
    pub batch: usize,
    /// artifact tree (None = discover); resolved once at `start`,
    /// shared by every session
    pub artifacts: Option<PathBuf>,
    /// kernel policy for `*Simd` engine kinds opened on this service:
    /// [`SimdPolicy::Auto`] honors host detection and the `DPD_SIMD`
    /// env override; [`SimdPolicy::Off`] forces the scalar kernel.
    /// Either way the engines are bit-identical (the kernel seam's
    /// contract) and coalescing classes do not depend on the choice.
    pub simd: SimdPolicy,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 4,
            queue_depth: 4,
            frame_len: 2048,
            batch: 1,
            artifacts: None,
            simd: SimdPolicy::default(),
        }
    }
}

/// How a worker constructs a session's engine, on its own thread.
pub(crate) type EngineBuild = Box<dyn FnOnce() -> Result<Box<dyn DpdEngine>> + Send>;

/// Open acknowledgement: what the worker learned building the engine.
pub(crate) struct OpenAck {
    pub name: &'static str,
    pub frame_len: Option<usize>,
}

/// Commands a session (or the service) sends to its worker.
pub(crate) enum Cmd {
    Open {
        id: u64,
        build: EngineBuild,
        /// whether this session may be coalesced into batched calls
        coalesce: bool,
        out: SyncSender<OutMsg>,
        reply: SyncSender<Result<OpenAck>>,
    },
    Frame {
        id: u64,
        frame: Frame,
        t0: Instant,
    },
    Reset {
        id: u64,
    },
    /// Orderly close: worker drops the engine and confirms with
    /// [`OutMsg::Finished`] after all queued frames are processed.
    Finish {
        id: u64,
    },
    /// Abandoned session (dropped without `finish`): drop the engine,
    /// no confirmation.
    Close {
        id: u64,
    },
    /// Hot-swap the session's engine (the adapt worker's refresh
    /// path). Atomic at a frame boundary by construction: commands are
    /// serialized, a coalescing group in progress is flushed first,
    /// and the replacement is built in-thread (like `Open`) and starts
    /// from reset state. A failed build poisons the session.
    Swap {
        id: u64,
        build: EngineBuild,
    },
}

/// What a worker sends back on a session's output channel.
pub(crate) enum OutMsg {
    Frame { frame: Frame, t0: Instant, busy: Duration },
    /// The engine failed; the worker dropped the session and stays up.
    Err(anyhow::Error),
    Finished,
}

struct Active {
    engine: Box<dyn DpdEngine>,
    out: SyncSender<OutMsg>,
    /// coalescing identity of this session's engine; `None` = never
    /// grouped (engine opted out, or the session asked for exclusivity)
    batch_class: Option<u64>,
    /// whether this session opted into coalescing (kept so an engine
    /// hot-swap can recompute `batch_class` for the new generation)
    coalesce: bool,
}

/// One frame waiting in the scheduler's current coalescing group.
type Pending = (u64, Frame, Instant);

/// Process one frame alone on its session's engine (the batch-of-one
/// path, identical to the pre-batching worker).
fn run_solo(sessions: &mut HashMap<u64, Active>, id: u64, mut frame: Frame, t0: Instant) {
    // unknown id: the session already failed or closed — frames still
    // in the queue are dropped deliberately
    let Some(a) = sessions.get_mut(&id) else { return };
    let t = Instant::now();
    match a.engine.process_frame(&mut frame.data) {
        Ok(()) => {
            let busy = t.elapsed();
            if a.out.send(OutMsg::Frame { frame, t0, busy }).is_err() {
                // receiver gone: session dropped mid-flight
                sessions.remove(&id);
            }
        }
        Err(e) => {
            // propagate, don't swallow: the error reaches the caller;
            // this worker keeps serving peers
            let a = sessions.remove(&id).expect("just found");
            a.out.send(OutMsg::Err(e.context("DPD engine failed"))).ok();
        }
    }
}

/// Flush the current coalescing group: one `run_batch` call over every
/// member's frame, each lane carrying that session's recurrent state.
/// A failed batch poisons every member session (same sticky error);
/// the worker survives either way.
fn run_group(sessions: &mut HashMap<u64, Active>, group: &mut Vec<Pending>) {
    let mut members: Vec<Pending> = std::mem::take(group);
    members.retain(|(id, ..)| sessions.contains_key(id));
    if members.len() < 2 {
        if let Some((id, frame, t0)) = members.pop() {
            run_solo(sessions, id, frame, t0);
        }
        return;
    }
    // snapshot each member's recurrent state into its lane
    let mut states: Vec<DpdState> =
        members.iter().map(|(id, ..)| sessions[id].engine.save_state()).collect();
    let runner_id = members[0].0;
    let t = Instant::now();
    let result = {
        let runner = sessions.get_mut(&runner_id).expect("retained above");
        let mut lanes: Vec<DpdLane> = members
            .iter_mut()
            .zip(states.iter_mut())
            .map(|((_, frame, _), st)| DpdLane { iq: frame.data.as_mut_slice(), state: st })
            .collect();
        runner.engine.run_batch(&mut lanes)
    };
    match result {
        Ok(()) => {
            // amortized busy attribution: the kernel ran once for all
            // members, each is billed an equal share
            let busy = t.elapsed() / members.len() as u32;
            for ((id, frame, t0), st) in members.into_iter().zip(&states) {
                let Some(a) = sessions.get_mut(&id) else { continue };
                if let Err(e) = a.engine.load_state(st) {
                    let a = sessions.remove(&id).expect("just found");
                    a.out.send(OutMsg::Err(e.context("restoring batched lane state"))).ok();
                    continue;
                }
                if a.out.send(OutMsg::Frame { frame, t0, busy }).is_err() {
                    sessions.remove(&id);
                }
            }
        }
        Err(e) => {
            // whole-batch failure: every coalesced session observes the
            // same sticky error (anyhow::Error is not Clone, so the
            // formatted chain is replicated per member)
            let msg = format!("{:#}", e.context("DPD engine failed (batched)"));
            for (id, ..) in members {
                if let Some(a) = sessions.remove(&id) {
                    a.out.send(OutMsg::Err(anyhow!("{msg}"))).ok();
                }
            }
        }
    }
}

/// The worker event loop: owns every engine of the sessions pinned to
/// it, processes commands in per-session FIFO order (distinct sessions'
/// frames may be reordered *within* one coalesced group, which is
/// unobservable), exits when the service and all its sessions have
/// dropped their senders. `max_batch > 1` enables the coalescing
/// scheduler (module docs).
fn worker_loop(rx: Receiver<Cmd>, max_batch: usize) {
    let mut sessions: HashMap<u64, Active> = HashMap::new();
    let mut gathered: Vec<Cmd> = Vec::new();
    // bound the opportunistic drain so one dispatch cannot starve the
    // pool of fairness (frames beyond the window stay queued)
    let gather_window = 2 * max_batch;
    while let Ok(first) = rx.recv() {
        gathered.push(first);
        if max_batch > 1 {
            // opportunistic, non-blocking: coalescing never waits for
            // traffic, so an idle stream sees zero added latency
            while gathered.len() < gather_window {
                match rx.try_recv() {
                    Ok(c) => gathered.push(c),
                    Err(_) => break,
                }
            }
        }
        let mut group: Vec<Pending> = Vec::new();
        let mut group_class = 0u64;
        for cmd in gathered.drain(..) {
            match cmd {
                Cmd::Open { id, build, coalesce, out, reply } => {
                    run_group(&mut sessions, &mut group);
                    match build() {
                        Ok(mut engine) => {
                            engine.reset();
                            let ack =
                                OpenAck { name: engine.name(), frame_len: engine.frame_len() };
                            let batch_class = if coalesce && max_batch > 1 {
                                engine.batch_class()
                            } else {
                                None
                            };
                            // only keep the session if the opener is
                            // still there
                            if reply.send(Ok(ack)).is_ok() {
                                sessions
                                    .insert(id, Active { engine, out, batch_class, coalesce });
                            }
                        }
                        Err(e) => {
                            reply.send(Err(e.context("building session engine"))).ok();
                        }
                    }
                }
                Cmd::Frame { id, frame, t0 } => {
                    let class = match sessions.get(&id) {
                        Some(a) => a.batch_class,
                        None => continue, // dropped deliberately (dead session)
                    };
                    match class {
                        Some(class) => {
                            // a second frame for a session already in
                            // the group is a *sequential* dependency —
                            // flush first; ditto a class change
                            let conflicts = !group.is_empty()
                                && (class != group_class
                                    || group.iter().any(|(gid, ..)| *gid == id));
                            if conflicts {
                                run_group(&mut sessions, &mut group);
                            }
                            group_class = class;
                            group.push((id, frame, t0));
                            if group.len() >= max_batch {
                                run_group(&mut sessions, &mut group);
                            }
                        }
                        None => {
                            // unbatchable session: keep global arrival
                            // order by flushing the group first
                            run_group(&mut sessions, &mut group);
                            run_solo(&mut sessions, id, frame, t0);
                        }
                    }
                }
                Cmd::Reset { id } => {
                    run_group(&mut sessions, &mut group);
                    if let Some(a) = sessions.get_mut(&id) {
                        a.engine.reset();
                    }
                }
                Cmd::Finish { id } => {
                    run_group(&mut sessions, &mut group);
                    if let Some(a) = sessions.remove(&id) {
                        a.out.send(OutMsg::Finished).ok();
                    }
                }
                Cmd::Close { id } => {
                    run_group(&mut sessions, &mut group);
                    sessions.remove(&id);
                }
                Cmd::Swap { id, build } => {
                    // the frame-boundary hot-swap: any coalescing group
                    // is flushed first, so frames queued before this
                    // command ran on the old engine and frames after it
                    // run on the new one — nothing straddles the swap
                    run_group(&mut sessions, &mut group);
                    let Some(a) = sessions.get_mut(&id) else { continue };
                    match build() {
                        Ok(mut engine) => {
                            engine.reset();
                            a.batch_class = if a.coalesce && max_batch > 1 {
                                engine.batch_class()
                            } else {
                                None
                            };
                            a.engine = engine;
                        }
                        Err(e) => {
                            let a = sessions.remove(&id).expect("just found");
                            a.out
                                .send(OutMsg::Err(e.context("hot-swapping session engine")))
                                .ok();
                        }
                    }
                }
            }
        }
        run_group(&mut sessions, &mut group);
    }
}

struct Worker {
    cmd: SyncSender<Cmd>,
    /// open sessions pinned here (placement + `Drop` bookkeeping)
    load: Arc<AtomicUsize>,
    handle: JoinHandle<()>,
}

/// The long-lived DPD service: a persistent pool of engine workers
/// that [`StreamSession`]s attach to. See the module docs for the
/// lifecycle; [`Coordinator`](super::Coordinator) remains as a thin
/// one-shot compatibility wrapper over this.
pub struct DpdService {
    cfg: ServiceConfig,
    /// resolved once at start; `None` when no artifact tree exists
    /// (custom-engine sessions still work, kind-based ones error)
    manifest: Option<Arc<Manifest>>,
    workers: Vec<Worker>,
    /// the closed-loop adaptation worker (one per service; idle until
    /// an adaptive session registers)
    adapt_tx: SyncSender<AdaptCmd>,
    adapt_handle: JoinHandle<()>,
    next_id: AtomicU64,
}

impl DpdService {
    /// Spawn the worker pool and resolve the artifact manifest once.
    ///
    /// A missing artifact tree is *not* fatal here: sessions opened
    /// with [`DpdService::open_session_with`] bring their own engines
    /// and never need it; [`DpdService::open_session`] reports the
    /// discovery error at open time instead.
    pub fn start(cfg: ServiceConfig) -> Result<DpdService> {
        anyhow::ensure!(cfg.workers > 0, "ServiceConfig.workers must be > 0");
        anyhow::ensure!(cfg.queue_depth > 0, "ServiceConfig.queue_depth must be > 0");
        anyhow::ensure!(cfg.frame_len > 0, "ServiceConfig.frame_len must be > 0");
        anyhow::ensure!(cfg.batch > 0, "ServiceConfig.batch must be > 0");
        let manifest = Manifest::discover(cfg.artifacts.as_deref()).ok().map(Arc::new);
        // coalescing headroom: a full group can only gather if the
        // worker command channel can hold `batch` queued frames, so the
        // channel is sized to max(queue_depth, batch) here once instead
        // of making every caller remember the rule (per-session output
        // queues keep their own depth — the in-flight-cap invariant is
        // per session and unaffected by a larger command channel)
        let channel_depth = cfg.queue_depth.max(cfg.batch);
        let workers = (0..cfg.workers)
            .map(|i| {
                let (cmd, rx) = sync_channel(channel_depth);
                let batch = cfg.batch;
                let handle = std::thread::Builder::new()
                    .name(format!("dpd-worker-{i}"))
                    .spawn(move || worker_loop(rx, batch))
                    .map_err(|e| anyhow!("spawning worker {i}: {e}"))?;
                Ok(Worker { cmd, load: Arc::new(AtomicUsize::new(0)), handle })
            })
            .collect::<Result<Vec<_>>>()?;
        // the adaptation worker: one per service, blocked on its
        // channel until a session registers; bounded so a slow trainer
        // backpressures `adapt_feedback`, never the data path
        let (adapt_tx, adapt_rx) = sync_channel(8);
        let adapt_handle = std::thread::Builder::new()
            .name("dpd-adapt".to_string())
            .spawn(move || adapt_worker_loop(adapt_rx))
            .map_err(|e| anyhow!("spawning the adapt worker: {e}"))?;
        Ok(DpdService {
            cfg,
            manifest,
            workers,
            adapt_tx,
            adapt_handle,
            next_id: AtomicU64::new(0),
        })
    }

    /// Pool size.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// The manifest shared by every kind-based session, if an
    /// artifact tree was found at start.
    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_deref()
    }

    /// Open sessions per worker right now (snapshot).
    pub fn loads(&self) -> Vec<usize> {
        self.workers.iter().map(|w| w.load.load(Ordering::SeqCst)).collect()
    }

    /// Open a session whose engine is built by kind against the
    /// shared manifest (resolved once for the whole service). The
    /// engine kind is per-session, so heterogeneous sessions — e.g. a
    /// `Fixed` production session plus a `CycleSim` shadow session
    /// auditing it — share one pool.
    ///
    /// With [`SessionConfig::adapt`] set, the session opens in
    /// closed-loop mode: the float twin is loaded from the manifest's
    /// `weights_float`, the initial engine is built from it through
    /// the re-quantization bridge, and PA feedback pushed via
    /// [`StreamSession::adapt_feedback`] drives periodic engine
    /// hot-swaps (see [`open_adaptive_session`]).
    ///
    /// [`open_adaptive_session`]: DpdService::open_adaptive_session
    pub fn open_session(&self, cfg: SessionConfig) -> Result<StreamSession> {
        let manifest = match &self.manifest {
            Some(m) => Arc::clone(m),
            // no tree at start: retry so the caller gets the real
            // discovery error (and late-appearing trees still work)
            None => Arc::new(
                Manifest::discover(self.cfg.artifacts.as_deref())
                    .context("DpdService found no artifact tree for a kind-based session")?,
            ),
        };
        if let Some(acfg) = cfg.adapt {
            let w0 = GruWeights::load(&manifest.weights_float)
                .context("loading the float twin for an adaptive session")?;
            // inherit the artifact tree's integer format unless the
            // caller pinned one: adaptive and frozen sessions on the
            // same service must deploy the same Q-format
            let acfg =
                SessionAdaptConfig { bits: acfg.bits.or(Some(manifest.qspec_bits)), ..acfg };
            return self.open_adaptive_session(SessionConfig { adapt: Some(acfg), ..cfg }, w0);
        }
        let factory =
            EngineFactory::from_manifest(cfg.engine, manifest)?.with_simd_policy(self.cfg.simd);
        self.open_session_with(cfg, move || factory.build())
    }

    /// Open a closed-loop adaptive session from an explicit float twin
    /// (no artifact tree needed — the hermetic path the adaptation
    /// tests and benches use). `cfg.adapt` must be set; `cfg.engine`
    /// must be a refreshable kind (`NativeF64`, `Fixed` or
    /// `DeltaFixed`). The initial engine is generation 0 of the
    /// re-quantization bridge applied to `w0`, so the deployed engine
    /// and the trainer twin start from the same function.
    pub fn open_adaptive_session(
        &self,
        cfg: SessionConfig,
        w0: GruWeights,
    ) -> Result<StreamSession> {
        let acfg = cfg
            .adapt
            .ok_or_else(|| anyhow!("open_adaptive_session needs SessionConfig.adapt"))?;
        anyhow::ensure!(acfg.refresh_interval > 0, "adapt.refresh_interval must be > 0");
        anyhow::ensure!(
            acfg.meter_nfft >= 2 && acfg.meter_nfft.is_power_of_two(),
            "adapt.meter_nfft must be a power of two >= 2 (the Welch FFT size)"
        );
        anyhow::ensure!(
            acfg.meter_window >= acfg.meter_nfft,
            "adapt.meter_window must hold at least one Welch segment"
        );
        let spec = QSpec::new(acfg.bits.unwrap_or(12))?;
        let rebuild = rebuild_for_kind(cfg.engine, spec, self.cfg.simd)?;
        let trainer = AdaptTrainer::new(w0.clone(), acfg.trainer)?;
        let initial = rebuild(&w0);
        // strip `adapt` before delegating: the inner opener would
        // reject it (custom engines can't be refreshed without w0)
        let mut session =
            self.open_session_with(SessionConfig { adapt: None, ..cfg }, initial)?;
        let shared = Arc::new(Mutex::new(AdaptStats::default()));
        self.adapt_tx
            .send(AdaptCmd::Open {
                id: session.id(),
                trainer: Box::new(trainer),
                cfg: acfg,
                rebuild,
                worker_cmd: session.worker_cmd(),
                shared: Arc::clone(&shared),
            })
            .map_err(|_| anyhow!("the adapt worker terminated"))?;
        session.attach_adapt(AdaptLink { tx: self.adapt_tx.clone(), shared });
        Ok(session)
    }

    /// Open a session around a caller-supplied engine constructor,
    /// run on the worker thread that will own the engine. This is the
    /// primitive `open_session` builds on; it needs no artifact tree,
    /// which is what lets session tests (and the hermetic benches)
    /// run on synthetic weights.
    pub fn open_session_with<F>(&self, cfg: SessionConfig, build: F) -> Result<StreamSession>
    where
        F: FnOnce() -> Result<Box<dyn DpdEngine>> + Send + 'static,
    {
        anyhow::ensure!(
            cfg.adapt.is_none(),
            "adaptive sessions need a float twin — use open_session (manifest) or \
             open_adaptive_session (explicit weights), not open_session_with"
        );
        let (wi, worker) = self
            .workers
            .iter()
            .enumerate()
            .min_by_key(|(_, w)| w.load.load(Ordering::SeqCst))
            .expect("pool has at least one worker");
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let queue_depth = cfg.queue_depth.unwrap_or(self.cfg.queue_depth);
        anyhow::ensure!(queue_depth > 0, "SessionConfig.queue_depth must be > 0");
        anyhow::ensure!(cfg.frame_len != Some(0), "SessionConfig.frame_len must be > 0");
        // reserve the slot before the (possibly slow) engine build so
        // concurrent opens spread across the pool
        worker.load.fetch_add(1, Ordering::SeqCst);
        let open = (|| -> Result<(OpenAck, Receiver<OutMsg>)> {
            // +1 slot: frames are capped at `queue_depth` by the
            // session, and the spare slot guarantees the terminal
            // `Finished`/`Err` message also never blocks the worker
            let (out_tx, out_rx) = sync_channel(queue_depth + 1);
            let (reply_tx, reply_rx) = sync_channel(1);
            worker
                .cmd
                .send(Cmd::Open {
                    id,
                    build: Box::new(build),
                    coalesce: cfg.coalesce,
                    out: out_tx,
                    reply: reply_tx,
                })
                .map_err(|_| anyhow!("worker {wi} terminated"))?;
            let ack = reply_rx
                .recv()
                .map_err(|_| anyhow!("worker {wi} died while opening the session"))?
                .with_context(|| format!("opening session {id} on worker {wi}"))?;
            Ok((ack, out_rx))
        })();
        let (ack, out_rx) = match open {
            Ok(v) => v,
            Err(e) => {
                worker.load.fetch_sub(1, Ordering::SeqCst);
                return Err(e);
            }
        };
        let frame_len =
            ack.frame_len.unwrap_or_else(|| cfg.frame_len.unwrap_or(self.cfg.frame_len));
        Ok(StreamSession::attach(
            id,
            ack.name,
            frame_len,
            queue_depth,
            worker.cmd.clone(),
            out_rx,
            Arc::clone(&worker.load),
        ))
    }

    /// Orderly teardown: joins every worker. Finish or drop all
    /// sessions first — workers only exit once the last session's
    /// command handle is gone, so this blocks while sessions live.
    /// (Plain `drop` never blocks: workers then wind down on their
    /// own when the last handle disappears.)
    ///
    /// Join order matters: the adapt worker holds `worker_cmd` clones
    /// for every adaptive session it ever swapped weights into, so it
    /// must drain and exit *first* — otherwise an engine worker would
    /// never see its command channel close and the join below it would
    /// deadlock. Engine workers are then joined in pool order.
    pub fn shutdown(self) -> Result<()> {
        drop(self.adapt_tx);
        self.adapt_handle.join().map_err(|_| anyhow!("the adapt worker panicked"))?;
        for w in self.workers {
            let Worker { cmd, handle, .. } = w;
            drop(cmd);
            handle.join().map_err(|_| anyhow!("a DPD worker panicked"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_are_sane() {
        let cfg = ServiceConfig::default();
        assert!(cfg.workers > 0 && cfg.queue_depth > 0 && cfg.frame_len > 0);
        assert!(cfg.artifacts.is_none());
    }

    #[test]
    fn start_validates_config() {
        assert!(DpdService::start(ServiceConfig { workers: 0, ..Default::default() }).is_err());
        assert!(DpdService::start(ServiceConfig { queue_depth: 0, ..Default::default() }).is_err());
        assert!(DpdService::start(ServiceConfig { frame_len: 0, ..Default::default() }).is_err());
        assert!(DpdService::start(ServiceConfig { batch: 0, ..Default::default() }).is_err());
    }

    #[test]
    fn config_default_is_unbatched() {
        // batch = 1 must reproduce the pre-batching scheduler exactly
        assert_eq!(ServiceConfig::default().batch, 1);
    }

    #[test]
    fn start_and_shutdown_without_sessions() {
        // pool lifecycle needs no artifact tree at all
        let svc = DpdService::start(ServiceConfig { workers: 3, ..Default::default() }).unwrap();
        assert_eq!(svc.workers(), 3);
        assert_eq!(svc.loads(), vec![0, 0, 0]);
        svc.shutdown().unwrap();
    }

    #[test]
    fn service_is_sync_and_sessions_are_send() {
        fn assert_sync<T: Sync>() {}
        fn assert_send<T: Send>() {}
        // the compat wrapper and the mMIMO example drive one service
        // from many threads: &DpdService crosses threads, sessions move
        assert_sync::<DpdService>();
        assert_send::<StreamSession>();
    }
}
