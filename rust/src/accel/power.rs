//! 22FDX energy/power model (Fig. 5's 195 mW at 2 GHz / 0.9 V).
//!
//! Per-event energy constants for GF 22FDX-class FD-SOI at the nominal
//! 0.9 V corner, drawn from the usual energy-per-op surveys (Horowitz
//! ISSCC'14 scaled 45->22 nm, and the 22 nm accelerator literature the
//! paper cites, e.g. BrainTTA [28]):
//!
//! | event                    | energy  |
//! |--------------------------|---------|
//! | 12-bit MAC (mult+acc)    | 0.35 pJ |
//! | 12-bit ALU op            | 0.06 pJ |
//! | activation (PWL)         | 0.05 pJ |
//! | activation (LUT ROM read)| 0.25 pJ |
//! | weight-buffer read (12b) | 0.55 pJ |
//! | hidden-buffer access     | 0.15 pJ |
//! | pipeline regs+ctrl /cycle| 28 pJ ... no — see below |
//!
//! The non-datapath share (clock tree, pipeline registers, FSM,
//! I/O) is modelled as a per-cycle overhead `e_cycle_overhead`; at
//! II=8, 250 MSps that term carries the balance of the published
//! 195 mW after the countable events. This split (≈45% datapath+SRAM,
//! ≈50% clock/registers, ≈5% leakage) is typical of short-pipeline
//! 2 GHz designs, where the clock network dominates.
//!
//! Scaling: dynamic power ∝ f·(V/V0)²; leakage ∝ V. The model exposes
//! both knobs so benches can sweep operating points.

use super::engine::EngineStats;
use super::fsm;
use crate::dpd::qgru::ActKind;

/// Energy constants (picojoules) at the 0.9 V, 22FDX nominal corner.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    pub v_nom: f64,
    pub e_mac_pj: f64,
    pub e_alu_pj: f64,
    pub e_act_pwl_pj: f64,
    pub e_act_lut_pj: f64,
    pub e_wbuf_read_pj: f64,
    pub e_hbuf_access_pj: f64,
    /// clock tree + pipeline registers + FSM, per clock cycle
    pub e_cycle_overhead_pj: f64,
    /// static (leakage) power at v_nom, mW
    pub p_leak_mw: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            v_nom: 0.9,
            e_mac_pj: 0.35,
            e_alu_pj: 0.06,
            e_act_pwl_pj: 0.05,
            e_act_lut_pj: 0.25,
            e_wbuf_read_pj: 0.55,
            e_hbuf_access_pj: 0.15,
            e_cycle_overhead_pj: 35.5,
            p_leak_mw: 6.0,
        }
    }
}

/// A computed power figure with its breakdown (mW).
#[derive(Clone, Debug)]
pub struct PowerBreakdown {
    pub mac_mw: f64,
    pub alu_mw: f64,
    pub act_mw: f64,
    pub wbuf_mw: f64,
    pub hbuf_mw: f64,
    pub overhead_mw: f64,
    pub leak_mw: f64,
}

impl PowerBreakdown {
    pub fn total_mw(&self) -> f64 {
        self.mac_mw + self.alu_mw + self.act_mw + self.wbuf_mw + self.hbuf_mw
            + self.overhead_mw
            + self.leak_mw
    }
}

impl EnergyModel {
    /// Power at an operating point, from measured per-sample activity.
    ///
    /// `stats` supplies events per sample (divide by `stats.samples`);
    /// `fs_msps` the I/Q rate; `f_clk_ghz`/`v` the operating point;
    /// `act` selects the PWL vs LUT activation energy.
    pub fn power(
        &self,
        stats: &EngineStats,
        act: &ActKind,
        fs_msps: f64,
        f_clk_ghz: f64,
        v: f64,
    ) -> PowerBreakdown {
        let n = stats.samples.max(1) as f64;
        let fs = fs_msps * 1e6;
        let vscale = (v / self.v_nom) * (v / self.v_nom);
        // pJ * 1/s = 1e-12 W; report mW -> *1e-9
        let per_sample = |events: f64, e_pj: f64| -> f64 { events / n * e_pj * fs * 1e-9 * vscale };
        let e_act = match act {
            ActKind::Hard => self.e_act_pwl_pj,
            ActKind::Lut(_) => self.e_act_lut_pj,
        };
        let cycles_per_s = f_clk_ghz * 1e9;
        PowerBreakdown {
            mac_mw: per_sample(stats.macs as f64, self.e_mac_pj),
            alu_mw: per_sample(stats.alu_ops as f64, self.e_alu_pj),
            act_mw: per_sample(stats.act_ops as f64, e_act),
            wbuf_mw: per_sample(stats.weight_reads as f64, self.e_wbuf_read_pj),
            hbuf_mw: per_sample(
                (stats.hidden_reads + stats.hidden_writes) as f64,
                self.e_hbuf_access_pj,
            ),
            overhead_mw: self.e_cycle_overhead_pj * cycles_per_s * 1e-9 * vscale,
            leak_mw: self.p_leak_mw * v / self.v_nom,
        }
    }

    /// Nominal-point power (2 GHz, 0.9 V, 250 MSps) — the Fig. 5 number.
    pub fn nominal_power_mw(&self, stats: &EngineStats, act: &ActKind) -> f64 {
        self.power(stats, act, fsm::max_sample_rate_msps(2.0), 2.0, 0.9)
            .total_mw()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::act_unit::ActImpl;
    use crate::accel::engine::CycleAccurateEngine;
    use crate::accel::fsm::HwConfig;
    use crate::dpd::weights::QGruWeights;
    use crate::fixed::QSpec;
    use crate::util::Rng;

    fn stats() -> EngineStats {
        let spec = QSpec::Q12;
        let mut rng = Rng::new(5);
        let bound = (0.3 * spec.scale()) as i64;
        let mut gen =
            |n: usize| -> Vec<i32> { (0..n).map(|_| rng.int_in(-bound, bound) as i32).collect() };
        let w = QGruWeights {
            hidden: 10,
            features: 4,
            spec,
            w_ih: gen(120),
            b_ih: gen(30),
            w_hh: gen(300),
            b_hh: gen(30),
            w_fc: gen(20),
            b_fc: gen(2),
        };
        let mut sim = CycleAccurateEngine::new(&w, ActImpl::Hard, HwConfig::default());
        let x: Vec<[i32; 2]> = (0..256)
            .map(|_| [rng.int_in(-600, 600) as i32, rng.int_in(-600, 600) as i32])
            .collect();
        sim.run_codes(&x).unwrap();
        sim.stats().clone()
    }

    #[test]
    fn nominal_power_matches_paper_within_10pct() {
        let s = stats();
        let p = EnergyModel::default().nominal_power_mw(&s, &ActKind::Hard);
        let rel = (p - 195.0).abs() / 195.0;
        assert!(rel < 0.10, "nominal power {p:.1} mW vs paper 195 mW");
    }

    #[test]
    fn power_scales_linearly_with_fclk() {
        let s = stats();
        let m = EnergyModel::default();
        // datapath power follows fs; with fs tied to f_clk/8 the total
        // scales ~linearly in f_clk (minus leakage)
        let p2 = m.power(&s, &ActKind::Hard, 250.0, 2.0, 0.9).total_mw();
        let p1 = m.power(&s, &ActKind::Hard, 125.0, 1.0, 0.9).total_mw();
        let dynamic2 = p2 - m.p_leak_mw;
        let dynamic1 = p1 - m.p_leak_mw;
        assert!((dynamic2 / dynamic1 - 2.0).abs() < 0.05);
    }

    #[test]
    fn power_scales_quadratically_with_v() {
        let s = stats();
        let m = EnergyModel::default();
        let p_hi = m.power(&s, &ActKind::Hard, 250.0, 2.0, 0.9);
        let p_lo = m.power(&s, &ActKind::Hard, 250.0, 2.0, 0.45);
        // dynamic terms scale by (0.45/0.9)^2 = 0.25
        assert!((p_lo.mac_mw / p_hi.mac_mw - 0.25).abs() < 1e-9);
        assert!((p_lo.overhead_mw / p_hi.overhead_mw - 0.25).abs() < 1e-9);
    }

    #[test]
    fn lut_activation_costs_more() {
        let s = stats();
        let m = EnergyModel::default();
        let hard = m.nominal_power_mw(&s, &ActKind::Hard);
        let lut = m.nominal_power_mw(
            &s,
            &ActKind::Lut(crate::dpd::qgru::LutTables::default_for(QSpec::Q12)),
        );
        assert!(lut > hard);
    }

    #[test]
    fn breakdown_sums() {
        let s = stats();
        let b = EnergyModel::default().power(&s, &ActKind::Hard, 250.0, 2.0, 0.9);
        let sum = b.mac_mw + b.alu_mw + b.act_mw + b.wbuf_mw + b.hbuf_mw + b.overhead_mw + b.leak_mw;
        assert!((sum - b.total_mw()).abs() < 1e-12);
    }
}
