//! Cost model of the sparse + mixed-precision execution path — what
//! the measured activity of a [`SparseStats`] stream is worth in MACs
//! and energy on SparseDPD/MP-DPD-style hardware (arXiv:2506.16591,
//! arXiv:2404.15364).
//!
//! The functional engine (`dpd::sparse::SparseMpGruDpd`) *counts* the
//! gate MACs it actually executed (surviving CSC entries of fired
//! columns); this module *prices* those counts against the dense
//! uniform-Q2.10 datapath under one documented convention:
//!
//! * a pruned (or zero) weight never costs a MAC, a weight-buffer
//!   read, or an index fetch — it simply is not stored;
//! * a skipped delta column (θ>0) additionally saves every surviving
//!   entry of that column, exactly as in [`super::delta`];
//! * narrow multipliers scale: a `wb x ab`-bit MAC is priced at
//!   `(wb·ab)/(12·12)` of the 12-bit MAC energy (array multiplier
//!   energy grows with the product of operand widths), and a narrow
//!   weight read at `wb/12` of the 12-bit word read;
//! * CSC row indices are real hardware: every executed gate entry
//!   pays one index fetch (priced as a [`IDX_BITS`]-bit buffer read)
//!   and one index-decode ALU op;
//! * the FC head and biases stay dense (at the profile's FC width);
//! * the pipeline II is unchanged — like delta skipping, pruning
//!   gates datapath *activity* (clock-gated PE columns), so it shows
//!   up in energy and effective MAC throughput, not in latency.
//!
//! `benches/pareto.rs` sweeps (ρ, profile) through this model against
//! measured ACPR/EVM on the golden OFDM waveform and holds the
//! resulting Pareto front on the record (`BENCH_pareto.json`).

use super::engine::EngineStats;
use super::fsm;
use super::ops::{macs_per_sample, ModelDims};
use super::power::EnergyModel;
use crate::dpd::qgru::ActKind;
use crate::dpd::SparseStats;
use crate::fixed::QProfile;

/// Stored width of a CSC row index (u16 in `SparseQGruWeights`).
pub const IDX_BITS: u32 = 16;

/// Reference width the energy constants are calibrated at (Q2.10).
const REF_BITS: f64 = 12.0;

/// Prices measured sparse/mixed-precision activity against the dense
/// uniform-Q2.10 datapath.
#[derive(Clone, Copy, Debug)]
pub struct SparseCostModel {
    pub dims: ModelDims,
    pub profile: QProfile,
}

impl SparseCostModel {
    pub fn new(dims: ModelDims, profile: QProfile) -> SparseCostModel {
        SparseCostModel { dims, profile }
    }

    /// Dense MACs per sample of the uniform datapath (the reduction
    /// denominator — 440 at the paper's dimensions).
    pub fn dense_macs_per_sample(&self) -> f64 {
        macs_per_sample(self.dims) as f64
    }

    /// Measured MACs per sample on the sparse path: the executed gate
    /// entries plus the dense 2H FC head.
    pub fn sparse_macs_per_sample(&self, s: &SparseStats) -> f64 {
        let steps = s.steps.max(1) as f64;
        s.gate_macs as f64 / steps + 2.0 * self.dims.hidden as f64
    }

    /// Measured MAC-reduction factor (dense / sparse; 1.0 = no win).
    /// Counts MACs as events — width scaling is energy's business.
    pub fn mac_reduction(&self, s: &SparseStats) -> f64 {
        self.dense_macs_per_sample() / self.sparse_macs_per_sample(s)
    }

    /// The gate-tensor weight width the profile prices MACs at (wa
    /// profiles are weight-homogeneous; a hand-built heterogeneous
    /// profile is priced at its widest gate tensor, conservatively).
    fn gate_weight_bits(&self) -> f64 {
        self.profile.w_ih.bits.max(self.profile.w_hh.bits) as f64
    }

    /// Project the stream's activity into the shape the 22FDX energy
    /// model consumes, **width-normalized**: event counts are scaled
    /// to 12-bit equivalents so the model's 12-bit energy constants
    /// price the narrow ops (a W4 MAC counts as 4·12/144 = 1/3 of a
    /// MAC event, a W4 weight read as 1/3 of a word read).
    pub fn normalized_stats(&self, s: &SparseStats) -> EngineStats {
        let h = self.dims.hidden as u64;
        let f = self.dims.features as u64;
        let n = s.steps;
        let wb = self.gate_weight_bits();
        let wfc = self.profile.w_fc.bits as f64;
        let ab = self.profile.act.bits as f64;
        let gate_mac_scale = (wb * ab) / (REF_BITS * REF_BITS);
        let fc_mac_scale = (wfc * ab) / (REF_BITS * REF_BITS);
        let hb_scale = ab / REF_BITS;
        let fc_macs = (n * 2 * h) as f64;
        EngineStats {
            samples: n,
            cycles: n * fsm::II_CYCLES as u64,
            macs: (s.gate_macs as f64 * gate_mac_scale + fc_macs * fc_mac_scale).round()
                as u64,
            // dense gate/update ALU work (8 per hidden unit + 1 per
            // output + 4 preproc), the F + H delta compares, and one
            // index decode per executed gate entry
            alu_ops: n * (8 * h + 2 + 4) + n * (f + h) + s.gate_macs,
            act_ops: n * 3 * h,
            // surviving gate entries pay a wb-bit weight read and an
            // IDX_BITS index fetch; the FC head + biases stay dense at
            // the FC width; gate biases live in the persistent
            // accumulators (same convention as the delta model)
            weight_reads: (s.gate_macs as f64 * (wb + IDX_BITS as f64) / REF_BITS
                + (n * (2 * h + 2)) as f64 * wfc / REF_BITS)
                .round() as u64,
            // delta compares re-read the live vectors (H) + z.h (H) +
            // FC (2H) reads of the committed hidden state, all in the
            // activation width
            hidden_reads: ((n * 4 * h) as f64 * hb_scale).round() as u64,
            hidden_writes: (((n * h + s.hid_updates) as f64) * hb_scale).round() as u64,
        }
    }

    /// Nominal-point (2 GHz, 0.9 V, 250 MSps) power of the sparse
    /// stream under the energy model.
    pub fn projected_power_mw(&self, s: &SparseStats, em: &EnergyModel, act: &ActKind) -> f64 {
        em.nominal_power_mw(&self.normalized_stats(s), act)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::QSpec;

    /// A synthetic activity record: every column fires, `nnz_ratio` of
    /// the dense gate entries survive pruning.
    fn stats_at(steps: u64, nnz_ratio: f64) -> SparseStats {
        let d = ModelDims::default();
        let dense_gate = (3 * d.hidden * (d.features + d.hidden)) as u64;
        SparseStats {
            steps,
            in_updates: steps * d.features as u64,
            in_cols: steps * d.features as u64,
            hid_updates: steps * d.hidden as u64,
            hid_cols: steps * d.hidden as u64,
            gate_macs: (steps as f64 * dense_gate as f64 * nnz_ratio) as u64,
            dense_gate_macs: steps * dense_gate,
        }
    }

    #[test]
    fn dense_uniform_activity_reproduces_the_dense_cost() {
        let m = SparseCostModel::new(ModelDims::default(), QProfile::uniform(QSpec::Q12));
        let s = stats_at(100, 1.0);
        assert_eq!(m.sparse_macs_per_sample(&s), 440.0);
        assert!((m.mac_reduction(&s) - 1.0).abs() < 1e-12);
        let p = m.normalized_stats(&s);
        // width scale is 1 at the uniform Q12 profile — MACs unscaled
        assert_eq!(p.macs, 100 * 440);
        assert_eq!(p.samples, 100);
        assert_eq!(p.cycles_per_sample(), fsm::II_CYCLES as f64);
    }

    #[test]
    fn pruning_reduction_scales_with_surviving_entries() {
        let m = SparseCostModel::new(ModelDims::default(), QProfile::uniform(QSpec::Q12));
        // half the gate entries survive: 210 + 20 = 230 -> 1.91x
        let s = stats_at(1000, 0.5);
        assert!((m.sparse_macs_per_sample(&s) - 230.0).abs() < 1e-9);
        assert!((m.mac_reduction(&s) - 440.0 / 230.0).abs() < 1e-9);
        // full pruning leaves only the dense FC floor
        let s0 = stats_at(1000, 0.0);
        assert_eq!(m.sparse_macs_per_sample(&s0), 20.0);
        assert!(m.mac_reduction(&s0) > 20.0);
    }

    #[test]
    fn narrow_profiles_cut_projected_power_on_identical_activity() {
        let em = EnergyModel::default();
        let s = stats_at(500, 0.5);
        let d = ModelDims::default();
        let p12 = SparseCostModel::new(d, QProfile::uniform(QSpec::Q12))
            .projected_power_mw(&s, &em, &ActKind::Hard);
        let p8 = SparseCostModel::new(d, QProfile::wa(8, 12).unwrap())
            .projected_power_mw(&s, &em, &ActKind::Hard);
        let p4 = SparseCostModel::new(d, QProfile::wa(4, 12).unwrap())
            .projected_power_mw(&s, &em, &ActKind::Hard);
        assert!(p12 > p8 && p8 > p4, "{p12} / {p8} / {p4}");
        // the clock/overhead floor remains
        assert!(p4 > 50.0, "overhead floor vanished: {p4}");
    }

    #[test]
    fn measured_engine_activity_feeds_the_model() {
        // End to end: run the real sparse engine at rho=50%, price its
        // counters — the acceptance-style >=1.5x MAC reduction.
        use crate::dpd::qgru::ActKind;
        use crate::dpd::weights::QGruWeights;
        use crate::dpd::SparseMpGruDpd;
        use crate::util::Rng;
        let sw = QGruWeights::synthetic(7, QSpec::Q12).to_sparse(50);
        let mut dpd = SparseMpGruDpd::new(sw, ActKind::Hard, 0);
        let mut rng = Rng::new(11);
        let x: Vec<[i32; 2]> = (0..400)
            .map(|_| [(rng.gauss() * 200.0) as i32, (rng.gauss() * 200.0) as i32])
            .collect();
        dpd.run_codes(&x);
        let m = SparseCostModel::new(
            ModelDims::default(),
            QProfile::uniform(QSpec::Q12),
        );
        let red = m.mac_reduction(&dpd.stats());
        assert!(red >= 1.5, "rho=50% should cut MACs >=1.5x, got {red:.2}x");
        let p = m.normalized_stats(&dpd.stats());
        assert_eq!(p.samples, 400);
        assert!(p.macs < 400 * 440);
    }
}
