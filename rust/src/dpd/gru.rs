//! Float (f64) GRU-RNN DPD — the paper's model (Eq. 1-6 + the residual
//! output and conditioned features, see DESIGN.md §Hardware-Adaptation).
//! Reference implementation for accuracy comparisons; the quantized
//! twin is `qgru`.

use anyhow::{bail, Result};

use super::weights::GruWeights;
use super::{process_lanes_sequential, Dpd, DpdLane, DpdState};

/// Hardsigmoid, Eq. (7).
#[inline]
pub fn hardsigmoid(x: f64) -> f64 {
    (x * 0.25 + 0.5).clamp(0.0, 1.0)
}

/// Hardtanh, Eq. (8).
#[inline]
pub fn hardtanh(x: f64) -> f64 {
    x.clamp(-1.0, 1.0)
}

/// Streaming float GRU DPD engine.
pub struct GruDpd {
    w: GruWeights,
    h: Vec<f64>,
    /// scratch buffers to avoid per-sample allocation
    gi: Vec<f64>,
    gh: Vec<f64>,
    /// column-major weight copies: the per-sample matvecs become
    /// 3H-wide SIMD axpys over contiguous columns (§Perf)
    wt_ih: Vec<f64>,
    wt_hh: Vec<f64>,
}

impl GruDpd {
    pub fn new(w: GruWeights) -> GruDpd {
        let h = vec![0.0; w.hidden];
        let g = vec![0.0; 3 * w.hidden];
        let rows = 3 * w.hidden;
        let mut wt_ih = vec![0.0; w.features * rows];
        for r in 0..rows {
            for c in 0..w.features {
                wt_ih[c * rows + r] = w.w_ih[r * w.features + c];
            }
        }
        let mut wt_hh = vec![0.0; w.hidden * rows];
        for r in 0..rows {
            for c in 0..w.hidden {
                wt_hh[c * rows + r] = w.w_hh[r * w.hidden + c];
            }
        }
        GruDpd { w, h, gi: g.clone(), gh: g, wt_ih, wt_hh }
    }

    pub fn weights(&self) -> &GruWeights {
        &self.w
    }

    /// Eq. (1) + conditioning: [i, q, 4|x|^2, (4|x|^2)^2].
    #[inline]
    pub fn features(iq: [f64; 2]) -> [f64; 4] {
        let p = 4.0 * (iq[0] * iq[0] + iq[1] * iq[1]);
        [iq[0], iq[1], p, p * p]
    }

    /// Structure-of-arrays batched execution over independent lanes
    /// sharing these weights. Each lane's f64 operation chain is
    /// exactly the scalar `process` one (same ops, same order — rustc
    /// does not re-associate or fuse floats), so the batched path is
    /// bit-identical to running every lane alone; the batch dimension
    /// only turns the axpy inner loops into wide contiguous sweeps.
    fn process_lanes_soa(&mut self, lanes: &mut [DpdLane<'_>]) -> Result<()> {
        let hd = self.w.hidden;
        for (b, lane) in lanes.iter().enumerate() {
            match &*lane.state {
                DpdState::F64(h) if h.len() == hd => {}
                other => bail!(
                    "gru-f64 batched lane {b}: incompatible state snapshot ({})",
                    other.kind()
                ),
            }
        }
        let mut idx: Vec<usize> = (0..lanes.len()).collect();
        idx.sort_by_key(|&i| lanes[i].iq.len());
        let (mut start, mut t0) = (0usize, 0usize);
        while start < idx.len() {
            let t1 = lanes[idx[start]].iq.len();
            if t1 > t0 {
                self.span_soa(lanes, &idx[start..], t0, t1);
                t0 = t1;
            }
            while start < idx.len() && lanes[idx[start]].iq.len() == t0 {
                start += 1;
            }
        }
        Ok(())
    }

    /// One lockstep span over the active lanes (all hold `t1` samples).
    fn span_soa(&self, lanes: &mut [DpdLane<'_>], active: &[usize], t0: usize, t1: usize) {
        let hd = self.w.hidden;
        let rows = 3 * hd;
        let ba = active.len();

        let mut hs = vec![0.0f64; hd * ba];
        for (j, &li) in active.iter().enumerate() {
            if let DpdState::F64(h) = &*lanes[li].state {
                for (k, &v) in h.iter().enumerate() {
                    hs[k * ba + j] = v;
                }
            }
        }
        let mut xb = vec![0.0f64; 4 * ba];
        let mut inputs = vec![[0.0f64; 2]; ba];
        let mut gi = vec![0.0f64; rows * ba];
        let mut gh = vec![0.0f64; rows * ba];

        for t in t0..t1 {
            for (j, &li) in active.iter().enumerate() {
                let s = lanes[li].iq[t];
                inputs[j] = s;
                let x = Self::features(s);
                for (c, &v) in x.iter().enumerate() {
                    xb[c * ba + j] = v;
                }
            }
            // gi = W_ih x + b_ih ; gh = W_hh h + b_hh (batch-fastest)
            for (r, &b) in self.w.b_ih.iter().enumerate() {
                gi[r * ba..(r + 1) * ba].fill(b);
            }
            for c in 0..4 {
                let col = &self.wt_ih[c * rows..(c + 1) * rows];
                let xrow = &xb[c * ba..(c + 1) * ba];
                for (r, &w) in col.iter().enumerate() {
                    for (a, &x) in gi[r * ba..(r + 1) * ba].iter_mut().zip(xrow) {
                        *a += w * x;
                    }
                }
            }
            for (r, &b) in self.w.b_hh.iter().enumerate() {
                gh[r * ba..(r + 1) * ba].fill(b);
            }
            for c in 0..hd {
                let col = &self.wt_hh[c * rows..(c + 1) * rows];
                let hrow = &hs[c * ba..(c + 1) * ba];
                for (r, &w) in col.iter().enumerate() {
                    for (a, &x) in gh[r * ba..(r + 1) * ba].iter_mut().zip(hrow) {
                        *a += w * x;
                    }
                }
            }
            // gates (Eq. 2-5), the scalar expressions per lane
            for k in 0..hd {
                for j in 0..ba {
                    let r = hardsigmoid(gi[k * ba + j] + gh[k * ba + j]);
                    let z = hardsigmoid(gi[(hd + k) * ba + j] + gh[(hd + k) * ba + j]);
                    let n = hardtanh(gi[(2 * hd + k) * ba + j] + r * gh[(2 * hd + k) * ba + j]);
                    hs[k * ba + j] = (1.0 - z) * n + z * hs[k * ba + j];
                }
            }
            // FC + residual (Eq. 6) per lane, scalar accumulation order
            for (j, &li) in active.iter().enumerate() {
                let mut y = [self.w.b_fc[0] + inputs[j][0], self.w.b_fc[1] + inputs[j][1]];
                for k in 0..hd {
                    y[0] += self.w.w_fc[k] * hs[k * ba + j];
                    y[1] += self.w.w_fc[hd + k] * hs[k * ba + j];
                }
                lanes[li].iq[t] = y;
            }
        }
        for (j, &li) in active.iter().enumerate() {
            if let DpdState::F64(h) = &mut *lanes[li].state {
                for (k, dst) in h.iter_mut().enumerate() {
                    *dst = hs[k * ba + j];
                }
            }
        }
    }
}

impl Dpd for GruDpd {
    fn process(&mut self, iq: [f64; 2]) -> [f64; 2] {
        let hd = self.w.hidden;
        let x = Self::features(iq);

        // gi = W_ih x + b_ih ; gh = W_hh h + b_hh (column-major axpys)
        let rows = 3 * hd;
        self.gi.copy_from_slice(&self.w.b_ih);
        for (c, &xv) in x.iter().enumerate() {
            let col = &self.wt_ih[c * rows..(c + 1) * rows];
            for (a, &wv) in self.gi.iter_mut().zip(col) {
                *a += wv * xv;
            }
        }
        self.gh.copy_from_slice(&self.w.b_hh);
        for c in 0..hd {
            let xv = self.h[c];
            let col = &self.wt_hh[c * rows..(c + 1) * rows];
            for (a, &wv) in self.gh.iter_mut().zip(col) {
                *a += wv * xv;
            }
        }

        // gates (Eq. 2-5)
        for k in 0..hd {
            let r = hardsigmoid(self.gi[k] + self.gh[k]);
            let z = hardsigmoid(self.gi[hd + k] + self.gh[hd + k]);
            let n = hardtanh(self.gi[2 * hd + k] + r * self.gh[2 * hd + k]);
            self.h[k] = (1.0 - z) * n + z * self.h[k];
        }

        // FC + residual (Eq. 6)
        let mut y = [self.w.b_fc[0] + iq[0], self.w.b_fc[1] + iq[1]];
        for k in 0..hd {
            y[0] += self.w.w_fc[k] * self.h[k];
            y[1] += self.w.w_fc[hd + k] * self.h[k];
        }
        y
    }

    fn reset(&mut self) {
        self.h.iter_mut().for_each(|v| *v = 0.0);
    }

    fn name(&self) -> &'static str {
        "gru-f64"
    }

    fn save_state(&self) -> DpdState {
        DpdState::F64(self.h.clone())
    }

    fn load_state(&mut self, state: &DpdState) -> Result<()> {
        match state {
            DpdState::F64(h) if h.len() == self.w.hidden => {
                self.h.copy_from_slice(h);
                Ok(())
            }
            other => bail!(
                "{}: incompatible state snapshot ({}) for hidden={}",
                self.name(),
                other.kind(),
                self.w.hidden
            ),
        }
    }

    fn batch_fingerprint(&self) -> Option<u64> {
        Some(self.w.fingerprint())
    }

    fn process_lanes(&mut self, lanes: &mut [DpdLane<'_>]) -> Result<()> {
        if lanes.len() < 2 {
            return process_lanes_sequential(self, lanes);
        }
        self.process_lanes_soa(lanes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_weights(seed: u64) -> GruWeights {
        let mut rng = Rng::new(seed);
        let hidden = 10;
        let features = 4;
        let bound = 1.0 / (hidden as f64).sqrt();
        let mut gen = |n: usize| -> Vec<f64> { (0..n).map(|_| rng.range(-bound, bound)).collect() };
        GruWeights {
            hidden,
            features,
            w_ih: gen(3 * hidden * features),
            b_ih: gen(3 * hidden),
            w_hh: gen(3 * hidden * hidden),
            b_hh: gen(3 * hidden),
            w_fc: gen(2 * hidden),
            b_fc: gen(2),
            meta_bits: None,
            meta_act: None,
            meta_val_nmse_db: None,
        }
    }

    #[test]
    fn activations_match_equations() {
        assert_eq!(hardsigmoid(3.0), 1.0);
        assert_eq!(hardsigmoid(-3.0), 0.0);
        assert_eq!(hardsigmoid(0.0), 0.5);
        assert_eq!(hardsigmoid(1.0), 0.75);
        assert_eq!(hardtanh(2.0), 1.0);
        assert_eq!(hardtanh(-2.0), -1.0);
        assert_eq!(hardtanh(0.3), 0.3);
    }

    #[test]
    fn reset_makes_runs_reproducible() {
        let mut dpd = GruDpd::new(rand_weights(1));
        let mut rng = Rng::new(2);
        let x: Vec<[f64; 2]> = (0..64).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect();
        let a = dpd.run(&x);
        let b = dpd.run(&x);
        assert_eq!(a, b);
    }

    #[test]
    fn recurrent_state_matters() {
        let mut dpd = GruDpd::new(rand_weights(3));
        let mut rng = Rng::new(4);
        let x: Vec<[f64; 2]> = (0..32).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect();
        let mut rev = x.clone();
        rev.reverse();
        let a = dpd.run(&x);
        let mut b = dpd.run(&rev);
        b.reverse();
        assert_ne!(a, b);
    }

    #[test]
    fn residual_at_zero_weights() {
        // zero FC weights + zero bias -> y == x exactly (the residual path)
        let mut w = rand_weights(5);
        w.w_fc.iter_mut().for_each(|v| *v = 0.0);
        w.b_fc.iter_mut().for_each(|v| *v = 0.0);
        let mut dpd = GruDpd::new(w);
        let x = [[0.1, -0.2], [0.3, 0.05]];
        let y = dpd.run(&x);
        assert_eq!(y, x.to_vec());
    }

    #[test]
    fn soa_lanes_bit_identical_to_sequential_fallback() {
        // f64 is where op-order sloppiness would show up first: the
        // SoA kernel must reproduce the scalar chain bit for bit.
        use crate::dpd::{process_lanes_sequential, DpdLane, DpdState};
        use crate::util::proptest::check;
        check("gru-f64 soa vs sequential lanes", 15, |rng| {
            let mut soa = GruDpd::new(rand_weights(rng.next_u64()));
            let mut seq = GruDpd::new(soa.weights().clone());
            let nb = rng.int_in(2, 6) as usize;
            let mut data: Vec<Vec<[f64; 2]>> = (0..nb)
                .map(|_| {
                    let len = rng.int_in(0, 48) as usize;
                    (0..len).map(|_| [rng.gauss() * 0.3, rng.gauss() * 0.3]).collect()
                })
                .collect();
            let states: Vec<DpdState> = (0..nb)
                .map(|_| DpdState::F64((0..10).map(|_| rng.range(-1.0, 1.0)).collect()))
                .collect();
            let mut data2 = data.clone();
            let mut st_a = states.clone();
            let mut st_b = states;
            let mut lanes: Vec<DpdLane> = data
                .iter_mut()
                .zip(st_a.iter_mut())
                .map(|(d, s)| DpdLane { iq: d.as_mut_slice(), state: s })
                .collect();
            soa.process_lanes(&mut lanes).map_err(|e| e.to_string())?;
            drop(lanes);
            let mut lanes: Vec<DpdLane> = data2
                .iter_mut()
                .zip(st_b.iter_mut())
                .map(|(d, s)| DpdLane { iq: d.as_mut_slice(), state: s })
                .collect();
            process_lanes_sequential(&mut seq, &mut lanes).map_err(|e| e.to_string())?;
            drop(lanes);
            if data != data2 {
                return Err("lane samples diverged".into());
            }
            if st_a != st_b {
                return Err("lane states diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn state_snapshot_round_trips() {
        let mut dpd = GruDpd::new(rand_weights(9));
        let mut rng = Rng::new(10);
        for _ in 0..40 {
            dpd.process([rng.gauss() * 0.25, rng.gauss() * 0.25]);
        }
        let snap = dpd.save_state();
        let a = dpd.process([0.1, -0.3]);
        dpd.load_state(&snap).unwrap();
        let b = dpd.process([0.1, -0.3]);
        assert_eq!(a, b);
        assert!(dpd.load_state(&crate::dpd::DpdState::I32(vec![0; 10])).is_err());
    }

    #[test]
    fn features_definition() {
        let f = GruDpd::features([0.3, -0.4]);
        let p = 4.0 * 0.25;
        assert_eq!(f, [0.3, -0.4, p, p * p]);
    }
}
