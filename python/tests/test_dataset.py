"""OFDM generator: statistics, spectrum containment, determinism."""

import numpy as np
import pytest

from compile import dataset


class TestConstellation:
    def test_unit_power(self):
        for order in (4, 16, 64, 256):
            c = dataset.qam_constellation(order)
            assert len(c) == order
            assert abs((np.abs(c) ** 2).mean() - 1.0) < 1e-12

    def test_rejects_non_square(self):
        with pytest.raises(AssertionError):
            dataset.qam_constellation(32)


class TestUsedBins:
    def test_dc_unused_and_symmetric(self):
        cfg = dataset.OfdmConfig()
        bins = dataset.used_bins(cfg)
        assert 0 not in bins
        assert len(bins) == cfg.n_used
        assert len(set(bins.tolist())) == cfg.n_used
        # symmetric: for each +k there is nfft-k
        pos = bins[bins <= cfg.nfft // 2]
        neg = cfg.nfft - bins[bins > cfg.nfft // 2]
        np.testing.assert_array_equal(np.sort(pos), np.sort(neg))


class TestGenerate:
    def test_shape_and_rms(self):
        cfg = dataset.OfdmConfig(n_symbols=8)
        x = dataset.generate_ofdm(cfg)
        assert x.shape == (8 * (cfg.nfft + cfg.cp), 2)
        rms = np.sqrt((x ** 2).sum(-1).mean())
        assert abs(rms - cfg.rms) < 1e-9

    def test_papr_realistic(self):
        x = dataset.generate_ofdm(dataset.OfdmConfig(n_symbols=32, seed=1))
        papr = dataset.papr_db(x)
        assert 7.0 < papr < 13.0, f"PAPR {papr:.1f} dB"

    def test_deterministic(self):
        a = dataset.generate_ofdm(dataset.OfdmConfig(n_symbols=4, seed=5))
        b = dataset.generate_ofdm(dataset.OfdmConfig(n_symbols=4, seed=5))
        np.testing.assert_array_equal(a, b)
        c = dataset.generate_ofdm(dataset.OfdmConfig(n_symbols=4, seed=6))
        assert not np.array_equal(a, c)

    def test_spectrum_contained(self):
        """TX filtering keeps adjacent-channel leakage below -60 dBc."""
        x = dataset.generate_ofdm(dataset.OfdmConfig(n_symbols=32, seed=2))
        c = x[..., 0] + 1j * x[..., 1]
        n = 4096
        w = np.hanning(n)
        psd = np.zeros(n)
        for i in range(len(c) // n):
            psd += np.abs(np.fft.fft(c[i * n : (i + 1) * n] * w)) ** 2
        psd = np.fft.fftshift(psd)
        f = np.fft.fftshift(np.fft.fftfreq(n))
        pin = psd[np.abs(f) < 0.13].sum()
        adj = psd[(np.abs(f) > 0.15) & (np.abs(f) < 0.4)].sum()
        assert 10 * np.log10(adj / pin) < -60.0

    def test_occupied_band_flat(self):
        """Power concentrated in |f| < 0.125 (the 4x-oversampled band)."""
        x = dataset.generate_ofdm(dataset.OfdmConfig(n_symbols=32, seed=4))
        c = x[..., 0] + 1j * x[..., 1]
        spec = np.abs(np.fft.fft(c)) ** 2
        f = np.fft.fftfreq(len(c))
        inband = spec[np.abs(f) < 0.13].sum()
        assert inband / spec.sum() > 0.999

    def test_unwindowed_unfiltered_still_works(self):
        x = dataset.generate_ofdm(dataset.OfdmConfig(n_symbols=4, window=0, fir_taps=0))
        assert np.isfinite(x).all()
        assert abs(np.sqrt((x ** 2).sum(-1).mean()) - 0.25) < 1e-9


class TestFrames:
    def test_disjoint_frames_cover(self):
        x = dataset.generate_ofdm(dataset.OfdmConfig(n_symbols=4))
        fr = dataset.frames_from_signal(x, 50)
        assert fr.shape[1:] == (50, 2)
        np.testing.assert_array_equal(fr[0], x[:50])
        np.testing.assert_array_equal(fr[1], x[50:100])

    def test_strided_frames(self):
        x = np.arange(40, dtype=float).reshape(20, 2)
        fr = dataset.frames_from_signal(x, 8, stride=4)
        assert fr.shape == (4, 8, 2)
        np.testing.assert_array_equal(fr[1], x[4:12])
