//! The service-side closed-loop adaptation plane: a background adapt
//! worker that every adaptive [`StreamSession`](super::StreamSession)
//! feeds PA observations into, and the engine hot-swap path back to
//! the session's worker.
//!
//! ```text
//!   caller ── x ──► StreamSession ── Cmd::Frame ──► engine worker
//!     │  push/drain                      ▲               │ u
//!     │                                  │ Cmd::Swap     ▼
//!     └─ adapt_feedback(x, u, y) ──► adapt worker   (deployed DPD)
//!            (y from the PA / feedback receiver)
//! ```
//!
//! The adapt worker owns one [`AdaptTrainer`] per registered session.
//! Feedback bursts stream in over a bounded channel (a slow trainer
//! backpressures `adapt_feedback`, never the data path), the trainer
//! runs its ILA windows in-thread, and every
//! [`SessionAdaptConfig::refresh_interval`] consumed samples it
//! re-quantizes the float twin and sends the session's engine worker a
//! [`Cmd::Swap`] — an **atomic hot-swap at a frame boundary**: worker
//! commands are serialized, so every frame that was queued before the
//! swap runs on the old engine, every frame after it on the new one,
//! and a coalescing group in progress is flushed first. The swapped-in
//! engine starts from reset state exactly like a freshly opened one
//! (`tests/adapt.rs` pins both sides of the boundary bit-exactly).
//!
//! Linearization quality is metered in-thread: feedback accumulates
//! into a measurement window and each full window yields ACPR (Welch)
//! and EVM (against `ĝ·backoff·x`) into the session-shared
//! [`AdaptStats`] — the window just before a refresh is kept as the
//! *pre* metric and the first full window after it as *post*, so
//! before/after linearization of every hot-swap is on the record in
//! [`SessionStats`](super::SessionStats).

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use super::service::{Cmd, EngineBuild};
use crate::dpd::adapt::{AdaptConfig, AdaptTrainer};
use crate::dpd::qgru::{ActKind, DeltaQGruDpd, QGruDpd};
use crate::dpd::{GruDpd, GruWeights, SparseMpGruDpd};
use crate::fixed::kernel::{resolve_simd, SimdPolicy};
use crate::fixed::{QProfile, QSpec};
use crate::metrics::acpr::{acpr_db, AcprConfig};
use crate::metrics::evm::evm_db_nmse;
use crate::runtime::backend::StreamingEngine;
use crate::runtime::{DpdEngine, EngineBase, EngineKind};
use crate::util::C64;

/// Per-session adaptation configuration (rides in
/// [`SessionConfig`](super::SessionConfig)).
#[derive(Clone, Copy, Debug)]
pub struct SessionAdaptConfig {
    /// trainer hyperparameters
    pub trainer: AdaptConfig,
    /// feedback samples consumed between engine refreshes
    pub refresh_interval: u64,
    /// integer format of re-quantized weight sets (and of the initial
    /// engine). `None` inherits: manifest-backed sessions take the
    /// artifact tree's `qspec_bits` (so adaptive and frozen sessions
    /// on one service deploy the same format), hermetic
    /// `open_adaptive_session` callers get the project's Q2.10
    pub bits: Option<u32>,
    /// measurement-window length for the ACPR/EVM meters
    pub meter_window: usize,
    /// Welch FFT size of the meter (must fit the window)
    pub meter_nfft: usize,
}

impl Default for SessionAdaptConfig {
    fn default() -> Self {
        SessionAdaptConfig {
            trainer: AdaptConfig::default(),
            refresh_interval: 1 << 16,
            bits: None,
            meter_window: 4096,
            meter_nfft: 1024,
        }
    }
}

/// Live adaptation metrics, shared between the adapt worker and the
/// owning session (surfaced through `SessionStats::adapt`).
#[derive(Clone, Copy, Debug, Default)]
pub struct AdaptStats {
    /// engine hot-swaps performed
    pub refreshes: u64,
    /// feedback samples consumed by the trainer
    pub samples: u64,
    /// optimizer steps taken
    pub steps: u64,
    /// lifetime training NMSE (dB)
    pub nmse_db: f64,
    /// recent training NMSE (dB, per-window EMA) — the convergence
    /// signal to watch; the lifetime average is history-dominated
    pub recent_nmse_db: f64,
    /// ACPR / EVM of the most recent completed measurement window
    pub window_acpr_dbc: Option<f64>,
    pub window_evm_db: Option<f64>,
    /// the window completed just before the latest refresh
    pub pre_refresh_acpr_dbc: Option<f64>,
    pub pre_refresh_evm_db: Option<f64>,
    /// the first window completed after the latest refresh
    pub post_refresh_acpr_dbc: Option<f64>,
    pub post_refresh_evm_db: Option<f64>,
}

impl AdaptStats {
    /// ACPR recovered across the latest refresh (positive = better).
    pub fn refresh_acpr_gain_db(&self) -> Option<f64> {
        Some(self.pre_refresh_acpr_dbc? - self.post_refresh_acpr_dbc?)
    }
}

/// How the adapt worker turns the adapted float twin into a fresh
/// engine at every refresh: it snapshots/re-quantizes the weights on
/// its own thread, but hands the worker an [`EngineBuild`] closure so
/// the engine itself is still constructed *in the worker thread* that
/// will own it (the same in-thread-construction rule `Cmd::Open`
/// follows).
pub(crate) type Rebuild = Box<dyn Fn(&GruWeights) -> EngineBuild + Send>;

/// The refresh bridge for a weights-backed engine kind: re-quantize
/// the float twin through the canonical bridge and construct the
/// matching streaming engine. Frame/simulator kinds have no refresh
/// path (the cycle model and the AOT artifact are compile-time weight
/// sets) and are rejected at session-open time.
///
/// The quantize bridge is fallible (a diverged trainer can hand back
/// non-finite weights — [`crate::dpd::NonFiniteWeightError`]): the
/// snapshot is quantized on the adapt thread, and a rejection travels
/// inside the [`EngineBuild`] closure so the in-worker build fails and
/// poisons the session exactly like any other engine-construction
/// error, instead of deploying garbage codes.
///
/// `simd` is the service's kernel policy; it only matters for the
/// `*Simd` kinds, where the kernel is resolved once here (the host
/// does not change mid-session) and every refreshed generation keeps
/// it — so a hot-swap can never flip the kernel under a session.
pub(crate) fn rebuild_for_kind(
    kind: EngineKind,
    spec: QSpec,
    simd: SimdPolicy,
) -> Result<Rebuild> {
    Ok(match kind.base {
        EngineBase::NativeF64 => Box::new(move |w: &GruWeights| -> EngineBuild {
            let w = w.clone();
            Box::new(move || {
                Ok(Box::new(StreamingEngine::new(Box::new(GruDpd::new(w))))
                    as Box<dyn DpdEngine>)
            })
        }),
        EngineBase::Fixed | EngineBase::Delta if kind.is_sparse_family() => {
            let kernel = if kind.simd { resolve_simd(simd) } else { None };
            let prof = match kind.profile {
                Some((wb, ab)) => QProfile::wa(wb as u32, ab as u32)?,
                None => QProfile::uniform(spec),
            };
            let rho_pct = kind.rho.unwrap_or(0);
            let theta = kind.theta;
            Box::new(move |w: &GruWeights| -> EngineBuild {
                // every refreshed generation re-prunes on the adapted
                // magnitudes, so the mask tracks the drifting twin
                let sw = w.prune_quantize(prof, rho_pct);
                Box::new(move || {
                    let sw = sw?;
                    Ok(match kernel {
                        Some(k) => Box::new(StreamingEngine::new(Box::new(
                            SparseMpGruDpd::with_kernel(sw, ActKind::Hard, theta, k),
                        ))) as Box<dyn DpdEngine>,
                        None => Box::new(StreamingEngine::new(Box::new(SparseMpGruDpd::new(
                            sw,
                            ActKind::Hard,
                            theta,
                        )))) as Box<dyn DpdEngine>,
                    })
                })
            })
        }
        EngineBase::Fixed | EngineBase::Delta => {
            let kernel = if kind.simd { resolve_simd(simd) } else { None };
            let base = kind.base;
            let theta = kind.theta;
            Box::new(move |w: &GruWeights| -> EngineBuild {
                let qw = w.quantize(spec);
                Box::new(move || {
                    let qw = qw?;
                    Ok(match (base, kernel) {
                        (EngineBase::Delta, Some(k)) => Box::new(StreamingEngine::new(
                            Box::new(DeltaQGruDpd::with_kernel(qw, ActKind::Hard, theta, k)),
                        ))
                            as Box<dyn DpdEngine>,
                        (EngineBase::Delta, None) => Box::new(StreamingEngine::new(Box::new(
                            DeltaQGruDpd::new(qw, ActKind::Hard, theta),
                        )))
                            as Box<dyn DpdEngine>,
                        (_, Some(k)) => Box::new(StreamingEngine::new(Box::new(
                            QGruDpd::with_kernel(qw, ActKind::Hard, k),
                        ))) as Box<dyn DpdEngine>,
                        (_, None) => Box::new(StreamingEngine::new(Box::new(QGruDpd::new(
                            qw,
                            ActKind::Hard,
                        )))) as Box<dyn DpdEngine>,
                    })
                })
            })
        }
        _ => bail!(
            "engine kind {kind} has no adaptation refresh path \
             (use native, fixed, delta[:θ], the sparse/@WwAa family, or their \
             +simd forms)"
        ),
    })
}

/// Commands a session (or `open_session`) sends to the adapt worker.
pub(crate) enum AdaptCmd {
    Open {
        id: u64,
        trainer: Box<AdaptTrainer>,
        cfg: SessionAdaptConfig,
        rebuild: Rebuild,
        /// the session's engine worker (swap target)
        worker_cmd: SyncSender<Cmd>,
        shared: Arc<Mutex<AdaptStats>>,
    },
    /// One feedback burst: original samples `x`, deployed-DPD output
    /// `u` (what entered the PA), PA observation `y`.
    Feedback {
        id: u64,
        x: Vec<[f64; 2]>,
        u: Vec<[f64; 2]>,
        y: Vec<[f64; 2]>,
    },
    /// Barrier: replied to once every command queued before it has
    /// been fully processed (feedback consumed, swaps *sent*).
    Sync { id: u64, reply: SyncSender<()> },
    /// Fleet-rollout deployment: hot-swap the session's engine to an
    /// externally supplied float generation (a weight-store blob the
    /// rollout controller resolved), through the *same* swap path a
    /// trainer refresh takes — so the pre/post ACPR meter bookkeeping
    /// rotates identically and `post_refresh_acpr_dbc` latches the
    /// deployed generation's first full window. The trainer is
    /// reseated on the deployed twin (fresh optimizer state: the new
    /// generation starts its own adaptation lineage). Replied once
    /// the swap has been sent to the engine worker.
    Deploy {
        id: u64,
        w: Box<GruWeights>,
        reply: SyncSender<Result<()>>,
    },
    Close { id: u64 },
}

struct Slot {
    trainer: Box<AdaptTrainer>,
    cfg: SessionAdaptConfig,
    rebuild: Rebuild,
    worker_cmd: SyncSender<Cmd>,
    shared: Arc<Mutex<AdaptStats>>,
    refreshes: u64,
    /// trainer-consumed samples since the last swap (full BPTT windows
    /// only — pushed-but-pending or skipped silence doesn't count)
    since_refresh: u64,
    /// optimizer steps at the last swap: a refresh only fires when the
    /// twin actually trained since then (re-deploying an unchanged
    /// generation would pointlessly reset the live engine's state)
    steps_at_refresh: u64,
    /// measurement accumulators (original x, PA observation y)
    meter_x: Vec<[f64; 2]>,
    meter_y: Vec<[f64; 2]>,
    /// latest completed window metrics
    window: Option<(f64, f64)>,
    /// pre-refresh metrics latched at the latest swap
    pre: Option<(f64, f64)>,
    /// true until the first post-refresh window completes
    await_post: bool,
}

impl Slot {
    fn publish(&self) {
        let p = self.trainer.progress();
        let mut s = self.shared.lock().expect("adapt stats lock");
        s.refreshes = self.refreshes;
        s.samples = p.samples;
        s.steps = p.steps;
        s.nmse_db = p.nmse_db;
        s.recent_nmse_db = p.recent_nmse_db;
        s.window_acpr_dbc = self.window.map(|w| w.0);
        s.window_evm_db = self.window.map(|w| w.1);
        s.pre_refresh_acpr_dbc = self.pre.map(|w| w.0);
        s.pre_refresh_evm_db = self.pre.map(|w| w.1);
        // while a post-refresh window is still pending the post slots
        // are cleared; once it lands, meter() wrote it directly and
        // publish leaves it alone
        if self.await_post {
            s.post_refresh_acpr_dbc = None;
            s.post_refresh_evm_db = None;
        }
    }

    /// Fold a feedback burst into the measurement window; on a full
    /// window compute ACPR/EVM and rotate the pre/post bookkeeping.
    fn meter(&mut self, x: &[[f64; 2]], y: &[[f64; 2]]) {
        self.meter_x.extend_from_slice(x);
        self.meter_y.extend_from_slice(y);
        let win = self.cfg.meter_window;
        while self.meter_x.len() >= win {
            let wx: Vec<[f64; 2]> = self.meter_x.drain(..win).collect();
            let wy: Vec<[f64; 2]> = self.meter_y.drain(..win).collect();
            let cfg = AcprConfig {
                welch: crate::dsp::welch::WelchConfig {
                    nfft: self.cfg.meter_nfft,
                    overlap: 0.5,
                },
                ..Default::default()
            };
            let Ok(acpr) = acpr_db(&wy, &cfg) else { continue };
            let g = self
                .trainer
                .gain_est()
                .map(|g| g.scale(self.trainer.config().backoff))
                .unwrap_or(C64::ONE);
            let evm = evm_db_nmse(&wy, &wx, g);
            self.window = Some((acpr.acpr_dbc, evm));
            if self.await_post {
                self.await_post = false;
                let mut s = self.shared.lock().expect("adapt stats lock");
                s.post_refresh_acpr_dbc = Some(acpr.acpr_dbc);
                s.post_refresh_evm_db = Some(evm);
            }
        }
    }

    /// Hot-swap the session engine to `w` (the refresh path with the
    /// weight source factored out: a trainer refresh deploys the
    /// adapted twin, a rollout deploy a store generation).
    fn swap_to(&mut self, id: u64, w: &GruWeights) {
        let build = (self.rebuild)(w);
        // blocking send is safe: the engine worker never blocks on
        // session output, so its command queue always drains; a failed
        // in-worker build poisons the session like any engine failure
        self.worker_cmd.send(Cmd::Swap { id, build }).ok();
        self.refreshes += 1;
        self.since_refresh = 0;
        self.steps_at_refresh = self.trainer.progress().steps;
        self.pre = self.window;
        self.await_post = true;
        // drop buffered pre-swap feedback so the latched post-refresh
        // window measures the *new* generation, not a window dominated
        // by samples the old engine predistorted
        self.meter_x.clear();
        self.meter_y.clear();
    }

    /// Re-quantize the twin and hot-swap the session engine.
    fn refresh(&mut self, id: u64) {
        let w = self.trainer.snapshot();
        self.swap_to(id, &w);
    }

    /// Rollout deployment: swap to an externally supplied generation
    /// and reseat the trainer on it (fresh optimizer state — the
    /// deployed generation starts its own adaptation lineage; the
    /// slot's refresh counter survives).
    fn deploy(&mut self, id: u64, w: &GruWeights) -> Result<()> {
        let trainer = AdaptTrainer::new(w.clone(), self.trainer.config())
            .map_err(|e| anyhow!("deploying weight generation: {e:#}"))?;
        self.trainer = Box::new(trainer);
        self.swap_to(id, w);
        Ok(())
    }
}

/// The adapt worker event loop: one thread per service, multiplexing
/// every adaptive session's trainer. Exits when the service and all
/// sessions have dropped their senders.
pub(crate) fn adapt_worker_loop(rx: Receiver<AdaptCmd>) {
    let mut slots: HashMap<u64, Slot> = HashMap::new();
    while let Ok(cmd) = rx.recv() {
        match cmd {
            AdaptCmd::Open { id, trainer, cfg, rebuild, worker_cmd, shared } => {
                slots.insert(
                    id,
                    Slot {
                        trainer,
                        cfg,
                        rebuild,
                        worker_cmd,
                        shared,
                        refreshes: 0,
                        since_refresh: 0,
                        steps_at_refresh: 0,
                        meter_x: Vec::new(),
                        meter_y: Vec::new(),
                        window: None,
                        pre: None,
                        await_post: false,
                    },
                );
            }
            AdaptCmd::Feedback { id, x, u, y } => {
                let Some(slot) = slots.get_mut(&id) else { continue };
                let consumed_before = slot.trainer.progress().samples;
                // a malformed burst (length mismatch) poisons nothing:
                // the trainer rejects it and the slot just skips
                if slot.trainer.observe(&u, &y).is_err() {
                    continue;
                }
                slot.meter(&x, &y);
                let p = slot.trainer.progress();
                // refresh cadence counts samples the trainer actually
                // consumed (full windows), and only fires when the
                // twin trained since the last swap — a silence gap must
                // not hot-swap an unchanged generation and reset the
                // live engine's state for nothing
                slot.since_refresh += p.samples - consumed_before;
                if slot.since_refresh >= slot.cfg.refresh_interval
                    && p.steps > slot.steps_at_refresh
                {
                    slot.refresh(id);
                }
                slot.publish();
            }
            AdaptCmd::Sync { reply, .. } => {
                reply.send(()).ok();
            }
            AdaptCmd::Deploy { id, w, reply } => {
                let Some(slot) = slots.get_mut(&id) else {
                    reply
                        .send(Err(anyhow!("no adaptive slot for session {id}")))
                        .ok();
                    continue;
                };
                let r = slot.deploy(id, &w);
                slot.publish();
                reply.send(r).ok();
            }
            AdaptCmd::Close { id } => {
                slots.remove(&id);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpd::adapt::identity_init;

    #[test]
    fn config_defaults_are_sane() {
        let cfg = SessionAdaptConfig::default();
        assert!(cfg.refresh_interval > 0);
        assert!(cfg.meter_window >= cfg.meter_nfft);
        assert_eq!(cfg.bits, None, "format is inherited unless pinned");
    }

    #[test]
    fn rebuild_covers_the_refreshable_kinds_and_rejects_the_rest() {
        let spec = QSpec::Q12;
        let w = identity_init(3, 10, 0.15);
        for kind in [
            EngineKind::native(),
            EngineKind::fixed(),
            EngineKind::delta(16),
            EngineKind::fixed_simd(),
            EngineKind::delta_simd(16),
            EngineKind::fixed().with_rho(50),
            EngineKind::fixed().with_rho(50).with_simd(),
            EngineKind::delta(16).with_profile(8, 12).with_rho(50).with_simd(),
        ] {
            let rebuild = rebuild_for_kind(kind, spec, SimdPolicy::Auto).unwrap();
            let mut eng = rebuild(&w)().unwrap();
            let mut burst = vec![[0.1, -0.05]; 8];
            eng.reset();
            eng.process_frame(&mut burst).unwrap();
            assert!(eng.batch_class().is_some(), "{kind:?} engines stay coalescible");
        }
        assert!(rebuild_for_kind(EngineKind::interp(), spec, SimdPolicy::Auto).is_err());
        assert!(rebuild_for_kind(EngineKind::cyclesim(), spec, SimdPolicy::Auto).is_err());
        // a refreshed simd engine under the Off policy is the scalar
        // datapath — and still lands in the same batch class, so the
        // kernel never splits coalescing
        let rebuild =
            rebuild_for_kind(EngineKind::fixed_simd(), spec, SimdPolicy::Off).unwrap();
        let forced = rebuild(&w)().unwrap();
        let plain = rebuild_for_kind(EngineKind::fixed(), spec, SimdPolicy::Auto).unwrap()(&w)()
            .unwrap();
        assert_eq!(forced.batch_class(), plain.batch_class());
    }

    #[test]
    fn rebuilt_engines_track_the_weight_generation() {
        // the coalescer separation: engines rebuilt from different
        // float twins land in different batch classes
        let spec = QSpec::Q12;
        let rebuild = rebuild_for_kind(EngineKind::fixed(), spec, SimdPolicy::Auto).unwrap();
        let w0 = identity_init(3, 10, 0.15);
        let mut w1 = w0.clone();
        w1.w_fc[0] += 0.25;
        let a = rebuild(&w0)().unwrap().batch_class();
        let b = rebuild(&w0)().unwrap().batch_class();
        let c = rebuild(&w1)().unwrap().batch_class();
        assert_eq!(a, b, "same generation, same class");
        assert_ne!(a, c, "refreshed generation must never coalesce with the old");
    }

    #[test]
    fn rebuild_surfaces_a_diverged_twin_as_a_build_error() {
        // a NaN in the adapted twin must fail the in-worker build (and
        // thus poison the session) rather than deploy garbage codes
        let spec = QSpec::Q12;
        let mut w = identity_init(3, 10, 0.15);
        w.w_ih[7] = f64::NAN;
        for kind in [
            EngineKind::fixed(),
            EngineKind::delta(16),
            EngineKind::fixed().with_profile(8, 12).with_rho(50),
        ] {
            let rebuild = rebuild_for_kind(kind, spec, SimdPolicy::Auto).unwrap();
            let err = rebuild(&w)().expect_err("NaN weights must not build");
            assert!(
                format!("{err:#}").contains("w_ih[7]"),
                "{kind:?}: error should name the offending weight"
            );
        }
    }

    #[test]
    fn refresh_gain_math() {
        let mut s = AdaptStats::default();
        assert!(s.refresh_acpr_gain_db().is_none());
        s.pre_refresh_acpr_dbc = Some(-30.0);
        s.post_refresh_acpr_dbc = Some(-38.5);
        assert!((s.refresh_acpr_gain_db().unwrap() - 8.5).abs() < 1e-12);
    }
}
