//! Cross-engine `DpdState` compatibility — the state-format
//! independence the unified executor guarantees:
//!
//! * a dense (`fixed`) `I32` snapshot loads into the carried-plan
//!   engines at their hinges (`delta:0`, `sparse:0` at a uniform
//!   profile) and the stream continues bit-exactly — the carried
//!   plans rebuild their caches around the bare hidden vector with
//!   the exact accumulator invariant (`x_prev = 0`, `h_prev = h`,
//!   accumulators = the matvec those imply);
//! * a carried (`DeltaI32`) snapshot loads into the dense engine
//!   (adopting its architectural `h`) and continues bit-exactly at
//!   the hinges, and carried snapshots travel between the delta and
//!   sparse plans;
//! * genuinely incompatible snapshots (wrong payload kind, wrong
//!   shape) are rejected with the typed [`StateMismatch`] error, so
//!   schedulers can tell "incompatible format" from I/O failures.

use dpd_ne::dpd::qgru::{ActKind, DeltaQGruDpd, QGruDpd};
use dpd_ne::dpd::weights::{GruWeights, QGruWeights};
use dpd_ne::dpd::{Dpd, DpdState, GruDpd, SparseMpGruDpd, StateMismatch};
use dpd_ne::fixed::QSpec;
use dpd_ne::util::Rng;

fn qweights() -> QGruWeights {
    QGruWeights::synthetic(42, QSpec::Q12)
}

fn signal(n: usize, seed: u64) -> Vec<[f64; 2]> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect()
}

/// Run `prefix` through a freshly-reset engine and snapshot it.
fn snapshot_after_prefix(e: &mut dyn Dpd, prefix: &[[f64; 2]]) -> DpdState {
    e.reset();
    for &s in prefix {
        e.process(s);
    }
    e.save_state()
}

/// Resume `suffix` from `state` on a freshly-reset engine.
fn resume(e: &mut dyn Dpd, state: &DpdState, suffix: &[[f64; 2]]) -> Vec<[f64; 2]> {
    e.reset();
    e.load_state(state).expect("compatible snapshot must load");
    suffix.iter().map(|&s| e.process(s)).collect()
}

#[test]
fn dense_snapshot_resumes_bit_exactly_on_every_hinge_engine() {
    // Save under `fixed`, load under `delta:0` and `sparse:0@uniform`:
    // the continuation must equal the dense engine's own, bit for bit.
    let input = signal(512, 7);
    let (prefix, suffix) = input.split_at(301);
    let mut dense = QGruDpd::new(qweights(), ActKind::Hard);
    let snap = snapshot_after_prefix(&mut dense, prefix);
    assert!(
        matches!(snap, DpdState::I32(_)),
        "dense engines snapshot the bare hidden state"
    );
    let want: Vec<[f64; 2]> = suffix.iter().map(|&s| dense.process(s)).collect();

    let mut delta0 = DeltaQGruDpd::new(qweights(), ActKind::Hard, 0);
    assert_eq!(
        resume(&mut delta0, &snap, suffix),
        want,
        "fixed -> delta:0: adopted snapshot diverged"
    );
    let mut sparse0 = SparseMpGruDpd::new(qweights().to_sparse(0), ActKind::Hard, 0);
    assert_eq!(
        resume(&mut sparse0, &snap, suffix),
        want,
        "fixed -> sparse:0@uniform: adopted snapshot diverged"
    );
    // at θ>0 the dense snapshot is still a *valid* state (the cache
    // rebuild preserves the accumulator invariant) — outputs may
    // drift by design, but adoption must be accepted
    let mut delta16 = DeltaQGruDpd::new(qweights(), ActKind::Hard, 16);
    delta16.reset();
    delta16.load_state(&snap).expect("dense snapshot must load at any θ");
}

#[test]
fn carried_snapshots_resume_bit_exactly_on_the_dense_engine() {
    // Vice versa: save under the carried plans, load under `fixed`
    // (which adopts the snapshot's architectural h) — and across the
    // two carried plans, which adopt the full delta state.
    let input = signal(512, 11);
    let (prefix, suffix) = input.split_at(257);

    let mut delta0 = DeltaQGruDpd::new(qweights(), ActKind::Hard, 0);
    let snap = snapshot_after_prefix(&mut delta0, prefix);
    assert!(
        matches!(snap, DpdState::DeltaI32(_)),
        "carried plans snapshot the full delta state"
    );
    let want: Vec<[f64; 2]> = suffix.iter().map(|&s| delta0.process(s)).collect();
    let mut dense = QGruDpd::new(qweights(), ActKind::Hard);
    assert_eq!(
        resume(&mut dense, &snap, suffix),
        want,
        "delta:0 -> fixed: adopted snapshot diverged"
    );

    let mut sparse0 = SparseMpGruDpd::new(qweights().to_sparse(0), ActKind::Hard, 0);
    let snap = snapshot_after_prefix(&mut sparse0, prefix);
    let want: Vec<[f64; 2]> = suffix.iter().map(|&s| sparse0.process(s)).collect();
    let mut dense = QGruDpd::new(qweights(), ActKind::Hard);
    assert_eq!(
        resume(&mut dense, &snap, suffix),
        want,
        "sparse:0@uniform -> fixed: adopted snapshot diverged"
    );
    let mut delta0 = DeltaQGruDpd::new(qweights(), ActKind::Hard, 0);
    assert_eq!(
        resume(&mut delta0, &snap, suffix),
        want,
        "sparse:0@uniform -> delta:0: adopted snapshot diverged"
    );
}

fn expect_mismatch(err: anyhow::Error, engine: &str, got: &str, hidden: usize) {
    let m = err
        .downcast_ref::<StateMismatch>()
        .unwrap_or_else(|| panic!("expected a typed StateMismatch, got: {err:#}"));
    assert_eq!(m.engine, engine);
    assert_eq!(m.got, got);
    assert_eq!(m.hidden, hidden);
}

#[test]
fn incompatible_snapshots_are_rejected_with_the_typed_error() {
    let hd = qweights().hidden;
    let mut dense = QGruDpd::new(qweights(), ActKind::Hard);
    // wrong payload kind
    let err = dense.load_state(&DpdState::F64(vec![0.0; hd])).unwrap_err();
    expect_mismatch(err, dense.name(), "f64", hd);
    // right kind, wrong shape
    let err = dense.load_state(&DpdState::I32(vec![0; hd + 1])).unwrap_err();
    expect_mismatch(err, dense.name(), "i32", hd);
    // carried plan: a DeltaI32 whose caches desynced from the weight
    // shape is not adoptable
    let mut delta = DeltaQGruDpd::new(qweights(), ActKind::Hard, 16);
    let DpdState::DeltaI32(mut s) = delta.save_state() else {
        panic!("carried plans snapshot the full delta state");
    };
    s.x_prev.push(0);
    let err = delta.load_state(&DpdState::DeltaI32(s)).unwrap_err();
    expect_mismatch(err, delta.name(), "delta-i32", hd);
    // the sparse plan enforces the same contract
    let mut sparse = SparseMpGruDpd::new(qweights().to_sparse(50), ActKind::Hard, 0);
    let err = sparse.load_state(&DpdState::F64(vec![0.0; hd])).unwrap_err();
    expect_mismatch(err, sparse.name(), "f64", hd);
    // and the float engine rejects integer snapshots the same way
    let mut native = GruDpd::new(GruWeights::synthetic(42));
    let err = native.load_state(&DpdState::I32(vec![0; hd])).unwrap_err();
    expect_mismatch(err, native.name(), "i32", hd);
}
