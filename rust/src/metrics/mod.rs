//! Linearization quality metrics: ACPR (the paper's headline dBc
//! figure), EVM (NMSE-form and constellation-form), NMSE.

pub mod acpr;
pub mod evm;

pub use acpr::{acpr_db, AcprConfig, AcprResult};
pub use evm::{evm_db_nmse, nmse_db};
