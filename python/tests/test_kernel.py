"""Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

Float kernel: allclose against ``ref.float_forward``.
Integer kernel: **bit-exact** against ``ref.int_forward`` across
hypothesis-swept shapes, precisions and activation kinds (this is the
same contract the rust engines are tested against via golden vectors).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import gru_cell, ref
from compile.kernels.quant import QSpec


def make_params(seed=0, hidden=10):
    return model.init_params(model.ModelConfig(hidden=hidden), jax.random.PRNGKey(seed))


def rand_iq(seed, b, t, scale=0.3):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, (b, t, 2)), jnp.float32)


def rand_codes(seed, b, t, spec, amp=0.7):
    rng = np.random.default_rng(seed)
    a = int(amp * spec.scale)
    return jnp.asarray(rng.integers(-a, a + 1, (b, t, 2)), jnp.int32)


class TestFloatKernel:
    def test_matches_ref_unquantized(self):
        params = make_params()
        iq = rand_iq(1, 3, 40)
        got = gru_cell.gru_dpd_pallas(params, iq)
        want = ref.float_forward(params, iq)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

    def test_matches_ref_quantized(self):
        params = make_params(2)
        iq = rand_iq(3, 2, 32)
        spec = QSpec(12)
        got = gru_cell.gru_dpd_pallas(params, iq, spec=spec)
        want = ref.float_forward(params, iq, spec=spec)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

    @given(
        st.integers(min_value=1, max_value=4),
        st.integers(min_value=1, max_value=64),
        st.integers(min_value=0, max_value=1000),
    )
    @settings(max_examples=15, deadline=None)
    def test_shape_sweep(self, b, t, seed):
        params = make_params(5)
        iq = rand_iq(seed, b, t)
        got = gru_cell.gru_dpd_pallas(params, iq)
        want = ref.float_forward(params, iq)
        assert got.shape == (b, t, 2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

    def test_hidden_state_actually_recurrent(self):
        """Permuting time steps must change the output (memory exists)."""
        params = make_params(3)
        iq = rand_iq(7, 1, 16)
        out = np.asarray(gru_cell.gru_dpd_pallas(params, iq))
        perm = np.asarray(gru_cell.gru_dpd_pallas(params, iq[:, ::-1]))[:, ::-1]
        assert not np.allclose(out, perm)


class TestIntKernel:
    @pytest.mark.parametrize("act", ["hard", "lut"])
    @pytest.mark.parametrize("bits", [8, 12, 16])
    def test_bit_exact(self, act, bits):
        spec = QSpec(bits)
        params = make_params(4)
        ip = ref.quantize_params(params, spec)
        codes = rand_codes(11, 2, 48, spec)
        got = np.asarray(gru_cell.gru_dpd_pallas_int(ip, codes, spec, act=act))
        want = np.asarray(ref.int_forward(ip, codes, spec, act=act))
        np.testing.assert_array_equal(got, want)

    @given(
        st.integers(min_value=1, max_value=3),
        st.integers(min_value=1, max_value=40),
        st.sampled_from([6, 8, 10, 12, 14, 16]),
        st.sampled_from(["hard", "lut"]),
        st.integers(min_value=0, max_value=10 ** 6),
    )
    @settings(max_examples=12, deadline=None)
    def test_bit_exact_sweep(self, b, t, bits, act, seed):
        spec = QSpec(bits)
        params = make_params(6)
        ip = ref.quantize_params(params, spec)
        codes = rand_codes(seed, b, t, spec)
        got = np.asarray(gru_cell.gru_dpd_pallas_int(ip, codes, spec, act=act))
        want = np.asarray(ref.int_forward(ip, codes, spec, act=act))
        np.testing.assert_array_equal(got, want)

    def test_full_scale_inputs_saturate_not_overflow(self):
        """Adversarial full-range codes: outputs stay in the code range."""
        spec = QSpec(12)
        params = make_params(8)
        ip = ref.quantize_params(params, spec)
        rng = np.random.default_rng(0)
        codes = jnp.asarray(
            rng.integers(spec.qmin, spec.qmax + 1, (1, 64, 2)), jnp.int32
        )
        out = np.asarray(gru_cell.gru_dpd_pallas_int(ip, codes, spec))
        assert out.min() >= spec.qmin and out.max() <= spec.qmax
        want = np.asarray(ref.int_forward(ip, codes, spec))
        np.testing.assert_array_equal(out, want)

    def test_int_close_to_fakequant_float(self):
        """The two views of the datapath agree to a few LSB."""
        spec = QSpec(12)
        params = make_params(9)
        ip = ref.quantize_params(params, spec)
        iq = rand_iq(13, 1, 64, scale=0.25)
        codes = jnp.asarray(
            np.clip(np.floor(np.asarray(iq) * spec.scale + 0.5), spec.qmin, spec.qmax), jnp.int32
        )
        out_int = np.asarray(ref.int_forward(ip, codes, spec)) / spec.scale
        out_f = np.asarray(ref.float_forward(params, iq, spec=spec))
        # int path uses floor-shift hardsigmoid; small LSB-level divergence
        # can be amplified slightly by recurrence
        assert np.max(np.abs(out_int - out_f)) <= 8 * spec.lsb


class TestModelWrappers:
    def test_forward_pallas_unbatched(self):
        params = make_params(1)
        iq = rand_iq(2, 1, 20)[0]
        out = model.forward_pallas(params, iq)
        assert out.shape == (20, 2)

    def test_forward_int_unbatched(self):
        spec = QSpec(12)
        params = make_params(1)
        ip = ref.quantize_params(params, spec)
        codes = rand_codes(3, 1, 20, spec)[0]
        out = model.forward_int(ip, codes, spec)
        assert out.shape == (20, 2)
        assert out.dtype == jnp.int32
