//! Quickstart: the 60-second tour.
//!
//! Loads the trained artifacts, pushes one OFDM burst through the
//! bit-exact DPD engine and the GaN-like PA, and prints the paper's
//! headline metrics (ACPR / EVM) with and without DPD.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use dpd_ne::dpd::qgru::{ActKind, QGruDpd};
use dpd_ne::dpd::weights::QGruWeights;
use dpd_ne::dpd::Dpd;
use dpd_ne::fixed::QSpec;
use dpd_ne::metrics::acpr::{acpr_db, AcprConfig};
use dpd_ne::metrics::evm::evm_db_nmse;
use dpd_ne::pa::{PaSpec, RappMemPa};
use dpd_ne::runtime::Manifest;
use dpd_ne::signal::ofdm::{OfdmConfig, OfdmModulator};

fn main() -> anyhow::Result<()> {
    // 1. artifacts: trained weights + the shared PA model
    let m = Manifest::discover(None)?;
    let pa = RappMemPa::new(PaSpec::load(&m.pa_model)?);
    let spec = QSpec::new(m.qspec_bits)?;
    let weights = QGruWeights::load_params_int(&m.weights_main, spec)?;
    println!(
        "loaded DPD-NeuralEngine model: {} params, Q2.{} fixed point",
        m.n_params,
        spec.frac()
    );

    // 2. a 64-QAM OFDM burst (the paper's bench signal, scaled)
    let sig = OfdmModulator::generate(&OfdmConfig { n_symbols: 24, seed: 7, ..Default::default() })?;

    // 3. through the PA without DPD
    let y_off = pa.run(&sig.iq);
    let acpr_off = acpr_db(&y_off, &AcprConfig::default())?.acpr_dbc;

    // 4. predistort with the chip's bit-exact datapath, then the PA
    let mut dpd = QGruDpd::new(weights, ActKind::Hard);
    let z = dpd.run(&sig.iq);
    let y_on = pa.run(&z);
    let acpr_on = acpr_db(&y_on, &AcprConfig::default())?.acpr_dbc;
    let evm_on = evm_db_nmse(&y_on, &sig.iq, pa.spec.target_gain());

    println!("ACPR without DPD : {acpr_off:6.1} dBc");
    println!("ACPR with DPD    : {acpr_on:6.1} dBc   (paper: -45.3 dBc)");
    println!("EVM with DPD     : {evm_on:6.1} dB    (paper: -39.8 dB)");
    println!("improvement      : {:6.1} dB", acpr_off - acpr_on);
    Ok(())
}
