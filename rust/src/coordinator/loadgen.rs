//! Fleet load generator: open-loop session churn against a [`Fleet`],
//! swept over session counts until aggregate throughput saturates.
//!
//! This is the measurement half of the fleet layer (ISSUE 7 / ROADMAP
//! item 3): where the micro benches report one engine's MSps, loadgen
//! answers the deployment question — *how many concurrent sessions
//! does a host sustain, and what happens to tail latency on the way
//! to the knee?* OpenDPDv2's critique (PAPERS.md) is that single-point
//! numbers hide exactly this curve.
//!
//! Shape of a run (`LoadgenConfig` → [`run`] → `BENCH_load.json`):
//!
//! * **Heterogeneous sessions.** Slots cycle through a fixed engine
//!   mix (`fixed`, `fixed+simd`, `delta:16`, `delta:32+simd`,
//!   `native`), all built hermetically from the shared synthetic
//!   weight fixtures ([`build_synthetic`]) — no artifact tree. Every
//!   `adaptive_every`-th slot instead opens a closed-loop adaptive
//!   session (synthetic float twin, PA feedback from the hermetic
//!   Rapp model) so the adapt workers carry load too.
//! * **Open-loop arrivals.** Each slot draws a deterministic arrival
//!   schedule from a forked [`Rng`](crate::util::Rng) — exponential
//!   inter-push gaps (`poisson`) or back-to-back bursts separated by
//!   long gaps (`bursty`). Driver threads replay the schedules in
//!   *virtual* time (a min-heap ordered by arrival stamp): the
//!   schedule fixes the interleaving and burst structure, while the
//!   actual push rate is whatever the fleet sustains — open-loop in
//!   the sense that arrival order never waits for completions.
//! * **Churn.** A slot that exhausts its per-life sample budget
//!   finishes its session (flushing the stream) and reopens a fresh
//!   one, `lives` times — so a sweep level with `n` slots opens up to
//!   `n × lives` sessions against admission caps sized to `n`.
//! * **Saturation sweep.** Session counts double from 1 until the
//!   aggregate MSps gain over the previous level falls under 5% (the
//!   knee) or `max_sessions` is reached. Each level runs on a fresh
//!   fleet, so levels are independent measurements.
//!
//! Every level also probes admission once with an over-cap open —
//! proving the typed-rejection path stays fast under load and making
//! the `rejected` counter in the artifact non-trivial.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::fleet::{AdmissionConfig, Fleet, FleetConfig, FleetSession, ShardPolicy};
use super::service::ServiceConfig;
use super::session::SessionConfig;
use crate::coordinator::SessionAdaptConfig;
use crate::dpd::GruWeights;
use crate::pa::{PaSpec, RappMemPa};
use crate::runtime::{build_synthetic, EngineKind};
use crate::util::hist::LatencyHistogram;
use crate::util::json::Json;
use crate::util::Rng;

/// Arrival schedule family for the open-loop drivers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalKind {
    /// exponential inter-push gaps (memoryless arrivals)
    Poisson,
    /// runs of 4–16 back-to-back pushes separated by long gaps
    Bursty,
}

impl std::fmt::Display for ArrivalKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArrivalKind::Poisson => write!(f, "poisson"),
            ArrivalKind::Bursty => write!(f, "bursty"),
        }
    }
}

/// Loadgen knobs. [`LoadgenConfig::full`] is the real sweep;
/// [`LoadgenConfig::quick`] is the CI smoke shape (seconds, small
/// budgets, same code path end to end).
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// independent `DpdService` shards in the fleet under test
    pub shards: usize,
    /// worker threads per shard
    pub workers_per_shard: usize,
    /// framer length for every session
    pub frame_len: usize,
    /// samples per push (the arrival schedule's unit of work)
    pub chunk: usize,
    /// samples each session life streams before finishing
    pub samples_per_session: usize,
    /// sessions opened per slot across a level (churn factor)
    pub lives: usize,
    /// sweep ceiling: levels double 1, 2, 4, … up to this
    pub max_sessions: usize,
    /// placement policy of the fleet under test
    pub policy: ShardPolicy,
    /// arrival schedule family
    pub arrival: ArrivalKind,
    /// every k-th slot opens adaptively (0 = all frozen)
    pub adaptive_every: usize,
    /// max sessions coalesced per worker dispatch (ServiceConfig.batch)
    pub batch: usize,
    /// master seed: signal, schedules and weights all fork from it
    pub seed: u64,
}

impl LoadgenConfig {
    /// The real sweep: hundreds of sessions, two lives per slot.
    pub fn full() -> LoadgenConfig {
        LoadgenConfig {
            shards: 2,
            workers_per_shard: 4,
            frame_len: 512,
            chunk: 2048,
            samples_per_session: 1 << 15,
            lives: 2,
            max_sessions: 256,
            policy: ShardPolicy::StickyByClass,
            arrival: ArrivalKind::Poisson,
            adaptive_every: 8,
            batch: 4,
            seed: 42,
        }
    }

    /// CI smoke shape: same code path, seconds of wall time.
    pub fn quick() -> LoadgenConfig {
        LoadgenConfig {
            workers_per_shard: 1,
            samples_per_session: 4096,
            lives: 1,
            max_sessions: 8,
            ..LoadgenConfig::full()
        }
    }

    fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.shards > 0, "loadgen needs at least one shard");
        anyhow::ensure!(self.workers_per_shard > 0, "loadgen needs at least one worker");
        anyhow::ensure!(self.chunk > 0 && self.frame_len > 0, "chunk/frame_len must be > 0");
        anyhow::ensure!(self.samples_per_session >= self.chunk, "budget under one chunk");
        anyhow::ensure!(self.lives > 0, "lives must be > 0");
        anyhow::ensure!(self.max_sessions > 0, "max_sessions must be > 0");
        Ok(())
    }
}

/// The frozen-engine mix a level's slots cycle through. `delta:0`
/// deliberately absent (it is `fixed` bit-for-bit); the θ values
/// match the conformance suite's.
pub fn engine_mix() -> Vec<EngineKind> {
    vec![
        EngineKind::fixed(),
        EngineKind::fixed_simd(),
        EngineKind::delta(16),
        EngineKind::delta_simd(32),
        EngineKind::native(),
    ]
}

/// One sweep level's measurement.
#[derive(Clone, Debug)]
pub struct LevelResult {
    /// concurrent session slots at this level
    pub sessions: usize,
    /// aggregate throughput: streamed samples ÷ level wall time
    pub msps: f64,
    /// samples streamed across every session life
    pub samples: u64,
    pub wall: Duration,
    /// sessions admitted / typed-rejected / closed over the level
    pub opened: u64,
    pub rejected: u64,
    pub drained: u64,
    /// merged per-push service latency across every shard
    pub latency: LatencyHistogram,
    /// per-shard (p50 µs, p99 µs, busy ratio) at drain time
    pub shards: Vec<(f64, f64, f64)>,
}

/// A full sweep: the sessions×MSps curve plus the saturation verdict.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub levels: Vec<LevelResult>,
    /// first level whose gain over its predecessor fell under 5%
    pub knee_sessions: Option<usize>,
    /// the curve's argmax: (sessions, MSps)
    pub saturation: (usize, f64),
}

/// one session slot's churn state inside a driver thread
struct Slot {
    /// `None` only between lives (and in schedule-only tests)
    session: Option<FleetSession>,
    kind: EngineKind,
    adaptive: bool,
    /// samples still to push in the current life
    remaining: usize,
    lives_left: usize,
    rng: Rng,
    /// bursty arrivals: pushes left at gap zero
    burst_left: u32,
    /// input samples pushed but not yet drained (adaptive alignment)
    x_fifo: Vec<[f64; 2]>,
    /// feedback plant for adaptive slots
    pa: Option<RappMemPa>,
    /// read cursor into the shared stimulus block
    sig_pos: usize,
    /// samples streamed by finished lives of this slot
    done: u64,
}

/// mean virtual inter-push gap (ns). Arbitrary but fixed: arrival
/// stamps only order pushes, they never pace real time.
const MEAN_GAP_NS: f64 = 1_000_000.0;

fn next_gap(slot: &mut Slot, arrival: ArrivalKind) -> u64 {
    match arrival {
        ArrivalKind::Poisson => {
            let u = slot.rng.uniform();
            (-(1.0 - u).ln() * MEAN_GAP_NS) as u64
        }
        ArrivalKind::Bursty => {
            if slot.burst_left > 0 {
                slot.burst_left -= 1;
                0
            } else {
                let n = 4 + slot.rng.below(13) as u32; // 4..=16
                slot.burst_left = n - 1;
                // the long gap "pays" for the whole burst
                (n as f64 * MEAN_GAP_NS) as u64
            }
        }
    }
}

/// open one slot's session on the fleet (frozen or adaptive)
fn open_slot(
    fleet: &Fleet,
    cfg: &LoadgenConfig,
    kind: EngineKind,
    adaptive: bool,
) -> Result<FleetSession> {
    let scfg = SessionConfig {
        engine: kind,
        frame_len: Some(cfg.frame_len),
        ..Default::default()
    };
    if adaptive {
        // big interval: the adapt worker carries trainer load, but a
        // loadgen life is too short to meaningfully converge a refresh
        let acfg = SessionAdaptConfig { refresh_interval: 1 << 20, ..Default::default() };
        fleet.open_adaptive_session(
            SessionConfig { adapt: Some(acfg), ..scfg },
            GruWeights::synthetic(cfg.seed),
        )
    } else {
        let seed = cfg.seed;
        let frame = cfg.frame_len;
        fleet.open_session_with(scfg, move || {
            build_synthetic(kind, seed, Default::default(), Some(frame))
        })
    }
}

/// drive one driver thread's slots through their schedules
fn drive(
    fleet: &Fleet,
    cfg: &LoadgenConfig,
    signal: &[[f64; 2]],
    mut slots: Vec<Slot>,
) -> Result<u64> {
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    for (i, slot) in slots.iter_mut().enumerate() {
        let t = next_gap(slot, cfg.arrival);
        heap.push(Reverse((t, i)));
    }
    while let Some(Reverse((t, i))) = heap.pop() {
        let slot = &mut slots[i];
        // next chunk of the shared stimulus, cycling
        let n = cfg.chunk.min(slot.remaining);
        let mut chunk = Vec::with_capacity(n);
        while chunk.len() < n {
            let take = (n - chunk.len()).min(signal.len() - slot.sig_pos);
            chunk.extend_from_slice(&signal[slot.sig_pos..slot.sig_pos + take]);
            slot.sig_pos = (slot.sig_pos + take) % signal.len();
        }
        let session = slot.session.as_mut().expect("scheduled slot holds a session");
        session.push(&chunk)?;
        slot.remaining -= n;
        if slot.adaptive {
            slot.x_fifo.extend_from_slice(&chunk);
            let u = session.drain()?;
            if !u.is_empty() {
                let x: Vec<[f64; 2]> = slot.x_fifo.drain(..u.len()).collect();
                let y = slot.pa.as_ref().expect("adaptive slot has a plant").run(&u);
                session.adapt_feedback(&x, &u, &y)?;
            }
        } else {
            // keep output queues shallow; samples are discarded (the
            // harness measures, it does not consume)
            session.drain()?;
        }
        if slot.remaining == 0 {
            // life over: flush + close *first* (releasing the
            // admission slot), then churn into a replacement — the
            // level's cap is exactly its slot count, so the reopen
            // always fits
            let out = slot.session.take().expect("scheduled slot holds a session").finish()?;
            slot.done += out.stats.samples_out;
            slot.lives_left -= 1;
            if slot.lives_left == 0 {
                continue; // retired: no further events for this slot
            }
            slot.session = Some(open_slot(fleet, cfg, slot.kind, slot.adaptive)?);
            slot.remaining = cfg.samples_per_session;
            slot.x_fifo.clear();
        }
        heap.push(Reverse((t + next_gap(slot, cfg.arrival), i)));
    }
    Ok(slots.iter().map(|s| s.done).sum())
}

/// Run one sweep level on a fresh fleet.
fn run_level(cfg: &LoadgenConfig, n: usize) -> Result<LevelResult> {
    let fleet = Fleet::start(FleetConfig {
        shards: cfg.shards,
        service: ServiceConfig {
            workers: cfg.workers_per_shard,
            frame_len: cfg.frame_len,
            batch: cfg.batch,
            ..Default::default()
        },
        policy: cfg.policy,
        // cap exactly at the level's slot count: churn finishes the
        // old session before reopening, so the reopen always fits,
        // and the probe below exercises the typed rejection
        admission: AdmissionConfig { max_sessions: n, ..Default::default() },
        ..Default::default()
    })?;

    // shared deterministic stimulus (one block, every slot cycles it)
    let mut sig_rng = Rng::new(cfg.seed ^ 0x10ad_5e55);
    let signal: Vec<[f64; 2]> =
        (0..1 << 13).map(|_| [sig_rng.gauss() * 0.25, sig_rng.gauss() * 0.25]).collect();

    let mix = engine_mix();
    let mut schedule_rng = Rng::new(cfg.seed ^ 0xa221_7a1);
    let mut slots: Vec<Slot> = (0..n)
        .map(|i| {
            let adaptive = cfg.adaptive_every > 0 && (i + 1) % cfg.adaptive_every == 0;
            let kind = if adaptive { EngineKind::fixed() } else { mix[i % mix.len()] };
            let session = open_slot(&fleet, cfg, kind, adaptive)?;
            Ok(Slot {
                session: Some(session),
                kind,
                adaptive,
                remaining: cfg.samples_per_session,
                lives_left: cfg.lives,
                rng: schedule_rng.fork(i as u64),
                burst_left: 0,
                x_fifo: Vec::new(),
                pa: adaptive.then(|| RappMemPa::new(PaSpec::ganlike())),
                sig_pos: (i * 97) % (1 << 13),
                done: 0,
            })
        })
        .collect::<Result<Vec<_>>>()?;

    // admission probe: with every slot held, one more open must trip
    // the typed rejection — fast, while the existing sessions stream
    let err = open_slot(&fleet, cfg, EngineKind::fixed(), false)
        .err()
        .ok_or_else(|| anyhow!("over-cap open unexpectedly admitted"))?;
    anyhow::ensure!(
        err.downcast_ref::<super::fleet::AdmissionError>().is_some(),
        "over-cap open failed without a typed AdmissionError: {err:#}"
    );

    // drive the slots from a few threads, each replaying its own
    // virtual-time schedule
    let n_threads = n.clamp(1, 4);
    let mut buckets: Vec<Vec<Slot>> = (0..n_threads).map(|_| Vec::new()).collect();
    for (i, slot) in slots.drain(..).enumerate() {
        buckets[i % n_threads].push(slot);
    }
    let t0 = Instant::now();
    let fleet_ref = &fleet;
    let signal_ref = &signal[..];
    let totals: Vec<Result<u64>> = std::thread::scope(|s| {
        let handles: Vec<_> = buckets
            .into_iter()
            .map(|bucket| s.spawn(move || drive(fleet_ref, cfg, signal_ref, bucket)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("driver thread panicked")).collect()
    });
    let wall = t0.elapsed();
    let samples: u64 = totals.into_iter().collect::<Result<Vec<u64>>>()?.iter().sum();

    let stats = fleet.drain().context("draining the level's fleet")?;
    Ok(LevelResult {
        sessions: n,
        msps: samples as f64 / wall.as_secs_f64().max(1e-9) / 1e6,
        samples,
        wall,
        opened: stats.sessions_opened,
        rejected: stats.sessions_rejected,
        drained: stats.sessions_drained,
        latency: stats.latency.clone(),
        shards: stats
            .shards
            .iter()
            .map(|sh| {
                (
                    sh.latency.p50().as_secs_f64() * 1e6,
                    sh.latency.p99().as_secs_f64() * 1e6,
                    sh.busy_ratio,
                )
            })
            .collect(),
    })
}

/// Run the sweep: session counts double from 1 until the throughput
/// gain over the previous level falls under 5% (the knee, confirmed
/// by running that level) or `max_sessions` is reached.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport> {
    cfg.validate()?;
    let mut levels: Vec<LevelResult> = Vec::new();
    let mut knee = None;
    let mut n = 1;
    loop {
        let level = run_level(cfg, n).with_context(|| format!("loadgen level n={n}"))?;
        let saturated = levels
            .last()
            .map(|prev| level.msps < prev.msps * 1.05)
            .unwrap_or(false);
        levels.push(level);
        if saturated && knee.is_none() {
            knee = Some(n);
            break;
        }
        if n >= cfg.max_sessions {
            break;
        }
        n = (n * 2).min(cfg.max_sessions);
    }
    let saturation = levels
        .iter()
        .max_by(|a, b| a.msps.total_cmp(&b.msps))
        .map(|l| (l.sessions, l.msps))
        .expect("at least one level ran");
    Ok(LoadReport { levels, knee_sessions: knee, saturation })
}

/// Serialize a sweep to `BENCH_load.json` in `$BENCH_OUT_DIR` (or the
/// working directory) — the same resolution as
/// [`bench::Report`](crate::bench::Report), so the CI artifact upload
/// finds both in one place. Returns the path written.
pub fn write_json(cfg: &LoadgenConfig, report: &LoadReport, quick: bool) -> Result<PathBuf> {
    let dir = std::env::var("BENCH_OUT_DIR").unwrap_or_else(|_| ".".to_string());
    write_json_to(std::path::Path::new(&dir), cfg, report, quick)
}

/// [`write_json`] into an explicit directory.
pub fn write_json_to(
    dir: &std::path::Path,
    cfg: &LoadgenConfig,
    report: &LoadReport,
    quick: bool,
) -> Result<PathBuf> {
    let curve: Vec<Json> = report
        .levels
        .iter()
        .map(|l| {
            let shards: Vec<Json> = l
                .shards
                .iter()
                .map(|&(p50_us, p99_us, busy)| {
                    Json::obj(vec![
                        ("p50_us", Json::num(p50_us)),
                        ("p99_us", Json::num(p99_us)),
                        ("busy_ratio", Json::num(busy)),
                    ])
                })
                .collect();
            Json::obj(vec![
                ("sessions", Json::num(l.sessions as f64)),
                ("msps", Json::num(l.msps)),
                ("samples", Json::num(l.samples as f64)),
                ("wall_s", Json::num(l.wall.as_secs_f64())),
                ("p50_us", Json::num(l.latency.p50().as_secs_f64() * 1e6)),
                ("p90_us", Json::num(l.latency.p90().as_secs_f64() * 1e6)),
                ("p99_us", Json::num(l.latency.p99().as_secs_f64() * 1e6)),
                ("opened", Json::num(l.opened as f64)),
                ("rejected", Json::num(l.rejected as f64)),
                ("drained", Json::num(l.drained as f64)),
                ("shards", Json::Arr(shards)),
            ])
        })
        .collect();
    let j = Json::obj(vec![
        ("bench", Json::str("load")),
        ("quick", Json::Bool(quick)),
        (
            "config",
            Json::obj(vec![
                ("shards", Json::num(cfg.shards as f64)),
                ("workers_per_shard", Json::num(cfg.workers_per_shard as f64)),
                ("frame_len", Json::num(cfg.frame_len as f64)),
                ("chunk", Json::num(cfg.chunk as f64)),
                ("samples_per_session", Json::num(cfg.samples_per_session as f64)),
                ("lives", Json::num(cfg.lives as f64)),
                ("max_sessions", Json::num(cfg.max_sessions as f64)),
                ("policy", Json::str(format!("{:?}", cfg.policy))),
                ("arrival", Json::str(cfg.arrival.to_string())),
                ("adaptive_every", Json::num(cfg.adaptive_every as f64)),
                ("batch", Json::num(cfg.batch as f64)),
                ("seed", Json::num(cfg.seed as f64)),
            ]),
        ),
        (
            "engine_mix",
            Json::Arr(engine_mix().iter().map(|k| Json::str(k.to_string())).collect()),
        ),
        ("curve", Json::Arr(curve)),
        (
            "saturation",
            Json::obj(vec![
                ("sessions", Json::num(report.saturation.0 as f64)),
                ("msps", Json::num(report.saturation.1)),
            ]),
        ),
        (
            "knee_sessions",
            report.knee_sessions.map(|n| Json::num(n as f64)).unwrap_or(Json::Null),
        ),
    ]);
    let path = dir.join("BENCH_load.json");
    std::fs::write(&path, j.dump()?).with_context(|| format!("writing {}", path.display()))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        LoadgenConfig::full().validate().unwrap();
        LoadgenConfig::quick().validate().unwrap();
        assert!(LoadgenConfig { shards: 0, ..LoadgenConfig::quick() }.validate().is_err());
        assert!(LoadgenConfig { lives: 0, ..LoadgenConfig::quick() }.validate().is_err());
    }

    #[test]
    fn engine_mix_is_heterogeneous_and_parseable() {
        use crate::runtime::EngineBase;
        let mix = engine_mix();
        assert!(mix.len() >= 4);
        assert!(mix.contains(&EngineKind::fixed_simd()), "mix must exercise the simd path");
        assert!(
            mix.iter().any(|k| k.base == EngineBase::Delta && k.theta > 0),
            "mix must exercise a non-trivial delta threshold"
        );
        for k in mix {
            assert_eq!(EngineKind::parse(&k.to_string()).unwrap(), k, "spec round-trip");
        }
    }

    #[test]
    fn arrival_schedules_are_deterministic_and_positive() {
        for arrival in [ArrivalKind::Poisson, ArrivalKind::Bursty] {
            let draw = |seed: u64| -> Vec<u64> {
                let mut slot_rng = Rng::new(seed);
                let mut slot = Slot {
                    session: None, // schedule-only: never pushed
                    kind: EngineKind::fixed(),
                    adaptive: false,
                    remaining: 0,
                    lives_left: 1,
                    rng: slot_rng.fork(0),
                    burst_left: 0,
                    x_fifo: Vec::new(),
                    pa: None,
                    sig_pos: 0,
                    done: 0,
                };
                (0..64).map(|_| next_gap(&mut slot, arrival)).collect()
            };
            assert_eq!(draw(7), draw(7), "same seed, same schedule ({arrival})");
            assert_ne!(draw(7), draw(8), "different seed, different schedule ({arrival})");
        }
    }

    #[test]
    fn quick_sweep_end_to_end() {
        // the hermetic acceptance path: a tiny sweep must produce a
        // curve, a saturation point, and non-empty latency histograms
        let cfg = LoadgenConfig {
            max_sessions: 2,
            samples_per_session: 2048,
            chunk: 512,
            frame_len: 256,
            adaptive_every: 2,
            ..LoadgenConfig::quick()
        };
        let report = run(&cfg).unwrap();
        assert!(!report.levels.is_empty());
        assert!(report.saturation.1 > 0.0, "throughput must be positive");
        for l in &report.levels {
            assert_eq!(l.samples as usize, l.sessions * cfg.samples_per_session * cfg.lives);
            assert!(!l.latency.is_empty());
            assert!(l.rejected >= 1, "the admission probe must be counted");
            assert!(l.latency.p50() <= l.latency.p99(), "quantiles must be ordered");
        }
        let dir = std::env::temp_dir().join("dpd_ne_loadgen_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = write_json_to(&dir, &cfg, &report, true).unwrap();
        let j = Json::parse_file(&path).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str().unwrap(), "load");
        assert!(j.get("curve").unwrap().as_arr().unwrap().len() >= 1);
        assert!(j.get("saturation").unwrap().get("msps").unwrap().as_f64().unwrap() > 0.0);
    }
}
