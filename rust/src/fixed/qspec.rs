//! Fixed-point format descriptor Q2.(bits-2).

use anyhow::{bail, Result};

/// Fixed-point format with 2 integer bits (incl. sign) and
/// `bits - 2` fractional bits. Codes live in `[-2^(bits-1), 2^(bits-1))`
/// and represent values in `[-2, 2)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QSpec {
    pub bits: u32,
}

impl QSpec {
    /// The paper's format: 12-bit Q2.10.
    pub const Q12: QSpec = QSpec { bits: 12 };

    pub fn new(bits: u32) -> Result<QSpec> {
        if !(4..=24).contains(&bits) {
            bail!("unsupported fixed-point width {bits} (need 4..=24)");
        }
        Ok(QSpec { bits })
    }

    /// Fractional bits (f in Q2.f).
    #[inline]
    pub fn frac(self) -> u32 {
        self.bits - 2
    }

    /// 2^f as f64.
    #[inline]
    pub fn scale(self) -> f64 {
        (1i64 << self.frac()) as f64
    }

    /// Smallest representable code.
    #[inline]
    pub fn qmin(self) -> i32 {
        -(1i32 << (self.bits - 1))
    }

    /// Largest representable code.
    #[inline]
    pub fn qmax(self) -> i32 {
        (1i32 << (self.bits - 1)) - 1
    }

    /// Value of one LSB.
    #[inline]
    pub fn lsb(self) -> f64 {
        1.0 / self.scale()
    }

    /// The code for +1.0.
    #[inline]
    pub fn one(self) -> i32 {
        1i32 << self.frac()
    }

    /// Quantize a float to a code: round-half-up then saturate.
    /// Bit-identical to `quant.quantize_to_int` in python.
    ///
    /// Total over every float: ±inf saturate to the code range like
    /// any out-of-range value. NaN has no meaningful code — the
    /// NaN-propagating `clamp` + `as i32` cast silently yield 0, so
    /// debug builds reject it here and the weight-quantization bridge
    /// ([`crate::dpd::GruWeights::quantize`]) screens non-finite
    /// weights with a typed error before ever reaching this point.
    #[inline]
    pub fn quantize(self, x: f64) -> i32 {
        debug_assert!(!x.is_nan(), "QSpec::quantize(NaN) has no meaningful code");
        let q = (x * self.scale() + 0.5).floor();
        let q = q.clamp(self.qmin() as f64, self.qmax() as f64);
        q as i32
    }

    /// Code -> float.
    #[inline]
    pub fn dequantize(self, code: i32) -> f64 {
        code as f64 / self.scale()
    }

    /// Quantize an I/Q slice of f64 pairs into codes.
    pub fn quantize_iq(self, iq: &[[f64; 2]]) -> Vec<[i32; 2]> {
        iq.iter()
            .map(|&[i, q]| [self.quantize(i), self.quantize(q)])
            .collect()
    }

    /// Codes -> I/Q floats.
    pub fn dequantize_iq(self, codes: &[[i32; 2]]) -> Vec<[f64; 2]> {
        codes
            .iter()
            .map(|&[i, q]| [self.dequantize(i), self.dequantize(q)])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn paper_format() {
        let s = QSpec::Q12;
        assert_eq!(s.frac(), 10);
        assert_eq!(s.scale(), 1024.0);
        assert_eq!(s.qmin(), -2048);
        assert_eq!(s.qmax(), 2047);
        assert_eq!(s.one(), 1024);
        assert!((s.lsb() - 2f64.powi(-10)).abs() < 1e-15);
    }

    #[test]
    fn invalid_widths_rejected() {
        assert!(QSpec::new(3).is_err());
        assert!(QSpec::new(25).is_err());
        assert!(QSpec::new(8).is_ok());
    }

    #[test]
    fn quantize_known_values() {
        let s = QSpec::Q12;
        assert_eq!(s.quantize(0.0), 0);
        assert_eq!(s.quantize(1.0), 1024);
        assert_eq!(s.quantize(-1.0), -1024);
        assert_eq!(s.quantize(100.0), 2047); // saturates
        assert_eq!(s.quantize(-100.0), -2048);
        // round-half-up at the tie: 0.5 LSB -> up
        assert_eq!(s.quantize(0.5 / 1024.0), 1);
        assert_eq!(s.quantize(-0.5 / 1024.0), 0); // ties toward +inf
    }

    #[test]
    fn quantize_is_total_over_out_of_range_and_infinite_inputs() {
        // totality sweep over every supported width: out-of-range and
        // infinite inputs saturate to the code range, never UB or a
        // mid-range code
        for bits in 4..=24u32 {
            let s = QSpec::new(bits).unwrap();
            for (x, want) in [
                (f64::INFINITY, s.qmax()),
                (f64::NEG_INFINITY, s.qmin()),
                (1e300, s.qmax()),
                (-1e300, s.qmin()),
                (f64::MAX, s.qmax()),
                (f64::MIN, s.qmin()),
            ] {
                assert_eq!(s.quantize(x), want, "bits={bits} x={x}");
            }
            // subnormals and signed zero round like tiny finite values
            assert_eq!(s.quantize(f64::MIN_POSITIVE), 0, "bits={bits}");
            assert_eq!(s.quantize(-0.0), 0, "bits={bits}");
        }
    }

    #[test]
    fn quantize_rejects_nan_in_debug_and_saturates_consistently() {
        check("quantize totality", 300, |rng| {
            let bits = rng.int_in(4, 24) as u32;
            let s = QSpec::new(bits).unwrap();
            // anywhere past the representable range must pin to the rail
            let mag = rng.range(2.0, 1e12);
            if s.quantize(mag) != s.qmax() {
                return Err(format!("bits={bits} quantize({mag}) != qmax"));
            }
            if s.quantize(-mag) != s.qmin() {
                return Err(format!("bits={bits} quantize({-mag}) != qmin"));
            }
            Ok(())
        });
        // NaN: debug builds assert; release builds keep the legacy
        // (cast-defined) 0 so the behavior stays total either way. The
        // weight bridge rejects NaN with a typed error before this.
        if cfg!(debug_assertions) {
            let caught = std::panic::catch_unwind(|| QSpec::Q12.quantize(f64::NAN));
            assert!(caught.is_err(), "debug quantize(NaN) must assert");
        } else {
            assert_eq!(QSpec::Q12.quantize(f64::NAN), 0);
        }
    }

    #[test]
    fn quantize_error_bound() {
        check("quantize error bound", 300, |rng| {
            let bits = rng.int_in(4, 16) as u32;
            let s = QSpec::new(bits).unwrap();
            let x = rng.range(-1.99, 1.99);
            let err = (s.dequantize(s.quantize(x)) - x).abs();
            if err > s.lsb() / 2.0 + 1e-12 {
                return Err(format!("bits={bits} x={x} err={err}"));
            }
            Ok(())
        });
    }

    #[test]
    fn quantize_monotone() {
        check("quantize monotone", 300, |rng| {
            let s = QSpec::new(rng.int_in(4, 16) as u32).unwrap();
            let a = rng.range(-4.0, 4.0);
            let b = rng.range(-4.0, 4.0);
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            if s.quantize(lo) > s.quantize(hi) {
                return Err(format!("non-monotone at {lo}, {hi}"));
            }
            Ok(())
        });
    }

    #[test]
    fn roundtrip_on_grid() {
        let s = QSpec::Q12;
        for code in (s.qmin()..=s.qmax()).step_by(7) {
            assert_eq!(s.quantize(s.dequantize(code)), code);
        }
    }

    #[test]
    fn iq_helpers() {
        let s = QSpec::Q12;
        let iq = vec![[0.5, -0.25], [1.5, -2.0]];
        let codes = s.quantize_iq(&iq);
        assert_eq!(codes, vec![[512, -256], [1536, -2048]]);
        let back = s.dequantize_iq(&codes);
        assert!((back[0][0] - 0.5).abs() < 1e-12);
    }
}
