//! Complex least squares via Householder QR on the normal-equation-free
//! path, plus a ridge-regularized variant (the GMP fit is mildly
//! ill-conditioned at high polynomial orders, exactly like the real
//! thing).

use anyhow::{ensure, Result};

use super::matrix::CMat;
use crate::util::C64;

/// Solve min ||A x - b||_2 by Householder QR (A: m x n, m >= n).
pub fn lstsq(a: &CMat, b: &[C64]) -> Result<Vec<C64>> {
    ensure!(a.rows >= a.cols, "underdetermined system ({}x{})", a.rows, a.cols);
    ensure!(b.len() == a.rows, "rhs length mismatch");
    let m = a.rows;
    let n = a.cols;
    let mut r = a.clone();
    let mut y: Vec<C64> = b.to_vec();

    // Householder QR: for each column k, reflect to zero below-diagonal.
    for k in 0..n {
        // norm of the k-th column below (and incl.) the diagonal
        let mut norm2 = 0.0;
        for i in k..m {
            norm2 += r.at(i, k).norm_sq();
        }
        let norm = norm2.sqrt();
        if norm < 1e-300 {
            anyhow::bail!("rank-deficient column {k}");
        }
        let akk = r.at(k, k);
        // alpha = -e^{i arg(akk)} * norm  (keeps v_k well conditioned)
        let phase = if akk.abs() > 0.0 { akk.scale(1.0 / akk.abs()) } else { C64::ONE };
        let alpha = -phase.scale(norm);
        // v = x - alpha e1
        let mut v: Vec<C64> = (k..m).map(|i| r.at(i, k)).collect();
        v[0] = v[0] - alpha;
        let vnorm2: f64 = v.iter().map(|z| z.norm_sq()).sum();
        if vnorm2 < 1e-300 {
            continue; // column already triangular
        }
        let beta = 2.0 / vnorm2;

        // apply H = I - beta v v^H to R[k.., k..]
        for j in k..n {
            let mut dot = C64::ZERO;
            for i in k..m {
                dot += v[i - k].conj() * r.at(i, j);
            }
            let s = dot.scale(beta);
            for i in k..m {
                let upd = r.at(i, j) - v[i - k] * s;
                *r.at_mut(i, j) = upd;
            }
        }
        // apply to rhs
        let mut dot = C64::ZERO;
        for i in k..m {
            dot += v[i - k].conj() * y[i];
        }
        let s = dot.scale(beta);
        for i in k..m {
            y[i] = y[i] - v[i - k] * s;
        }
    }

    // back substitution on the n x n upper triangle
    let mut x = vec![C64::ZERO; n];
    for k in (0..n).rev() {
        let mut acc = y[k];
        for j in k + 1..n {
            acc -= r.at(k, j) * x[j];
        }
        let d = r.at(k, k);
        ensure!(d.abs() > 1e-300, "singular diagonal at {k}");
        x[k] = acc / d;
    }
    Ok(x)
}

/// Ridge-regularized LS: min ||A x - b||^2 + lambda ||x||^2, solved by
/// stacking sqrt(lambda) I below A (numerically robust QR path).
pub fn ridge_lstsq(a: &CMat, b: &[C64], lambda: f64) -> Result<Vec<C64>> {
    if lambda == 0.0 {
        return lstsq(a, b);
    }
    let m = a.rows;
    let n = a.cols;
    let mut aug = CMat::zeros(m + n, n);
    aug.data[..m * n].copy_from_slice(&a.data);
    let sl = lambda.sqrt();
    for k in 0..n {
        *aug.at_mut(m + k, k) = C64::new(sl, 0.0);
    }
    let mut rhs = b.to_vec();
    rhs.extend(std::iter::repeat(C64::ZERO).take(n));
    lstsq(&aug, &rhs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::Rng;

    fn rand_mat(rng: &mut Rng, m: usize, n: usize) -> CMat {
        let mut a = CMat::zeros(m, n);
        for v in a.data.iter_mut() {
            *v = C64::new(rng.gauss(), rng.gauss());
        }
        a
    }

    #[test]
    fn exact_solution_square_system() {
        check("lstsq exact on square", 25, |rng| {
            let n = rng.int_in(1, 8) as usize;
            let a = rand_mat(rng, n, n);
            let x_true: Vec<C64> = (0..n).map(|_| C64::new(rng.gauss(), rng.gauss())).collect();
            let b = a.mul_vec(&x_true);
            let x = lstsq(&a, &b).map_err(|e| e.to_string())?;
            for (g, w) in x.iter().zip(&x_true) {
                if (*g - *w).abs() > 1e-8 {
                    return Err(format!("x mismatch {g:?} vs {w:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn residual_orthogonal_to_columns() {
        check("lstsq residual orthogonality", 20, |rng| {
            let m = 40;
            let n = rng.int_in(2, 10) as usize;
            let a = rand_mat(rng, m, n);
            let b: Vec<C64> = (0..m).map(|_| C64::new(rng.gauss(), rng.gauss())).collect();
            let x = lstsq(&a, &b).map_err(|e| e.to_string())?;
            let ax = a.mul_vec(&x);
            let resid: Vec<C64> = b.iter().zip(&ax).map(|(p, q)| *p - *q).collect();
            // A^H r == 0 at the LS optimum
            let proj = a.hermitian_mul_vec(&resid);
            for p in proj {
                if p.abs() > 1e-8 {
                    return Err(format!("non-orthogonal residual: {}", p.abs()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn overdetermined_recovers_planted_model() {
        let mut rng = Rng::new(77);
        let m = 200;
        let n = 6;
        let a = rand_mat(&mut rng, m, n);
        let x_true: Vec<C64> = (0..n).map(|_| C64::new(rng.gauss(), rng.gauss())).collect();
        let mut b = a.mul_vec(&x_true);
        for v in b.iter_mut() {
            *v += C64::new(rng.gauss(), rng.gauss()).scale(1e-6);
        }
        let x = lstsq(&a, &b).unwrap();
        for (g, w) in x.iter().zip(&x_true) {
            assert!((*g - *w).abs() < 1e-5);
        }
    }

    #[test]
    fn ridge_shrinks_norm() {
        let mut rng = Rng::new(5);
        let a = rand_mat(&mut rng, 30, 5);
        let b: Vec<C64> = (0..30).map(|_| C64::new(rng.gauss(), rng.gauss())).collect();
        let x0 = lstsq(&a, &b).unwrap();
        let x1 = ridge_lstsq(&a, &b, 10.0).unwrap();
        let n0: f64 = x0.iter().map(|z| z.norm_sq()).sum();
        let n1: f64 = x1.iter().map(|z| z.norm_sq()).sum();
        assert!(n1 < n0);
    }

    #[test]
    fn rejects_underdetermined() {
        let a = CMat::zeros(2, 5);
        assert!(lstsq(&a, &[C64::ZERO, C64::ZERO]).is_err());
    }
}
