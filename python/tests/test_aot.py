"""AOT export: HLO text validity, golden-vector schema, end-to-end fast build."""

import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import ref
from compile.kernels.quant import QSpec


@pytest.fixture(scope="module")
def small_int_model():
    params = model.init_params(model.ModelConfig(), jax.random.PRNGKey(0))
    spec = QSpec(12)
    return ref.quantize_params(params, spec), spec


class TestHloText:
    def test_lower_int_model_structure(self, small_int_model):
        ip, spec = small_int_model
        txt = aot.lower_int_model(ip, spec, "hard", 1, 16)
        assert txt.startswith("HloModule")
        # entry layout: one s32[1,16,2] param -> tuple of one s32[1,16,2]
        assert "s32[1,16,2]" in txt
        assert "ENTRY" in txt

    def test_lower_float_model_structure(self):
        params = model.init_params(model.ModelConfig(), jax.random.PRNGKey(1))
        txt = aot.lower_float_model(params, 1, 16)
        assert txt.startswith("HloModule")
        assert "f32[1,16,2]" in txt

    def test_no_custom_calls(self, small_int_model):
        """interpret=True pallas must lower to plain HLO (no Mosaic)."""
        ip, spec = small_int_model
        txt = aot.lower_int_model(ip, spec, "hard", 1, 8)
        assert "custom-call" not in txt.lower()

    def test_lut_variant_lowers(self, small_int_model):
        ip, spec = small_int_model
        txt = aot.lower_int_model(ip, spec, "lut", 1, 8)
        assert txt.startswith("HloModule")


class TestGolden:
    def test_golden_case_schema(self, small_int_model):
        ip, spec = small_int_model
        case = aot.golden_case(ip, spec, "hard", t=16, seed=0)
        assert case["bits"] == 12
        assert np.asarray(case["iq_codes"]).shape == (16, 2)
        assert np.asarray(case["out_codes"]).shape == (16, 2)
        assert len(case["trace"]["h"]) == 8
        assert len(case["trace"]["y"]) == 8
        assert np.asarray(case["trace"]["features"]).shape == (8, 4)

    def test_golden_trace_consistent_with_forward(self, small_int_model):
        """Per-step trace y must equal the scan forward's first steps."""
        ip, spec = small_int_model
        case = aot.golden_case(ip, spec, "hard", t=16, seed=1)
        out = np.asarray(case["out_codes"])
        trace_y = np.asarray(case["trace"]["y"])
        np.testing.assert_array_equal(out[: len(trace_y)], trace_y)

    def test_golden_deterministic(self, small_int_model):
        ip, spec = small_int_model
        a = aot.golden_case(ip, spec, "hard", t=8, seed=5)
        b = aot.golden_case(ip, spec, "hard", t=8, seed=5)
        assert a["iq_codes"] == b["iq_codes"]
        assert a["out_codes"] == b["out_codes"]


@pytest.mark.slow
class TestEndToEndFast:
    def test_fast_build(self, tmp_path):
        """Full --fast AOT build produces a coherent artifact tree."""
        outdir = tmp_path / "artifacts"
        env = dict(os.environ)
        pydir = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        res = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--outdir", str(outdir), "--fast"],
            cwd=pydir,
            capture_output=True,
            text=True,
            env=env,
            timeout=1200,
        )
        assert res.returncode == 0, res.stderr[-2000:]
        manifest = json.loads((outdir / "manifest.json").read_text())
        assert manifest["model"]["n_params"] == 502
        assert manifest["qspec"] == {"bits": 12, "frac": 10}
        for entry in manifest["hlo"]:
            text = (outdir / entry["file"]).read_text()
            assert text.startswith("HloModule")
        for g in manifest["golden"]:
            case = json.loads((outdir / g).read_text())
            assert "params_int" in case
        assert (outdir / "pa_model.json").exists()
        assert (outdir / "weights_main.json").exists()


class TestHloRegression:
    """Regressions for the two AOT sharp edges (DESIGN.md §9)."""

    def test_large_constants_not_elided(self, small_int_model):
        """as_hlo_text must print weight constants, not '{...}'."""
        ip, spec = small_int_model
        txt = aot.lower_int_model(ip, spec, "hard", 1, 8)
        assert "constant({...})" not in txt
        # at least one multi-element constant with real digits
        import re
        assert re.search(r"constant\(\{[^}]*-?\d+[,}]", txt)

    def test_no_s64_compute_in_12bit_artifact(self, small_int_model):
        """12-bit models must lower with int32 accumulation; only the
        loop counters may be s64 (xla_extension 0.5.1 miscompiles wide
        s64 elementwise chains)."""
        ip, spec = small_int_model
        txt = aot.lower_int_model(ip, spec, "hard", 1, 8)
        for line in txt.splitlines():
            if "s64[" in line:
                # allow scalar (s64[]) control only
                assert "s64[]" in line and "s64[1" not in line and "s64[8" not in line, line

    def test_int32_and_int64_kernels_agree(self, small_int_model):
        import jax.numpy as jnp
        from compile.kernels import gru_cell
        ip, spec = small_int_model
        rng = np.random.default_rng(0)
        codes = jnp.asarray(rng.integers(-2048, 2048, (1, 32, 2)), jnp.int32)
        a = gru_cell.gru_dpd_pallas_int(ip, codes, spec, acc_dtype=jnp.int32)
        b = gru_cell.gru_dpd_pallas_int(ip, codes, spec, acc_dtype=jnp.int64)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
