//! Sparse + mixed-precision Pareto golden regression — hermetic,
//! checked-in data, cross-validated against an independently written
//! Python oracle (`python/tools/gen_golden_pareto.py`).
//!
//! `tests/data/golden_pareto.json` pins, for each (profile, ρ, θ) grid
//! point of the sparse/MP engine family on the golden CP-OFDM burst
//! (the waveform lives in `golden_ofdm_q12.json` — one stimulus for
//! both golden suites):
//!
//! 1. the first 64 output codes — **bit-exact** (catches any change to
//!    the prune order, CSC construction, per-tensor requantization or
//!    delta-firing algebra, with exact diffs);
//! 2. the activity counters and surviving-entry count — **exact**
//!    (catches skip-accounting drift, the numbers the accel cost model
//!    prices);
//! 3. the cost-model MAC reduction (1e-9) and the measured ACPR/EVM
//!    through the shared Rapp+memory PA (±0.05 dB);
//! 4. the acceptance point of the family (ISSUE 8): at least one grid
//!    row reaches ≥ 1.5× modeled MAC reduction while staying within
//!    0.5 dB ACPR of the dense Q2.10 baseline — re-measured here, not
//!    just replayed from the JSON.

use std::path::PathBuf;

use dpd_ne::accel::ops::ModelDims;
use dpd_ne::accel::power::EnergyModel;
use dpd_ne::accel::SparseCostModel;
use dpd_ne::dpd::qgru::ActKind;
use dpd_ne::dpd::weights::GruWeights;
use dpd_ne::dpd::{SparseMpGruDpd, SparseStats};
use dpd_ne::dsp::welch::WelchConfig;
use dpd_ne::fixed::{QProfile, QSpec};
use dpd_ne::metrics::acpr::{acpr_db, AcprConfig};
use dpd_ne::metrics::evm::evm_db_nmse;
use dpd_ne::pa::{PaSpec, RappMemPa};
use dpd_ne::util::json::Json;

fn data_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/data").join(name)
}

fn load_iq() -> Vec<[f64; 2]> {
    let j = Json::parse_file(&data_path("golden_ofdm_q12.json"))
        .expect("golden waveform file must parse");
    j.get("iq")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|p| {
            let v = p.as_f64_vec().unwrap();
            [v[0], v[1]]
        })
        .collect()
}

fn load_code_pairs(j: &Json) -> Vec<[i32; 2]> {
    j.as_arr()
        .unwrap()
        .iter()
        .map(|p| {
            let v = p.as_i32_vec().unwrap();
            [v[0], v[1]]
        })
        .collect()
}

/// One golden grid row, decoded.
struct Row {
    profile: QProfile,
    rho: u8,
    theta: u32,
    gate_nnz: usize,
    stats: SparseStats,
    mac_reduction: f64,
    acpr_dbc: f64,
    evm_db: f64,
    head_codes: Vec<[i32; 2]>,
}

fn decode_row(j: &Json, act: QSpec) -> Row {
    let profile = match j.get("profile").unwrap() {
        Json::Null => QProfile::uniform(act),
        p => {
            let wa = p.as_i32_vec().unwrap();
            assert_eq!(wa[1] as u32, act.bits, "golden profile act width drifted");
            QProfile::wa(wa[0] as u32, wa[1] as u32).unwrap()
        }
    };
    let s = j.get("stats").unwrap();
    let stat = |k: &str| s.get(k).unwrap().as_usize().unwrap() as u64;
    Row {
        profile,
        rho: j.get("rho").unwrap().as_usize().unwrap() as u8,
        theta: j.get("theta").unwrap().as_usize().unwrap() as u32,
        gate_nnz: j.get("gate_nnz").unwrap().as_usize().unwrap(),
        stats: SparseStats {
            steps: stat("steps"),
            in_updates: stat("in_updates"),
            in_cols: stat("in_cols"),
            hid_updates: stat("hid_updates"),
            hid_cols: stat("hid_cols"),
            gate_macs: stat("gate_macs"),
            dense_gate_macs: stat("dense_gate_macs"),
        },
        mac_reduction: j.get("mac_reduction").unwrap().as_f64().unwrap(),
        acpr_dbc: j.get("acpr_dbc").unwrap().as_f64().unwrap(),
        evm_db: j.get("evm_db").unwrap().as_f64().unwrap(),
        head_codes: load_code_pairs(j.get("head_codes").unwrap()),
    }
}

#[test]
fn pareto_grid_matches_the_python_oracle() {
    let j = Json::parse_file(&data_path("golden_pareto.json"))
        .expect("pareto golden file must parse");
    let meta = j.get("meta").unwrap();
    let act = QSpec::new(meta.get("act_bits").unwrap().as_usize().unwrap() as u32).unwrap();
    let seed = meta.get("weights_seed").unwrap().as_usize().unwrap() as u64;
    let nfft = meta.get("welch_nfft").unwrap().as_usize().unwrap();
    let tol = meta.get("tol_db").unwrap().as_f64().unwrap();
    let min_red = meta.get("min_mac_reduction").unwrap().as_f64().unwrap();
    let max_delta = meta.get("max_acpr_delta_db").unwrap().as_f64().unwrap();

    let iq = load_iq();
    let codes = act.quantize_iq(&iq);
    let fw = GruWeights::synthetic(seed);
    let pa = RappMemPa::new(PaSpec::ganlike());
    let g = pa.spec.target_gain();
    let cfg = AcprConfig { bw: 0.25, offset: 0.275, welch: WelchConfig { nfft, overlap: 0.5 } };

    // the dense Q2.10 baseline == the (uniform, ρ=0, θ=0) hinge row
    let base = j.get("baseline").unwrap();
    let base_head = load_code_pairs(base.get("head_codes").unwrap());
    let base_acpr = base.get("acpr_dbc").unwrap().as_f64().unwrap();

    let em = EnergyModel::default();
    let dims = ModelDims::default();
    let mut base_power = None;
    let mut accepted = Vec::new();

    for (i, row_json) in j.get("rows").unwrap().as_arr().unwrap().iter().enumerate() {
        let row = decode_row(row_json, act);
        let label = format!(
            "row {i} (profile {}, rho={}, theta={})",
            row.profile, row.rho, row.theta
        );
        let sw = fw
            .prune_quantize(row.profile, row.rho)
            .expect("synthetic float weights are finite");
        assert_eq!(sw.gate_nnz(), row.gate_nnz, "{label}: surviving-entry count drifted");

        let mut dpd = SparseMpGruDpd::new(sw, ActKind::Hard, row.theta);
        let out = dpd.run_codes(&codes);

        // ring 1: bit-exact output codes
        assert_eq!(
            &out[..row.head_codes.len()],
            &row.head_codes[..],
            "{label}: integer datapath drifted from the Python oracle"
        );
        // ring 2: exact activity accounting
        assert_eq!(dpd.stats(), row.stats, "{label}: skip/MAC accounting drifted");

        // ring 3: cost model + analog metrics
        let model = SparseCostModel::new(dims, row.profile);
        let red = model.mac_reduction(&dpd.stats());
        assert!(
            (red - row.mac_reduction).abs() < 1e-9,
            "{label}: MAC reduction {red} vs pinned {}",
            row.mac_reduction
        );
        let z = act.dequantize_iq(&out);
        let y = pa.run(&z);
        let acpr = acpr_db(&y, &cfg).unwrap().acpr_dbc;
        let evm = evm_db_nmse(&y, &iq, g);
        assert!(
            (acpr - row.acpr_dbc).abs() <= tol,
            "{label}: ACPR {acpr:.6} vs {:.6} ± {tol}",
            row.acpr_dbc
        );
        assert!(
            (evm - row.evm_db).abs() <= tol,
            "{label}: EVM {evm:.6} vs {:.6} ± {tol}",
            row.evm_db
        );

        // the hinge row doubles as the baseline
        let power = model.projected_power_mw(&dpd.stats(), &em, &ActKind::Hard);
        if i == 0 {
            assert_eq!(out[..base_head.len()], base_head[..], "hinge row != baseline");
            assert!((acpr - base_acpr).abs() <= tol);
            base_power = Some(power);
        } else {
            // every decorated point must beat the uniform dense hinge
            // on projected power (narrower ops and/or fewer of them)
            let bp = base_power.expect("row 0 is the baseline");
            assert!(power < bp, "{label}: projected power {power:.1} mW >= baseline {bp:.1}");
        }

        // re-measure the acceptance predicate instead of trusting it
        if red >= min_red && (acpr - base_acpr).abs() <= max_delta {
            accepted.push(i as i32);
        }
    }

    // ISSUE 8 acceptance: the family earns ≥1.5× modeled MAC reduction
    // within 0.5 dB ACPR of the dense baseline, and the generator and
    // this re-measurement agree on exactly which rows achieve it
    let want_accepted = j.get("accepted_rows").unwrap().as_i32_vec().unwrap();
    assert_eq!(accepted, want_accepted, "acceptance set drifted from the oracle");
    assert!(!accepted.is_empty(), "no grid row met the 1.5x-within-0.5dB bar");
}
