//! Session-runtime contract tests — hermetic by construction.
//!
//! Every test here builds its engines from synthetic weights
//! (`QGruWeights::synthetic`, the same fixture class the accel tests
//! and artifact-less bench runs use), so parity, backpressure,
//! error-propagation, isolation and state-persistence all run in the
//! hermetic CI build — no `artifacts/` tree, no skips.
//!
//! The parity oracle is the bit-exact `QGruDpd` run directly over the
//! whole signal: a `Fixed`-style session must reproduce it exactly no
//! matter how the caller chunks its pushes, because the GRU hidden
//! state persists across `push` calls for the life of the session.

use anyhow::Result;
use dpd_ne::coordinator::{DpdService, ServiceConfig, SessionAdaptConfig, SessionConfig};
use dpd_ne::dpd::qgru::{ActKind, QGruDpd};
use dpd_ne::dpd::weights::{GruWeights, QGruWeights};
use dpd_ne::dpd::Dpd;
use dpd_ne::fixed::QSpec;
use dpd_ne::runtime::backend::{CycleSimDpd, StreamingEngine};
use dpd_ne::runtime::{DpdEngine, Manifest};
use dpd_ne::util::Rng;

fn signal(n: usize, seed: u64) -> Vec<[f64; 2]> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect()
}

fn synth_weights(seed: u64) -> QGruWeights {
    QGruWeights::synthetic(seed, QSpec::Q12)
}

/// The bit-exact streaming engine on synthetic weights — what a
/// `Fixed` session runs, minus the artifact tree.
fn fixed_engine(seed: u64) -> Box<dyn DpdEngine> {
    Box::new(StreamingEngine::new(Box::new(QGruDpd::new(synth_weights(seed), ActKind::Hard))))
}

/// Same weights through the cycle-accurate ASIC simulator.
fn cyclesim_engine(seed: u64) -> Box<dyn DpdEngine> {
    let qw = synth_weights(seed);
    Box::new(StreamingEngine::new(Box::new(CycleSimDpd::new(&qw))))
}

/// Oracle: one continuous run over the whole signal, state never reset.
fn direct(seed: u64, input: &[[f64; 2]]) -> Vec<[f64; 2]> {
    let mut d = QGruDpd::new(synth_weights(seed), ActKind::Hard);
    d.run(input)
}

/// Identity engine that fails after `after` frames — the deliberately
/// failing worker of the error-propagation tests.
struct FailingEngine {
    after: usize,
    seen: usize,
}

impl DpdEngine for FailingEngine {
    fn name(&self) -> &'static str {
        "failing"
    }
    fn process_frame(&mut self, _iq: &mut [[f64; 2]]) -> Result<()> {
        self.seen += 1;
        anyhow::ensure!(self.seen <= self.after, "injected engine failure");
        Ok(())
    }
    fn reset(&mut self) {}
}

/// Identity engine that sleeps on every frame — holds the worker busy
/// so frames for its session peers pile up in the command queue, which
/// makes coalesced-group formation (next frame dispatch) near-certain.
struct SlowEngine;

impl DpdEngine for SlowEngine {
    fn name(&self) -> &'static str {
        "slow"
    }
    fn process_frame(&mut self, _iq: &mut [[f64; 2]]) -> Result<()> {
        std::thread::sleep(std::time::Duration::from_millis(200));
        Ok(())
    }
    fn reset(&mut self) {}
}

/// Batchable identity engine whose batched entry point dies whenever
/// it is actually coalesced (>= 2 lanes) — the "worker dies
/// mid-coalesced-batch" fault of the regression suite.
struct FailInBatchEngine;

impl DpdEngine for FailInBatchEngine {
    fn name(&self) -> &'static str {
        "fail-in-batch"
    }
    fn process_frame(&mut self, _iq: &mut [[f64; 2]]) -> Result<()> {
        Ok(())
    }
    fn reset(&mut self) {}
    fn batch_class(&self) -> Option<u64> {
        Some(0xBADB_A7C4)
    }
    fn run_batch(&mut self, lanes: &mut [dpd_ne::runtime::DpdLane<'_>]) -> Result<()> {
        anyhow::ensure!(lanes.len() < 2, "injected batched engine failure");
        dpd_ne::runtime::backend::run_batch_sequential(self, lanes)
    }
}

#[test]
fn parity_any_chunking_matches_whole_signal_run() {
    // The headline contract: pushing in arbitrary chunk sizes (with
    // interleaved drains) is bit-identical to one direct engine run —
    // frame boundaries and push boundaries must not disturb state.
    let input = signal(1500, 7);
    let want = direct(42, &input);
    let service =
        DpdService::start(ServiceConfig { workers: 2, frame_len: 128, ..Default::default() })
            .unwrap();
    for chunks in [vec![1500], vec![1, 3, 17, 64, 255, 1024, 136], vec![499, 499, 499, 3]] {
        let mut sess =
            service.open_session_with(SessionConfig::default(), || Ok(fixed_engine(42))).unwrap();
        let mut got = Vec::new();
        let mut i = 0;
        for c in chunks {
            sess.push(&input[i..i + c]).unwrap();
            i += c;
            got.extend(sess.drain().unwrap());
        }
        assert_eq!(i, input.len());
        let out = sess.finish().unwrap();
        got.extend(out.iq);
        assert_eq!(got, want);
        assert_eq!(out.stats.samples_in as usize, input.len());
        assert_eq!(out.stats.samples_out as usize, input.len());
    }
    service.shutdown().unwrap();
}

#[test]
fn reset_restarts_the_stream_mid_session() {
    let a = signal(333, 1);
    let b = signal(700, 2);
    let service =
        DpdService::start(ServiceConfig { workers: 1, frame_len: 64, ..Default::default() })
            .unwrap();
    let mut sess =
        service.open_session_with(SessionConfig::default(), || Ok(fixed_engine(9))).unwrap();
    sess.push(&a).unwrap();
    sess.reset().unwrap();
    sess.push(&b).unwrap();
    let got = sess.finish().unwrap();
    // each segment behaves like a fresh stream (h reset in between)
    let mut want = direct(9, &a);
    want.extend(direct(9, &b));
    assert_eq!(got.iq, want);
    service.shutdown().unwrap();
}

#[test]
fn backpressure_tiny_queues_push_never_deadlocks() {
    // queue_depth 1 both ways, no manual drains: push's opportunistic
    // output absorption is what keeps the loop moving
    let input = signal(5000, 3);
    let service = DpdService::start(ServiceConfig {
        workers: 1,
        queue_depth: 1,
        frame_len: 16,
        ..Default::default()
    })
    .unwrap();
    let mut sess =
        service.open_session_with(SessionConfig::default(), || Ok(fixed_engine(5))).unwrap();
    for chunk in input.chunks(777) {
        sess.push(chunk).unwrap();
    }
    let out = sess.finish().unwrap();
    assert_eq!(out.iq, direct(5, &input));
    assert_eq!(out.stats.frames, (5000 + 15) / 16);
    service.shutdown().unwrap();
}

#[test]
fn single_thread_multiplexing_coworker_sessions_never_deadlocks() {
    // The adversarial shape for the in-flight-cap invariant: two
    // sessions pinned to the same worker, driven alternately from one
    // thread, pushes large enough (62 frames each at depth 1) to
    // overrun every queue, no drains in between. Without the cap the
    // worker could block on session A's full output queue while B's
    // push spins on the shared command queue — a livelock.
    let service = DpdService::start(ServiceConfig {
        workers: 1,
        queue_depth: 1,
        frame_len: 16,
        ..Default::default()
    })
    .unwrap();
    let a_in = signal(2000, 31);
    let b_in = signal(2000, 32);
    let mut a =
        service.open_session_with(SessionConfig::default(), || Ok(fixed_engine(51))).unwrap();
    let mut b =
        service.open_session_with(SessionConfig::default(), || Ok(fixed_engine(52))).unwrap();
    for (ca, cb) in a_in.chunks(1000).zip(b_in.chunks(1000)) {
        a.push(ca).unwrap();
        b.push(cb).unwrap();
    }
    assert_eq!(a.finish().unwrap().iq, direct(51, &a_in));
    assert_eq!(b.finish().unwrap().iq, direct(52, &b_in));
    service.shutdown().unwrap();
}

#[test]
fn worker_error_propagates_and_worker_survives() {
    // Regression for the old pipeline bug: a dead engine used to look
    // like clean EOF and silently truncate the output. Now the error
    // must surface from push or finish — never an Ok with short data.
    let service =
        DpdService::start(ServiceConfig { workers: 1, frame_len: 32, ..Default::default() })
            .unwrap();
    let mut sess = service
        .open_session_with(SessionConfig::default(), || {
            Ok(Box::new(FailingEngine { after: 2, seen: 0 }))
        })
        .unwrap();
    let input = signal(32 * 10, 4);
    let mut push_err = None;
    for chunk in input.chunks(64) {
        if let Err(e) = sess.push(chunk) {
            push_err = Some(e);
            break;
        }
    }
    let err = match push_err {
        Some(e) => e,
        None => sess.finish().expect_err("failure must not be swallowed"),
    };
    assert!(
        format!("{err:#}").contains("injected engine failure"),
        "error lost its cause: {err:#}"
    );

    // the worker itself survives the engine failure and serves the
    // next session correctly
    let input = signal(200, 6);
    let mut sess =
        service.open_session_with(SessionConfig::default(), || Ok(fixed_engine(6))).unwrap();
    sess.push(&input).unwrap();
    assert_eq!(sess.finish().unwrap().iq, direct(6, &input));
    service.shutdown().unwrap();
}

#[test]
fn batched_engine_failure_poisons_every_session_in_the_group() {
    // Extends the failing-engine coverage to the coalescing scheduler:
    // when an engine dies *inside a batched call*, every session whose
    // frame was coalesced into that batch must observe the sticky Err
    // (no lane may silently succeed or truncate), and the worker must
    // survive to serve its other sessions.
    let service = DpdService::start(ServiceConfig {
        workers: 1,
        frame_len: 32,
        queue_depth: 4,
        batch: 4,
        ..Default::default()
    })
    .unwrap();
    // a slow (unbatchable) session holds the worker each round while
    // the victims' frames queue up behind it
    let mut slow = service
        .open_session_with(SessionConfig::default(), || {
            Ok(Box::new(SlowEngine) as Box<dyn DpdEngine>)
        })
        .unwrap();
    let mut victims: Vec<_> = (0..3)
        .map(|_| {
            service
                .open_session_with(SessionConfig::default(), || {
                    Ok(Box::new(FailInBatchEngine) as Box<dyn DpdEngine>)
                })
                .unwrap()
        })
        .collect();
    let frame = signal(32, 1);
    let mut poisoned = vec![false; victims.len()];
    'drive: for _ in 0..10 {
        slow.push(&frame).unwrap();
        for (k, v) in victims.iter_mut().enumerate() {
            if let Err(e) = v.push(&frame) {
                assert!(
                    format!("{e:#}").contains("injected batched engine failure"),
                    "error lost its cause: {e:#}"
                );
                poisoned[k] = true;
                break 'drive;
            }
        }
    }
    // the batch that failed had >= 2 lanes (the fault only fires when
    // genuinely coalesced), and *every* session in it is poisoned
    for (k, v) in victims.into_iter().enumerate() {
        match v.finish() {
            Err(e) => {
                poisoned[k] = true;
                let msg = format!("{e:#}");
                assert!(
                    msg.contains("injected batched engine failure"),
                    "victim {k}: wrong error: {msg}"
                );
                assert!(msg.contains("batched"), "victim {k}: batch context lost: {msg}");
            }
            Ok(out) => {
                // a session whose frame was never coalesced may finish
                // clean — but then it must not have lost samples
                assert_eq!(out.stats.samples_out, out.stats.samples_in, "victim {k}");
            }
        }
    }
    let n_poisoned = poisoned.iter().filter(|&&p| p).count();
    assert!(
        n_poisoned >= 2,
        "a failed batch must poison every coalesced session (got {n_poisoned})"
    );
    // the worker survives the batched failure: the slow session keeps
    // working and a fresh bit-exact session serves correctly
    slow.finish().unwrap();
    let input = signal(200, 6);
    let mut sess =
        service.open_session_with(SessionConfig::default(), || Ok(fixed_engine(6))).unwrap();
    sess.push(&input).unwrap();
    assert_eq!(sess.finish().unwrap().iq, direct(6, &input));
    service.shutdown().unwrap();
}

#[test]
fn error_is_sticky_across_calls() {
    let service =
        DpdService::start(ServiceConfig { workers: 1, frame_len: 8, ..Default::default() })
            .unwrap();
    let mut sess = service
        .open_session_with(SessionConfig::default(), || {
            Ok(Box::new(FailingEngine { after: 0, seen: 0 }))
        })
        .unwrap();
    let input = signal(64, 8);
    // drive until the failure lands, then every call must keep failing
    let mut saw_err = false;
    for _ in 0..100 {
        if sess.push(&input).is_err() {
            saw_err = true;
            break;
        }
    }
    assert!(saw_err, "failure never surfaced");
    assert!(sess.drain().is_err());
    assert!(sess.reset().is_err());
    assert!(sess.finish().is_err());
    service.shutdown().unwrap();
}

#[test]
fn sessions_are_isolated_even_on_a_shared_worker() {
    // 3 sessions on 2 workers: at least two share a worker; each
    // session has its own weights, input and state
    let service =
        DpdService::start(ServiceConfig { workers: 2, frame_len: 64, ..Default::default() })
            .unwrap();
    let seeds = [21u64, 22, 23];
    let inputs: Vec<Vec<[f64; 2]>> = (0..3).map(|k| signal(901, 40 + k as u64)).collect();
    let mut sessions: Vec<_> = seeds
        .iter()
        .map(|&s| {
            service.open_session_with(SessionConfig::default(), move || Ok(fixed_engine(s))).unwrap()
        })
        .collect();
    assert_eq!(service.loads().iter().sum::<usize>(), 3);
    // interleave pushes round-robin from one thread
    for chunk_idx in 0..(901 + 200) / 201 {
        for (k, sess) in sessions.iter_mut().enumerate() {
            let lo = chunk_idx * 201;
            let hi = (lo + 201).min(inputs[k].len());
            if lo < hi {
                sess.push(&inputs[k][lo..hi]).unwrap();
            }
        }
    }
    for (k, sess) in sessions.into_iter().enumerate() {
        let out = sess.finish().unwrap();
        assert_eq!(out.iq, direct(seeds[k], &inputs[k]), "session {k} contaminated");
    }
    assert_eq!(service.loads().iter().sum::<usize>(), 0);
    service.shutdown().unwrap();
}

#[test]
fn heterogeneous_shadow_session_audits_bit_exactly() {
    // the on-line parity-audit deployment: a Fixed production session
    // and a CycleSim shadow session on one service, identical input —
    // the shared integer datapath makes them bit-identical
    let input = signal(600, 17);
    let service =
        DpdService::start(ServiceConfig { workers: 2, frame_len: 50, ..Default::default() })
            .unwrap();
    let mut prod =
        service.open_session_with(SessionConfig::default(), || Ok(fixed_engine(33))).unwrap();
    let mut shadow =
        service.open_session_with(SessionConfig::default(), || Ok(cyclesim_engine(33))).unwrap();
    for chunk in input.chunks(97) {
        prod.push(chunk).unwrap();
        shadow.push(chunk).unwrap();
    }
    let a = prod.finish().unwrap();
    let b = shadow.finish().unwrap();
    assert_eq!(a.iq, b.iq, "cycle-accurate shadow diverged from the functional model");
    service.shutdown().unwrap();
}

#[test]
fn drop_without_finish_frees_the_worker() {
    let service =
        DpdService::start(ServiceConfig { workers: 1, frame_len: 32, ..Default::default() })
            .unwrap();
    {
        let mut sess =
            service.open_session_with(SessionConfig::default(), || Ok(fixed_engine(2))).unwrap();
        sess.push(&signal(500, 5)).unwrap();
        assert_eq!(service.loads(), vec![1]);
        // dropped here, mid-stream, without finish
    }
    assert_eq!(service.loads(), vec![0]);
    // the worker keeps serving
    let input = signal(300, 11);
    let mut sess =
        service.open_session_with(SessionConfig::default(), || Ok(fixed_engine(3))).unwrap();
    sess.push(&input).unwrap();
    assert_eq!(sess.finish().unwrap().iq, direct(3, &input));
    service.shutdown().unwrap();
}

#[test]
fn empty_session_finishes_clean() {
    let service = DpdService::start(ServiceConfig { workers: 1, ..Default::default() }).unwrap();
    let sess =
        service.open_session_with(SessionConfig::default(), || Ok(fixed_engine(1))).unwrap();
    assert_eq!(sess.engine(), "qgru-hard");
    let out = sess.finish().unwrap();
    assert!(out.iq.is_empty());
    assert_eq!(out.stats.samples_in, 0);
    assert_eq!(out.stats.frames, 0);
    service.shutdown().unwrap();
}

#[test]
fn stats_snapshot_tracks_the_stream() {
    let service =
        DpdService::start(ServiceConfig { workers: 1, frame_len: 100, ..Default::default() })
            .unwrap();
    let mut sess =
        service.open_session_with(SessionConfig::default(), || Ok(fixed_engine(4))).unwrap();
    let input = signal(950, 14);
    let mut drained = Vec::new();
    sess.push(&input[..600]).unwrap();
    drained.extend(sess.drain().unwrap());
    let st = sess.stats();
    assert_eq!(st.samples_in, 600);
    assert!(st.samples_out <= 600);
    assert!(st.in_flight <= 6, "in-flight beyond what was framed");
    sess.push(&input[600..]).unwrap();
    drained.extend(sess.drain().unwrap());
    let out = sess.finish().unwrap();
    // finish returns the remainder; totals cover the whole stream
    drained.extend(out.iq);
    assert_eq!(drained, direct(4, &input));
    assert_eq!(out.stats.samples_in, 950);
    assert_eq!(out.stats.samples_out, 950);
    assert_eq!(out.stats.frames, 10);
    assert!(out.stats.lat_max >= out.stats.lat_mean);
    service.shutdown().unwrap();
}

/// run `f` on its own thread with a deadline — shutdown-ordering bugs
/// present as hangs, and CI must see a failure, not a stuck job
fn with_watchdog(name: &'static str, f: impl FnOnce() -> Result<()> + Send + 'static) {
    const WATCHDOG: std::time::Duration = std::time::Duration::from_secs(120);
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    let runner = std::thread::spawn(move || {
        let r = f();
        done_tx.send(()).ok();
        r
    });
    match done_rx.recv_timeout(WATCHDOG) {
        Ok(()) => runner.join().expect("watchdog runner panicked").unwrap(),
        Err(_) => panic!("{name} did not complete within {WATCHDOG:?} — shutdown deadlock?"),
    }
}

#[test]
fn explicit_shutdown_after_adaptive_sessions_never_deadlocks() {
    // Regression for the shutdown ordering: the adapt worker holds
    // worker-command senders (hot-swap targets), so joining the engine
    // workers before it leaves their command channels open and the
    // joins never return. `DpdService::shutdown` must join the adapt
    // worker first — this hangs (watchdog) if the order regresses.
    with_watchdog("adaptive shutdown", || {
        let service = DpdService::start(ServiceConfig {
            workers: 1,
            frame_len: 32,
            ..Default::default()
        })?;
        let acfg = SessionAdaptConfig { refresh_interval: 1 << 20, ..Default::default() };
        let mut sess = service.open_adaptive_session(
            SessionConfig { adapt: Some(acfg), ..Default::default() },
            GruWeights::synthetic(3),
        )?;
        let x = signal(256, 21);
        sess.push(&x)?;
        let u = sess.drain()?;
        if !u.is_empty() {
            // self-feedback is a fine stand-in for a PA here: the test
            // is about thread lifecycle, not convergence
            sess.adapt_feedback(&x[..u.len()], &u, &u)?;
        }
        sess.adapt_barrier()?;
        sess.finish()?;
        service.shutdown()
    });
}

#[test]
fn dropping_the_service_with_live_sessions_keeps_streams_and_sticky_errors() {
    // Dropping the service (instead of shutdown) while sessions are
    // mid-stream must neither deadlock nor disturb them: sessions hold
    // their own worker-channel clones, so the workers keep serving
    // until the last session closes — and a session already poisoned
    // keeps its sticky error through the service drop.
    with_watchdog("service drop with live sessions", || {
        let service = DpdService::start(ServiceConfig {
            workers: 1,
            frame_len: 32,
            ..Default::default()
        })?;
        let input = signal(600, 23);
        let mut live =
            service.open_session_with(SessionConfig::default(), || Ok(fixed_engine(61)))?;
        let mut poisoned = service.open_session_with(SessionConfig::default(), || {
            Ok(Box::new(FailingEngine { after: 0, seen: 0 }) as Box<dyn DpdEngine>)
        })?;
        live.push(&input[..300])?;
        let mut saw_err = false;
        for _ in 0..100 {
            if poisoned.push(&input[..64]).is_err() {
                saw_err = true;
                break;
            }
        }
        anyhow::ensure!(saw_err, "injected failure never surfaced");

        drop(service); // sessions still open, frames still in flight

        live.push(&input[300..])?;
        let out = live.finish()?;
        anyhow::ensure!(
            out.iq == direct(61, &input),
            "live session corrupted by the service drop"
        );
        let err = poisoned.finish().expect_err("sticky error lost across the service drop");
        anyhow::ensure!(
            format!("{err:#}").contains("injected engine failure"),
            "sticky error lost its cause: {err:#}"
        );
        Ok(())
    });
}

#[test]
fn kind_sessions_need_the_artifact_tree() {
    let service = DpdService::start(ServiceConfig { workers: 1, ..Default::default() }).unwrap();
    match Manifest::discover(None) {
        Ok(_) => {
            // tree present (local dev): kind-based open works end to end
            let input = signal(256, 19);
            let mut sess = service.open_session(SessionConfig::default()).unwrap();
            sess.push(&input).unwrap();
            assert_eq!(sess.finish().unwrap().iq.len(), input.len());
        }
        Err(_) => {
            // hermetic CI: the discovery failure reaches the caller
            // with a pointer at the missing artifacts
            let err = service.open_session(SessionConfig::default()).unwrap_err();
            assert!(format!("{err:#}").contains("artifact"), "unhelpful error: {err:#}");
        }
    }
    service.shutdown().unwrap();
}
