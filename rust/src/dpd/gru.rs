//! Float (f64) GRU-RNN DPD — the paper's model (Eq. 1-6 + the residual
//! output and conditioned features, see DESIGN.md §Hardware-Adaptation).
//! Reference implementation for accuracy comparisons; the quantized
//! twin is `qgru`.
//!
//! The twin discipline and the kernel seam: `qgru`'s integer engines
//! are generic over a [`crate::fixed::GateKernel`] (scalar or AVX2)
//! and store their transposed gate matrices in a lane-padded blocked
//! layout. This f64 twin deliberately stays scalar and unpadded — it
//! is the accuracy oracle, not a throughput path, and keeping exactly
//! one layout here means a layout bug on the integer side shows up as
//! a twin divergence instead of being mirrored into the reference.
//! The structural correspondence that matters is per *column*:
//! `transpose_gates_f64` and `qgru::transpose_gates_blocked` agree on
//! the first `3*hidden` entries of every column; the integer side's
//! pad tail (zero weights, zero accumulator contributions) is an
//! implementation detail the kernels never let escape.

use anyhow::{bail, Result};

use super::weights::GruWeights;
use super::{
    process_lanes_sequential, DeltaF64Snapshot, DeltaStats, Dpd, DpdLane, DpdState, StateMismatch,
};
use crate::util::fnv1a_words;

/// Hardsigmoid, Eq. (7).
#[inline]
pub fn hardsigmoid(x: f64) -> f64 {
    (x * 0.25 + 0.5).clamp(0.0, 1.0)
}

/// Hardtanh, Eq. (8).
#[inline]
pub fn hardtanh(x: f64) -> f64 {
    x.clamp(-1.0, 1.0)
}

/// Column-major transposes of the gate matrices (f64 twin of
/// `qgru::transpose_gates_blocked`, minus the lane padding):
/// wt[(c, r)] = w[r][c], 3H-contiguous per column — shared by the dense and delta engines so their layouts
/// cannot drift apart (the θ=0 bit-exactness contract depends on both
/// reading identical column vectors).
fn transpose_gates_f64(w: &GruWeights) -> (Vec<f64>, Vec<f64>) {
    let rows = 3 * w.hidden;
    let mut wt_ih = vec![0.0; w.features * rows];
    for r in 0..rows {
        for c in 0..w.features {
            wt_ih[c * rows + r] = w.w_ih[r * w.features + c];
        }
    }
    let mut wt_hh = vec![0.0; w.hidden * rows];
    for r in 0..rows {
        for c in 0..w.hidden {
            wt_hh[c * rows + r] = w.w_hh[r * w.hidden + c];
        }
    }
    (wt_ih, wt_hh)
}

/// Delta pass over one matvec side: refresh the cached contribution
/// column `w[:, c] * v[c]` for every column whose value moved more
/// than θ, bumping `fired` per propagated column.
fn refresh_cols(
    wt: &[f64],
    ct: &mut [f64],
    v: &[f64],
    v_prev: &mut [f64],
    theta: f64,
    rows: usize,
    fired: &mut u64,
) {
    for (c, &xv) in v.iter().enumerate() {
        if (xv - v_prev[c]).abs() > theta {
            let col = &wt[c * rows..(c + 1) * rows];
            for (ct, &wv) in ct[c * rows..(c + 1) * rows].iter_mut().zip(col) {
                *ct = wv * xv;
            }
            v_prev[c] = xv;
            *fired += 1;
        }
    }
}

/// Re-sum cached contribution columns into the gate pre-activations in
/// the dense engine's exact accumulation order (bias, then column 0..C).
fn resum_cols(g: &mut [f64], b: &[f64], ct: &[f64], rows: usize) {
    g.copy_from_slice(b);
    for col in ct.chunks_exact(rows) {
        for (a, &v) in g.iter_mut().zip(col) {
            *a += v;
        }
    }
}

/// Gates (Eq. 2-5) + FC residual (Eq. 6): the downstream chain shared
/// by the dense and delta engines, op for op — the θ=0 bit-exactness
/// contract depends on both running the identical f64 expression.
fn gates_and_fc(w: &GruWeights, gi: &[f64], gh: &[f64], h: &mut [f64], iq: [f64; 2]) -> [f64; 2] {
    let hd = w.hidden;
    for k in 0..hd {
        let r = hardsigmoid(gi[k] + gh[k]);
        let z = hardsigmoid(gi[hd + k] + gh[hd + k]);
        let n = hardtanh(gi[2 * hd + k] + r * gh[2 * hd + k]);
        h[k] = (1.0 - z) * n + z * h[k];
    }
    let mut y = [w.b_fc[0] + iq[0], w.b_fc[1] + iq[1]];
    for k in 0..hd {
        y[0] += w.w_fc[k] * h[k];
        y[1] += w.w_fc[hd + k] * h[k];
    }
    y
}

/// Streaming float GRU DPD engine.
pub struct GruDpd {
    w: GruWeights,
    h: Vec<f64>,
    /// scratch buffers to avoid per-sample allocation
    gi: Vec<f64>,
    gh: Vec<f64>,
    /// column-major weight copies: the per-sample matvecs become
    /// 3H-wide SIMD axpys over contiguous columns (§Perf)
    wt_ih: Vec<f64>,
    wt_hh: Vec<f64>,
}

impl GruDpd {
    pub fn new(w: GruWeights) -> GruDpd {
        let h = vec![0.0; w.hidden];
        let g = vec![0.0; 3 * w.hidden];
        let (wt_ih, wt_hh) = transpose_gates_f64(&w);
        GruDpd { w, h, gi: g.clone(), gh: g, wt_ih, wt_hh }
    }

    pub fn weights(&self) -> &GruWeights {
        &self.w
    }

    /// Eq. (1) + conditioning: [i, q, 4|x|^2, (4|x|^2)^2].
    #[inline]
    pub fn features(iq: [f64; 2]) -> [f64; 4] {
        let p = 4.0 * (iq[0] * iq[0] + iq[1] * iq[1]);
        [iq[0], iq[1], p, p * p]
    }

    /// Structure-of-arrays batched execution over independent lanes
    /// sharing these weights. Each lane's f64 operation chain is
    /// exactly the scalar `process` one (same ops, same order — rustc
    /// does not re-associate or fuse floats), so the batched path is
    /// bit-identical to running every lane alone; the batch dimension
    /// only turns the axpy inner loops into wide contiguous sweeps.
    fn process_lanes_soa(&mut self, lanes: &mut [DpdLane<'_>]) -> Result<()> {
        let hd = self.w.hidden;
        for (b, lane) in lanes.iter().enumerate() {
            match &*lane.state {
                DpdState::F64(h) if h.len() == hd => {}
                other => bail!(
                    "gru-f64 batched lane {b}: incompatible state snapshot ({})",
                    other.kind()
                ),
            }
        }
        let mut idx: Vec<usize> = (0..lanes.len()).collect();
        idx.sort_by_key(|&i| lanes[i].iq.len());
        let (mut start, mut t0) = (0usize, 0usize);
        while start < idx.len() {
            let t1 = lanes[idx[start]].iq.len();
            if t1 > t0 {
                self.span_soa(lanes, &idx[start..], t0, t1);
                t0 = t1;
            }
            while start < idx.len() && lanes[idx[start]].iq.len() == t0 {
                start += 1;
            }
        }
        Ok(())
    }

    /// One lockstep span over the active lanes (all hold `t1` samples).
    fn span_soa(&self, lanes: &mut [DpdLane<'_>], active: &[usize], t0: usize, t1: usize) {
        let hd = self.w.hidden;
        let rows = 3 * hd;
        let ba = active.len();

        let mut hs = vec![0.0f64; hd * ba];
        for (j, &li) in active.iter().enumerate() {
            if let DpdState::F64(h) = &*lanes[li].state {
                for (k, &v) in h.iter().enumerate() {
                    hs[k * ba + j] = v;
                }
            }
        }
        let mut xb = vec![0.0f64; 4 * ba];
        let mut inputs = vec![[0.0f64; 2]; ba];
        let mut gi = vec![0.0f64; rows * ba];
        let mut gh = vec![0.0f64; rows * ba];

        for t in t0..t1 {
            for (j, &li) in active.iter().enumerate() {
                let s = lanes[li].iq[t];
                inputs[j] = s;
                let x = Self::features(s);
                for (c, &v) in x.iter().enumerate() {
                    xb[c * ba + j] = v;
                }
            }
            // gi = W_ih x + b_ih ; gh = W_hh h + b_hh (batch-fastest)
            for (r, &b) in self.w.b_ih.iter().enumerate() {
                gi[r * ba..(r + 1) * ba].fill(b);
            }
            for c in 0..4 {
                let col = &self.wt_ih[c * rows..(c + 1) * rows];
                let xrow = &xb[c * ba..(c + 1) * ba];
                for (r, &w) in col.iter().enumerate() {
                    for (a, &x) in gi[r * ba..(r + 1) * ba].iter_mut().zip(xrow) {
                        *a += w * x;
                    }
                }
            }
            for (r, &b) in self.w.b_hh.iter().enumerate() {
                gh[r * ba..(r + 1) * ba].fill(b);
            }
            for c in 0..hd {
                let col = &self.wt_hh[c * rows..(c + 1) * rows];
                let hrow = &hs[c * ba..(c + 1) * ba];
                for (r, &w) in col.iter().enumerate() {
                    for (a, &x) in gh[r * ba..(r + 1) * ba].iter_mut().zip(hrow) {
                        *a += w * x;
                    }
                }
            }
            // gates (Eq. 2-5), the scalar expressions per lane
            for k in 0..hd {
                for j in 0..ba {
                    let r = hardsigmoid(gi[k * ba + j] + gh[k * ba + j]);
                    let z = hardsigmoid(gi[(hd + k) * ba + j] + gh[(hd + k) * ba + j]);
                    let n = hardtanh(gi[(2 * hd + k) * ba + j] + r * gh[(2 * hd + k) * ba + j]);
                    hs[k * ba + j] = (1.0 - z) * n + z * hs[k * ba + j];
                }
            }
            // FC + residual (Eq. 6) per lane, scalar accumulation order
            for (j, &li) in active.iter().enumerate() {
                let mut y = [self.w.b_fc[0] + inputs[j][0], self.w.b_fc[1] + inputs[j][1]];
                for k in 0..hd {
                    y[0] += self.w.w_fc[k] * hs[k * ba + j];
                    y[1] += self.w.w_fc[hd + k] * hs[k * ba + j];
                }
                lanes[li].iq[t] = y;
            }
        }
        for (j, &li) in active.iter().enumerate() {
            if let DpdState::F64(h) = &mut *lanes[li].state {
                for (k, dst) in h.iter_mut().enumerate() {
                    *dst = hs[k * ba + j];
                }
            }
        }
    }
}

impl Dpd for GruDpd {
    fn process(&mut self, iq: [f64; 2]) -> [f64; 2] {
        let hd = self.w.hidden;
        let x = Self::features(iq);

        // gi = W_ih x + b_ih ; gh = W_hh h + b_hh (column-major axpys)
        let rows = 3 * hd;
        self.gi.copy_from_slice(&self.w.b_ih);
        for (c, &xv) in x.iter().enumerate() {
            let col = &self.wt_ih[c * rows..(c + 1) * rows];
            for (a, &wv) in self.gi.iter_mut().zip(col) {
                *a += wv * xv;
            }
        }
        self.gh.copy_from_slice(&self.w.b_hh);
        for c in 0..hd {
            let xv = self.h[c];
            let col = &self.wt_hh[c * rows..(c + 1) * rows];
            for (a, &wv) in self.gh.iter_mut().zip(col) {
                *a += wv * xv;
            }
        }

        gates_and_fc(&self.w, &self.gi, &self.gh, &mut self.h, iq)
    }

    fn reset(&mut self) {
        self.h.iter_mut().for_each(|v| *v = 0.0);
    }

    fn name(&self) -> &'static str {
        "gru-f64"
    }

    fn save_state(&self) -> DpdState {
        DpdState::F64(self.h.clone())
    }

    fn load_state(&mut self, state: &DpdState) -> Result<()> {
        match state {
            DpdState::F64(h) if h.len() == self.w.hidden => {
                self.h.copy_from_slice(h);
                Ok(())
            }
            other => Err(StateMismatch {
                engine: self.name(),
                got: other.kind(),
                hidden: self.w.hidden,
            }
            .into()),
        }
    }

    fn batch_fingerprint(&self) -> Option<u64> {
        Some(self.w.fingerprint())
    }

    fn process_lanes(&mut self, lanes: &mut [DpdLane<'_>]) -> Result<()> {
        if lanes.len() < 2 {
            return process_lanes_sequential(self, lanes);
        }
        self.process_lanes_soa(lanes)
    }
}

/// f64 twin of the delta execution path (`qgru::DeltaQGruDpd`) — the
/// float reference for the delta semantics.
///
/// Because float addition is not associative, a carried-sum design
/// could not be bit-identical to [`GruDpd`] at θ=0. This twin
/// therefore caches per-column *contributions* instead: for every
/// matvec column it keeps the product vector `w[:, c] * v_prev[c]`,
/// refreshed only when `|v[c] - v_prev[c]| > θ`, and re-sums the
/// cached columns in the dense engine's exact accumulation order each
/// step. At θ=0 every changed column refreshes, so the summands and
/// their order equal the dense engine's — bit-exact by construction
/// (the property suite below pins it). A skipped column saves the 3H
/// multiplies (the adds remain), which is the float model of the
/// integer engine's skipped MACs.
pub struct DeltaGruDpd {
    w: GruWeights,
    /// propagation threshold on the float feature/hidden values
    theta: f64,
    st: DeltaF64Snapshot,
    /// column-major weight copies (as in [`GruDpd`])
    wt_ih: Vec<f64>,
    wt_hh: Vec<f64>,
    gi: Vec<f64>,
    gh: Vec<f64>,
    stats: DeltaStats,
}

impl DeltaGruDpd {
    pub fn new(w: GruWeights, theta: f64) -> DeltaGruDpd {
        assert!(theta >= 0.0 && theta.is_finite(), "theta must be finite and >= 0");
        let (wt_ih, wt_hh) = transpose_gates_f64(&w);
        let st = Self::fresh_state(&w);
        let g = vec![0.0; 3 * w.hidden];
        DeltaGruDpd {
            w,
            theta,
            st,
            wt_ih,
            wt_hh,
            gi: g.clone(),
            gh: g,
            stats: DeltaStats::default(),
        }
    }

    /// Reset state: h = v_prev = 0, every cached contribution 0.0
    /// (w * 0.0 for every column — what the dense engine would add).
    fn fresh_state(w: &GruWeights) -> DeltaF64Snapshot {
        let rows = 3 * w.hidden;
        DeltaF64Snapshot {
            h: vec![0.0; w.hidden],
            x_prev: vec![0.0; w.features],
            h_prev: vec![0.0; w.hidden],
            ct_ih: vec![0.0; w.features * rows],
            ct_hh: vec![0.0; w.hidden * rows],
        }
    }

    pub fn weights(&self) -> &GruWeights {
        &self.w
    }

    pub fn theta(&self) -> f64 {
        self.theta
    }

    /// Column-update activity so far.
    pub fn stats(&self) -> DeltaStats {
        self.stats
    }
}

impl Dpd for DeltaGruDpd {
    fn process(&mut self, iq: [f64; 2]) -> [f64; 2] {
        let hd = self.w.hidden;
        let rows = 3 * hd;
        let x = GruDpd::features(iq);

        // delta passes, then re-sum and the dense downstream chain —
        // every piece shares the dense engine's op order exactly
        let st = &mut self.st;
        let (theta, stats) = (self.theta, &mut self.stats);
        refresh_cols(&self.wt_ih, &mut st.ct_ih, &x, &mut st.x_prev, theta, rows, &mut stats.in_updates);
        refresh_cols(&self.wt_hh, &mut st.ct_hh, &st.h, &mut st.h_prev, theta, rows, &mut stats.hid_updates);
        self.stats.steps += 1;
        self.stats.in_cols += self.w.features as u64;
        self.stats.hid_cols += hd as u64;

        resum_cols(&mut self.gi, &self.w.b_ih, &st.ct_ih, rows);
        resum_cols(&mut self.gh, &self.w.b_hh, &st.ct_hh, rows);
        gates_and_fc(&self.w, &self.gi, &self.gh, &mut st.h, iq)
    }

    fn reset(&mut self) {
        self.st = Self::fresh_state(&self.w);
    }

    fn name(&self) -> &'static str {
        "delta-gru-f64"
    }

    fn save_state(&self) -> DpdState {
        DpdState::DeltaF64(self.st.clone())
    }

    fn load_state(&mut self, state: &DpdState) -> Result<()> {
        let rows = 3 * self.w.hidden;
        match state {
            DpdState::DeltaF64(s)
                if s.h.len() == self.w.hidden
                    && s.h_prev.len() == self.w.hidden
                    && s.x_prev.len() == self.w.features
                    && s.ct_ih.len() == self.w.features * rows
                    && s.ct_hh.len() == self.w.hidden * rows =>
            {
                self.st = s.clone();
                Ok(())
            }
            other => Err(StateMismatch {
                engine: self.name(),
                got: other.kind(),
                hidden: self.w.hidden,
            }
            .into()),
        }
    }

    fn batch_fingerprint(&self) -> Option<u64> {
        Some(fnv1a_words(
            "delta-gru-f64",
            [self.w.fingerprint(), self.theta.to_bits()],
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_weights(seed: u64) -> GruWeights {
        let mut rng = Rng::new(seed);
        let hidden = 10;
        let features = 4;
        let bound = 1.0 / (hidden as f64).sqrt();
        let mut gen = |n: usize| -> Vec<f64> { (0..n).map(|_| rng.range(-bound, bound)).collect() };
        GruWeights {
            hidden,
            features,
            w_ih: gen(3 * hidden * features),
            b_ih: gen(3 * hidden),
            w_hh: gen(3 * hidden * hidden),
            b_hh: gen(3 * hidden),
            w_fc: gen(2 * hidden),
            b_fc: gen(2),
            meta_bits: None,
            meta_act: None,
            meta_val_nmse_db: None,
        }
    }

    #[test]
    fn activations_match_equations() {
        assert_eq!(hardsigmoid(3.0), 1.0);
        assert_eq!(hardsigmoid(-3.0), 0.0);
        assert_eq!(hardsigmoid(0.0), 0.5);
        assert_eq!(hardsigmoid(1.0), 0.75);
        assert_eq!(hardtanh(2.0), 1.0);
        assert_eq!(hardtanh(-2.0), -1.0);
        assert_eq!(hardtanh(0.3), 0.3);
    }

    #[test]
    fn reset_makes_runs_reproducible() {
        let mut dpd = GruDpd::new(rand_weights(1));
        let mut rng = Rng::new(2);
        let x: Vec<[f64; 2]> = (0..64).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect();
        let a = dpd.run(&x);
        let b = dpd.run(&x);
        assert_eq!(a, b);
    }

    #[test]
    fn recurrent_state_matters() {
        let mut dpd = GruDpd::new(rand_weights(3));
        let mut rng = Rng::new(4);
        let x: Vec<[f64; 2]> = (0..32).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect();
        let mut rev = x.clone();
        rev.reverse();
        let a = dpd.run(&x);
        let mut b = dpd.run(&rev);
        b.reverse();
        assert_ne!(a, b);
    }

    #[test]
    fn residual_at_zero_weights() {
        // zero FC weights + zero bias -> y == x exactly (the residual path)
        let mut w = rand_weights(5);
        w.w_fc.iter_mut().for_each(|v| *v = 0.0);
        w.b_fc.iter_mut().for_each(|v| *v = 0.0);
        let mut dpd = GruDpd::new(w);
        let x = [[0.1, -0.2], [0.3, 0.05]];
        let y = dpd.run(&x);
        assert_eq!(y, x.to_vec());
    }

    #[test]
    fn soa_lanes_bit_identical_to_sequential_fallback() {
        // f64 is where op-order sloppiness would show up first: the
        // SoA kernel must reproduce the scalar chain bit for bit.
        use crate::dpd::{process_lanes_sequential, DpdLane, DpdState};
        use crate::util::proptest::check;
        check("gru-f64 soa vs sequential lanes", 15, |rng| {
            let mut soa = GruDpd::new(rand_weights(rng.next_u64()));
            let mut seq = GruDpd::new(soa.weights().clone());
            let nb = rng.int_in(2, 6) as usize;
            let mut data: Vec<Vec<[f64; 2]>> = (0..nb)
                .map(|_| {
                    let len = rng.int_in(0, 48) as usize;
                    (0..len).map(|_| [rng.gauss() * 0.3, rng.gauss() * 0.3]).collect()
                })
                .collect();
            let states: Vec<DpdState> = (0..nb)
                .map(|_| DpdState::F64((0..10).map(|_| rng.range(-1.0, 1.0)).collect()))
                .collect();
            let mut data2 = data.clone();
            let mut st_a = states.clone();
            let mut st_b = states;
            let mut lanes: Vec<DpdLane> = data
                .iter_mut()
                .zip(st_a.iter_mut())
                .map(|(d, s)| DpdLane { iq: d.as_mut_slice(), state: s })
                .collect();
            soa.process_lanes(&mut lanes).map_err(|e| e.to_string())?;
            drop(lanes);
            let mut lanes: Vec<DpdLane> = data2
                .iter_mut()
                .zip(st_b.iter_mut())
                .map(|(d, s)| DpdLane { iq: d.as_mut_slice(), state: s })
                .collect();
            process_lanes_sequential(&mut seq, &mut lanes).map_err(|e| e.to_string())?;
            drop(lanes);
            if data != data2 {
                return Err("lane samples diverged".into());
            }
            if st_a != st_b {
                return Err("lane states diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn delta_theta_zero_bit_exact_to_dense_f64() {
        // The contribution-cache design makes the f64 delta twin
        // bit-identical to the dense engine at θ=0 despite float
        // non-associativity: same summands, same order.
        use crate::util::proptest::check;
        check("delta-gru theta=0 vs dense", 20, |rng| {
            let w = rand_weights(rng.next_u64());
            let mut dense = GruDpd::new(w.clone());
            let mut delta = DeltaGruDpd::new(w, 0.0);
            dense.reset();
            delta.reset();
            for t in 0..150 {
                let iq = [rng.gauss() * 0.3, rng.gauss() * 0.3];
                let a = dense.process(iq);
                let b = delta.process(iq);
                if a != b {
                    return Err(format!("outputs diverged at sample {t}: {a:?} vs {b:?}"));
                }
            }
            if dense.h != delta.st.h {
                return Err("hidden states diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn delta_f64_theta_bounds_staleness_and_tracks_dense() {
        // θ>0: propagated values stay within θ of the live ones, and
        // on a smooth stream the output tracks the dense engine within
        // a small envelope while skipping a meaningful share of
        // columns (deterministic seed — not flaky).
        let w = rand_weights(17);
        let theta = 0.005;
        let mut dense = GruDpd::new(w.clone());
        let mut delta = DeltaGruDpd::new(w, theta);
        let mut rng = Rng::new(18);
        // smooth random walk, small steps
        let mut cur = [0.0f64, 0.0];
        let (mut err, mut refp) = (0.0, 0.0);
        for _ in 0..400 {
            cur[0] = (cur[0] + rng.gauss() * 0.01).clamp(-0.6, 0.6);
            cur[1] = (cur[1] + rng.gauss() * 0.01).clamp(-0.6, 0.6);
            let a = dense.process(cur);
            let b = delta.process(cur);
            err += (a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2);
            refp += a[0] * a[0] + a[1] * a[1];
            let x = GruDpd::features(cur);
            for (c, &xp) in delta.st.x_prev.iter().enumerate() {
                assert!((x[c] - xp).abs() <= theta, "x_prev[{c}] staler than θ");
            }
        }
        let nmse_db = 10.0 * (err / refp).log10();
        assert!(nmse_db < -20.0, "delta drift too large: {nmse_db:.1} dB");
        let s = delta.stats();
        assert!(s.update_ratio() < 0.9, "smooth stream skipped nothing");
        assert!(s.steps == 400 && s.in_cols == 1600 && s.hid_cols == 4000);
    }

    #[test]
    fn delta_f64_state_snapshot_round_trips() {
        let mut dpd = DeltaGruDpd::new(rand_weights(23), 0.01);
        let mut rng = Rng::new(24);
        for _ in 0..60 {
            dpd.process([rng.gauss() * 0.25, rng.gauss() * 0.25]);
        }
        let snap = dpd.save_state();
        let probe: Vec<[f64; 2]> =
            (0..10).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect();
        let a: Vec<_> = probe.iter().map(|&s| dpd.process(s)).collect();
        dpd.load_state(&snap).unwrap();
        let b: Vec<_> = probe.iter().map(|&s| dpd.process(s)).collect();
        assert_eq!(a, b);
        // plain F64 hidden snapshots are rejected: restoring h without
        // the contribution caches would desync the engine
        assert!(dpd.load_state(&crate::dpd::DpdState::F64(vec![0.0; 10])).is_err());
        assert!(dpd.load_state(&crate::dpd::DpdState::Stateless).is_err());
    }

    #[test]
    fn delta_f64_fingerprint_separates_theta_and_weights() {
        let w = rand_weights(29);
        let a = DeltaGruDpd::new(w.clone(), 0.0);
        let b = DeltaGruDpd::new(w.clone(), 0.0);
        let c = DeltaGruDpd::new(w.clone(), 0.01);
        let dense = GruDpd::new(w);
        let other = DeltaGruDpd::new(rand_weights(30), 0.0);
        assert_eq!(a.batch_fingerprint(), b.batch_fingerprint());
        assert_ne!(a.batch_fingerprint(), c.batch_fingerprint());
        assert_ne!(a.batch_fingerprint(), other.batch_fingerprint());
        assert_ne!(a.batch_fingerprint(), dense.batch_fingerprint());
    }

    #[test]
    fn state_snapshot_round_trips() {
        let mut dpd = GruDpd::new(rand_weights(9));
        let mut rng = Rng::new(10);
        for _ in 0..40 {
            dpd.process([rng.gauss() * 0.25, rng.gauss() * 0.25]);
        }
        let snap = dpd.save_state();
        let a = dpd.process([0.1, -0.3]);
        dpd.load_state(&snap).unwrap();
        let b = dpd.process([0.1, -0.3]);
        assert_eq!(a, b);
        assert!(dpd.load_state(&crate::dpd::DpdState::I32(vec![0; 10])).is_err());
    }

    #[test]
    fn features_definition() {
        let f = GruDpd::features([0.3, -0.4]);
        let p = 4.0 * 0.25;
        assert_eq!(f, [0.3, -0.4, p, p * p]);
    }
}
