//! Engine runtime: the artifact manifest, the unified [`DpdEngine`]
//! backend, and (under `--features xla`) PJRT execution of the AOT
//! HLO-text artifacts produced by `python/compile/aot.py`. Python
//! never runs here.
//!
//! Default builds are hermetic: the PJRT paths ([`engine`], the `Hlo`
//! backend) only exist with the non-default `xla` cargo feature; the
//! interpreted fallback ([`backend::InterpGruEngine`]) covers the
//! frame-based execution mode with the in-tree bit-exact datapath.
//!
//! The content-addressed weight store ([`store`]) sits beside the
//! engines: fingerprint-keyed generations with lineage and delta
//! encoding, the distribution substrate the fleet rollout controller
//! ([`crate::coordinator::rollout`]) deploys from.

pub mod artifacts;
pub mod backend;
#[cfg(feature = "xla")]
pub mod engine;
pub mod store;

pub use artifacts::Manifest;
pub use backend::{
    build_synthetic, DpdEngine, DpdLane, DpdState, EngineBase, EngineFactory, EngineKind,
    EngineSpec,
};
pub use store::{DeltaStats, GenMeta, GenRecord, WeightSet, WeightStore};
#[cfg(feature = "xla")]
pub use engine::HloGruEngine;
