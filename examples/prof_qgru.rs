//! Profiling repro binary for the §Perf pass: runs the bit-exact
//! engine hot loop long enough for `perf record` attribution.
//!
//! ```bash
//! cargo build --release --example prof_qgru
//! perf record ./target/release/examples/prof_qgru && perf report
//! ```
use dpd_ne::dpd::qgru::{ActKind, QGruDpd};
use dpd_ne::dpd::weights::QGruWeights;
use dpd_ne::fixed::QSpec;
use dpd_ne::runtime::Manifest;

fn main() {
    let m = Manifest::discover(None).expect("run `make artifacts` first");
    let spec = QSpec::Q12;
    let w = QGruWeights::load_params_int(&m.weights_main, spec).unwrap();
    let mut dpd = QGruDpd::new(w, ActKind::Hard);
    let mut rng = dpd_ne::util::Rng::new(1);
    let codes: Vec<[i32; 2]> = (0..16384)
        .map(|_| [rng.int_in(-900, 900) as i32, rng.int_in(-900, 900) as i32])
        .collect();
    for _ in 0..300 {
        std::hint::black_box(dpd.run_codes(&codes));
    }
}
