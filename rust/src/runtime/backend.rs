//! The unified DPD engine backend: one frame-level trait over every
//! engine substrate, plus the factory the coordinator and benches use
//! to construct them.
//!
//! [`DpdEngine`] is the execution contract of the transmit chain: a
//! mutable burst of f64 I/Q goes in, the predistorted burst comes out
//! in place. Two families implement it:
//!
//! * **streaming** engines ([`StreamingEngine`] over any [`Dpd`]) —
//!   sample-in/sample-out, hidden state carries across frames (the
//!   silicon's continuous operating mode);
//! * **frame** engines ([`InterpGruEngine`], and [`HloEngine`] under
//!   `--features xla`) — shape-specialized to a compiled frame length,
//!   hidden state resets at every frame start (h0 = 0, the AOT HLO
//!   artifact's training convention). They report the length through
//!   [`DpdEngine::frame_len`] so the framer can match it.
//!
//! Parity contract (enforced by the unit tests below, the golden
//! vectors and the conformance matrix in `tests/conformance.rs`):
//! `Fixed`, `CycleSim`, `Interp` and `DeltaFixed` at θ=0 share the
//! bit-exact integer datapath — equal inputs give *identical* outputs
//! (modulo the frame-reset semantics of `Interp`). `DeltaFixed` with
//! θ>0 deliberately trades bounded drift for skipped MACs (golden
//! delta trace pins the envelope). `FixedSimd`/`DeltaFixedSimd` are
//! the same datapaths behind the vector
//! [`GateKernel`](crate::fixed::GateKernel) and are bit-identical to
//! their scalar twins on every host (the kernel seam's contract) —
//! including when the host lacks AVX2 or `DPD_SIMD=off` forces the
//! scalar fallback. `NativeF64` is the float
//! reference; it tracks the integer engines within the quantization
//! envelope (documented tolerance: NMSE better than -12 dB and
//! per-sample deviation under 0.3 on small-signal stimulus at Q2.10).
//!
//! Engine selection is string-addressable: [`EngineKind::parse`] and
//! `Display` round-trip the spec grammar `native |
//! fixed[@WwAa][+sparse:ρ][+simd] | delta[:θ][@WwAa][+sparse:ρ][+simd]
//! | cyclesim | interp | hlo` — the `@WwAa` (per-tensor
//! mixed-precision profile) and `+sparse:ρ` (magnitude pruning)
//! decorations select the [`SparseMpGruDpd`] family member — and
//! [`EngineFactory::available_kinds`] returns structured
//! [`EngineDescriptor`] rows (kind, spec, syntax, host SIMD state) so
//! CLI help and examples render from the registry instead of
//! hardcoded lists.
//!
//! Without the `xla` feature, `EngineKind::Hlo` does not exist and the
//! frame-semantics role is served by `Interp` — the pure-Rust
//! *interpreted* twin of the HLO artifact: the same bit-exact
//! `QGruDpd` datapath the artifact was lowered from, run with the same
//! per-frame h0 reset and tail zero-padding. Default builds therefore
//! stay hermetic (no PJRT, no network) without losing the frame path.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::accel::act_unit::ActImpl;
use crate::accel::fsm::HwConfig;
use crate::accel::CycleAccurateEngine;
use crate::dpd::qgru::{ActKind, DeltaQGruDpd, QGruDpd};
use crate::dpd::weights::{GruWeights, QGruWeights};
use crate::dpd::{Dpd, GruDpd, SparseMpGruDpd};
use crate::fixed::kernel::{resolve_simd, SimdPolicy};
use crate::fixed::{QProfile, QSpec};
use crate::runtime::Manifest;
use crate::util::fnv1a_words;

pub use crate::dpd::{DpdLane, DpdState};

/// Frame length used by `Interp` when the artifact tree carries no
/// lowered HLO entry to inherit a shape from.
pub const DEFAULT_FRAME_LEN: usize = 2048;

/// Which DPD engine a worker instantiates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// f64 GRU (float reference)
    NativeF64,
    /// bit-exact Q2.10 fixed-point (the chip's functional model)
    Fixed,
    /// delta-sparsity fixed-point: `Fixed`'s hot loop with DeltaDPD
    /// column skipping at threshold `theta` (codes). θ=0 is
    /// bit-identical to `Fixed` — the contract the conformance matrix
    /// enforces; θ>0 trades bounded ACPR/EVM drift for skipped MACs
    DeltaFixed {
        /// propagation threshold in Q-format codes
        theta: u32,
    },
    /// `Fixed`'s datapath behind the vector
    /// [`GateKernel`](crate::fixed::GateKernel) (AVX2, runtime
    /// detected). Bit-identical to `Fixed` by the kernel seam's
    /// contract; on hosts without AVX2, or under `DPD_SIMD=off` /
    /// [`SimdPolicy::Off`], the engine silently carries the scalar
    /// kernel instead — same bits, no error
    FixedSimd,
    /// `DeltaFixed` composed with the vector kernel — the same
    /// fallback and bit-exactness contract as `FixedSimd`, applied to
    /// the i64 delta accumulators
    DeltaFixedSimd {
        /// propagation threshold in Q-format codes
        theta: u32,
    },
    /// the SparseDPD x MP-DPD family member: magnitude-pruned
    /// compressed sparse-column gate tensors
    /// ([`SparseQGruWeights`](crate::dpd::SparseQGruWeights)) with
    /// per-tensor mixed-precision formats
    /// ([`QProfile`](crate::fixed::QProfile)), composable with the
    /// delta threshold and the vector kernel. Invariant: at least one
    /// of `profile` / `rho` is `Some` (otherwise the spec string would
    /// collide with the plain `Fixed`/`DeltaFixed` spellings — `parse`
    /// only constructs decorated kinds). ρ=0 at a uniform profile and
    /// θ=0 is bit-identical to `Fixed` (the conformance hinge).
    SparseMp {
        /// `Some((w, a))` = per-tensor weight bits `w`, activation
        /// bits `a` (the `@WwAa` decoration); `None` = uniform at the
        /// manifest's Q-format
        profile: Option<(u8, u8)>,
        /// `Some(ρ)` = prune the ρ% smallest-magnitude codes per gate
        /// tensor (the `+sparse:ρ` decoration); `None` = keep dense
        rho: Option<u8>,
        /// `Some(θ)` = compose with DeltaDPD column skipping at
        /// threshold θ (the `delta:θ` base); `None` = the `fixed` base
        theta: Option<u32>,
        /// run the gather loops behind the vector kernel (the `+simd`
        /// suffix; same scalar-fallback contract as `FixedSimd`)
        simd: bool,
    },
    /// cycle-accurate ASIC simulator
    CycleSim,
    /// interpreted frame engine: the bit-exact `QGruDpd` run with the
    /// HLO artifact's frame semantics (h0 reset per frame) — the
    /// hermetic stand-in for `Hlo`
    Interp,
    /// AOT HLO via the PJRT CPU client (frame-based)
    #[cfg(feature = "xla")]
    Hlo,
}

impl std::fmt::Display for EngineKind {
    /// The canonical engine-spec string; [`EngineKind::parse`] is the
    /// exact inverse (round-trip contract, pinned by the unit tests).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineKind::NativeF64 => write!(f, "native"),
            EngineKind::Fixed => write!(f, "fixed"),
            EngineKind::DeltaFixed { theta } => write!(f, "delta:{theta}"),
            EngineKind::FixedSimd => write!(f, "fixed+simd"),
            EngineKind::DeltaFixedSimd { theta } => write!(f, "delta:{theta}+simd"),
            EngineKind::SparseMp { profile, rho, theta, simd } => {
                match theta {
                    Some(t) => write!(f, "delta:{t}")?,
                    None => write!(f, "fixed")?,
                }
                if let Some((w, a)) = profile {
                    write!(f, "@W{w}A{a}")?;
                }
                if let Some(r) = rho {
                    write!(f, "+sparse:{r}")?;
                }
                if *simd {
                    write!(f, "+simd")?;
                }
                Ok(())
            }
            EngineKind::CycleSim => write!(f, "cyclesim"),
            EngineKind::Interp => write!(f, "interp"),
            #[cfg(feature = "xla")]
            EngineKind::Hlo => write!(f, "hlo"),
        }
    }
}

impl EngineKind {
    /// Parse an engine-spec string — the single grammar every surface
    /// (CLI `--engine`, conformance scenario labels, service configs)
    /// shares:
    ///
    /// ```text
    /// native | fixed[@WwAa][+sparse:ρ][+simd]
    ///        | delta[:θ][@WwAa][+sparse:ρ][+simd]
    ///        | cyclesim | interp | hlo
    /// ```
    ///
    /// Bare `delta` means θ=0 (the bit-exact hinge). The `@WwAa` /
    /// `+sparse:ρ` decorations select the sparse + mixed-precision
    /// family ([`EngineKind::SparseMp`]) and compose only with the
    /// `fixed` / `delta[:θ]` bases; `+simd` composes only with the
    /// kernel-seam kinds (`fixed`, `delta`, and the decorated family);
    /// anything else with a suffix is rejected rather than silently
    /// ignored. `parse(&k.to_string()) == k` for every kind in this
    /// build.
    pub fn parse(spec: &str) -> Result<EngineKind> {
        let s = spec.trim();
        let (decorated, simd) = match s.strip_suffix("+simd") {
            Some(b) => (b, true),
            None => (s, false),
        };
        // the sparse/mixed-precision decorations, outermost first
        // (Display order is base[@WwAa][+sparse:ρ], so strip +sparse
        // from the tail before splitting the profile off the base)
        let (rest, rho) = match decorated.split_once("+sparse:") {
            Some((b, r)) => {
                let rho: u8 = r.parse().with_context(|| {
                    format!("bad ρ in engine spec '{spec}' (want +sparse:<percent>)")
                })?;
                if rho > 100 {
                    bail!("engine spec '{spec}': sparsity ρ={rho} is a percentage (0..=100)");
                }
                (b, Some(rho))
            }
            None => (decorated, None),
        };
        let (base, profile) = match rest.split_once('@') {
            Some((b, p)) => (b, Some(parse_profile_bits(p).with_context(|| {
                format!("bad precision profile in engine spec '{spec}' (want @W<bits>A<bits>)")
            })?)),
            None => (rest, None),
        };
        if profile.is_some() || rho.is_some() {
            let theta = if base == "fixed" {
                None
            } else if base == "delta" {
                Some(0)
            } else if let Some(t) = base.strip_prefix("delta:") {
                Some(t.parse().with_context(|| {
                    format!("bad θ in engine spec '{spec}' (want delta:<codes>)")
                })?)
            } else {
                bail!(
                    "engine spec '{spec}': '@WwAa' / '+sparse:ρ' compose only with \
                     'fixed' or 'delta[:θ]'"
                );
            };
            return Ok(EngineKind::SparseMp { profile, rho, theta, simd });
        }
        if base == "delta" || base.starts_with("delta:") {
            let theta: u32 = match base.strip_prefix("delta:") {
                Some(t) => t
                    .parse()
                    .with_context(|| format!("bad θ in engine spec '{spec}' (want delta:<codes>)"))?,
                None => 0,
            };
            return Ok(if simd {
                EngineKind::DeltaFixedSimd { theta }
            } else {
                EngineKind::DeltaFixed { theta }
            });
        }
        if base == "fixed" {
            return Ok(if simd { EngineKind::FixedSimd } else { EngineKind::Fixed });
        }
        if simd {
            bail!("engine spec '{spec}': '+simd' composes only with 'fixed' or 'delta[:θ]'");
        }
        Ok(match base {
            "native" | "native-f64" => EngineKind::NativeF64,
            "cyclesim" => EngineKind::CycleSim,
            "interp" => EngineKind::Interp,
            #[cfg(feature = "xla")]
            "hlo" => EngineKind::Hlo,
            #[cfg(not(feature = "xla"))]
            "hlo" => bail!("engine 'hlo' needs a build with --features xla (try 'interp')"),
            other => bail!(
                "unknown engine '{other}' \
                 (spec grammar: native | fixed[@WwAa][+sparse:ρ][+simd] | \
                 delta[:θ][@WwAa][+sparse:ρ][+simd] | cyclesim | interp | hlo)"
            ),
        })
    }
}

/// Parse the `W<bits>A<bits>` payload of an `@` decoration into the
/// `(weight_bits, act_bits)` pair [`EngineKind::SparseMp`] carries,
/// validating ranges through [`QProfile::wa`] so a spec string can
/// never name a profile the engine cannot construct.
fn parse_profile_bits(s: &str) -> Result<(u8, u8)> {
    let p = QProfile::parse_wa(s)?;
    let w = p.weight_bits().expect("wa profiles are weight-homogeneous");
    Ok((w as u8, p.act.bits as u8))
}

impl std::str::FromStr for EngineKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<EngineKind> {
        EngineKind::parse(s)
    }
}

/// One registry row from [`EngineFactory::available_kinds`]: the
/// structured description CLI help, examples and reports render from,
/// so the engine list can never drift from what the build constructs.
#[derive(Clone, Debug)]
pub struct EngineDescriptor {
    /// canonical kind (θ=0 for the delta family's registry row)
    pub kind: EngineKind,
    /// canonical spec string, `kind.to_string()`
    pub spec: String,
    /// human-facing spec syntax, e.g. `"delta[:θ][+simd]"`
    pub syntax: &'static str,
    /// `Some(active)` for kernel-seam kinds: whether the vector kernel
    /// would engage on this host under [`SimdPolicy::Auto`] (AVX2
    /// detected and not vetoed by `DPD_SIMD`); `None` for kinds with
    /// no kernel seam
    pub simd: Option<bool>,
}

/// A DPD engine behind the unified frame-level interface.
pub trait DpdEngine {
    /// Engine label for reports and stats.
    fn name(&self) -> &'static str;

    /// `Some(n)` when the engine is shape-specialized to n-sample
    /// frames (the framer should cut the stream accordingly);
    /// `None` for streaming engines that accept any burst length.
    fn frame_len(&self) -> Option<usize> {
        None
    }

    /// Predistort a burst in place. Streaming engines carry hidden
    /// state across calls; frame engines process in `frame_len()`
    /// chunks with a state reset at each frame start, zero-padding a
    /// ragged tail internally (the output keeps the input length).
    fn process_frame(&mut self, iq: &mut [[f64; 2]]) -> Result<()>;

    /// Reset internal state (no-op for frame engines, which reset at
    /// every frame anyway).
    fn reset(&mut self);

    /// Snapshot the current stream's recurrent state (the lane payload
    /// of a batched call). Default: [`DpdState::Stateless`]; stateful
    /// engines override this together with [`DpdEngine::load_state`]
    /// so the pair round-trips exactly.
    fn save_state(&self) -> DpdState {
        DpdState::Stateless
    }

    /// Restore a snapshot from [`DpdEngine::save_state`] on the same
    /// engine kind and shape.
    fn load_state(&mut self, state: &DpdState) -> Result<()> {
        match state {
            DpdState::Stateless => Ok(()),
            other => {
                anyhow::bail!("{}: cannot load a {} state snapshot", self.name(), other.kind())
            }
        }
    }

    /// Coalescing identity: engines with equal `Some` classes promise
    /// identical datapaths (kind + format + weights + activation), so
    /// the scheduler may gather their sessions' frames into one
    /// [`DpdEngine::run_batch`] call on any one of them. `None` (the
    /// default) opts out of coalescing entirely.
    fn batch_class(&self) -> Option<u64> {
        None
    }

    /// Batched execution over several independent streams: lane k's
    /// samples in `lanes[k].iq`, its recurrent state in
    /// `lanes[k].state`, both updated in place. Must be bit-identical,
    /// lane for lane, to processing each stream alone through
    /// [`DpdEngine::process_frame`] (the batch-parity contract). On
    /// error the whole batch is reported failed and the lanes must be
    /// discarded (already-processed lanes may have advanced) — the
    /// scheduler poisons every member session and drops the frames.
    ///
    /// The default multiplexes lanes sequentially via
    /// `save_state`/`load_state` (valid for engines whose snapshots
    /// round-trip their full state, and trivially for stateless frame
    /// engines); `self`'s own stream state is preserved.
    fn run_batch(&mut self, lanes: &mut [DpdLane<'_>]) -> Result<()> {
        run_batch_sequential(self, lanes)
    }
}

/// The sequential fallback behind [`DpdEngine::run_batch`].
pub fn run_batch_sequential<E: DpdEngine + ?Sized>(
    engine: &mut E,
    lanes: &mut [DpdLane<'_>],
) -> Result<()> {
    let own = engine.save_state();
    let mut result = Ok(());
    for lane in lanes.iter_mut() {
        if let Err(e) = engine.load_state(lane.state) {
            result = Err(e);
            break;
        }
        if let Err(e) = engine.process_frame(lane.iq) {
            result = Err(e);
            break;
        }
        *lane.state = engine.save_state();
    }
    engine.load_state(&own).ok();
    result
}

/// Adapter: any streaming [`Dpd`] as a [`DpdEngine`].
pub struct StreamingEngine {
    inner: Box<dyn Dpd>,
}

impl StreamingEngine {
    pub fn new(inner: Box<dyn Dpd>) -> StreamingEngine {
        StreamingEngine { inner }
    }
}

impl DpdEngine for StreamingEngine {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn process_frame(&mut self, iq: &mut [[f64; 2]]) -> Result<()> {
        for s in iq.iter_mut() {
            *s = self.inner.process(*s);
        }
        Ok(())
    }

    fn reset(&mut self) {
        self.inner.reset();
    }

    fn save_state(&self) -> DpdState {
        self.inner.save_state()
    }

    fn load_state(&mut self, state: &DpdState) -> Result<()> {
        self.inner.load_state(state)
    }

    fn batch_class(&self) -> Option<u64> {
        self.inner.batch_fingerprint()
    }

    fn run_batch(&mut self, lanes: &mut [DpdLane<'_>]) -> Result<()> {
        // delegate to the Dpd-level batched path (SoA kernels for
        // QGruDpd/GruDpd, sequential multiplexing otherwise)
        self.inner.process_lanes(lanes)
    }
}

/// Adapter: the cycle-accurate simulator as a streaming [`Dpd`].
pub struct CycleSimDpd {
    sim: CycleAccurateEngine,
    spec: QSpec,
    /// batch-class fingerprint, resolved once at construction
    fingerprint: u64,
}

impl CycleSimDpd {
    pub fn new(w: &QGruWeights) -> CycleSimDpd {
        CycleSimDpd {
            sim: CycleAccurateEngine::new(w, ActImpl::Hard, HwConfig::default()),
            spec: w.spec,
            fingerprint: fnv1a_words("cyclesim-hard", [w.fingerprint()]),
        }
    }
}

impl Dpd for CycleSimDpd {
    fn process(&mut self, iq: [f64; 2]) -> [f64; 2] {
        let codes = [self.spec.quantize(iq[0]), self.spec.quantize(iq[1])];
        let y = self.sim.step(codes).expect("sim step");
        [self.spec.dequantize(y[0]), self.spec.dequantize(y[1])]
    }
    fn reset(&mut self) {
        self.sim.reset();
    }
    fn name(&self) -> &'static str {
        "cyclesim"
    }
    fn save_state(&self) -> DpdState {
        DpdState::I32(self.sim.hidden_state())
    }
    fn load_state(&mut self, state: &DpdState) -> Result<()> {
        match state {
            DpdState::I32(h) => self.sim.set_hidden_state(h),
            other => anyhow::bail!("cyclesim: incompatible state snapshot ({})", other.kind()),
        }
    }
    fn batch_fingerprint(&self) -> Option<u64> {
        // sessions coalesce via the default sequential lane multiplexer
        // (no SoA kernel for the cycle model — it exercises the trait's
        // fallback path in the parity suite)
        Some(self.fingerprint)
    }
}

/// The interpreted frame engine: bit-exact `QGruDpd` with the HLO
/// artifact's frame semantics (h0 = 0 at frame start, zero-padded
/// tail). On the code grid its output equals the lowered artifact's.
pub struct InterpGruEngine {
    dpd: QGruDpd,
    frame_len: usize,
}

impl InterpGruEngine {
    pub fn new(dpd: QGruDpd, frame_len: usize) -> InterpGruEngine {
        assert!(frame_len > 0);
        InterpGruEngine { dpd, frame_len }
    }
}

impl DpdEngine for InterpGruEngine {
    fn name(&self) -> &'static str {
        "interp-qgru"
    }

    fn frame_len(&self) -> Option<usize> {
        Some(self.frame_len)
    }

    fn process_frame(&mut self, iq: &mut [[f64; 2]]) -> Result<()> {
        let spec = self.dpd.spec();
        let t = self.frame_len;
        let mut frame = vec![[0i32; 2]; t];
        for chunk in iq.chunks_mut(t) {
            let n = chunk.len();
            for (dst, s) in frame.iter_mut().zip(chunk.iter()) {
                *dst = [spec.quantize(s[0]), spec.quantize(s[1])];
            }
            for dst in frame.iter_mut().skip(n) {
                *dst = [0, 0];
            }
            // run_codes resets the hidden state first — frame semantics
            let y = self.dpd.run_codes(&frame);
            for (dst, &[i, q]) in chunk.iter_mut().zip(&y) {
                *dst = [spec.dequantize(i), spec.dequantize(q)];
            }
        }
        Ok(())
    }

    fn reset(&mut self) {}

    fn batch_class(&self) -> Option<u64> {
        // stateless across process_frame calls (h0 resets every frame),
        // so the default sequential run_batch is trivially bit-exact;
        // the class still gates coalescing to identical datapaths
        self.dpd
            .batch_fingerprint()
            .map(|fp| fnv1a_words("interp-frame", [fp, self.frame_len as u64]))
    }
}

/// The PJRT-executed AOT HLO artifact as a [`DpdEngine`].
#[cfg(feature = "xla")]
pub struct HloEngine {
    // the client must outlive the executable compiled on it
    _client: xla::PjRtClient,
    inner: crate::runtime::HloGruEngine,
    /// coalescing identity of the compiled artifact (file + shape +
    /// format), resolved once at load
    batch_class: u64,
}

#[cfg(feature = "xla")]
impl HloEngine {
    /// Compile the best integer HLO artifact of a manifest.
    pub fn load(m: &Manifest) -> Result<HloEngine> {
        let e = m.best_int_hlo().context("no integer HLO artifact")?.clone();
        let client = xla::PjRtClient::cpu()?;
        let spec = QSpec::new(e.bits)?;
        let inner = crate::runtime::HloGruEngine::load(
            &client,
            &m.hlo_path(&e),
            e.batch,
            e.time,
            true,
            Some(spec),
        )?;
        // coalescing identity is *content*-true like every other
        // engine's (weight fingerprints): hash the compiled artifact's
        // bytes + shape + format, so regenerating the tree in place
        // can never alias a stale executable with a fresh one
        let path = m.hlo_path(&e);
        let text = std::fs::read(&path)
            .with_context(|| format!("reading {} for the batch class", path.display()))?;
        let batch_class = fnv1a_words(
            "hlo-frame",
            [e.batch as u64, e.time as u64, e.bits as u64]
                .into_iter()
                .chain(text.into_iter().map(u64::from)),
        );
        Ok(HloEngine { _client: client, inner, batch_class })
    }
}

#[cfg(feature = "xla")]
impl DpdEngine for HloEngine {
    fn name(&self) -> &'static str {
        "hlo-pjrt"
    }

    fn frame_len(&self) -> Option<usize> {
        Some(self.inner.time)
    }

    fn process_frame(&mut self, iq: &mut [[f64; 2]]) -> Result<()> {
        let out = self.inner.run_burst(iq)?;
        iq.copy_from_slice(&out);
        Ok(())
    }

    // Frame engine: hidden state resets at every frame start (the AOT
    // artifact's training convention), so there is no cross-frame
    // stream state to reset or snapshot — the `save_state`/`load_state`
    // defaults (`Stateless`) are exact, and the default sequential
    // `run_batch` is trivially bit-identical to solo processing.
    fn reset(&mut self) {}

    fn batch_class(&self) -> Option<u64> {
        // stateless per frame (like Interp): sequential lane
        // multiplexing is exact, and the class gates coalescing to
        // sessions compiled against the identical artifact
        Some(self.batch_class)
    }
}

/// Resolves an [`EngineKind`] against an artifact tree and builds
/// engines from it. Construction happens on the caller's thread (the
/// manifest is `Send`); [`EngineFactory::build`] runs wherever the
/// engine will live — the PJRT client is `!Send`, so the coordinator
/// calls it inside the worker thread.
pub struct EngineFactory {
    kind: EngineKind,
    manifest: Arc<Manifest>,
    frame_len: Option<usize>,
    /// kernel policy for the `*Simd` kinds: `Auto` (host detection +
    /// the `DPD_SIMD` veto) or `Off` (force the scalar kernel)
    simd: SimdPolicy,
}

impl EngineFactory {
    /// Discover the artifact tree and resolve the engine's preferred
    /// frame length (frame engines inherit the lowered artifact's
    /// compiled shape).
    pub fn new(kind: EngineKind, artifacts: Option<&Path>) -> Result<EngineFactory> {
        EngineFactory::from_manifest(kind, Arc::new(Manifest::discover(artifacts)?))
    }

    /// Build a factory over an already-resolved manifest. This is how
    /// a [`DpdService`](crate::coordinator::DpdService) shares one
    /// manifest (discovery + JSON parse done once) across every
    /// session it opens, instead of re-resolving per stream.
    pub fn from_manifest(kind: EngineKind, manifest: Arc<Manifest>) -> Result<EngineFactory> {
        let frame_len = match kind {
            EngineKind::Interp => Some(
                manifest.best_int_hlo().map(|e| e.time).unwrap_or(DEFAULT_FRAME_LEN),
            ),
            #[cfg(feature = "xla")]
            EngineKind::Hlo => {
                Some(manifest.best_int_hlo().context("no integer HLO artifact")?.time)
            }
            _ => None,
        };
        Ok(EngineFactory { kind, manifest, frame_len, simd: SimdPolicy::default() })
    }

    /// Override the SIMD kernel policy (default [`SimdPolicy::Auto`]).
    /// `Off` forces the scalar kernel even on AVX2 hosts — the
    /// `DPD_SIMD=off` escape hatch, routed here by
    /// [`ServiceConfig`](crate::coordinator::ServiceConfig).
    pub fn with_simd_policy(mut self, simd: SimdPolicy) -> EngineFactory {
        self.simd = simd;
        self
    }

    /// Structured descriptors for every kind this build can construct,
    /// with the host's SIMD state resolved — the single source of
    /// truth for CLI help and `examples/end_to_end.rs`.
    pub fn available_kinds() -> Vec<EngineDescriptor> {
        let host_simd = resolve_simd(SimdPolicy::Auto).is_some();
        available_kinds()
            .into_iter()
            .map(|kind| {
                let (syntax, simd) = match kind {
                    EngineKind::NativeF64 => ("native", None),
                    EngineKind::Fixed => ("fixed", Some(false)),
                    EngineKind::DeltaFixed { .. } => ("delta[:θ]", Some(false)),
                    EngineKind::FixedSimd => ("fixed+simd", Some(host_simd)),
                    EngineKind::DeltaFixedSimd { .. } => ("delta[:θ]+simd", Some(host_simd)),
                    EngineKind::SparseMp { simd, .. } => (
                        "fixed|delta[:θ][@WwAa][+sparse:ρ][+simd]",
                        Some(simd && host_simd),
                    ),
                    EngineKind::CycleSim => ("cyclesim", None),
                    EngineKind::Interp => ("interp", None),
                    #[cfg(feature = "xla")]
                    EngineKind::Hlo => ("hlo", None),
                };
                EngineDescriptor { kind, spec: kind.to_string(), syntax, simd }
            })
            .collect()
    }

    pub fn kind(&self) -> EngineKind {
        self.kind
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The shared manifest handle (cheap to clone into more factories).
    pub fn manifest_arc(&self) -> Arc<Manifest> {
        Arc::clone(&self.manifest)
    }

    /// The frame length the framer should cut: the engine's compiled
    /// shape for frame engines, `default` for streaming engines.
    pub fn frame_len(&self, default: usize) -> usize {
        self.frame_len.unwrap_or(default)
    }

    /// Construct the engine (call on the thread that will run it).
    pub fn build(&self) -> Result<Box<dyn DpdEngine>> {
        let m = &self.manifest;
        Ok(match self.kind {
            EngineKind::NativeF64 => {
                let w = GruWeights::load(&m.weights_float)?;
                Box::new(StreamingEngine::new(Box::new(GruDpd::new(w))))
            }
            EngineKind::Fixed => {
                let spec = QSpec::new(m.qspec_bits)?;
                let w = QGruWeights::load_params_int(&m.weights_main, spec)?;
                Box::new(StreamingEngine::new(Box::new(QGruDpd::new(w, ActKind::Hard))))
            }
            EngineKind::DeltaFixed { theta } => {
                let spec = QSpec::new(m.qspec_bits)?;
                let w = QGruWeights::load_params_int(&m.weights_main, spec)?;
                Box::new(StreamingEngine::new(Box::new(DeltaQGruDpd::new(
                    w,
                    ActKind::Hard,
                    theta,
                ))))
            }
            EngineKind::FixedSimd => {
                let spec = QSpec::new(m.qspec_bits)?;
                let w = QGruWeights::load_params_int(&m.weights_main, spec)?;
                match resolve_simd(self.simd) {
                    Some(k) => Box::new(StreamingEngine::new(Box::new(QGruDpd::with_kernel(
                        w,
                        ActKind::Hard,
                        k,
                    )))),
                    // always-available fallback, bit-identical by the
                    // kernel seam's contract
                    None => {
                        Box::new(StreamingEngine::new(Box::new(QGruDpd::new(w, ActKind::Hard))))
                    }
                }
            }
            EngineKind::DeltaFixedSimd { theta } => {
                let spec = QSpec::new(m.qspec_bits)?;
                let w = QGruWeights::load_params_int(&m.weights_main, spec)?;
                match resolve_simd(self.simd) {
                    Some(k) => Box::new(StreamingEngine::new(Box::new(
                        DeltaQGruDpd::with_kernel(w, ActKind::Hard, theta, k),
                    ))),
                    None => Box::new(StreamingEngine::new(Box::new(DeltaQGruDpd::new(
                        w,
                        ActKind::Hard,
                        theta,
                    )))),
                }
            }
            EngineKind::SparseMp { profile, rho, theta, simd } => {
                let spec = QSpec::new(m.qspec_bits)?;
                let rho_pct = rho.unwrap_or(0);
                let theta = theta.unwrap_or(0);
                // profile-less specs prune the manifest's *integer*
                // codes directly, so `fixed+sparse:0` is bit-identical
                // to `fixed` from the very same artifact tree; an
                // explicit @WwAa profile needs the float twin to
                // requantize from
                let sw = match profile {
                    None => {
                        QGruWeights::load_params_int(&m.weights_main, spec)?.to_sparse(rho_pct)
                    }
                    Some((wb, ab)) => {
                        let prof = QProfile::wa(wb as u32, ab as u32)?;
                        GruWeights::load(&m.weights_float)?.prune_quantize(prof, rho_pct)?
                    }
                };
                match (simd, resolve_simd(self.simd)) {
                    (true, Some(k)) => Box::new(StreamingEngine::new(Box::new(
                        SparseMpGruDpd::with_kernel(sw, ActKind::Hard, theta, k),
                    ))),
                    _ => Box::new(StreamingEngine::new(Box::new(SparseMpGruDpd::new(
                        sw,
                        ActKind::Hard,
                        theta,
                    )))),
                }
            }
            EngineKind::CycleSim => {
                let spec = QSpec::new(m.qspec_bits)?;
                let w = QGruWeights::load_params_int(&m.weights_main, spec)?;
                Box::new(StreamingEngine::new(Box::new(CycleSimDpd::new(&w))))
            }
            EngineKind::Interp => {
                let spec = QSpec::new(m.qspec_bits)?;
                let w = QGruWeights::load_params_int(&m.weights_main, spec)?;
                let frame = self.frame_len.unwrap_or(DEFAULT_FRAME_LEN);
                Box::new(InterpGruEngine::new(QGruDpd::new(w, ActKind::Hard), frame))
            }
            #[cfg(feature = "xla")]
            EngineKind::Hlo => Box::new(HloEngine::load(m)?),
        })
    }
}

impl EngineFactory {
    /// The engine-spec registry rendered as a Markdown table — the
    /// generator behind the README's engine table (embedded between
    /// `<!-- engine-spec-table:begin/end -->` markers and pinned by a
    /// drift-guard test, so the docs cannot diverge from what this
    /// build constructs). Deliberately host- and feature-independent:
    /// only the registry's syntax column is used (no live SIMD
    /// detection), and the feature-gated `hlo` row is appended
    /// statically so default and `--features xla` builds render the
    /// same table.
    pub fn spec_table_markdown() -> String {
        fn describe(kind: EngineKind) -> (&'static str, &'static str) {
            match kind {
                EngineKind::NativeF64 => (
                    "f64 GRU (float reference)",
                    "tracks the integer engines within the quantization envelope",
                ),
                EngineKind::Fixed => (
                    "bit-exact Q2.10 fixed point",
                    "the chip's functional model; the conformance baseline",
                ),
                EngineKind::DeltaFixed { .. } => (
                    "delta-sparsity fixed point",
                    "θ=0 is bit-identical to `fixed`; θ>0 skips MACs with bounded drift",
                ),
                EngineKind::FixedSimd => (
                    "`fixed` behind the AVX2 gate kernels",
                    "bit-identical to `fixed`; scalar fallback off-AVX2 or under `DPD_SIMD=off`",
                ),
                EngineKind::DeltaFixedSimd { .. } => (
                    "`delta` behind the AVX2 gate kernels",
                    "same fallback and bit-exactness contract, on the i64 delta accumulators",
                ),
                EngineKind::SparseMp { .. } => (
                    "magnitude-pruned sparse + mixed-precision fixed point",
                    "CSC gate tensors at ρ% pruning, per-tensor W/A widths; ρ=0 at a \
                     uniform profile and θ=0 is bit-identical to `fixed`",
                ),
                EngineKind::CycleSim => (
                    "cycle-accurate ASIC simulator",
                    "bit-identical to `fixed`, plus cycle/energy accounting",
                ),
                EngineKind::Interp => (
                    "interpreted frame engine",
                    "the bit-exact datapath with the HLO artifact's per-frame h0 reset",
                ),
                #[cfg(feature = "xla")]
                EngineKind::Hlo => unreachable!("hlo row is rendered statically"),
            }
        }
        let mut out = String::from("| spec | engine | notes |\n|---|---|---|\n");
        for row in EngineFactory::available_kinds() {
            #[cfg(feature = "xla")]
            if row.kind == EngineKind::Hlo {
                continue;
            }
            let (what, notes) = describe(row.kind);
            out.push_str(&format!("| `{}` | {} | {} |\n", row.syntax, what, notes));
        }
        out.push_str(
            "| `hlo` | AOT-lowered HLO via the PJRT CPU client | needs a build with \
             `--features xla`; `interp` is its hermetic twin |\n",
        );
        out
    }
}

/// Build a hermetic engine of `kind` from the shared synthetic weight
/// fixtures ([`QGruWeights::synthetic`] / [`GruWeights::synthetic`],
/// seeded, no artifact tree) — the construction path of the fleet
/// tests and the `loadgen` harness. Engines built here obey the same
/// parity contract as manifest-backed ones: equal `(kind, seed)` give
/// bit-identical engines wherever they run. `frame_len` only affects
/// the frame-based `Interp` kind (`None` = [`DEFAULT_FRAME_LEN`]);
/// `hlo` has no synthetic form (it needs a compiled artifact) and is
/// rejected.
pub fn build_synthetic(
    kind: EngineKind,
    seed: u64,
    simd: SimdPolicy,
    frame_len: Option<usize>,
) -> Result<Box<dyn DpdEngine>> {
    let qw = || QGruWeights::synthetic(seed, QSpec::Q12);
    Ok(match kind {
        EngineKind::NativeF64 => {
            Box::new(StreamingEngine::new(Box::new(GruDpd::new(GruWeights::synthetic(seed)))))
        }
        EngineKind::Fixed => {
            Box::new(StreamingEngine::new(Box::new(QGruDpd::new(qw(), ActKind::Hard))))
        }
        EngineKind::DeltaFixed { theta } => Box::new(StreamingEngine::new(Box::new(
            DeltaQGruDpd::new(qw(), ActKind::Hard, theta),
        ))),
        EngineKind::FixedSimd => match resolve_simd(simd) {
            Some(k) => Box::new(StreamingEngine::new(Box::new(QGruDpd::with_kernel(
                qw(),
                ActKind::Hard,
                k,
            )))),
            None => Box::new(StreamingEngine::new(Box::new(QGruDpd::new(qw(), ActKind::Hard)))),
        },
        EngineKind::DeltaFixedSimd { theta } => match resolve_simd(simd) {
            Some(k) => Box::new(StreamingEngine::new(Box::new(DeltaQGruDpd::with_kernel(
                qw(),
                ActKind::Hard,
                theta,
                k,
            )))),
            None => Box::new(StreamingEngine::new(Box::new(DeltaQGruDpd::new(
                qw(),
                ActKind::Hard,
                theta,
            )))),
        },
        EngineKind::SparseMp { profile, rho, theta, simd: want_simd } => {
            let rho_pct = rho.unwrap_or(0);
            let theta = theta.unwrap_or(0);
            // profile-less kinds prune the same integer fixture Fixed
            // uses (ρ=0 ≡ `fixed`, bit for bit); an explicit profile
            // requantizes the float fixture per tensor
            let sw = match profile {
                None => qw().to_sparse(rho_pct),
                Some((wb, ab)) => GruWeights::synthetic(seed)
                    .prune_quantize(QProfile::wa(wb as u32, ab as u32)?, rho_pct)?,
            };
            match (want_simd, resolve_simd(simd)) {
                (true, Some(k)) => Box::new(StreamingEngine::new(Box::new(
                    SparseMpGruDpd::with_kernel(sw, ActKind::Hard, theta, k),
                ))),
                _ => Box::new(StreamingEngine::new(Box::new(SparseMpGruDpd::new(
                    sw,
                    ActKind::Hard,
                    theta,
                )))),
            }
        }
        EngineKind::CycleSim => Box::new(StreamingEngine::new(Box::new(CycleSimDpd::new(&qw())))),
        EngineKind::Interp => Box::new(InterpGruEngine::new(
            QGruDpd::new(qw(), ActKind::Hard),
            frame_len.unwrap_or(DEFAULT_FRAME_LEN),
        )),
        #[cfg(feature = "xla")]
        EngineKind::Hlo => bail!("hlo engines need a compiled artifact tree (no synthetic form)"),
    })
}

/// The kinds available in this build (used by reports and the CLI).
pub fn available_kinds() -> Vec<EngineKind> {
    let mut kinds = vec![
        EngineKind::NativeF64,
        EngineKind::Fixed,
        EngineKind::DeltaFixed { theta: 0 },
        EngineKind::FixedSimd,
        EngineKind::DeltaFixedSimd { theta: 0 },
        EngineKind::SparseMp { profile: Some((8, 12)), rho: Some(50), theta: None, simd: false },
        EngineKind::CycleSim,
        EngineKind::Interp,
    ];
    #[cfg(feature = "xla")]
    kinds.push(EngineKind::Hlo);
    kinds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Documented tolerance of the float reference against the
    /// integer datapath on small-signal stimulus (see module docs).
    const NATIVE_ABS_TOL: f64 = 0.3;
    const NATIVE_NMSE_DB_TOL: f64 = -12.0;

    fn synth_float_weights(seed: u64) -> GruWeights {
        let mut rng = Rng::new(seed);
        let hidden = 10;
        let features = 4;
        let mut gen = |n: usize| -> Vec<f64> { (0..n).map(|_| rng.range(-0.15, 0.15)).collect() };
        GruWeights {
            hidden,
            features,
            w_ih: gen(3 * hidden * features),
            b_ih: gen(3 * hidden),
            w_hh: gen(3 * hidden * hidden),
            b_hh: gen(3 * hidden),
            w_fc: gen(2 * hidden),
            b_fc: gen(2),
            meta_bits: None,
            meta_act: None,
            meta_val_nmse_db: None,
        }
    }

    fn stimulus(n: usize, seed: u64) -> Vec<[f64; 2]> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| [rng.gauss() * 0.2, rng.gauss() * 0.2]).collect()
    }

    fn run_engine(eng: &mut dyn DpdEngine, input: &[[f64; 2]]) -> Vec<[f64; 2]> {
        let mut buf = input.to_vec();
        eng.reset();
        eng.process_frame(&mut buf).unwrap();
        buf
    }

    #[test]
    fn backends_agree_on_short_frame() {
        // The parity claim of tests/golden_parity.rs, runnable without
        // xla or an artifact tree: table-driven over the backends, each
        // with its documented tolerance against the Fixed reference.
        let fw = synth_float_weights(42);
        let spec = QSpec::Q12;
        let qw = fw.quantize(spec).unwrap();
        let input = stimulus(48, 7);

        let mut reference =
            StreamingEngine::new(Box::new(QGruDpd::new(qw.clone(), ActKind::Hard)));
        let want = run_engine(&mut reference, &input);

        // (engine, exact?, label)
        let table: Vec<(Box<dyn DpdEngine>, bool, &str)> = vec![
            (
                Box::new(StreamingEngine::new(Box::new(QGruDpd::new(
                    qw.clone(),
                    ActKind::Hard,
                )))),
                true,
                "fixed",
            ),
            (
                Box::new(StreamingEngine::new(Box::new(CycleSimDpd::new(&qw)))),
                true,
                "cyclesim",
            ),
            (
                Box::new(StreamingEngine::new(Box::new(DeltaQGruDpd::new(
                    qw.clone(),
                    ActKind::Hard,
                    0,
                )))),
                true,
                "delta-fixed@0",
            ),
            (
                Box::new(StreamingEngine::new(Box::new(GruDpd::new(fw.clone())))),
                false,
                "native-f64",
            ),
        ];

        for (mut eng, exact, label) in table {
            let got = run_engine(eng.as_mut(), &input);
            assert_eq!(got.len(), want.len(), "{label}");
            if exact {
                assert_eq!(got, want, "{label}: integer backends must be bit-exact");
                continue;
            }
            let mut err = 0.0;
            let mut refp = 0.0;
            for (g, w) in got.iter().zip(&want) {
                let (di, dq) = (g[0] - w[0], g[1] - w[1]);
                assert!(
                    di.abs() < NATIVE_ABS_TOL && dq.abs() < NATIVE_ABS_TOL,
                    "{label}: sample deviation {di}/{dq} beyond envelope"
                );
                err += di * di + dq * dq;
                refp += w[0] * w[0] + w[1] * w[1];
            }
            let nmse = 10.0 * (err / refp).log10();
            assert!(
                nmse < NATIVE_NMSE_DB_TOL,
                "{label}: NMSE {nmse:.1} dB vs integer reference"
            );
        }
    }

    #[test]
    fn interp_matches_per_frame_reset_reference() {
        // InterpGruEngine must equal the manual chunk/reset/pad loop
        // (i.e. the HLO artifact's frame semantics) exactly.
        let qw = synth_float_weights(3).quantize(QSpec::Q12).unwrap();
        let spec = qw.spec;
        let frame = 16;
        let input = stimulus(40, 11); // 2 full frames + ragged tail

        let mut interp = InterpGruEngine::new(QGruDpd::new(qw.clone(), ActKind::Hard), frame);
        let mut got = input.clone();
        interp.process_frame(&mut got).unwrap();

        let mut reference = QGruDpd::new(qw, ActKind::Hard);
        let mut want: Vec<[f64; 2]> = Vec::new();
        for chunk in input.chunks(frame) {
            let mut padded: Vec<[i32; 2]> = chunk
                .iter()
                .map(|&[i, q]| [spec.quantize(i), spec.quantize(q)])
                .collect();
            padded.resize(frame, [0, 0]);
            let y = reference.run_codes(&padded);
            want.extend(
                y[..chunk.len()]
                    .iter()
                    .map(|&[i, q]| [spec.dequantize(i), spec.dequantize(q)]),
            );
        }
        assert_eq!(got, want);
    }

    #[test]
    fn streaming_engine_state_carries_across_frames() {
        let qw = synth_float_weights(5).quantize(QSpec::Q12).unwrap();
        let input = stimulus(64, 13);

        let mut whole = StreamingEngine::new(Box::new(QGruDpd::new(qw.clone(), ActKind::Hard)));
        let want = run_engine(&mut whole, &input);

        let mut split = StreamingEngine::new(Box::new(QGruDpd::new(qw, ActKind::Hard)));
        split.reset();
        let (mut a, mut b) = (input[..24].to_vec(), input[24..].to_vec());
        split.process_frame(&mut a).unwrap();
        split.process_frame(&mut b).unwrap();
        a.extend_from_slice(&b);
        assert_eq!(a, want, "frame boundaries must not disturb streaming state");
    }

    #[test]
    fn engine_kind_is_frame_or_streaming_as_documented() {
        let qw = synth_float_weights(9).quantize(QSpec::Q12).unwrap();
        let streaming = StreamingEngine::new(Box::new(QGruDpd::new(qw.clone(), ActKind::Hard)));
        assert_eq!(streaming.frame_len(), None);
        let interp = InterpGruEngine::new(QGruDpd::new(qw, ActKind::Hard), 256);
        assert_eq!(interp.frame_len(), Some(256));
        assert_eq!(interp.name(), "interp-qgru");
    }

    #[test]
    fn batch_classes_separate_kinds_weights_and_geometry() {
        let fw = synth_float_weights(31);
        let qw = fw.quantize(QSpec::Q12).unwrap();
        let fixed_a = StreamingEngine::new(Box::new(QGruDpd::new(qw.clone(), ActKind::Hard)));
        let fixed_b = StreamingEngine::new(Box::new(QGruDpd::new(qw.clone(), ActKind::Hard)));
        let cyclesim = StreamingEngine::new(Box::new(CycleSimDpd::new(&qw)));
        let native = StreamingEngine::new(Box::new(GruDpd::new(fw.clone())));
        let interp16 = InterpGruEngine::new(QGruDpd::new(qw.clone(), ActKind::Hard), 16);
        let interp64 = InterpGruEngine::new(QGruDpd::new(qw.clone(), ActKind::Hard), 64);
        // same kind + same weights coalesce
        assert!(fixed_a.batch_class().is_some());
        assert_eq!(fixed_a.batch_class(), fixed_b.batch_class());
        // kinds never mix, even on identical weights
        assert_ne!(fixed_a.batch_class(), cyclesim.batch_class());
        assert_ne!(fixed_a.batch_class(), native.batch_class());
        assert_ne!(fixed_a.batch_class(), interp16.batch_class());
        // frame geometry is part of a frame engine's identity
        assert_ne!(interp16.batch_class(), interp64.batch_class());
        // the delta engine is its own class: never mixed with Fixed
        // (even at θ=0) and split by θ
        let delta0 = StreamingEngine::new(Box::new(DeltaQGruDpd::new(
            qw.clone(),
            ActKind::Hard,
            0,
        )));
        let delta8 = StreamingEngine::new(Box::new(DeltaQGruDpd::new(
            qw.clone(),
            ActKind::Hard,
            8,
        )));
        assert!(delta0.batch_class().is_some());
        assert_ne!(delta0.batch_class(), fixed_a.batch_class());
        assert_ne!(delta0.batch_class(), delta8.batch_class());
        // different weights never coalesce
        let other = synth_float_weights(32).quantize(QSpec::Q12).unwrap();
        let fixed_c = StreamingEngine::new(Box::new(QGruDpd::new(other, ActKind::Hard)));
        assert_ne!(fixed_a.batch_class(), fixed_c.batch_class());
    }

    #[test]
    fn run_batch_is_bit_identical_to_solo_processing() {
        // The trait-level batch-parity contract over every hermetic
        // engine family (the full differential suite lives in
        // tests/batch_parity.rs; this pins the trait defaults and the
        // StreamingEngine delegation next to their definitions).
        let fw = synth_float_weights(21);
        let qw = fw.quantize(QSpec::Q12).unwrap();
        type Mk<'a> = Box<dyn Fn() -> Box<dyn DpdEngine> + 'a>;
        let makers: Vec<(Mk, &str)> = vec![
            (
                Box::new(|| -> Box<dyn DpdEngine> {
                    Box::new(StreamingEngine::new(Box::new(QGruDpd::new(
                        qw.clone(),
                        ActKind::Hard,
                    ))))
                }),
                "fixed",
            ),
            (
                Box::new(|| -> Box<dyn DpdEngine> {
                    Box::new(StreamingEngine::new(Box::new(CycleSimDpd::new(&qw))))
                }),
                "cyclesim",
            ),
            (
                Box::new(|| -> Box<dyn DpdEngine> {
                    Box::new(StreamingEngine::new(Box::new(GruDpd::new(fw.clone()))))
                }),
                "native-f64",
            ),
            (
                Box::new(|| -> Box<dyn DpdEngine> {
                    Box::new(InterpGruEngine::new(QGruDpd::new(qw.clone(), ActKind::Hard), 16))
                }),
                "interp",
            ),
            (
                Box::new(|| -> Box<dyn DpdEngine> {
                    // θ>0 on purpose: lane snapshots must round-trip
                    // the delta caches, not just the hidden state
                    Box::new(StreamingEngine::new(Box::new(DeltaQGruDpd::new(
                        qw.clone(),
                        ActKind::Hard,
                        24,
                    ))))
                }),
                "delta-fixed@24",
            ),
        ];
        for (mk, label) in makers {
            let mut batched = mk();
            batched.reset();
            let mut solos: Vec<Box<dyn DpdEngine>> = (0..3).map(|_| mk()).collect();
            for s in solos.iter_mut() {
                s.reset();
            }
            let mut states: Vec<DpdState> =
                solos.iter().map(|_| batched.save_state()).collect();
            let mut rng = Rng::new(77);
            // several rounds: lane states must carry streams across
            // run_batch calls exactly like the solo engines' own state
            for round in 0..3 {
                let lens = [17 + round, 40, 8];
                let mut chunks: Vec<Vec<[f64; 2]>> = lens
                    .iter()
                    .map(|&n| {
                        (0..n).map(|_| [rng.gauss() * 0.2, rng.gauss() * 0.2]).collect()
                    })
                    .collect();
                let mut want = chunks.clone();
                for (s, w) in solos.iter_mut().zip(want.iter_mut()) {
                    s.process_frame(w).unwrap();
                }
                let mut lanes: Vec<DpdLane> = chunks
                    .iter_mut()
                    .zip(states.iter_mut())
                    .map(|(c, st)| DpdLane { iq: c.as_mut_slice(), state: st })
                    .collect();
                batched.run_batch(&mut lanes).unwrap();
                drop(lanes);
                assert_eq!(chunks, want, "{label}: batched lanes diverged in round {round}");
            }
        }
    }

    #[test]
    fn synthetic_sparse_family_honors_the_fixed_hinge() {
        // `fixed+sparse:0` from the synthetic construction path is
        // bit-identical to `fixed` at the same seed (the conformance
        // hinge, checked here at the factory level), while remaining
        // its own batch class — like delta@0, a sparse engine never
        // coalesces with the dense implementation
        let input = stimulus(96, 5);
        let mut fixed = build_synthetic(EngineKind::Fixed, 11, SimdPolicy::Off, None).unwrap();
        let want = run_engine(fixed.as_mut(), &input);
        let kind = EngineKind::parse("fixed+sparse:0").unwrap();
        let mut sparse = build_synthetic(kind, 11, SimdPolicy::Off, None).unwrap();
        let got = run_engine(sparse.as_mut(), &input);
        assert_eq!(got, want, "fixed+sparse:0 must be bit-identical to fixed");
        assert!(sparse.batch_class().is_some());
        assert_ne!(fixed.batch_class(), sparse.batch_class());
        // decorated kinds build working engines end to end
        for spec in ["fixed@W8A12+sparse:50", "delta:24+sparse:30", "fixed@W4A12"] {
            let kind = EngineKind::parse(spec).unwrap();
            let mut eng = build_synthetic(kind, 11, SimdPolicy::Off, None).unwrap();
            let out = run_engine(eng.as_mut(), &input);
            assert_eq!(out.len(), input.len(), "{spec}");
            assert!(out.iter().all(|s| s[0].is_finite() && s[1].is_finite()), "{spec}");
        }
    }

    #[test]
    fn available_kinds_lists_default_backends() {
        let kinds = available_kinds();
        assert!(kinds.contains(&EngineKind::NativeF64));
        assert!(kinds.contains(&EngineKind::Fixed));
        assert!(kinds.contains(&EngineKind::DeltaFixed { theta: 0 }));
        assert!(kinds.contains(&EngineKind::FixedSimd));
        assert!(kinds.contains(&EngineKind::DeltaFixedSimd { theta: 0 }));
        assert!(kinds.contains(&EngineKind::CycleSim));
        assert!(kinds.contains(&EngineKind::Interp));
        assert!(kinds.contains(&EngineKind::SparseMp {
            profile: Some((8, 12)),
            rho: Some(50),
            theta: None,
            simd: false,
        }));
    }

    #[test]
    fn engine_spec_strings_round_trip() {
        // parse is the exact inverse of Display for every kind in the
        // build, including non-registry θ values
        let mut kinds = available_kinds();
        kinds.push(EngineKind::DeltaFixed { theta: 32 });
        kinds.push(EngineKind::DeltaFixedSimd { theta: 32 });
        // the sparse/mixed-precision family: every combination of
        // optional decorations (profile/rho/theta/simd) that satisfies
        // the at-least-one-decoration invariant must round-trip
        for profile in [None, Some((4u8, 12u8)), Some((8, 12))] {
            for rho in [None, Some(0u8), Some(50), Some(100)] {
                if profile.is_none() && rho.is_none() {
                    continue; // would collide with the plain spellings
                }
                for theta in [None, Some(0u32), Some(32)] {
                    for simd in [false, true] {
                        kinds.push(EngineKind::SparseMp { profile, rho, theta, simd });
                    }
                }
            }
        }
        for kind in kinds {
            let spec = kind.to_string();
            assert_eq!(EngineKind::parse(&spec).unwrap(), kind, "round-trip of '{spec}'");
        }
        // the canonical spellings are API surface — pin them
        assert_eq!(EngineKind::Fixed.to_string(), "fixed");
        assert_eq!(EngineKind::FixedSimd.to_string(), "fixed+simd");
        assert_eq!(EngineKind::DeltaFixed { theta: 32 }.to_string(), "delta:32");
        assert_eq!(EngineKind::DeltaFixedSimd { theta: 32 }.to_string(), "delta:32+simd");
        // bare "delta" means θ=0, with or without the simd suffix
        assert_eq!(EngineKind::parse("delta").unwrap(), EngineKind::DeltaFixed { theta: 0 });
        assert_eq!(
            EngineKind::parse("delta+simd").unwrap(),
            EngineKind::DeltaFixedSimd { theta: 0 }
        );
        // whitespace-tolerant, and FromStr delegates
        assert_eq!(EngineKind::parse(" fixed+simd ").unwrap(), EngineKind::FixedSimd);
        assert_eq!("delta:7".parse::<EngineKind>().unwrap(), EngineKind::DeltaFixed { theta: 7 });
        // canonical sparse/mixed-precision spellings are API surface
        assert_eq!(
            EngineKind::SparseMp { profile: None, rho: Some(50), theta: None, simd: false }
                .to_string(),
            "fixed+sparse:50"
        );
        assert_eq!(
            EngineKind::SparseMp {
                profile: Some((8, 12)),
                rho: Some(50),
                theta: Some(32),
                simd: true,
            }
            .to_string(),
            "delta:32@W8A12+sparse:50+simd"
        );
        assert_eq!(
            EngineKind::parse("fixed@W4A12").unwrap(),
            EngineKind::SparseMp { profile: Some((4, 12)), rho: None, theta: None, simd: false }
        );
        // bare `delta` with a decoration still means θ=0
        assert_eq!(
            EngineKind::parse("delta+sparse:30").unwrap(),
            EngineKind::SparseMp { profile: None, rho: Some(30), theta: Some(0), simd: false }
        );
    }

    #[test]
    fn engine_spec_rejects_malformed_strings() {
        for bad in [
            "",
            "quantum",
            "delta:",
            "delta:x",
            "delta:-3",
            "native+simd",
            "cyclesim+simd",
            "interp+simd",
            "fixed+avx",
            // sparse/mixed-precision decorations: incomplete payloads,
            // out-of-range widths/percentages, or the wrong base kind
            "fixed@",
            "fixed@W4",
            "fixed@4A12",
            "fixed@W13A12", // weights wider than activations
            "fixed@W2A12",  // below QSpec's 4-bit floor
            "fixed+sparse:",
            "fixed+sparse:x",
            "fixed+sparse:101",
            "cyclesim@W4A12",
            "native+sparse:50",
            "interp@W8A12+sparse:50",
        ] {
            assert!(EngineKind::parse(bad).is_err(), "'{bad}' should not parse");
        }
        #[cfg(not(feature = "xla"))]
        {
            let err = EngineKind::parse("hlo").unwrap_err();
            assert!(format!("{err:#}").contains("xla"));
        }
    }

    #[test]
    fn readme_engine_spec_table_matches_the_generator() {
        // the README's engine table is pasted generator output between
        // HTML markers; this pins it so the docs cannot drift from the
        // registry (add an engine → this fails until the README block
        // is regenerated from `EngineFactory::spec_table_markdown()`)
        let readme =
            std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/../README.md"))
                .expect("README.md at the repo root");
        let begin = "<!-- engine-spec-table:begin -->";
        let end = "<!-- engine-spec-table:end -->";
        let start = readme.find(begin).expect("README lost the begin marker") + begin.len();
        let stop = readme.find(end).expect("README lost the end marker");
        assert_eq!(
            readme[start..stop].trim(),
            EngineFactory::spec_table_markdown().trim(),
            "README engine-spec table drifted — regenerate the block between the \
             engine-spec-table markers from EngineFactory::spec_table_markdown()"
        );
    }

    #[test]
    fn factory_registry_descriptors_cover_every_kind() {
        // the structured registry is in lockstep with available_kinds
        // and every row's spec string parses back to its kind — the
        // property that keeps CLI help from drifting
        let rows = EngineFactory::available_kinds();
        assert_eq!(rows.len(), available_kinds().len());
        for row in &rows {
            assert_eq!(EngineKind::parse(&row.spec).unwrap(), row.kind, "spec '{}'", row.spec);
            assert!(!row.syntax.is_empty());
        }
        let simd_row = rows.iter().find(|r| r.kind == EngineKind::FixedSimd).unwrap();
        assert!(simd_row.simd.is_some(), "kernel kinds must report host SIMD state");
        let scalar_row = rows.iter().find(|r| r.kind == EngineKind::Fixed).unwrap();
        assert_eq!(scalar_row.simd, Some(false), "scalar kinds carry the seam, vector off");
        let native = rows.iter().find(|r| r.kind == EngineKind::NativeF64).unwrap();
        assert!(native.simd.is_none(), "no kernel seam on the float twin");
    }

    #[test]
    fn batch_class_is_independent_of_kernel_choice() {
        // Coalescing must never split on host capability: a SIMD-built
        // engine advertises the same batch class as the scalar build of
        // the same datapath (dense and delta alike), so sessions opened
        // as "fixed+simd" and "fixed" coalesce wherever the weights and
        // θ agree. The class hashes kind + format + weights + act only;
        // the kernel is bit-neutral by contract, hence class-neutral.
        use crate::fixed::SimdKernel;
        let qw = synth_float_weights(31).quantize(QSpec::Q12).unwrap();
        let scalar = StreamingEngine::new(Box::new(QGruDpd::new(qw.clone(), ActKind::Hard)));
        let scalar_delta =
            StreamingEngine::new(Box::new(DeltaQGruDpd::new(qw.clone(), ActKind::Hard, 24)));
        if let Some(k) = SimdKernel::try_new() {
            let vector = StreamingEngine::new(Box::new(QGruDpd::with_kernel(
                qw.clone(),
                ActKind::Hard,
                k,
            )));
            assert_eq!(scalar.batch_class(), vector.batch_class());
            let vector_delta = StreamingEngine::new(Box::new(DeltaQGruDpd::with_kernel(
                qw.clone(),
                ActKind::Hard,
                24,
                k,
            )));
            assert_eq!(scalar_delta.batch_class(), vector_delta.batch_class());
        } else {
            eprintln!("host has no AVX2 — scalar half of the class check only");
        }
        assert!(scalar.batch_class().is_some());
        assert_ne!(scalar.batch_class(), scalar_delta.batch_class());
    }

    #[test]
    fn factory_builds_every_available_kind_with_artifacts() {
        let Ok(factory) = EngineFactory::new(EngineKind::Fixed, None) else {
            eprintln!("skipping (no artifacts)");
            return;
        };
        drop(factory);
        for kind in available_kinds() {
            let f = EngineFactory::new(kind, None).unwrap();
            assert_eq!(f.kind(), kind);
            match f.build() {
                Ok(mut eng) => {
                    let mut burst = stimulus(32, 1);
                    eng.process_frame(&mut burst).unwrap();
                    assert_eq!(burst.len(), 32);
                }
                // the xla stub compiles but cannot execute
                #[cfg(feature = "xla")]
                Err(e) if kind == EngineKind::Hlo => {
                    eprintln!("hlo backend unavailable: {e:#}");
                }
                Err(e) => panic!("{kind:?}: {e:#}"),
            }
        }
    }

    #[test]
    fn from_manifest_shares_one_resolution() {
        // A synthetic manifest (no artifact tree on disk) is enough to
        // resolve factories for every streaming kind plus Interp's
        // default frame length — the path DpdService uses to share one
        // manifest across heterogeneous sessions.
        let m = Arc::new(Manifest {
            root: std::path::PathBuf::from("/synthetic"),
            hidden: 10,
            features: 4,
            n_params: 502,
            qspec_bits: 12,
            pa_model: std::path::PathBuf::from("/synthetic/pa.json"),
            weights_main: std::path::PathBuf::from("/synthetic/weights_main.json"),
            weights_float: std::path::PathBuf::from("/synthetic/weights_float.json"),
            sweep: Vec::new(),
            hlo: Vec::new(),
            golden: Vec::new(),
        });
        for kind in [
            EngineKind::NativeF64,
            EngineKind::Fixed,
            EngineKind::DeltaFixed { theta: 32 },
            EngineKind::CycleSim,
        ] {
            let f = EngineFactory::from_manifest(kind, Arc::clone(&m)).unwrap();
            assert_eq!(f.kind(), kind);
            assert_eq!(f.frame_len(100), 100, "streaming kinds keep the caller's frame");
        }
        let f = EngineFactory::from_manifest(EngineKind::Interp, Arc::clone(&m)).unwrap();
        assert_eq!(f.frame_len(100), DEFAULT_FRAME_LEN, "no HLO entry -> default frame");
        assert_eq!(f.manifest().n_params, 502);
        // the resolution is genuinely shared, not copied per factory
        assert!(Arc::ptr_eq(&f.manifest_arc(), &m));
    }

    /// What `artifacts.rs` also asserts, restated here because the
    /// factory depends on it: discovery fails cleanly with a pointer
    /// to `make artifacts` when no tree exists.
    #[test]
    fn factory_error_mentions_artifacts() {
        let err = EngineFactory::new(
            EngineKind::Fixed,
            Some(std::path::Path::new("/nonexistent/nowhere")),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("manifest"));
    }
}
