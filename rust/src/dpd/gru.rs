//! Float (f64) GRU-RNN DPD — the paper's model (Eq. 1-6 + the residual
//! output and conditioned features, see DESIGN.md §Hardware-Adaptation).
//! Reference implementation for accuracy comparisons; the quantized
//! twin is `qgru`.

use super::weights::GruWeights;
use super::Dpd;

/// Hardsigmoid, Eq. (7).
#[inline]
pub fn hardsigmoid(x: f64) -> f64 {
    (x * 0.25 + 0.5).clamp(0.0, 1.0)
}

/// Hardtanh, Eq. (8).
#[inline]
pub fn hardtanh(x: f64) -> f64 {
    x.clamp(-1.0, 1.0)
}

/// Streaming float GRU DPD engine.
pub struct GruDpd {
    w: GruWeights,
    h: Vec<f64>,
    /// scratch buffers to avoid per-sample allocation
    gi: Vec<f64>,
    gh: Vec<f64>,
    /// column-major weight copies: the per-sample matvecs become
    /// 3H-wide SIMD axpys over contiguous columns (§Perf)
    wt_ih: Vec<f64>,
    wt_hh: Vec<f64>,
}

impl GruDpd {
    pub fn new(w: GruWeights) -> GruDpd {
        let h = vec![0.0; w.hidden];
        let g = vec![0.0; 3 * w.hidden];
        let rows = 3 * w.hidden;
        let mut wt_ih = vec![0.0; w.features * rows];
        for r in 0..rows {
            for c in 0..w.features {
                wt_ih[c * rows + r] = w.w_ih[r * w.features + c];
            }
        }
        let mut wt_hh = vec![0.0; w.hidden * rows];
        for r in 0..rows {
            for c in 0..w.hidden {
                wt_hh[c * rows + r] = w.w_hh[r * w.hidden + c];
            }
        }
        GruDpd { w, h, gi: g.clone(), gh: g, wt_ih, wt_hh }
    }

    pub fn weights(&self) -> &GruWeights {
        &self.w
    }

    /// Eq. (1) + conditioning: [i, q, 4|x|^2, (4|x|^2)^2].
    #[inline]
    pub fn features(iq: [f64; 2]) -> [f64; 4] {
        let p = 4.0 * (iq[0] * iq[0] + iq[1] * iq[1]);
        [iq[0], iq[1], p, p * p]
    }
}

impl Dpd for GruDpd {
    fn process(&mut self, iq: [f64; 2]) -> [f64; 2] {
        let hd = self.w.hidden;
        let x = Self::features(iq);

        // gi = W_ih x + b_ih ; gh = W_hh h + b_hh (column-major axpys)
        let rows = 3 * hd;
        self.gi.copy_from_slice(&self.w.b_ih);
        for (c, &xv) in x.iter().enumerate() {
            let col = &self.wt_ih[c * rows..(c + 1) * rows];
            for (a, &wv) in self.gi.iter_mut().zip(col) {
                *a += wv * xv;
            }
        }
        self.gh.copy_from_slice(&self.w.b_hh);
        for c in 0..hd {
            let xv = self.h[c];
            let col = &self.wt_hh[c * rows..(c + 1) * rows];
            for (a, &wv) in self.gh.iter_mut().zip(col) {
                *a += wv * xv;
            }
        }

        // gates (Eq. 2-5)
        for k in 0..hd {
            let r = hardsigmoid(self.gi[k] + self.gh[k]);
            let z = hardsigmoid(self.gi[hd + k] + self.gh[hd + k]);
            let n = hardtanh(self.gi[2 * hd + k] + r * self.gh[2 * hd + k]);
            self.h[k] = (1.0 - z) * n + z * self.h[k];
        }

        // FC + residual (Eq. 6)
        let mut y = [self.w.b_fc[0] + iq[0], self.w.b_fc[1] + iq[1]];
        for k in 0..hd {
            y[0] += self.w.w_fc[k] * self.h[k];
            y[1] += self.w.w_fc[hd + k] * self.h[k];
        }
        y
    }

    fn reset(&mut self) {
        self.h.iter_mut().for_each(|v| *v = 0.0);
    }

    fn name(&self) -> &'static str {
        "gru-f64"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_weights(seed: u64) -> GruWeights {
        let mut rng = Rng::new(seed);
        let hidden = 10;
        let features = 4;
        let bound = 1.0 / (hidden as f64).sqrt();
        let mut gen = |n: usize| -> Vec<f64> { (0..n).map(|_| rng.range(-bound, bound)).collect() };
        GruWeights {
            hidden,
            features,
            w_ih: gen(3 * hidden * features),
            b_ih: gen(3 * hidden),
            w_hh: gen(3 * hidden * hidden),
            b_hh: gen(3 * hidden),
            w_fc: gen(2 * hidden),
            b_fc: gen(2),
            meta_bits: None,
            meta_act: None,
            meta_val_nmse_db: None,
        }
    }

    #[test]
    fn activations_match_equations() {
        assert_eq!(hardsigmoid(3.0), 1.0);
        assert_eq!(hardsigmoid(-3.0), 0.0);
        assert_eq!(hardsigmoid(0.0), 0.5);
        assert_eq!(hardsigmoid(1.0), 0.75);
        assert_eq!(hardtanh(2.0), 1.0);
        assert_eq!(hardtanh(-2.0), -1.0);
        assert_eq!(hardtanh(0.3), 0.3);
    }

    #[test]
    fn reset_makes_runs_reproducible() {
        let mut dpd = GruDpd::new(rand_weights(1));
        let mut rng = Rng::new(2);
        let x: Vec<[f64; 2]> = (0..64).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect();
        let a = dpd.run(&x);
        let b = dpd.run(&x);
        assert_eq!(a, b);
    }

    #[test]
    fn recurrent_state_matters() {
        let mut dpd = GruDpd::new(rand_weights(3));
        let mut rng = Rng::new(4);
        let x: Vec<[f64; 2]> = (0..32).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect();
        let mut rev = x.clone();
        rev.reverse();
        let a = dpd.run(&x);
        let mut b = dpd.run(&rev);
        b.reverse();
        assert_ne!(a, b);
    }

    #[test]
    fn residual_at_zero_weights() {
        // zero FC weights + zero bias -> y == x exactly (the residual path)
        let mut w = rand_weights(5);
        w.w_fc.iter_mut().for_each(|v| *v = 0.0);
        w.b_fc.iter_mut().for_each(|v| *v = 0.0);
        let mut dpd = GruDpd::new(w);
        let x = [[0.1, -0.2], [0.3, 0.05]];
        let y = dpd.run(&x);
        assert_eq!(y, x.to_vec());
    }

    #[test]
    fn features_definition() {
        let f = GruDpd::features([0.3, -0.4]);
        let p = 4.0 * 0.25;
        assert_eq!(f, [0.3, -0.4, p, p * p]);
    }
}
