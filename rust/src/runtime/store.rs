//! Content-addressed weight store — manifest v2.
//!
//! PR 5's adaptation loop mints new weight generations continuously;
//! PR 7's fleet runs thousands of sessions that all need to agree on
//! *which* generation they serve. This module is the versioned
//! distribution substrate between the two (ROADMAP item 4), modeled
//! on the sharded-manifest design the roadmap points at: every weight
//! set is a **content-addressed blob** keyed by its existing
//! fingerprint (`GruWeights::fingerprint` / `QGruWeights::fingerprint`
//! — the same identity the coalescing batch classes already use), and
//! every publication is a **generation record** carrying lineage
//! (parent hash + trainer metadata: window/step counts, NMSE at
//! freeze, the deployment QProfile knobs).
//!
//! Two properties carry the whole design:
//!
//! * **Byte-exact codec.** The store document is canonical JSON
//!   (`util::json`): sorted keys, pinned number spellings, every
//!   finite f64 round-tripping bit-identically. Serializing the same
//!   store twice — in this crate or in the Python oracle
//!   (`python/tools/gen_golden_store.py`) — yields identical bytes,
//!   so blob hashes are reproducible across languages and a golden
//!   file can pin the whole wire format
//!   (`rust/tests/data/golden_store.json`).
//! * **Delta encoding between adjacent generations.** The DeltaDPD
//!   observation applies to weight trajectories too: adjacent
//!   generations of an adaptation run share most of their words —
//!   exactly at the quantized-code level, where one Adam step rarely
//!   flips a Q2.10 code. A child blob whose parent has the same kind,
//!   dims (and spec, for quantized sets) is stored as the list of
//!   `(tensor, index, new word)` triples that changed; everything
//!   else falls back to a full blob. The measured touched-fraction on
//!   a real `AdaptTrainer` refresh is pinned in EXPERIMENTS.md.
//!
//! Loading **verifies**: each decoded generation's fingerprint is
//! recomputed and must equal the recorded content hash, so a
//! corrupted blob or a mis-applied delta can never impersonate a
//! generation — this is the bit-exactness argument the rollout
//! controller's rollback path (`coordinator/rollout.rs`) rests on:
//! rolling back to the parent hash rebuilds engines from *verified*
//! parent words, hence bit-identical behavior to the pre-rollout
//! engine.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::dpd::weights::{GruWeights, QGruWeights};
use crate::fixed::QSpec;
use crate::util::json::Json;

/// Fixed tensor walk order — shared with the fingerprints, the delta
/// codec and the Python oracle. Never reorder.
pub const TENSOR_ORDER: [&str; 6] = ["w_ih", "b_ih", "w_hh", "b_hh", "w_fc", "b_fc"];

/// Wire version tag of the store document.
pub const STORE_VERSION: &str = "dpd-weight-store-v2";

/// Trainer metadata frozen into a generation record at publish time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GenMeta {
    /// feedback samples the trainer had absorbed at freeze
    pub adapt_samples: u64,
    /// optimizer steps (trained windows) at freeze
    pub adapt_steps: u64,
    /// trainer NMSE (dB) at freeze — must be finite (a fresh trainer
    /// reports 0.0)
    pub nmse_db: f64,
    /// deployment quantization intent: uniform bitwidth
    pub spec_bits: u32,
    /// deployment pruning density ρ (percent), 0 = dense
    pub rho: u8,
    /// deployment delta threshold θ, 0 = dense updates
    pub theta: u32,
}

impl Default for GenMeta {
    fn default() -> Self {
        GenMeta {
            adapt_samples: 0,
            adapt_steps: 0,
            nmse_db: 0.0,
            spec_bits: 12,
            rho: 0,
            theta: 0,
        }
    }
}

/// One stored weight set: the float twin the trainer adapts, or a
/// quantized deployment set.
#[derive(Clone, Debug)]
pub enum WeightSet {
    Float(GruWeights),
    Quant(QGruWeights),
}

impl WeightSet {
    /// Content hash — the existing fingerprint of the inner set.
    pub fn fingerprint(&self) -> u64 {
        match self {
            WeightSet::Float(w) => w.fingerprint(),
            WeightSet::Quant(q) => q.fingerprint(),
        }
    }

    /// Wire kind tag (`"gru-f64"` / `"qgru"`, matching the
    /// fingerprint tags).
    pub fn kind(&self) -> &'static str {
        match self {
            WeightSet::Float(_) => "gru-f64",
            WeightSet::Quant(_) => "qgru",
        }
    }

    /// Total weight words across the six tensors.
    pub fn n_words(&self) -> usize {
        let (h, f) = self.dims();
        3 * h * f + 3 * h + 3 * h * h + 3 * h + 2 * h + 2
    }

    pub fn dims(&self) -> (usize, usize) {
        match self {
            WeightSet::Float(w) => (w.hidden, w.features),
            WeightSet::Quant(q) => (q.hidden, q.features),
        }
    }
}

/// A generation's lineage record (the blob itself lives next to it in
/// the store).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GenRecord {
    /// content hash (fingerprint) of the weight set
    pub hash: u64,
    /// content hash of the generation this one descends from (`None`
    /// for a lineage root)
    pub parent: Option<u64>,
    /// publish order, 0-based and dense
    pub seq: u64,
    /// trainer metadata at freeze
    pub meta: GenMeta,
}

/// How a generation will travel on the wire, plus the numbers behind
/// the delta-encoding win.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DeltaStats {
    /// words that differ from the parent blob
    pub changed_words: usize,
    /// total words in the set
    pub total_words: usize,
}

impl DeltaStats {
    /// Fraction of weight words the generation actually touched.
    pub fn touched_fraction(&self) -> f64 {
        if self.total_words == 0 {
            return 0.0;
        }
        self.changed_words as f64 / self.total_words as f64
    }
}

/// The content-addressed weight store. In-memory; (de)serializes to
/// the canonical manifest-v2 JSON document (module docs).
#[derive(Clone, Debug, Default)]
pub struct WeightStore {
    gens: Vec<(GenRecord, WeightSet)>,
    index: BTreeMap<u64, usize>,
    head: Option<u64>,
}

/// `"fnv1a64:%016x"` — the wire spelling of a content hash.
pub fn format_hash(h: u64) -> String {
    format!("fnv1a64:{h:016x}")
}

/// Inverse of [`format_hash`].
pub fn parse_hash(s: &str) -> Result<u64> {
    let hex = s
        .strip_prefix("fnv1a64:")
        .ok_or_else(|| anyhow!("content hash '{s}' lacks the fnv1a64: prefix"))?;
    if hex.len() != 16 {
        bail!("content hash '{s}' must carry 16 hex digits");
    }
    u64::from_str_radix(hex, 16).with_context(|| format!("content hash '{s}'"))
}

impl WeightStore {
    pub fn new() -> WeightStore {
        WeightStore::default()
    }

    pub fn len(&self) -> usize {
        self.gens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gens.is_empty()
    }

    /// Hash of the most recently published generation.
    pub fn head(&self) -> Option<u64> {
        self.head
    }

    /// Lineage record for a stored generation.
    pub fn record(&self, hash: u64) -> Option<&GenRecord> {
        self.index.get(&hash).map(|&i| &self.gens[i].0)
    }

    /// All records in publish (seq) order.
    pub fn records(&self) -> impl Iterator<Item = &GenRecord> {
        self.gens.iter().map(|(r, _)| r)
    }

    /// Publish a float (trainer-twin) generation. The parent is the
    /// current head; loader metadata (`meta_bits`/`meta_act`/...) is
    /// stripped — it is not covered by the fingerprint and must not
    /// leak into the content-addressed blob.
    pub fn publish_float(&mut self, w: &GruWeights, meta: GenMeta) -> Result<u64> {
        w.check_finite().context("publishing a float weight generation")?;
        let mut clean = w.clone();
        clean.meta_bits = None;
        clean.meta_act = None;
        clean.meta_val_nmse_db = None;
        self.push_gen(WeightSet::Float(clean), meta)
    }

    /// Publish a quantized deployment generation.
    pub fn publish_quant(&mut self, q: &QGruWeights, meta: GenMeta) -> Result<u64> {
        self.push_gen(WeightSet::Quant(q.clone()), meta)
    }

    fn push_gen(&mut self, set: WeightSet, meta: GenMeta) -> Result<u64> {
        if !meta.nmse_db.is_finite() {
            bail!("generation metadata nmse_db must be finite, got {}", meta.nmse_db);
        }
        let hash = set.fingerprint();
        if self.index.contains_key(&hash) {
            bail!("generation {} is already stored", format_hash(hash));
        }
        let rec = GenRecord { hash, parent: self.head, seq: self.gens.len() as u64, meta };
        self.index.insert(hash, self.gens.len());
        self.gens.push((rec, set));
        self.head = Some(hash);
        Ok(hash)
    }

    /// The stored float twin for `hash`.
    pub fn get_float(&self, hash: u64) -> Result<&GruWeights> {
        match self.get(hash)? {
            WeightSet::Float(w) => Ok(w),
            WeightSet::Quant(_) => {
                bail!("generation {} is quantized, not a float twin", format_hash(hash))
            }
        }
    }

    /// The stored quantized set for `hash`.
    pub fn get_quant(&self, hash: u64) -> Result<&QGruWeights> {
        match self.get(hash)? {
            WeightSet::Quant(q) => Ok(q),
            WeightSet::Float(_) => {
                bail!("generation {} is a float twin, not quantized", format_hash(hash))
            }
        }
    }

    /// The stored weight set for `hash`.
    pub fn get(&self, hash: u64) -> Result<&WeightSet> {
        self.index
            .get(&hash)
            .map(|&i| &self.gens[i].1)
            .ok_or_else(|| anyhow!("unknown weight generation {}", format_hash(hash)))
    }

    /// Hash chain from `hash` back to its lineage root (inclusive,
    /// child first).
    pub fn lineage(&self, hash: u64) -> Result<Vec<u64>> {
        let mut chain = Vec::new();
        let mut cur = Some(hash);
        while let Some(h) = cur {
            let rec = self
                .record(h)
                .ok_or_else(|| anyhow!("lineage broken at {}", format_hash(h)))?;
            chain.push(h);
            if chain.len() > self.gens.len() {
                bail!("lineage cycle at {}", format_hash(h));
            }
            cur = rec.parent;
        }
        Ok(chain)
    }

    /// Wire shape of a generation vs its parent: `Some` when it
    /// delta-encodes (same kind, dims and spec as the parent), `None`
    /// when it travels as a full blob.
    pub fn delta_stats(&self, hash: u64) -> Option<DeltaStats> {
        let rec = self.record(hash)?;
        let set = self.get(hash).ok()?;
        let parent = self.get(rec.parent?).ok()?;
        let changed = delta_words(parent, set)?;
        Some(DeltaStats { changed_words: changed.len(), total_words: set.n_words() })
    }

    // ---- canonical serialization ------------------------------------

    /// The canonical manifest-v2 document.
    pub fn to_json(&self) -> Json {
        let gens: Vec<Json> = self
            .gens
            .iter()
            .map(|(rec, set)| {
                let parent_set = rec.parent.and_then(|p| self.get(p).ok());
                let blob = encode_blob(set, parent_set);
                Json::obj(vec![
                    ("blob", blob),
                    ("hash", Json::str(format_hash(rec.hash))),
                    ("kind", Json::str(set.kind())),
                    ("meta", encode_meta(&rec.meta)),
                    (
                        "parent",
                        rec.parent.map(|p| Json::str(format_hash(p))).unwrap_or(Json::Null),
                    ),
                    ("seq", Json::num(rec.seq as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("generations", Json::Arr(gens)),
            ("head", self.head.map(|h| Json::str(format_hash(h))).unwrap_or(Json::Null)),
            ("version", Json::str(STORE_VERSION)),
        ])
    }

    /// Canonical bytes: same store → same string, in this crate and
    /// in the Python oracle.
    pub fn to_json_string(&self) -> Result<String> {
        self.to_json().dump().context("serializing weight store")
    }

    /// Decode a store document, applying deltas and **verifying every
    /// generation's recomputed fingerprint against its recorded
    /// content hash**.
    pub fn from_json(doc: &Json) -> Result<WeightStore> {
        let version = doc.get("version")?.as_str()?;
        if version != STORE_VERSION {
            bail!("unsupported store version '{version}' (want '{STORE_VERSION}')");
        }
        let mut store = WeightStore::new();
        for (i, g) in doc.get("generations")?.as_arr()?.iter().enumerate() {
            let ctx = || format!("store generation #{i}");
            let hash = parse_hash(g.get("hash").and_then(|h| h.as_str()).with_context(ctx)?)?;
            let parent = match g.get("parent").with_context(ctx)? {
                Json::Null => None,
                p => Some(parse_hash(p.as_str().with_context(ctx)?)?),
            };
            let seq = g.get("seq").and_then(|s| s.as_i64()).with_context(ctx)? as u64;
            if seq != i as u64 {
                bail!("store generation #{i} carries seq {seq} — records must be dense");
            }
            let meta = decode_meta(g.get("meta").with_context(ctx)?).with_context(ctx)?;
            let kind = g.get("kind").and_then(|k| k.as_str()).with_context(ctx)?;
            let parent_set = match parent {
                Some(p) => {
                    Some(store.get(p).with_context(|| {
                        format!("store generation #{i}: parent not yet decoded")
                    })?)
                }
                None => None,
            };
            let set = decode_blob(g.get("blob").with_context(ctx)?, kind, meta.spec_bits, parent_set)
                .with_context(ctx)?;
            let got = set.fingerprint();
            if got != hash {
                bail!(
                    "store generation #{i} corrupt: decoded content hashes to {}, record says {}",
                    format_hash(got),
                    format_hash(hash)
                );
            }
            store.index.insert(hash, store.gens.len());
            store.gens.push((GenRecord { hash, parent, seq, meta }, set));
        }
        store.head = match doc.get("head")? {
            Json::Null => None,
            h => Some(parse_hash(h.as_str()?)?),
        };
        if let Some(h) = store.head {
            if !store.index.contains_key(&h) {
                bail!("store head {} names no stored generation", format_hash(h));
            }
        }
        Ok(store)
    }

    pub fn from_json_str(text: &str) -> Result<WeightStore> {
        WeightStore::from_json(&Json::parse(text).context("parsing weight store document")?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json_string()? + "\n")
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<WeightStore> {
        WeightStore::from_json(&Json::parse_file(path)?)
            .with_context(|| format!("loading weight store {}", path.display()))
    }
}

// ---- blob codec ------------------------------------------------------

/// One changed word: (tensor name, flat index, new word as Json).
type DeltaWord = (&'static str, usize, Json);

/// Word-level diff vs the parent, in `TENSOR_ORDER` then ascending
/// index. `None` when the pair cannot delta-encode (kind, dims or
/// spec mismatch).
fn delta_words(parent: &WeightSet, child: &WeightSet) -> Option<Vec<DeltaWord>> {
    match (parent, child) {
        (WeightSet::Float(p), WeightSet::Float(c)) => {
            if (p.hidden, p.features) != (c.hidden, c.features) {
                return None;
            }
            let mut out = Vec::new();
            for (name, pt, ct) in [
                ("w_ih", &p.w_ih, &c.w_ih),
                ("b_ih", &p.b_ih, &c.b_ih),
                ("w_hh", &p.w_hh, &c.w_hh),
                ("b_hh", &p.b_hh, &c.b_hh),
                ("w_fc", &p.w_fc, &c.w_fc),
                ("b_fc", &p.b_fc, &c.b_fc),
            ] {
                for (i, (&pv, &cv)) in pt.iter().zip(ct).enumerate() {
                    if pv.to_bits() != cv.to_bits() {
                        out.push((name, i, Json::num(cv)));
                    }
                }
            }
            Some(out)
        }
        (WeightSet::Quant(p), WeightSet::Quant(c)) => {
            if (p.hidden, p.features, p.spec.bits) != (c.hidden, c.features, c.spec.bits) {
                return None;
            }
            let mut out = Vec::new();
            for (name, pt, ct) in [
                ("w_ih", &p.w_ih, &c.w_ih),
                ("b_ih", &p.b_ih, &c.b_ih),
                ("w_hh", &p.w_hh, &c.w_hh),
                ("b_hh", &p.b_hh, &c.b_hh),
                ("w_fc", &p.w_fc, &c.w_fc),
                ("b_fc", &p.b_fc, &c.b_fc),
            ] {
                for (i, (&pv, &cv)) in pt.iter().zip(ct).enumerate() {
                    if pv != cv {
                        out.push((name, i, Json::num(cv as f64)));
                    }
                }
            }
            Some(out)
        }
        _ => None,
    }
}

fn encode_blob(set: &WeightSet, parent: Option<&WeightSet>) -> Json {
    if let Some(p) = parent {
        if let Some(changed) = delta_words(p, set) {
            let triples: Vec<Json> = changed
                .into_iter()
                .map(|(name, i, v)| Json::Arr(vec![Json::str(name), Json::num(i as f64), v]))
                .collect();
            return Json::obj(vec![(
                "delta",
                Json::obj(vec![("changed", Json::Arr(triples))]),
            )]);
        }
    }
    let payload = match set {
        WeightSet::Float(w) => Json::obj(vec![
            ("b_fc", Json::arr_f64(&w.b_fc)),
            ("b_hh", Json::arr_f64(&w.b_hh)),
            ("b_ih", Json::arr_f64(&w.b_ih)),
            ("features", Json::num(w.features as f64)),
            ("hidden", Json::num(w.hidden as f64)),
            ("w_fc", Json::arr_f64(&w.w_fc)),
            ("w_hh", Json::arr_f64(&w.w_hh)),
            ("w_ih", Json::arr_f64(&w.w_ih)),
        ]),
        WeightSet::Quant(q) => {
            let arr = |v: &[i32]| Json::Arr(v.iter().map(|&x| Json::num(x as f64)).collect());
            Json::obj(vec![
                ("b_fc", arr(&q.b_fc)),
                ("b_hh", arr(&q.b_hh)),
                ("b_ih", arr(&q.b_ih)),
                ("features", Json::num(q.features as f64)),
                ("hidden", Json::num(q.hidden as f64)),
                ("w_fc", arr(&q.w_fc)),
                ("w_hh", arr(&q.w_hh)),
                ("w_ih", arr(&q.w_ih)),
            ])
        }
    };
    Json::obj(vec![("full", payload)])
}

fn decode_blob(
    blob: &Json,
    kind: &str,
    spec_bits: u32,
    parent: Option<&WeightSet>,
) -> Result<WeightSet> {
    if let Some(full) = blob.opt("full") {
        return decode_full(full, kind, spec_bits);
    }
    let delta = blob
        .opt("delta")
        .ok_or_else(|| anyhow!("blob carries neither 'full' nor 'delta'"))?;
    let parent = parent.ok_or_else(|| anyhow!("delta blob without a parent generation"))?;
    if parent.kind() != kind {
        bail!("delta blob kind '{kind}' differs from parent kind '{}'", parent.kind());
    }
    let mut set = parent.clone();
    for (j, t) in delta.get("changed")?.as_arr()?.iter().enumerate() {
        let t = t.as_arr()?;
        if t.len() != 3 {
            bail!("delta word #{j}: want [tensor, index, value]");
        }
        let name = t[0].as_str().with_context(|| format!("delta word #{j}"))?;
        let idx = t[1].as_usize().with_context(|| format!("delta word #{j}"))?;
        match &mut set {
            WeightSet::Float(w) => {
                let tensor = match name {
                    "w_ih" => &mut w.w_ih,
                    "b_ih" => &mut w.b_ih,
                    "w_hh" => &mut w.w_hh,
                    "b_hh" => &mut w.b_hh,
                    "w_fc" => &mut w.w_fc,
                    "b_fc" => &mut w.b_fc,
                    _ => bail!("delta word #{j}: unknown tensor '{name}'"),
                };
                let slot = tensor
                    .get_mut(idx)
                    .ok_or_else(|| anyhow!("delta word #{j}: index {idx} outside '{name}'"))?;
                *slot = t[2].as_f64().with_context(|| format!("delta word #{j}"))?;
            }
            WeightSet::Quant(q) => {
                let tensor = match name {
                    "w_ih" => &mut q.w_ih,
                    "b_ih" => &mut q.b_ih,
                    "w_hh" => &mut q.w_hh,
                    "b_hh" => &mut q.b_hh,
                    "w_fc" => &mut q.w_fc,
                    "b_fc" => &mut q.b_fc,
                    _ => bail!("delta word #{j}: unknown tensor '{name}'"),
                };
                let slot = tensor
                    .get_mut(idx)
                    .ok_or_else(|| anyhow!("delta word #{j}: index {idx} outside '{name}'"))?;
                *slot = t[2].as_i64().with_context(|| format!("delta word #{j}"))? as i32;
            }
        }
    }
    Ok(set)
}

fn decode_full(full: &Json, kind: &str, spec_bits: u32) -> Result<WeightSet> {
    let hidden = full.get("hidden")?.as_usize()?;
    let features = full.get("features")?.as_usize()?;
    let want = |name: &str, n: usize, got: usize| -> Result<()> {
        if got != n {
            bail!("tensor '{name}' has {got} words, dims ({hidden}, {features}) demand {n}");
        }
        Ok(())
    };
    match kind {
        "gru-f64" => {
            let t = |name: &str, n: usize| -> Result<Vec<f64>> {
                let v = full.get(name)?.as_f64_vec().with_context(|| format!("tensor '{name}'"))?;
                want(name, n, v.len())?;
                Ok(v)
            };
            Ok(WeightSet::Float(GruWeights {
                hidden,
                features,
                w_ih: t("w_ih", 3 * hidden * features)?,
                b_ih: t("b_ih", 3 * hidden)?,
                w_hh: t("w_hh", 3 * hidden * hidden)?,
                b_hh: t("b_hh", 3 * hidden)?,
                w_fc: t("w_fc", 2 * hidden)?,
                b_fc: t("b_fc", 2)?,
                meta_bits: None,
                meta_act: None,
                meta_val_nmse_db: None,
            }))
        }
        "qgru" => {
            let t = |name: &str, n: usize| -> Result<Vec<i32>> {
                let v = full.get(name)?.as_i32_vec().with_context(|| format!("tensor '{name}'"))?;
                want(name, n, v.len())?;
                Ok(v)
            };
            let spec = QSpec::new(spec_bits)
                .with_context(|| format!("meta spec_bits {spec_bits}"))?;
            Ok(WeightSet::Quant(QGruWeights {
                hidden,
                features,
                spec,
                w_ih: t("w_ih", 3 * hidden * features)?,
                b_ih: t("b_ih", 3 * hidden)?,
                w_hh: t("w_hh", 3 * hidden * hidden)?,
                b_hh: t("b_hh", 3 * hidden)?,
                w_fc: t("w_fc", 2 * hidden)?,
                b_fc: t("b_fc", 2)?,
            }))
        }
        k => bail!("unknown generation kind '{k}'"),
    }
}

fn encode_meta(m: &GenMeta) -> Json {
    Json::obj(vec![
        ("adapt_samples", Json::num(m.adapt_samples as f64)),
        ("adapt_steps", Json::num(m.adapt_steps as f64)),
        ("nmse_db", Json::num(m.nmse_db)),
        ("rho", Json::num(m.rho as f64)),
        ("spec_bits", Json::num(m.spec_bits as f64)),
        ("theta", Json::num(m.theta as f64)),
    ])
}

fn decode_meta(j: &Json) -> Result<GenMeta> {
    Ok(GenMeta {
        adapt_samples: j.get("adapt_samples")?.as_i64()? as u64,
        adapt_steps: j.get("adapt_steps")?.as_i64()? as u64,
        nmse_db: j.get("nmse_db")?.as_f64()?,
        spec_bits: j.get("spec_bits")?.as_usize()? as u32,
        rho: {
            let r = j.get("rho")?.as_usize()?;
            if r > 100 {
                bail!("meta rho {r} out of range (0..=100)");
            }
            r as u8
        },
        theta: j.get("theta")?.as_usize()? as u32,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(steps: u64) -> GenMeta {
        GenMeta { adapt_steps: steps, adapt_samples: steps * 32, nmse_db: -20.5, ..Default::default() }
    }

    fn perturbed(w: &GruWeights, touches: &[(usize, f64)]) -> GruWeights {
        let mut c = w.clone();
        for &(i, dv) in touches {
            c.w_hh[i] += dv;
        }
        c
    }

    #[test]
    fn publish_lineage_and_lookup() {
        let w0 = GruWeights::synthetic(7);
        let w1 = perturbed(&w0, &[(3, 0.01), (17, -0.02)]);
        let mut store = WeightStore::new();
        assert!(store.is_empty() && store.head().is_none());
        let h0 = store.publish_float(&w0, meta(0)).unwrap();
        let h1 = store.publish_float(&w1, meta(5)).unwrap();
        assert_ne!(h0, h1);
        assert_eq!(store.len(), 2);
        assert_eq!(store.head(), Some(h1));
        let r1 = store.record(h1).unwrap();
        assert_eq!(r1.parent, Some(h0));
        assert_eq!(r1.seq, 1);
        assert_eq!(r1.meta.adapt_steps, 5);
        assert_eq!(store.lineage(h1).unwrap(), vec![h1, h0]);
        assert_eq!(store.get_float(h0).unwrap().fingerprint(), h0);
        // content addressing: re-publishing identical words is refused
        assert!(store.publish_float(&w1, meta(9)).is_err());
        // and unknown hashes are contextual errors, not panics
        assert!(store.get_float(0xdead_beef).is_err());
    }

    #[test]
    fn roundtrip_is_byte_identical_and_verified() {
        let w0 = GruWeights::synthetic(7);
        let w1 = perturbed(&w0, &[(0, 0.005), (42, 0.005), (99, -0.01)]);
        let q1 = w1.quantize(QSpec::Q12).unwrap();
        let mut store = WeightStore::new();
        store.publish_float(&w0, meta(0)).unwrap();
        let h1 = store.publish_float(&w1, meta(3)).unwrap();
        let hq = store.publish_quant(&q1, meta(3)).unwrap();
        let text = store.to_json_string().unwrap();
        let back = WeightStore::from_json_str(&text).unwrap();
        assert_eq!(back.to_json_string().unwrap(), text, "re-encode must be byte-identical");
        assert_eq!(back.head(), Some(hq));
        assert_eq!(back.get_float(h1).unwrap().fingerprint(), h1);
        assert_eq!(back.get_quant(hq).unwrap().fingerprint(), hq);
        // the float child rides as a 3-word delta on the wire
        let ds = store.delta_stats(h1).unwrap();
        assert_eq!(ds.changed_words, 3);
        assert_eq!(ds.total_words, w1.n_params());
        assert!(ds.touched_fraction() < 0.01);
        // the quant generation follows a float parent: full blob
        assert!(store.delta_stats(hq).is_none());
        let doc = Json::parse(&text).unwrap();
        let gens = doc.get("generations").unwrap().as_arr().unwrap();
        assert!(gens[1].get("blob").unwrap().opt("delta").is_some());
        assert!(gens[2].get("blob").unwrap().opt("full").is_some());
    }

    #[test]
    fn corruption_cannot_impersonate_a_generation() {
        let w0 = GruWeights::synthetic(11);
        let mut store = WeightStore::new();
        store.publish_float(&w0, meta(0)).unwrap();
        let text = store.to_json_string().unwrap();
        // flip one stored word: the recomputed fingerprint must expose it
        let mut doc = Json::parse(&text).unwrap();
        if let Json::Obj(m) = &mut doc {
            let gens = m.get_mut("generations").unwrap();
            if let Json::Arr(a) = gens {
                if let Json::Obj(g) = &mut a[0] {
                    let blob = g.get_mut("blob").unwrap();
                    let full = blob.opt("full").unwrap().clone();
                    if let Json::Obj(f) = full {
                        let mut f = f;
                        f.insert("b_fc".into(), Json::arr_f64(&[0.25, 0.25]));
                        *blob = Json::obj(vec![("full", Json::Obj(f))]);
                    }
                }
            }
        }
        let err = WeightStore::from_json(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("corrupt"), "want corruption error, got {err:#}");
    }

    #[test]
    fn quant_chain_deltas_and_spec_change_falls_back_to_full() {
        let w0 = GruWeights::synthetic(3);
        let q0 = w0.quantize(QSpec::Q12).unwrap();
        let mut q1 = q0.clone();
        q1.w_ih[5] += 1;
        q1.b_fc[0] -= 2;
        let q_other_spec = w0.quantize(QSpec::new(8).unwrap()).unwrap();
        let mut store = WeightStore::new();
        store.publish_quant(&q0, meta(0)).unwrap();
        let h1 = store.publish_quant(&q1, meta(1)).unwrap();
        let h2 = store
            .publish_quant(&q_other_spec, GenMeta { spec_bits: 8, ..meta(2) })
            .unwrap();
        let ds = store.delta_stats(h1).unwrap();
        assert_eq!(ds.changed_words, 2);
        assert!(store.delta_stats(h2).is_none(), "spec change must not delta-encode");
        let text = store.to_json_string().unwrap();
        let back = WeightStore::from_json_str(&text).unwrap();
        assert_eq!(back.get_quant(h1).unwrap().fingerprint(), h1);
        assert_eq!(back.get_quant(h2).unwrap().spec.bits, 8);
        assert_eq!(back.to_json_string().unwrap(), text);
    }

    #[test]
    fn malformed_documents_fail_with_context() {
        for (what, text) in [
            ("wrong version", r#"{"generations":[],"head":null,"version":"v1"}"#),
            ("missing head", r#"{"generations":[],"version":"dpd-weight-store-v2"}"#),
            (
                "dangling head",
                r#"{"generations":[],"head":"fnv1a64:0123456789abcdef","version":"dpd-weight-store-v2"}"#,
            ),
            (
                "bad hash spelling",
                r#"{"generations":[{"blob":{"full":{}},"hash":"sha256:00","kind":"gru-f64","meta":{},"parent":null,"seq":0}],"head":null,"version":"dpd-weight-store-v2"}"#,
            ),
        ] {
            assert!(WeightStore::from_json_str(text).is_err(), "{what} must be rejected");
        }
        // hash helpers are total
        assert!(parse_hash("fnv1a64:0123456789abcdef").is_ok());
        assert!(parse_hash("fnv1a64:123").is_err());
        assert!(parse_hash("0123456789abcdef").is_err());
        let h = 0xdead_beef_0bad_f00du64;
        assert_eq!(parse_hash(&format_hash(h)).unwrap(), h);
    }

    #[test]
    fn publish_rejects_non_finite_inputs() {
        let mut w = GruWeights::synthetic(1);
        w.w_fc[0] = f64::NAN;
        let mut store = WeightStore::new();
        assert!(store.publish_float(&w, meta(0)).is_err());
        let ok = GruWeights::synthetic(1);
        assert!(store
            .publish_float(&ok, GenMeta { nmse_db: f64::INFINITY, ..meta(0) })
            .is_err());
        assert!(store.is_empty(), "failed publishes must not leave partial records");
    }
}
