//! The multi-stream streaming pipeline.
//!
//! Per stream, three stages run on their own threads, linked by
//! *bounded* channels (`sync_channel`) so a slow stage backpressures
//! the producer instead of buffering unboundedly:
//!
//! ```text
//!   source thread -> [frames] -> DPD worker -> [frames] -> sink
//! ```
//!
//! Engines are constructed inside the worker thread (the PJRT client is
//! not Send). Multiple streams run fully in parallel — the mMIMO
//! deployment shape, one engine instance per antenna.

use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::framer::{Frame, Framer};
use super::stats::{LatencyAgg, PipelineStats};
use crate::dpd::qgru::{ActKind, QGruDpd};
use crate::dpd::weights::{GruWeights, QGruWeights};
use crate::dpd::{Dpd, GruDpd};
use crate::fixed::QSpec;
use crate::runtime::{HloGruEngine, Manifest};

/// Which DPD engine the worker instantiates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// f64 GRU (float reference)
    NativeF64,
    /// bit-exact Q2.10 fixed-point (the chip's functional model)
    Fixed,
    /// cycle-accurate ASIC simulator
    CycleSim,
    /// AOT HLO via the PJRT CPU client (frame-based)
    Hlo,
}

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub engine: EngineKind,
    /// frame length for the framer (HLO engines override with their
    /// compiled frame size)
    pub frame_len: usize,
    /// bounded-channel depth (frames in flight per link)
    pub queue_depth: usize,
    /// artifact tree (None = discover)
    pub artifacts: Option<PathBuf>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            engine: EngineKind::Fixed,
            frame_len: 2048,
            queue_depth: 4,
            artifacts: None,
        }
    }
}

/// Output of one stream.
#[derive(Debug)]
pub struct StreamOutput {
    pub iq: Vec<[f64; 2]>,
    pub stats: PipelineStats,
}

/// The coordinator: runs N independent streams through the pipeline.
pub struct Coordinator {
    pub cfg: CoordinatorConfig,
}

enum Msg {
    Frame(Frame, Instant),
    Eof,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Coordinator {
        Coordinator { cfg }
    }

    /// Run one stream to completion.
    pub fn run_stream(&self, input: &[[f64; 2]]) -> Result<StreamOutput> {
        let outs = self.run_streams(vec![input.to_vec()])?;
        Ok(outs.into_iter().next().unwrap())
    }

    /// Run multiple independent streams in parallel (mMIMO shape).
    pub fn run_streams(&self, inputs: Vec<Vec<[f64; 2]>>) -> Result<Vec<StreamOutput>> {
        let mut handles = Vec::new();
        for input in inputs {
            let cfg = self.cfg.clone();
            handles.push(std::thread::spawn(move || run_one(cfg, input)));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("stream thread panicked"))
            .collect()
    }
}

fn build_dyn_engine(cfg: &CoordinatorConfig) -> Result<Box<dyn Dpd>> {
    let m = Manifest::discover(cfg.artifacts.as_deref())?;
    match cfg.engine {
        EngineKind::NativeF64 => {
            let w = GruWeights::load(&m.weights_float)?;
            Ok(Box::new(GruDpd::new(w)))
        }
        EngineKind::Fixed => {
            let spec = QSpec::new(m.qspec_bits)?;
            let w = QGruWeights::load_params_int(&m.weights_main, spec)?;
            Ok(Box::new(QGruDpd::new(w, ActKind::Hard)))
        }
        EngineKind::CycleSim => {
            let spec = QSpec::new(m.qspec_bits)?;
            let w = QGruWeights::load_params_int(&m.weights_main, spec)?;
            Ok(Box::new(CycleSimDpd::new(&w)))
        }
        EngineKind::Hlo => unreachable!("HLO handled separately"),
    }
}

/// Adapter: the cycle-accurate simulator as a `Dpd`.
struct CycleSimDpd {
    sim: crate::accel::CycleAccurateEngine,
    spec: QSpec,
}

impl CycleSimDpd {
    fn new(w: &QGruWeights) -> CycleSimDpd {
        CycleSimDpd {
            sim: crate::accel::CycleAccurateEngine::new(
                w,
                crate::accel::act_unit::ActImpl::Hard,
                crate::accel::fsm::HwConfig::default(),
            ),
            spec: w.spec,
        }
    }
}

impl Dpd for CycleSimDpd {
    fn process(&mut self, iq: [f64; 2]) -> [f64; 2] {
        let codes = [self.spec.quantize(iq[0]), self.spec.quantize(iq[1])];
        let y = self.sim.step(codes).expect("sim step");
        [self.spec.dequantize(y[0]), self.spec.dequantize(y[1])]
    }
    fn reset(&mut self) {
        self.sim.reset();
    }
    fn name(&self) -> &'static str {
        "cyclesim"
    }
}

fn run_one(cfg: CoordinatorConfig, input: Vec<[f64; 2]>) -> Result<StreamOutput> {
    // frame length: HLO engines are shape-specialized
    let (frame_len, hlo_entry) = if cfg.engine == EngineKind::Hlo {
        let m = Manifest::discover(cfg.artifacts.as_deref())?;
        let e = m
            .best_int_hlo()
            .context("no integer HLO artifact")?
            .clone();
        ((e.time), Some((m, e)))
    } else {
        (cfg.frame_len, None)
    };

    let t_start = Instant::now();
    let n_in = input.len() as u64;
    let (tx_work, rx_work): (SyncSender<Msg>, Receiver<Msg>) = sync_channel(cfg.queue_depth);
    let (tx_done, rx_done): (SyncSender<Msg>, Receiver<Msg>) = sync_channel(cfg.queue_depth);

    // source + framer thread
    let src = std::thread::spawn(move || -> Result<()> {
        let mut framer = Framer::new(frame_len);
        for chunk in input.chunks(1024) {
            for fr in framer.push(chunk) {
                tx_work.send(Msg::Frame(fr, Instant::now())).ok();
            }
        }
        if let Some(fr) = framer.flush() {
            tx_work.send(Msg::Frame(fr, Instant::now())).ok();
        }
        tx_work.send(Msg::Eof).ok();
        Ok(())
    });

    // DPD worker thread (engine built here; PJRT client is !Send)
    let worker_cfg = cfg.clone();
    let worker = std::thread::spawn(move || -> Result<Duration> {
        let mut busy = Duration::ZERO;
        match hlo_entry {
            Some((m, e)) => {
                let client = xla::PjRtClient::cpu()?;
                let spec = QSpec::new(e.bits)?;
                let mut eng =
                    HloGruEngine::load(&client, &m.hlo_path(&e), e.batch, e.time, true, Some(spec))?;
                while let Ok(Msg::Frame(mut fr, t0)) = rx_work.recv() {
                    let t = Instant::now();
                    let codes: Vec<[i32; 2]> = fr
                        .data
                        .iter()
                        .map(|&[i, q]| [spec.quantize(i), spec.quantize(q)])
                        .collect();
                    let y = eng.run_frame_codes(&codes)?;
                    for (dst, &[i, q]) in fr.data.iter_mut().zip(&y) {
                        *dst = [spec.dequantize(i), spec.dequantize(q)];
                    }
                    busy += t.elapsed();
                    tx_done.send(Msg::Frame(fr, t0)).ok();
                }
                tx_done.send(Msg::Eof).ok();
            }
            None => {
                let mut eng = build_dyn_engine(&worker_cfg)?;
                eng.reset();
                while let Ok(Msg::Frame(mut fr, t0)) = rx_work.recv() {
                    let t = Instant::now();
                    for s in fr.data.iter_mut() {
                        *s = eng.process(*s);
                    }
                    busy += t.elapsed();
                    tx_done.send(Msg::Frame(fr, t0)).ok();
                }
                tx_done.send(Msg::Eof).ok();
            }
        }
        Ok(busy)
    });

    // sink (this thread)
    let mut out: Vec<[f64; 2]> = Vec::new();
    let mut frames = 0u64;
    let mut lat = LatencyAgg::default();
    let mut expected_seq = 0u64;
    while let Ok(msg) = rx_done.recv() {
        match msg {
            Msg::Frame(fr, t0) => {
                anyhow::ensure!(fr.seq == expected_seq, "frame reordering detected");
                expected_seq += 1;
                frames += 1;
                lat.record(t0.elapsed());
                out.extend_from_slice(&fr.data[..fr.valid]);
            }
            Msg::Eof => break,
        }
    }

    src.join().expect("source panicked")?;
    let busy = worker.join().expect("worker panicked")?;
    let wall = t_start.elapsed();
    let stats = PipelineStats {
        samples_in: n_in,
        samples_out: out.len() as u64,
        frames,
        wall,
        dpd_busy: busy,
        lat_mean: lat.mean(),
        lat_max: lat.max(),
    };
    Ok(StreamOutput { iq: out, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn artifacts_present() -> bool {
        Manifest::discover(None).is_ok()
    }

    fn signal(n: usize, seed: u64) -> Vec<[f64; 2]> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect()
    }

    #[test]
    fn conservation_and_order_fixed_engine() {
        if !artifacts_present() {
            eprintln!("skipping (no artifacts)");
            return;
        }
        let c = Coordinator::new(CoordinatorConfig {
            engine: EngineKind::Fixed,
            frame_len: 100,
            queue_depth: 2,
            artifacts: None,
        });
        let input = signal(1234, 1);
        let out = c.run_stream(&input).unwrap();
        assert_eq!(out.iq.len(), 1234);
        assert_eq!(out.stats.samples_in, 1234);
        assert_eq!(out.stats.samples_out, 1234);
        assert_eq!(out.stats.frames, 13);
    }

    #[test]
    fn pipeline_output_equals_direct_engine_run() {
        if !artifacts_present() {
            eprintln!("skipping (no artifacts)");
            return;
        }
        let input = signal(777, 2);
        let c = Coordinator::new(CoordinatorConfig {
            engine: EngineKind::Fixed,
            frame_len: 128,
            queue_depth: 3,
            artifacts: None,
        });
        let piped = c.run_stream(&input).unwrap();

        // direct: same engine, continuous stream (no reset per frame in
        // the pipeline either — state carries across frames)
        let m = Manifest::discover(None).unwrap();
        let spec = QSpec::new(m.qspec_bits).unwrap();
        let w = QGruWeights::load_params_int(&m.weights_main, spec).unwrap();
        let mut eng = QGruDpd::new(w, ActKind::Hard);
        let direct = eng.run(&input);
        assert_eq!(piped.iq, direct);
    }

    #[test]
    fn multi_stream_isolation() {
        if !artifacts_present() {
            eprintln!("skipping (no artifacts)");
            return;
        }
        let c = Coordinator::new(CoordinatorConfig {
            engine: EngineKind::Fixed,
            frame_len: 64,
            queue_depth: 2,
            artifacts: None,
        });
        let a = signal(500, 3);
        let b = signal(500, 4);
        let joint = c.run_streams(vec![a.clone(), b.clone()]).unwrap();
        let solo_a = c.run_stream(&a).unwrap();
        let solo_b = c.run_stream(&b).unwrap();
        assert_eq!(joint[0].iq, solo_a.iq);
        assert_eq!(joint[1].iq, solo_b.iq);
    }

    #[test]
    fn cycle_sim_engine_matches_fixed() {
        if !artifacts_present() {
            eprintln!("skipping (no artifacts)");
            return;
        }
        let input = signal(300, 5);
        let fixed = Coordinator::new(CoordinatorConfig {
            engine: EngineKind::Fixed,
            frame_len: 64,
            ..Default::default()
        })
        .run_stream(&input)
        .unwrap();
        let sim = Coordinator::new(CoordinatorConfig {
            engine: EngineKind::CycleSim,
            frame_len: 64,
            ..Default::default()
        })
        .run_stream(&input)
        .unwrap();
        assert_eq!(fixed.iq, sim.iq);
    }

    #[test]
    fn backpressure_small_queue_still_completes() {
        if !artifacts_present() {
            eprintln!("skipping (no artifacts)");
            return;
        }
        let c = Coordinator::new(CoordinatorConfig {
            engine: EngineKind::Fixed,
            frame_len: 32,
            queue_depth: 1,
            artifacts: None,
        });
        let input = signal(2000, 6);
        let out = c.run_stream(&input).unwrap();
        assert_eq!(out.iq.len(), 2000);
        assert!(out.stats.engine_msps() > 0.0);
    }
}
