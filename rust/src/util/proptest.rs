//! Tiny property-testing helper (no proptest crate offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` random
//! inputs drawn from a deterministic seed derived from `name`, so
//! failures are reproducible; on failure it reports the case index and
//! the seed to re-run with. Set `DPD_PROPTEST_SEED=<seed>` to replay a
//! reported failure: case 0 then starts at exactly that seed (the
//! shrinking workflow — re-run one seed, tighten the property, repeat).

use super::rng::Rng;

/// Base seed for a property: the env override when set (reproducible
/// replay of a reported failure), else a stable hash of the name
/// (the shared content hash with an empty word stream).
fn base_seed(name: &str) -> u64 {
    match std::env::var("DPD_PROPTEST_SEED") {
        Ok(s) => s
            .trim()
            .parse::<u64>()
            .unwrap_or_else(|_| panic!("DPD_PROPTEST_SEED must be a u64, got '{s}'")),
        Err(_) => super::fnv1a_words(name, std::iter::empty()),
    }
}

/// Run `f` for `cases` seeded iterations; `f` returns Err(description)
/// on a property violation. Panics with full reproduction info.
pub fn check<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = base_seed(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}): {msg}\n\
                 replay with DPD_PROPTEST_SEED={seed}"
            );
        }
    }
}

/// Assert two floats are within an absolute tolerance, with context.
pub fn assert_close(got: f64, want: f64, tol: f64, what: &str) -> Result<(), String> {
    if (got - want).abs() > tol {
        return Err(format!("{what}: got {got}, want {want} (tol {tol})"));
    }
    Ok(())
}

/// Assert two slices are element-wise within tolerance.
pub fn assert_close_slice(got: &[f64], want: &[f64], tol: f64, what: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{what}: length {} vs {}", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        if (g - w).abs() > tol {
            return Err(format!("{what}[{i}]: got {g}, want {w} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("counter", 25, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failure() {
        check("fails", 10, |rng| {
            let v = rng.uniform();
            if v >= 0.0 {
                Err(format!("always fails, v={v}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        check("det", 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check("det", 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
