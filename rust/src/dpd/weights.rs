//! GRU weight containers + loaders for the artifact JSON schema
//! (shared with `python/compile/model.py::params_to_jsonable`).

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::fixed::QSpec;
use crate::util::json::Json;

/// Float GRU-DPD weights. Gate row order is [r; z; n] (rows 0..H,
/// H..2H, 2H..3H) — the PyTorch convention the whole project uses.
#[derive(Clone, Debug)]
pub struct GruWeights {
    pub hidden: usize,
    pub features: usize,
    /// (3H, F) row-major
    pub w_ih: Vec<f64>,
    pub b_ih: Vec<f64>,
    /// (3H, H) row-major
    pub w_hh: Vec<f64>,
    pub b_hh: Vec<f64>,
    /// (2, H) row-major
    pub w_fc: Vec<f64>,
    pub b_fc: Vec<f64>,
    pub meta_bits: Option<u32>,
    pub meta_act: Option<String>,
    pub meta_val_nmse_db: Option<f64>,
}

/// Integer (Q2.f code) GRU weights.
#[derive(Clone, Debug)]
pub struct QGruWeights {
    pub hidden: usize,
    pub features: usize,
    pub spec: QSpec,
    pub w_ih: Vec<i32>,
    pub b_ih: Vec<i32>,
    pub w_hh: Vec<i32>,
    pub b_hh: Vec<i32>,
    pub w_fc: Vec<i32>,
    pub b_fc: Vec<i32>,
}

fn tensor_f64(obj: &Json, key: &str, want_len: usize) -> Result<Vec<f64>> {
    let t = obj.get(key)?;
    let data = t.get("data")?.as_f64_vec()?;
    ensure!(data.len() == want_len, "{key}: length {} != {want_len}", data.len());
    Ok(data)
}

fn tensor_i32(obj: &Json, key: &str, want_len: usize) -> Result<Vec<i32>> {
    let t = obj.get(key)?;
    let data = t.get("data")?.as_i32_vec()?;
    ensure!(data.len() == want_len, "{key}: length {} != {want_len}", data.len());
    Ok(data)
}

fn dims(params: &Json) -> Result<(usize, usize)> {
    let shape = params.get("w_ih")?.get("shape")?.as_arr()?;
    let rows = shape[0].as_usize()?;
    let features = shape[1].as_usize()?;
    ensure!(rows % 3 == 0, "w_ih rows not divisible by 3");
    Ok((rows / 3, features))
}

impl GruWeights {
    /// Load from a weights JSON (`weights_float.json`, sweep entries,
    /// or `weights_main.json` — anything with a `params` block).
    pub fn load(path: &Path) -> Result<GruWeights> {
        let j = Json::parse_file(path).context("loading GRU weights")?;
        let params = j.get("params")?;
        let (hidden, features) = dims(params)?;
        let meta = j.opt("meta");
        let meta_f64 = |k: &str| meta.and_then(|m| m.opt(k)).and_then(|v| v.as_f64().ok());
        Ok(GruWeights {
            hidden,
            features,
            w_ih: tensor_f64(params, "w_ih", 3 * hidden * features)?,
            b_ih: tensor_f64(params, "b_ih", 3 * hidden)?,
            w_hh: tensor_f64(params, "w_hh", 3 * hidden * hidden)?,
            b_hh: tensor_f64(params, "b_hh", 3 * hidden)?,
            w_fc: tensor_f64(params, "w_fc", 2 * hidden)?,
            b_fc: tensor_f64(params, "b_fc", 2)?,
            meta_bits: meta_f64("bits").map(|v| v as u32),
            meta_act: meta
                .and_then(|m| m.opt("act"))
                .and_then(|v| v.as_str().ok().map(String::from)),
            meta_val_nmse_db: meta_f64("val_nmse_db"),
        })
    }

    /// Amplitude-realistic synthetic float weights at the paper's
    /// dimensions (H=10, F=4, |w| < 0.15) — the float counterpart of
    /// [`QGruWeights::synthetic`], used wherever an artifact-less run
    /// needs a float twin (adaptive sessions in the fleet/loadgen
    /// paths, native-engine test fixtures). One definition so the
    /// hermetic constructions cannot drift apart.
    pub fn synthetic(seed: u64) -> GruWeights {
        let mut rng = crate::util::Rng::new(seed);
        let hidden = 10;
        let features = 4;
        let mut gen = |n: usize| -> Vec<f64> { (0..n).map(|_| rng.range(-0.15, 0.15)).collect() };
        GruWeights {
            hidden,
            features,
            w_ih: gen(3 * hidden * features),
            b_ih: gen(3 * hidden),
            w_hh: gen(3 * hidden * hidden),
            b_hh: gen(3 * hidden),
            w_fc: gen(2 * hidden),
            b_fc: gen(2),
            meta_bits: None,
            meta_act: None,
            meta_val_nmse_db: None,
        }
    }

    /// Total parameter count (paper: 502).
    pub fn n_params(&self) -> usize {
        self.w_ih.len() + self.b_ih.len() + self.w_hh.len() + self.b_hh.len()
            + self.w_fc.len() + self.b_fc.len()
    }

    /// Content fingerprint over dims + every weight word (f64 bit
    /// patterns). Two `GruDpd`s with equal fingerprints compute the
    /// same function — the batch-class test of the coalescing
    /// scheduler.
    pub fn fingerprint(&self) -> u64 {
        let dims = [self.hidden as u64, self.features as u64];
        let words = dims.into_iter().chain(
            self.w_ih
                .iter()
                .chain(&self.b_ih)
                .chain(&self.w_hh)
                .chain(&self.b_hh)
                .chain(&self.w_fc)
                .chain(&self.b_fc)
                .map(|v| v.to_bits()),
        );
        crate::util::fnv1a_words("gru-f64", words)
    }

    /// Quantize to Q2.f codes with the canonical round-half-up rule —
    /// bit-identical to python `ref.quantize_params`.
    pub fn quantize(&self, spec: QSpec) -> QGruWeights {
        let q = |v: &[f64]| -> Vec<i32> { v.iter().map(|&x| spec.quantize(x)).collect() };
        QGruWeights {
            hidden: self.hidden,
            features: self.features,
            spec,
            w_ih: q(&self.w_ih),
            b_ih: q(&self.b_ih),
            w_hh: q(&self.w_hh),
            b_hh: q(&self.b_hh),
            w_fc: q(&self.w_fc),
            b_fc: q(&self.b_fc),
        }
    }
}

impl QGruWeights {
    /// Amplitude-realistic synthetic weights at the paper's dimensions
    /// (H=10, F=4, |w| <= 0.3): the shared stimulus class used by the
    /// accel model tests and by artifact-less bench runs. One
    /// definition so the constructions cannot drift apart.
    pub fn synthetic(seed: u64, spec: QSpec) -> QGruWeights {
        let mut rng = crate::util::Rng::new(seed);
        let hidden = 10;
        let features = 4;
        let bound = (0.3 * spec.scale()) as i64;
        let mut gen =
            |n: usize| -> Vec<i32> { (0..n).map(|_| rng.int_in(-bound, bound) as i32).collect() };
        QGruWeights {
            hidden,
            features,
            spec,
            w_ih: gen(3 * hidden * features),
            b_ih: gen(3 * hidden),
            w_hh: gen(3 * hidden * hidden),
            b_hh: gen(3 * hidden),
            w_fc: gen(2 * hidden),
            b_fc: gen(2),
        }
    }

    /// Content fingerprint over format + dims + every weight code.
    /// Equal fingerprints promise an identical integer datapath —
    /// what lets the coalescing scheduler group sessions whose
    /// engines share one weight set into a single batched call.
    pub fn fingerprint(&self) -> u64 {
        let head = [self.spec.bits as u64, self.hidden as u64, self.features as u64];
        let words = head.into_iter().chain(
            self.w_ih
                .iter()
                .chain(&self.b_ih)
                .chain(&self.w_hh)
                .chain(&self.b_hh)
                .chain(&self.w_fc)
                .chain(&self.b_fc)
                .map(|&v| v as u32 as u64),
        );
        crate::util::fnv1a_words("qgru", words)
    }

    /// Load the pre-quantized `params_int` block of `weights_main.json`
    /// (written by aot.py; equals `GruWeights::quantize` of `params`).
    pub fn load_params_int(path: &Path, spec: QSpec) -> Result<QGruWeights> {
        let j = Json::parse_file(path).context("loading int GRU weights")?;
        let params = j.get("params_int")?;
        let (hidden, features) = dims(params)?;
        Ok(QGruWeights {
            hidden,
            features,
            spec,
            w_ih: tensor_i32(params, "w_ih", 3 * hidden * features)?,
            b_ih: tensor_i32(params, "b_ih", 3 * hidden)?,
            w_hh: tensor_i32(params, "w_hh", 3 * hidden * hidden)?,
            b_hh: tensor_i32(params, "b_hh", 3 * hidden)?,
            w_fc: tensor_i32(params, "w_fc", 2 * hidden)?,
            b_fc: tensor_i32(params, "b_fc", 2)?,
        })
    }

    /// Load from a golden-vector JSON (`golden/g_*.json` has the same
    /// `params_int` block plus test vectors).
    pub fn load_golden(path: &Path) -> Result<(QGruWeights, Json)> {
        let j = Json::parse_file(path).context("loading golden case")?;
        let bits = j.get("bits")?.as_usize()? as u32;
        let spec = QSpec::new(bits)?;
        let params = j.get("params_int")?;
        let (hidden, features) = dims(params)?;
        let w = QGruWeights {
            hidden,
            features,
            spec,
            w_ih: tensor_i32(params, "w_ih", 3 * hidden * features)?,
            b_ih: tensor_i32(params, "b_ih", 3 * hidden)?,
            w_hh: tensor_i32(params, "w_hh", 3 * hidden * hidden)?,
            b_hh: tensor_i32(params, "b_hh", 3 * hidden)?,
            w_fc: tensor_i32(params, "w_fc", 2 * hidden)?,
            b_fc: tensor_i32(params, "b_fc", 2)?,
        };
        Ok((w, j))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_weights_json(hidden: usize, features: usize) -> String {
        let tensor = |rows: usize, cols: Option<usize>| -> String {
            let n = rows * cols.unwrap_or(1);
            let data: Vec<String> = (0..n).map(|i| format!("{}", (i as f64) * 0.001 - 0.05)).collect();
            let shape = match cols {
                Some(c) => format!("[{rows},{c}]"),
                None => format!("[{rows}]"),
            };
            format!("{{\"shape\":{shape},\"data\":[{}]}}", data.join(","))
        };
        format!(
            "{{\"meta\":{{\"bits\":12,\"act\":\"hard\",\"val_nmse_db\":-37.5}},\"params\":{{\
             \"w_ih\":{},\"b_ih\":{},\"w_hh\":{},\"b_hh\":{},\"w_fc\":{},\"b_fc\":{}}}}}",
            tensor(3 * hidden, Some(features)),
            tensor(3 * hidden, None),
            tensor(3 * hidden, Some(hidden)),
            tensor(3 * hidden, None),
            tensor(2, Some(hidden)),
            tensor(2, None),
        )
    }

    #[test]
    fn load_roundtrip() {
        let dir = std::env::temp_dir().join("dpd_ne_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.json");
        std::fs::write(&path, fake_weights_json(10, 4)).unwrap();
        let w = GruWeights::load(&path).unwrap();
        assert_eq!(w.hidden, 10);
        assert_eq!(w.features, 4);
        assert_eq!(w.n_params(), 502);
        assert_eq!(w.meta_bits, Some(12));
        assert_eq!(w.meta_act.as_deref(), Some("hard"));
        assert!((w.meta_val_nmse_db.unwrap() + 37.5).abs() < 1e-12);
    }

    #[test]
    fn quantize_matches_qspec_rule() {
        let dir = std::env::temp_dir().join("dpd_ne_test_weights2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.json");
        std::fs::write(&path, fake_weights_json(10, 4)).unwrap();
        let w = GruWeights::load(&path).unwrap();
        let spec = QSpec::Q12;
        let qw = w.quantize(spec);
        for (f, q) in w.w_ih.iter().zip(&qw.w_ih) {
            assert_eq!(*q, spec.quantize(*f));
        }
    }

    #[test]
    fn fingerprints_identify_weight_content() {
        let a = QGruWeights::synthetic(1, QSpec::Q12);
        let b = QGruWeights::synthetic(1, QSpec::Q12);
        let c = QGruWeights::synthetic(2, QSpec::Q12);
        assert_eq!(a.fingerprint(), b.fingerprint(), "same content, same class");
        assert_ne!(a.fingerprint(), c.fingerprint(), "different weights, different class");
        // the format is part of the identity (same codes at 8 bits
        // compute a different function)
        let d = QGruWeights { spec: QSpec::new(8).unwrap(), ..a.clone() };
        assert_ne!(a.fingerprint(), d.fingerprint());
        // a single flipped weight changes the class
        let mut e = a.clone();
        e.w_hh[17] ^= 1;
        assert_ne!(a.fingerprint(), e.fingerprint());
    }

    #[test]
    fn rejects_wrong_lengths() {
        let dir = std::env::temp_dir().join("dpd_ne_test_weights3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.json");
        // truncated b_fc
        let bad = fake_weights_json(10, 4).replace(
            "\"b_fc\":{\"shape\":[2],\"data\":[-0.05,-0.049]}",
            "\"b_fc\":{\"shape\":[2],\"data\":[-0.05]}",
        );
        std::fs::write(&path, bad).unwrap();
        assert!(GruWeights::load(&path).is_err());
    }
}
