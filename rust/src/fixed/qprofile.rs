//! Per-tensor mixed-precision profile (MP-DPD, arXiv:2404.15364).
//!
//! One [`QSpec`] per weight tensor plus one for the activation/stream
//! domain. The datapath contract (implemented by
//! `dpd::sparse::SparseMpGruDpd`): activations, biases and I/Q codes
//! live in the activation format `act` (Q2.fa), each weight tensor in
//! its own format (Q2.fw), products accumulate in the fa+fw domain,
//! and every matvec requantizes by the *weight* fraction back into
//! the activation domain:
//!
//! ```text
//!   acc = (b_code(fa) << fw) + Σ w_code(fw) · x_code(fa)
//!   gate_code = rshift_round(acc, fw) saturated to act
//! ```
//!
//! With every spec equal this degenerates, bit for bit, to the
//! uniform-[`QSpec`] datapath (`dpd::qgru`) — the equivalence the
//! conformance matrix pins.

use std::fmt;

use anyhow::{bail, Result};

use super::QSpec;

/// Mixed-precision quantization profile: one format per weight
/// tensor, one for the activation/stream domain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QProfile {
    /// input-to-hidden gate weights W_ih
    pub w_ih: QSpec,
    /// hidden-to-hidden gate weights W_hh
    pub w_hh: QSpec,
    /// output FC weights W_fc
    pub w_fc: QSpec,
    /// activations, biases, hidden state, and the I/Q stream
    pub act: QSpec,
}

impl QProfile {
    /// Every tensor in one format — the profile equivalent of today's
    /// uniform `QSpec` datapath.
    pub fn uniform(spec: QSpec) -> QProfile {
        QProfile { w_ih: spec, w_hh: spec, w_fc: spec, act: spec }
    }

    /// The `W{w}A{a}` shorthand from the engine-spec grammar: all
    /// three weight tensors at `wbits`, activations at `abits`.
    pub fn wa(wbits: u32, abits: u32) -> Result<QProfile> {
        let w = QSpec::new(wbits)?;
        let a = QSpec::new(abits)?;
        if wbits > abits {
            bail!("W{wbits}A{abits}: weight width must not exceed activation width");
        }
        Ok(QProfile { w_ih: w, w_hh: w, w_fc: w, act: a })
    }

    /// True when every tensor shares one format (the uniform-QSpec
    /// equivalence domain).
    pub fn is_uniform(&self) -> bool {
        self.w_ih == self.act && self.w_hh == self.act && self.w_fc == self.act
    }

    /// The common weight width when all three weight tensors agree
    /// (always true for profiles built by [`QProfile::wa`] /
    /// [`QProfile::uniform`]).
    pub fn weight_bits(&self) -> Option<u32> {
        if self.w_ih == self.w_hh && self.w_hh == self.w_fc {
            Some(self.w_ih.bits)
        } else {
            None
        }
    }

    /// Parse the `W{w}A{a}` shorthand (e.g. `W4A12`).
    pub fn parse_wa(s: &str) -> Result<QProfile> {
        let rest = match s.strip_prefix('W') {
            Some(r) => r,
            None => bail!("bad quantization profile '{s}' (want W<wbits>A<abits>, e.g. W4A12)"),
        };
        let (w, a) = match rest.split_once('A') {
            Some((w, a)) if !w.is_empty() && !a.is_empty() => (w, a),
            _ => bail!("bad quantization profile '{s}' (want W<wbits>A<abits>, e.g. W4A12)"),
        };
        let wbits: u32 = w
            .parse()
            .map_err(|_| anyhow::anyhow!("bad weight width in profile '{s}'"))?;
        let abits: u32 = a
            .parse()
            .map_err(|_| anyhow::anyhow!("bad activation width in profile '{s}'"))?;
        QProfile::wa(wbits, abits)
    }
}

impl fmt::Display for QProfile {
    /// Canonical spec-string form. Profiles with heterogeneous weight
    /// widths fall outside the grammar and print each tensor.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.weight_bits() {
            Some(w) => write!(f, "W{w}A{a}", a = self.act.bits),
            None => write!(
                f,
                "Wih{}Whh{}Wfc{}A{}",
                self.w_ih.bits, self.w_hh.bits, self.w_fc.bits, self.act.bits
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wa_shorthand_roundtrips() {
        for (w, a) in [(4u32, 12u32), (8, 12), (8, 10), (6, 12), (12, 12)] {
            let p = QProfile::wa(w, a).unwrap();
            assert_eq!(p.weight_bits(), Some(w));
            assert_eq!(p.act.bits, a);
            let s = p.to_string();
            assert_eq!(s, format!("W{w}A{a}"));
            assert_eq!(QProfile::parse_wa(&s).unwrap(), p);
        }
    }

    #[test]
    fn uniform_profile_is_uniform() {
        let p = QProfile::uniform(QSpec::Q12);
        assert!(p.is_uniform());
        assert_eq!(p.to_string(), "W12A12");
        assert_eq!(QProfile::parse_wa("W12A12").unwrap(), p);
        assert!(!QProfile::wa(8, 12).unwrap().is_uniform());
    }

    #[test]
    fn rejects_malformed_and_unsound_profiles() {
        for bad in ["", "W4", "A12", "W4A", "WA12", "W4A12A", "w4a12", "W4B12", "WxA12", "W4Ax"] {
            assert!(QProfile::parse_wa(bad).is_err(), "accepted {bad:?}");
        }
        // widths outside QSpec's 4..=24, and weights wider than acts
        assert!(QProfile::wa(3, 12).is_err());
        assert!(QProfile::wa(8, 25).is_err());
        assert!(QProfile::wa(12, 8).is_err());
    }
}
