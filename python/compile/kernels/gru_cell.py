"""L1 — Pallas kernels for the fused GRU-RNN DPD cell.

This is the software expression of the DPD-NeuralEngine datapath
(DESIGN.md §2 Hardware-Adaptation): one ``pallas_call`` processes an
entire I/Q frame per grid step, with

* the three gate weight matrices concatenated into single ``(3H, F)`` /
  ``(3H, H)`` operands that are loaded into VMEM once per frame — the
  analogue of the ASIC's weight buffer (weights stationary);
* the hidden state carried as a loop value across the in-kernel time
  loop — the analogue of the hidden-state buffer;
* Hardsigmoid/Hardtanh as clip-based VPU ops (the paper's PWL units),
  or a gathered ROM for the LUT baseline;
* the batch (grid) dimension modelling independent antenna streams.

Kernels are lowered with ``interpret=True`` — the CPU PJRT client that
the Rust runtime embeds cannot execute Mosaic custom calls, and
interpret-mode lowering produces plain HLO that runs anywhere.

Float and integer variants exist; the integer variant is bit-exact with
``ref.int_forward`` (the canonical datapath) and therefore with the Rust
fixed-point engine.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .activations import (
    LutSpec,
    hardsigmoid,
    hardsigmoid_int,
    hardtanh,
    hardtanh_int,
    make_sigmoid_table,
    make_tanh_table,
)
from .quant import QSpec, fake_quant, rshift_round, saturate

__all__ = ["gru_dpd_pallas", "gru_dpd_pallas_int"]


# ---------------------------------------------------------------------------
# Float kernel
# ---------------------------------------------------------------------------


def _float_kernel(iq_ref, w_ih_ref, b_ih_ref, w_hh_ref, b_hh_ref, w_fc_ref, b_fc_ref, out_ref, *, spec, act):
    """Kernel body: one frame (T, 2) -> (T, 2), weights VMEM-resident."""
    iq = iq_ref[0]  # (T, 2) block
    w_ih, b_ih = w_ih_ref[...], b_ih_ref[...]
    w_hh, b_hh = w_hh_ref[...], b_hh_ref[...]
    w_fc, b_fc = w_fc_ref[...], b_fc_ref[...]
    T = iq.shape[0]
    hidden = w_hh.shape[1]

    def q(v):
        return fake_quant(v, spec) if spec is not None else v

    def sig(v):
        y = hardsigmoid(v) if act == "hard" else jax.nn.sigmoid(v)
        return q(y)

    def tanh(v):
        y = hardtanh(v) if act == "hard" else jnp.tanh(v)
        return q(y)

    # Preprocessor (Eq. 1) on the whole frame at once — the 2-PE
    # feature extractor runs ahead of the recurrent loop.
    iqq = q(iq)
    i_ch, q_ch = iqq[:, 0], iqq[:, 1]
    p = q(4.0 * (i_ch * i_ch + q_ch * q_ch))
    p2 = q(p * p)
    feats = jnp.stack([i_ch, q_ch, p, p2], axis=-1)  # (T, 4)

    wq_ih, bq_ih = q(w_ih), q(b_ih)
    wq_hh, bq_hh = q(w_hh), q(b_hh)
    wq_fc, bq_fc = q(w_fc), q(b_fc)

    def body(t, carry):
        h, ys = carry
        x = jax.lax.dynamic_slice_in_dim(feats, t, 1, axis=0)[0]  # (4,)
        gi = q(wq_ih @ x + bq_ih)
        gh = q(wq_hh @ h + bq_hh)
        r = sig(q(gi[:hidden] + gh[:hidden]))
        z = sig(q(gi[hidden : 2 * hidden] + gh[hidden : 2 * hidden]))
        n = tanh(q(gi[2 * hidden :] + q(r * gh[2 * hidden :])))
        h_new = q(q((1.0 - z) * n) + q(z * h))
        # residual output around the (quantized) I/Q input
        y = q(wq_fc @ h_new + bq_fc + x[0:2])
        ys = jax.lax.dynamic_update_slice_in_dim(ys, y[None, :], t, axis=0)
        return h_new, ys

    h0 = jnp.zeros((hidden,), iq.dtype)
    ys0 = jnp.zeros((T, 2), iq.dtype)
    _, ys = jax.lax.fori_loop(0, T, body, (h0, ys0))
    out_ref[0] = ys


def _replicated(shape):
    """BlockSpec for an operand every grid step sees in full (weights)."""
    return pl.BlockSpec(shape, lambda b: (0,) * len(shape))


def gru_dpd_pallas(params, iq, spec: QSpec | None = None, act: str = "hard"):
    """Run the float GRU-DPD Pallas kernel over batched frames.

    ``iq``: (B, T, 2) float32. Returns (B, T, 2) predistorted I/Q.
    """
    B, T, _ = iq.shape
    kern = partial(_float_kernel, spec=spec, act=act)
    return pl.pallas_call(
        kern,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, T, 2), lambda b: (b, 0, 0)),
            _replicated(params["w_ih"].shape),
            _replicated(params["b_ih"].shape),
            _replicated(params["w_hh"].shape),
            _replicated(params["b_hh"].shape),
            _replicated(params["w_fc"].shape),
            _replicated(params["b_fc"].shape),
        ],
        out_specs=pl.BlockSpec((1, T, 2), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, 2), iq.dtype),
        interpret=True,
    )(
        iq,
        params["w_ih"],
        params["b_ih"],
        params["w_hh"],
        params["b_hh"],
        params["w_fc"],
        params["b_fc"],
    )


# ---------------------------------------------------------------------------
# Integer kernel — bit-exact with ref.int_forward
# ---------------------------------------------------------------------------


def _int_kernel(
    iq_ref,
    w_ih_ref,
    b_ih_ref,
    w_hh_ref,
    b_hh_ref,
    w_fc_ref,
    b_fc_ref,
    sig_tab_ref,
    tanh_tab_ref,
    out_ref,
    *,
    spec: QSpec,
    act: str,
    lut: LutSpec,
    acc_dtype=jnp.int64,
):
    # Accumulator width: int64 is the reference; int32 is bit-identical
    # for bits <= 13 (|code| < 2^12 -> product < 2^24, x(H+1) < 2^28)
    # and is what the AOT artifacts use — the PJRT runtime embedded in
    # rust (xla_extension 0.5.1) miscompiles s64 elementwise chains.
    iq = iq_ref[0].astype(acc_dtype)  # (T, 2)
    w_ih = w_ih_ref[...].astype(acc_dtype)
    b_ih = b_ih_ref[...].astype(acc_dtype)
    w_hh = w_hh_ref[...].astype(acc_dtype)
    b_hh = b_hh_ref[...].astype(acc_dtype)
    w_fc = w_fc_ref[...].astype(acc_dtype)
    b_fc = b_fc_ref[...].astype(acc_dtype)
    sig_tab = sig_tab_ref[...]
    tanh_tab = tanh_tab_ref[...]
    T = iq.shape[0]
    hidden = w_hh.shape[1]
    f = spec.frac
    one = 1 << f

    def lut_idx(x_code):
        span_codes = int(round((lut.hi - lut.lo) * spec.scale))
        lo_code = int(round(lut.lo * spec.scale))
        if span_codes >= lut.n:
            shift = (span_codes // lut.n).bit_length() - 1
            idx = jnp.right_shift(x_code - lo_code, shift)
        else:
            idx = (x_code - lo_code) * (lut.n // max(span_codes, 1))
        return jnp.clip(idx, 0, lut.n - 1)

    def sig(v):
        if act == "hard":
            return hardsigmoid_int(v, spec).astype(acc_dtype)
        return jnp.take(sig_tab, lut_idx(v)).astype(acc_dtype)

    def tanh(v):
        if act == "hard":
            return hardtanh_int(v, spec).astype(acc_dtype)
        return jnp.take(tanh_tab, lut_idx(v)).astype(acc_dtype)

    # Preprocessor on the whole frame (wide intermediates).
    # feat3 = 4*|x|^2 (x4 absorbed in the f-2 shift), feat4 = feat3^2.
    i_ch, q_ch = iq[:, 0], iq[:, 1]
    p = saturate(rshift_round(i_ch * i_ch + q_ch * q_ch, f - 2), spec)
    p2 = saturate(rshift_round(p * p, f), spec)
    feats = jnp.stack([i_ch, q_ch, p, p2], axis=-1)  # (T, 4) wide

    def matvec(w, x, b):
        acc = w @ x + (b << f)
        return saturate(rshift_round(acc, f), spec)

    def body(t, carry):
        h, ys = carry
        x = jax.lax.dynamic_slice_in_dim(feats, t, 1, axis=0)[0]
        gi = matvec(w_ih, x, b_ih)
        gh = matvec(w_hh, h, b_hh)
        r = sig(saturate(gi[:hidden] + gh[:hidden], spec))
        z = sig(saturate(gi[hidden : 2 * hidden] + gh[hidden : 2 * hidden], spec))
        rh = saturate(rshift_round(r * gh[2 * hidden :], f), spec)
        n = tanh(saturate(gi[2 * hidden :] + rh, spec))
        zn = rshift_round((one - z) * n, f)
        zh = rshift_round(z * h, f)
        h_new = saturate(zn + zh, spec)
        # residual output around the raw I/Q codes
        y = saturate(matvec(w_fc, h_new, b_fc) + x[0:2], spec)
        ys = jax.lax.dynamic_update_slice_in_dim(ys, y[None, :].astype(jnp.int32), t, axis=0)
        return h_new, ys

    h0 = jnp.zeros((hidden,), acc_dtype)
    ys0 = jnp.zeros((T, 2), jnp.int32)
    _, ys = jax.lax.fori_loop(0, T, body, (h0, ys0))
    out_ref[0] = ys


def gru_dpd_pallas_int(
    iparams,
    iq_codes,
    spec: QSpec,
    act: str = "hard",
    lut: LutSpec | None = None,
    acc_dtype=None,
):
    """Integer (Q2.f) GRU-DPD Pallas kernel over batched frames.

    ``iq_codes``: (B, T, 2) int32 codes. Returns (B, T, 2) int32 codes,
    bit-exact with ``ref.int_forward``. This lowered computation (with
    weights baked as constants) is what the Rust runtime executes via
    PJRT — the chip's exact arithmetic on the request path.
    """
    B, T, _ = iq_codes.shape
    lut = lut or LutSpec()
    # int32 accumulation is bit-identical for bits <= 13 and is required
    # for the AOT artifacts (the rust-embedded XLA miscompiles s64).
    if acc_dtype is None:
        acc_dtype = jnp.int32 if spec.bits <= 13 else jnp.int64
    sig_tab = jnp.asarray(make_sigmoid_table(lut, spec))
    tanh_tab = jnp.asarray(make_tanh_table(lut, spec))
    kern = partial(_int_kernel, spec=spec, act=act, lut=lut, acc_dtype=acc_dtype)
    return pl.pallas_call(
        kern,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, T, 2), lambda b: (b, 0, 0)),
            _replicated(iparams["w_ih"].shape),
            _replicated(iparams["b_ih"].shape),
            _replicated(iparams["w_hh"].shape),
            _replicated(iparams["b_hh"].shape),
            _replicated(iparams["w_fc"].shape),
            _replicated(iparams["b_fc"].shape),
            _replicated(sig_tab.shape),
            _replicated(tanh_tab.shape),
        ],
        out_specs=pl.BlockSpec((1, T, 2), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, 2), jnp.int32),
        interpret=True,
    )(
        iq_codes,
        iparams["w_ih"],
        iparams["b_ih"],
        iparams["w_hh"],
        iparams["b_hh"],
        iparams["w_fc"],
        iparams["b_fc"],
        sig_tab,
        tanh_tab,
    )
