//! A long-lived streaming session on a [`DpdService`] worker.
//!
//! A [`StreamSession`] is the incremental face of the transmit chain:
//! the caller `push`es I/Q in chunks of any size, the session frames
//! them and feeds its worker through the bounded command channel
//! (blocking = backpressure), and predistorted samples come back via
//! `drain`/`finish`. The GRU hidden state lives in the worker-owned
//! engine and **persists across pushes** for the life of the session —
//! the silicon's continuous operating mode, and the property that
//! makes temporal-delta tricks (DeltaDPD-style) expressible at all.
//!
//! Deadlock freedom rests on one invariant: a session keeps at most
//! `queue_depth` frames in flight (unabsorbed), and its output
//! channel holds `queue_depth + 1` slots — so the worker can *always*
//! place completed output (and the final `Finished`/`Err`) without
//! blocking, which means the worker always drains its command queue,
//! which means a blocked `push` (absorbing its own output while it
//! waits) always makes progress. One thread can therefore multiplex
//! any number of sessions — even sessions sharing a worker — without
//! a consumer thread per session.
//!
//! [`DpdService`]: super::DpdService

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError, TrySendError,
};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::adapt::{AdaptCmd, AdaptStats, SessionAdaptConfig};
use super::framer::Framer;
use super::service::{Cmd, OutMsg};
use super::stats::{LatencyAgg, PipelineStats};
use super::StreamOutput;
use crate::dpd::GruWeights;
use crate::runtime::EngineKind;
use crate::util::hist::AtomicHistogram;

/// Per-session configuration. `None` fields inherit the service
/// defaults; `engine` only matters for [`DpdService::open_session`]
/// (kind-based construction against the shared manifest) — sessions
/// opened with `open_session_with` bring their own engine.
///
/// [`DpdService::open_session`]: super::DpdService::open_session
#[derive(Clone, Copy, Debug)]
pub struct SessionConfig {
    /// engine kind for manifest-backed sessions (per-session, so one
    /// service can host heterogeneous sessions)
    pub engine: EngineKind,
    /// framer length override (frame engines still win with their
    /// compiled shape)
    pub frame_len: Option<usize>,
    /// output-queue depth override
    pub queue_depth: Option<usize>,
    /// whether this session's frames may be coalesced with same-class
    /// peers into batched engine calls (when the service runs with
    /// `ServiceConfig::batch > 1`). Outputs are bit-identical either
    /// way — opting out (`false`) only buys a latency-critical session
    /// exclusive engine dispatches.
    pub coalesce: bool,
    /// closed-loop adaptation: when set, the session owns an
    /// [`AdaptTrainer`](crate::dpd::AdaptTrainer) slot on the service's
    /// adapt worker, accepts PA feedback through
    /// [`StreamSession::adapt_feedback`], and its engine is hot-swapped
    /// to a freshly re-quantized weight generation every
    /// `refresh_interval` feedback samples.
    pub adapt: Option<SessionAdaptConfig>,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            engine: EngineKind::fixed(),
            frame_len: None,
            queue_depth: None,
            coalesce: true,
            adapt: None,
        }
    }
}

/// Live snapshot of a session's pipeline counters: the
/// [`PipelineStats`] fields plus the in-flight depth. Values are as
/// of the last `push`/`drain` (those calls absorb worker output).
#[derive(Clone, Debug)]
pub struct SessionStats {
    /// engine label (from the worker-built engine)
    pub engine: &'static str,
    pub samples_in: u64,
    /// samples the engine has completed (drained or awaiting drain)
    pub samples_out: u64,
    pub frames: u64,
    /// frames sent to the worker and not yet returned
    pub in_flight: u64,
    /// wall-clock since the session opened
    pub wall: Duration,
    pub dpd_busy: Duration,
    pub lat_mean: Duration,
    pub lat_max: Duration,
    /// closed-loop adaptation metrics (None for non-adaptive sessions):
    /// refresh count, trainer progress, and the before/after ACPR/EVM
    /// of the latest engine hot-swap
    pub adapt: Option<AdaptStats>,
}

impl SessionStats {
    /// End-to-end throughput in Msamples/s so far.
    pub fn throughput_msps(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.samples_out as f64 / self.wall.as_secs_f64() / 1e6
    }

    /// DPD-stage-only throughput (what the engine itself sustains).
    pub fn engine_msps(&self) -> f64 {
        if self.dpd_busy.is_zero() {
            return 0.0;
        }
        self.samples_out as f64 / self.dpd_busy.as_secs_f64() / 1e6
    }

    /// The one-shot stats shape ([`PipelineStats`]) this snapshot
    /// extends — what `finish` reports and the compat wrapper returns.
    pub fn to_pipeline(&self) -> PipelineStats {
        PipelineStats {
            samples_in: self.samples_in,
            samples_out: self.samples_out,
            frames: self.frames,
            wall: self.wall,
            dpd_busy: self.dpd_busy,
            lat_mean: self.lat_mean,
            lat_max: self.lat_max,
        }
    }
}

/// A streaming session pinned to one service worker. Obtained from
/// [`DpdService::open_session`] / [`open_session_with`]; consumed by
/// [`StreamSession::finish`]. Dropping without `finish` abandons the
/// stream (the worker frees the engine; queued output is discarded).
///
/// [`DpdService::open_session`]: super::DpdService::open_session
/// [`open_session_with`]: super::DpdService::open_session_with
pub struct StreamSession {
    id: u64,
    engine_name: &'static str,
    cmd: SyncSender<Cmd>,
    out: Receiver<OutMsg>,
    framer: Framer,
    frame_len: usize,
    /// in-flight cap = output-queue depth (see the module docs: this
    /// is what keeps the worker from ever blocking on our output)
    depth: u64,
    /// predistorted samples absorbed from the worker, not yet drained
    ready: Vec<[f64; 2]>,
    in_flight: u64,
    expected_seq: u64,
    samples_in: u64,
    samples_out: u64,
    frames_done: u64,
    busy: Duration,
    lat: LatencyAgg,
    /// optional shared per-push latency sink (the fleet layer's
    /// per-shard histogram; plain sessions carry none)
    lat_sink: Option<Arc<AtomicHistogram>>,
    t_open: Instant,
    load: Arc<AtomicUsize>,
    /// sticky failure (formatted chain) — every later call reports it
    error: Option<String>,
    closed: bool,
    /// closed-loop adaptation plumbing (adaptive sessions only)
    adapt: Option<AdaptLink>,
}

/// The session's handle onto the service adapt worker: the command
/// channel feedback flows through, and the stats block the worker
/// publishes into.
pub(crate) struct AdaptLink {
    pub(crate) tx: SyncSender<AdaptCmd>,
    pub(crate) shared: Arc<Mutex<AdaptStats>>,
}

impl StreamSession {
    pub(crate) fn attach(
        id: u64,
        engine_name: &'static str,
        frame_len: usize,
        depth: usize,
        cmd: SyncSender<Cmd>,
        out: Receiver<OutMsg>,
        load: Arc<AtomicUsize>,
    ) -> StreamSession {
        StreamSession {
            id,
            engine_name,
            cmd,
            out,
            framer: Framer::new(frame_len),
            frame_len,
            depth: depth as u64,
            ready: Vec::new(),
            in_flight: 0,
            expected_seq: 0,
            samples_in: 0,
            samples_out: 0,
            frames_done: 0,
            busy: Duration::ZERO,
            lat: LatencyAgg::default(),
            lat_sink: None,
            t_open: Instant::now(),
            load,
            error: None,
            closed: false,
            adapt: None,
        }
    }

    /// Wire the adapt-worker link (service-side, right after open).
    pub(crate) fn attach_adapt(&mut self, link: AdaptLink) {
        self.adapt = Some(link);
    }

    /// Stamp every completed frame's service latency (push → absorb)
    /// into a shared histogram as well as the session's own
    /// [`LatencyAgg`]. The fleet layer attaches its per-shard
    /// [`AtomicHistogram`] here right after open, which is how
    /// per-shard and merged p50/p90/p99 exist without the session
    /// layer knowing about shards.
    pub(crate) fn attach_latency_sink(&mut self, sink: Arc<AtomicHistogram>) {
        self.lat_sink = Some(sink);
    }

    /// The worker command channel (the adapt worker's swap target).
    pub(crate) fn worker_cmd(&self) -> SyncSender<Cmd> {
        self.cmd.clone()
    }

    /// Session id (unique within its service).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Label of the worker-built engine (e.g. `"qgru-hard"`).
    pub fn engine(&self) -> &'static str {
        self.engine_name
    }

    /// The frame length this session cuts the stream into.
    pub fn frame_len(&self) -> usize {
        self.frame_len
    }

    /// Push a chunk of I/Q samples — any length, any chunking; the
    /// session frames them internally and the engine's hidden state
    /// carries across pushes. Blocks (backpressure) when the worker
    /// queue is full, draining completed output meanwhile.
    pub fn push(&mut self, samples: &[[f64; 2]]) -> Result<()> {
        self.check()?;
        self.samples_in += samples.len() as u64;
        for frame in self.framer.push(samples) {
            self.send_cmd(Cmd::Frame { id: self.id, frame, t0: Instant::now() })?;
        }
        // opportunistic: keep the output queue shallow
        self.pump(false)
    }

    /// Take every predistorted sample completed so far (non-blocking).
    pub fn drain(&mut self) -> Result<Vec<[f64; 2]>> {
        self.pump(false)?;
        self.check()?;
        Ok(std::mem::take(&mut self.ready))
    }

    /// Live stats snapshot (see [`SessionStats`]).
    pub fn stats(&self) -> SessionStats {
        SessionStats {
            engine: self.engine_name,
            samples_in: self.samples_in,
            samples_out: self.samples_out,
            frames: self.frames_done,
            in_flight: self.in_flight,
            wall: self.t_open.elapsed(),
            dpd_busy: self.busy,
            lat_mean: self.lat.mean(),
            lat_max: self.lat.max(),
            adapt: self.adapt_stats(),
        }
    }

    /// Whether this session runs the closed adaptation loop.
    pub fn is_adaptive(&self) -> bool {
        self.adapt.is_some()
    }

    /// Live adaptation metrics (None for non-adaptive sessions).
    pub fn adapt_stats(&self) -> Option<AdaptStats> {
        self.adapt.as_ref().map(|l| *l.shared.lock().expect("adapt stats lock"))
    }

    /// Push one burst of PA feedback into the adaptation loop: `x` the
    /// original samples, `u` the deployed DPD's output for them (what
    /// entered the amplifier), `y` the feedback receiver's observation
    /// of the PA output. All three must be equal length and aligned
    /// sample-for-sample. Blocks (backpressure) when the adapt worker
    /// is behind; the data path is unaffected. The trainer consumes
    /// the pairs in BPTT windows and hot-swaps this session's engine
    /// every `refresh_interval` *consumed* samples (silence the
    /// trainer skips never triggers a swap) — see
    /// [`SessionStats::adapt`] for before/after linearization metrics.
    pub fn adapt_feedback(
        &mut self,
        x: &[[f64; 2]],
        u: &[[f64; 2]],
        y: &[[f64; 2]],
    ) -> Result<()> {
        self.check()?;
        anyhow::ensure!(
            x.len() == u.len() && u.len() == y.len(),
            "adapt_feedback bursts must align: x {} / u {} / y {}",
            x.len(),
            u.len(),
            y.len()
        );
        let Some(link) = &self.adapt else {
            bail!("session {} is not adaptive (SessionConfig.adapt not set)", self.id)
        };
        link.tx
            .send(AdaptCmd::Feedback {
                id: self.id,
                x: x.to_vec(),
                u: u.to_vec(),
                y: y.to_vec(),
            })
            .map_err(|_| anyhow!("the adapt worker terminated"))
    }

    /// Barrier: returns once the adapt worker has consumed every
    /// feedback burst pushed so far — any refresh they triggered has
    /// been *sent* to the engine worker, so frames pushed after this
    /// call run on the refreshed engine. (Deterministic swap-boundary
    /// control for tests and the CLI demo; production callers can just
    /// stream and let refreshes land asynchronously.)
    pub fn adapt_barrier(&mut self) -> Result<()> {
        self.check()?;
        let Some(link) = &self.adapt else {
            bail!("session {} is not adaptive (SessionConfig.adapt not set)", self.id)
        };
        let (reply_tx, reply_rx) = sync_channel(1);
        link.tx
            .send(AdaptCmd::Sync { id: self.id, reply: reply_tx })
            .map_err(|_| anyhow!("the adapt worker terminated"))?;
        reply_rx.recv().map_err(|_| anyhow!("the adapt worker died mid-barrier"))
    }

    /// Deploy an externally supplied float weight generation to this
    /// session: the engine is hot-swapped at a frame boundary through
    /// the same path a trainer refresh takes (so the pre/post ACPR
    /// meter rotates and [`AdaptStats::post_refresh_acpr_dbc`] will
    /// latch the deployed generation's first full feedback window),
    /// and the trainer is reseated on the deployed twin. This is the
    /// fleet rollout controller's push seam
    /// ([`crate::coordinator::rollout`]); only adaptive sessions can
    /// receive deployments. Returns once the swap has been *sent* —
    /// frames pushed after this call run on the deployed engine.
    pub fn deploy_weights(&mut self, w: &GruWeights) -> Result<()> {
        self.check()?;
        let Some(link) = &self.adapt else {
            bail!("session {} is not adaptive (SessionConfig.adapt not set)", self.id)
        };
        let (reply_tx, reply_rx) = sync_channel(1);
        link.tx
            .send(AdaptCmd::Deploy {
                id: self.id,
                w: Box::new(w.clone()),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("the adapt worker terminated"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("the adapt worker died mid-deploy"))?
    }

    /// Reset the engine's hidden state, in stream order: a partial
    /// frame is flushed (zero-padded, trimmed on output) first, so
    /// samples pushed after `reset` behave exactly like the start of
    /// a fresh stream.
    pub fn reset(&mut self) -> Result<()> {
        self.check()?;
        if let Some(tail) = self.framer.flush() {
            self.send_cmd(Cmd::Frame { id: self.id, frame: tail, t0: Instant::now() })?;
        }
        self.send_cmd(Cmd::Reset { id: self.id })
    }

    /// Flush the tail, wait for every in-flight frame, close the
    /// session and return the not-yet-drained output plus final stats
    /// (`stats.samples_out` counts the whole stream even if part of
    /// it was consumed incrementally via `drain`).
    pub fn finish(mut self) -> Result<StreamOutput> {
        self.check()?;
        if let Some(tail) = self.framer.flush() {
            self.send_cmd(Cmd::Frame { id: self.id, frame: tail, t0: Instant::now() })?;
        }
        self.send_cmd(Cmd::Finish { id: self.id })?;
        loop {
            match self.out.recv() {
                Ok(OutMsg::Finished) => break,
                Ok(msg) => self.absorb(msg)?,
                Err(_) => {
                    self.error = Some("worker dropped the session".into());
                    self.check()?;
                }
            }
        }
        self.closed = true;
        self.load.fetch_sub(1, Ordering::SeqCst);
        if let Some(link) = self.adapt.take() {
            link.tx.send(AdaptCmd::Close { id: self.id }).ok();
        }
        let mut stats = self.stats().to_pipeline();
        stats.wall = self.t_open.elapsed();
        Ok(StreamOutput { iq: std::mem::take(&mut self.ready), stats })
    }

    /// Fail fast on a sticky error.
    fn check(&self) -> Result<()> {
        match &self.error {
            Some(msg) => bail!("session {} failed: {msg}", self.id),
            None => Ok(()),
        }
    }

    /// Send a command to the worker without ever deadlocking: frames
    /// first wait under the in-flight cap, and a full command queue is
    /// ridden out by absorbing our own output while the worker (which
    /// never blocks on output) drains it.
    fn send_cmd(&mut self, msg: Cmd) -> Result<()> {
        let is_frame = matches!(msg, Cmd::Frame { .. });
        // the deadlock-freedom invariant (module docs): never exceed
        // `depth` unabsorbed frames, so completed output always fits
        // in our output queue and the worker never blocks sending it
        while is_frame && self.in_flight >= self.depth {
            self.pump(true)?;
        }
        let mut msg = msg;
        loop {
            match self.cmd.try_send(msg) {
                Ok(()) => {
                    if is_frame {
                        self.in_flight += 1;
                    }
                    return Ok(());
                }
                Err(TrySendError::Full(m)) => {
                    msg = m;
                    self.pump(true)?;
                }
                Err(TrySendError::Disconnected(_)) => {
                    self.error = Some("worker terminated (service shut down?)".into());
                    return self.check();
                }
            }
        }
    }

    /// Absorb completed output. `wait_one = true` blocks briefly for
    /// the first message (used while the command queue is full);
    /// otherwise strictly non-blocking.
    fn pump(&mut self, wait_one: bool) -> Result<()> {
        let mut wait = wait_one;
        loop {
            let msg = if wait {
                match self.out.recv_timeout(Duration::from_millis(1)) {
                    Ok(m) => m,
                    Err(RecvTimeoutError::Timeout) => return Ok(()),
                    Err(RecvTimeoutError::Disconnected) => return self.on_disconnect(),
                }
            } else {
                match self.out.try_recv() {
                    Ok(m) => m,
                    Err(TryRecvError::Empty) => return Ok(()),
                    Err(TryRecvError::Disconnected) => return self.on_disconnect(),
                }
            };
            wait = false;
            self.absorb(msg)?;
        }
    }

    fn on_disconnect(&mut self) -> Result<()> {
        // the worker dropped our output sender without an Err/Finished:
        // only legitimate when nothing was pending
        if self.error.is_none() && (self.in_flight > 0 || !self.closed) {
            self.error = Some("worker dropped the session".into());
        }
        self.check()
    }

    fn absorb(&mut self, msg: OutMsg) -> Result<()> {
        match msg {
            OutMsg::Frame { frame, t0, busy } => {
                anyhow::ensure!(frame.seq == self.expected_seq, "frame reordering detected");
                self.expected_seq += 1;
                self.frames_done += 1;
                self.in_flight = self.in_flight.saturating_sub(1);
                self.busy += busy;
                let lat = t0.elapsed();
                self.lat.record(lat);
                if let Some(sink) = &self.lat_sink {
                    sink.record(lat);
                }
                self.samples_out += frame.valid as u64;
                self.ready.extend_from_slice(&frame.data[..frame.valid]);
                Ok(())
            }
            OutMsg::Err(e) => {
                // the worker already dropped the session state
                self.in_flight = 0;
                self.error = Some(format!("{e:#}"));
                self.check()
            }
            OutMsg::Finished => Err(anyhow!("protocol error: unexpected Finished")),
        }
    }
}

impl Drop for StreamSession {
    fn drop(&mut self) {
        if !self.closed {
            // blocking send so the worker reliably frees the engine
            // (bounded wait: the worker never blocks on output, so its
            // command queue always drains); an Err here means the
            // worker is already gone, which frees everything anyway
            self.cmd.send(Cmd::Close { id: self.id }).ok();
            self.load.fetch_sub(1, Ordering::SeqCst);
            if let Some(link) = self.adapt.take() {
                link.tx.send(AdaptCmd::Close { id: self.id }).ok();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_config_defaults_inherit_service() {
        let cfg = SessionConfig::default();
        assert_eq!(cfg.engine, EngineKind::fixed());
        assert!(cfg.frame_len.is_none() && cfg.queue_depth.is_none());
        assert!(cfg.coalesce, "sessions default into the batched path");
        assert!(cfg.adapt.is_none(), "sessions default to a frozen engine");
    }

    #[test]
    fn session_stats_math_and_pipeline_mapping() {
        let s = SessionStats {
            engine: "fixture",
            samples_in: 2_000_000,
            samples_out: 1_000_000,
            frames: 10,
            in_flight: 3,
            wall: Duration::from_millis(100),
            dpd_busy: Duration::from_millis(50),
            lat_mean: Duration::from_micros(20),
            lat_max: Duration::from_micros(90),
            adapt: None,
        };
        assert!((s.throughput_msps() - 10.0).abs() < 1e-9);
        assert!((s.engine_msps() - 20.0).abs() < 1e-9);
        let p = s.to_pipeline();
        assert_eq!(p.samples_in, 2_000_000);
        assert_eq!(p.samples_out, 1_000_000);
        assert_eq!(p.frames, 10);
        assert_eq!(p.lat_max, Duration::from_micros(90));
        assert!((p.engine_msps() - s.engine_msps()).abs() < 1e-12);
    }

    #[test]
    fn zero_division_safe() {
        let s = SessionStats {
            engine: "x",
            samples_in: 0,
            samples_out: 0,
            frames: 0,
            in_flight: 0,
            wall: Duration::ZERO,
            dpd_busy: Duration::ZERO,
            lat_mean: Duration::ZERO,
            lat_max: Duration::ZERO,
            adapt: None,
        };
        assert_eq!(s.throughput_msps(), 0.0);
        assert_eq!(s.engine_msps(), 0.0);
    }
}
