//! Parametric Q2.f fixed-point arithmetic — the ASIC's number system.
//!
//! The paper's datapath (§III-C) is 12-bit Q2.10: 2 integer bits (one
//! of them sign) and 10 fractional bits, for weights, activations and
//! the I/Q streams. [`QSpec`] generalizes to any width for the Fig. 3
//! precision sweep; [`ops`] holds the canonical rounding / saturation
//! primitives shared (bit-for-bit) with the python reference
//! (`python/compile/kernels/quant.py`) and used by every quantized
//! engine in the crate (`dpd::qgru`, `accel::engine`).

pub mod kernel;
pub mod ops;
pub mod qprofile;
pub mod qspec;

pub use kernel::{GateKernel, ScalarKernel, SimdKernel, SimdPolicy};
pub use ops::{rshift_round, saturate_i64};
pub use qprofile::QProfile;
pub use qspec::QSpec;
