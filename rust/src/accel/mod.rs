//! The DPD-NeuralEngine ASIC model (paper §III, Fig. 2/5, Tables I-III).
//!
//! * [`ops`] — exact operation accounting (the paper's "OP/S" column);
//! * [`pe`] / [`preproc`] / [`act_unit`] / [`buffers`] — datapath units
//!   with activity counters;
//! * [`fsm`] — the cycle schedule: the GRU recurrence closes an
//!   8-cycle dependency loop at 2 GHz -> 250 MSps, with a 15-cycle
//!   input-to-output pipeline latency (7.5 ns);
//! * [`engine`] — the cycle-accurate simulator (bit-exact with
//!   `dpd::qgru`, plus cycle/activity/energy accounting);
//! * [`delta`] — the delta execution path's cost model: prices the
//!   measured column sparsity of the `dpd` delta engines into MAC
//!   reduction and projected energy (DeltaDPD-style clock gating);
//! * [`power`] — the 22FDX energy model (Fig. 5's 195 mW);
//! * [`area`] — the area model (Fig. 5's 0.2 mm^2);
//! * [`fpga`] — the Zynq-7020 resource estimator (Table I, Fig. 4);
//! * [`spec`] — the headline-number calculator tying it all together
//!   (Fig. 5, Tables II/III rows).

pub mod act_unit;
pub mod area;
pub mod buffers;
pub mod delta;
pub mod engine;
pub mod fpga;
pub mod fsm;
pub mod ops;
pub mod pe;
pub mod power;
pub mod preproc;
pub mod sparse;
pub mod spec;

pub use delta::DeltaCostModel;
pub use sparse::SparseCostModel;
pub use engine::{CycleAccurateEngine, EngineStats};
pub use spec::AsicSpec;
