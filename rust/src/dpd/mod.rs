//! Digital pre-distortion engines.
//!
//! * [`gmp`] — the generalized-memory-polynomial baseline (paper
//!   Table II's FPGA competitors all run GMP/MP models), fit by
//!   indirect learning with the ridge LS solver;
//! * [`gru`] — float GRU-RNN DPD (the paper's model, f64 reference
//!   implementation);
//! * [`qgru`] — the bit-exact Q2.f fixed-point GRU, mirroring the
//!   canonical integer datapath (`kernels/ref.py::int_step`)
//!   instruction for instruction — this is the functional model of
//!   the silicon;
//! * [`weights`] — loaders for the artifact weight JSONs.
//!
//! All engines implement the [`Dpd`] trait: a causal, streaming
//! sample-in/sample-out predistorter.

pub mod gmp;
pub mod gru;
pub mod qgru;
pub mod weights;

use anyhow::{bail, Result};

pub use gmp::GmpDpd;
pub use gru::GruDpd;
pub use qgru::QGruDpd;
pub use weights::GruWeights;

/// Recurrent-state snapshot of a streaming predistorter — one stream's
/// lane in a batched call. Opaque to callers: only `save_state` /
/// `load_state` on the engine kind that produced it interpret the
/// contents.
#[derive(Clone, Debug, PartialEq)]
pub enum DpdState {
    /// the engine carries no per-stream recurrent state
    Stateless,
    /// integer hidden-state codes (`QGruDpd`, the cycle-accurate sim)
    I32(Vec<i32>),
    /// float hidden state (`GruDpd`)
    F64(Vec<f64>),
}

impl DpdState {
    /// Short descriptor for error messages (never dumps the payload).
    pub fn kind(&self) -> &'static str {
        match self {
            DpdState::Stateless => "stateless",
            DpdState::I32(_) => "i32",
            DpdState::F64(_) => "f64",
        }
    }
}

/// One independent stream's slot in a batched call: the samples
/// (predistorted in place) plus that stream's recurrent state (updated
/// in place). Lanes may have different lengths (ragged tails).
pub struct DpdLane<'a> {
    pub iq: &'a mut [[f64; 2]],
    pub state: &'a mut DpdState,
}

/// A causal streaming predistorter.
pub trait Dpd {
    /// Process one I/Q sample.
    fn process(&mut self, iq: [f64; 2]) -> [f64; 2];

    /// Reset internal state (hidden state / delay lines).
    fn reset(&mut self);

    /// Convenience: process a whole burst after a reset.
    fn run(&mut self, x: &[[f64; 2]]) -> Vec<[f64; 2]> {
        self.reset();
        x.iter().map(|&s| self.process(s)).collect()
    }

    /// Engine label for reports.
    fn name(&self) -> &'static str;

    /// Snapshot the current stream's recurrent state. The default is
    /// [`DpdState::Stateless`]; engines with real state must override
    /// this *and* [`Dpd::load_state`] so the pair round-trips exactly —
    /// that round-trip is what makes multi-lane batching bit-exact.
    fn save_state(&self) -> DpdState {
        DpdState::Stateless
    }

    /// Restore a snapshot produced by [`Dpd::save_state`] on the same
    /// engine kind and shape.
    fn load_state(&mut self, state: &DpdState) -> Result<()> {
        match state {
            DpdState::Stateless => Ok(()),
            other => bail!("{}: cannot load a {} state snapshot", self.name(), other.kind()),
        }
    }

    /// Fingerprint identifying predistorters that may share one batched
    /// call: equal fingerprints promise identical datapaths (same kind,
    /// dims, format, weights and activation). `None` (the default)
    /// means "never coalesce me with anyone".
    fn batch_fingerprint(&self) -> Option<u64> {
        None
    }

    /// Process several independent streams in one call, each lane
    /// carrying its own recurrent state. Must be bit-identical, lane
    /// for lane, to processing each stream alone through
    /// [`Dpd::process`] — the contract `tests/batch_parity.rs`
    /// enforces. The default multiplexes the lanes sequentially over
    /// `self` via `save_state`/`load_state`; structure-of-arrays
    /// overrides (`QGruDpd`, `GruDpd`) vectorize across lanes.
    ///
    /// On error the whole batch is *reported* failed together and the
    /// lanes must be discarded: already-processed lanes may have had
    /// their samples and state snapshots advanced, so retrying or
    /// salvaging individual lanes is not sound. The coalescing
    /// scheduler relies on this to give every session of a failed
    /// batch the same sticky error (and drops the frames).
    fn process_lanes(&mut self, lanes: &mut [DpdLane<'_>]) -> Result<()> {
        process_lanes_sequential(self, lanes)
    }
}

/// The sequential fallback behind [`Dpd::process_lanes`]: multiplex
/// the lanes one at a time over a single engine, swapping each lane's
/// state in and out. `self`'s own stream state is preserved.
pub fn process_lanes_sequential<D: Dpd + ?Sized>(
    dpd: &mut D,
    lanes: &mut [DpdLane<'_>],
) -> Result<()> {
    let own = dpd.save_state();
    let mut result = Ok(());
    for lane in lanes.iter_mut() {
        if let Err(e) = dpd.load_state(lane.state) {
            result = Err(e);
            break;
        }
        for s in lane.iq.iter_mut() {
            *s = dpd.process(*s);
        }
        *lane.state = dpd.save_state();
    }
    dpd.load_state(&own).ok();
    result
}

/// The identity DPD (for "DPD off" rows in the tables).
pub struct NoDpd;

impl Dpd for NoDpd {
    fn process(&mut self, iq: [f64; 2]) -> [f64; 2] {
        iq
    }
    fn reset(&mut self) {}
    fn name(&self) -> &'static str {
        "none"
    }
}
