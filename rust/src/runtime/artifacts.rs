//! Artifact manifest: the inventory `aot.py` writes next to the HLO
//! text files, weight JSONs and golden vectors.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One lowered HLO executable description.
#[derive(Clone, Debug)]
pub struct HloEntry {
    pub file: String,
    /// "int" (Q2.f codes) or "float" (f32)
    pub kind: String,
    pub bits: u32,
    pub act: String,
    pub batch: usize,
    pub time: usize,
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub hidden: usize,
    pub features: usize,
    pub n_params: usize,
    pub qspec_bits: u32,
    pub pa_model: PathBuf,
    pub weights_main: PathBuf,
    pub weights_float: PathBuf,
    pub sweep: Vec<(String, PathBuf)>,
    pub hlo: Vec<HloEntry>,
    pub golden: Vec<PathBuf>,
}

impl Manifest {
    pub fn load(root: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&root.join("manifest.json")).context("loading manifest")?;
        let model = j.get("model")?;
        let weights = j.get("weights")?;
        let mut sweep = Vec::new();
        if let Some(sw) = weights.opt("sweep") {
            for (name, path) in sw.as_obj()? {
                sweep.push((name.clone(), root.join(path.as_str()?)));
            }
        }
        let mut hlo = Vec::new();
        for e in j.get("hlo")?.as_arr()? {
            hlo.push(HloEntry {
                file: e.get("file")?.as_str()?.to_string(),
                kind: e.get("kind")?.as_str()?.to_string(),
                bits: e.get("bits")?.as_usize()? as u32,
                act: e.get("act")?.as_str()?.to_string(),
                batch: e.get("batch")?.as_usize()?,
                time: e.get("time")?.as_usize()?,
            });
        }
        let golden = j
            .get("golden")?
            .as_arr()?
            .iter()
            .map(|g| Ok(root.join(g.as_str()?)))
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            root: root.to_path_buf(),
            hidden: model.get("hidden")?.as_usize()?,
            features: model.get("features")?.as_usize()?,
            n_params: model.get("n_params")?.as_usize()?,
            qspec_bits: j.get("qspec")?.get("bits")?.as_usize()? as u32,
            pa_model: root.join(j.get("pa")?.as_str()?),
            weights_main: root.join(weights.get("main")?.as_str()?),
            weights_float: root.join(weights.get("float")?.as_str()?),
            sweep,
            hlo,
            golden,
        })
    }

    /// Locate the artifact tree: explicit path, $DPD_NE_ARTIFACTS, or
    /// the crate-root `artifacts/` directory.
    pub fn discover(explicit: Option<&Path>) -> Result<Manifest> {
        if let Some(p) = explicit {
            return Manifest::load(p);
        }
        if let Ok(env) = std::env::var("DPD_NE_ARTIFACTS") {
            return Manifest::load(Path::new(&env));
        }
        let default = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if default.join("manifest.json").exists() {
            return Manifest::load(&default);
        }
        bail!(
            "no artifact tree found: pass a path, set DPD_NE_ARTIFACTS, \
             or run `make artifacts`"
        )
    }

    /// The preferred integer HLO entry with the longest frame.
    pub fn best_int_hlo(&self) -> Option<&HloEntry> {
        self.hlo
            .iter()
            .filter(|e| e.kind == "int")
            .max_by_key(|e| e.time)
    }

    /// An integer HLO entry with an exact frame length.
    pub fn int_hlo_with_time(&self, time: usize) -> Option<&HloEntry> {
        self.hlo
            .iter()
            .find(|e| e.kind == "int" && e.time == time)
    }

    pub fn hlo_path(&self, e: &HloEntry) -> PathBuf {
        self.root.join(&e.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_root() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn loads_real_manifest_if_present() {
        let Some(root) = artifacts_root() else {
            eprintln!("skipping (no artifacts)");
            return;
        };
        let m = Manifest::load(&root).unwrap();
        assert_eq!(m.n_params, 502);
        assert_eq!(m.hidden, 10);
        assert_eq!(m.qspec_bits, 12);
        assert!(!m.hlo.is_empty());
        assert!(m.best_int_hlo().is_some());
        assert!(m.pa_model.exists());
        assert!(m.weights_main.exists());
        for g in &m.golden {
            assert!(g.exists(), "{g:?} missing");
        }
        // sweep covers the Fig. 3 grid
        assert!(m.sweep.len() >= 4);
    }

    #[test]
    fn discover_fails_cleanly_without_tree() {
        let err = Manifest::load(Path::new("/nonexistent/nowhere")).unwrap_err();
        assert!(format!("{err:#}").contains("manifest"));
    }
}
