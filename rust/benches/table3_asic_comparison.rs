//! Table III reproduction: comparison with prior RNN/DNN ASICs on
//! area/power/throughput efficiency. Our row comes from the models;
//! literature rows use the paper's *published derived columns*
//! (TOPS/W, GOPS/mm², PAE) verbatim — several prior chips report peak
//! throughput and nominal power at different operating points (e.g.
//! [29]: 3,604 GOPS but 6.83 TOPS/W), so re-deriving efficiency from
//! GOPS/power would misrepresent them, exactly as the paper avoids.
//!
//! Shape to preserve: this work has the highest PAE (TOPS/W/mm²) of
//! all rows — the paper's headline claim — with [29] (7 nm) second.
//!
//! Run: `cargo bench --bench table3_asic_comparison`

use dpd_ne::accel::AsicSpec;
use dpd_ne::dpd::weights::QGruWeights;
use dpd_ne::fixed::QSpec;
use dpd_ne::report::{f2, Table};
use dpd_ne::runtime::Manifest;

struct Asic {
    name: &'static str,
    tech_nm: u32,
    fclk_mhz: f64,
    bits: &'static str,
    area_mm2: f64,
    power_mw: f64,
    gops: f64,
    /// published derived columns (paper Table III)
    tops_w: f64,
    gops_mm2: f64,
    pae: f64,
}

/// Paper Table III rows, columns as printed.
const PRIOR: [Asic; 7] = [
    Asic { name: "[23] JSSC'20", tech_nm: 65, fclk_mhz: 80.0, bits: "32", area_mm2: 7.7, power_mw: 67.0, gops: 165.0, tops_w: 2.45, gops_mm2: 21.3, pae: 0.32 },
    Asic { name: "[24] DNPU", tech_nm: 65, fclk_mhz: 200.0, bits: "32", area_mm2: 16.0, power_mw: 21.0, gops: 25.0, tops_w: 1.19, gops_mm2: 1.6, pae: 0.07 },
    Asic { name: "[25] KWS", tech_nm: 65, fclk_mhz: 0.25, bits: "32", area_mm2: 0.4, power_mw: 0.02, gops: 0.004, tops_w: 0.17, gops_mm2: 0.01, pae: 0.40 },
    Asic { name: "[26] UNPU", tech_nm: 65, fclk_mhz: 200.0, bits: "16", area_mm2: 16.0, power_mw: 297.0, gops: 346.0, tops_w: 3.08, gops_mm2: 21.6, pae: 0.07 },
    Asic { name: "[27] EIE", tech_nm: 45, fclk_mhz: 800.0, bits: "4", area_mm2: 40.8, power_mw: 590.0, gops: 102.0, tops_w: 0.17, gops_mm2: 2.5, pae: 0.004 },
    Asic { name: "[28] BrainTTA", tech_nm: 22, fclk_mhz: 300.0, bits: "8", area_mm2: 3.0, power_mw: 31.0, gops: 77.0, tops_w: 2.47, gops_mm2: 25.8, pae: 0.83 },
    Asic { name: "[29] 7nm SoC", tech_nm: 7, fclk_mhz: 880.0, bits: "8", area_mm2: 3.0, power_mw: 174.0, gops: 3604.0, tops_w: 6.83, gops_mm2: 1185.7, pae: 2.25 },
];

fn main() -> anyhow::Result<()> {
    let Ok(m) = Manifest::discover(None) else {
        eprintln!("table3: skipped (run `make artifacts` first)");
        return Ok(());
    };
    let w = QGruWeights::load_params_int(&m.weights_main, QSpec::new(m.qspec_bits)?)?;
    let s = AsicSpec::nominal(&w, true);
    let ours = Asic {
        name: "This Work (model)",
        tech_nm: 22,
        fclk_mhz: 2000.0,
        bits: "12",
        area_mm2: s.area.total_mm2(),
        power_mw: s.power.total_mw(),
        gops: s.throughput_gops,
        tops_w: s.power_efficiency_gops_w() / 1e3,
        gops_mm2: s.area_efficiency_gops_mm2(),
        pae: s.pae_tops_w_mm2(),
    };

    let mut t = Table::new(
        "Table III: prior RNN/DNN ASICs (PAE = TOPS/W/mm²)",
        &["work", "tech nm", "f_clk MHz", "bits", "mm²", "mW", "GOPS", "TOPS/W", "GOPS/mm²", "PAE"],
    );
    let mut all: Vec<&Asic> = PRIOR.iter().collect();
    all.push(&ours);
    for a in &all {
        t.row(&[
            a.name.to_string(),
            a.tech_nm.to_string(),
            format!("{:.0}", a.fclk_mhz),
            a.bits.to_string(),
            format!("{:.2}", a.area_mm2),
            format!("{:.1}", a.power_mw),
            format!("{:.1}", a.gops),
            f2(a.tops_w),
            format!("{:.1}", a.gops_mm2),
            f2(a.pae),
        ]);
    }
    println!("{}", t.render());

    // shape assertions: PAE ranking (ours first, [29] second)
    let mut ranked: Vec<(&str, f64)> = all.iter().map(|a| (a.name, a.pae)).collect();
    ranked.sort_by(|a, b| b.1.total_cmp(&a.1));
    println!("PAE ranking:");
    for (i, (name, pae)) in ranked.iter().enumerate() {
        println!("  {}. {:<18} {:.3}", i + 1, name, pae);
    }
    assert_eq!(ranked[0].0, "This Work (model)", "this work must lead PAE");
    assert_eq!(ranked[1].0, "[29] 7nm SoC", "7nm SoC must rank second");
    assert!(ours.pae > 2.0 * ranked[1].1, "PAE lead must be >2x (paper: 6.58 vs 2.25)");
    // our row must land near the paper's published values
    assert!((ours.pae - 6.58).abs() / 6.58 < 0.25);
    assert!((ours.gops_mm2 - 1282.5).abs() / 1282.5 < 0.10);
    println!(
        "\nshape checks passed: PAE leadership preserved ({:.2} vs {:.2} for the 7 nm SoC)\n",
        ours.pae, ranked[1].1
    );

    dpd_ne::bench::bench("table3: spec computation", || {
        std::hint::black_box(AsicSpec::nominal(&w, true));
    });
    Ok(())
}
