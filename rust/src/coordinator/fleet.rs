//! Fleet-scale serving: shard sessions across N independent
//! [`DpdService`] pools, with admission control and live latency
//! observability.
//!
//! One [`DpdService`] is the paper's deployment unit — a worker pool
//! linearizing a handful of transmit chains. The ROADMAP north-star
//! (millions of users, one resident DPD per antenna across many
//! radios) is *many* such pools, and that aggregation layer is what a
//! [`Fleet`] provides:
//!
//! ```text
//!   Fleet::start(cfg)
//!        │  spawn N shards (independent DpdService pools)
//!   open_session(cfg)
//!        │  admission: draining? global cap? per-shard cap?  ── typed
//!        │      AdmissionError rejection (never unbounded queueing)
//!        │  placement: ShardPolicy picks a shard
//!        ▼
//!   FleetSession ── push/drain/finish ──▶ shard k's StreamSession
//!        │  every completed frame stamps shard k's AtomicHistogram
//!   fleet.stats() ──▶ FleetStats: open/rejected/drained counters,
//!        │            per-shard busy ratio + queue depth,
//!        │            per-shard and merged p50/p90/p99
//!   fleet.drain()
//!        │  stop admitting (Draining rejections), wait for callers
//!        │  to flush + close their sessions, then shut every shard
//!        ▼  down in order (adapt worker first, then engine workers)
//! ```
//!
//! Shards are deliberately *independent* services — separate worker
//! threads, separate adapt workers, separate coalescing schedulers —
//! so a stalled or poisoned shard cannot stall its peers, and the
//! per-service deadlock-freedom invariant (session module docs) holds
//! shard-locally without any cross-shard reasoning.
//!
//! Placement ([`ShardPolicy`]) matters because of the coalescing
//! scheduler: batched engine calls only form *within* one worker, so
//! [`ShardPolicy::StickyByClass`] routes sessions with the same
//! engine spec to the same shard, keeping coalescable peers together;
//! `RoundRobin`/`LeastLoaded` instead optimize for spread. Outputs are
//! bit-identical under every policy — placement only moves *where* a
//! session runs, never *what* it computes (proven by the fleet parity
//! test against direct single-service sessions).

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::service::{DpdService, ServiceConfig};
use super::session::{SessionConfig, SessionStats, StreamSession};
use super::StreamOutput;
use crate::dpd::GruWeights;
use crate::runtime::DpdEngine;
use crate::util::fnv1a_words;
use crate::util::hist::{AtomicHistogram, LatencyHistogram};

/// How the fleet picks a shard for a new session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardPolicy {
    /// Rotate through shards in order (skipping full ones) —
    /// deterministic spread, oblivious to load.
    RoundRobin,
    /// Place on the shard with the fewest open sessions — evens out
    /// load when session lifetimes vary wildly.
    LeastLoaded,
    /// Route sessions with the same engine spec to the same shard, so
    /// coalescable sessions (same batch class) land on one worker pool
    /// and the coalescing scheduler can actually gather them. Sessions
    /// that opted out of coalescing, or whose home shard is full,
    /// spill to the least-loaded shard with capacity.
    StickyByClass,
}

/// Admission limits. A fleet never queues session opens — beyond these
/// caps it rejects fast with a typed [`AdmissionError`], so callers
/// (load balancers, the loadgen harness) see backpressure immediately
/// instead of building an unbounded backlog.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// max open sessions per shard (`usize::MAX` = unlimited)
    pub max_sessions_per_shard: usize,
    /// max open sessions across the whole fleet (`usize::MAX` =
    /// unlimited)
    pub max_sessions: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            max_sessions_per_shard: usize::MAX,
            max_sessions: usize::MAX,
        }
    }
}

/// Why the fleet refused a session. Carried inside the
/// [`anyhow::Error`] returned from the open calls — recover it with
/// `err.downcast_ref::<AdmissionError>()` to distinguish an admission
/// rejection (expected under load; retry later or elsewhere) from an
/// engine-construction failure (a bug or a broken artifact tree).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionError {
    /// the global [`AdmissionConfig::max_sessions`] cap is reached
    FleetFull { limit: usize },
    /// every admissible shard is at
    /// [`AdmissionConfig::max_sessions_per_shard`]; `shard` is the
    /// placement policy's first choice
    ShardFull { shard: usize, limit: usize },
    /// [`Fleet::drain`] has begun: the fleet no longer admits sessions
    Draining,
}

impl fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmissionError::FleetFull { limit } => {
                write!(f, "fleet admission rejected the session: global limit of {limit} open sessions reached")
            }
            AdmissionError::ShardFull { shard, limit } => {
                write!(
                    f,
                    "fleet admission rejected the session: shard {shard} (and every alternative) is at its per-shard limit of {limit} open sessions"
                )
            }
            AdmissionError::Draining => {
                write!(f, "fleet is draining: no new sessions are admitted")
            }
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Fleet configuration: N independent shards, each a full
/// [`ServiceConfig`] worker pool, plus placement and admission policy.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// number of independent `DpdService` shards
    pub shards: usize,
    /// per-shard service configuration (every shard is identical)
    pub service: ServiceConfig,
    /// session placement policy
    pub policy: ShardPolicy,
    /// admission limits (default: unlimited)
    pub admission: AdmissionConfig,
    /// how long [`Fleet::drain`] waits for session owners to release
    /// their handles before giving up with a typed [`DrainTimeout`].
    /// `None` (the default, matching the pre-deadline behavior) waits
    /// forever.
    pub drain_deadline: Option<Duration>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            shards: 2,
            service: ServiceConfig::default(),
            policy: ShardPolicy::RoundRobin,
            admission: AdmissionConfig::default(),
            drain_deadline: None,
        }
    }
}

/// [`Fleet::drain`] gave up waiting: some session handles were never
/// finished or dropped within [`FleetConfig::drain_deadline`]. The
/// fleet stops admitting (the draining flag stays set) and the shard
/// services are *dropped, not joined* — a leaked handle keeps its
/// worker channel alive, so joining would inherit the very hang the
/// deadline exists to break; workers wind down on their own when the
/// last handle disappears.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DrainTimeout {
    /// sessions still open when the deadline expired
    pub stuck_sessions: usize,
    /// the configured deadline that expired
    pub deadline: Duration,
}

impl std::fmt::Display for DrainTimeout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "fleet drain timed out after {:?} with {} session(s) still open",
            self.deadline, self.stuck_sessions
        )
    }
}

impl std::error::Error for DrainTimeout {}

/// Live per-shard snapshot inside [`FleetStats`].
#[derive(Clone, Debug)]
pub struct ShardStats {
    /// open sessions placed on this shard right now
    pub sessions_open: usize,
    /// frames in flight (sent to workers, not yet absorbed) summed
    /// over this shard's sessions, as of each session's last
    /// push/drain
    pub queue_depth: u64,
    /// engine-busy time ÷ (wall time × workers): the fraction of this
    /// shard's compute capacity actually spent inside engines. ~1.0
    /// means the shard is saturated; the loadgen sweep's knee is where
    /// the busiest shards pin here.
    pub busy_ratio: f64,
    /// per-push service latency (push → frame absorbed) distribution
    pub latency: LatencyHistogram,
}

/// Live fleet snapshot from [`Fleet::stats`].
#[derive(Clone, Debug)]
pub struct FleetStats {
    /// sessions open across the fleet right now
    pub sessions_open: usize,
    /// sessions ever admitted
    pub sessions_opened: u64,
    /// opens refused by admission control (typed [`AdmissionError`])
    pub sessions_rejected: u64,
    /// admitted sessions since closed (finished or dropped)
    pub sessions_drained: u64,
    /// whether [`Fleet::drain`] has begun
    pub draining: bool,
    /// per-shard breakdown, indexed by shard id
    pub shards: Vec<ShardStats>,
    /// the merge of every shard's latency histogram
    pub latency: LatencyHistogram,
}

/// placement + admission bookkeeping, all mutations under one lock
/// (opens/closes are rare next to pushes, so a mutex here costs
/// nothing on the data path and makes cap checks race-free)
struct Placement {
    open_total: usize,
    open: Vec<usize>,
    rr: usize,
    draining: bool,
}

/// hot-path per-shard meters (updated lock-free from sessions)
struct ShardMeter {
    hist: Arc<AtomicHistogram>,
    busy_ns: AtomicU64,
    queue: AtomicU64,
}

struct Shared {
    place: Mutex<Placement>,
    meters: Vec<ShardMeter>,
    opened: AtomicU64,
    rejected: AtomicU64,
    drained: AtomicU64,
    t_start: Instant,
    workers_per_shard: usize,
}

impl Shared {
    /// undo one admitted session's bookkeeping (close or failed open)
    fn release(&self, shard: usize) {
        let mut p = self.place.lock().expect("fleet placement lock");
        p.open_total = p.open_total.saturating_sub(1);
        p.open[shard] = p.open[shard].saturating_sub(1);
    }
}

/// A pool of independent [`DpdService`] shards behind one admission
/// and placement front door. See the module docs for the lifecycle.
pub struct Fleet {
    cfg: FleetConfig,
    services: Vec<DpdService>,
    shared: Arc<Shared>,
}

impl Fleet {
    /// Spawn every shard's worker pool. Shards are identical
    /// ([`FleetConfig::service`]) and fully independent.
    pub fn start(cfg: FleetConfig) -> Result<Fleet> {
        anyhow::ensure!(cfg.shards > 0, "FleetConfig.shards must be > 0");
        anyhow::ensure!(
            cfg.admission.max_sessions_per_shard > 0,
            "AdmissionConfig.max_sessions_per_shard must be > 0"
        );
        anyhow::ensure!(
            cfg.admission.max_sessions > 0,
            "AdmissionConfig.max_sessions must be > 0"
        );
        let services = (0..cfg.shards)
            .map(|_| DpdService::start(cfg.service.clone()))
            .collect::<Result<Vec<_>>>()?;
        let shared = Arc::new(Shared {
            place: Mutex::new(Placement {
                open_total: 0,
                open: vec![0; cfg.shards],
                rr: 0,
                draining: false,
            }),
            meters: (0..cfg.shards)
                .map(|_| ShardMeter {
                    hist: Arc::new(AtomicHistogram::new()),
                    busy_ns: AtomicU64::new(0),
                    queue: AtomicU64::new(0),
                })
                .collect(),
            opened: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            drained: AtomicU64::new(0),
            t_start: Instant::now(),
            workers_per_shard: cfg.service.workers,
        });
        Ok(Fleet { cfg, services, shared })
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.services.len()
    }

    /// Admission + placement: returns the shard index reserved for a
    /// new session, or the typed rejection. The caller must
    /// `shared.release(shard)` if the session open then fails.
    fn admit(&self, cfg: &SessionConfig) -> Result<usize, AdmissionError> {
        let n = self.services.len();
        let cap = self.cfg.admission.max_sessions_per_shard;
        let mut p = self.shared.place.lock().expect("fleet placement lock");
        if p.draining {
            self.shared.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(AdmissionError::Draining);
        }
        if p.open_total >= self.cfg.admission.max_sessions {
            self.shared.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(AdmissionError::FleetFull {
                limit: self.cfg.admission.max_sessions,
            });
        }
        // the policy's first choice, before capacity filtering —
        // reported in ShardFull so the rejection names a real shard
        let preferred = match self.cfg.policy {
            ShardPolicy::RoundRobin => p.rr % n,
            ShardPolicy::LeastLoaded => least_loaded(&p.open, cap).unwrap_or(0),
            ShardPolicy::StickyByClass => sticky_home(cfg, n),
        };
        let picked = match self.cfg.policy {
            ShardPolicy::RoundRobin => {
                // probe from the cursor, skipping full shards
                (0..n).map(|k| (p.rr + k) % n).find(|&s| p.open[s] < cap)
            }
            ShardPolicy::LeastLoaded => least_loaded(&p.open, cap),
            ShardPolicy::StickyByClass => {
                let home = sticky_home(cfg, n);
                if cfg.coalesce && p.open[home] < cap {
                    Some(home)
                } else {
                    // opted-out sessions gain nothing from
                    // co-location; full homes spill rather than reject
                    least_loaded(&p.open, cap)
                }
            }
        };
        let Some(shard) = picked else {
            self.shared.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(AdmissionError::ShardFull { shard: preferred, limit: cap });
        };
        if self.cfg.policy == ShardPolicy::RoundRobin {
            p.rr = (shard + 1) % n;
        }
        p.open_total += 1;
        p.open[shard] += 1;
        Ok(shard)
    }

    /// wrap a freshly opened session: wire the latency sink and the
    /// meter bookkeeping
    fn wrap(&self, shard: usize, open: Result<StreamSession>) -> Result<FleetSession> {
        match open {
            Ok(mut inner) => {
                inner.attach_latency_sink(Arc::clone(&self.shared.meters[shard].hist));
                self.shared.opened.fetch_add(1, Ordering::Relaxed);
                Ok(FleetSession {
                    inner: Some(inner),
                    shard,
                    shared: Arc::clone(&self.shared),
                    last_busy_ns: 0,
                    last_in_flight: 0,
                })
            }
            Err(e) => {
                self.shared.release(shard);
                Err(e)
            }
        }
    }

    /// Open a manifest-backed session (see
    /// [`DpdService::open_session`]) on the shard the policy picks.
    /// Admission rejections carry a typed [`AdmissionError`].
    pub fn open_session(&self, cfg: SessionConfig) -> Result<FleetSession> {
        let shard = self.admit(&cfg).map_err(anyhow::Error::new)?;
        self.wrap(shard, self.services[shard].open_session(cfg))
    }

    /// Open a session around a caller-supplied engine constructor (see
    /// [`DpdService::open_session_with`]) — the hermetic path: no
    /// artifact tree needed. Note [`ShardPolicy::StickyByClass`] keys
    /// on `cfg.engine`, so set it to the kind the builder actually
    /// constructs if sticky placement should co-locate it correctly.
    pub fn open_session_with<F>(&self, cfg: SessionConfig, build: F) -> Result<FleetSession>
    where
        F: FnOnce() -> Result<Box<dyn DpdEngine>> + Send + 'static,
    {
        let shard = self.admit(&cfg).map_err(anyhow::Error::new)?;
        self.wrap(shard, self.services[shard].open_session_with(cfg, build))
    }

    /// Open a closed-loop adaptive session from an explicit float twin
    /// (see [`DpdService::open_adaptive_session`]).
    pub fn open_adaptive_session(
        &self,
        cfg: SessionConfig,
        w0: GruWeights,
    ) -> Result<FleetSession> {
        let shard = self.admit(&cfg).map_err(anyhow::Error::new)?;
        self.wrap(shard, self.services[shard].open_adaptive_session(cfg, w0))
    }

    /// Live fleet snapshot: admission counters, per-shard meters, and
    /// per-shard + merged latency histograms.
    pub fn stats(&self) -> FleetStats {
        let (open, draining) = {
            let p = self.shared.place.lock().expect("fleet placement lock");
            (p.open.clone(), p.draining)
        };
        let wall = self.shared.t_start.elapsed();
        let capacity_ns = (wall.as_nanos() as f64) * self.shared.workers_per_shard as f64;
        let mut merged = LatencyHistogram::new();
        let shards: Vec<ShardStats> = self
            .shared
            .meters
            .iter()
            .zip(&open)
            .map(|(m, &sessions_open)| {
                let latency = m.hist.snapshot();
                merged.merge(&latency);
                ShardStats {
                    sessions_open,
                    queue_depth: m.queue.load(Ordering::Relaxed),
                    busy_ratio: if capacity_ns > 0.0 {
                        m.busy_ns.load(Ordering::Relaxed) as f64 / capacity_ns
                    } else {
                        0.0
                    },
                    latency,
                }
            })
            .collect();
        FleetStats {
            sessions_open: open.iter().sum(),
            sessions_opened: self.shared.opened.load(Ordering::Relaxed),
            sessions_rejected: self.shared.rejected.load(Ordering::Relaxed),
            sessions_drained: self.shared.drained.load(Ordering::Relaxed),
            draining,
            shards,
            latency: merged,
        }
    }

    /// Graceful drain: stop admitting (new opens get
    /// [`AdmissionError::Draining`]), wait until every admitted
    /// session has been finished or dropped by its owner, then shut
    /// every shard down in order (each shard joins its adapt worker
    /// first, then its engine workers — see [`DpdService::shutdown`]).
    /// Returns the final stats snapshot.
    ///
    /// Blocks until the callers holding sessions release them — do not
    /// call it from a thread that still owns a `FleetSession`. In-
    /// flight frames are never lost: each session's own
    /// `finish`/`drop` flushes its stream before drain can observe the
    /// open count reach zero.
    ///
    /// With [`FleetConfig::drain_deadline`] set, a leaked handle no
    /// longer hangs the drain forever: once the deadline expires the
    /// call returns a typed [`DrainTimeout`] carrying the stuck-session
    /// count, and the shard services are dropped without joining
    /// (joining would wait on the leaked handle's worker channel —
    /// exactly the hang the deadline breaks).
    pub fn drain(self) -> Result<FleetStats> {
        self.shared.place.lock().expect("fleet placement lock").draining = true;
        let t0 = Instant::now();
        loop {
            let open = self.shared.place.lock().expect("fleet placement lock").open_total;
            if open == 0 {
                break;
            }
            if let Some(deadline) = self.cfg.drain_deadline {
                if t0.elapsed() >= deadline {
                    drop(self.services);
                    return Err(DrainTimeout { stuck_sessions: open, deadline }.into());
                }
            }
            std::thread::sleep(Duration::from_micros(500));
        }
        let stats = self.stats();
        for svc in self.services {
            svc.shutdown()?;
        }
        Ok(stats)
    }
}

/// least-open shard under the cap (`None` when every shard is full)
fn least_loaded(open: &[usize], cap: usize) -> Option<usize> {
    open.iter()
        .enumerate()
        .filter(|(_, &o)| o < cap)
        .min_by_key(|(_, &o)| o)
        .map(|(s, _)| s)
}

/// sticky home shard: hash of the session's engine spec. Sessions on
/// the same spec against the same (shared) manifest have identical
/// weights, hence the same coalescing batch class — spec equality is
/// the fleet-level proxy for class equality.
fn sticky_home(cfg: &SessionConfig, n: usize) -> usize {
    (fnv1a_words(&cfg.engine.to_string(), std::iter::empty()) % n as u64) as usize
}

/// A session opened through a [`Fleet`]: a [`StreamSession`] pinned to
/// one shard, plus the meter bookkeeping that feeds [`FleetStats`].
/// The streaming API delegates 1:1 — outputs are bit-identical to the
/// underlying session's.
pub struct FleetSession {
    /// `None` only after `finish` consumed the inner session
    inner: Option<StreamSession>,
    shard: usize,
    shared: Arc<Shared>,
    /// last values pushed into the shard meter (delta accounting, so
    /// concurrent sessions can share the same atomics)
    last_busy_ns: u64,
    last_in_flight: u64,
}

impl FleetSession {
    fn inner(&mut self) -> &mut StreamSession {
        self.inner.as_mut().expect("fleet session already finished")
    }

    /// fold this session's latest busy/in-flight numbers into its
    /// shard meter (monotone deltas, lock-free)
    fn sync_meter(&mut self) {
        let st = self.inner.as_ref().expect("fleet session already finished").stats();
        self.apply_meter(st.dpd_busy, st.in_flight);
    }

    fn apply_meter(&mut self, busy: Duration, in_flight: u64) {
        let busy_ns = busy.as_nanos().min(u64::MAX as u128) as u64;
        let m = &self.shared.meters[self.shard];
        m.busy_ns.fetch_add(busy_ns.saturating_sub(self.last_busy_ns), Ordering::Relaxed);
        if in_flight >= self.last_in_flight {
            m.queue.fetch_add(in_flight - self.last_in_flight, Ordering::Relaxed);
        } else {
            m.queue.fetch_sub(self.last_in_flight - in_flight, Ordering::Relaxed);
        }
        self.last_busy_ns = busy_ns;
        self.last_in_flight = in_flight;
    }

    /// final meter update + placement release for a closing session
    fn close_meter(&mut self, busy: Duration) {
        self.apply_meter(busy, 0);
        self.shared.release(self.shard);
        self.shared.drained.fetch_add(1, Ordering::Relaxed);
    }

    /// The shard this session landed on.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Session id (unique within its shard's service).
    pub fn id(&self) -> u64 {
        self.inner.as_ref().expect("fleet session already finished").id()
    }

    /// Label of the worker-built engine (e.g. `"qgru-hard"`).
    pub fn engine(&self) -> &'static str {
        self.inner.as_ref().expect("fleet session already finished").engine()
    }

    /// The frame length this session cuts the stream into.
    pub fn frame_len(&self) -> usize {
        self.inner.as_ref().expect("fleet session already finished").frame_len()
    }

    /// Whether this session runs the closed adaptation loop.
    pub fn is_adaptive(&self) -> bool {
        self.inner.as_ref().expect("fleet session already finished").is_adaptive()
    }

    /// See [`StreamSession::push`]. Every completed frame also stamps
    /// the shard's latency histogram.
    pub fn push(&mut self, samples: &[[f64; 2]]) -> Result<()> {
        let r = self.inner().push(samples);
        self.sync_meter();
        r
    }

    /// See [`StreamSession::drain`].
    pub fn drain(&mut self) -> Result<Vec<[f64; 2]>> {
        let r = self.inner().drain();
        self.sync_meter();
        r
    }

    /// See [`StreamSession::stats`].
    pub fn stats(&self) -> SessionStats {
        self.inner.as_ref().expect("fleet session already finished").stats()
    }

    /// See [`StreamSession::reset`].
    pub fn reset(&mut self) -> Result<()> {
        self.inner().reset()
    }

    /// See [`StreamSession::adapt_feedback`].
    pub fn adapt_feedback(
        &mut self,
        x: &[[f64; 2]],
        u: &[[f64; 2]],
        y: &[[f64; 2]],
    ) -> Result<()> {
        self.inner().adapt_feedback(x, u, y)
    }

    /// See [`StreamSession::adapt_barrier`].
    pub fn adapt_barrier(&mut self) -> Result<()> {
        self.inner().adapt_barrier()
    }

    /// See [`StreamSession::deploy_weights`] — the rollout
    /// controller's per-session push seam.
    pub fn deploy_weights(&mut self, w: &GruWeights) -> Result<()> {
        self.inner().deploy_weights(w)
    }

    /// See [`StreamSession::finish`]: flush the tail, wait for every
    /// in-flight frame, close the session, release its admission slot.
    pub fn finish(mut self) -> Result<StreamOutput> {
        let inner = self.inner.take().expect("fleet session already finished");
        let res = inner.finish();
        let busy = match &res {
            Ok(out) => out.stats.dpd_busy,
            // the session is gone either way; keep the meter monotone
            Err(_) => Duration::from_nanos(self.last_busy_ns),
        };
        self.close_meter(busy);
        res
    }
}

impl Drop for FleetSession {
    fn drop(&mut self) {
        if self.inner.is_some() {
            let busy = self.inner.as_ref().expect("just checked").stats().dpd_busy;
            // drop the inner session first (sends Close to its worker)
            self.inner = None;
            self.close_meter(busy);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpd::qgru::{ActKind, QGruDpd};
    use crate::dpd::weights::QGruWeights;
    use crate::fixed::QSpec;
    use crate::runtime::backend::StreamingEngine;
    use crate::util::Rng;

    fn fixed_engine(seed: u64) -> Box<dyn DpdEngine> {
        let qw = QGruWeights::synthetic(seed, QSpec::Q12);
        Box::new(StreamingEngine::new(Box::new(QGruDpd::new(qw, ActKind::Hard))))
    }

    fn small_cfg() -> FleetConfig {
        FleetConfig {
            shards: 3,
            service: ServiceConfig { workers: 1, frame_len: 32, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn config_defaults_are_sane() {
        let cfg = FleetConfig::default();
        assert!(cfg.shards > 0);
        assert_eq!(cfg.policy, ShardPolicy::RoundRobin);
        assert_eq!(cfg.admission.max_sessions, usize::MAX);
        assert_eq!(cfg.admission.max_sessions_per_shard, usize::MAX);
    }

    #[test]
    fn start_validates_config() {
        assert!(Fleet::start(FleetConfig { shards: 0, ..Default::default() }).is_err());
        let zero_cap = AdmissionConfig { max_sessions: 0, ..Default::default() };
        assert!(Fleet::start(FleetConfig { admission: zero_cap, ..small_cfg() }).is_err());
    }

    #[test]
    fn admission_error_display_names_the_limit() {
        let e = AdmissionError::FleetFull { limit: 7 };
        assert!(e.to_string().contains('7'));
        let e = AdmissionError::ShardFull { shard: 2, limit: 3 };
        assert!(e.to_string().contains('2') && e.to_string().contains('3'));
        assert!(AdmissionError::Draining.to_string().contains("draining"));
    }

    #[test]
    fn empty_fleet_starts_and_drains() {
        let fleet = Fleet::start(small_cfg()).unwrap();
        assert_eq!(fleet.shards(), 3);
        let stats = fleet.drain().unwrap();
        assert_eq!(stats.sessions_open, 0);
        assert_eq!(stats.sessions_opened, 0);
        assert!(stats.draining);
        assert!(stats.latency.is_empty());
    }

    #[test]
    fn round_robin_spreads_sessions_across_shards() {
        let fleet = Fleet::start(small_cfg()).unwrap();
        let sessions: Vec<FleetSession> = (0..3)
            .map(|i| {
                fleet
                    .open_session_with(SessionConfig::default(), move || Ok(fixed_engine(i)))
                    .unwrap()
            })
            .collect();
        let mut shards: Vec<usize> = sessions.iter().map(|s| s.shard()).collect();
        shards.sort_unstable();
        assert_eq!(shards, vec![0, 1, 2], "one session per shard");
        let stats = fleet.stats();
        assert_eq!(stats.sessions_open, 3);
        assert!(stats.shards.iter().all(|s| s.sessions_open == 1));
        drop(sessions);
        fleet.drain().unwrap();
    }

    #[test]
    fn sticky_policy_colocates_equal_specs() {
        let fleet = Fleet::start(FleetConfig {
            policy: ShardPolicy::StickyByClass,
            ..small_cfg()
        })
        .unwrap();
        let shards: Vec<usize> = (0..4)
            .map(|_| {
                // same spec (Fixed) and coalescable — must share a home
                let s = fleet
                    .open_session_with(SessionConfig::default(), || Ok(fixed_engine(9)))
                    .unwrap();
                s.shard()
            })
            .collect();
        assert!(shards.windows(2).all(|w| w[0] == w[1]), "sticky home moved: {shards:?}");
        fleet.drain().unwrap();
    }

    #[test]
    fn global_cap_rejects_with_typed_error() {
        let fleet = Fleet::start(FleetConfig {
            admission: AdmissionConfig { max_sessions: 2, ..Default::default() },
            ..small_cfg()
        })
        .unwrap();
        let a = fleet.open_session_with(SessionConfig::default(), || Ok(fixed_engine(1)));
        let b = fleet.open_session_with(SessionConfig::default(), || Ok(fixed_engine(2)));
        assert!(a.is_ok() && b.is_ok());
        let err = fleet
            .open_session_with(SessionConfig::default(), || Ok(fixed_engine(3)))
            .expect_err("third session must be rejected");
        assert_eq!(
            err.downcast_ref::<AdmissionError>(),
            Some(&AdmissionError::FleetFull { limit: 2 })
        );
        // closing one session frees the slot again
        drop(a);
        let c = fleet.open_session_with(SessionConfig::default(), || Ok(fixed_engine(4)));
        assert!(c.is_ok(), "slot must be reusable after a close");
        let stats = fleet.stats();
        assert_eq!(stats.sessions_rejected, 1);
        assert_eq!(stats.sessions_drained, 1);
        drop((b, c));
        fleet.drain().unwrap();
    }

    #[test]
    fn fleet_session_streams_and_stamps_latency() {
        let fleet = Fleet::start(small_cfg()).unwrap();
        let mut s = fleet
            .open_session_with(SessionConfig::default(), || Ok(fixed_engine(5)))
            .unwrap();
        let mut rng = Rng::new(11);
        let iq: Vec<[f64; 2]> =
            (0..256).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect();
        s.push(&iq).unwrap();
        let out = s.finish().unwrap();
        assert_eq!(out.iq.len(), 256);
        let stats = fleet.drain().unwrap();
        assert_eq!(stats.sessions_drained, 1);
        assert!(!stats.latency.is_empty(), "frames must stamp the shard histogram");
        assert_eq!(
            stats.latency.count(),
            stats.shards.iter().map(|s| s.latency.count()).sum::<u64>(),
            "merged histogram must equal the per-shard sum"
        );
        assert!(stats.shards.iter().all(|s| s.queue_depth == 0), "drained ⇒ empty queues");
    }

    #[test]
    fn draining_fleet_rejects_new_sessions() {
        // drain() consumes the fleet, so exercise the draining flag
        // through the admission path directly
        let fleet = Fleet::start(small_cfg()).unwrap();
        fleet.shared.place.lock().unwrap().draining = true;
        let err = fleet
            .open_session_with(SessionConfig::default(), || Ok(fixed_engine(6)))
            .expect_err("draining fleet must reject");
        assert_eq!(err.downcast_ref::<AdmissionError>(), Some(&AdmissionError::Draining));
        fleet.shared.place.lock().unwrap().draining = false;
        fleet.drain().unwrap();
    }
}
