//! Scenario DSL for the cross-engine conformance matrix.
//!
//! The engine zoo (NativeF64, Fixed, DeltaFixed, their `+simd`
//! kernel-backed forms, CycleSim, Interp, Hlo) stays honest only if
//! every engine is driven through the same gauntlet of operating
//! conditions and compared under its documented contract. This module is the shared harness: a [`Scenario`] is a
//! script of bursts, mid-stream resets and save/load round-trips over
//! generated stimuli (OFDM, tone pairs, silence/DC, full-scale
//! saturation); [`run_scalar`] plays it through one engine's
//! `process_frame` path, [`run_batched`] plays it through `run_batch`
//! with ragged per-lane tails, and [`lane_scenario`] derives the
//! per-lane reference script so the two can be compared lane for
//! lane. `tests/conformance.rs` instantiates the full matrix:
//! bit-exactness inside the integer family (Fixed ≡ DeltaFixed@θ=0 ≡
//! CycleSim ≡ the AVX2-kernel engines ≡ the forced scalar fallback),
//! scalar ≡ batched for every engine, envelope tolerances for the
//! float reference, and bounded ACPR/EVM drift for θ>0 — where the
//! θ>0 engines must additionally be kernel-invariant (identical bits
//! whichever `GateKernel` ran). The harness itself never names a
//! kernel: the choice is baked into the engine a maker constructs, so
//! adding a kernel means adding maker rows, not new DSL.
//!
//! The harness lives in `util` so unit suites can reuse it, but it is
//! engine-agnostic on purpose: everything it knows about an engine is
//! the [`DpdEngine`] trait.

use anyhow::{ensure, Result};

use crate::runtime::{DpdEngine, DpdLane, DpdState};
use crate::signal::ofdm::{OfdmConfig, OfdmModulator};
use crate::util::Rng;

/// A stimulus generator — each variant renders a deterministic burst.
#[derive(Clone, Debug)]
pub enum Stimulus {
    /// CP-OFDM 64-QAM burst at the project's nominal RMS 0.25
    Ofdm { symbols: usize, seed: u64 },
    /// two complex tones at normalized frequencies f1, f2
    TonePair { f1: f64, f2: f64, amp: f64, n: usize },
    /// all-zero samples (the deepest delta-skip path)
    Silence { n: usize },
    /// a constant I/Q level (DC — nonzero but changeless)
    Dc { i: f64, q: f64, n: usize },
    /// uniform samples spanning the whole representable range, so the
    /// quantizer and datapath saturate hard
    FullScale { seed: u64, n: usize },
    /// small-signal gaussian noise at a given RMS
    Gauss { seed: u64, n: usize, rms: f64 },
    /// envelope drift: gaussian whose RMS ramps linearly `rms0 ->
    /// rms1` across the burst — the non-stationary drive of the
    /// closed-loop adaptation scenarios (a drifting PA's feedback
    /// statistics move exactly like this, so engines must stay
    /// contract-clean under a moving envelope)
    Drift { seed: u64, n: usize, rms0: f64, rms1: f64 },
}

impl Stimulus {
    /// Render the burst (deterministic in the variant's parameters).
    pub fn render(&self) -> Vec<[f64; 2]> {
        match *self {
            Stimulus::Ofdm { symbols, seed } => {
                OfdmModulator::generate(&OfdmConfig {
                    n_symbols: symbols,
                    seed,
                    ..Default::default()
                })
                .expect("default OFDM config is valid")
                .iq
            }
            Stimulus::TonePair { f1, f2, amp, n } => (0..n)
                .map(|t| {
                    let (p1, p2) = (
                        2.0 * std::f64::consts::PI * f1 * t as f64,
                        2.0 * std::f64::consts::PI * f2 * t as f64,
                    );
                    [amp * (p1.cos() + p2.cos()), amp * (p1.sin() + p2.sin())]
                })
                .collect(),
            Stimulus::Silence { n } => vec![[0.0, 0.0]; n],
            Stimulus::Dc { i, q, n } => vec![[i, q]; n],
            Stimulus::FullScale { seed, n } => {
                let mut rng = Rng::new(seed);
                (0..n).map(|_| [rng.range(-1.999, 1.999), rng.range(-1.999, 1.999)]).collect()
            }
            Stimulus::Gauss { seed, n, rms } => {
                let mut rng = Rng::new(seed);
                (0..n).map(|_| [rng.gauss() * rms, rng.gauss() * rms]).collect()
            }
            Stimulus::Drift { seed, n, rms0, rms1 } => {
                let mut rng = Rng::new(seed);
                let span = (n.max(2) - 1) as f64;
                (0..n)
                    .map(|t| {
                        let rms = rms0 + (rms1 - rms0) * t as f64 / span;
                        [rng.gauss() * rms, rng.gauss() * rms]
                    })
                    .collect()
            }
        }
    }
}

/// One step of a scenario script.
#[derive(Clone, Debug)]
pub enum Step {
    /// process a burst (output collected)
    Burst(Vec<[f64; 2]>),
    /// mid-stream engine reset
    Reset,
    /// snapshot the state, process the burst, restore, process again:
    /// both futures must match exactly (the restored run's output is
    /// collected)
    SaveLoadReplay(Vec<[f64; 2]>),
}

/// A named script of steps, played identically against every engine.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub steps: Vec<Step>,
}

impl Scenario {
    pub fn new(name: &str, steps: Vec<Step>) -> Scenario {
        Scenario { name: name.to_string(), steps }
    }

    /// Total samples a scalar run of this scenario emits.
    pub fn len(&self) -> usize {
        self.steps
            .iter()
            .map(|s| match s {
                Step::Burst(b) | Step::SaveLoadReplay(b) => b.len(),
                Step::Reset => 0,
            })
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// How many samples lane `k` drops from the tail of every burst —
/// ragged lanes are part of the batched contract, so the grid bakes
/// them in rather than treating raggedness as a special case.
fn ragged_cut(lane: usize) -> usize {
    lane * 3
}

/// The per-lane variant of a scenario: lane k's bursts lose
/// `ragged_cut(k)` tail samples. Lane 0 is the scenario itself.
pub fn lane_scenario(s: &Scenario, lane: usize) -> Scenario {
    let cut = ragged_cut(lane);
    let trim = |b: &Vec<[f64; 2]>| -> Vec<[f64; 2]> { b[..b.len().saturating_sub(cut)].to_vec() };
    Scenario {
        name: format!("{}[lane {lane}]", s.name),
        steps: s
            .steps
            .iter()
            .map(|st| match st {
                Step::Burst(b) => Step::Burst(trim(b)),
                Step::SaveLoadReplay(b) => Step::SaveLoadReplay(trim(b)),
                Step::Reset => Step::Reset,
            })
            .collect(),
    }
}

/// Play a scenario through one engine's scalar (`process_frame`) path.
/// Returns the concatenated output samples.
pub fn run_scalar(engine: &mut dyn DpdEngine, s: &Scenario) -> Result<Vec<[f64; 2]>> {
    engine.reset();
    let mut out = Vec::with_capacity(s.len());
    for step in &s.steps {
        match step {
            Step::Burst(b) => {
                let mut buf = b.clone();
                engine.process_frame(&mut buf)?;
                out.extend_from_slice(&buf);
            }
            Step::Reset => engine.reset(),
            Step::SaveLoadReplay(b) => {
                let snap = engine.save_state();
                let mut first = b.clone();
                engine.process_frame(&mut first)?;
                engine.load_state(&snap)?;
                let mut again = b.clone();
                engine.process_frame(&mut again)?;
                ensure!(
                    first == again,
                    "{}: scenario '{}': save/load round-trip diverged",
                    engine.name(),
                    s.name
                );
                out.extend_from_slice(&again);
            }
        }
    }
    Ok(out)
}

/// Play a scenario through one engine's batched (`run_batch`) path,
/// with `lanes` independent streams whose bursts have ragged tails
/// (lane k follows [`lane_scenario`]`(s, k)`). Every lane's state is
/// carried in its own [`DpdState`] snapshot, exactly like the
/// coalescing scheduler does. Returns per-lane concatenated outputs.
pub fn run_batched(
    engine: &mut dyn DpdEngine,
    s: &Scenario,
    lanes: usize,
) -> Result<Vec<Vec<[f64; 2]>>> {
    ensure!(lanes > 0, "need at least one lane");
    engine.reset();
    let name = engine.name();
    let fresh = engine.save_state();
    let mut states: Vec<DpdState> = vec![fresh.clone(); lanes];
    let mut out: Vec<Vec<[f64; 2]>> = vec![Vec::new(); lanes];

    let mut run_step = |states: &mut Vec<DpdState>,
                        bufs: &mut Vec<Vec<[f64; 2]>>|
     -> Result<()> {
        let mut lane_views: Vec<DpdLane> = bufs
            .iter_mut()
            .zip(states.iter_mut())
            .map(|(b, st)| DpdLane { iq: b.as_mut_slice(), state: st })
            .collect();
        engine.run_batch(&mut lane_views)
    };

    for step in &s.steps {
        match step {
            Step::Burst(b) => {
                let mut bufs: Vec<Vec<[f64; 2]>> = (0..lanes)
                    .map(|k| b[..b.len().saturating_sub(ragged_cut(k))].to_vec())
                    .collect();
                run_step(&mut states, &mut bufs)?;
                for (o, buf) in out.iter_mut().zip(bufs) {
                    o.extend(buf);
                }
            }
            Step::Reset => {
                for st in states.iter_mut() {
                    *st = fresh.clone();
                }
            }
            Step::SaveLoadReplay(b) => {
                let make_bufs = || -> Vec<Vec<[f64; 2]>> {
                    (0..lanes)
                        .map(|k| b[..b.len().saturating_sub(ragged_cut(k))].to_vec())
                        .collect()
                };
                let snap = states.clone();
                let mut first = make_bufs();
                run_step(&mut states, &mut first)?;
                states = snap;
                let mut again = make_bufs();
                run_step(&mut states, &mut again)?;
                ensure!(
                    first == again,
                    "{name}: scenario '{}': batched save/load round-trip diverged",
                    s.name
                );
                for (o, buf) in out.iter_mut().zip(again) {
                    o.extend(buf);
                }
            }
        }
    }
    Ok(out)
}

/// The standard conformance grid: every operating condition the
/// matrix must hold across — OFDM bursts, tone pairs, silence/DC,
/// full-scale saturation, mid-stream resets, save/load round-trips.
/// Ragged batch tails come from [`run_batched`] itself. `seed` varies
/// the stimuli without changing the scenario structure.
pub fn standard_grid(seed: u64) -> Vec<Scenario> {
    let gauss = |s: u64, n: usize| Stimulus::Gauss { seed: seed ^ s, n, rms: 0.2 }.render();
    vec![
        Scenario::new(
            "ofdm-burst",
            vec![Step::Burst(Stimulus::Ofdm { symbols: 4, seed }.render())],
        ),
        Scenario::new(
            "tone-pair",
            vec![Step::Burst(
                Stimulus::TonePair { f1: 0.01171875, f2: 0.0234375, amp: 0.25, n: 512 }.render(),
            )],
        ),
        Scenario::new(
            "silence-dc-silence",
            vec![
                Step::Burst(Stimulus::Silence { n: 64 }.render()),
                Step::Burst(Stimulus::Dc { i: 0.45, q: -0.3, n: 128 }.render()),
                Step::Burst(Stimulus::Silence { n: 64 }.render()),
            ],
        ),
        Scenario::new(
            "full-scale-saturation",
            vec![Step::Burst(Stimulus::FullScale { seed: seed ^ 0xf5, n: 256 }.render())],
        ),
        Scenario::new(
            "midstream-reset",
            vec![
                Step::Burst(gauss(1, 200)),
                Step::Reset,
                Step::Burst(gauss(2, 200)),
                Step::Reset,
                Step::Burst(gauss(3, 77)),
            ],
        ),
        Scenario::new(
            "save-load-roundtrip",
            vec![
                Step::Burst(gauss(4, 150)),
                Step::SaveLoadReplay(gauss(5, 100)),
                Step::Burst(gauss(6, 150)),
            ],
        ),
        Scenario::new(
            // the closed-loop runtime's shape replayed as a scenario:
            // a drifting envelope streams in, the engine is refreshed
            // at a frame boundary (hot-swapped engines start from
            // reset state — Reset is exactly the swap's semantics),
            // the drift trajectory continues on the fresh engine, and
            // a save/load round-trip must still replay exactly under
            // a moving envelope
            "adapt-replay",
            vec![
                Step::Burst(
                    Stimulus::Drift { seed: seed ^ 0xad, n: 300, rms0: 0.15, rms1: 0.45 }.render(),
                ),
                Step::Reset,
                Step::Burst(
                    Stimulus::Drift { seed: seed ^ 0xae, n: 300, rms0: 0.45, rms1: 0.2 }.render(),
                ),
                Step::SaveLoadReplay(
                    Stimulus::Drift { seed: seed ^ 0xaf, n: 120, rms0: 0.2, rms1: 0.6 }.render(),
                ),
            ],
        ),
        Scenario::new(
            "mixed-gauntlet",
            vec![
                Step::Burst(Stimulus::Ofdm { symbols: 1, seed: seed ^ 9 }.render()),
                Step::Burst(Stimulus::Silence { n: 40 }.render()),
                Step::SaveLoadReplay(gauss(7, 60)),
                Step::Burst(Stimulus::FullScale { seed: seed ^ 10, n: 90 }.render()),
                Step::Reset,
                Step::Burst(Stimulus::Dc { i: -0.2, q: 0.55, n: 70 }.render()),
                Step::Burst(gauss(8, 130)),
            ],
        ),
    ]
}

/// Largest per-component deviation between two sample streams
/// (panics on length mismatch — that is already a conformance bug).
pub fn max_abs_dev(a: &[[f64; 2]], b: &[[f64; 2]]) -> f64 {
    assert_eq!(a.len(), b.len(), "stream lengths diverged");
    a.iter()
        .zip(b)
        .map(|(u, v)| (u[0] - v[0]).abs().max((u[1] - v[1]).abs()))
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stimuli_render_deterministically() {
        for s in [
            Stimulus::Ofdm { symbols: 1, seed: 3 },
            Stimulus::TonePair { f1: 0.01, f2: 0.03, amp: 0.3, n: 64 },
            Stimulus::Silence { n: 10 },
            Stimulus::Dc { i: 0.1, q: 0.2, n: 10 },
            Stimulus::FullScale { seed: 5, n: 32 },
            Stimulus::Gauss { seed: 7, n: 32, rms: 0.25 },
            Stimulus::Drift { seed: 9, n: 32, rms0: 0.1, rms1: 0.5 },
        ] {
            let a = s.render();
            let b = s.render();
            assert_eq!(a, b, "{s:?} not deterministic");
            assert!(!a.is_empty());
        }
    }

    #[test]
    fn full_scale_actually_saturates() {
        let b = Stimulus::FullScale { seed: 1, n: 512 }.render();
        assert!(b.iter().any(|s| s[0].abs() > 1.8 || s[1].abs() > 1.8));
    }

    #[test]
    fn grid_covers_the_contracted_conditions() {
        let grid = standard_grid(42);
        let names: Vec<&str> = grid.iter().map(|s| s.name.as_str()).collect();
        for want in [
            "ofdm-burst",
            "tone-pair",
            "silence-dc-silence",
            "full-scale-saturation",
            "midstream-reset",
            "save-load-roundtrip",
            "adapt-replay",
            "mixed-gauntlet",
        ] {
            assert!(names.contains(&want), "grid lost scenario '{want}'");
        }
        assert!(grid.iter().any(|s| s.steps.iter().any(|st| matches!(st, Step::Reset))));
        assert!(grid
            .iter()
            .any(|s| s.steps.iter().any(|st| matches!(st, Step::SaveLoadReplay(_)))));
        for s in &grid {
            assert!(!s.is_empty(), "scenario '{}' emits nothing", s.name);
        }
    }

    #[test]
    fn drift_stimulus_envelope_actually_ramps() {
        let b = Stimulus::Drift { seed: 3, n: 4000, rms0: 0.05, rms1: 0.5 }.render();
        let power = |s: &[[f64; 2]]| -> f64 {
            s.iter().map(|v| v[0] * v[0] + v[1] * v[1]).sum::<f64>() / s.len() as f64
        };
        let head = power(&b[..1000]);
        let tail = power(&b[3000..]);
        assert!(tail > 10.0 * head, "envelope did not ramp: head {head:.4} tail {tail:.4}");
    }

    #[test]
    fn lane_scenarios_are_ragged() {
        let s = Scenario::new("t", vec![Step::Burst(vec![[0.0, 0.0]; 20])]);
        assert_eq!(lane_scenario(&s, 0).len(), 20);
        assert_eq!(lane_scenario(&s, 1).len(), 17);
        assert_eq!(lane_scenario(&s, 4).len(), 8);
    }

    #[test]
    fn harness_against_a_real_engine() {
        // scalar vs batched on the bit-exact fixed engine: the harness
        // itself must not perturb the stream
        use crate::dpd::qgru::{ActKind, QGruDpd};
        use crate::dpd::weights::QGruWeights;
        use crate::fixed::QSpec;
        use crate::runtime::backend::StreamingEngine;
        let mk = || {
            StreamingEngine::new(Box::new(QGruDpd::new(
                QGruWeights::synthetic(3, QSpec::Q12),
                ActKind::Hard,
            )))
        };
        for sc in standard_grid(7) {
            let mut scalar_refs = Vec::new();
            for k in 0..3 {
                let mut e = mk();
                scalar_refs.push(run_scalar(&mut e, &lane_scenario(&sc, k)).unwrap());
            }
            let mut batched = mk();
            let lanes = run_batched(&mut batched, &sc, 3).unwrap();
            assert_eq!(lanes, scalar_refs, "scenario '{}' diverged", sc.name);
        }
    }
}
