//! The headline-spec calculator: ties the FSM timing, op counts, power
//! and area models into the numbers Fig. 5 and Tables II/III report.

use super::act_unit::ActImpl;
use super::area::{AreaBreakdown, AreaModel};
use super::engine::CycleAccurateEngine;
use super::fsm::{self, HwConfig};
use super::ops::{self, ModelDims};
use super::power::{EnergyModel, PowerBreakdown};
use crate::dpd::qgru::{ActKind, LutTables};
use crate::dpd::weights::QGruWeights;
use crate::util::Rng;

/// The full operating-point specification (one Fig. 5 panel).
#[derive(Clone, Debug)]
pub struct AsicSpec {
    pub f_clk_ghz: f64,
    pub v: f64,
    pub fs_msps: f64,
    pub ops_per_sample: usize,
    pub latency_ns: f64,
    pub throughput_gops: f64,
    pub power: PowerBreakdown,
    pub area: AreaBreakdown,
}

impl AsicSpec {
    /// Compute the spec at the nominal point (2 GHz, 0.9 V, 250 MSps)
    /// by actually running the cycle-accurate engine on a
    /// representative stimulus (activity-annotated, like the paper's
    /// switching-activity post-layout flow).
    pub fn nominal(w: &QGruWeights, hard_act: bool) -> AsicSpec {
        AsicSpec::at_operating_point(w, hard_act, 2.0, 0.9)
    }

    /// Spec at an arbitrary (f_clk, V) point; fs tracks f_clk / II.
    pub fn at_operating_point(w: &QGruWeights, hard_act: bool, f_clk_ghz: f64, v: f64) -> AsicSpec {
        let cfg = HwConfig { f_clk_ghz, ..HwConfig::default() };
        let spec = w.spec;
        let act_impl = if hard_act {
            ActImpl::Hard
        } else {
            ActImpl::Lut(LutTables::default_for(spec))
        };
        let act_kind = if hard_act {
            ActKind::Hard
        } else {
            ActKind::Lut(LutTables::default_for(spec))
        };

        // representative stimulus: amplitude-realistic random codes
        let mut sim = CycleAccurateEngine::new(w, act_impl, cfg);
        let mut rng = Rng::new(0xD19);
        let amp = (0.6 * spec.scale()) as i64;
        let stim: Vec<[i32; 2]> = (0..2048)
            .map(|_| [rng.int_in(-amp, amp) as i32, rng.int_in(-amp, amp) as i32])
            .collect();
        sim.run_codes(&stim).expect("sim run");

        let dims = ModelDims { features: w.features, hidden: w.hidden };
        let fs_msps = fsm::max_sample_rate_msps(f_clk_ghz);
        let energy = EnergyModel::default();
        let power = energy.power(sim.stats(), &act_kind, fs_msps, f_clk_ghz, v);
        let area = AreaModel::default().area(&cfg, 502, w.hidden, &act_kind);

        AsicSpec {
            f_clk_ghz,
            v,
            fs_msps,
            ops_per_sample: ops::ops_per_sample(dims).total(),
            latency_ns: fsm::latency_ns(f_clk_ghz),
            throughput_gops: ops::gops(dims, fs_msps),
            power,
            area,
        }
    }

    /// GOPS/W.
    pub fn power_efficiency_gops_w(&self) -> f64 {
        self.throughput_gops / (self.power.total_mw() * 1e-3)
    }

    /// GOPS/mm².
    pub fn area_efficiency_gops_mm2(&self) -> f64 {
        self.throughput_gops / self.area.total_mm2()
    }

    /// TOPS/W/mm² — the paper's headline PAE metric.
    pub fn pae_tops_w_mm2(&self) -> f64 {
        self.power_efficiency_gops_w() * 1e-3 / self.area.total_mm2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::QSpec;

    fn weights() -> QGruWeights {
        // same stream as the old inline generator (seed 11, |w| <= 0.3)
        QGruWeights::synthetic(11, QSpec::Q12)
    }

    #[test]
    fn fig5_headline_numbers() {
        let s = AsicSpec::nominal(&weights(), true);
        // paper: 250 MSps, 7.5 ns, 256.5 GOPS, 195 mW, 0.2 mm²,
        // 1315 GOPS/W, 6.58 TOPS/W/mm²
        assert!((s.fs_msps - 250.0).abs() < 1e-9);
        assert!((s.latency_ns - 7.5).abs() < 1e-12);
        assert!((s.throughput_gops - 256.5).abs() / 256.5 < 0.04);
        assert!((s.power.total_mw() - 195.0).abs() / 195.0 < 0.10, "power {}", s.power.total_mw());
        assert!((s.area.total_mm2() - 0.2).abs() / 0.2 < 0.10, "area {}", s.area.total_mm2());
        let pe = s.power_efficiency_gops_w();
        assert!((pe - 1315.4).abs() / 1315.4 < 0.15, "GOPS/W {pe}");
        let pae = s.pae_tops_w_mm2();
        assert!((pae - 6.58).abs() / 6.58 < 0.25, "PAE {pae}");
    }

    #[test]
    fn voltage_scaling_improves_efficiency() {
        let hi = AsicSpec::at_operating_point(&weights(), true, 2.0, 0.9);
        let lo = AsicSpec::at_operating_point(&weights(), true, 1.0, 0.65);
        assert!(lo.power_efficiency_gops_w() > hi.power_efficiency_gops_w());
        assert!(lo.throughput_gops < hi.throughput_gops);
    }

    #[test]
    fn lut_activation_worse_pae() {
        let hard = AsicSpec::nominal(&weights(), true);
        let lut = AsicSpec::nominal(&weights(), false);
        assert!(lut.pae_tops_w_mm2() < hard.pae_tops_w_mm2());
    }
}
