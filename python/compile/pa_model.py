"""Differentiable power-amplifier behavioral model (the training plant).

The paper drives a GaN Doherty PA at 40 dBm average output. We have no
lab bench, so the plant is a **Rapp-static + memory** behavioral model,
the standard surrogate for solid-state GaN stages:

* static AM/AM: modified Rapp saturation
      G(A) = g1 / (1 + (A^2/asat^2)^p)^(1/(2p))        (monotone)
* static AM/PM: phase rotation phi(A) = apm*A^2 / (1 + bpm*A^2)
* memory: complex FIR taps on the static output plus one cubic
  (|s|^2 s) memory tap — the short-term electro-thermal memory that
  produces spectral-regrowth asymmetry.

Monotonicity of A*G(A) guarantees the PA is invertible at the nominal
drive, which a physical Doherty below hard saturation is; an earlier
pure-polynomial candidate was rejected exactly because its 7th-order
term made the AM/AM non-monotone at the signal peaks (see DESIGN.md).

Calibration at the nominal OFDM drive (rms 0.25, ~9.5 dB PAPR):
~1.9 dB compression at the signal peak, ~7 deg AM/PM swing, uncorrected
ACPR ~= -32 dBc — the regime the paper's measurements start from. An
ideal high-order GMP pre-inverse reaches ~= -48 dBc ACPR / -43 dB EVM
through this plant with outputs clipped to the Q2.10 range, bounding
what any 502-parameter DPD can achieve (paper: -45.3 / -39.8).

The same parameters are serialized to ``artifacts/pa_model.json`` and
loaded by ``rust/src/pa``, so the python training plant and the rust
evaluation plant are the *same* amplifier. Arithmetic is plain real
I/Q; the rust port is line-for-line.

DPD training targets the backed-off gain ``g_target = g1 *
target_backoff`` (default 0.95): the predistorter needs a little
headroom below the saturated output ceiling to reach its linear target
at the signal peaks.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = ["PASpec", "ganlike_spec", "apply_pa", "apply_pa_np", "linear_gain", "target_gain", "save_spec", "load_spec"]


@dataclass(frozen=True)
class PASpec:
    """Rapp-static + memory PA model parameters."""

    g1: Tuple[float, float] = (0.995, 0.087)  # complex small-signal gain
    asat: float = 0.82                         # saturation envelope
    p: float = 1.1                             # Rapp knee smoothness
    apm: float = 0.9                           # AM/PM numerator coeff
    bpm: float = 1.6                           # AM/PM denominator coeff
    # complex linear memory taps at delays 1..len
    mem_linear: Tuple[Tuple[float, float], ...] = (
        (0.08, -0.045),
        (-0.032, 0.018),
        (0.011, -0.006),
    )
    # complex cubic-memory taps (|s|^2 s) at delays 1..len
    mem_cubic: Tuple[Tuple[float, float], ...] = ((-0.055, 0.035),)
    target_backoff: float = 0.95
    label: str = "ganlike-doherty-rapp-mem"


def ganlike_spec() -> PASpec:
    """The calibrated GaN-Doherty-like default (see module docstring)."""
    return PASpec()


def linear_gain(spec: PASpec) -> complex:
    """Small-signal complex gain g1."""
    return complex(spec.g1[0], spec.g1[1])


def target_gain(spec: PASpec) -> complex:
    """The gain a DPD should linearize to (g1 with peak headroom)."""
    return linear_gain(spec) * spec.target_backoff


def _delayed(x: jnp.ndarray, m: int) -> jnp.ndarray:
    """x(n-m) along the time axis (axis=-2 of an (..., T, 2) array)."""
    if m == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[-2] = (m, 0)
    return jnp.pad(x, pad)[..., : x.shape[-2], :]


def _static(x: jnp.ndarray, spec: PASpec) -> jnp.ndarray:
    """Static Rapp AM/AM + AM/PM stage in real I/Q arithmetic."""
    xr, xi = x[..., 0], x[..., 1]
    a2 = xr * xr + xi * xi
    g = (1.0 + (a2 / (spec.asat * spec.asat)) ** spec.p) ** (-1.0 / (2.0 * spec.p))
    phi = spec.apm * a2 / (1.0 + spec.bpm * a2)
    c, s = jnp.cos(phi), jnp.sin(phi)
    gr, gi = spec.g1
    # x * G * e^{j phi} * g1
    yr = g * (xr * c - xi * s)
    yi = g * (xr * s + xi * c)
    zr = gr * yr - gi * yi
    zi = gr * yi + gi * yr
    return jnp.stack([zr, zi], axis=-1)


def apply_pa(x: jnp.ndarray, spec: PASpec) -> jnp.ndarray:
    """Run I/Q through the PA model. ``x``: (..., T, 2) -> same shape.

    Differentiable; used as the plant for direct-learning DPD training.
    """
    s = _static(x, spec)
    y = s
    for m, (br, bi) in enumerate(spec.mem_linear, start=1):
        d = _delayed(s, m)
        dr, di = d[..., 0], d[..., 1]
        y = y + jnp.stack([br * dr - bi * di, br * di + bi * dr], axis=-1)
    for m, (cr, ci) in enumerate(spec.mem_cubic, start=1):
        d = _delayed(s, m)
        dr, di = d[..., 0], d[..., 1]
        e2 = dr * dr + di * di
        y = y + jnp.stack([(cr * dr - ci * di) * e2, (cr * di + ci * dr) * e2], axis=-1)
    return y


def apply_pa_np(x: np.ndarray, spec: PASpec) -> np.ndarray:
    """Numpy twin of ``apply_pa`` (dataset prep, calibration tests)."""
    xc = x[..., 0] + 1j * x[..., 1]
    a2 = np.abs(xc) ** 2
    g = (1.0 + (a2 / spec.asat ** 2) ** spec.p) ** (-1.0 / (2.0 * spec.p))
    phi = spec.apm * a2 / (1.0 + spec.bpm * a2)
    s = xc * g * np.exp(1j * phi) * complex(*spec.g1)
    y = s.copy()
    for m, (br, bi) in enumerate(spec.mem_linear, start=1):
        d = np.roll(s, m, axis=-1)
        d[..., :m] = 0
        y = y + (br + 1j * bi) * d
    for m, (cr, ci) in enumerate(spec.mem_cubic, start=1):
        d = np.roll(s, m, axis=-1)
        d[..., :m] = 0
        y = y + (cr + 1j * ci) * d * np.abs(d) ** 2
    return np.stack([y.real, y.imag], axis=-1)


def save_spec(path: str, spec: PASpec) -> None:
    payload = {
        "label": spec.label,
        "g1": list(spec.g1),
        "asat": spec.asat,
        "p": spec.p,
        "apm": spec.apm,
        "bpm": spec.bpm,
        "mem_linear": [list(t) for t in spec.mem_linear],
        "mem_cubic": [list(t) for t in spec.mem_cubic],
        "target_backoff": spec.target_backoff,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1)


def load_spec(path: str) -> PASpec:
    with open(path) as fh:
        p = json.load(fh)
    return PASpec(
        g1=tuple(p["g1"]),
        asat=float(p["asat"]),
        p=float(p["p"]),
        apm=float(p["apm"]),
        bpm=float(p["bpm"]),
        mem_linear=tuple(tuple(t) for t in p["mem_linear"]),
        mem_cubic=tuple(tuple(t) for t in p["mem_cubic"]),
        target_backoff=float(p["target_backoff"]),
        label=p.get("label", "custom"),
    )
