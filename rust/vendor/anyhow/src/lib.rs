//! Vendored, dependency-free subset of the `anyhow` error API.
//!
//! The offline build must work on a clean checkout with no network and
//! no registry cache, so the workspace pins `anyhow` to this path
//! crate. It implements exactly the surface this repository uses:
//!
//! * [`Error`]: an opaque error carrying a context chain. `{}` prints
//!   the outermost message, `{:#}` the full `a: b: c` chain (matching
//!   upstream anyhow's alternate formatting).
//! * [`Result<T>`] alias with `Error` as the default error type.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros with format-args.
//! * [`Context`]: `.context(..)` / `.with_context(..)` on `Result`
//!   (any error convertible into [`Error`], including `Error` itself)
//!   and on `Option`.
//! * `From<E> for Error` for any `E: std::error::Error + Send + Sync`,
//!   so `?` works on io/parse/etc. errors. Like upstream, `Error`
//!   deliberately does not implement `std::error::Error` — that is
//!   what makes the blanket `From` coherent.
//!
//! Swapping back to crates.io anyhow is a one-line change in
//! `rust/Cargo.toml`; no call site would notice.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: a chain of human-readable messages, outermost
/// context first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages in the chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("non-empty chain")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// The standard-error bridge for `?`. Coherent with `From<T> for T`
// because `Error` itself does not implement `std::error::Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        let mut chain = vec![error.to_string()];
        let mut source = error.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

/// Attach context to errors (`Result`) or turn `None` into an error
/// (`Option`).
pub trait Context<T, E> {
    /// Wrap the error with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                "condition failed: `{}`",
                stringify!($cond)
            ));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e = Error::msg("root").context("mid").context("top");
        assert_eq!(format!("{e}"), "top");
        assert_eq!(format!("{e:#}"), "top: mid: root");
        assert_eq!(e.root_cause(), "root");
        assert_eq!(e.chain().count(), 3);
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            let v: i32 = s.parse()?;
            Ok(v)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading config: file missing");

        let n: Option<u32> = None;
        let e = n.with_context(|| format!("missing {}", "field")).unwrap_err();
        assert_eq!(format!("{e}"), "missing field");

        // context on a Result that already carries an anyhow::Error
        let r: Result<()> = Err(Error::msg("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too large: {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too large: 101");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }

    #[test]
    fn ensure_without_message() {
        fn f(x: i32) -> Result<()> {
            ensure!(x == 0);
            Ok(())
        }
        let e = f(1).unwrap_err();
        assert!(format!("{e}").contains("x == 0"));
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::msg("root").context("top");
        let d = format!("{e:?}");
        assert!(d.contains("top") && d.contains("Caused by") && d.contains("root"));
    }
}
