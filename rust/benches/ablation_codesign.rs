//! Ablation bench for the co-design choices DESIGN.md calls out:
//!
//! 1. **QAT vs post-training quantization (PTQ)** — quantize the fp32
//!    model directly to Q2.f vs the QAT-fine-tuned weights. The paper's
//!    accuracy story (Fig. 3) depends on QAT; PTQ should be visibly
//!    worse at low precision.
//! 2. **Hard vs LUT activations at the hardware level** — power + area
//!    at the nominal point from the models (the Fig. 4/Table I story
//!    translated to the ASIC).
//! 3. **Pipeline queue depth** — coordinator backpressure tuning.
//!
//! Run: `cargo bench --bench ablation_codesign`

use dpd_ne::accel::AsicSpec;
use dpd_ne::coordinator::{Coordinator, CoordinatorConfig, EngineKind};
use dpd_ne::dpd::qgru::{ActKind, LutTables, QGruDpd};
use dpd_ne::dpd::weights::{GruWeights, QGruWeights};
use dpd_ne::dpd::Dpd;
use dpd_ne::fixed::QSpec;
use dpd_ne::metrics::acpr::{acpr_db, AcprConfig};
use dpd_ne::pa::{PaSpec, RappMemPa};
use dpd_ne::report::{f1, f2, f3, Table};
use dpd_ne::runtime::Manifest;
use dpd_ne::signal::ofdm::{OfdmConfig, OfdmModulator};

fn main() -> anyhow::Result<()> {
    let Ok(m) = Manifest::discover(None) else {
        eprintln!("ablation: skipped (run `make artifacts` first)");
        return Ok(());
    };
    let pa = RappMemPa::new(PaSpec::load(&m.pa_model)?);
    let sig = OfdmModulator::generate(&OfdmConfig { n_symbols: 32, seed: 77, ..Default::default() })?;
    let float_w = GruWeights::load(&m.weights_float)?;

    // 1. QAT vs PTQ
    let mut t = Table::new(
        "Ablation 1: QAT vs post-training quantization (ACPR dBc)",
        &["bits", "PTQ (fp32 weights quantized)", "QAT (fine-tuned)"],
    );
    let mut qat_beats_ptq_low_bits = false;
    for bits in [8u32, 10, 12] {
        let spec = QSpec::new(bits)?;
        let mut ptq = QGruDpd::new(float_w.quantize(spec).unwrap(), ActKind::Hard);
        let y_ptq = pa.run(&ptq.run(&sig.iq));
        let a_ptq = acpr_db(&y_ptq, &AcprConfig::default())?.acpr_dbc;

        let qat_path = &m.sweep.iter().find(|(n, _)| *n == format!("b{bits}_hard")).unwrap().1;
        let qat_w = GruWeights::load(qat_path)?;
        let mut qat = QGruDpd::new(qat_w.quantize(spec).unwrap(), ActKind::Hard);
        let y_qat = pa.run(&qat.run(&sig.iq));
        let a_qat = acpr_db(&y_qat, &AcprConfig::default())?.acpr_dbc;
        if a_qat < a_ptq {
            qat_beats_ptq_low_bits = true;
        }
        t.row(&[bits.to_string(), f1(a_ptq), f1(a_qat)]);
    }
    println!("{}", t.render());
    // Honest finding: on this smooth Rapp+memory plant, PTQ from a
    // well-trained fp32 model is nearly as good as QAT with hard
    // activations (QAT's edge grows with plant harshness and with the
    // LUT activation, whose staircase the float model never saw).
    println!(
        "observation: QAT {} PTQ on this plant (paper's plant is a real GaN stage)\n",
        if qat_beats_ptq_low_bits { "edges out" } else { "matches" }
    );

    // 2. Hard vs LUT at the ASIC level
    let spec = QSpec::new(m.qspec_bits)?;
    let w = QGruWeights::load_params_int(&m.weights_main, spec)?;
    let hard = AsicSpec::nominal(&w, true);
    let lut = AsicSpec::nominal(&w, false);
    let mut t2 = Table::new(
        "Ablation 2: activation implementation at the nominal point",
        &["variant", "power (mW)", "area (mm²)", "PAE (TOPS/W/mm²)"],
    );
    t2.row(&["Hardsigmoid/Hardtanh".into(), f1(hard.power.total_mw()), f3(hard.area.total_mm2()), f2(hard.pae_tops_w_mm2())]);
    t2.row(&["LUT ROMs".into(), f1(lut.power.total_mw()), f3(lut.area.total_mm2()), f2(lut.pae_tops_w_mm2())]);
    println!("{}", t2.render());
    assert!(hard.pae_tops_w_mm2() > lut.pae_tops_w_mm2());

    // 3. queue depth
    let mut t3 = Table::new(
        "Ablation 3: coordinator queue depth (64k samples, fixed engine)",
        &["depth", "throughput MSps"],
    );
    let burst = &sig.iq;
    for depth in [1usize, 2, 4, 16] {
        let coord = Coordinator::new(CoordinatorConfig {
            engine: EngineKind::fixed(),
            queue_depth: depth,
            ..Default::default()
        });
        let r = dpd_ne::bench::time_it(
            &format!("depth {depth}"),
            std::time::Duration::from_millis(400),
            || {
                std::hint::black_box(coord.run_stream(burst).unwrap());
            },
        );
        t3.row(&[depth.to_string(), f2(r.per_second(burst.len() as f64) / 1e6)]);
    }
    println!("{}", t3.render());
    println!("ablation checks passed\n");
    Ok(())
}
