//! GRU weight containers + loaders for the artifact JSON schema
//! (shared with `python/compile/model.py::params_to_jsonable`).

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::fixed::{QProfile, QSpec};
use crate::util::json::Json;

/// A weight tensor holding NaN/±inf — what a diverged [`AdaptTrainer`]
/// (`dpd::adapt`) produces. Quantizing such a tensor silently maps NaN
/// to code 0 (the NaN-propagating `clamp` + `as i32` cast), so the
/// quantization bridge screens for it and refuses with this typed
/// error instead of hot-swapping an all-zero-ish engine.
#[derive(Clone, Debug, PartialEq)]
pub struct NonFiniteWeightError {
    /// which tensor diverged (`"w_ih"`, `"b_fc"`, ...)
    pub tensor: &'static str,
    /// flat index of the first offending element
    pub index: usize,
    /// the offending value (NaN or ±inf)
    pub value: f64,
}

impl std::fmt::Display for NonFiniteWeightError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "non-finite weight {}[{}] = {} — refusing to quantize (diverged trainer?)",
            self.tensor, self.index, self.value
        )
    }
}

impl std::error::Error for NonFiniteWeightError {}

/// Float GRU-DPD weights. Gate row order is [r; z; n] (rows 0..H,
/// H..2H, 2H..3H) — the PyTorch convention the whole project uses.
#[derive(Clone, Debug)]
pub struct GruWeights {
    pub hidden: usize,
    pub features: usize,
    /// (3H, F) row-major
    pub w_ih: Vec<f64>,
    pub b_ih: Vec<f64>,
    /// (3H, H) row-major
    pub w_hh: Vec<f64>,
    pub b_hh: Vec<f64>,
    /// (2, H) row-major
    pub w_fc: Vec<f64>,
    pub b_fc: Vec<f64>,
    pub meta_bits: Option<u32>,
    pub meta_act: Option<String>,
    pub meta_val_nmse_db: Option<f64>,
}

/// Integer (Q2.f code) GRU weights.
#[derive(Clone, Debug)]
pub struct QGruWeights {
    pub hidden: usize,
    pub features: usize,
    pub spec: QSpec,
    pub w_ih: Vec<i32>,
    pub b_ih: Vec<i32>,
    pub w_hh: Vec<i32>,
    pub b_hh: Vec<i32>,
    pub w_fc: Vec<i32>,
    pub b_fc: Vec<i32>,
}

fn tensor_f64(obj: &Json, key: &str, want_len: usize) -> Result<Vec<f64>> {
    let t = obj.get(key)?;
    let data = t.get("data")?.as_f64_vec()?;
    ensure!(data.len() == want_len, "{key}: length {} != {want_len}", data.len());
    Ok(data)
}

fn tensor_i32(obj: &Json, key: &str, want_len: usize) -> Result<Vec<i32>> {
    let t = obj.get(key)?;
    let data = t.get("data")?.as_i32_vec()?;
    ensure!(data.len() == want_len, "{key}: length {} != {want_len}", data.len());
    Ok(data)
}

fn dims(params: &Json) -> Result<(usize, usize)> {
    let shape = params.get("w_ih")?.get("shape")?.as_arr()?;
    let rows = shape[0].as_usize()?;
    let features = shape[1].as_usize()?;
    ensure!(rows % 3 == 0, "w_ih rows not divisible by 3");
    Ok((rows / 3, features))
}

impl GruWeights {
    /// Load from a weights JSON (`weights_float.json`, sweep entries,
    /// or `weights_main.json` — anything with a `params` block).
    pub fn load(path: &Path) -> Result<GruWeights> {
        let j = Json::parse_file(path).context("loading GRU weights")?;
        let params = j.get("params")?;
        let (hidden, features) = dims(params)?;
        let meta = j.opt("meta");
        let meta_f64 = |k: &str| meta.and_then(|m| m.opt(k)).and_then(|v| v.as_f64().ok());
        Ok(GruWeights {
            hidden,
            features,
            w_ih: tensor_f64(params, "w_ih", 3 * hidden * features)?,
            b_ih: tensor_f64(params, "b_ih", 3 * hidden)?,
            w_hh: tensor_f64(params, "w_hh", 3 * hidden * hidden)?,
            b_hh: tensor_f64(params, "b_hh", 3 * hidden)?,
            w_fc: tensor_f64(params, "w_fc", 2 * hidden)?,
            b_fc: tensor_f64(params, "b_fc", 2)?,
            meta_bits: meta_f64("bits").map(|v| v as u32),
            meta_act: meta
                .and_then(|m| m.opt("act"))
                .and_then(|v| v.as_str().ok().map(String::from)),
            meta_val_nmse_db: meta_f64("val_nmse_db"),
        })
    }

    /// Amplitude-realistic synthetic float weights at the paper's
    /// dimensions (H=10, F=4, |w| < 0.15) — the float counterpart of
    /// [`QGruWeights::synthetic`], used wherever an artifact-less run
    /// needs a float twin (adaptive sessions in the fleet/loadgen
    /// paths, native-engine test fixtures). One definition so the
    /// hermetic constructions cannot drift apart.
    pub fn synthetic(seed: u64) -> GruWeights {
        let mut rng = crate::util::Rng::new(seed);
        let hidden = 10;
        let features = 4;
        let mut gen = |n: usize| -> Vec<f64> { (0..n).map(|_| rng.range(-0.15, 0.15)).collect() };
        GruWeights {
            hidden,
            features,
            w_ih: gen(3 * hidden * features),
            b_ih: gen(3 * hidden),
            w_hh: gen(3 * hidden * hidden),
            b_hh: gen(3 * hidden),
            w_fc: gen(2 * hidden),
            b_fc: gen(2),
            meta_bits: None,
            meta_act: None,
            meta_val_nmse_db: None,
        }
    }

    /// Total parameter count (paper: 502).
    pub fn n_params(&self) -> usize {
        self.w_ih.len() + self.b_ih.len() + self.w_hh.len() + self.b_hh.len()
            + self.w_fc.len() + self.b_fc.len()
    }

    /// Content fingerprint over dims + every weight word (f64 bit
    /// patterns). Two `GruDpd`s with equal fingerprints compute the
    /// same function — the batch-class test of the coalescing
    /// scheduler.
    pub fn fingerprint(&self) -> u64 {
        let dims = [self.hidden as u64, self.features as u64];
        let words = dims.into_iter().chain(
            self.w_ih
                .iter()
                .chain(&self.b_ih)
                .chain(&self.w_hh)
                .chain(&self.b_hh)
                .chain(&self.w_fc)
                .chain(&self.b_fc)
                .map(|v| v.to_bits()),
        );
        crate::util::fnv1a_words("gru-f64", words)
    }

    /// Screen every tensor for NaN/±inf, naming the first offender.
    /// The precondition of [`GruWeights::quantize`] and
    /// [`GruWeights::prune_quantize`].
    pub fn check_finite(&self) -> std::result::Result<(), NonFiniteWeightError> {
        let tensors: [(&'static str, &[f64]); 6] = [
            ("w_ih", &self.w_ih),
            ("b_ih", &self.b_ih),
            ("w_hh", &self.w_hh),
            ("b_hh", &self.b_hh),
            ("w_fc", &self.w_fc),
            ("b_fc", &self.b_fc),
        ];
        for (tensor, data) in tensors {
            if let Some((index, &value)) =
                data.iter().enumerate().find(|(_, v)| !v.is_finite())
            {
                return Err(NonFiniteWeightError { tensor, index, value });
            }
        }
        Ok(())
    }

    /// Quantize to Q2.f codes with the canonical round-half-up rule —
    /// bit-identical to python `ref.quantize_params`. Rejects
    /// non-finite weights with a typed error: NaN otherwise casts to
    /// code 0, and an adaptation hot-swap must fail loudly rather
    /// than deploy a silently-zeroed engine.
    pub fn quantize(&self, spec: QSpec) -> std::result::Result<QGruWeights, NonFiniteWeightError> {
        self.check_finite()?;
        let q = |v: &[f64]| -> Vec<i32> { v.iter().map(|&x| spec.quantize(x)).collect() };
        Ok(QGruWeights {
            hidden: self.hidden,
            features: self.features,
            spec,
            w_ih: q(&self.w_ih),
            b_ih: q(&self.b_ih),
            w_hh: q(&self.w_hh),
            b_hh: q(&self.b_hh),
            w_fc: q(&self.w_fc),
            b_fc: q(&self.b_fc),
        })
    }

    /// Magnitude-prune + mixed-precision quantize into the compressed
    /// sparse-gate form (SparseDPD × MP-DPD): quantize each tensor in
    /// its [`QProfile`] format, then drop the ⌊ρ% · N⌋
    /// smallest-magnitude codes per gate tensor. Defined as
    /// `SparseQGruWeights::from_dense ∘ quantize` so the float and
    /// pre-quantized construction paths can never disagree.
    pub fn prune_quantize(
        &self,
        profile: QProfile,
        rho: u8,
    ) -> std::result::Result<SparseQGruWeights, NonFiniteWeightError> {
        self.check_finite()?;
        let q = |v: &[f64], s: QSpec| -> Vec<i32> { v.iter().map(|&x| s.quantize(x)).collect() };
        Ok(SparseQGruWeights::from_parts(
            self.hidden,
            self.features,
            profile,
            rho,
            &q(&self.w_ih, profile.w_ih),
            q(&self.b_ih, profile.act),
            &q(&self.w_hh, profile.w_hh),
            q(&self.b_hh, profile.act),
            q(&self.w_fc, profile.w_fc),
            q(&self.b_fc, profile.act),
        ))
    }
}

impl QGruWeights {
    /// Amplitude-realistic synthetic weights at the paper's dimensions
    /// (H=10, F=4, |w| <= 0.3): the shared stimulus class used by the
    /// accel model tests and by artifact-less bench runs. One
    /// definition so the constructions cannot drift apart.
    pub fn synthetic(seed: u64, spec: QSpec) -> QGruWeights {
        let mut rng = crate::util::Rng::new(seed);
        let hidden = 10;
        let features = 4;
        let bound = (0.3 * spec.scale()) as i64;
        let mut gen =
            |n: usize| -> Vec<i32> { (0..n).map(|_| rng.int_in(-bound, bound) as i32).collect() };
        QGruWeights {
            hidden,
            features,
            spec,
            w_ih: gen(3 * hidden * features),
            b_ih: gen(3 * hidden),
            w_hh: gen(3 * hidden * hidden),
            b_hh: gen(3 * hidden),
            w_fc: gen(2 * hidden),
            b_fc: gen(2),
        }
    }

    /// Content fingerprint over format + dims + every weight code.
    /// Equal fingerprints promise an identical integer datapath —
    /// what lets the coalescing scheduler group sessions whose
    /// engines share one weight set into a single batched call.
    pub fn fingerprint(&self) -> u64 {
        let head = [self.spec.bits as u64, self.hidden as u64, self.features as u64];
        let words = head.into_iter().chain(
            self.w_ih
                .iter()
                .chain(&self.b_ih)
                .chain(&self.w_hh)
                .chain(&self.b_hh)
                .chain(&self.w_fc)
                .chain(&self.b_fc)
                .map(|&v| v as u32 as u64),
        );
        crate::util::fnv1a_words("qgru", words)
    }

    /// Parse a `params_int`-style block (the one loader both artifact
    /// shapes funnel through).
    fn from_params(params: &Json, spec: QSpec) -> Result<QGruWeights> {
        let (hidden, features) = dims(params)?;
        Ok(QGruWeights {
            hidden,
            features,
            spec,
            w_ih: tensor_i32(params, "w_ih", 3 * hidden * features)?,
            b_ih: tensor_i32(params, "b_ih", 3 * hidden)?,
            w_hh: tensor_i32(params, "w_hh", 3 * hidden * hidden)?,
            b_hh: tensor_i32(params, "b_hh", 3 * hidden)?,
            w_fc: tensor_i32(params, "w_fc", 2 * hidden)?,
            b_fc: tensor_i32(params, "b_fc", 2)?,
        })
    }

    /// Load the pre-quantized `params_int` block of `weights_main.json`
    /// (written by aot.py; equals `GruWeights::quantize` of `params`).
    pub fn load_params_int(path: &Path, spec: QSpec) -> Result<QGruWeights> {
        let j = Json::parse_file(path).context("loading int GRU weights")?;
        QGruWeights::from_params(j.get("params_int")?, spec)
    }

    /// Load from a golden-vector JSON (`golden/g_*.json` has the same
    /// `params_int` block plus test vectors).
    pub fn load_golden(path: &Path) -> Result<(QGruWeights, Json)> {
        let j = Json::parse_file(path).context("loading golden case")?;
        let spec = QSpec::new(j.get("bits")?.as_usize()? as u32)?;
        let w = QGruWeights::from_params(j.get("params_int")?, spec)?;
        Ok((w, j))
    }

    /// Prune + re-profile pre-quantized codes into the sparse form.
    /// `spec` becomes the uniform profile, so `from_dense(qw, 0)`
    /// computes exactly `qw`'s function — the `fixed+sparse:0` ≡
    /// `fixed` conformance contract.
    pub fn to_sparse(&self, rho: u8) -> SparseQGruWeights {
        SparseQGruWeights::from_parts(
            self.hidden,
            self.features,
            QProfile::uniform(self.spec),
            rho,
            &self.w_ih,
            self.b_ih.clone(),
            &self.w_hh,
            self.b_hh.clone(),
            self.w_fc.clone(),
            self.b_fc.clone(),
        )
    }
}

/// Deterministic magnitude-pruning mask: `true` marks the ⌊ρ% · N⌋
/// entries to drop — the smallest by (|code|, index), the total order
/// that makes the mask reproducible in the Python mirror
/// (`gen_golden_pareto.py::prune_mask`).
pub fn prune_mask(codes: &[i32], rho: u8) -> Vec<bool> {
    let k = codes.len() * (rho.min(100) as usize) / 100;
    let mut order: Vec<usize> = (0..codes.len()).collect();
    order.sort_by_key(|&i| (codes[i].unsigned_abs(), i));
    let mut pruned = vec![false; codes.len()];
    for &i in &order[..k] {
        pruned[i] = true;
    }
    pruned
}

/// Build one CSC tensor: per column `c`, the surviving entries are
/// `rows[ptr[c]..ptr[c+1]]` / `vals[..]`. An entry survives iff it is
/// unpruned AND nonzero — eliding a zero code is exact (its product
/// contributes nothing), so `rho = 0` sparse storage still computes
/// the dense function bit for bit.
fn csc_from_dense(
    w: &[i32],
    rows: usize,
    cols: usize,
    pruned: &[bool],
) -> (Vec<usize>, Vec<u16>, Vec<i32>) {
    debug_assert_eq!(w.len(), rows * cols);
    debug_assert!(rows <= u16::MAX as usize + 1);
    let mut ptr = Vec::with_capacity(cols + 1);
    let mut out_rows = Vec::new();
    let mut out_vals = Vec::new();
    ptr.push(0);
    for c in 0..cols {
        for r in 0..rows {
            let idx = r * cols + c;
            if !pruned[idx] && w[idx] != 0 {
                out_rows.push(r as u16);
                out_vals.push(w[idx]);
            }
        }
        ptr.push(out_rows.len());
    }
    (ptr, out_rows, out_vals)
}

/// Pruned, mixed-precision GRU weights in compressed sparse-column
/// form — the storage format of the SparseDPD/MP-DPD engine family
/// (`dpd::sparse::SparseMpGruDpd`).
///
/// The gate tensors W_ih (3H × F) and W_hh (3H × H) are stored as one
/// CSC list per *input column* — exactly the access pattern of the
/// delta/dense column-update loop (`acc[r] += w[r][c] · x[c]`), so a
/// pruned weight costs no MAC and no storage. Biases and the tiny FC
/// head (2 × H) stay dense. Weight codes are in each tensor's
/// [`QProfile`] format; biases in the activation format.
#[derive(Clone, Debug)]
pub struct SparseQGruWeights {
    pub hidden: usize,
    pub features: usize,
    pub profile: QProfile,
    /// requested prune fraction, percent (part of the identity: the
    /// same surviving codes under a different ρ request are still a
    /// different deployment intent)
    pub rho: u8,
    /// CSC of W_ih: column `c` of `features` holds rows
    /// `ih_rows[ih_ptr[c]..ih_ptr[c+1]]` (row indices in 0..3H)
    pub ih_ptr: Vec<usize>,
    pub ih_rows: Vec<u16>,
    pub ih_vals: Vec<i32>,
    /// CSC of W_hh: `hidden` columns of row indices in 0..3H
    pub hh_ptr: Vec<usize>,
    pub hh_rows: Vec<u16>,
    pub hh_vals: Vec<i32>,
    pub b_ih: Vec<i32>,
    pub b_hh: Vec<i32>,
    /// (2, H) row-major, dense
    pub w_fc: Vec<i32>,
    pub b_fc: Vec<i32>,
}

impl SparseQGruWeights {
    /// Shared construction funnel: prune each dense gate tensor by
    /// magnitude, compress to CSC. Used by both the float path
    /// ([`GruWeights::prune_quantize`]) and the pre-quantized path
    /// ([`QGruWeights::to_sparse`]).
    #[allow(clippy::too_many_arguments)]
    fn from_parts(
        hidden: usize,
        features: usize,
        profile: QProfile,
        rho: u8,
        w_ih: &[i32],
        b_ih: Vec<i32>,
        w_hh: &[i32],
        b_hh: Vec<i32>,
        w_fc: Vec<i32>,
        b_fc: Vec<i32>,
    ) -> SparseQGruWeights {
        let rows = 3 * hidden;
        let (ih_ptr, ih_rows, ih_vals) =
            csc_from_dense(w_ih, rows, features, &prune_mask(w_ih, rho));
        let (hh_ptr, hh_rows, hh_vals) =
            csc_from_dense(w_hh, rows, hidden, &prune_mask(w_hh, rho));
        SparseQGruWeights {
            hidden,
            features,
            profile,
            rho,
            ih_ptr,
            ih_rows,
            ih_vals,
            hh_ptr,
            hh_rows,
            hh_vals,
            b_ih,
            b_hh,
            w_fc,
            b_fc,
        }
    }

    /// Surviving gate entries (= MACs per fired column-update, summed
    /// over all columns) — what the accel cost model prices.
    pub fn gate_nnz(&self) -> usize {
        self.ih_vals.len() + self.hh_vals.len()
    }

    /// Dense gate entry count, for sparsity ratios.
    pub fn gate_dense(&self) -> usize {
        3 * self.hidden * (self.features + self.hidden)
    }

    /// Content fingerprint over the profile, ρ, the sparsity pattern
    /// (CSC pointers + row indices) and every surviving code — the
    /// batch class of the sparse engine family. Two engines coalesce
    /// only when mask, bitwidths and weights all agree.
    pub fn fingerprint(&self) -> u64 {
        let head = [
            self.profile.w_ih.bits as u64,
            self.profile.w_hh.bits as u64,
            self.profile.w_fc.bits as u64,
            self.profile.act.bits as u64,
            self.rho as u64,
            self.hidden as u64,
            self.features as u64,
        ];
        let words = head
            .into_iter()
            .chain(self.ih_ptr.iter().map(|&v| v as u64))
            .chain(self.ih_rows.iter().map(|&v| v as u64))
            .chain(self.ih_vals.iter().map(|&v| v as u32 as u64))
            .chain(self.hh_ptr.iter().map(|&v| v as u64))
            .chain(self.hh_rows.iter().map(|&v| v as u64))
            .chain(self.hh_vals.iter().map(|&v| v as u32 as u64))
            .chain(
                self.b_ih
                    .iter()
                    .chain(&self.b_hh)
                    .chain(&self.w_fc)
                    .chain(&self.b_fc)
                    .map(|&v| v as u32 as u64),
            );
        crate::util::fnv1a_words("sparse-mp", words)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_weights_json(hidden: usize, features: usize) -> String {
        let tensor = |rows: usize, cols: Option<usize>| -> String {
            let n = rows * cols.unwrap_or(1);
            let data: Vec<String> = (0..n).map(|i| format!("{}", (i as f64) * 0.001 - 0.05)).collect();
            let shape = match cols {
                Some(c) => format!("[{rows},{c}]"),
                None => format!("[{rows}]"),
            };
            format!("{{\"shape\":{shape},\"data\":[{}]}}", data.join(","))
        };
        format!(
            "{{\"meta\":{{\"bits\":12,\"act\":\"hard\",\"val_nmse_db\":-37.5}},\"params\":{{\
             \"w_ih\":{},\"b_ih\":{},\"w_hh\":{},\"b_hh\":{},\"w_fc\":{},\"b_fc\":{}}}}}",
            tensor(3 * hidden, Some(features)),
            tensor(3 * hidden, None),
            tensor(3 * hidden, Some(hidden)),
            tensor(3 * hidden, None),
            tensor(2, Some(hidden)),
            tensor(2, None),
        )
    }

    #[test]
    fn load_roundtrip() {
        let dir = std::env::temp_dir().join("dpd_ne_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.json");
        std::fs::write(&path, fake_weights_json(10, 4)).unwrap();
        let w = GruWeights::load(&path).unwrap();
        assert_eq!(w.hidden, 10);
        assert_eq!(w.features, 4);
        assert_eq!(w.n_params(), 502);
        assert_eq!(w.meta_bits, Some(12));
        assert_eq!(w.meta_act.as_deref(), Some("hard"));
        assert!((w.meta_val_nmse_db.unwrap() + 37.5).abs() < 1e-12);
    }

    #[test]
    fn quantize_matches_qspec_rule() {
        let dir = std::env::temp_dir().join("dpd_ne_test_weights2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.json");
        std::fs::write(&path, fake_weights_json(10, 4)).unwrap();
        let w = GruWeights::load(&path).unwrap();
        let spec = QSpec::Q12;
        let qw = w.quantize(spec).unwrap();
        for (f, q) in w.w_ih.iter().zip(&qw.w_ih) {
            assert_eq!(*q, spec.quantize(*f));
        }
    }

    #[test]
    fn quantize_rejects_non_finite_weights_with_a_typed_error() {
        // Regression: NaN weights used to quantize silently to code 0
        // (the NaN-propagating clamp + `as i32` cast); the bridge must
        // refuse instead, naming the offending tensor/element.
        let mut w = GruWeights::synthetic(9);
        assert!(w.check_finite().is_ok());
        w.w_hh[17] = f64::NAN;
        let err = w.quantize(QSpec::Q12).unwrap_err();
        assert_eq!(err.tensor, "w_hh");
        assert_eq!(err.index, 17);
        assert!(err.value.is_nan());
        assert!(err.to_string().contains("w_hh[17]"), "{err}");
        // ±inf is rejected the same way, in any tensor
        let mut w2 = GruWeights::synthetic(9);
        w2.b_fc[1] = f64::INFINITY;
        let err2 = w2.quantize(QSpec::Q12).unwrap_err();
        assert_eq!((err2.tensor, err2.index), ("b_fc", 1));
        // prune_quantize shares the screen
        assert!(w2.prune_quantize(QProfile::uniform(QSpec::Q12), 50).is_err());
    }

    #[test]
    fn prune_mask_drops_the_smallest_magnitudes_deterministically() {
        let codes = [5, -1, 0, 7, -3, 2, 0, -7];
        // rho=50% of 8 -> 4 pruned: |0|@2, |0|@6, |-1|@1, |2|@5
        let mask = prune_mask(&codes, 50);
        assert_eq!(mask, [false, true, true, false, false, true, true, false]);
        // ties broken by index: equal |.|=7 keeps both at rho=50
        assert_eq!(prune_mask(&codes, 0), [false; 8]);
        let all = prune_mask(&codes, 100);
        assert!(all.iter().all(|&p| p));
    }

    #[test]
    fn sparse_csc_stores_exactly_the_surviving_nonzero_codes() {
        let qw = QGruWeights::synthetic(3, QSpec::Q12);
        let sw = qw.to_sparse(0);
        assert_eq!(sw.profile, QProfile::uniform(QSpec::Q12));
        assert_eq!(sw.ih_ptr.len(), qw.features + 1);
        assert_eq!(sw.hh_ptr.len(), qw.hidden + 1);
        // rho=0: every nonzero code survives, at its exact position
        let rows = 3 * qw.hidden;
        let nonzero_ih = qw.w_ih.iter().filter(|&&v| v != 0).count();
        assert_eq!(sw.ih_vals.len(), nonzero_ih);
        for c in 0..qw.features {
            for k in sw.ih_ptr[c]..sw.ih_ptr[c + 1] {
                let r = sw.ih_rows[k] as usize;
                assert!(r < rows);
                assert_eq!(sw.ih_vals[k], qw.w_ih[r * qw.features + c]);
            }
        }
        // rho=50 halves the stored gate entries (up to zero-code elision)
        let half = qw.to_sparse(50);
        let dense_n = qw.w_ih.len() + qw.w_hh.len();
        assert!(half.gate_nnz() <= dense_n - dense_n / 2);
        assert!(half.gate_nnz() < sw.gate_nnz());
        assert_eq!(half.gate_dense(), dense_n);
    }

    #[test]
    fn sparse_fingerprint_separates_mask_profile_and_rho() {
        let w = GruWeights::synthetic(5);
        let base = w.prune_quantize(QProfile::uniform(QSpec::Q12), 0).unwrap();
        let same = w.prune_quantize(QProfile::uniform(QSpec::Q12), 0).unwrap();
        assert_eq!(base.fingerprint(), same.fingerprint());
        // different rho -> different mask and class
        let pruned = w.prune_quantize(QProfile::uniform(QSpec::Q12), 50).unwrap();
        assert_ne!(base.fingerprint(), pruned.fingerprint());
        // different weight bitwidth -> different class
        let mp = w.prune_quantize(QProfile::wa(8, 12).unwrap(), 0).unwrap();
        assert_ne!(base.fingerprint(), mp.fingerprint());
        // same codes, different declared rho -> still a different class
        let mut relabeled = base.clone();
        relabeled.rho = 1;
        assert_ne!(base.fingerprint(), relabeled.fingerprint());
    }

    #[test]
    fn float_and_prequantized_sparse_paths_agree() {
        // prune_quantize == to_sparse ∘ quantize on uniform profiles —
        // the funnel contract
        let w = GruWeights::synthetic(11);
        let via_float = w.prune_quantize(QProfile::uniform(QSpec::Q12), 30).unwrap();
        let via_codes = w.quantize(QSpec::Q12).unwrap().to_sparse(30);
        assert_eq!(via_float.fingerprint(), via_codes.fingerprint());
    }

    #[test]
    fn fingerprints_identify_weight_content() {
        let a = QGruWeights::synthetic(1, QSpec::Q12);
        let b = QGruWeights::synthetic(1, QSpec::Q12);
        let c = QGruWeights::synthetic(2, QSpec::Q12);
        assert_eq!(a.fingerprint(), b.fingerprint(), "same content, same class");
        assert_ne!(a.fingerprint(), c.fingerprint(), "different weights, different class");
        // the format is part of the identity (same codes at 8 bits
        // compute a different function)
        let d = QGruWeights { spec: QSpec::new(8).unwrap(), ..a.clone() };
        assert_ne!(a.fingerprint(), d.fingerprint());
        // a single flipped weight changes the class
        let mut e = a.clone();
        e.w_hh[17] ^= 1;
        assert_ne!(a.fingerprint(), e.fingerprint());
    }

    #[test]
    fn rejects_wrong_lengths() {
        let dir = std::env::temp_dir().join("dpd_ne_test_weights3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.json");
        // truncated b_fc
        let bad = fake_weights_json(10, 4).replace(
            "\"b_fc\":{\"shape\":[2],\"data\":[-0.05,-0.049]}",
            "\"b_fc\":{\"shape\":[2],\"data\":[-0.05]}",
        );
        std::fs::write(&path, bad).unwrap();
        assert!(GruWeights::load(&path).is_err());
    }

    #[test]
    fn load_failures_name_what_went_wrong() {
        let dir = std::env::temp_dir().join("dpd_ne_test_weights4");
        std::fs::create_dir_all(&dir).unwrap();
        let load_err = |name: &str, text: &str| -> String {
            let path = dir.join(name);
            std::fs::write(&path, text).unwrap();
            format!("{:#}", GruWeights::load(&path).unwrap_err())
        };
        let good = fake_weights_json(10, 4);

        // not JSON at all -> the load context survives
        let err = load_err("garbage.json", "not json {");
        assert!(err.contains("loading GRU weights"), "{err}");

        // structurally valid JSON with no params block
        let err = load_err("noparams.json", "{\"meta\":{\"bits\":12}}");
        assert!(err.contains("params"), "{err}");

        // w_ih row count that is not a gate multiple
        let err = load_err("rows.json", &good.replace("\"shape\":[30,4]", "\"shape\":[31,4]"));
        assert!(err.contains("w_ih rows not divisible by 3"), "{err}");

        // negative dimension
        let err = load_err("neg.json", &good.replace("\"shape\":[30,4]", "\"shape\":[30,-4]"));
        assert!(err.contains("negative"), "{err}");

        // truncated hidden-gate tensor: error names tensor + both lengths
        // (hand-built H=1/F=1 doc; b_hh carries 2 of the 3 required)
        let err = load_err(
            "short.json",
            "{\"params\":{\
             \"w_ih\":{\"shape\":[3,1],\"data\":[0.1,0.2,0.3]},\
             \"b_ih\":{\"shape\":[3],\"data\":[0.0,0.0,0.0]},\
             \"w_hh\":{\"shape\":[3,1],\"data\":[0.1,0.2,0.3]},\
             \"b_hh\":{\"shape\":[3],\"data\":[0.0,0.0]},\
             \"w_fc\":{\"shape\":[2,1],\"data\":[1.0,0.0]},\
             \"b_fc\":{\"shape\":[2],\"data\":[0.0,0.0]}}}",
        );
        assert!(err.contains("b_hh"), "{err}");
        assert!(err.contains("2 != 3"), "{err}");

        // a declared shape larger than the data is a length error too —
        // dims come from the shape, data is checked against them
        let err = load_err("bigshape.json", &good.replace("\"shape\":[30,4]", "\"shape\":[33,4]"));
        assert!(err.contains("w_ih"), "{err}");
        assert!(err.contains("132"), "{err}");
    }

    #[test]
    fn prune_mask_is_total_over_the_rho_range() {
        let codes = [5, -1, 0, 7, -3, 2, 0, -7];
        // overdriven rho clamps to 100% — every entry pruned, no panic,
        // and identical to the rho=100 mask
        let full = prune_mask(&codes, 100);
        for rho in [101u8, 150, 255] {
            assert_eq!(prune_mask(&codes, rho), full, "rho={rho}");
        }
        assert!(prune_mask(&codes, 255).iter().all(|&p| p));
        // empty input: every rho yields an empty mask
        for rho in [0u8, 50, 100, 255] {
            assert!(prune_mask(&[], rho).is_empty(), "rho={rho}");
        }
        // the sparse constructor inherits the clamp: rho=255 stores
        // only what zero-code elision would anyway (nothing)
        let qw = QGruWeights::synthetic(3, QSpec::Q12);
        let sw = qw.to_sparse(255);
        assert_eq!(sw.gate_nnz(), 0);
        assert_eq!(sw.rho, 255, "declared rho is preserved verbatim");
    }
}
