//! DPD-NeuralEngine CLI — the leader entrypoint.
//!
//! Subcommands (hand-rolled parser; no clap offline):
//!   run          end-to-end linearization (OFDM -> DPD -> PA -> ACPR/EVM)
//!   serve        long-lived DpdService: N sessions multiplexed on a
//!                persistent worker pool (+ optional shadow-audit session);
//!                `--adapt` runs the closed adaptation loop against a
//!                drifting PA (ILA trainer + periodic engine hot-swaps,
//!                knobs --drift-ramp / --refresh-interval)
//!   stream       multi-stream one-shot throughput run (compat wrapper)
//!   asic-report  Fig. 5 post-layout-style spec from the models
//!   fpga-report  Table I / Fig. 4 resource estimates
//!   sweep        Fig. 3 precision x activation sweep
//!   info         artifact manifest summary
//!   loadgen      fleet saturation sweep: churn heterogeneous sessions
//!                through a sharded Fleet under open-loop arrivals and
//!                emit BENCH_load.json (sessions x MSps curve, knee,
//!                latency quantiles); `--quick` is the CI smoke shape
//!   rollout      canary-first weight rollout across a hermetic fleet:
//!                a content-addressed candidate generation deploys to
//!                one shard, the post-refresh ACPR meters judge it,
//!                and it promotes fleet-wide or rolls back to its
//!                parent (`--inject-bad` forces the rollback path)
//!
//! Flags are checked against a per-command allowlist: an unknown flag
//! is a usage error naming the offending flag, never a silent no-op
//! (a typo'd `--refreshinterval` used to run the default silently).
//!
//! Common flags: --artifacts <dir>, --engine <spec>, --streams <n>,
//! --symbols <n>, --seed <n>; `serve` adds --sessions <n>,
//! --workers <n>, --rounds <n>, --shadow <engine> and --batch <n>
//! (coalesce up to n same-engine sessions per worker dispatch into
//! one batched engine call — bit-identical output, higher aggregate
//! throughput).
//!
//! `--engine` takes an engine-spec string parsed by
//! [`EngineKind::parse`] — `native | fixed[+simd] | delta[:θ][+simd]
//! | cyclesim | interp | hlo` — and the help text renders the list
//! from `EngineFactory::available_kinds()`, so it can never drift
//! from what the build constructs. `delta:<codes>` carries the
//! DeltaDPD column-skip threshold inline (0 is bit-identical to
//! `fixed`, so `--engine delta:0 --shadow fixed` is a live
//! equivalence audit); `--delta-theta <codes>` survives as a
//! deprecated alias for specs that name no θ. `+simd` engages the
//! AVX2 gate kernels where the host supports them and falls back to
//! the bit-identical scalar kernel otherwise (`DPD_SIMD=off`
//! forces the fallback). The `hlo` engine needs a build with
//! `--features xla`; `interp` is its hermetic frame-based twin.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use dpd_ne::coordinator::{
    Coordinator, CoordinatorConfig, DpdService, EngineKind, ServiceConfig, SessionAdaptConfig,
    SessionConfig,
};
use dpd_ne::dpd::qgru::{ActKind, LutTables, QGruDpd};
use dpd_ne::dpd::weights::{GruWeights, QGruWeights};
use dpd_ne::dpd::Dpd;
use dpd_ne::fixed::QSpec;
use dpd_ne::metrics::acpr::{acpr_db, AcprConfig};
use dpd_ne::metrics::evm::evm_db_nmse;
use dpd_ne::pa::{DriftTrajectory, DriftingPa, PaSpec, RappMemPa};
use dpd_ne::report::{f1, f2, f3, Table};
use dpd_ne::runtime::{EngineFactory, Manifest};
use dpd_ne::signal::ofdm::{OfdmConfig, OfdmModulator};

/// flags every signal-driven command shares
const COMMON_FLAGS: &[&str] = &["artifacts", "engine", "streams", "symbols", "seed", "delta-theta"];

/// The per-command flag allowlist; `None` means an unknown command.
/// `parse_flags` rejects anything outside it, so a typo'd flag is a
/// usage error instead of a silently ignored default.
fn allowed_flags(cmd: &str) -> Option<Vec<&'static str>> {
    let extra: &[&str] = match cmd {
        "run" | "stream" | "asic-report" | "fpga-report" | "sweep" | "info" => &[],
        "serve" => &[
            "sessions",
            "workers",
            "rounds",
            "shadow",
            "batch",
            "adapt",
            "drift-ramp",
            "refresh-interval",
        ],
        "loadgen" => {
            return Some(vec![
                "quick",
                "shards",
                "workers",
                "sessions",
                "samples",
                "chunk",
                "frame",
                "lives",
                "batch",
                "adaptive-every",
                "policy",
                "arrival",
                "seed",
            ])
        }
        "rollout" => {
            return Some(vec![
                "shards",
                "sessions",
                "budget-db",
                "inject-bad",
                "seed",
                "symbols",
            ])
        }
        _ => return None,
    };
    Some(COMMON_FLAGS.iter().chain(extra).copied().collect())
}

fn parse_flags(
    args: &[String],
    allowed: &[&'static str],
) -> Result<(Vec<String>, HashMap<String, String>)> {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if !allowed.contains(&name) {
                bail!("unknown flag '--{name}' for this command\n{}", usage());
            }
            // a following token that is itself a flag means this one is
            // bare (e.g. `serve --adapt --engine fixed`)
            match args.get(i + 1) {
                Some(v) if !v.starts_with("--") => {
                    flags.insert(name.to_string(), v.clone());
                    i += 2;
                }
                _ => {
                    flags.insert(name.to_string(), String::new());
                    i += 1;
                }
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    Ok((pos, flags))
}

fn parse_engine(name: &str, flags: &HashMap<String, String>) -> Result<EngineKind> {
    let kind = EngineKind::parse(name)?;
    // deprecated alias: `--delta-theta <codes>` fills in the θ of a
    // delta spec that names none (`delta`, `delta+simd`), keeping the
    // pre-spec invocations (`--engine delta --delta-theta 32`)
    // bit-identical. A spec with an explicit `:θ` wins; the flag is
    // ignored on non-delta kinds, exactly as before.
    if let Some(theta) = flags.get("delta-theta") {
        if !name.contains(':') && kind.base == dpd_ne::runtime::EngineBase::Delta {
            return Ok(EngineKind { theta: theta.parse()?, ..kind });
        }
    }
    Ok(kind)
}

fn engine_kind(flags: &HashMap<String, String>) -> Result<EngineKind> {
    parse_engine(flags.get("engine").map(String::as_str).unwrap_or("fixed"), flags)
}

fn artifacts(flags: &HashMap<String, String>) -> Option<PathBuf> {
    flags.get("artifacts").map(PathBuf::from)
}

/// CLI help, rendered from the engine registry: the spec syntax list
/// and the host's SIMD state come from
/// [`EngineFactory::available_kinds`], never a hardcoded copy.
fn usage() -> String {
    let rows = EngineFactory::available_kinds();
    let syntax: Vec<&'static str> = rows.iter().map(|r| r.syntax).collect();
    let host_simd = rows.iter().any(|r| r.simd == Some(true));
    format!(
        "usage: dpd-ne <run|serve|stream|loadgen|rollout|asic-report|fpga-report|sweep|info> [flags]\n\
         flags: --artifacts <dir> --engine <{engines}> \
         --streams <n> --symbols <n> --seed <n>\n\
         serve: --sessions <n> --workers <n> --rounds <n> --shadow <engine> --batch <n>\n\
         serve --adapt: closed-loop tracking of a drifting PA \
         (--drift-ramp <samples> --refresh-interval <samples>)\n\
         loadgen: fleet saturation sweep -> BENCH_load.json; --quick for the CI smoke shape, \
         --shards/--workers/--sessions/--samples/--chunk/--frame/--lives/--batch/\
         --adaptive-every <n> --policy <rr|least|sticky> --arrival <poisson|bursty> --seed <n>\n\
         rollout: canary-first weight rollout across a hermetic fleet \
         (--shards <n> --sessions <per-shard> --budget-db <dB> --inject-bad --seed <n>)\n\
         delta: θ in codes rides in the spec (delta:32; 0 = bit-identical to 'fixed'); \
         --delta-theta <codes> is a deprecated alias\n\
         +simd: AVX2 gate kernels, host support {simd}; \
         DPD_SIMD=off forces the bit-identical scalar kernel\n\
         (engine 'hlo' needs a build with --features xla)",
        engines = syntax.join("|"),
        simd = if host_simd { "detected" } else { "absent (scalar fallback)" },
    )
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().cloned() else {
        println!("{}", usage());
        return Ok(());
    };
    let Some(allowed) = allowed_flags(&cmd) else {
        bail!("unknown command '{cmd}'\n{}", usage());
    };
    let (_pos, flags) = parse_flags(&args[1..], &allowed)?;
    match cmd.as_str() {
        "run" => cmd_run(&flags),
        "serve" => cmd_serve(&flags),
        "stream" => cmd_stream(&flags),
        "loadgen" => cmd_loadgen(&flags),
        "rollout" => cmd_rollout(&flags),
        "asic-report" => cmd_asic_report(&flags),
        "fpga-report" => cmd_fpga_report(),
        "sweep" => cmd_sweep(&flags),
        "info" => cmd_info(&flags),
        other => unreachable!("allowed_flags admitted unknown command '{other}'"),
    }
}

fn test_signal(flags: &HashMap<String, String>) -> Result<dpd_ne::signal::ofdm::OfdmSignal> {
    let n_symbols: usize = flags.get("symbols").map(|s| s.parse()).transpose()?.unwrap_or(24);
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(42);
    OfdmModulator::generate(&OfdmConfig { n_symbols, seed, ..Default::default() })
}

fn cmd_run(flags: &HashMap<String, String>) -> Result<()> {
    let m = Manifest::discover(artifacts(flags).as_deref())?;
    let pa = RappMemPa::new(PaSpec::load(&m.pa_model)?);
    let g = pa.spec.target_gain();
    let sig = test_signal(flags)?;

    let coord = Coordinator::new(CoordinatorConfig {
        engine: engine_kind(flags)?,
        artifacts: artifacts(flags),
        ..Default::default()
    });

    let y_off = pa.run(&sig.iq);
    let off = acpr_db(&y_off, &AcprConfig::default())?;
    let evm_off = evm_db_nmse(&y_off, &sig.iq, g);

    let out = coord.run_stream(&sig.iq)?;
    let y_on = pa.run(&out.iq);
    let on = acpr_db(&y_on, &AcprConfig::default())?;
    let evm_on = evm_db_nmse(&y_on, &sig.iq, g);

    let mut t = Table::new(
        "End-to-end linearization (paper: ACPR -45.3 dBc, EVM -39.8 dB)",
        &["config", "ACPR (dBc)", "EVM (dB)"],
    );
    t.row(&["DPD off".into(), f1(off.acpr_dbc), f1(evm_off)]);
    t.row(&[format!("DPD on ({})", coord.cfg.engine), f1(on.acpr_dbc), f1(evm_on)]);
    println!("{}", t.render());
    println!(
        "engine throughput: {:.2} MSps ({:.3}x of the 250 MSps line rate)",
        out.stats.engine_msps(),
        out.stats.realtime_factor_vs_250msps()
    );
    Ok(())
}

fn cmd_stream(flags: &HashMap<String, String>) -> Result<()> {
    let n_streams: usize = flags.get("streams").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let sig = test_signal(flags)?;
    let coord = Coordinator::new(CoordinatorConfig {
        engine: engine_kind(flags)?,
        artifacts: artifacts(flags),
        ..Default::default()
    });
    let inputs: Vec<Vec<[f64; 2]>> = (0..n_streams).map(|_| sig.iq.clone()).collect();
    let t0 = std::time::Instant::now();
    let outs = coord.run_streams(inputs)?;
    let wall = t0.elapsed();
    let total: u64 = outs.iter().map(|o| o.stats.samples_out).sum();
    let mut t = Table::new(
        "Multi-stream coordinator (mMIMO fan-out)",
        &["stream", "samples", "engine MSps", "frame lat mean", "frame lat max"],
    );
    for (i, o) in outs.iter().enumerate() {
        t.row(&[
            format!("{i}"),
            o.stats.samples_out.to_string(),
            f2(o.stats.engine_msps()),
            format!("{:?}", o.stats.lat_mean),
            format!("{:?}", o.stats.lat_max),
        ]);
    }
    println!("{}", t.render());
    println!(
        "aggregate: {} samples in {:?} = {:.2} MSps across {} streams",
        total,
        wall,
        total as f64 / wall.as_secs_f64() / 1e6,
        outs.len()
    );
    Ok(())
}

/// The service-native path: one persistent worker pool, N long-lived
/// sessions multiplexed from this thread (`push` auto-drains, so no
/// consumer thread per session is needed), engine state carried
/// across every burst. `--shadow <engine>` opens one more session
/// that mirrors session 0's input for an on-line parity audit —
/// e.g. `--engine fixed --shadow cyclesim` checks the functional
/// model against the cycle-accurate ASIC simulator while serving.
fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    if flags.contains_key("adapt") {
        return cmd_serve_adapt(flags);
    }
    let n_sessions: usize = flags.get("sessions").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let n_workers: usize = flags.get("workers").map(|s| s.parse()).transpose()?.unwrap_or(2);
    let rounds: usize = flags.get("rounds").map(|s| s.parse()).transpose()?.unwrap_or(3);
    let batch: usize = flags.get("batch").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let engine = engine_kind(flags)?;
    let shadow_kind = flags.get("shadow").map(|s| parse_engine(s, flags)).transpose()?;
    let sig = test_signal(flags)?;

    let service = DpdService::start(ServiceConfig {
        workers: n_workers,
        // the service sizes worker channels for coalescing headroom
        // itself (max(queue_depth, batch)); no override needed here
        batch,
        artifacts: artifacts(flags),
        ..Default::default()
    })?;
    let mut sessions = Vec::new();
    for _ in 0..n_sessions {
        sessions.push(service.open_session(SessionConfig { engine, ..Default::default() })?);
    }
    let mut shadow = shadow_kind
        .map(|kind| service.open_session(SessionConfig { engine: kind, ..Default::default() }))
        .transpose()?;
    println!(
        "DpdService: {} workers, {} sessions ({engine}){}, batch {batch}, \
         {} samples/burst x {rounds} bursts",
        service.workers(),
        n_sessions,
        match shadow_kind {
            Some(k) => format!(" + shadow ({k})"),
            None => String::new(),
        },
        sig.iq.len()
    );

    let mut outputs: Vec<Vec<[f64; 2]>> = vec![Vec::new(); n_sessions];
    let mut shadow_out: Vec<[f64; 2]> = Vec::new();
    let t0 = std::time::Instant::now();
    for _ in 0..rounds {
        for chunk in sig.iq.chunks(4096) {
            for (k, s) in sessions.iter_mut().enumerate() {
                s.push(chunk)?;
                outputs[k].extend(s.drain()?);
            }
            if let Some(sh) = shadow.as_mut() {
                sh.push(chunk)?;
                shadow_out.extend(sh.drain()?);
            }
        }
    }

    let mut t = Table::new(
        "DpdService sessions (hidden state persisted across bursts)",
        &["session", "engine", "samples", "frames", "engine MSps", "frame lat mean"],
    );
    let mut agg = 0u64;
    for (k, s) in sessions.into_iter().enumerate() {
        let out = s.finish()?;
        agg += out.stats.samples_out;
        outputs[k].extend(out.iq);
        t.row(&[
            format!("{k}"),
            format!("{engine}"),
            out.stats.samples_out.to_string(),
            out.stats.frames.to_string(),
            f2(out.stats.engine_msps()),
            format!("{:?}", out.stats.lat_mean),
        ]);
    }
    if let Some(sh) = shadow.take() {
        let out = sh.finish()?;
        shadow_out.extend(out.iq);
        t.row(&[
            "shadow".into(),
            format!("{}", shadow_kind.unwrap()),
            out.stats.samples_out.to_string(),
            out.stats.frames.to_string(),
            f2(out.stats.engine_msps()),
            format!("{:?}", out.stats.lat_mean),
        ]);
    }
    let wall = t0.elapsed();
    println!("{}", t.render());
    println!(
        "aggregate: {} samples in {:?} = {:.2} MSps across the pool",
        agg,
        wall,
        agg as f64 / wall.as_secs_f64() / 1e6
    );
    if !shadow_out.is_empty() && !outputs.is_empty() {
        let dev = shadow_out
            .iter()
            .zip(&outputs[0])
            .map(|(a, b)| (a[0] - b[0]).abs().max((a[1] - b[1]).abs()))
            .fold(0.0f64, f64::max);
        if dev == 0.0 {
            println!("shadow audit: bit-identical to session 0");
        } else {
            println!("shadow audit: max |dev| vs session 0 = {dev:.6}");
        }
    }
    service.shutdown()
}

/// `serve --adapt`: the closed-loop demo — one adaptive session
/// tracking a drifting amplifier. The original samples stream through
/// the deployed (re-quantized) engine, the predistorted output feeds a
/// [`DriftingPa`] whose parameters follow the reference trajectory,
/// and the observed PA output is pushed back via `adapt_feedback`; the
/// background adapt worker trains the float twin and hot-swaps the
/// engine every `--refresh-interval` samples. Knobs: `--drift-ramp`
/// (samples to full excursion, 0 = step), `--refresh-interval`,
/// `--rounds`, `--engine` (a refreshable spec: `native`,
/// `fixed[+simd]` or `delta[:θ][+simd]`).
fn cmd_serve_adapt(flags: &HashMap<String, String>) -> Result<()> {
    // defaults sized so the stock invocation actually demonstrates the
    // loop: 8 rounds x 24 symbols = ~52k feedback samples -> several
    // hot-swaps (refresh every 16k) across a full drift excursion
    // (ramp 32k)
    let rounds: usize = flags.get("rounds").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let ramp: u64 = flags.get("drift-ramp").map(|s| s.parse()).transpose()?.unwrap_or(1 << 15);
    let refresh: u64 =
        flags.get("refresh-interval").map(|s| s.parse()).transpose()?.unwrap_or(1 << 14);
    let engine = engine_kind(flags)?;
    let sig = test_signal(flags)?;

    let service = DpdService::start(ServiceConfig {
        workers: 1,
        artifacts: artifacts(flags),
        ..Default::default()
    })?;
    let m = service
        .manifest()
        .ok_or_else(|| anyhow::anyhow!("serve --adapt needs an artifact tree (make artifacts)"))?
        .clone();
    let mut pa = DriftingPa::new(PaSpec::load(&m.pa_model)?, DriftTrajectory::reference(ramp));
    let acfg = SessionAdaptConfig { refresh_interval: refresh, ..Default::default() };
    let mut session =
        service.open_session(SessionConfig { engine, adapt: Some(acfg), ..Default::default() })?;
    println!(
        "closed loop: engine {engine}, drift ramp {ramp} samples, refresh every {refresh}, \
         {} samples/round x {rounds} rounds",
        sig.iq.len()
    );

    let mut t = Table::new(
        "Closed-loop adaptation against the drifting PA",
        &[
            "round",
            "drift",
            "refreshes",
            "recent NMSE (dB)",
            "window ACPR (dBc)",
            "last swap ΔACPR (dB)",
        ],
    );
    // alignment queue: x samples pushed but not yet drained as u
    let mut x_fifo: Vec<[f64; 2]> = Vec::new();
    for round in 0..rounds {
        for chunk in sig.iq.chunks(4096) {
            session.push(chunk)?;
            x_fifo.extend_from_slice(chunk);
            let u = session.drain()?;
            if u.is_empty() {
                continue;
            }
            let x: Vec<[f64; 2]> = x_fifo.drain(..u.len()).collect();
            let y = pa.run(&u);
            session.adapt_feedback(&x, &u, &y)?;
        }
        session.adapt_barrier()?;
        let s = session.adapt_stats().expect("adaptive session");
        t.row(&[
            format!("{round}"),
            format!("{:.2}", pa.trajectory().fraction_at(pa.clock())),
            s.refreshes.to_string(),
            f1(s.recent_nmse_db),
            s.window_acpr_dbc.map(f1).unwrap_or_else(|| "-".into()),
            s.refresh_acpr_gain_db().map(f1).unwrap_or_else(|| "-".into()),
        ]);
    }
    let out = session.finish()?;
    println!("{}", t.render());
    println!(
        "stream: {} samples at {:.2} MSps engine throughput",
        out.stats.samples_out,
        out.stats.engine_msps()
    );
    service.shutdown()
}

/// `loadgen`: the fleet saturation sweep. Hermetic by construction —
/// every session runs a synthetic-weight engine, so no artifact tree
/// is needed and the CI smoke (`--quick`, or `BENCH_QUICK=1` like the
/// micro benches) exercises the exact deployment code path: sharded
/// [`Fleet`](dpd_ne::coordinator::Fleet), admission caps, churn,
/// per-push latency histograms, and the `BENCH_load.json` artifact.
fn cmd_loadgen(flags: &HashMap<String, String>) -> Result<()> {
    use dpd_ne::coordinator::loadgen::{self, ArrivalKind, LoadgenConfig};
    use dpd_ne::coordinator::ShardPolicy;

    let quick = flags.contains_key("quick") || dpd_ne::bench::quick_mode();
    let mut cfg = if quick { LoadgenConfig::quick() } else { LoadgenConfig::full() };
    if let Some(v) = flags.get("shards") {
        cfg.shards = v.parse()?;
    }
    if let Some(v) = flags.get("workers") {
        cfg.workers_per_shard = v.parse()?;
    }
    if let Some(v) = flags.get("sessions") {
        cfg.max_sessions = v.parse()?;
    }
    if let Some(v) = flags.get("samples") {
        cfg.samples_per_session = v.parse()?;
    }
    if let Some(v) = flags.get("chunk") {
        cfg.chunk = v.parse()?;
    }
    if let Some(v) = flags.get("frame") {
        cfg.frame_len = v.parse()?;
    }
    if let Some(v) = flags.get("lives") {
        cfg.lives = v.parse()?;
    }
    if let Some(v) = flags.get("batch") {
        cfg.batch = v.parse()?;
    }
    if let Some(v) = flags.get("adaptive-every") {
        cfg.adaptive_every = v.parse()?;
    }
    if let Some(v) = flags.get("seed") {
        cfg.seed = v.parse()?;
    }
    if let Some(v) = flags.get("policy") {
        cfg.policy = match v.as_str() {
            "rr" | "round-robin" => ShardPolicy::RoundRobin,
            "least" | "least-loaded" => ShardPolicy::LeastLoaded,
            "sticky" => ShardPolicy::StickyByClass,
            other => bail!("unknown --policy '{other}' (rr|least|sticky)"),
        };
    }
    if let Some(v) = flags.get("arrival") {
        cfg.arrival = match v.as_str() {
            "poisson" => ArrivalKind::Poisson,
            "bursty" => ArrivalKind::Bursty,
            other => bail!("unknown --arrival '{other}' (poisson|bursty)"),
        };
    }

    println!(
        "loadgen{}: sweeping 1..={} sessions on {} shard(s) x {} worker(s), \
         {} arrivals, {:?} placement, adaptive every {}",
        if quick { " (quick)" } else { "" },
        cfg.max_sessions,
        cfg.shards,
        cfg.workers_per_shard,
        cfg.arrival,
        cfg.policy,
        cfg.adaptive_every,
    );
    let report = loadgen::run(&cfg)?;

    let mut t = Table::new(
        "Fleet load sweep (open-loop arrivals, churned heterogeneous sessions)",
        &["sessions", "MSps", "p50 (us)", "p90 (us)", "p99 (us)", "opened", "rejected"],
    );
    for l in &report.levels {
        t.row(&[
            l.sessions.to_string(),
            f2(l.msps),
            f1(l.latency.p50().as_secs_f64() * 1e6),
            f1(l.latency.p90().as_secs_f64() * 1e6),
            f1(l.latency.p99().as_secs_f64() * 1e6),
            l.opened.to_string(),
            l.rejected.to_string(),
        ]);
    }
    println!("{}", t.render());
    match report.knee_sessions {
        Some(n) => println!(
            "saturation knee at {n} sessions; peak {:.2} MSps at {} sessions",
            report.saturation.1, report.saturation.0
        ),
        None => println!(
            "no knee inside the sweep (peak {:.2} MSps at {} sessions) — raise --sessions",
            report.saturation.1, report.saturation.0
        ),
    }
    let path = loadgen::write_json(&cfg, &report, quick)?;
    println!("wrote {}", path.display());
    Ok(())
}

/// `rollout`: hermetic canary-rollout demo. Builds a content-addressed
/// weight store (base generation + a candidate child), opens a fleet of
/// adaptive sessions against the GAN-like PA model, and runs the
/// [`RolloutController`](dpd_ne::coordinator::RolloutController): the
/// candidate deploys to one canary shard, the per-session post-refresh
/// ACPR meters judge it against `--budget-db`, and it is promoted
/// fleet-wide or rolled back to its parent. `--inject-bad` wrecks the
/// candidate's output head so the canary visibly catches it and the
/// rollback path runs. No artifact tree needed.
fn cmd_rollout(flags: &HashMap<String, String>) -> Result<()> {
    use dpd_ne::coordinator::{
        Fleet, FleetConfig, FleetSession, RolloutConfig, RolloutController, RolloutOutcome,
    };
    use dpd_ne::dpd::adapt::identity_init;
    use dpd_ne::runtime::store::{format_hash, GenMeta, WeightStore};
    use dpd_ne::util::Rng;

    let shards: usize = flags.get("shards").map(|s| s.parse()).transpose()?.unwrap_or(3);
    let per_shard: usize = flags.get("sessions").map(|s| s.parse()).transpose()?.unwrap_or(2);
    let budget_db: f64 = flags.get("budget-db").map(|s| s.parse()).transpose()?.unwrap_or(1.0);
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(42);
    let inject_bad = flags.contains_key("inject-bad");

    // lineage: base generation -> candidate child, content-addressed
    let w0 = identity_init(seed, 10, 0.15);
    let mut store = WeightStore::new();
    let gen0 = store.publish_float(&w0, GenMeta::default())?;
    let mut w1 = w0.clone();
    let mut rng = Rng::new(seed ^ 0x9e37_79b9);
    if inject_bad {
        // wreck the output head — the canary meter must catch this
        for v in w1.w_fc.iter_mut() {
            *v += rng.range(-1.5, 1.5);
        }
    } else {
        // a realistic adaptation step: a few words nudged below the
        // Q2.10 code step, so the deployed engines stay bit-identical
        for _ in 0..8 {
            let i = rng.below(w1.w_hh.len() as u64) as usize;
            w1.w_hh[i] += rng.range(-1e-4, 1e-4);
        }
    }
    let cand = store.publish_float(&w1, GenMeta { adapt_steps: 8, ..Default::default() })?;

    let fleet = Fleet::start(FleetConfig {
        shards,
        service: ServiceConfig { workers: 1, frame_len: 64, ..Default::default() },
        ..Default::default()
    })?;
    let acfg = SessionAdaptConfig {
        // the controller owns the deployment cadence: the trainer must
        // never self-refresh over it
        refresh_interval: u64::MAX,
        meter_window: 512,
        meter_nfft: 256,
        ..Default::default()
    };
    let mut sessions: Vec<FleetSession> = Vec::new();
    for _ in 0..shards * per_shard {
        sessions.push(fleet.open_adaptive_session(
            SessionConfig { engine: EngineKind::fixed(), adapt: Some(acfg), ..Default::default() },
            w0.clone(),
        )?);
    }
    println!(
        "rollout: {} shard(s) x {} session(s), base {}, candidate {}{}, budget {budget_db} dB",
        shards,
        per_shard,
        format_hash(gen0),
        format_hash(cand),
        if inject_bad { " (injected-bad)" } else { "" },
    );

    // pump: one band-limited chunk + PA feedback per session per round
    // (ACPR needs an in-band signal; white noise has no adjacent
    // channel to regrow into)
    let sig = test_signal(flags)?;
    let pa = RappMemPa::new(PaSpec::ganlike());
    const CHUNK: usize = 512;
    let mut cursors = vec![0usize; sessions.len()];
    let controller = RolloutController::new(RolloutConfig {
        acpr_budget_db: budget_db,
        ..Default::default()
    });
    let report = controller.run(&store, cand, &mut sessions, |sessions| {
        for (k, s) in sessions.iter_mut().enumerate() {
            let x: Vec<[f64; 2]> =
                (0..CHUNK).map(|j| sig.iq[(cursors[k] + j) % sig.iq.len()]).collect();
            cursors[k] = (cursors[k] + CHUNK) % sig.iq.len();
            s.push(&x)?;
            let mut u = Vec::with_capacity(CHUNK);
            while u.len() < CHUNK {
                u.extend(s.drain()?);
            }
            let y = pa.run(&u);
            s.adapt_feedback(&x, &u, &y)?;
            s.adapt_barrier()?;
        }
        Ok(())
    })?;

    let mut t = Table::new(
        "Canary rollout (per-session post-deploy linearization)",
        &["session", "shard", "role", "window ACPR (dBc)", "last deploy ΔACPR (dB)"],
    );
    for (k, s) in sessions.iter().enumerate() {
        let a = s.stats().adapt.unwrap_or_default();
        t.row(&[
            format!("{k}"),
            s.shard().to_string(),
            if s.shard() == report.plan.canary_shard { "canary".into() } else { "fleet".into() },
            a.window_acpr_dbc.map(f1).unwrap_or_else(|| "-".into()),
            a.refresh_acpr_gain_db().map(|g| f1(-g)).unwrap_or_else(|| "-".into()),
        ]);
    }
    println!("{}", t.render());
    match report.outcome {
        RolloutOutcome::Promoted => println!(
            "PROMOTED: candidate {} on all {} session(s); worst canary regression {} dB \
             (budget {budget_db})",
            format_hash(cand),
            report.deployed_sessions,
            f2(report.verdict.worst_regression_db),
        ),
        RolloutOutcome::RolledBack => println!(
            "ROLLED BACK to parent {}: worst canary regression {} dB exceeded the \
             {budget_db} dB budget; {} canary session(s) restored, other shards never \
             saw the candidate",
            format_hash(report.plan.parent),
            f2(report.verdict.worst_regression_db),
            report.verdict.sessions,
        ),
    }
    if let Some(ds) = store.delta_stats(cand) {
        println!(
            "store: {} generation(s); candidate delta-encodes {}/{} words \
             ({:.2}% touched)",
            store.len(),
            ds.changed_words,
            ds.total_words,
            100.0 * ds.touched_fraction(),
        );
    }
    drop(sessions);
    fleet.drain()?;
    Ok(())
}

fn cmd_asic_report(flags: &HashMap<String, String>) -> Result<()> {
    let m = Manifest::discover(artifacts(flags).as_deref())?;
    let w = QGruWeights::load_params_int(&m.weights_main, QSpec::new(m.qspec_bits)?)?;
    let s = dpd_ne::accel::AsicSpec::nominal(&w, true);
    let mut t = Table::new(
        "ASIC spec (paper Fig. 5: 2 GHz, 0.9 V, 250 MSps, 7.5 ns, 256.5 GOPS, 195 mW, 0.2 mm², 6.58 TOPS/W/mm²)",
        &["metric", "model", "paper"],
    );
    t.row(&["f_clk (GHz)".into(), f2(s.f_clk_ghz), "2.0".into()]);
    t.row(&["f_s,I/Q (MSps)".into(), f1(s.fs_msps), "250".into()]);
    t.row(&["OP/sample".into(), s.ops_per_sample.to_string(), "1026".into()]);
    t.row(&["latency (ns)".into(), f2(s.latency_ns), "7.5".into()]);
    t.row(&["throughput (GOPS)".into(), f1(s.throughput_gops), "256.5".into()]);
    t.row(&["power (mW)".into(), f1(s.power.total_mw()), "195".into()]);
    t.row(&["area (mm²)".into(), f3(s.area.total_mm2()), "0.2".into()]);
    t.row(&["GOPS/W".into(), f1(s.power_efficiency_gops_w()), "1315.4".into()]);
    t.row(&["GOPS/mm²".into(), f1(s.area_efficiency_gops_mm2()), "1282.5".into()]);
    t.row(&["PAE (TOPS/W/mm²)".into(), f2(s.pae_tops_w_mm2()), "6.58".into()]);
    println!("{}", t.render());
    Ok(())
}

fn cmd_fpga_report() -> Result<()> {
    use dpd_ne::accel::fpga::{FpgaAct, FpgaCostModel, ZYNQ_7020};
    let model = FpgaCostModel::default();
    let mut t = Table::new(
        "Zynq-7020 utilization (paper Table I)",
        &["variant", "LUT", "FF", "DSP", "BRAM"],
    );
    for (label, act) in [("LUT-Sig./Tanh", FpgaAct::LutTables), ("Hard-Sig./Tanh", FpgaAct::Hard)] {
        let (u, _) = model.estimate(act);
        let (lp, fp, dp, _) = u.pct(&ZYNQ_7020);
        t.row(&[
            label.into(),
            format!("{} ({:.1}%)", u.lut, lp),
            format!("{} ({:.1}%)", u.ff, fp),
            format!("{} ({:.1}%)", u.dsp, dp),
            u.bram.to_string(),
        ]);
    }
    println!("{}", t.render());

    let (sig_red, tanh_red) = model.reduction_factors();
    println!("Fig. 4 reductions: sigmoid {sig_red:.1}x, tanh {tanh_red:.1}x (paper: 18.9x / 35.3x)");
    Ok(())
}

fn cmd_sweep(flags: &HashMap<String, String>) -> Result<()> {
    let m = Manifest::discover(artifacts(flags).as_deref())?;
    let pa = RappMemPa::new(PaSpec::load(&m.pa_model)?);
    let g = pa.spec.target_gain();
    let sig = test_signal(flags)?;
    let mut t = Table::new(
        "Fig. 3: linearization vs precision x activation",
        &["bits", "act", "ACPR (dBc)", "EVM (dB)"],
    );
    let mut sweep = m.sweep.clone();
    sweep.sort_by_key(|(name, _)| {
        let bits: u32 = name[1..name.find('_').unwrap_or(1)].parse().unwrap_or(0);
        (bits, name.clone())
    });
    for (_name, path) in &sweep {
        let fw = GruWeights::load(path)?;
        let bits = fw.meta_bits.context("missing bits meta")?;
        let act_name = fw.meta_act.clone().unwrap_or_default();
        let spec = QSpec::new(bits)?;
        let qw = fw.quantize(spec)?;
        let act = if act_name == "hard" {
            ActKind::Hard
        } else {
            ActKind::Lut(LutTables::default_for(spec))
        };
        let mut dpd = QGruDpd::new(qw, act);
        let z = dpd.run(&sig.iq);
        let y = pa.run(&z);
        let a = acpr_db(&y, &AcprConfig::default())?;
        let e = evm_db_nmse(&y, &sig.iq, g);
        t.row(&[bits.to_string(), act_name, f1(a.acpr_dbc), f1(e)]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_info(flags: &HashMap<String, String>) -> Result<()> {
    let m = Manifest::discover(artifacts(flags).as_deref())?;
    println!("artifact tree: {}", m.root.display());
    println!("model: hidden={} features={} params={}", m.hidden, m.features, m.n_params);
    println!("qspec: {} bits", m.qspec_bits);
    println!("hlo executables:");
    for e in &m.hlo {
        println!("  {} kind={} act={} shape=({},{},2)", e.file, e.kind, e.act, e.batch, e.time);
    }
    println!("sweep configs: {}", m.sweep.len());
    println!("golden vectors: {}", m.golden.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_flags_accepts_known_flags_and_values() {
        let (pos, flags) =
            parse_flags(&argv(&["--engine", "delta:32+simd", "--seed", "7", "extra"]), &[
                "engine", "seed",
            ])
            .unwrap();
        assert_eq!(flags.get("engine").unwrap(), "delta:32+simd");
        assert_eq!(flags.get("seed").unwrap(), "7");
        assert_eq!(pos, vec!["extra".to_string()]);
    }

    #[test]
    fn parse_flags_keeps_the_bare_flag_heuristic() {
        // `--adapt` followed by another flag stays bare
        let (_, flags) =
            parse_flags(&argv(&["--adapt", "--engine", "fixed"]), &["adapt", "engine"]).unwrap();
        assert_eq!(flags.get("adapt").unwrap(), "");
        assert_eq!(flags.get("engine").unwrap(), "fixed");
        // trailing bare flag
        let (_, flags) = parse_flags(&argv(&["--quick"]), &["quick"]).unwrap();
        assert_eq!(flags.get("quick").unwrap(), "");
    }

    #[test]
    fn parse_flags_rejects_unknown_flags_naming_the_offender() {
        let err = parse_flags(&argv(&["--refreshinterval", "100"]), &["refresh-interval"])
            .unwrap_err()
            .to_string();
        assert!(err.contains("--refreshinterval"), "must name the offending flag: {err}");
        assert!(err.contains("usage:"), "must include the usage text: {err}");
        // the value of a rejected flag must not leak into positionals
        let err = parse_flags(&argv(&["--bogus"]), &[]).unwrap_err().to_string();
        assert!(err.contains("--bogus"));
    }

    #[test]
    fn every_dispatched_command_has_an_allowlist() {
        for cmd in [
            "run",
            "serve",
            "stream",
            "loadgen",
            "rollout",
            "asic-report",
            "fpga-report",
            "sweep",
            "info",
        ] {
            assert!(allowed_flags(cmd).is_some(), "no allowlist for {cmd}");
        }
        assert!(allowed_flags("bogus").is_none());
    }

    #[test]
    fn rollout_allowlist_covers_every_flag_cmd_rollout_reads() {
        let allowed = allowed_flags("rollout").unwrap();
        for f in ["shards", "sessions", "budget-db", "inject-bad", "seed", "symbols"] {
            assert!(allowed.contains(&f), "rollout must allow --{f}");
        }
        // rollout is hermetic: no artifact tree, no engine spec
        assert!(!allowed.contains(&"artifacts"));
        assert!(!allowed.contains(&"engine"));
    }

    #[test]
    fn serve_allowlist_covers_every_flag_cmd_serve_reads() {
        let allowed = allowed_flags("serve").unwrap();
        for f in [
            "engine",
            "shadow",
            "sessions",
            "workers",
            "rounds",
            "batch",
            "adapt",
            "drift-ramp",
            "refresh-interval",
            "symbols",
            "seed",
            "artifacts",
            "delta-theta",
        ] {
            assert!(allowed.contains(&f), "serve must allow --{f}");
        }
    }

    #[test]
    fn loadgen_allowlist_covers_every_flag_cmd_loadgen_reads() {
        let allowed = allowed_flags("loadgen").unwrap();
        for f in [
            "quick",
            "shards",
            "workers",
            "sessions",
            "samples",
            "chunk",
            "frame",
            "lives",
            "batch",
            "adaptive-every",
            "policy",
            "arrival",
            "seed",
        ] {
            assert!(allowed.contains(&f), "loadgen must allow --{f}");
        }
    }

    #[test]
    fn usage_names_every_command() {
        let u = usage();
        for cmd in
            ["run", "serve", "stream", "loadgen", "rollout", "asic-report", "fpga-report", "sweep"]
        {
            assert!(u.contains(cmd), "usage must mention {cmd}");
        }
    }
}
