#!/usr/bin/env python3
"""Golden generator for the manifest-v2 weight store
(`rust/src/runtime/store.rs`).

Mirrors, byte for byte, the Rust side's canonical serialization of a
content-addressed weight store:

* the canonical JSON writer of `rust/src/util/json.rs` (sorted keys,
  no whitespace, pinned number spellings: integral f64 below 2^53 as
  plain integers, everything else in Rust's `{:e}` shortest
  scientific),
* the `fnv1a_words` content hash (the crate's historical multiplier
  0x1000000001b3 — NOT the canonical FNV-64 prime) behind
  `GruWeights::fingerprint` ("gru-f64") and `QGruWeights::fingerprint`
  ("qgru"),
* the store wire format: generation records with lineage + trainer
  metadata, full blobs for lineage roots / kind changes, and
  `(tensor, index, word)` delta triples between compatible adjacent
  generations.

The emitted document (`rust/tests/data/golden_store.json`) pins a
5-generation lineage built from Rng-exact perturbations that
`rust/tests/rollout.rs` rebuilds independently; Rust's
`WeightStore::to_json_string() + "\\n"` must equal this file's bytes.

Also measures, for EXPERIMENTS.md, the touched-fraction of a real
`AdaptTrainer` refresh (float words vs Q2.10 codes) — the numbers
behind the store's delta-encoding design note.

Run from anywhere: `python3 python/tools/gen_golden_store.py`.
"""

import decimal
import math
import os
import pathlib
import struct
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import gen_golden_ofdm as G  # noqa: E402  (rust twins: Rng, quantize, trainer)

MASK = (1 << 64) - 1
TENSOR_ORDER = ["w_ih", "b_ih", "w_hh", "b_hh", "w_fc", "b_fc"]
STORE_VERSION = "dpd-weight-store-v2"

# --- pinned lineage parameters (rust/tests/rollout.rs mirrors these) ------
INIT_SEED = 7
HIDDEN = 10
GATE_BOUND = 0.15
PERTURB_SEED = 0x5705
G1_TOUCHES = 12  # w_hh (300 words), dv in +-0.05
G2_TOUCHES = 5  # w_ih (120 words), dv in +-0.02
G4_TOUCHES = 7  # w_hh codes, +-1


# --- rust/src/util/mod.rs::fnv1a_words twin -------------------------------


def fnv1a_words(tag: str, words) -> int:
    p = 0x1000000001B3
    h = 0xCBF29CE484222325
    for b in tag.encode():
        h = ((h ^ b) * p) & MASK
    for w in words:
        v = w & MASK
        for _ in range(8):
            h = ((h ^ (v & 0xFF)) * p) & MASK
            v >>= 8
    return h


def f64_bits(v: float) -> int:
    return struct.unpack("<Q", struct.pack("<d", v))[0]


def fp_float(w: dict) -> int:
    words = [w["hidden"], w["features"]]
    for t in TENSOR_ORDER:
        words.extend(f64_bits(v) for v in w[t])
    return fnv1a_words("gru-f64", words)


def fp_quant(q: dict) -> int:
    words = [q["bits"], q["hidden"], q["features"]]
    for t in TENSOR_ORDER:
        words.extend(v & 0xFFFFFFFF for v in q[t])
    return fnv1a_words("qgru", words)


# --- rust/src/util/json.rs canonical writer twin --------------------------


def canon_num(v) -> str:
    """`write_canonical_num` twin: integral |v| < 2^53 (except -0.0)
    prints as an integer, everything else as Rust `{:e}` shortest
    scientific (mantissa `d[.ddd]`, bare exponent)."""
    if isinstance(v, int):
        return str(v)
    if not math.isfinite(v):
        raise ValueError(f"non-finite {v} has no canonical spelling")
    if v.is_integer() and abs(v) < 2.0**53 and not (v == 0.0 and math.copysign(1.0, v) < 0):
        return str(int(v))
    if v == 0.0:  # only -0.0 reaches here
        return "-0e0"
    # repr() is the shortest round-tripping decimal — the same digits
    # Rust's {:e} prints; reshape them into d.ddd e<exp> form.
    sign, digits, exp = decimal.Decimal(repr(v)).normalize().as_tuple()
    e = exp + len(digits) - 1
    mant = str(digits[0])
    if len(digits) > 1:
        mant += "." + "".join(map(str, digits[1:]))
    return ("-" if sign else "") + mant + "e" + str(e)


def escape(s: str) -> str:
    out = ['"']
    for c in s:
        if c == '"':
            out.append('\\"')
        elif c == "\\":
            out.append("\\\\")
        elif c == "\n":
            out.append("\\n")
        elif c == "\r":
            out.append("\\r")
        elif c == "\t":
            out.append("\\t")
        elif ord(c) < 0x20:
            out.append("\\u%04x" % ord(c))
        else:
            out.append(c)
    out.append('"')
    return "".join(out)


def dump(v) -> str:
    if v is None:
        return "null"
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, (int, float)):
        return canon_num(v)
    if isinstance(v, str):
        return escape(v)
    if isinstance(v, (list, tuple)):
        return "[" + ",".join(dump(x) for x in v) + "]"
    if isinstance(v, dict):
        return "{" + ",".join(escape(k) + ":" + dump(v[k]) for k in sorted(v)) + "}"
    raise TypeError(f"cannot dump {type(v)}")


# --- rust/src/runtime/store.rs wire-format twin ---------------------------


def format_hash(h: int) -> str:
    return "fnv1a64:%016x" % h


def delta_words(parent: dict, child: dict):
    """`store::delta_words` twin: None when the pair cannot
    delta-encode, else (tensor, index, word) triples in TENSOR_ORDER
    then ascending index. Float words compare by bit pattern."""
    if parent["kind"] != child["kind"]:
        return None
    ps, cs = parent["set"], child["set"]
    if (ps["hidden"], ps["features"]) != (cs["hidden"], cs["features"]):
        return None
    if parent["kind"] == "qgru" and ps["bits"] != cs["bits"]:
        return None
    is_float = parent["kind"] == "gru-f64"
    out = []
    for t in TENSOR_ORDER:
        for i, (pv, cv) in enumerate(zip(ps[t], cs[t])):
            if (f64_bits(pv) != f64_bits(cv)) if is_float else (pv != cv):
                out.append([t, i, cv])
    return out


def encode_blob(gen: dict, parent: dict):
    if parent is not None:
        changed = delta_words(parent, gen)
        if changed is not None:
            return {"delta": {"changed": changed}}
    s = gen["set"]
    payload = {"hidden": s["hidden"], "features": s["features"]}
    for t in TENSOR_ORDER:
        payload[t] = list(s[t])
    return {"full": payload}


def encode_store(gens: list) -> str:
    by_hash = {g["hash"]: g for g in gens}
    doc_gens = []
    for g in gens:
        parent = by_hash[g["parent"]] if g["parent"] is not None else None
        doc_gens.append(
            {
                "blob": encode_blob(g, parent),
                "hash": format_hash(g["hash"]),
                "kind": g["kind"],
                "meta": g["meta"],
                "parent": None if g["parent"] is None else format_hash(g["parent"]),
                "seq": g["seq"],
            }
        )
    doc = {
        "generations": doc_gens,
        "head": format_hash(gens[-1]["hash"]) if gens else None,
        "version": STORE_VERSION,
    }
    return dump(doc)


def publish(gens: list, kind: str, wset: dict, meta: dict) -> int:
    h = fp_float(wset) if kind == "gru-f64" else fp_quant(wset)
    assert h not in {g["hash"] for g in gens}, "duplicate generation"
    gens.append(
        {
            "hash": h,
            "parent": gens[-1]["hash"] if gens else None,
            "seq": len(gens),
            "kind": kind,
            "set": wset,
            "meta": meta,
        }
    )
    return h


def meta(samples: int, steps: int, nmse_db: float, theta: int = 0) -> dict:
    return {
        "adapt_samples": samples,
        "adapt_steps": steps,
        "nmse_db": nmse_db,
        "rho": 0,
        "spec_bits": 12,
        "theta": theta,
    }


# --- lineage construction (Rng-exact; the rust test re-derives this) ------


def clone_w(w: dict) -> dict:
    return {k: (list(v) if isinstance(v, list) else v) for k, v in w.items()}


def quantize_weights(w: dict) -> dict:
    q = {"hidden": w["hidden"], "features": w["features"], "bits": 12}
    for t in TENSOR_ORDER:
        q[t] = [G.quantize(v) for v in w[t]]
    return q


def build_lineage():
    w0 = G.identity_init(INIT_SEED, HIDDEN, GATE_BOUND)
    rng = G.Rng(PERTURB_SEED)

    w1 = clone_w(w0)
    for _ in range(G1_TOUCHES):
        i = rng.below(3 * HIDDEN * HIDDEN)
        w1["w_hh"][i] += rng.range(-0.05, 0.05)

    w2 = clone_w(w1)
    for _ in range(G2_TOUCHES):
        i = rng.below(3 * HIDDEN * 4)
        w2["w_ih"][i] += rng.range(-0.02, 0.02)

    q3 = quantize_weights(w2)

    q4 = clone_w(q3)
    for _ in range(G4_TOUCHES):
        i = rng.below(3 * HIDDEN * HIDDEN)
        q4["w_hh"][i] += 1 if rng.below(2) == 0 else -1

    gens = []
    publish(gens, "gru-f64", w0, meta(0, 0, 0.0))
    publish(gens, "gru-f64", w1, meta(4096, 128, -27.5))
    publish(gens, "gru-f64", w2, meta(8192, 256, -31.25))
    publish(gens, "qgru", q3, meta(8192, 256, -31.25))
    publish(gens, "qgru", q4, meta(8192, 256, -31.25, theta=8))
    return gens


# --- self-validation: decode own document, recompute every hash -----------


def decode_and_verify(text: str, gens: list) -> None:
    import json as stdjson

    doc = stdjson.loads(text)
    assert doc["version"] == STORE_VERSION
    decoded = {}
    order = []
    for i, g in enumerate(doc["generations"]):
        assert g["seq"] == i, "records must be dense"
        if "full" in g["blob"]:
            s = dict(g["blob"]["full"])
            if g["kind"] == "qgru":
                s["bits"] = g["meta"]["spec_bits"]
        else:
            parent = decoded[g["parent"]]
            s = {k: (list(v) if isinstance(v, list) else v) for k, v in parent.items()}
            for t, idx, v in g["blob"]["delta"]["changed"]:
                s[t][idx] = v
        got = fp_float(s) if g["kind"] == "gru-f64" else fp_quant(s)
        assert format_hash(got) == g["hash"], f"generation #{i} hash mismatch"
        decoded[g["hash"]] = s
        order.append(g["hash"])
    assert doc["head"] == order[-1]
    assert [format_hash(g["hash"]) for g in gens] == order
    # the delta shape itself is part of the pinned contract
    shapes = ["full" if "full" in g["blob"] else "delta" for g in doc["generations"]]
    assert shapes == ["full", "delta", "delta", "full", "delta"], shapes


# --- EXPERIMENTS.md provenance: trainer-refresh touched fraction ----------


def measure_touched_fraction() -> None:
    import numpy as np

    wave = [(float(a), float(b)) for a, b in G.make_adapt_waveform()]
    tr = G.AdaptTrainer(G.identity_init(2026, 10, 0.15))

    def run(samples):
        u = G.gru_run_f64(tr.w, samples)
        y = G.pa_run(np.array([complex(a, b) for a, b in u]))
        tr.observe(u, [(float(c.real), float(c.imag)) for c in y])

    def report(label, before):
        total = sum(len(before[t]) for t in TENSOR_ORDER)
        f_changed = sum(
            1
            for t in TENSOR_ORDER
            for a, b in zip(before[t], tr.w[t])
            if f64_bits(a) != f64_bits(b)
        )
        q_changed = sum(
            1
            for t in TENSOR_ORDER
            for a, b in zip(before[t], tr.w[t])
            if G.quantize(a) != G.quantize(b)
        )
        print(f"  {label}:")
        print(f"    float words touched: {f_changed}/{total} ({100.0 * f_changed / total:.1f}%)")
        print(f"    Q2.10 codes touched: {q_changed}/{total} ({100.0 * q_changed / total:.1f}%)")

    nwin = len(wave) // 32
    print(f"trainer-refresh touched fraction ({len(wave)} samples = {nwin} Adam windows/pass):")
    run(wave)
    run(wave)
    before = clone_w(tr.w)
    run(wave)
    report("early lineage, full-pass cadence (pass 3 vs 2)", before)
    for _ in range(3):
        run(wave)
    before = clone_w(tr.w)
    run(wave)
    report("late lineage, full-pass cadence (pass 7 vs 6)", before)
    before = clone_w(tr.w)
    run(wave[:32])
    report("late lineage, single-window refresh (32 samples, 1 Adam step)", before)


def main() -> None:
    root = pathlib.Path(__file__).resolve().parents[2]
    out_path = root / "rust" / "tests" / "data" / "golden_store.json"

    gens = build_lineage()
    text = encode_store(gens)
    decode_and_verify(text, gens)
    assert encode_store(gens) == text, "re-encode must be byte-identical"

    out_path.write_text(text + "\n")
    print(f"wrote {out_path} ({out_path.stat().st_size} bytes)")
    for g in gens:
        blob = "full"
        if g["parent"] is not None:
            parent = next(p for p in gens if p["hash"] == g["parent"])
            d = delta_words(parent, g)
            if d is not None:
                n = sum(len(g["set"][t]) for t in TENSOR_ORDER)
                blob = f"delta {len(d)}/{n} words"
        print(f"  gen{g['seq']} {g['kind']:7s} {format_hash(g['hash'])} [{blob}]")

    measure_touched_fraction()


if __name__ == "__main__":
    main()
