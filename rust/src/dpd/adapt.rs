//! Closed-loop adaptation: the indirect-learning-architecture (ILA)
//! trainer that keeps the f64 GRU twin tracking a drifting amplifier.
//!
//! The deployment loop (OpenDPDv2's argument made runnable, under the
//! weight-refresh assumption DeltaDPD bakes in):
//!
//! ```text
//!   x ──► DPD (deployed QGruDpd) ──► u ──► PA ──► y
//!                 ▲                  │           │
//!                 │ re-quantize      └─►(u, y)──►│ feedback
//!           AdaptTrainer (f64 twin) ◄────────────┘
//! ```
//!
//! The trainer learns the PA *postinverse*: feed the normalized
//! feedback `v = y / (backoff · ĝ)` through the float GRU and regress
//! its output onto the actual PA input `u` (squared error, tracked as
//! NMSE) — at the ILA fixed point the deployed chain linearizes to
//! gain `backoff · ĝ`, i.e. `backoff` is genuine peak headroom. At
//! the fixed point the postinverse equals the predistorter
//! (the classic ILA identity), so a snapshot of the adapted float
//! weights — re-quantized through the canonical round-half-up bridge
//! ([`GruWeights::quantize`], bit-identical to the Python oracle) — is
//! a fresh deployable integer weight set. The complex reference gain
//! `ĝ` is estimated online (per-window least squares, EMA-smoothed):
//! a drifting amplifier's gain moves, and regressing against a stale
//! fixed gain would drive the DPD into saturation chasing an
//! infeasible target (measured: recovery fails without it).
//!
//! Training is streamed: `observe(u, y)` buffers feedback pairs and
//! runs one truncated-BPTT window (length [`AdaptConfig::window`])
//! plus one Adam step per full window, carrying the GRU hidden state
//! across windows. Everything is plain f64 — this is the *float twin*
//! path; the deployed integer engines never train.
//!
//! Weight generations: every snapshot carries a fresh content
//! fingerprint ([`QGruWeights::fingerprint`]), so the coalescing batch
//! scheduler can never group sessions running different weight
//! generations — refreshed and stale engines are distinct batch
//! classes by construction (pinned in `tests/adapt.rs`).

use anyhow::{ensure, Result};

use super::gru::{hardsigmoid, hardtanh, GruDpd};
use super::weights::{GruWeights, NonFiniteWeightError, QGruWeights, SparseQGruWeights};
use crate::fixed::QSpec;
use crate::util::C64;

/// EMA coefficient of the per-window NMSE tracked by
/// [`AdaptTrainer::recent_nmse_db`] (~ the last 20 windows dominate).
const RECENT_NMSE_EMA: f64 = 0.05;

/// Trainer hyperparameters. The defaults are the measured operating
/// point of the adaptation tests and the `serve --adapt` demo
/// (validated on the golden adapt waveform: ~13 dB ACPR improvement
/// from scratch — reaching the paper's −45.3 dBc — and ~9 dB
/// re-convergence after the reference drift).
#[derive(Clone, Copy, Debug)]
pub struct AdaptConfig {
    /// Adam learning rate
    pub lr: f64,
    /// BPTT truncation window (samples per optimizer step)
    pub window: usize,
    /// target linearization gain as a fraction of the estimated PA
    /// gain (peak headroom, like `PaSpec::target_backoff`)
    pub backoff: f64,
    /// EMA coefficient of the per-window least-squares gain estimate
    pub gain_ema: f64,
    /// Adam first-moment decay
    pub beta1: f64,
    /// Adam second-moment decay
    pub beta2: f64,
    /// Adam denominator epsilon
    pub eps: f64,
}

impl Default for AdaptConfig {
    fn default() -> Self {
        AdaptConfig {
            lr: 3e-3,
            window: 32,
            backoff: 0.95,
            gain_ema: 0.1,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }
}

/// Per-tensor buffers matching the [`GruWeights`] layout (gradients,
/// Adam moments).
#[derive(Clone, Debug)]
struct Tensors {
    w_ih: Vec<f64>,
    b_ih: Vec<f64>,
    w_hh: Vec<f64>,
    b_hh: Vec<f64>,
    w_fc: Vec<f64>,
    b_fc: Vec<f64>,
}

impl Tensors {
    fn zeros_like(w: &GruWeights) -> Tensors {
        Tensors {
            w_ih: vec![0.0; w.w_ih.len()],
            b_ih: vec![0.0; w.b_ih.len()],
            w_hh: vec![0.0; w.w_hh.len()],
            b_hh: vec![0.0; w.b_hh.len()],
            w_fc: vec![0.0; w.w_fc.len()],
            b_fc: vec![0.0; w.b_fc.len()],
        }
    }

    fn zero(&mut self) {
        for t in [
            &mut self.w_ih,
            &mut self.b_ih,
            &mut self.w_hh,
            &mut self.b_hh,
            &mut self.w_fc,
            &mut self.b_fc,
        ] {
            t.iter_mut().for_each(|v| *v = 0.0);
        }
    }
}

/// Live adaptation counters (what [`SessionStats`] surfaces).
///
/// [`SessionStats`]: crate::coordinator::SessionStats
#[derive(Clone, Copy, Debug, Default)]
pub struct AdaptProgress {
    /// feedback samples consumed by completed windows
    pub samples: u64,
    /// optimizer steps taken (completed BPTT windows)
    pub steps: u64,
    /// lifetime training NMSE in dB (postinverse error vs PA input,
    /// accumulated since the trainer started)
    pub nmse_db: f64,
    /// recent training NMSE in dB (EMA over per-window NMSE) — the
    /// convergence signal an operator should watch: the lifetime
    /// average stays dominated by the large from-scratch early error
    /// and barely moves on a drift event
    pub recent_nmse_db: f64,
    /// current complex gain estimate (None until the first window)
    pub gain_est: Option<[f64; 2]>,
}

/// The streamed ILA trainer over the f64 GRU twin (module docs).
pub struct AdaptTrainer {
    w: GruWeights,
    cfg: AdaptConfig,
    m: Tensors,
    v: Tensors,
    grads: Tensors,
    /// running beta powers for Adam bias correction (kept as products,
    /// not `powf`, so trajectories are exactly reproducible)
    b1_pow: f64,
    b2_pow: f64,
    steps: u64,
    /// carried hidden state across windows (truncated BPTT)
    h: Vec<f64>,
    g_est: Option<C64>,
    /// buffered partial window: (pa input u, pa output y)
    pend_u: Vec<[f64; 2]>,
    pend_y: Vec<[f64; 2]>,
    err_acc: f64,
    ref_acc: f64,
    /// EMA of the per-window error/reference power ratio (the recent
    /// convergence signal; coefficient [`RECENT_NMSE_EMA`])
    recent_ratio: Option<f64>,
    samples: u64,
    // per-window scratch (allocated once)
    hs: Vec<f64>,
    xs: Vec<f64>,
    gis: Vec<f64>,
    ghs: Vec<f64>,
    rs: Vec<f64>,
    zs: Vec<f64>,
    ns: Vec<f64>,
    es: Vec<f64>,
    dh: Vec<f64>,
    dgi_row: Vec<f64>,
    dgh_row: Vec<f64>,
}

impl AdaptTrainer {
    /// Start from an initial float twin. Any hidden size works; the
    /// feature preprocessor is the fixed 4-feature conditioning of the
    /// paper's model.
    pub fn new(w0: GruWeights, cfg: AdaptConfig) -> Result<AdaptTrainer> {
        ensure!(w0.features == 4, "AdaptTrainer needs the 4-feature conditioning");
        ensure!(cfg.window >= 2, "AdaptConfig.window must be >= 2");
        ensure!(cfg.lr > 0.0 && cfg.lr.is_finite(), "AdaptConfig.lr must be positive");
        ensure!((0.0..=1.0).contains(&cfg.gain_ema), "AdaptConfig.gain_ema in [0, 1]");
        ensure!(cfg.backoff > 0.0, "AdaptConfig.backoff must be positive");
        let hd = w0.hidden;
        let t = cfg.window;
        let m = Tensors::zeros_like(&w0);
        Ok(AdaptTrainer {
            v: m.clone(),
            grads: m.clone(),
            m,
            b1_pow: 1.0,
            b2_pow: 1.0,
            steps: 0,
            h: vec![0.0; hd],
            g_est: None,
            pend_u: Vec::new(),
            pend_y: Vec::new(),
            err_acc: 0.0,
            ref_acc: 0.0,
            recent_ratio: None,
            samples: 0,
            hs: vec![0.0; (t + 1) * hd],
            xs: vec![0.0; t * 4],
            gis: vec![0.0; t * 3 * hd],
            ghs: vec![0.0; t * 3 * hd],
            rs: vec![0.0; t * hd],
            zs: vec![0.0; t * hd],
            ns: vec![0.0; t * hd],
            es: vec![0.0; t * 2],
            dh: vec![0.0; hd],
            dgi_row: vec![0.0; 3 * hd],
            dgh_row: vec![0.0; 3 * hd],
            w: w0,
            cfg,
        })
    }

    /// The live float twin (the weights being adapted).
    pub fn weights(&self) -> &GruWeights {
        &self.w
    }

    pub fn config(&self) -> AdaptConfig {
        self.cfg
    }

    /// Lifetime training NMSE (postinverse output vs PA input) in dB,
    /// accumulated over every window since the trainer started.
    pub fn nmse_db(&self) -> f64 {
        if self.ref_acc == 0.0 {
            return 0.0;
        }
        10.0 * (self.err_acc / self.ref_acc).log10()
    }

    /// Recent training NMSE in dB: an EMA over per-window NMSE. This
    /// is the convergence signal to watch — the lifetime average stays
    /// dominated by the from-scratch early error and barely reacts to
    /// a drift event, while this one tracks the current fit.
    pub fn recent_nmse_db(&self) -> f64 {
        match self.recent_ratio {
            Some(r) if r > 0.0 => 10.0 * r.log10(),
            _ => self.nmse_db(),
        }
    }

    /// Current complex PA gain estimate.
    pub fn gain_est(&self) -> Option<C64> {
        self.g_est
    }

    /// Live counters snapshot.
    pub fn progress(&self) -> AdaptProgress {
        AdaptProgress {
            samples: self.samples,
            steps: self.steps,
            nmse_db: self.nmse_db(),
            recent_nmse_db: self.recent_nmse_db(),
            gain_est: self.g_est.map(|g| [g.re, g.im]),
        }
    }

    /// **The re-quantization bridge**: snapshot the adapted float twin
    /// into a fresh integer weight set through the canonical
    /// round-half-up quantizer — bit-identical to the Python oracle
    /// (`ref.quantize_params`), which the golden adapt vectors pin.
    /// Out-of-range weights saturate onto the code grid (part of the
    /// bridge's contract; the adaptation tests measure post-bridge
    /// linearization *including* that clamp). The returned set carries
    /// its own content fingerprint, i.e. a new weight *generation* the
    /// batch coalescer will never mix with the old one.
    ///
    /// A diverged twin (NaN/±inf weights) is rejected with a typed
    /// [`NonFiniteWeightError`] — NaN would otherwise quantize to code
    /// 0 and the hot-swap would silently deploy a zeroed engine.
    pub fn quantized(
        &self,
        spec: QSpec,
    ) -> std::result::Result<QGruWeights, NonFiniteWeightError> {
        self.w.quantize(spec)
    }

    /// The sparse / mixed-precision flavor of the bridge: prune +
    /// per-tensor quantize the float twin (see
    /// [`GruWeights::prune_quantize`]). Shares the non-finite screen
    /// with [`AdaptTrainer::quantized`].
    pub fn quantized_sparse(
        &self,
        profile: crate::fixed::QProfile,
        rho: u8,
    ) -> std::result::Result<SparseQGruWeights, NonFiniteWeightError> {
        self.w.prune_quantize(profile, rho)
    }

    /// Snapshot the float twin itself (e.g. to refresh a `NativeF64`
    /// session engine).
    pub fn snapshot(&self) -> GruWeights {
        self.w.clone()
    }

    /// Stream one feedback burst: `u` is what entered the amplifier
    /// (the deployed DPD's output), `y` what came back from the
    /// feedback receiver. Pairs are buffered and consumed in
    /// [`AdaptConfig::window`]-sized BPTT windows; a partial tail
    /// waits for the next burst.
    pub fn observe(&mut self, u: &[[f64; 2]], y: &[[f64; 2]]) -> Result<()> {
        ensure!(u.len() == y.len(), "feedback burst length mismatch: {} vs {}", u.len(), y.len());
        self.pend_u.extend_from_slice(u);
        self.pend_y.extend_from_slice(y);
        let t = self.cfg.window;
        let full = (self.pend_u.len() / t) * t;
        if full == 0 {
            return Ok(());
        }
        // take the buffers out for the duration of the windows (they
        // alias `self`), then slide the tail down in place and hand
        // the same allocations back — no per-burst reallocation
        let mut pu = std::mem::take(&mut self.pend_u);
        let mut py = std::mem::take(&mut self.pend_y);
        for s in (0..full).step_by(t) {
            self.train_window(&pu[s..s + t], &py[s..s + t]);
        }
        let rem = pu.len() - full;
        pu.copy_within(full.., 0);
        pu.truncate(rem);
        py.copy_within(full.., 0);
        py.truncate(rem);
        self.pend_u = pu;
        self.pend_y = py;
        Ok(())
    }

    /// One BPTT window + Adam step over `window` feedback pairs.
    fn train_window(&mut self, u: &[[f64; 2]], y: &[[f64; 2]]) {
        let t_len = u.len();
        // per-window least-squares complex gain y ~= g * u, EMA-smoothed
        let mut num = C64::ZERO;
        let mut den = 0.0;
        for (uu, yy) in u.iter().zip(y) {
            let cu = C64::new(uu[0], uu[1]);
            let cy = C64::new(yy[0], yy[1]);
            num = num + cy * cu.conj();
            den += cu.norm_sq();
        }
        // a window with (effectively) zero PA input carries no gain
        // information and no usable regression target — skip it
        // entirely, whether it's startup silence or a mid-stream idle
        // carrier. Training on it would drag the twin toward f(·)=0
        // and its steps could trigger a pointless engine hot-swap.
        if den <= 1e-30 {
            return;
        }
        let gw = num.scale(1.0 / den);
        let g = match self.g_est {
            None => gw,
            Some(g) => g.scale(1.0 - self.cfg.gain_ema) + gw.scale(self.cfg.gain_ema),
        };
        self.g_est = Some(g);
        // v = y / (backoff · g): the normalized postinverse input. At
        // the ILA fixed point the deployed chain then realizes
        // y = backoff·ĝ·x — backoff < 1 really is peak *headroom*
        // (normalizing by backoff/ĝ instead would converge to ĝ/backoff,
        // driving the PA hotter and inverting the knob).
        let q = g.scale(self.cfg.backoff).recip();

        let hd = self.w.hidden;
        let rows = 3 * hd;
        let (mut w_err, mut w_ref) = (0.0f64, 0.0f64);
        // ---- forward, recording every intermediate ----
        self.hs[..hd].copy_from_slice(&self.h);
        for t in 0..t_len {
            let cv = C64::new(y[t][0], y[t][1]) * q;
            let x = GruDpd::features([cv.re, cv.im]);
            self.xs[t * 4..t * 4 + 4].copy_from_slice(&x);
            let (h_prev, rest) = self.hs[t * hd..].split_at_mut(hd);
            let h_next = &mut rest[..hd];
            let gi = &mut self.gis[t * rows..(t + 1) * rows];
            for r in 0..rows {
                let row = &self.w.w_ih[r * 4..(r + 1) * 4];
                gi[r] = self.w.b_ih[r]
                    + row[0] * x[0]
                    + row[1] * x[1]
                    + row[2] * x[2]
                    + row[3] * x[3];
            }
            let gh = &mut self.ghs[t * rows..(t + 1) * rows];
            for r in 0..rows {
                let row = &self.w.w_hh[r * hd..(r + 1) * hd];
                let mut acc = self.w.b_hh[r];
                for (wv, hv) in row.iter().zip(h_prev.iter()) {
                    acc += wv * hv;
                }
                gh[r] = acc;
            }
            for k in 0..hd {
                let r = hardsigmoid(gi[k] + gh[k]);
                let z = hardsigmoid(gi[hd + k] + gh[hd + k]);
                let n = hardtanh(gi[2 * hd + k] + r * gh[2 * hd + k]);
                self.rs[t * hd + k] = r;
                self.zs[t * hd + k] = z;
                self.ns[t * hd + k] = n;
                h_next[k] = (1.0 - z) * n + z * h_prev[k];
            }
            for o in 0..2 {
                let row = &self.w.w_fc[o * hd..(o + 1) * hd];
                let mut yy = self.w.b_fc[o] + [cv.re, cv.im][o];
                for (wv, hv) in row.iter().zip(h_next.iter()) {
                    yy += wv * hv;
                }
                self.es[t * 2 + o] = yy - u[t][o];
            }
            w_err += self.es[t * 2] * self.es[t * 2] + self.es[t * 2 + 1] * self.es[t * 2 + 1];
            w_ref += u[t][0] * u[t][0] + u[t][1] * u[t][1];
        }
        self.err_acc += w_err;
        self.ref_acc += w_ref;
        if w_ref > 0.0 {
            let ratio = w_err / w_ref;
            self.recent_ratio = Some(match self.recent_ratio {
                None => ratio,
                Some(r) => r * (1.0 - RECENT_NMSE_EMA) + ratio * RECENT_NMSE_EMA,
            });
        }
        self.h.copy_from_slice(&self.hs[t_len * hd..(t_len + 1) * hd]);
        self.samples += t_len as u64;

        // ---- backward (reverse-mode through the window) ----
        self.grads.zero();
        self.dh.iter_mut().for_each(|v| *v = 0.0);
        let g = &mut self.grads;
        let dh = &mut self.dh;
        let dgi_row = &mut self.dgi_row;
        let dgh_row = &mut self.dgh_row;
        let scale = 2.0 / t_len as f64;
        for t in (0..t_len).rev() {
            let h_prev = &self.hs[t * hd..(t + 1) * hd];
            let h_next = &self.hs[(t + 1) * hd..(t + 2) * hd];
            let gi = &self.gis[t * rows..(t + 1) * rows];
            let gh = &self.ghs[t * rows..(t + 1) * rows];
            let (rs, zs, ns) = (
                &self.rs[t * hd..(t + 1) * hd],
                &self.zs[t * hd..(t + 1) * hd],
                &self.ns[t * hd..(t + 1) * hd],
            );
            // output layer
            for o in 0..2 {
                let dy = self.es[t * 2 + o] * scale;
                g.b_fc[o] += dy;
                let row_g = &mut g.w_fc[o * hd..(o + 1) * hd];
                let row_w = &self.w.w_fc[o * hd..(o + 1) * hd];
                for k in 0..hd {
                    row_g[k] += dy * h_next[k];
                    dh[k] += row_w[k] * dy;
                }
            }
            // gate pass — STAGED: first derive every pre-activation
            // gradient from the untouched dL/dh_t, only then fold the
            // W_hh backprop into dh (mixing the two in one loop would
            // contaminate dL/dh_t for later units with dL/dh_{t-1}
            // contributions — the finite-difference suite pins this).
            // hardsigmoid grad = 0.25 inside (-2, 2), hardtanh grad = 1
            // inside (-1, 1), 0 outside.
            for k in 0..hd {
                let dhk = dh[k];
                let dz = dhk * (h_prev[k] - ns[k]);
                let dn = dhk * (1.0 - zs[k]);
                let a_n = gi[2 * hd + k] + rs[k] * gh[2 * hd + k];
                let dan = if a_n > -1.0 && a_n < 1.0 { dn } else { 0.0 };
                let dr = dan * gh[2 * hd + k];
                let a_r = gi[k] + gh[k];
                let dar = if a_r > -2.0 && a_r < 2.0 { dr * 0.25 } else { 0.0 };
                let a_z = gi[hd + k] + gh[hd + k];
                let daz = if a_z > -2.0 && a_z < 2.0 { dz * 0.25 } else { 0.0 };
                // dgi rows: [r at k, z at hd+k, n at 2hd+k]; dgh the
                // same except the n row is scaled by r
                dgi_row[k] = dar;
                dgi_row[hd + k] = daz;
                dgi_row[2 * hd + k] = dan;
                dgh_row[k] = dar;
                dgh_row[hd + k] = daz;
                dgh_row[2 * hd + k] = dan * rs[k];
            }
            // direct carry into h_{t-1} through the z gate
            for k in 0..hd {
                dh[k] *= zs[k];
            }
            // parameter gradients + the W_hh path into h_{t-1}
            let x = &self.xs[t * 4..t * 4 + 4];
            for r_idx in 0..rows {
                let dgi_r = dgi_row[r_idx];
                let dgh_r = dgh_row[r_idx];
                g.b_ih[r_idx] += dgi_r;
                let row = &mut g.w_ih[r_idx * 4..r_idx * 4 + 4];
                for c in 0..4 {
                    row[c] += dgi_r * x[c];
                }
                g.b_hh[r_idx] += dgh_r;
                let row_g = &mut g.w_hh[r_idx * hd..(r_idx + 1) * hd];
                let row_w = &self.w.w_hh[r_idx * hd..(r_idx + 1) * hd];
                for c in 0..hd {
                    row_g[c] += dgh_r * h_prev[c];
                    dh[c] += row_w[c] * dgh_r;
                }
            }
        }

        // ---- Adam step ----
        self.steps += 1;
        self.b1_pow *= self.cfg.beta1;
        self.b2_pow *= self.cfg.beta2;
        let bc1 = 1.0 - self.b1_pow;
        let bc2 = 1.0 - self.b2_pow;
        let (lr, b1, b2, eps) = (self.cfg.lr, self.cfg.beta1, self.cfg.beta2, self.cfg.eps);
        let mut apply = |p: &mut [f64], gr: &[f64], m: &mut [f64], v: &mut [f64]| {
            for i in 0..p.len() {
                m[i] = b1 * m[i] + (1.0 - b1) * gr[i];
                v[i] = b2 * v[i] + (1.0 - b2) * gr[i] * gr[i];
                p[i] -= lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + eps);
            }
        };
        apply(&mut self.w.w_ih, &self.grads.w_ih, &mut self.m.w_ih, &mut self.v.w_ih);
        apply(&mut self.w.b_ih, &self.grads.b_ih, &mut self.m.b_ih, &mut self.v.b_ih);
        apply(&mut self.w.w_hh, &self.grads.w_hh, &mut self.m.w_hh, &mut self.v.w_hh);
        apply(&mut self.w.b_hh, &self.grads.b_hh, &mut self.m.b_hh, &mut self.v.b_hh);
        apply(&mut self.w.w_fc, &self.grads.w_fc, &mut self.m.w_fc, &mut self.v.w_fc);
        apply(&mut self.w.b_fc, &self.grads.b_fc, &mut self.m.b_fc, &mut self.v.b_fc);
    }
}

/// Deterministic small-random initial twin for from-scratch adaptation
/// (gates uniform in ±`gate_bound`, FC zero so the initial DPD is the
/// exact identity through the residual path — `serve --adapt` and the
/// tests start here).
pub fn identity_init(seed: u64, hidden: usize, gate_bound: f64) -> GruWeights {
    let mut rng = crate::util::Rng::new(seed);
    let mut gen =
        |n: usize| -> Vec<f64> { (0..n).map(|_| rng.range(-gate_bound, gate_bound)).collect() };
    GruWeights {
        hidden,
        features: 4,
        w_ih: gen(3 * hidden * 4),
        b_ih: gen(3 * hidden),
        w_hh: gen(3 * hidden * hidden),
        b_hh: gen(3 * hidden),
        w_fc: vec![0.0; 2 * hidden],
        b_fc: vec![0.0; 2],
        meta_bits: None,
        meta_act: None,
        meta_val_nmse_db: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpd::Dpd;
    use crate::util::Rng;

    fn loss_of(
        w: &GruWeights,
        cfg: AdaptConfig,
        h0: &[f64],
        u: &[[f64; 2]],
        v: &[[f64; 2]],
    ) -> f64 {
        // forward-only reference loss: mean squared error over the
        // window, computed with a plain GruDpd clone of the math
        let hd = w.hidden;
        let mut h = h0.to_vec();
        let mut loss = 0.0;
        for (uu, vv) in u.iter().zip(v) {
            let x = GruDpd::features(*vv);
            let mut gi = vec![0.0; 3 * hd];
            let mut gh = vec![0.0; 3 * hd];
            for r in 0..3 * hd {
                let row = &w.w_ih[r * 4..(r + 1) * 4];
                gi[r] = w.b_ih[r] + row[0] * x[0] + row[1] * x[1] + row[2] * x[2] + row[3] * x[3];
                let rowh = &w.w_hh[r * hd..(r + 1) * hd];
                gh[r] = w.b_hh[r] + rowh.iter().zip(&h).map(|(a, b)| a * b).sum::<f64>();
            }
            for k in 0..hd {
                let r = hardsigmoid(gi[k] + gh[k]);
                let z = hardsigmoid(gi[hd + k] + gh[hd + k]);
                let n = hardtanh(gi[2 * hd + k] + r * gh[2 * hd + k]);
                h[k] = (1.0 - z) * n + z * h[k];
            }
            for o in 0..2 {
                let row = &w.w_fc[o * hd..(o + 1) * hd];
                let y = w.b_fc[o] + vv[o] + row.iter().zip(&h).map(|(a, b)| a * b).sum::<f64>();
                let e = y - uu[o];
                loss += e * e;
            }
        }
        let _ = cfg;
        loss / u.len() as f64
    }

    #[test]
    fn bptt_gradients_match_finite_differences() {
        // The correctness anchor of the whole trainer: analytic BPTT
        // gradients against central finite differences on every tensor,
        // for random weights, hidden state and stimulus. Activation
        // kinks (hardsigmoid/hardtanh breakpoints) are measure-zero
        // under random continuous inputs; tolerance covers fd noise.
        let mut rng = Rng::new(41);
        for case in 0..3 {
            let w0 = identity_init(100 + case, 10, 0.25);
            // non-zero FC so the output path has gradient flow
            let mut w0 = w0;
            w0.w_fc.iter_mut().for_each(|v| *v = rng.range(-0.2, 0.2));
            w0.b_fc.iter_mut().for_each(|v| *v = rng.range(-0.05, 0.05));
            // window 8 = one exact window per observe; lr tiny so the
            // recorded grads correspond to the probed weights while the
            // Adam machinery still runs
            let cfg = AdaptConfig { window: 8, lr: 1e-12, ..Default::default() };
            let mut tr = AdaptTrainer::new(w0.clone(), cfg).unwrap();
            let h0: Vec<f64> = (0..10).map(|_| rng.range(-0.5, 0.5)).collect();
            tr.h.copy_from_slice(&h0);
            // fix the gain estimate so v is a known pure function of y
            tr.g_est = Some(C64::new(1.0, 0.0));
            let u: Vec<[f64; 2]> =
                (0..8).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect();
            let y: Vec<[f64; 2]> =
                (0..8).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect();
            // the normalized input the trainer will derive from y
            let q = {
                // EMA update with den > 0 moves g_est; replicate it
                let mut num = C64::ZERO;
                let mut den = 0.0;
                for (uu, yy) in u.iter().zip(&y) {
                    num = num + C64::new(yy[0], yy[1]) * C64::new(uu[0], uu[1]).conj();
                    den += uu[0] * uu[0] + uu[1] * uu[1];
                }
                let gw = num.scale(1.0 / den);
                (C64::new(1.0, 0.0).scale(1.0 - cfg.gain_ema) + gw.scale(cfg.gain_ema))
                    .scale(cfg.backoff)
                    .recip()
            };
            let v: Vec<[f64; 2]> = y
                .iter()
                .map(|&[a, b]| {
                    let c = C64::new(a, b) * q;
                    [c.re, c.im]
                })
                .collect();
            tr.observe(&u, &y).unwrap();
            let analytic = tr.grads.clone();
            let eps = 1e-6;
            let mut check = |get: &dyn Fn(&GruWeights) -> &Vec<f64>,
                             set: &dyn Fn(&mut GruWeights, usize, f64),
                             grad: &[f64],
                             name: &str| {
                let n = get(&w0).len();
                // probe a deterministic subset (fd is O(n) forwards)
                for i in (0..n).step_by(1 + n / 17) {
                    let base = get(&w0)[i];
                    let mut wp = w0.clone();
                    set(&mut wp, i, base + eps);
                    let lp = loss_of(&wp, cfg, &h0, &u, &v);
                    let mut wm = w0.clone();
                    set(&mut wm, i, base - eps);
                    let lm = loss_of(&wm, cfg, &h0, &u, &v);
                    let fd = (lp - lm) / (2.0 * eps);
                    let an = grad[i];
                    let tol = 1e-5 + 1e-4 * fd.abs().max(an.abs());
                    assert!(
                        (fd - an).abs() < tol,
                        "case {case} {name}[{i}]: analytic {an:.3e} vs fd {fd:.3e}"
                    );
                }
            };
            check(&|w| &w.w_ih, &|w, i, v| w.w_ih[i] = v, &analytic.w_ih, "w_ih");
            check(&|w| &w.b_ih, &|w, i, v| w.b_ih[i] = v, &analytic.b_ih, "b_ih");
            check(&|w| &w.w_hh, &|w, i, v| w.w_hh[i] = v, &analytic.w_hh, "w_hh");
            check(&|w| &w.b_hh, &|w, i, v| w.b_hh[i] = v, &analytic.b_hh, "b_hh");
            check(&|w| &w.w_fc, &|w, i, v| w.w_fc[i] = v, &analytic.w_fc, "w_fc");
            check(&|w| &w.b_fc, &|w, i, v| w.b_fc[i] = v, &analytic.b_fc, "b_fc");
        }
    }

    #[test]
    fn identity_init_is_the_identity_dpd() {
        let w = identity_init(7, 10, 0.15);
        let mut dpd = GruDpd::new(w);
        let x = [[0.21, -0.17], [0.0, 0.0], [-0.6, 0.45]];
        assert_eq!(dpd.run(&x), x.to_vec());
    }

    #[test]
    fn trainer_learns_a_static_postinverse() {
        // Toy inverse problem: y = u * (1 - 0.25 |u|^2) (a memoryless
        // cubic "PA" with unit gain). The trainer must drive its NMSE
        // well below the identity baseline within a modest budget.
        fn burst(tr: &mut AdaptTrainer, rng: &mut Rng) {
            let u: Vec<[f64; 2]> =
                (0..1024).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect();
            let y: Vec<[f64; 2]> = u
                .iter()
                .map(|&[i, q]| {
                    let e2 = i * i + q * q;
                    [i * (1.0 - 0.25 * e2), q * (1.0 - 0.25 * e2)]
                })
                .collect();
            tr.observe(&u, &y).unwrap();
        }
        // recent-window NMSE via accumulator deltas (the running
        // nmse_db is a lifetime average — early error would mask the
        // converged quality)
        fn recent(tr: &mut AdaptTrainer, rng: &mut Rng, bursts: usize) -> f64 {
            let (e0, r0) = (tr.err_acc, tr.ref_acc);
            for _ in 0..bursts {
                burst(tr, rng);
            }
            10.0 * ((tr.err_acc - e0) / (tr.ref_acc - r0)).log10()
        }
        let mut rng = Rng::new(5);
        let mut tr =
            AdaptTrainer::new(identity_init(11, 10, 0.15), AdaptConfig::default()).unwrap();
        // identity baseline: the first bursts, before training bites
        let early = recent(&mut tr, &mut rng, 2);
        for _ in 0..26 {
            burst(&mut tr, &mut rng);
        }
        let late = recent(&mut tr, &mut rng, 4);
        // measured 12.8 dB on this seed; 6 dB keeps cross-platform
        // float headroom
        assert!(
            late < early - 6.0,
            "trainer failed to learn: early {early:.1} dB -> late {late:.1} dB"
        );
        // the recent EMA tracks the converged fit, unlike the lifetime
        // average that stays pinned near the early error
        assert!(
            tr.recent_nmse_db() < early - 6.0,
            "recent NMSE ({:.1}) should track the converged windows",
            tr.recent_nmse_db()
        );
        assert!(tr.progress().steps > 0 && tr.progress().samples > 0);
        let g = tr.gain_est().unwrap();
        assert!((g.abs() - 1.0).abs() < 0.1, "gain estimate off: {:?}", g);
    }

    #[test]
    fn observe_buffers_partial_windows_chunk_invariantly() {
        // feeding the same stream in different chunkings must produce
        // the identical weight trajectory (windows are cut from the
        // buffered stream, not from burst boundaries)
        let mut rng = Rng::new(9);
        let u: Vec<[f64; 2]> =
            (0..999).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect();
        let y: Vec<[f64; 2]> =
            u.iter().map(|&[a, b]| [0.9 * a - 0.1 * b, 0.1 * a + 0.9 * b]).collect();
        let mut a = AdaptTrainer::new(identity_init(3, 10, 0.15), AdaptConfig::default()).unwrap();
        a.observe(&u, &y).unwrap();
        let mut b = AdaptTrainer::new(identity_init(3, 10, 0.15), AdaptConfig::default()).unwrap();
        let mut s = 0;
        for chunk in [7usize, 131, 64, 500, 297] {
            let e = (s + chunk).min(u.len());
            b.observe(&u[s..e], &y[s..e]).unwrap();
            s = e;
        }
        assert_eq!(a.weights().w_ih, b.weights().w_ih);
        assert_eq!(a.weights().w_hh, b.weights().w_hh);
        assert_eq!(a.weights().w_fc, b.weights().w_fc);
        assert_eq!(a.samples, b.samples);
        // 999 = 31 full windows + 7 pending
        assert_eq!(a.samples, 31 * 32);
        assert_eq!(a.pend_u.len(), 7);
        // mismatched burst lengths are rejected
        assert!(a.observe(&u[..3], &y[..2]).is_err());
    }

    #[test]
    fn silence_windows_never_train() {
        let mut tr = AdaptTrainer::new(identity_init(1, 10, 0.15), AdaptConfig::default()).unwrap();
        let zeros = vec![[0.0, 0.0]; 64];
        tr.observe(&zeros, &zeros).unwrap();
        assert!(tr.gain_est().is_none(), "no gain information in silence");
        assert_eq!(tr.progress().steps, 0);
        let u = vec![[0.2, -0.1]; 64];
        tr.observe(&u, &u).unwrap();
        assert!(tr.gain_est().is_some());
        let after_signal = tr.progress();
        assert!(after_signal.steps > 0);
        // a mid-stream idle carrier must not train either: zero input
        // would drag the twin toward f(·)=0 and its steps could
        // trigger a pointless engine hot-swap
        let w_before = tr.weights().clone();
        tr.observe(&zeros, &zeros).unwrap();
        assert_eq!(tr.progress().steps, after_signal.steps, "silence trained mid-stream");
        assert_eq!(tr.progress().samples, after_signal.samples);
        assert_eq!(tr.weights().w_fc, w_before.w_fc, "silence perturbed the twin");
        // and signal resumes training afterwards
        tr.observe(&u, &u).unwrap();
        assert!(tr.progress().steps > after_signal.steps);
    }

    #[test]
    fn quantized_bridge_equals_the_canonical_quantizer() {
        let mut w = identity_init(21, 10, 0.4);
        // include out-of-range values: the bridge must saturate them
        w.w_hh[3] = 3.7;
        w.w_hh[5] = -9.9;
        let tr = AdaptTrainer::new(w.clone(), AdaptConfig::default()).unwrap();
        let spec = QSpec::Q12;
        let qw = tr.quantized(spec).unwrap();
        for (f, q) in w.w_hh.iter().zip(&qw.w_hh) {
            assert_eq!(*q, spec.quantize(*f));
        }
        assert_eq!(qw.w_hh[3], spec.qmax(), "out-of-range weight must clamp");
        assert_eq!(qw.w_hh[5], spec.qmin());
        // a refreshed set is a new weight generation: distinct content
        // fingerprint (hence distinct batch class downstream)
        let mut w2 = w.clone();
        w2.w_ih[0] += 0.01;
        let tr2 = AdaptTrainer::new(w2, AdaptConfig::default()).unwrap();
        assert_ne!(
            tr.quantized(spec).unwrap().fingerprint(),
            tr2.quantized(spec).unwrap().fingerprint()
        );
    }

    #[test]
    fn quantized_bridge_rejects_a_diverged_twin() {
        // Regression: a trainer whose float twin diverged to NaN used
        // to quantize NaN weights to code 0 — the adaptation worker
        // would hot-swap a silently-zeroed engine. The bridge must
        // refuse with the typed error instead.
        let mut w = identity_init(33, 10, 0.4);
        w.w_ih[7] = f64::NAN;
        let tr = AdaptTrainer::new(w, AdaptConfig::default()).unwrap();
        let err = tr.quantized(QSpec::Q12).unwrap_err();
        assert_eq!((err.tensor, err.index), ("w_ih", 7));
        assert!(err.value.is_nan());
        // the sparse flavor of the bridge shares the screen
        let profile = crate::fixed::QProfile::wa(8, 12).unwrap();
        assert!(tr.quantized_sparse(profile, 50).is_err());
        // a healthy twin still bridges fine on both flavors
        let ok = AdaptTrainer::new(identity_init(33, 10, 0.4), AdaptConfig::default()).unwrap();
        assert!(ok.quantized(QSpec::Q12).is_ok());
        assert!(ok.quantized_sparse(profile, 50).is_ok());
    }
}
