//! Generalized Memory Polynomial DPD — the classical baseline the
//! paper's Table II competitors implement ([13][15] GMP, [14] MP), and
//! our Fig. 3/Table II comparison baseline.
//!
//!   F(x)(n) = sum_{k odd <= Ka} sum_{m < Ma} a_{k,m} x(n-m) |x(n-m)|^{k-1}
//!           + sum_{k odd, 3<=k<=Kb} sum_{m < Mb} sum_{l=1..Lb}
//!               b_{k,m,l} x(n-m) |x(n-m-l)|^{k-1}        (lagging cross terms)
//!
//! Fitting is **indirect learning** (ILA): on a PA in/out capture
//! (x, y), solve the ridge LS problem F(y/g) ~= x, then deploy F as
//! the predistorter. This is exactly how the FPGA baselines are
//! trained in practice.

use anyhow::Result;

use super::Dpd;
use crate::linalg::{ridge_lstsq, CMat};
use crate::util::C64;

/// GMP structure hyper-parameters.
#[derive(Clone, Debug)]
pub struct GmpConfig {
    /// max aligned order (odd), e.g. 9
    pub k_max: usize,
    /// aligned memory depth
    pub mem: usize,
    /// max cross-term order (odd, >=3; 0 disables cross terms)
    pub cross_k: usize,
    /// cross-term memory depth
    pub cross_m: usize,
    /// number of envelope lags (1..=cross_lags)
    pub cross_lags: usize,
    /// ridge regularization
    pub lambda: f64,
}

impl Default for GmpConfig {
    fn default() -> Self {
        // ~36 complex parameters, comparable to Table II's ref [13]
        GmpConfig { k_max: 9, mem: 4, cross_k: 5, cross_m: 2, cross_lags: 2, lambda: 1e-9 }
    }
}

impl GmpConfig {
    /// Number of complex coefficients.
    pub fn n_terms(&self) -> usize {
        let aligned = ((self.k_max + 1) / 2) * self.mem;
        let cross = if self.cross_k >= 3 {
            ((self.cross_k - 1) / 2) * self.cross_m * self.cross_lags
        } else {
            0
        };
        aligned + cross
    }

    /// Real-valued parameter count (for complexity comparisons).
    pub fn n_params_real(&self) -> usize {
        2 * self.n_terms()
    }
}

/// Fitted GMP predistorter.
pub struct GmpDpd {
    pub cfg: GmpConfig,
    pub coeffs: Vec<C64>,
    /// streaming delay line of recent inputs (newest first)
    dline: Vec<C64>,
}

fn basis_row(cfg: &GmpConfig, window: &[C64]) -> Vec<C64> {
    // window[d] = x(n-d), d = 0..depth
    let mut row = Vec::with_capacity(cfg.n_terms());
    let mut k = 1;
    while k <= cfg.k_max {
        for m in 0..cfg.mem {
            let xm = window[m];
            let e = xm.abs();
            row.push(xm.scale(e.powi((k - 1) as i32)));
        }
        k += 2;
    }
    if cfg.cross_k >= 3 {
        let mut k = 3;
        while k <= cfg.cross_k {
            for m in 0..cfg.cross_m {
                for l in 1..=cfg.cross_lags {
                    let xm = window[m];
                    let e = window[m + l].abs();
                    row.push(xm.scale(e.powi((k - 1) as i32)));
                }
            }
            k += 2;
        }
    }
    row
}

impl GmpDpd {
    /// Maximum delay the basis looks back.
    fn depth(cfg: &GmpConfig) -> usize {
        let aligned = cfg.mem;
        let cross = if cfg.cross_k >= 3 { cfg.cross_m + cfg.cross_lags } else { 0 };
        aligned.max(cross).max(1)
    }

    /// Indirect-learning fit on a PA capture: input `x`, output `y`,
    /// target gain `g` (the post-inverse is fit on u = y/g).
    pub fn fit_ila(cfg: &GmpConfig, x: &[[f64; 2]], y: &[[f64; 2]], g: C64) -> Result<GmpDpd> {
        anyhow::ensure!(x.len() == y.len(), "length mismatch");
        let depth = Self::depth(cfg);
        let n = x.len();
        anyhow::ensure!(n > depth + 16 * cfg.n_terms(), "capture too short for fit");
        let ginv = g.recip();
        let u: Vec<C64> = y.iter().map(|&[re, im]| C64::new(re, im) * ginv).collect();

        let rows = n - depth;
        let mut mat = CMat::zeros(rows, cfg.n_terms());
        let mut rhs = Vec::with_capacity(rows);
        let mut window = vec![C64::ZERO; depth + 1];
        for i in depth..n {
            for (d, w) in window.iter_mut().enumerate() {
                *w = u[i - d];
            }
            let row = basis_row(cfg, &window);
            let r = i - depth;
            mat.data[r * cfg.n_terms()..(r + 1) * cfg.n_terms()].copy_from_slice(&row);
            rhs.push(C64::new(x[i][0], x[i][1]));
        }
        let coeffs = ridge_lstsq(&mat, &rhs, cfg.lambda)?;
        Ok(GmpDpd { cfg: cfg.clone(), coeffs, dline: vec![C64::ZERO; depth + 1] })
    }

    /// Post-fit residual NMSE of the ILA regression (dB) on a capture.
    pub fn fit_residual_db(&self, x: &[[f64; 2]], y: &[[f64; 2]], g: C64) -> f64 {
        let depth = Self::depth(&self.cfg);
        let ginv = g.recip();
        let u: Vec<C64> = y.iter().map(|&[re, im]| C64::new(re, im) * ginv).collect();
        let mut window = vec![C64::ZERO; depth + 1];
        let mut err = 0.0;
        let mut refp = 0.0;
        for i in depth..x.len() {
            for (d, w) in window.iter_mut().enumerate() {
                *w = u[i - d];
            }
            let row = basis_row(&self.cfg, &window);
            let mut pred = C64::ZERO;
            for (c, b) in self.coeffs.iter().zip(&row) {
                pred += *c * *b;
            }
            let t = C64::new(x[i][0], x[i][1]);
            err += (pred - t).norm_sq();
            refp += t.norm_sq();
        }
        10.0 * (err / refp).log10()
    }
}

impl Dpd for GmpDpd {
    fn process(&mut self, iq: [f64; 2]) -> [f64; 2] {
        // shift delay line (newest first)
        for d in (1..self.dline.len()).rev() {
            self.dline[d] = self.dline[d - 1];
        }
        self.dline[0] = C64::new(iq[0], iq[1]);
        let row = basis_row(&self.cfg, &self.dline);
        let mut y = C64::ZERO;
        for (c, b) in self.coeffs.iter().zip(&row) {
            y += *c * *b;
        }
        [y.re, y.im]
    }

    fn reset(&mut self) {
        self.dline.iter_mut().for_each(|v| *v = C64::ZERO);
    }

    fn name(&self) -> &'static str {
        "gmp"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::acpr::{acpr_db, AcprConfig};
    use crate::metrics::evm::evm_db_nmse;
    use crate::pa::{PaSpec, RappMemPa};
    use crate::signal::ofdm::{OfdmConfig, OfdmModulator};

    #[test]
    fn term_count() {
        let cfg = GmpConfig::default();
        // aligned: 5 orders (1,3,5,7,9) x 4 mem = 20; cross: (3,5) x 2 x 2 = 8
        assert_eq!(cfg.n_terms(), 28);
        assert_eq!(cfg.n_params_real(), 56);
    }

    #[test]
    fn fit_rejects_short_capture() {
        let cfg = GmpConfig::default();
        let x = vec![[0.1, 0.0]; 64];
        assert!(GmpDpd::fit_ila(&cfg, &x, &x, C64::ONE).is_err());
    }

    #[test]
    fn identity_plant_learns_identity() {
        // PA == identity: the fitted DPD must be ~identity too
        let sig = OfdmModulator::generate(&OfdmConfig { n_symbols: 8, seed: 1, ..Default::default() }).unwrap();
        let cfg = GmpConfig { k_max: 5, mem: 2, cross_k: 0, cross_m: 0, cross_lags: 0, lambda: 1e-9 };
        let mut dpd = GmpDpd::fit_ila(&cfg, &sig.iq, &sig.iq, C64::ONE).unwrap();
        let z = dpd.run(&sig.iq);
        let evm = evm_db_nmse(&z, &sig.iq, C64::ONE);
        assert!(evm < -55.0, "identity fit EVM {evm}");
    }

    #[test]
    fn linearizes_the_gan_pa() {
        // the headline sanity check: GMP-ILA improves ACPR by >12 dB
        let sig = OfdmModulator::generate(&OfdmConfig { n_symbols: 24, seed: 2, ..Default::default() }).unwrap();
        let pa = RappMemPa::new(PaSpec::ganlike());
        let y = pa.run(&sig.iq);
        let g = pa.spec.target_gain();
        let cfg = GmpConfig::default();
        let mut dpd = GmpDpd::fit_ila(&cfg, &sig.iq, &y, g).unwrap();

        let before = acpr_db(&y, &AcprConfig::default()).unwrap().acpr_dbc;
        let z = dpd.run(&sig.iq);
        // clip to the DAC range like the real chain
        let zc: Vec<[f64; 2]> = z
            .iter()
            .map(|&[i, q]| {
                let e = (i * i + q * q).sqrt();
                if e > 2.0 {
                    [i * 2.0 / e, q * 2.0 / e]
                } else {
                    [i, q]
                }
            })
            .collect();
        let y2 = pa.run(&zc);
        let after = acpr_db(&y2, &AcprConfig::default()).unwrap().acpr_dbc;
        assert!(after < before - 12.0, "ACPR {before} -> {after}");
        let evm = evm_db_nmse(&y2, &sig.iq, g);
        assert!(evm < -35.0, "EVM {evm}");
    }

    #[test]
    fn streaming_matches_batch() {
        let sig = OfdmModulator::generate(&OfdmConfig { n_symbols: 4, seed: 3, ..Default::default() }).unwrap();
        let pa = RappMemPa::new(PaSpec::ganlike());
        let y = pa.run(&sig.iq);
        let cfg = GmpConfig { k_max: 5, mem: 3, cross_k: 3, cross_m: 2, cross_lags: 1, lambda: 1e-9 };
        let mut dpd = GmpDpd::fit_ila(&cfg, &sig.iq, &y, pa.spec.target_gain()).unwrap();
        let a = dpd.run(&sig.iq);
        let b = dpd.run(&sig.iq); // second run after reset must match
        assert_eq!(a, b);
    }
}
