//! Sparse + mixed-precision GRU DPD engine — the SparseDPD
//! (arXiv:2506.16591) × MP-DPD (arXiv:2404.15364) family member.
//!
//! [`SparseMpGruDpd`] combines three MAC-reduction levers behind one
//! datapath:
//!
//! * **static weight sparsity** — the gate tensors arrive magnitude-
//!   pruned in compressed sparse-column form
//!   ([`SparseQGruWeights`]), so a pruned weight costs no storage and
//!   no MAC in the per-column update loop;
//! * **per-tensor mixed precision** — each weight tensor carries its
//!   own [`QSpec`](crate::fixed::QSpec) (the
//!   [`QProfile`](crate::fixed::QProfile)), with activations, biases
//!   and the I/Q stream in the activation format. Products accumulate
//!   in the fa+fw domain and every matvec requantizes by the *weight*
//!   fraction back to the activation domain;
//! * **temporal delta skipping** — the same θ-threshold column firing
//!   as [`DeltaQGruDpd`](super::DeltaQGruDpd): accumulators are
//!   carried across steps and only columns whose input/hidden delta
//!   exceeds θ fold in (`fixed::kernel::GateKernel::
//!   sparse_delta_axpy_i64`).
//!
//! **Equivalence contracts** (pinned by `tests/conformance.rs` and the
//! property suite below):
//!
//! * uniform profile + ρ=0 + θ=0 ⇒ bit-identical to the dense
//!   [`QGruDpd`](super::QGruDpd): the CSC holds exactly the nonzero
//!   codes (eliding a zero is exact), θ=0 keeps `v_prev == v`, and
//!   with fw == fa the accumulate/requantize chain is the dense one
//!   op for op;
//! * uniform profile + ρ=0 + any θ ⇒ bit-identical to
//!   [`DeltaQGruDpd`](super::DeltaQGruDpd) at the same θ (same fire
//!   decisions, same exact i64 accumulators — integer addition is
//!   order-independent).
//!
//! For ρ>0 or narrow weights the engine computes a *different*
//! (cheaper) function whose linearization cost is swept into
//! `BENCH_pareto.json` and cross-validated against the Python mirror
//! (`python/tools/gen_golden_pareto.py`).

use anyhow::{bail, Result};

use super::qgru::{features_codes, sigmoid_code, tanh_code, ActKind};
use super::weights::SparseQGruWeights;
use super::{DeltaSnapshot, Dpd, DpdState};
use crate::fixed::kernel::{GateKernel, ScalarKernel};
use crate::fixed::ops::{exceeds_theta, requantize, rshift_round, saturate_i64};
use crate::util::fnv1a_words;

/// Column-update + MAC activity of a sparse engine — the measured
/// work the accel cost model (`accel::sparse`) prices. Like
/// [`DeltaStats`](super::DeltaStats), counters accumulate across the
/// engine's whole life and survive `reset`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SparseStats {
    /// samples processed
    pub steps: u64,
    /// input feature columns whose delta exceeded θ (fired)
    pub in_updates: u64,
    /// input feature column opportunities (steps × F)
    pub in_cols: u64,
    /// hidden columns whose delta exceeded θ (fired)
    pub hid_updates: u64,
    /// hidden column opportunities (steps × H)
    pub hid_cols: u64,
    /// gate MACs actually executed: Σ over fired columns of that
    /// column's surviving (unpruned, nonzero) entry count
    pub gate_macs: u64,
    /// gate MACs the dense engine performs: steps × 3H(F+H)
    pub dense_gate_macs: u64,
}

impl SparseStats {
    /// Executed / dense gate MACs (1.0 = no savings).
    pub fn mac_ratio(&self) -> f64 {
        if self.dense_gate_macs == 0 {
            return 1.0;
        }
        self.gate_macs as f64 / self.dense_gate_macs as f64
    }

    /// Fraction of all matvec columns that fired.
    pub fn update_ratio(&self) -> f64 {
        let cols = self.in_cols + self.hid_cols;
        if cols == 0 {
            return 1.0;
        }
        (self.in_updates + self.hid_updates) as f64 / cols as f64
    }
}

/// Streaming sparse mixed-precision GRU DPD (see the module docs for
/// the datapath and its equivalence contracts). Generic over the gate
/// kernel like every integer engine; the sparse column update is the
/// kernel's `sparse_delta_axpy_i64` gather.
pub struct SparseMpGruDpd<K: GateKernel = ScalarKernel> {
    w: SparseQGruWeights,
    act: ActKind,
    /// delta propagation threshold in activation codes (0 = every
    /// nonzero delta fires)
    theta: u32,
    st: DeltaSnapshot,
    gi: Vec<i32>,
    gh: Vec<i32>,
    kernel: K,
    stats: SparseStats,
}

impl SparseMpGruDpd {
    /// Scalar-kernel constructor (the portable default).
    pub fn new(w: SparseQGruWeights, act: ActKind, theta: u32) -> SparseMpGruDpd {
        SparseMpGruDpd::with_kernel(w, act, theta, ScalarKernel)
    }
}

impl<K: GateKernel> SparseMpGruDpd<K> {
    /// Construct over an explicit gate kernel (the factory's dispatch
    /// point, mirroring `QGruDpd::with_kernel`).
    pub fn with_kernel(
        w: SparseQGruWeights,
        act: ActKind,
        theta: u32,
        kernel: K,
    ) -> SparseMpGruDpd<K> {
        let g = vec![0i32; 3 * w.hidden];
        let st = Self::fresh_state(&w);
        SparseMpGruDpd { st, gi: g.clone(), gh: g, kernel, w, act, theta, stats: SparseStats::default() }
    }

    /// The reset state: h = v_prev = 0, accumulators hold only the
    /// biases aligned into each tensor's accumulation domain
    /// (`b_code(fa) << fw` — the matvec of the all-zero vector).
    fn fresh_state(w: &SparseQGruWeights) -> DeltaSnapshot {
        let f_ih = w.profile.w_ih.frac();
        let f_hh = w.profile.w_hh.frac();
        DeltaSnapshot {
            h: vec![0; w.hidden],
            x_prev: vec![0; w.features],
            h_prev: vec![0; w.hidden],
            acc_ih: w.b_ih.iter().map(|&b| (b as i64) << f_ih).collect(),
            acc_hh: w.b_hh.iter().map(|&b| (b as i64) << f_hh).collect(),
        }
    }

    /// The active kernel's label (diagnostics; not part of the
    /// datapath identity).
    pub fn kernel_name(&self) -> &'static str {
        self.kernel.name()
    }

    pub fn weights(&self) -> &SparseQGruWeights {
        &self.w
    }

    pub fn theta(&self) -> u32 {
        self.theta
    }

    /// Activity so far (feeds `accel::sparse`).
    pub fn stats(&self) -> SparseStats {
        self.stats
    }

    /// One sparse datapath step on activation-format codes. Same
    /// signature as `QGruDpd::step_codes` so differential tests can
    /// drive both.
    pub fn step_codes(&mut self, iq: [i32; 2]) -> [i32; 2] {
        let act_spec = self.w.profile.act;
        let fa = act_spec.frac();
        let f_ih = self.w.profile.w_ih.frac();
        let f_hh = self.w.profile.w_hh.frac();
        let f_fc = self.w.profile.w_fc.frac();
        let hd = self.w.hidden;
        let k = self.kernel;
        let one = 1i64 << fa;
        let x = features_codes(act_spec, iq);

        // delta pass over the input feature columns: only surviving
        // CSC entries are touched, so a pruned weight costs no MAC
        for (c, &xv) in x.iter().enumerate() {
            let d = xv - self.st.x_prev[c];
            if exceeds_theta(d, self.theta) {
                let (lo, hi) = (self.w.ih_ptr[c], self.w.ih_ptr[c + 1]);
                k.sparse_delta_axpy_i64(
                    &mut self.st.acc_ih,
                    &self.w.ih_rows[lo..hi],
                    &self.w.ih_vals[lo..hi],
                    d,
                );
                self.st.x_prev[c] = xv;
                self.stats.in_updates += 1;
                self.stats.gate_macs += (hi - lo) as u64;
            }
        }
        // delta pass over the hidden columns
        for c in 0..hd {
            let d = self.st.h[c] - self.st.h_prev[c];
            if exceeds_theta(d, self.theta) {
                let (lo, hi) = (self.w.hh_ptr[c], self.w.hh_ptr[c + 1]);
                k.sparse_delta_axpy_i64(
                    &mut self.st.acc_hh,
                    &self.w.hh_rows[lo..hi],
                    &self.w.hh_vals[lo..hi],
                    d,
                );
                self.st.h_prev[c] = self.st.h[c];
                self.stats.hid_updates += 1;
                self.stats.gate_macs += (hi - lo) as u64;
            }
        }
        self.stats.steps += 1;
        self.stats.in_cols += self.w.features as u64;
        self.stats.hid_cols += hd as u64;
        self.stats.dense_gate_macs += (3 * hd * (self.w.features + hd)) as u64;

        // readout: requantize each carried accumulator by its tensor's
        // weight fraction, back into the activation domain
        k.requantize_block_i64(&self.st.acc_ih, f_ih, act_spec, &mut self.gi);
        k.requantize_block_i64(&self.st.acc_hh, f_hh, act_spec, &mut self.gh);

        // gates — the dense chain in the activation format (wide form,
        // identical to DeltaQGruDpd's)
        for j in 0..hd {
            let r = sigmoid_code(
                &self.act,
                act_spec,
                saturate_i64(self.gi[j] as i64 + self.gh[j] as i64, act_spec),
            );
            let z = sigmoid_code(
                &self.act,
                act_spec,
                saturate_i64(self.gi[hd + j] as i64 + self.gh[hd + j] as i64, act_spec),
            );
            let rh = requantize(r as i64 * self.gh[2 * hd + j] as i64, fa, act_spec);
            let n = tanh_code(
                &self.act,
                act_spec,
                saturate_i64(self.gi[2 * hd + j] as i64 + rh as i64, act_spec),
            );
            let zn = rshift_round((one - z as i64) * n as i64, fa);
            let zh = rshift_round(z as i64 * self.st.h[j] as i64, fa);
            self.st.h[j] = saturate_i64(zn + zh, act_spec);
        }

        // FC + residual, dense (2 × H — no sparsity leverage there);
        // weights in the FC format, requantized by its fraction
        let mut y = [0i32; 2];
        for (o, out) in y.iter_mut().enumerate() {
            let row = &self.w.w_fc[o * hd..(o + 1) * hd];
            let mut acc = (self.w.b_fc[o] as i64) << f_fc;
            for (wv, hv) in row.iter().zip(&self.st.h) {
                acc += *wv as i64 * *hv as i64;
            }
            let fc = requantize(acc, f_fc, act_spec);
            *out = saturate_i64(fc as i64 + iq[o] as i64, act_spec);
        }
        y
    }

    /// Run a whole burst of codes (resets state first).
    pub fn run_codes(&mut self, iq: &[[i32; 2]]) -> Vec<[i32; 2]> {
        self.reset();
        iq.iter().map(|&s| self.step_codes(s)).collect()
    }
}

impl<K: GateKernel> Dpd for SparseMpGruDpd<K> {
    fn process(&mut self, iq: [f64; 2]) -> [f64; 2] {
        let act_spec = self.w.profile.act;
        let codes = [act_spec.quantize(iq[0]), act_spec.quantize(iq[1])];
        let y = self.step_codes(codes);
        [act_spec.dequantize(y[0]), act_spec.dequantize(y[1])]
    }

    fn reset(&mut self) {
        // activity counters survive (they track total work)
        self.st = Self::fresh_state(&self.w);
    }

    fn name(&self) -> &'static str {
        "sparse-mp-qgru"
    }

    fn save_state(&self) -> DpdState {
        DpdState::DeltaI32(self.st.clone())
    }

    fn load_state(&mut self, state: &DpdState) -> Result<()> {
        match state {
            DpdState::DeltaI32(s)
                if s.h.len() == self.w.hidden
                    && s.h_prev.len() == self.w.hidden
                    && s.x_prev.len() == self.w.features
                    && s.acc_ih.len() == 3 * self.w.hidden
                    && s.acc_hh.len() == 3 * self.w.hidden =>
            {
                self.st = s.clone();
                Ok(())
            }
            other => bail!(
                "{}: incompatible state snapshot ({}) for hidden={}",
                self.name(),
                other.kind(),
                self.w.hidden
            ),
        }
    }

    fn batch_fingerprint(&self) -> Option<u64> {
        // the weight fingerprint already covers profile + ρ + mask +
        // codes; θ joins it like the delta engine's
        let base = super::qgru::act_fingerprint(&self.act, self.w.fingerprint());
        Some(fnv1a_words("sparse-mp-theta", [base, self.theta as u64]))
    }

    // process_lanes: the sequential default is exact because the
    // snapshot round-trips the entire delta state (h + v_prev +
    // accumulators) — same argument as DeltaQGruDpd's.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpd::qgru::{DeltaQGruDpd, QGruDpd};
    use crate::dpd::weights::{GruWeights, QGruWeights};
    use crate::dpd::DpdLane;
    use crate::fixed::{QProfile, QSpec};
    use crate::util::proptest::check;
    use crate::util::Rng;

    fn rand_stream(rng: &mut Rng, n: usize) -> Vec<[f64; 2]> {
        (0..n).map(|_| [rng.range(-0.9, 0.9), rng.range(-0.9, 0.9)]).collect()
    }

    #[test]
    fn uniform_rho0_theta0_is_bit_identical_to_dense() {
        check("sparse rho=0 == dense", 30, |rng| {
            let seed = rng.next_u64();
            let qw = QGruWeights::synthetic(seed, QSpec::Q12);
            let mut dense = QGruDpd::new(qw.clone(), ActKind::Hard);
            let mut sparse = SparseMpGruDpd::new(qw.to_sparse(0), ActKind::Hard, 0);
            let x = rand_stream(rng, 64);
            for (t, &s) in x.iter().enumerate() {
                let a = dense.process(s);
                let b = sparse.process(s);
                if a != b {
                    return Err(format!("seed {seed}: diverged at t={t}: {a:?} vs {b:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn uniform_rho0_matches_the_delta_engine_at_any_theta() {
        check("sparse rho=0 == delta @theta", 20, |rng| {
            let seed = rng.next_u64();
            let theta = rng.int_in(0, 64) as u32;
            let qw = QGruWeights::synthetic(seed, QSpec::Q12);
            let mut delta = DeltaQGruDpd::new(qw.clone(), ActKind::Hard, theta);
            let mut sparse = SparseMpGruDpd::new(qw.to_sparse(0), ActKind::Hard, theta);
            let x = rand_stream(rng, 96);
            for (t, &s) in x.iter().enumerate() {
                let a = delta.process(s);
                let b = sparse.process(s);
                if a != b {
                    return Err(format!(
                        "seed {seed} theta={theta}: diverged at t={t}: {a:?} vs {b:?}"
                    ));
                }
            }
            // same fire decisions -> same update counts
            let (ds, ss) = (delta.stats(), sparse.stats());
            if (ds.in_updates, ds.hid_updates) != (ss.in_updates, ss.hid_updates) {
                return Err(format!("seed {seed}: fire counts diverged"));
            }
            Ok(())
        });
    }

    #[test]
    fn pruning_reduces_gate_macs_proportionally() {
        let qw = QGruWeights::synthetic(7, QSpec::Q12);
        let mut rng = Rng::new(99);
        let x = rand_stream(&mut rng, 200);
        let mut dense0 = SparseMpGruDpd::new(qw.to_sparse(0), ActKind::Hard, 0);
        let mut pruned = SparseMpGruDpd::new(qw.to_sparse(50), ActKind::Hard, 0);
        for &s in &x {
            dense0.process(s);
            pruned.process(s);
        }
        let (s0, s1) = (dense0.stats(), pruned.stats());
        assert_eq!(s0.steps, 200);
        assert!(s1.gate_macs * 2 <= s0.dense_gate_macs, "rho=50 must halve gate MACs");
        assert!(s1.mac_ratio() < s0.mac_ratio());
        assert!(s0.mac_ratio() <= 1.0);
    }

    #[test]
    fn mixed_precision_profile_still_linearizes_reasonably() {
        // W8A12 on the same codes: not bit-identical to dense, but the
        // output must stay close (narrow weights, same activations) —
        // a sanity floor; the real quality accounting is the Pareto
        // golden test.
        let w = GruWeights::synthetic(13);
        let qw = w.quantize(QSpec::Q12).unwrap();
        let sw = w.prune_quantize(QProfile::wa(8, 12).unwrap(), 0).unwrap();
        let mut dense = QGruDpd::new(qw, ActKind::Hard);
        let mut mp = SparseMpGruDpd::new(sw, ActKind::Hard, 0);
        let mut rng = Rng::new(5);
        let x = rand_stream(&mut rng, 256);
        let mut err = 0.0f64;
        let mut pow = 0.0f64;
        for &s in &x {
            let a = dense.process(s);
            let b = mp.process(s);
            err += (a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2);
            pow += a[0].powi(2) + a[1].powi(2);
        }
        let nmse_db = 10.0 * (err / pow).log10();
        assert!(nmse_db < -20.0, "W8A12 deviates too much from dense: {nmse_db:.1} dB");
    }

    #[test]
    fn state_roundtrip_is_exact_mid_stream() {
        let qw = QGruWeights::synthetic(4, QSpec::Q12);
        let sw = qw.to_sparse(40);
        let mut rng = Rng::new(8);
        let x = rand_stream(&mut rng, 120);
        // uninterrupted reference
        let mut a = SparseMpGruDpd::new(sw.clone(), ActKind::Hard, 24);
        let want: Vec<[f64; 2]> = x.iter().map(|&s| a.process(s)).collect();
        // interrupted: snapshot + restore across a fresh engine
        let mut b1 = SparseMpGruDpd::new(sw.clone(), ActKind::Hard, 24);
        let mut got: Vec<[f64; 2]> = x[..60].iter().map(|&s| b1.process(s)).collect();
        let snap = b1.save_state();
        let mut b2 = SparseMpGruDpd::new(sw, ActKind::Hard, 24);
        b2.load_state(&snap).unwrap();
        got.extend(x[60..].iter().map(|&s| b2.process(s)));
        assert_eq!(got, want, "state snapshot must round-trip exactly");
    }

    #[test]
    fn batched_lanes_match_solo_processing() {
        let qw = QGruWeights::synthetic(19, QSpec::Q12);
        let sw = qw.to_sparse(50);
        let mut rng = Rng::new(3);
        let mut streams: Vec<Vec<[f64; 2]>> =
            (0..3).map(|_| rand_stream(&mut rng, 80)).collect();
        // solo references
        let want: Vec<Vec<[f64; 2]>> = streams
            .iter()
            .map(|s| {
                let mut e = SparseMpGruDpd::new(sw.clone(), ActKind::Hard, 16);
                s.iter().map(|&v| e.process(v)).collect()
            })
            .collect();
        // batched over the sequential default
        let mut e = SparseMpGruDpd::new(sw.clone(), ActKind::Hard, 16);
        let mut states: Vec<DpdState> = (0..3)
            .map(|_| DpdState::DeltaI32(SparseMpGruDpd::<ScalarKernel>::fresh_state(&sw)))
            .collect();
        let mut lanes: Vec<DpdLane> = streams
            .iter_mut()
            .zip(states.iter_mut())
            .map(|(iq, state)| DpdLane { iq, state })
            .collect();
        e.process_lanes(&mut lanes).unwrap();
        for (got, want) in streams.iter().zip(&want) {
            assert_eq!(got, want, "batched lane diverged from solo");
        }
    }

    #[test]
    fn batch_fingerprint_separates_theta_and_mask() {
        let qw = QGruWeights::synthetic(2, QSpec::Q12);
        let fp = |rho: u8, theta: u32| {
            SparseMpGruDpd::new(qw.to_sparse(rho), ActKind::Hard, theta)
                .batch_fingerprint()
                .unwrap()
        };
        assert_eq!(fp(0, 0), fp(0, 0));
        assert_ne!(fp(0, 0), fp(0, 32), "theta is part of the identity");
        assert_ne!(fp(0, 0), fp(50, 0), "the mask is part of the identity");
        // and the sparse family never collides with the dense engine's
        let dense = QGruDpd::new(qw.clone(), ActKind::Hard);
        assert_ne!(fp(0, 0), dense.batch_fingerprint().unwrap());
    }
}
