//! Minimal complex-f64 type (no num-complex offline; this also keeps
//! the arithmetic identical to the python reference, which works in
//! explicit I/Q real pairs).

use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub, SubAssign};

/// Complex number with f64 parts. `re` = I, `im` = Q.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    pub const ZERO: C64 = C64 { re: 0.0, im: 0.0 };
    pub const ONE: C64 = C64 { re: 1.0, im: 0.0 };

    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        C64 { re, im }
    }

    /// e^{j theta}
    #[inline]
    pub fn cis(theta: f64) -> Self {
        let (s, c) = theta.sin_cos();
        C64 { re: c, im: s }
    }

    #[inline]
    pub fn conj(self) -> Self {
        C64 { re: self.re, im: -self.im }
    }

    /// |z|^2 (envelope squared — the preprocessor's feature).
    #[inline]
    pub fn norm_sq(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    #[inline]
    pub fn abs(self) -> f64 {
        self.norm_sq().sqrt()
    }

    #[inline]
    pub fn arg(self) -> f64 {
        self.im.atan2(self.re)
    }

    #[inline]
    pub fn scale(self, k: f64) -> Self {
        C64 { re: self.re * k, im: self.im * k }
    }

    /// Reciprocal (panics in debug on zero).
    #[inline]
    pub fn recip(self) -> Self {
        let d = self.norm_sq();
        debug_assert!(d > 0.0);
        C64 { re: self.re / d, im: -self.im / d }
    }
}

impl Add for C64 {
    type Output = C64;
    #[inline]
    fn add(self, o: C64) -> C64 {
        C64::new(self.re + o.re, self.im + o.im)
    }
}

impl Sub for C64 {
    type Output = C64;
    #[inline]
    fn sub(self, o: C64) -> C64 {
        C64::new(self.re - o.re, self.im - o.im)
    }
}

impl Mul for C64 {
    type Output = C64;
    #[inline]
    fn mul(self, o: C64) -> C64 {
        C64::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
}

impl Div for C64 {
    type Output = C64;
    #[inline]
    fn div(self, o: C64) -> C64 {
        self * o.recip()
    }
}

impl Neg for C64 {
    type Output = C64;
    #[inline]
    fn neg(self) -> C64 {
        C64::new(-self.re, -self.im)
    }
}

impl AddAssign for C64 {
    #[inline]
    fn add_assign(&mut self, o: C64) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl SubAssign for C64 {
    #[inline]
    fn sub_assign(&mut self, o: C64) {
        self.re -= o.re;
        self.im -= o.im;
    }
}

impl MulAssign for C64 {
    #[inline]
    fn mul_assign(&mut self, o: C64) {
        *self = *self * o;
    }
}

impl From<f64> for C64 {
    #[inline]
    fn from(re: f64) -> Self {
        C64 { re, im: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: C64, b: C64) -> bool {
        (a - b).abs() < 1e-12
    }

    #[test]
    fn field_identities() {
        let z = C64::new(3.0, -4.0);
        assert!(close(z + C64::ZERO, z));
        assert!(close(z * C64::ONE, z));
        assert!(close(z * z.recip(), C64::ONE));
        assert!(close(z / z, C64::ONE));
        assert!(close(-(-z), z));
    }

    #[test]
    fn abs_and_norm() {
        let z = C64::new(3.0, 4.0);
        assert_eq!(z.abs(), 5.0);
        assert_eq!(z.norm_sq(), 25.0);
        assert_eq!(z.conj().im, -4.0);
    }

    #[test]
    fn cis_unit_circle() {
        for k in 0..16 {
            let t = k as f64 * 0.4;
            let z = C64::cis(t);
            assert!((z.abs() - 1.0).abs() < 1e-12);
            assert!((z.arg() - t.sin().atan2(t.cos())).abs() < 1e-12);
        }
    }

    #[test]
    fn mul_matches_polar() {
        let a = C64::cis(0.7).scale(2.0);
        let b = C64::cis(0.5).scale(3.0);
        let p = a * b;
        assert!((p.abs() - 6.0).abs() < 1e-12);
        assert!((p.arg() - 1.2).abs() < 1e-12);
    }
}
