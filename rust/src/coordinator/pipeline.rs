//! The multi-stream streaming pipeline.
//!
//! Per stream, three stages run on their own threads, linked by
//! *bounded* channels (`sync_channel`) so a slow stage backpressures
//! the producer instead of buffering unboundedly:
//!
//! ```text
//!   source thread -> [frames] -> DPD worker -> [frames] -> sink
//! ```
//!
//! Engine construction and dispatch go through the unified
//! [`DpdEngine`](crate::runtime::DpdEngine) trait: the worker holds a
//! `Box<dyn DpdEngine>` built by an [`EngineFactory`] *inside* the
//! worker thread (the PJRT client behind the `Hlo` backend is not
//! `Send`); the factory itself resolves the manifest and the frame
//! length up front so the framer can match shape-specialized engines.
//! Multiple streams run fully in parallel — the mMIMO deployment
//! shape, one engine instance per antenna.

use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::framer::{Frame, Framer};
use super::stats::{LatencyAgg, PipelineStats};
use crate::runtime::EngineFactory;

pub use crate::runtime::EngineKind;

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub engine: EngineKind,
    /// frame length for the framer (frame-based engines override with
    /// their compiled frame size, see [`EngineFactory::frame_len`])
    pub frame_len: usize,
    /// bounded-channel depth (frames in flight per link)
    pub queue_depth: usize,
    /// artifact tree (None = discover)
    pub artifacts: Option<PathBuf>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            engine: EngineKind::Fixed,
            frame_len: 2048,
            queue_depth: 4,
            artifacts: None,
        }
    }
}

/// Output of one stream.
#[derive(Debug)]
pub struct StreamOutput {
    pub iq: Vec<[f64; 2]>,
    pub stats: PipelineStats,
}

/// The coordinator: runs N independent streams through the pipeline.
pub struct Coordinator {
    pub cfg: CoordinatorConfig,
}

enum Msg {
    Frame(Frame, Instant),
    Eof,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Coordinator {
        Coordinator { cfg }
    }

    /// Run one stream to completion.
    pub fn run_stream(&self, input: &[[f64; 2]]) -> Result<StreamOutput> {
        let outs = self.run_streams(vec![input.to_vec()])?;
        Ok(outs.into_iter().next().unwrap())
    }

    /// Run multiple independent streams in parallel (mMIMO shape).
    pub fn run_streams(&self, inputs: Vec<Vec<[f64; 2]>>) -> Result<Vec<StreamOutput>> {
        let mut handles = Vec::new();
        for input in inputs {
            let cfg = self.cfg.clone();
            handles.push(std::thread::spawn(move || run_one(cfg, input)));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("stream thread panicked"))
            .collect()
    }
}

fn run_one(cfg: CoordinatorConfig, input: Vec<[f64; 2]>) -> Result<StreamOutput> {
    // resolve the engine + frame geometry up front (manifest is Send;
    // the engine itself is built inside the worker thread)
    let factory = EngineFactory::new(cfg.engine, cfg.artifacts.as_deref())?;
    let frame_len = factory.frame_len(cfg.frame_len);

    let t_start = Instant::now();
    let n_in = input.len() as u64;
    let (tx_work, rx_work): (SyncSender<Msg>, Receiver<Msg>) = sync_channel(cfg.queue_depth);
    let (tx_done, rx_done): (SyncSender<Msg>, Receiver<Msg>) = sync_channel(cfg.queue_depth);

    // source + framer thread
    let src = std::thread::spawn(move || -> Result<()> {
        let mut framer = Framer::new(frame_len);
        for chunk in input.chunks(1024) {
            for fr in framer.push(chunk) {
                tx_work.send(Msg::Frame(fr, Instant::now())).ok();
            }
        }
        if let Some(fr) = framer.flush() {
            tx_work.send(Msg::Frame(fr, Instant::now())).ok();
        }
        tx_work.send(Msg::Eof).ok();
        Ok(())
    });

    // DPD worker thread: all engines behind the one DpdEngine trait
    let worker = std::thread::spawn(move || -> Result<Duration> {
        let mut eng = factory.build()?;
        eng.reset();
        let mut busy = Duration::ZERO;
        while let Ok(Msg::Frame(mut fr, t0)) = rx_work.recv() {
            let t = Instant::now();
            eng.process_frame(&mut fr.data)?;
            busy += t.elapsed();
            tx_done.send(Msg::Frame(fr, t0)).ok();
        }
        tx_done.send(Msg::Eof).ok();
        Ok(busy)
    });

    // sink (this thread)
    let mut out: Vec<[f64; 2]> = Vec::new();
    let mut frames = 0u64;
    let mut lat = LatencyAgg::default();
    let mut expected_seq = 0u64;
    while let Ok(msg) = rx_done.recv() {
        match msg {
            Msg::Frame(fr, t0) => {
                anyhow::ensure!(fr.seq == expected_seq, "frame reordering detected");
                expected_seq += 1;
                frames += 1;
                lat.record(t0.elapsed());
                out.extend_from_slice(&fr.data[..fr.valid]);
            }
            Msg::Eof => break,
        }
    }

    src.join().expect("source panicked")?;
    let busy = worker.join().expect("worker panicked")?;
    let wall = t_start.elapsed();
    let stats = PipelineStats {
        samples_in: n_in,
        samples_out: out.len() as u64,
        frames,
        wall,
        dpd_busy: busy,
        lat_mean: lat.mean(),
        lat_max: lat.max(),
    };
    Ok(StreamOutput { iq: out, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpd::qgru::{ActKind, QGruDpd};
    use crate::dpd::weights::QGruWeights;
    use crate::dpd::Dpd;
    use crate::fixed::QSpec;
    use crate::runtime::Manifest;
    use crate::util::Rng;

    fn artifacts_present() -> bool {
        Manifest::discover(None).is_ok()
    }

    fn signal(n: usize, seed: u64) -> Vec<[f64; 2]> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect()
    }

    #[test]
    fn conservation_and_order_fixed_engine() {
        if !artifacts_present() {
            eprintln!("skipping (no artifacts)");
            return;
        }
        let c = Coordinator::new(CoordinatorConfig {
            engine: EngineKind::Fixed,
            frame_len: 100,
            queue_depth: 2,
            artifacts: None,
        });
        let input = signal(1234, 1);
        let out = c.run_stream(&input).unwrap();
        assert_eq!(out.iq.len(), 1234);
        assert_eq!(out.stats.samples_in, 1234);
        assert_eq!(out.stats.samples_out, 1234);
        assert_eq!(out.stats.frames, 13);
    }

    #[test]
    fn pipeline_output_equals_direct_engine_run() {
        if !artifacts_present() {
            eprintln!("skipping (no artifacts)");
            return;
        }
        let input = signal(777, 2);
        let c = Coordinator::new(CoordinatorConfig {
            engine: EngineKind::Fixed,
            frame_len: 128,
            queue_depth: 3,
            artifacts: None,
        });
        let piped = c.run_stream(&input).unwrap();

        // direct: same engine, continuous stream (no reset per frame in
        // the pipeline either — state carries across frames)
        let m = Manifest::discover(None).unwrap();
        let spec = QSpec::new(m.qspec_bits).unwrap();
        let w = QGruWeights::load_params_int(&m.weights_main, spec).unwrap();
        let mut eng = QGruDpd::new(w, ActKind::Hard);
        let direct = eng.run(&input);
        assert_eq!(piped.iq, direct);
    }

    #[test]
    fn multi_stream_isolation() {
        if !artifacts_present() {
            eprintln!("skipping (no artifacts)");
            return;
        }
        let c = Coordinator::new(CoordinatorConfig {
            engine: EngineKind::Fixed,
            frame_len: 64,
            queue_depth: 2,
            artifacts: None,
        });
        let a = signal(500, 3);
        let b = signal(500, 4);
        let joint = c.run_streams(vec![a.clone(), b.clone()]).unwrap();
        let solo_a = c.run_stream(&a).unwrap();
        let solo_b = c.run_stream(&b).unwrap();
        assert_eq!(joint[0].iq, solo_a.iq);
        assert_eq!(joint[1].iq, solo_b.iq);
    }

    #[test]
    fn cycle_sim_engine_matches_fixed() {
        if !artifacts_present() {
            eprintln!("skipping (no artifacts)");
            return;
        }
        let input = signal(300, 5);
        let fixed = Coordinator::new(CoordinatorConfig {
            engine: EngineKind::Fixed,
            frame_len: 64,
            ..Default::default()
        })
        .run_stream(&input)
        .unwrap();
        let sim = Coordinator::new(CoordinatorConfig {
            engine: EngineKind::CycleSim,
            frame_len: 64,
            ..Default::default()
        })
        .run_stream(&input)
        .unwrap();
        assert_eq!(fixed.iq, sim.iq);
    }

    #[test]
    fn interp_engine_conserves_and_uses_artifact_frame() {
        if !artifacts_present() {
            eprintln!("skipping (no artifacts)");
            return;
        }
        let c = Coordinator::new(CoordinatorConfig {
            engine: EngineKind::Interp,
            ..Default::default()
        });
        let input = signal(3000, 8);
        let out = c.run_stream(&input).unwrap();
        assert_eq!(out.iq.len(), 3000);
        // frame count follows the artifact's compiled frame length
        let m = Manifest::discover(None).unwrap();
        if let Some(e) = m.best_int_hlo() {
            let expect = (3000 + e.time - 1) / e.time;
            assert_eq!(out.stats.frames, expect as u64);
        }
    }

    #[test]
    fn backpressure_small_queue_still_completes() {
        if !artifacts_present() {
            eprintln!("skipping (no artifacts)");
            return;
        }
        let c = Coordinator::new(CoordinatorConfig {
            engine: EngineKind::Fixed,
            frame_len: 32,
            queue_depth: 1,
            artifacts: None,
        });
        let input = signal(2000, 6);
        let out = c.run_stream(&input).unwrap();
        assert_eq!(out.iq.len(), 2000);
        assert!(out.stats.engine_msps() > 0.0);
    }
}
