//! Delay/gain alignment between two complex signals (cross-correlation
//! peak + LS complex gain). Used by the GMP indirect-learning fit and
//! by EVM measurement to line up the PA output with its reference.

use crate::util::C64;

/// Find the integer delay d in [-max_lag, max_lag] maximizing the
/// energy-normalized correlation |sum x(n) * conj(y(n-d))| /
/// sqrt(E_x * E_y) over the overlap, and the complex gain g
/// minimizing ||x - g*y_d||^2. Returns (delay, gain).
///
/// The normalization matters: the raw correlation sums over n - |d|
/// overlap samples, so on short correlated bursts the many-term
/// near-zero lags outweigh a true peak near max_lag. Dividing by the
/// overlap energies makes the metric a proper cosine similarity,
/// invariant to how many samples happen to overlap.
pub fn align(x: &[[f64; 2]], y: &[[f64; 2]], max_lag: usize) -> (i64, C64) {
    let n = x.len().min(y.len());
    let mut best = (0i64, 0.0f64);
    for d in -(max_lag as i64)..=(max_lag as i64) {
        let mut acc = C64::ZERO;
        let mut ex = 0.0f64;
        let mut ey = 0.0f64;
        for i in 0..n {
            let j = i as i64 - d;
            if j < 0 || j >= n as i64 {
                continue;
            }
            let xv = C64::new(x[i][0], x[i][1]);
            let yv = C64::new(y[j as usize][0], y[j as usize][1]);
            acc += xv * yv.conj();
            ex += xv.norm_sq();
            ey += yv.norm_sq();
        }
        let den = (ex * ey).sqrt();
        let mag = if den > 0.0 { acc.abs() / den } else { 0.0 };
        if mag > best.1 {
            best = (d, mag);
        }
    }
    let d = best.0;
    // complex LS gain at the chosen lag: g = <x, y_d> / <y_d, y_d>
    let mut num = C64::ZERO;
    let mut den = 0.0;
    for i in 0..n {
        let j = i as i64 - d;
        if j < 0 || j >= n as i64 {
            continue;
        }
        let xv = C64::new(x[i][0], x[i][1]);
        let yv = C64::new(y[j as usize][0], y[j as usize][1]);
        num += xv * yv.conj();
        den += yv.norm_sq();
    }
    let g = if den > 0.0 { num.scale(1.0 / den) } else { C64::ZERO };
    (d, g)
}

/// Apply (delay, gain): returns g * y(n - d) over the overlap range,
/// along with the matching slice of x, for residual computation.
pub fn apply_alignment(
    x: &[[f64; 2]],
    y: &[[f64; 2]],
    d: i64,
    g: C64,
) -> (Vec<[f64; 2]>, Vec<[f64; 2]>) {
    let n = x.len().min(y.len());
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for i in 0..n {
        let j = i as i64 - d;
        if j < 0 || j >= n as i64 {
            continue;
        }
        let yv = C64::new(y[j as usize][0], y[j as usize][1]) * g;
        xs.push(x[i]);
        ys.push([yv.re, yv.im]);
    }
    (xs, ys)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::Rng;

    #[test]
    fn recovers_known_delay_and_gain() {
        check("align recovers delay/gain", 25, |rng| {
            let n = 512;
            let sig: Vec<[f64; 2]> = (0..n).map(|_| [rng.gauss(), rng.gauss()]).collect();
            let d_true = rng.int_in(-20, 20);
            let g_true = C64::cis(rng.range(-3.0, 3.0)).scale(rng.range(0.5, 2.0));
            // x(n) = g * sig(n - d)
            let mut x = vec![[0.0; 2]; n];
            for i in 0..n {
                let j = i as i64 - d_true;
                if j >= 0 && (j as usize) < n {
                    let v = C64::new(sig[j as usize][0], sig[j as usize][1]) * g_true;
                    x[i] = [v.re, v.im];
                }
            }
            let (d, g) = align(&x, &sig, 32);
            if d != d_true {
                return Err(format!("delay {d} != {d_true}"));
            }
            if (g - g_true).abs() > 1e-6 {
                return Err(format!("gain {g:?} != {g_true:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn short_burst_delay_near_max_lag_is_not_biased_toward_zero() {
        // Regression: with the unnormalized correlation metric, a lag
        // of d sums over n - |d| overlap samples, so on a short burst
        // of *correlated* samples the many-term sums near lag 0 beat
        // the true peak near max_lag (48 * rho^28 > 20 here). The
        // energy-normalized metric recovers d = 28 for every one of
        // these seeds; the raw metric recovers none of them.
        for seed in 1..=20u64 {
            let mut rng = Rng::new(seed);
            let n = 48usize;
            let d_true = 28i64;
            let max_lag = 32usize;
            // complex AR(1) stream with rho(k) = alpha^k, unit power
            let alpha = 0.98f64;
            let beta = (1.0 - alpha * alpha).sqrt();
            let total = n + d_true as usize;
            let mut s = Vec::with_capacity(total);
            let mut cur = C64::new(rng.gauss(), rng.gauss());
            for _ in 0..total {
                s.push(cur);
                cur = cur.scale(alpha) + C64::new(rng.gauss(), rng.gauss()).scale(beta);
            }
            // x and y are overlapping windows of the same stream:
            // x(i) = s(i), y(j) = s(j + d_true) + noise, so
            // x(i) ~ y(i - d_true) and the true delay is +d_true.
            let x: Vec<[f64; 2]> = (0..n).map(|i| [s[i].re, s[i].im]).collect();
            let y: Vec<[f64; 2]> = (0..n)
                .map(|j| {
                    let v = s[j + d_true as usize]
                        + C64::new(rng.gauss(), rng.gauss()).scale(0.05);
                    [v.re, v.im]
                })
                .collect();
            let (d, _g) = align(&x, &y, max_lag);
            assert_eq!(d, d_true, "seed {seed}: detected delay {d}, want {d_true}");
        }
    }

    #[test]
    fn zero_residual_after_alignment() {
        let mut rng = Rng::new(4);
        let n = 256;
        let sig: Vec<[f64; 2]> = (0..n).map(|_| [rng.gauss(), rng.gauss()]).collect();
        let g = C64::new(0.8, 0.3);
        let x: Vec<[f64; 2]> = sig
            .iter()
            .map(|&[a, b]| {
                let v = C64::new(a, b) * g;
                [v.re, v.im]
            })
            .collect();
        let (d, gg) = align(&x, &sig, 8);
        let (xs, ys) = apply_alignment(&x, &sig, d, gg);
        let err: f64 = xs
            .iter()
            .zip(&ys)
            .map(|(a, b)| (a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2))
            .sum();
        assert!(err < 1e-18, "residual {err}");
    }
}
