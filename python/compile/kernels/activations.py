"""Gate activation functions of the DPD-NeuralEngine.

The paper compares two hardware implementations (§III-B, Fig. 3/4):

* **PWL (Hardsigmoid / Hardtanh)** — Eq. (7)/(8); comparators and a
  shifter in hardware; the chip's choice.
* **LUT** — a ROM holding the true sigmoid/tanh sampled on a uniform
  grid; the baseline that costs ~20k FPGA LUTs.

Both exist in a float view (for QAT) and an integer view (bit-exact with
the Rust datapath). The integer Hardsigmoid uses a *floor* shift for the
/4 — that is what a hardware shifter does — while the float/QAT view
uses exact division; the discrepancy is below 1 LSB and only the integer
view is used for inference parity.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .quant import QSpec, fake_quant

__all__ = [
    "hardsigmoid",
    "hardtanh",
    "hardsigmoid_int",
    "hardtanh_int",
    "LutSpec",
    "make_sigmoid_table",
    "make_tanh_table",
    "lut_activation",
    "lut_activation_int",
]

# ---------------------------------------------------------------------------
# PWL (hard) activations
# ---------------------------------------------------------------------------


def hardsigmoid(x: jnp.ndarray) -> jnp.ndarray:
    """Eq. (7): 0 below -2, x/4 + 1/2 inside, 1 above 2."""
    return jnp.clip(x * 0.25 + 0.5, 0.0, 1.0)


def hardtanh(x: jnp.ndarray) -> jnp.ndarray:
    """Eq. (8): clamp to [-1, 1]."""
    return jnp.clip(x, -1.0, 1.0)


def hardsigmoid_int(x: jnp.ndarray, spec: QSpec) -> jnp.ndarray:
    """Integer Hardsigmoid on Q2.f codes.

    y = clip((x >> 2) + 0.5, 0, 1) in the code domain. ``x >> 2`` is the
    hardware shifter (arithmetic, floor); 0.5 and 1.0 are the codes
    ``1 << (f-1)`` and ``1 << f``.
    """
    half = 1 << (spec.frac - 1)
    one = 1 << spec.frac
    return jnp.clip(jnp.right_shift(x, 2) + half, 0, one)


def hardtanh_int(x: jnp.ndarray, spec: QSpec) -> jnp.ndarray:
    """Integer Hardtanh on Q2.f codes: clamp to ±(1 << f)."""
    one = 1 << spec.frac
    return jnp.clip(x, -one, one)


# ---------------------------------------------------------------------------
# LUT activations (the paper's baseline)
# ---------------------------------------------------------------------------


class LutSpec:
    """Uniform-grid lookup table over ``[lo, hi)`` with ``n`` entries.

    Address generation matches the hardware: the Q2.f input code is
    offset by ``lo`` and floor-shifted so that each table entry covers
    ``2^shift`` input codes. Out-of-range inputs clamp to the first/last
    entry (the ROM's guard entries hold the asymptotic values).
    """

    def __init__(self, lo: float = -4.0, hi: float = 4.0, addr_bits: int = 10):
        self.lo = lo
        self.hi = hi
        self.addr_bits = addr_bits
        self.n = 1 << addr_bits

    def centers(self) -> np.ndarray:
        step = (self.hi - self.lo) / self.n
        return self.lo + step * (np.arange(self.n) + 0.5)

    def index_float(self, x: jnp.ndarray) -> jnp.ndarray:
        step = (self.hi - self.lo) / self.n
        idx = jnp.floor((x - self.lo) / step).astype(jnp.int32)
        return jnp.clip(idx, 0, self.n - 1)

    def index_int(self, x_code: jnp.ndarray, spec: QSpec) -> jnp.ndarray:
        """Address from a Q2.f code using shift-based hardware addressing.

        Requires the span/``n`` ratio to be a power-of-two multiple of the
        LSB, which holds for the default (span 8, n=1024, f>=7).
        """
        span_codes = int(round((self.hi - self.lo) * spec.scale))
        per_entry = span_codes // self.n
        if per_entry < 1:
            # Finer table than the input grid: direct offset addressing.
            lo_code = int(round(self.lo * spec.scale))
            idx = (x_code - lo_code) * (self.n // max(span_codes, 1))
            return jnp.clip(idx, 0, self.n - 1)
        shift = int(per_entry).bit_length() - 1
        assert (1 << shift) == per_entry, "table span must divide power-of-two"
        lo_code = int(round(self.lo * spec.scale))
        idx = jnp.right_shift(x_code - lo_code, shift)
        return jnp.clip(idx, 0, self.n - 1)


def make_sigmoid_table(lut: LutSpec, spec: QSpec) -> np.ndarray:
    """Sigmoid ROM contents as Q2.f codes (int32)."""
    vals = 1.0 / (1.0 + np.exp(-lut.centers()))
    return np.clip(np.floor(vals * spec.scale + 0.5), spec.qmin, spec.qmax).astype(np.int32)


def make_tanh_table(lut: LutSpec, spec: QSpec) -> np.ndarray:
    """Tanh ROM contents as Q2.f codes (int32)."""
    vals = np.tanh(lut.centers())
    return np.clip(np.floor(vals * spec.scale + 0.5), spec.qmin, spec.qmax).astype(np.int32)


def lut_activation(x: jnp.ndarray, table_codes: jnp.ndarray, lut: LutSpec, spec: QSpec) -> jnp.ndarray:
    """Float view of the LUT activation (for QAT): gather + dequantize.

    The gather is non-differentiable; QAT uses an STE against the smooth
    function so gradients still flow (handled by the caller via
    ``fake_quant``-style composition).
    """
    idx = lut.index_float(fake_quant(x, spec))
    return jnp.take(table_codes, idx).astype(jnp.float32) / spec.scale


def lut_activation_int(x_code: jnp.ndarray, table_codes: jnp.ndarray, lut: LutSpec, spec: QSpec) -> jnp.ndarray:
    """Integer view: ROM read addressed by the shifted input code."""
    idx = lut.index_int(x_code, spec)
    return jnp.take(table_codes, idx)
