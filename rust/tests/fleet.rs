//! Fleet integration tests — the acceptance gates of the fleet layer.
//!
//! Three contracts, all hermetic (synthetic weights, no artifact
//! tree) and all watchdog-guarded so a placement/admission/drain
//! deadlock fails the test instead of hanging CI:
//!
//! 1. **Parity.** A session opened through a [`Fleet`] is bit-identical
//!    to a direct single-service session for every integer engine spec
//!    — placement moves *where* a session runs, never *what* it
//!    computes.
//! 2. **Admission.** The (cap+1)-th open is rejected with a typed
//!    [`AdmissionError`] while the already-admitted sessions keep
//!    streaming, unperturbed, to bit-exact completion.
//! 3. **Graceful drain.** Under multi-threaded open/push/finish churn,
//!    `drain` stops admission, waits for every in-flight frame to
//!    flush, and joins every shard without losing a sample.
//!
//! CI runs this file as its own watchdog-guarded step (the `fleet`
//! job), debug and release.

use std::time::Duration;

use anyhow::Result;
use dpd_ne::coordinator::{
    AdmissionConfig, AdmissionError, DpdService, Fleet, FleetConfig, FleetSession,
    ServiceConfig, SessionConfig, ShardPolicy,
};
use dpd_ne::runtime::{build_synthetic, DpdEngine as _, EngineKind};
use dpd_ne::util::Rng;

const WATCHDOG: Duration = Duration::from_secs(120);

fn signal(n: usize, seed: u64) -> Vec<[f64; 2]> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| [rng.gauss() * 0.25, rng.gauss() * 0.25]).collect()
}

/// run `f` on its own thread and fail loudly if it doesn't complete —
/// the session_stress pattern: CI sees a test failure, not a hung job
fn with_watchdog(name: &'static str, f: impl FnOnce() -> Result<()> + Send + 'static) {
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    let runner = std::thread::spawn(move || {
        let r = f();
        done_tx.send(()).ok();
        r
    });
    match done_rx.recv_timeout(WATCHDOG) {
        Ok(()) => runner.join().expect("fleet test runner panicked").unwrap(),
        Err(_) => panic!("{name} did not complete within {WATCHDOG:?} — fleet deadlock?"),
    }
}

/// every integer engine spec the fleet must serve bit-identically
/// (hlo is xla-gated; native is float — covered by the loadgen mix)
const INTEGER_SPECS: &[&str] =
    &["fixed", "fixed+simd", "delta:0", "delta:32", "delta:32+simd", "cyclesim", "interp"];

#[test]
fn fleet_sessions_bit_identical_to_direct_service_for_every_integer_spec() {
    with_watchdog("fleet parity", || {
        const FRAME: usize = 64;
        let input = signal(1200, 77);
        let fleet = Fleet::start(FleetConfig {
            shards: 3,
            service: ServiceConfig { workers: 2, frame_len: FRAME, ..Default::default() },
            policy: ShardPolicy::LeastLoaded,
            ..Default::default()
        })?;
        let direct = DpdService::start(ServiceConfig {
            workers: 1,
            frame_len: FRAME,
            ..Default::default()
        })?;
        for spec in INTEGER_SPECS {
            let kind = EngineKind::parse(spec)?;
            let scfg = SessionConfig { engine: kind, ..Default::default() };
            let mut fs = fleet.open_session_with(scfg, move || {
                build_synthetic(kind, 42, Default::default(), Some(FRAME))
            })?;
            let mut ds = direct.open_session_with(scfg, move || {
                build_synthetic(kind, 42, Default::default(), Some(FRAME))
            })?;
            // different chunkings on purpose: parity must not depend on
            // push boundaries, only on the sample stream
            let mut got_fleet = Vec::new();
            for chunk in input.chunks(123) {
                fs.push(chunk)?;
                got_fleet.extend(fs.drain()?);
            }
            got_fleet.extend(fs.finish()?.iq);
            let mut got_direct = Vec::new();
            for chunk in input.chunks(500) {
                ds.push(chunk)?;
                got_direct.extend(ds.drain()?);
            }
            got_direct.extend(ds.finish()?.iq);
            anyhow::ensure!(
                got_fleet.len() == input.len(),
                "spec {spec}: fleet session lost samples ({}/{})",
                got_fleet.len(),
                input.len()
            );
            anyhow::ensure!(
                got_fleet == got_direct,
                "spec {spec}: fleet session diverged from the direct service session"
            );
        }
        direct.shutdown()?;
        let stats = fleet.drain()?;
        anyhow::ensure!(stats.sessions_open == 0 && stats.sessions_rejected == 0);
        anyhow::ensure!(stats.sessions_drained == INTEGER_SPECS.len() as u64);
        Ok(())
    });
}

#[test]
fn over_cap_open_rejects_typed_while_admitted_sessions_keep_streaming() {
    with_watchdog("fleet admission", || {
        const CAP: usize = 4;
        let fleet = Fleet::start(FleetConfig {
            shards: 2,
            service: ServiceConfig { workers: 1, frame_len: 32, ..Default::default() },
            policy: ShardPolicy::RoundRobin,
            admission: AdmissionConfig { max_sessions: CAP, ..Default::default() },
        })?;
        let inputs: Vec<Vec<[f64; 2]>> = (0..CAP).map(|k| signal(700, 50 + k as u64)).collect();
        let mut sessions: Vec<FleetSession> = (0..CAP)
            .map(|k| {
                let seed = 50 + k as u64;
                fleet.open_session_with(SessionConfig::default(), move || {
                    build_synthetic(EngineKind::fixed(), seed, Default::default(), Some(32))
                })
            })
            .collect::<Result<_>>()?;
        // half the stream is in flight when the rejection happens
        for (k, s) in sessions.iter_mut().enumerate() {
            s.push(&inputs[k][..350])?;
        }
        let err = fleet
            .open_session_with(SessionConfig::default(), move || {
                build_synthetic(EngineKind::fixed(), 99, Default::default(), Some(32))
            })
            .expect_err("the (cap+1)-th session must be rejected");
        anyhow::ensure!(
            err.downcast_ref::<AdmissionError>()
                == Some(&AdmissionError::FleetFull { limit: CAP }),
            "rejection must be the typed FleetFull error, got: {err:#}"
        );
        // the rejection must not have perturbed the admitted sessions:
        // they stream to completion, bit-identical to the direct engine
        for (k, s) in sessions.iter_mut().enumerate() {
            s.push(&inputs[k][350..])?;
        }
        for (k, s) in sessions.into_iter().enumerate() {
            let seed = 50 + k as u64;
            let mut oracle = build_synthetic(EngineKind::fixed(), seed, Default::default(), None)?;
            let mut want = inputs[k].clone();
            for frame in want.chunks_mut(32) {
                oracle.process_frame(frame)?;
            }
            let out = s.finish()?;
            anyhow::ensure!(
                out.iq == want,
                "session {k} corrupted by the over-cap rejection"
            );
        }
        let stats = fleet.drain()?;
        anyhow::ensure!(stats.sessions_rejected == 1, "exactly one typed rejection");
        anyhow::ensure!(stats.sessions_drained == CAP as u64);
        Ok(())
    });
}

#[test]
fn per_shard_cap_spills_then_rejects_shard_full() {
    with_watchdog("fleet per-shard admission", || {
        let fleet = Fleet::start(FleetConfig {
            shards: 2,
            service: ServiceConfig { workers: 1, frame_len: 32, ..Default::default() },
            policy: ShardPolicy::StickyByClass,
            admission: AdmissionConfig { max_sessions_per_shard: 1, ..Default::default() },
        })?;
        // same spec twice: the first takes the sticky home, the second
        // spills to the other shard rather than rejecting
        let open = |seed: u64| {
            fleet.open_session_with(SessionConfig::default(), move || {
                build_synthetic(EngineKind::fixed(), seed, Default::default(), Some(32))
            })
        };
        let a = open(1)?;
        let b = open(2)?;
        anyhow::ensure!(a.shard() != b.shard(), "full home must spill, not stack");
        let err = open(3).expect_err("both shards at per-shard cap");
        anyhow::ensure!(
            matches!(
                err.downcast_ref::<AdmissionError>(),
                Some(&AdmissionError::ShardFull { limit: 1, .. })
            ),
            "expected the typed ShardFull error, got: {err:#}"
        );
        drop((a, b));
        fleet.drain()?;
        Ok(())
    });
}

#[test]
fn graceful_drain_under_churn_flushes_every_in_flight_frame() {
    with_watchdog("fleet drain under churn", || {
        let fleet = Fleet::start(FleetConfig {
            shards: 2,
            service: ServiceConfig {
                workers: 1,
                queue_depth: 1,
                frame_len: 32,
                ..Default::default()
            },
            policy: ShardPolicy::LeastLoaded,
            ..Default::default()
        })?;

        // phase 1 — churn: 3 threads x 8 short-lived sessions racing
        // opens, pushes and closes through the placement lock
        std::thread::scope(|scope| -> Result<()> {
            let fr = &fleet;
            let churners: Vec<_> = (0..3u64)
                .map(|t| {
                    scope.spawn(move || -> Result<()> {
                        for k in 0..8u64 {
                            let seed = t * 100 + k;
                            let mut sess = fr.open_session_with(
                                SessionConfig::default(),
                                move || {
                                    build_synthetic(
                                        EngineKind::fixed(),
                                        seed,
                                        Default::default(),
                                        Some(32),
                                    )
                                },
                            )?;
                            let sig = signal(400 + 37 * k as usize, seed);
                            for chunk in sig.chunks(97) {
                                sess.push(chunk)?;
                            }
                            let out = sess.finish()?;
                            anyhow::ensure!(
                                out.iq.len() == sig.len(),
                                "churn session lost samples: {}/{}",
                                out.iq.len(),
                                sig.len()
                            );
                        }
                        Ok(())
                    })
                })
                .collect();
            for c in churners {
                c.join().expect("churn thread panicked")?;
            }
            Ok(())
        })?;

        // phase 2 — drain concurrent with live sessions: open sessions
        // with frames still in flight, start drain on another thread,
        // then flush + finish while the drain is already waiting
        let held: Vec<(FleetSession, Vec<[f64; 2]>)> = (0..4u64)
            .map(|k| -> Result<_> {
                let mut sess = fleet.open_session_with(SessionConfig::default(), move || {
                    build_synthetic(EngineKind::fixed(), 500 + k, Default::default(), Some(32))
                })?;
                let sig = signal(600, 700 + k);
                sess.push(&sig[..300])?;
                Ok((sess, sig))
            })
            .collect::<Result<_>>()?;
        let drainer = std::thread::spawn(move || fleet.drain());
        // give drain a moment to raise the draining flag and start
        // polling, so the finishes below genuinely race it
        std::thread::sleep(Duration::from_millis(20));
        for (mut sess, sig) in held {
            sess.push(&sig[300..])?;
            let out = sess.finish()?;
            anyhow::ensure!(
                out.iq.len() == sig.len(),
                "drain lost in-flight frames: {}/{}",
                out.iq.len(),
                sig.len()
            );
        }
        let stats = drainer.join().expect("drainer thread panicked")?;
        anyhow::ensure!(stats.draining && stats.sessions_open == 0);
        anyhow::ensure!(
            stats.sessions_drained == stats.sessions_opened,
            "every admitted session must be accounted drained: {}/{}",
            stats.sessions_drained,
            stats.sessions_opened
        );
        anyhow::ensure!(stats.sessions_opened == 3 * 8 + 4);
        anyhow::ensure!(
            stats.shards.iter().all(|s| s.queue_depth == 0),
            "drained fleet must hold no in-flight frames"
        );
        anyhow::ensure!(!stats.latency.is_empty(), "churn must have stamped latencies");
        Ok(())
    });
}

/// Satellite regression (drain-deadline bugfix): a leaked session
/// handle used to spin `drain()`'s 500 µs poll loop forever — the
/// open count can never reach zero if an owner forgets its handle.
/// With `drain_deadline` set, drain must terminate with a typed
/// [`DrainTimeout`] that counts the stuck sessions, and a fleet
/// without leaks must be entirely unaffected by the deadline.
#[test]
fn drain_with_leaked_handle_times_out_with_typed_error() {
    with_watchdog("drain deadline", || {
        let fleet = Fleet::start(FleetConfig {
            shards: 2,
            service: ServiceConfig { workers: 1, frame_len: 32, ..Default::default() },
            drain_deadline: Some(Duration::from_millis(200)),
            ..Default::default()
        })?;
        // a healthy session, finished properly...
        let mut ok = fleet.open_session_with(SessionConfig::default(), || {
            build_synthetic(EngineKind::fixed(), 11, Default::default(), Some(32))
        })?;
        ok.push(&signal(64, 5))?;
        ok.finish()?;
        // ...and two handles their owner leaks (mem::forget models a
        // crashed/wedged owner thread that never drops)
        for k in 0..2u64 {
            let leaked = fleet.open_session_with(SessionConfig::default(), move || {
                build_synthetic(EngineKind::fixed(), 20 + k, Default::default(), Some(32))
            })?;
            std::mem::forget(leaked);
        }
        let err = match fleet.drain() {
            Ok(_) => anyhow::bail!("drain must not succeed with leaked handles"),
            Err(e) => e,
        };
        let timeout = err
            .downcast_ref::<dpd_ne::coordinator::DrainTimeout>()
            .ok_or_else(|| anyhow::anyhow!("expected DrainTimeout, got: {err:#}"))?;
        anyhow::ensure!(
            timeout.stuck_sessions == 2,
            "stuck count must name both leaked handles: {timeout}"
        );
        anyhow::ensure!(timeout.deadline == Duration::from_millis(200));

        // control: the same deadline on a leak-free fleet drains clean
        let fleet = Fleet::start(FleetConfig {
            shards: 2,
            service: ServiceConfig { workers: 1, frame_len: 32, ..Default::default() },
            drain_deadline: Some(Duration::from_secs(30)),
            ..Default::default()
        })?;
        let mut s = fleet.open_session_with(SessionConfig::default(), || {
            build_synthetic(EngineKind::fixed(), 31, Default::default(), Some(32))
        })?;
        s.push(&signal(64, 6))?;
        s.finish()?;
        let stats = fleet.drain()?;
        anyhow::ensure!(stats.sessions_open == 0 && stats.draining);
        Ok(())
    });
}
