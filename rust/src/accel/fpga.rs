//! Zynq-7020 (XC7Z020) resource estimator — Table I and Fig. 4.
//!
//! Component-level model of the FPGA emulation of DPD-NeuralEngine.
//! The FPGA prototype time-multiplexes the 156-PE design onto the
//! DSP48E1 slices; what distinguishes the two Table I rows is the
//! activation implementation:
//!
//! * **LUT-Sigmoid/Tanh (baseline)**: each nonlinear function is a
//!   synthesized 12-bit-in -> 12-bit-out combinational table. Logic
//!   synthesis of a smooth 12b function costs ~700 LUT6 per output
//!   bit, i.e. ~8.5k LUTs for sigmoid and ~8.1k for tanh — which is
//!   how the paper's baseline ends up spending more LUTs on the two
//!   activations than on all the MACs combined (Fig. 4).
//! * **Hardsigmoid/Hardtanh**: comparators + shifter + mux per lane —
//!   two orders of magnitude cheaper (the paper reports 18.9x and
//!   35.3x reductions).
//!
//! Numbers are calibrated against Table I's published totals; the
//! *structure* (what scales with what) is the model's content.

/// Zynq-7020 available resources (Table I header row).
#[derive(Clone, Copy, Debug)]
pub struct FpgaDevice {
    pub lut: usize,
    pub ff: usize,
    pub dsp: usize,
    pub bram: usize,
}

pub const ZYNQ_7020: FpgaDevice = FpgaDevice { lut: 53_200, ff: 106_400, dsp: 220, bram: 140 };

/// Activation implementation selector for the estimate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FpgaAct {
    LutTables,
    Hard,
}

/// Component-level resource costs.
#[derive(Clone, Debug)]
pub struct FpgaCostModel {
    /// DSP48E1 slices used for the time-multiplexed MAC datapath
    pub dsp_macs: usize,
    /// extra DSPs the synthesizer spends when activations are cheap
    /// enough to rebalance the datapath (Table I: 85 -> 95)
    pub dsp_extra_hard: usize,
    /// LUTs per MAC lane of glue (operand mux, requantize, saturate)
    pub lut_per_mac_lane: usize,
    /// control/FSM + AXI interface LUTs
    pub lut_control: usize,
    /// LUTs for a synthesized 12b sigmoid table
    pub lut_sigmoid_table: usize,
    /// LUTs for a synthesized 12b tanh table
    pub lut_tanh_table: usize,
    /// LUTs per hard-sigmoid lane (comparators+shifter+mux)
    pub lut_hard_sigmoid_lane: usize,
    /// LUTs per hard-tanh lane (clamp)
    pub lut_hard_tanh_lane: usize,
    /// flip-flops: pipeline + buffers, per DSP lane and fixed
    pub ff_per_lane: usize,
    pub ff_fixed: usize,
    /// extra FFs the LUT-table variant needs (table output pipelining)
    pub ff_lut_extra: usize,
    pub sigmoid_lanes: usize,
    pub tanh_lanes: usize,
}

impl Default for FpgaCostModel {
    fn default() -> Self {
        FpgaCostModel {
            dsp_macs: 85,
            dsp_extra_hard: 10,
            lut_per_mac_lane: 26,
            lut_control: 1900,
            lut_sigmoid_table: 8504,
            lut_tanh_table: 8118,
            lut_hard_sigmoid_lane: 23,
            lut_hard_tanh_lane: 23,
            ff_per_lane: 30,
            ff_fixed: 606,
            ff_lut_extra: 763,
            sigmoid_lanes: 20,
            tanh_lanes: 10,
        }
    }
}

/// An estimated utilization row (Table I format).
#[derive(Clone, Copy, Debug)]
pub struct FpgaUtilization {
    pub lut: usize,
    pub ff: usize,
    pub dsp: usize,
    pub bram: usize,
}

impl FpgaUtilization {
    pub fn pct(&self, dev: &FpgaDevice) -> (f64, f64, f64, f64) {
        (
            100.0 * self.lut as f64 / dev.lut as f64,
            100.0 * self.ff as f64 / dev.ff as f64,
            100.0 * self.dsp as f64 / dev.dsp as f64,
            100.0 * self.bram as f64 / dev.bram as f64,
        )
    }
}

/// Per-block LUT breakdown (Fig. 4's bar chart).
#[derive(Clone, Debug)]
pub struct LutBreakdown {
    pub pe_array: usize,
    pub sigmoid: usize,
    pub tanh: usize,
    pub control: usize,
}

impl LutBreakdown {
    pub fn total(&self) -> usize {
        self.pe_array + self.sigmoid + self.tanh + self.control
    }
}

impl FpgaCostModel {
    pub fn estimate(&self, act: FpgaAct) -> (FpgaUtilization, LutBreakdown) {
        let dsp = match act {
            FpgaAct::LutTables => self.dsp_macs,
            FpgaAct::Hard => self.dsp_macs + self.dsp_extra_hard,
        };
        let pe_array = dsp * self.lut_per_mac_lane;
        let (sigmoid, tanh) = match act {
            FpgaAct::LutTables => (self.lut_sigmoid_table, self.lut_tanh_table),
            FpgaAct::Hard => (
                self.lut_hard_sigmoid_lane * self.sigmoid_lanes,
                self.lut_hard_tanh_lane * self.tanh_lanes,
            ),
        };
        let breakdown = LutBreakdown { pe_array, sigmoid, tanh, control: self.lut_control };
        let ff = self.ff_fixed
            + dsp * self.ff_per_lane
            + if act == FpgaAct::LutTables { self.ff_lut_extra } else { 0 };
        let util = FpgaUtilization {
            lut: breakdown.total(),
            ff,
            dsp,
            bram: 0, // weights fit in distributed RAM / registers
        };
        (util, breakdown)
    }

    /// The paper's headline reduction factors (Fig. 4): LUT cost of
    /// each function, LUT-table vs hard implementation.
    pub fn reduction_factors(&self) -> (f64, f64) {
        (
            self.lut_sigmoid_table as f64 / (self.lut_hard_sigmoid_lane * self.sigmoid_lanes) as f64,
            self.lut_tanh_table as f64 / (self.lut_hard_tanh_lane * self.tanh_lanes) as f64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PAPER_LUT_BASELINE: usize = 20_522;
    const PAPER_LUT_HARD: usize = 5_439;
    const PAPER_FF_BASELINE: usize = 3_969;
    const PAPER_FF_HARD: usize = 3_156;
    const PAPER_DSP_BASELINE: usize = 85;
    const PAPER_DSP_HARD: usize = 95;

    fn rel(a: usize, b: usize) -> f64 {
        (a as f64 - b as f64).abs() / b as f64
    }

    #[test]
    fn table1_baseline_row() {
        let (u, _) = FpgaCostModel::default().estimate(FpgaAct::LutTables);
        assert!(rel(u.lut, PAPER_LUT_BASELINE) < 0.10, "LUT {}", u.lut);
        assert!(rel(u.ff, PAPER_FF_BASELINE) < 0.10, "FF {}", u.ff);
        assert_eq!(u.dsp, PAPER_DSP_BASELINE);
        assert_eq!(u.bram, 0);
    }

    #[test]
    fn table1_hard_row() {
        let (u, _) = FpgaCostModel::default().estimate(FpgaAct::Hard);
        assert!(rel(u.lut, PAPER_LUT_HARD) < 0.10, "LUT {}", u.lut);
        assert!(rel(u.ff, PAPER_FF_HARD) < 0.10, "FF {}", u.ff);
        assert_eq!(u.dsp, PAPER_DSP_HARD);
        assert_eq!(u.bram, 0);
    }

    #[test]
    fn fig4_reduction_factors() {
        let (sig, tanh) = FpgaCostModel::default().reduction_factors();
        assert!((sig - 18.9).abs() < 0.8, "sigmoid reduction {sig:.1}x");
        assert!((tanh - 35.3).abs() < 1.5, "tanh reduction {tanh:.1}x");
    }

    #[test]
    fn fig4_activation_dominance_in_baseline() {
        let (_, b) = FpgaCostModel::default().estimate(FpgaAct::LutTables);
        // the paper's headline: LUT activations cost more than the PEs
        assert!(b.sigmoid + b.tanh > b.pe_array);
        assert!(b.sigmoid + b.tanh > 15_000);
    }

    #[test]
    fn fits_the_device() {
        for act in [FpgaAct::LutTables, FpgaAct::Hard] {
            let (u, _) = FpgaCostModel::default().estimate(act);
            assert!(u.lut <= ZYNQ_7020.lut);
            assert!(u.ff <= ZYNQ_7020.ff);
            assert!(u.dsp <= ZYNQ_7020.dsp);
        }
    }

    #[test]
    fn utilization_percentages() {
        let (u, _) = FpgaCostModel::default().estimate(FpgaAct::Hard);
        let (lut_pct, _, dsp_pct, _) = u.pct(&ZYNQ_7020);
        // paper: 10.2% LUT, 43.2% DSP
        assert!((lut_pct - 10.2).abs() < 1.5, "LUT% {lut_pct:.1}");
        assert!((dsp_pct - 43.2).abs() < 1.0, "DSP% {dsp_pct:.1}");
    }
}
