"""OFDM 64-QAM dataset generation (numpy, build path only).

Stand-in for the paper's two signal sets (the 200 MHz OpenDPD capture
and the 80 MHz 64-QAM OFDM bench signal): a CP-OFDM 64-QAM baseband
with ~9 dB PAPR, oversampled 4x so the adjacent channels needed for
ACPR are inside the simulated band. Two spectrum-containment stages
mirror a real transmit chain:

* raised-cosine symbol windowing (weighted overlap-add) to soften the
  CP-OFDM symbol transitions;
* a windowed-sinc (Kaiser) TX lowpass whose transition fits inside the
  channel raster's guard band.

After both, the clean signal's ACPR floor is below -130 dBc, so every
dBc measured downstream is PA distortion, not generator leakage. The
rust generator (``rust/src/signal``) implements the identical
construction; parity is checked in the rust test-suite.

Channel raster (normalized to fs): occupied BW 0.25, channel spacing
0.275 (i.e. 10% guard), adjacent channels at ±0.275 — with fs mapped to
250 MSps this is a 62.5 MHz signal, matching the paper's 60 MHz f_BB
operating point.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = [
    "OfdmConfig",
    "generate_ofdm",
    "papr_db",
    "frames_from_signal",
    "kaiser_lowpass",
    "qam_constellation",
    "used_bins",
]


@dataclass(frozen=True)
class OfdmConfig:
    nfft: int = 256
    n_used: int = 64          # occupied subcarriers (DC excluded) -> 4x oversampling
    cp: int = 16
    qam: int = 64
    n_symbols: int = 64
    rms: float = 0.25
    seed: int = 0
    window: int = 12          # RC taper length, must be <= cp (0 = rectangular)
    fir_taps: int = 511       # TX lowpass (0 = no filter)
    fir_cutoff: float = 0.130
    fir_beta: float = 10.0


def qam_constellation(order: int) -> np.ndarray:
    """Square QAM constellation, unit average power."""
    side = int(round(np.sqrt(order)))
    assert side * side == order, "square QAM only"
    levels = 2 * np.arange(side) - (side - 1)
    re, im = np.meshgrid(levels, levels)
    pts = (re + 1j * im).reshape(-1)
    return pts / np.sqrt((np.abs(pts) ** 2).mean())


def used_bins(cfg: OfdmConfig) -> np.ndarray:
    """Occupied FFT bin indices: symmetric around DC, DC itself unused."""
    half = cfg.n_used // 2
    pos = np.arange(1, half + 1)
    neg = cfg.nfft - np.arange(1, cfg.n_used - half + 1)
    return np.concatenate([pos, neg])


def kaiser_lowpass(ntaps: int, cutoff: float, beta: float) -> np.ndarray:
    """Windowed-sinc lowpass, unity DC gain. ``cutoff`` in cycles/sample."""
    n = np.arange(ntaps) - (ntaps - 1) / 2
    h = 2 * cutoff * np.sinc(2 * cutoff * n) * np.kaiser(ntaps, beta)
    return h / h.sum()


def generate_ofdm(cfg: OfdmConfig) -> np.ndarray:
    """Generate a windowed, filtered CP-OFDM burst. Returns (T, 2) f64.

    T = n_symbols * (nfft + cp). Deterministic in cfg.seed.
    """
    rng = np.random.default_rng(cfg.seed)
    const = qam_constellation(cfg.qam)
    bins = used_bins(cfg)
    win = cfg.window
    assert win <= cfg.cp, "RC taper must fit inside the CP (win <= cp)"
    sym_len = cfg.nfft + cfg.cp

    if win > 0:
        t = (np.arange(win) + 0.5) / win
        edge = 0.5 * (1 - np.cos(np.pi * t))
    x = np.zeros(cfg.n_symbols * sym_len + win, dtype=np.complex128)
    for s in range(cfg.n_symbols):
        syms = const[rng.integers(0, len(const), size=cfg.n_used)]
        spec = np.zeros(cfg.nfft, dtype=np.complex128)
        spec[bins] = syms
        td = np.fft.ifft(spec) * np.sqrt(cfg.nfft)
        if win > 0:
            # classic W-OFDM: CP + body + `win` cyclic suffix; taper the
            # first/last `win` samples; consecutive symbols overlap-add
            # only inside each other's tapered guard regions, so the
            # FFT body stays ISI-free (taper lives inside the CP).
            ext = np.concatenate([td[-cfg.cp :], td, td[:win]])
            w = np.ones(len(ext))
            w[:win] *= edge
            w[-win:] *= edge[::-1]
            x[s * sym_len : s * sym_len + len(ext)] += ext * w
        else:
            x[s * sym_len : (s + 1) * sym_len] = np.concatenate([td[-cfg.cp :], td])
    x = x[: cfg.n_symbols * sym_len]

    if cfg.fir_taps > 0:
        h = kaiser_lowpass(cfg.fir_taps, cfg.fir_cutoff, cfg.fir_beta)
        x = np.convolve(x, h, mode="same")

    x *= cfg.rms / np.sqrt((np.abs(x) ** 2).mean())
    return np.stack([x.real, x.imag], axis=-1)


def papr_db(x: np.ndarray) -> float:
    """Peak-to-average power ratio of an (T, 2) I/Q signal, in dB."""
    p = x[..., 0] ** 2 + x[..., 1] ** 2
    return 10.0 * np.log10(p.max() / p.mean())


def frames_from_signal(x: np.ndarray, frame_len: int = 50, stride: int | None = None) -> np.ndarray:
    """Cut (T, 2) into (N, frame_len, 2) training frames.

    The paper trains with frame length 50 and stride 1; we default to
    stride = frame_len (disjoint frames) which converges to the same
    model in far fewer steps — stride 1 just resamples the same data.
    """
    stride = stride or frame_len
    n = (x.shape[0] - frame_len) // stride + 1
    idx = np.arange(frame_len)[None, :] + stride * np.arange(n)[:, None]
    return x[idx]
