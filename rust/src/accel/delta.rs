//! Cost model of the delta execution path — what the measured column
//! sparsity of a [`DeltaStats`] stream is worth in MACs and energy on
//! DeltaDPD-style hardware (arXiv:2505.06250).
//!
//! The functional delta engines (`dpd::qgru::DeltaQGruDpd`,
//! `dpd::gru::DeltaGruDpd`) *count* which matvec columns actually
//! fired; this module *prices* those counts against the dense
//! datapath under one documented convention:
//!
//! * a skipped column saves its 3H MACs **and** its 3H weight-buffer
//!   reads (delta hardware fetches a column only to fold a delta in);
//! * gate-bias reads disappear entirely (the carried accumulators are
//!   persistent registers, preloaded once at reset);
//! * the FC stage (2 x H) stays dense — MACs, weight and hidden reads;
//! * the delta tests themselves cost F + H subtract-compares per
//!   sample (counted as ALU ops) and re-read the live vectors;
//! * the pipeline II is unchanged — the schedule still closes the
//!   recurrence in 8 cycles; delta skipping gates datapath *activity*
//!   (clock-gated PE columns), so it shows up in energy and in
//!   effective MAC throughput, not in latency.
//!
//! `benches/micro.rs` reports `mac_reduction` from this model next to
//! `delta_msps`, and the conformance suite holds the golden-waveform
//! reduction on the record.

use super::engine::EngineStats;
use super::fsm;
use super::ops::{macs_per_sample, ModelDims};
use super::power::EnergyModel;
use crate::dpd::qgru::ActKind;
use crate::dpd::DeltaStats;

/// Prices measured delta activity against the dense datapath.
#[derive(Clone, Copy, Debug)]
pub struct DeltaCostModel {
    pub dims: ModelDims,
}

impl DeltaCostModel {
    pub fn new(dims: ModelDims) -> DeltaCostModel {
        DeltaCostModel { dims }
    }

    /// Dense MACs per sample (the reduction denominator).
    pub fn dense_macs_per_sample(&self) -> f64 {
        macs_per_sample(self.dims) as f64
    }

    /// Measured MACs per sample on the delta path: only fired columns
    /// pay their 3H, the FC stays dense.
    pub fn delta_macs_per_sample(&self, s: &DeltaStats) -> f64 {
        let h = self.dims.hidden as f64;
        let steps = s.steps.max(1) as f64;
        (s.in_updates + s.hid_updates) as f64 / steps * 3.0 * h + 2.0 * h
    }

    /// Measured MAC-reduction factor (dense / delta; 1.0 = no win).
    pub fn mac_reduction(&self, s: &DeltaStats) -> f64 {
        self.dense_macs_per_sample() / self.delta_macs_per_sample(s)
    }

    /// Project the delta stream's per-unit activity into the shape the
    /// 22FDX energy model consumes, under the module's conventions.
    pub fn projected_stats(&self, s: &DeltaStats) -> EngineStats {
        let h = self.dims.hidden as u64;
        let f = self.dims.features as u64;
        let n = s.steps;
        let fired = s.in_updates + s.hid_updates;
        EngineStats {
            samples: n,
            cycles: n * fsm::II_CYCLES as u64,
            macs: fired * 3 * h + n * 2 * h,
            // dense gate/update ALU work (8 per hidden unit + 1 per
            // output + 4 preproc) plus the F + H delta compares
            alu_ops: n * (8 * h + 2 + 4) + n * (f + h),
            act_ops: n * 3 * h,
            // fired gate columns + dense FC weights + FC biases; gate
            // biases live in the persistent accumulators
            weight_reads: fired * 3 * h + n * (2 * h + 2),
            // delta compares re-read the live vectors (H) + z.h (H) +
            // FC (2H) reads of the committed hidden state
            hidden_reads: n * 4 * h,
            // committed hidden writes + propagated-column cache writes
            hidden_writes: n * h + s.hid_updates,
        }
    }

    /// Nominal-point (2 GHz, 0.9 V, 250 MSps) power of the delta
    /// stream under the energy model.
    pub fn projected_power_mw(&self, s: &DeltaStats, em: &EnergyModel, act: &ActKind) -> f64 {
        em.nominal_power_mw(&self.projected_stats(s), act)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic activity record at a given update ratio.
    fn stats_at(steps: u64, in_ratio: f64, hid_ratio: f64) -> DeltaStats {
        let d = ModelDims::default();
        DeltaStats {
            steps,
            in_updates: (steps as f64 * d.features as f64 * in_ratio) as u64,
            in_cols: steps * d.features as u64,
            hid_updates: (steps as f64 * d.hidden as f64 * hid_ratio) as u64,
            hid_cols: steps * d.hidden as u64,
        }
    }

    #[test]
    fn dense_activity_reproduces_the_dense_cost() {
        let m = DeltaCostModel::new(ModelDims::default());
        let s = stats_at(100, 1.0, 1.0);
        // every column fires -> no reduction, MACs equal the dense 440
        assert_eq!(m.delta_macs_per_sample(&s), 440.0);
        assert!((m.mac_reduction(&s) - 1.0).abs() < 1e-12);
        let p = m.projected_stats(&s);
        assert_eq!(p.macs, 100 * 440);
        assert_eq!(p.act_ops, 100 * 30);
        assert_eq!(p.samples, 100);
        assert_eq!(p.cycles_per_sample(), fsm::II_CYCLES as f64);
    }

    #[test]
    fn reduction_scales_with_sparsity() {
        let m = DeltaCostModel::new(ModelDims::default());
        // half the columns fire: (7 cols * 30) + 20 = 230 -> 1.91x
        let s = stats_at(1000, 0.5, 0.5);
        assert!((m.delta_macs_per_sample(&s) - 230.0).abs() < 1e-9);
        assert!((m.mac_reduction(&s) - 440.0 / 230.0).abs() < 1e-9);
        // full skip leaves only the dense FC floor
        let s0 = stats_at(1000, 0.0, 0.0);
        assert_eq!(m.delta_macs_per_sample(&s0), 20.0);
        assert!(m.mac_reduction(&s0) > 20.0);
    }

    #[test]
    fn projected_power_drops_monotonically_with_sparsity() {
        let m = DeltaCostModel::new(ModelDims::default());
        let em = EnergyModel::default();
        let dense = m.projected_power_mw(&stats_at(500, 1.0, 1.0), &em, &ActKind::Hard);
        let half = m.projected_power_mw(&stats_at(500, 0.5, 0.5), &em, &ActKind::Hard);
        let sparse = m.projected_power_mw(&stats_at(500, 0.1, 0.1), &em, &ActKind::Hard);
        assert!(dense > half && half > sparse, "{dense} / {half} / {sparse}");
        // the clock/overhead floor remains: even full sparsity cannot
        // reach zero
        let floor = m.projected_power_mw(&stats_at(500, 0.0, 0.0), &em, &ActKind::Hard);
        assert!(floor > 50.0, "overhead floor vanished: {floor}");
    }

    #[test]
    fn measured_engine_activity_feeds_the_model() {
        // End to end: run the real delta engine, price its counters.
        use crate::dpd::qgru::DeltaQGruDpd;
        use crate::dpd::weights::QGruWeights;
        use crate::fixed::QSpec;
        let w = QGruWeights::synthetic(7, QSpec::Q12);
        let mut dpd = DeltaQGruDpd::new(w, ActKind::Hard, 16);
        // constant stream: heavy skipping after warmup
        let x = vec![[500, -400]; 200];
        dpd.run_codes(&x);
        let m = DeltaCostModel::new(ModelDims::default());
        let red = m.mac_reduction(&dpd.stats());
        assert!(red > 1.5, "DC stream should cut MACs substantially, got {red:.2}x");
        let p = m.projected_stats(&dpd.stats());
        assert_eq!(p.samples, 200);
        assert!(p.macs < 200 * 440);
    }
}
